"""The unified scan-compiled training engine (repro.train.engine).

Equivalence guarantees, in order of strictness:

  * the ``sequential`` strategy (any ``scan_chunk``) reproduces the seed
    repo's Python-stepped loop — history to numerical tolerance, params
    bit-identically, *including the dropout rng stream*;
  * checkpoint at epoch e + resume == an uninterrupted run;
  * the ``async_ps`` strategy reproduces the pre-refactor async trainer's
    deterministic stale-gradient update sequence;
  * ``sync_mesh`` on one device is numerically inert.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSLHyper, build_affinity_graph, plan_meta_batches
from repro.data import MetaBatchPipeline, drop_labels, make_corpus
from repro.models.dnn import DNNConfig, init_dnn
from repro.optim import adagrad, constant_lr, parallel_lr_schedule
from repro.train import train_dnn_ssl
from repro.train.async_trainer import train_dnn_ssl_async
from repro.train.engine import Engine, TrainState, prefetch_to_device
from repro.train.train_step import dnn_ssl_loss, dnn_ssl_step

CFG = DNNConfig(input_dim=32, hidden_dim=48, n_hidden=2, n_classes=6,
                dropout=0.0)
HYPER = SSLHyper(0.3, 1e-4, 1e-5)


@pytest.fixture(scope="module")
def engine_setup():
    full = make_corpus(800, n_classes=6, input_dim=32, manifold_dim=5, seed=0)
    corpus = dataclasses.replace(
        full, X=full.X[:600], y=full.y[:600], label_mask=full.label_mask[:600])
    labeled = drop_labels(corpus, 0.1, seed=1)
    graph = build_affinity_graph(corpus.X, k=8)
    plan = plan_meta_batches(graph, batch_size=96, n_classes=6, seed=0)
    test = (full.X[600:], full.y[600:])
    return labeled, graph, plan, test


def fresh_pipeline(setup, n_workers: int = 1):
    labeled, graph, plan, _ = setup
    return MetaBatchPipeline(labeled, graph, plan, n_workers=n_workers,
                             seed=0).epoch


def max_param_delta(a, b) -> float:
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ------------------------------------------------- seed-loop reference
def python_loop_reference(pipeline_epoch, *, n_epochs, dropout, base_lr,
                          pairwise="ref", seed=0):
    """The seed repo's training loop, verbatim: one jitted step per batch,
    host-side rng splits, per-epoch metric means."""
    opt = adagrad()
    key = jax.random.PRNGKey(seed)
    key, init_key = jax.random.split(key)
    params = init_dnn(CFG, init_key)
    opt_state = opt.init(params)
    schedule = parallel_lr_schedule(base_lr, 1, 10)
    step_fn = jax.jit(
        lambda p, s, b, lr, rng: dnn_ssl_step(
            p, s, b, cfg=CFG, hyper=HYPER, opt=opt, lr=lr,
            dropout_rng=rng, dropout=dropout, pairwise=pairwise))
    history = []
    for epoch in range(n_epochs):
        lr = jnp.float32(schedule(epoch))
        ms = []
        for batch in pipeline_epoch():
            key, rng = jax.random.split(key)
            jb = {k: jnp.asarray(v)
                  for k, v in dataclasses.asdict(batch).items()
                  if v is not None}
            params, opt_state, metrics = step_fn(params, opt_state, jb, lr,
                                                 rng)
            ms.append(metrics)
        history.append(
            {k: float(np.mean([float(m[k]) for m in ms])) for k in ms[0]})
    return params, history


# ----------------------------------------------------------- TrainState
def test_train_state_is_a_pytree():
    state = TrainState.create({"w": jnp.ones(3)}, {"accum": jnp.zeros(3)},
                              jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(state)
    again = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(again, TrainState)
    assert int(again.step) == 0

    bumped = jax.jit(lambda s: dataclasses.replace(s, step=s.step + 1))(state)
    assert int(bumped.step) == 1
    assert isinstance(bumped, TrainState)


# --------------------------------------------- sequential scan == seed loop
@pytest.mark.parametrize("scan_chunk,dropout", [
    (1, 0.0),    # per-step scan == the seed loop, the satellite's contract
    (1, 0.2),    # ...including the dropout rng stream
    (0, 0.2),    # whole-epoch compilation changes nothing
    (3, 0.0),    # nor does chunking with a ragged remainder
])
def test_sequential_scan_reproduces_seed_loop(engine_setup, scan_chunk,
                                              dropout):
    want_params, want_hist = python_loop_reference(
        fresh_pipeline(engine_setup), n_epochs=3, dropout=dropout,
        base_lr=5e-3)
    res = train_dnn_ssl(
        fresh_pipeline(engine_setup), cfg=CFG, hyper=HYPER, n_epochs=3,
        dropout=dropout, base_lr=5e-3, seed=0, pairwise="ref",
        scan_chunk=scan_chunk)
    assert len(res.history) == len(want_hist)
    for got, want in zip(res.history, want_hist):
        for k in want:
            np.testing.assert_allclose(got[k], want[k], rtol=1e-5,
                                       err_msg=k)
    assert max_param_delta(res.params, want_params) == 0.0
    assert int(res.state.step) == 3 * len(
        list(fresh_pipeline(engine_setup)()))


def test_prefetch_depth_does_not_change_results(engine_setup):
    kw = dict(cfg=CFG, hyper=HYPER, n_epochs=2, dropout=0.2, base_lr=5e-3,
              seed=0, pairwise="ref")
    res0 = train_dnn_ssl(fresh_pipeline(engine_setup), prefetch=0, **kw)
    res2 = train_dnn_ssl(fresh_pipeline(engine_setup), prefetch=2, **kw)
    assert max_param_delta(res0.params, res2.params) == 0.0


# ----------------------------------------------------------- sync_mesh
def test_sync_mesh_strategy_matches_sequential(engine_setup):
    """On one device the replicated/sharded placement is numerically inert."""
    kw = dict(cfg=CFG, hyper=HYPER, n_epochs=2, dropout=0.0, base_lr=5e-3,
              seed=0, pairwise="ref", n_workers=2)
    seq = train_dnn_ssl(fresh_pipeline(engine_setup, 2), **kw)
    mesh = train_dnn_ssl(fresh_pipeline(engine_setup, 2),
                         strategy="sync_mesh", **kw)
    for a, b in zip(seq.history, mesh.history):
        np.testing.assert_allclose(a["loss/total"], b["loss/total"],
                                   rtol=1e-6)


# ------------------------------------------------------------- async_ps
def async_reference(pipeline_epoch, *, n_epochs, n_workers, max_staleness,
                    base_lr, seed=0):
    """The pre-refactor async trainer, verbatim: round-robin workers pushing
    stale gradients, snapshots refreshed every ``max_staleness`` pushes."""
    opt = adagrad()
    params = init_dnn(CFG, jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    grad_fn = jax.jit(lambda p, b: jax.grad(
        lambda q: dnn_ssl_loss(q, b, CFG, HYPER)[0])(p))
    update_fn = jax.jit(lambda g, s, p, lr: opt.update(g, s, p, lr))
    snapshots = [params] * n_workers
    ages = [0] * n_workers
    for _ in range(n_epochs):
        for step, batch in enumerate(pipeline_epoch()):
            w = step % n_workers
            jb = {k: jnp.asarray(v)
                  for k, v in dataclasses.asdict(batch).items()
                  if v is not None}
            g = grad_fn(snapshots[w], jb)
            params, opt_state = update_fn(g, opt_state, params,
                                          jnp.float32(base_lr))
            ages[w] += 1
            if ages[w] >= max_staleness:
                snapshots[w] = params
                ages[w] = 0
    return params


@pytest.mark.parametrize("n_workers,max_staleness", [(4, 2), (3, 1)])
def test_async_ps_reproduces_reference_update_sequence(engine_setup,
                                                       n_workers,
                                                       max_staleness):
    want = async_reference(fresh_pipeline(engine_setup), n_epochs=2,
                           n_workers=n_workers, max_staleness=max_staleness,
                           base_lr=5e-3)
    got, hist = train_dnn_ssl_async(
        fresh_pipeline(engine_setup), cfg=CFG, hyper=HYPER, n_epochs=2,
        n_workers=n_workers, max_staleness=max_staleness, base_lr=5e-3,
        seed=0)
    assert max_param_delta(got, want) == 0.0
    assert [h["epoch"] for h in hist] == [0, 1]


# ------------------------------------------------------ checkpoint/resume
def test_checkpoint_then_resume_matches_uninterrupted(engine_setup, tmp_path):
    kw = dict(cfg=CFG, hyper=HYPER, dropout=0.2, base_lr=5e-3, seed=0,
              pairwise="ref")
    uninterrupted = train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=4,
                                  **kw)
    # Run 1: train 2 epochs, checkpointing every 2.
    train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=2,
                  checkpoint_every=2, checkpoint_dir=str(tmp_path), **kw)
    assert (tmp_path / "ckpt_00002.npz").exists()
    assert (tmp_path / "LATEST").read_text() == "ckpt_00002"
    # Run 2 (fresh process state): resume and finish.
    resumed = train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=4,
                            checkpoint_every=2, checkpoint_dir=str(tmp_path),
                            resume=True, **kw)
    assert max_param_delta(resumed.params, uninterrupted.params) == 0.0
    assert [r["epoch"] for r in resumed.history] == [0, 1, 2, 3]
    for a, b in zip(uninterrupted.history, resumed.history):
        for k in ("loss/total", "loss/graph", "lr"):
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6, err_msg=k)
    # rng and step counters were part of the restored state.
    assert int(resumed.state.step) == int(uninterrupted.state.step)
    np.testing.assert_array_equal(np.asarray(resumed.state.rng),
                                  np.asarray(uninterrupted.state.rng))


def test_resume_of_completed_run_skips_pipeline_replay(engine_setup,
                                                       tmp_path):
    """Resuming a job that already finished must return the saved result
    without re-walking the data pipeline for the skipped epochs."""
    kw = dict(cfg=CFG, hyper=HYPER, dropout=0.0, base_lr=5e-3, seed=0,
              pairwise="ref", checkpoint_every=2,
              checkpoint_dir=str(tmp_path))
    train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=2, **kw)

    def exploding_pipeline():
        raise AssertionError("completed run must not touch the pipeline")

    res = train_dnn_ssl(exploding_pipeline, n_epochs=2, resume=True, **kw)
    assert [r["epoch"] for r in res.history] == [0, 1]


def test_resume_without_checkpoint_starts_fresh(engine_setup, tmp_path):
    res = train_dnn_ssl(fresh_pipeline(engine_setup), cfg=CFG, hyper=HYPER,
                        n_epochs=1, dropout=0.0, base_lr=5e-3, seed=0,
                        pairwise="ref", checkpoint_every=1,
                        checkpoint_dir=str(tmp_path / "empty"), resume=True)
    assert [r["epoch"] for r in res.history] == [0]


def test_async_checkpoint_carries_snapshots(engine_setup, tmp_path):
    """async_ps checkpoints the whole strategy carry (snapshots + ages), so
    a resumed stale-gradient run is exact too."""
    want = async_reference(fresh_pipeline(engine_setup), n_epochs=4,
                           n_workers=3, max_staleness=2, base_lr=5e-3)
    common = dict(cfg=CFG, hyper=HYPER, n_workers=3, max_staleness=2,
                  base_lr=5e-3, dropout=0.0, seed=0, strategy="async_ps",
                  lr_schedule=constant_lr(5e-3),
                  params=init_dnn(CFG, jax.random.PRNGKey(0)))
    uninterrupted = train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=4,
                                  **common)
    ckpt = dict(checkpoint_every=2, checkpoint_dir=str(tmp_path))
    train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=2, **common, **ckpt)
    resumed = train_dnn_ssl(fresh_pipeline(engine_setup), n_epochs=4,
                            resume=True, **common, **ckpt)
    # Exact vs the uninterrupted engine run (the carry roundtrip is
    # lossless); tolerance vs the two-jit reference loop (XLA fuses the
    # scan body differently — ulp-level drift over 4 epochs is expected).
    assert max_param_delta(resumed.params, uninterrupted.params) == 0.0
    for a, b in zip(jax.tree.leaves(resumed.params), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ------------------------------------------------------------- prefetch
def test_prefetch_preserves_order_and_exhausts():
    got = list(prefetch_to_device(range(20), lambda x: x * 2, depth=3))
    assert got == [x * 2 for x in range(20)]


def test_prefetch_propagates_producer_errors():
    def bad_put(x):
        if x == 3:
            raise RuntimeError("boom at 3")
        return x

    it = prefetch_to_device(range(10), bad_put, depth=2)
    with pytest.raises(RuntimeError, match="boom at 3"):
        list(it)


def test_prefetch_stops_producer_when_abandoned():
    """An early-exiting consumer (step exception, closed generator) must not
    strand the producer thread or keep staging chunks."""
    import threading
    import time as _time

    produced = []
    it = prefetch_to_device(iter(range(10_000)), lambda x: produced.append(x)
                            or x, depth=2)
    assert next(it) == 0
    it.close()    # GeneratorExit at the yield → stop + drain + join
    deadline = _time.monotonic() + 5.0
    while _time.monotonic() < deadline and any(
            t.name == "engine-prefetch" and t.is_alive()
            for t in threading.enumerate()):
        _time.sleep(0.02)
    assert not any(t.name == "engine-prefetch" and t.is_alive()
                   for t in threading.enumerate())
    assert len(produced) < 10_000


def test_async_ps_rejects_dropout():
    with pytest.raises(ValueError, match="dropout"):
        train_dnn_ssl(lambda: iter(()), cfg=CFG, hyper=HYPER, n_epochs=1,
                      dropout=0.2, strategy="async_ps")


# ----------------------------------------------------------- validation
def test_engine_rejects_bad_configuration():
    step = lambda s, b, lr: (s, {})  # noqa: E731
    with pytest.raises(ValueError, match="scan_chunk"):
        Engine(step, scan_chunk=-1)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        Engine(step, checkpoint_every=2)
    with pytest.raises(ValueError, match="grad_fn"):
        Engine(step, strategy="async_ps")       # no grad_fn/opt
    with pytest.raises(ValueError, match="mesh"):
        Engine(step, strategy="sync_mesh")      # no mesh
    with pytest.raises(ValueError, match="step_fn"):
        Engine(None, strategy="sequential")
    with pytest.raises(KeyError, match="strategy"):
        Engine(step, strategy="warp_drive")


def test_empty_epoch_warns_and_skips_row():
    state = TrainState.create({"w": jnp.ones(2)}, {}, jax.random.PRNGKey(0))
    eng = Engine(lambda s, b, lr: (s, {"loss": jnp.float32(0)}))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = eng.run(lambda: iter(()), state=state, n_epochs=2,
                      lr_schedule=constant_lr(1e-3))
    assert res.history == []
    assert any("no batches" in str(w.message) for w in caught)
