"""Optimizers, schedules, checkpointing, data pipeline, MoE routing, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (MetaBatchPipeline, drop_labels, lm_batches,
                        make_corpus, make_token_corpus, random_batch_pipeline,
                        sequence_features)
from repro.models.layers.moe import apply_moe, init_moe
from repro.optim import adagrad, adam, constant_lr, parallel_lr_schedule, sgd
from repro.serve.decode import generate, sample_tokens
from repro.train import load_checkpoint, save_checkpoint


# --------------------------------------------------------------- optimizers
@pytest.mark.parametrize("opt,lr,steps", [(adagrad(), 0.5, 500),
                                          (sgd(0.9), 0.1, 300),
                                          (adam(), 0.1, 300)])
def test_optimizers_minimize_quadratic(opt, lr, steps):
    # AdaGrad's effective step decays 1/√t — give it a larger lr + budget.
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.tree.map(lambda w: 2 * w, params)   # d/dw ||w||²
        params, state = opt.update(grads, state, params, lr)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_adagrad_accumulator_monotone():
    opt = adagrad()
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    prev = state["accum"]["w"]
    for _ in range(5):
        params, state = opt.update({"w": jnp.ones(3)}, state, params, 0.01)
        assert (state["accum"]["w"] >= prev).all()
        prev = state["accum"]["w"]


def test_parallel_lr_schedule_paper_rule():
    """§3: lr = 0.001·k for 10 epochs, then reset to 0.001."""
    s = parallel_lr_schedule(1e-3, n_workers=8, reset_epochs=10)
    assert s(0) == pytest.approx(8e-3)
    assert s(9) == pytest.approx(8e-3)
    assert s(10) == pytest.approx(1e-3)
    assert constant_lr(5e-4)(100) == 5e-4


# -------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones(4), jnp.zeros((2, 2), jnp.int32)]}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored = load_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_bare_path_normalized_once(tmp_path):
    """A bare (no ``.npz``) path must produce ONE archive (plus its
    checksum sidecar) that the same bare path loads back — ``np.savez``
    used to append a second extension behind the caller's back and desync
    save/load."""
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    bare = str(tmp_path / "ckpt")
    save_checkpoint(bare, tree)
    assert sorted(os.listdir(tmp_path)) == ["ckpt.npz", "ckpt.npz.sha256"]
    for p in (bare, bare + ".npz"):
        restored = load_checkpoint(p, jax.tree.map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


def test_checkpoint_restore_preserves_saved_dtypes(tmp_path):
    """Restore keeps the dtype each leaf was SAVED with: a uint32 PRNG key
    or int32 step counter must not be cast to the template leaf's dtype."""
    tree = {"rng": jax.random.PRNGKey(7), "step": jnp.asarray(5, jnp.int32),
            "w": jnp.ones(3, jnp.bfloat16)}
    path = str(tmp_path / "state")
    save_checkpoint(path, tree)
    # Template with the right shapes but wrong dtypes everywhere.
    like = {"rng": np.zeros(2, np.float64), "step": np.float32(0),
            "w": np.zeros(3, np.float32)}
    restored = load_checkpoint(path, like)
    assert restored["rng"].dtype == np.uint32
    assert restored["step"].dtype == np.int32
    assert restored["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(restored["rng"],
                                  np.asarray(tree["rng"]))


# --------------------------------------------------------------------- data
def test_corpus_and_label_dropping():
    c = make_corpus(800, n_classes=13, input_dim=40, seed=3)
    assert c.X.shape == (800, 40) and c.label_mask.all()
    d = drop_labels(c, 0.02, seed=0)
    assert 0.01 < d.label_ratio() < 0.08
    # at least one label per class survives
    for cls in range(13):
        assert d.label_mask[d.y == cls].any()


def test_metabatch_pipeline_shapes_and_padding(small_graph_setup):
    corpus, graph, plan = small_graph_setup
    pipe = MetaBatchPipeline(corpus, graph, plan, n_workers=2, seed=0)
    batch = next(iter(pipe.epoch()))
    k, P = batch.x.shape[:2]
    assert k == 2 and P % 64 == 0
    assert batch.W.shape == (2, P, P)
    assert batch.valid.shape == (2, P)
    # padding rows have zero affinity and zero mask
    for w in range(2):
        pad = ~batch.valid[w]
        assert batch.W[w][pad].sum() == 0
        assert batch.label_mask[w][pad].sum() == 0
    # affinity block symmetric
    np.testing.assert_allclose(batch.W[0], batch.W[0].T, atol=1e-6)


def test_random_pipeline_low_connectivity(small_graph_setup):
    """Fig 1a regime: random batches carry almost no within-batch affinity."""
    corpus, graph, plan = small_graph_setup
    rnd = next(iter(random_batch_pipeline(corpus, graph, 192, seed=0)))
    meta = next(iter(MetaBatchPipeline(corpus, graph, plan, seed=0).epoch()))
    per_row_rnd = rnd.W[0].sum() / rnd.valid[0].sum()
    per_row_meta = meta.W[0].sum() / meta.valid[0].sum()
    assert per_row_meta > 2 * per_row_rnd


def test_token_corpus_and_features():
    toks, topics = make_token_corpus(40, 64, 500, n_topics=4, seed=0)
    assert toks.shape == (40, 64) and toks.max() < 500
    feats = sequence_features(toks, 500, dim=16)
    assert feats.shape == (40, 16)
    # same-topic sequences are closer on average than cross-topic
    from repro.core.affinity import pairwise_sq_dists
    d = pairwise_sq_dists(feats, feats)
    same = d[topics[:, None] == topics[None, :]].mean()
    diff = d[topics[:, None] != topics[None, :]].mean()
    assert same < diff
    x, y = next(lm_batches(toks, 8))
    assert x.shape == (8, 63) and (x[:, 1:] == y[:, :-1]).all()


# ---------------------------------------------------------------------- MoE
def test_moe_no_drop_equals_dense_mixture(rng):
    """With capacity ≥ all assignments, dispatch == explicit top-k mixture."""
    B, T, d, E, k, f = 2, 6, 16, 4, 2, 32
    p = init_moe(jax.random.PRNGKey(0), d, f, E, "swiglu")
    x = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    y, aux = apply_moe(p, x, top_k=k, capacity_factor=float(E * 4),
                       activation="swiglu")
    # explicit dense computation
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / top_w.sum(-1, keepdims=True)

    def expert(e, h):
        g = jax.nn.silu(h @ p["wg"][e])
        u = h @ p["wu"][e]
        return (g * u) @ p["wd"][e]

    want = jnp.zeros_like(x)
    for e in range(E):
        w_e = jnp.sum(jnp.where(top_e == e, top_w, 0.0), -1)
        want = want + w_e[..., None] * expert(e, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=2e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens(rng):
    B, T, d, E = 1, 32, 8, 2
    p = init_moe(jax.random.PRNGKey(1), d, 16, E, "gelu")
    x = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    y_tight, _ = apply_moe(p, x, top_k=1, capacity_factor=0.25,
                           activation="gelu")
    y_loose, _ = apply_moe(p, x, top_k=1, capacity_factor=4.0,
                           activation="gelu")
    # tight capacity must zero-out some tokens' outputs
    dropped = np.asarray(jnp.abs(y_tight).sum(-1) == 0).sum()
    kept = np.asarray(jnp.abs(y_loose).sum(-1) == 0).sum()
    assert dropped > kept


# ------------------------------------------------------------------ serving
def test_sample_tokens_greedy_vs_temperature(rng):
    logits = jnp.asarray(rng.normal(size=(3, 1, 50)), jnp.float32)
    g = sample_tokens(logits, jax.random.PRNGKey(0), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(g[:, 0]),
                                  np.asarray(jnp.argmax(logits[:, -1], -1)))
    s = sample_tokens(logits, jax.random.PRNGKey(0), temperature=1.0,
                      top_k=5)
    assert s.shape == (3, 1)


def test_generate_greedy_deterministic():
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    a = generate(params, cfg, prompt, steps=6, cache_len=32)
    b = generate(params, cfg, prompt, steps=6, cache_len=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (1, 10)


def test_generate_key_stream_independent_of_prefill_length():
    """Regression: prefill must consume no RNG — the sampled continuation's
    key stream is a function of ``seed`` alone, so two prompts of different
    lengths draw identical samples when the logits don't discriminate.

    Zeroed params make every step's logits constant (uniform sampling), so
    any continuation difference could only come from the key stream.  The
    seed implementation reused the unsplit key across prefill steps and
    re-split it in the decode loop, shifting the stream by prompt length.
    """
    from repro.configs import get_config
    from repro.models import transformer as tf
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = jax.tree.map(jnp.zeros_like,
                          tf.init_params(cfg, jax.random.PRNGKey(0)))
    short = jnp.asarray([[1, 2]], jnp.int32)
    long = jnp.asarray([[5, 6, 7, 8, 9]], jnp.int32)
    a = generate(params, cfg, short, steps=8, cache_len=32,
                 temperature=1.0, seed=3)
    b = generate(params, cfg, long, steps=8, cache_len=32,
                 temperature=1.0, seed=3)
    np.testing.assert_array_equal(np.asarray(a[:, 2:]), np.asarray(b[:, 5:]))
    # sanity: a different seed draws a different continuation
    c = generate(params, cfg, short, steps=8, cache_len=32,
                 temperature=1.0, seed=4)
    assert not np.array_equal(np.asarray(a[:, 2:]), np.asarray(c[:, 2:]))
