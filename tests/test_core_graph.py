"""Affinity graph, partitioner, meta-batch synthesis — unit + property tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (build_affinity_graph, edge_cut, partition_graph,
                        partition_permutation)
from repro.core.affinity import knn_edges, pairwise_sq_dists
from repro.core.metabatch import NeighborSampler, batch_graph
from repro.core.stats import (batch_label_entropy, connectivity_distribution,
                              entropy_distribution, random_batches)


# ----------------------------------------------------------------- affinity
def test_pairwise_sq_dists_matches_numpy(rng):
    X = rng.normal(size=(40, 7))
    Y = rng.normal(size=(25, 7))
    d2 = pairwise_sq_dists(X, Y)
    ref = ((X[:, None] - Y[None]) ** 2).sum(-1)
    np.testing.assert_allclose(d2, ref, atol=1e-8)


def test_knn_exactness_against_bruteforce(rng):
    X = rng.normal(size=(150, 10))
    src, dst, d2 = knn_edges(X, 5, block=32)
    full = pairwise_sq_dists(X, X)
    np.fill_diagonal(full, np.inf)
    for i in range(150):
        mine = set(dst[src == i])
        ref = set(np.argsort(full[i])[:5])
        # allow ties at the boundary
        assert len(mine & ref) >= 4


def test_affinity_graph_symmetric_zero_diag(small_graph_setup):
    _, graph, _ = small_graph_setup
    W = graph.W
    assert (W != W.T).nnz == 0
    assert W.diagonal().sum() == 0
    assert W.data.min() > 0 and W.data.max() <= 1.0 + 1e-9
    # every node has at least k neighbours after symmetrization
    assert graph.neighbor_counts().min() >= graph.k


def test_permuted_graph_preserves_weights(small_graph_setup):
    _, graph, plan = small_graph_setup
    perm = partition_permutation(plan.mini_block_labels)
    gp = graph.permuted(perm)
    assert gp.W.nnz == graph.W.nnz
    np.testing.assert_allclose(gp.W.sum(), graph.W.sum(), rtol=1e-9)
    # spot check: entry (a, b) in permuted == (perm[a], perm[b]) in original
    a, b = 3, 17
    np.testing.assert_allclose(gp.W[a, b], graph.W[perm[a], perm[b]])


def test_dense_block_matches_csr(small_graph_setup):
    _, graph, _ = small_graph_setup
    idx = np.arange(0, 60, 2)
    blk = graph.dense_block(idx)
    ref = np.asarray(graph.W[idx][:, idx].todense())
    np.testing.assert_allclose(blk, ref, atol=1e-7)


# ---------------------------------------------------------------- partition
def test_partition_balanced_and_better_than_random(small_graph_setup):
    _, graph, _ = small_graph_setup
    k = 12
    res = partition_graph(graph.W, k, tol=0.15, seed=0)
    n = graph.n_nodes
    assert res.sizes.sum() == n
    assert res.sizes.max() <= int(np.ceil(n / k * 1.3))
    # min-cut partitioning beats a random balanced split decisively
    rng = np.random.default_rng(0)
    rand_labels = rng.permutation(np.arange(n) % k)
    assert res.cut < 0.7 * edge_cut(graph.W, rand_labels)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 120), k=st.integers(2, 6), seed=st.integers(0, 5))
def test_partition_properties(n, k, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 4))
    g = build_affinity_graph(X, k=4)
    res = partition_graph(g.W, k, tol=0.3, seed=seed)
    assert res.labels.shape == (n,)
    assert res.labels.min() >= 0 and res.labels.max() < k
    assert res.sizes.sum() == n
    perm = partition_permutation(res.labels)
    assert sorted(perm) == list(range(n))
    # permutation groups labels contiguously
    assert (np.diff(res.labels[perm]) >= 0).all()


# --------------------------------------------------------------- metabatch
def test_meta_batches_partition_the_dataset(small_graph_setup):
    corpus, _, plan = small_graph_setup
    allidx = np.concatenate(plan.meta_batches)
    assert sorted(allidx) == list(range(corpus.n))  # exactly-once cover


def test_meta_batch_sizes_near_B(small_graph_setup):
    _, _, plan = small_graph_setup
    sizes = np.array([len(m) for m in plan.meta_batches])
    assert (sizes > 0.5 * plan.batch_size).all()
    assert (sizes < 1.9 * plan.batch_size).all()


def test_meta_batches_improve_connectivity_vs_random(small_graph_setup):
    corpus, graph, plan = small_graph_setup
    rng = np.random.default_rng(1)
    c_meta = connectivity_distribution(graph, plan.meta_batches)
    c_rand = connectivity_distribution(
        graph, random_batches(corpus.n, plan.batch_size, rng=rng))
    assert c_meta.mean() > 2.0 * c_rand.mean()


def test_meta_batch_entropy_recovers_toward_global(small_graph_setup):
    """Fig 2a: meta-batches ≈ global entropy, mini-blocks are much lower."""
    corpus, graph, plan = small_graph_setup
    glob = batch_label_entropy(corpus.y, np.arange(corpus.n), corpus.n_classes)
    e_meta = entropy_distribution(corpus.y, plan.meta_batches,
                                  corpus.n_classes)
    blocks = [np.where(plan.mini_block_labels == b)[0]
              for b in range(plan.mini_block_labels.max() + 1)]
    e_mini = entropy_distribution(corpus.y, blocks, corpus.n_classes)
    assert e_meta.mean() > e_mini.mean()
    assert e_meta.mean() > 0.75 * glob


def test_neighbor_sampler_eq6(small_graph_setup):
    _, graph, plan = small_graph_setup
    s = NeighborSampler(plan.batch_edges, seed=0)
    for i in range(plan.n_meta):
        nbrs, p = s.probs(i)
        if len(nbrs):
            np.testing.assert_allclose(p.sum(), 1.0)
            assert (p > 0).all()
            j = s.sample(i)
            assert j in set(nbrs.tolist())
    # Eq 6: probability proportional to |C_ij|
    E = plan.batch_edges
    i = int(np.argmax(np.diff(E.indptr)))
    nbrs, p = s.probs(i)
    w = np.array([E[i, j] for j in nbrs])
    np.testing.assert_allclose(p, w / w.sum())


def test_batch_graph_counts_cross_edges(small_graph_setup):
    corpus, graph, plan = small_graph_setup
    meta_of_node = plan.meta_of_block[plan.mini_block_labels]
    E = batch_graph(graph, meta_of_node, plan.n_meta)
    # total cross-meta edge count equals the complement of within-batch edges
    coo = graph.W.tocoo()
    cross = (meta_of_node[coo.row] != meta_of_node[coo.col]).sum() / 2
    np.testing.assert_allclose(E.sum(), 2 * cross / 2)  # symmetric storage
    assert (E != E.T).nnz == 0


def test_meta_batch_connectivity_variance_reduction(small_graph_setup):
    """§2.1: Var[C_meta] ≈ Var[C_mini]/K, mean preserved (Fig 2b)."""
    corpus, graph, plan = small_graph_setup
    blocks = [np.where(plan.mini_block_labels == b)[0]
              for b in range(plan.mini_block_labels.max() + 1)]
    c_mini = connectivity_distribution(graph, blocks)
    c_meta = connectivity_distribution(graph, plan.meta_batches)
    assert c_meta.mean() >= 0.8 * c_mini.mean()    # E[C_meta] >= E[C_mini] (approx)
    assert c_meta.std() < c_mini.std()             # variance shrinks
