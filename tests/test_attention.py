"""Flash attention vs O(T²) oracle: shape/dtype/mask sweeps + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers.attention import (KVCache, chunked_attention,
                                           decode_attention,
                                           reference_attention)


def _mk(rng, B, Tq, Tk, H, KV, hd, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(B, Tq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Tk, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Tk, KV, hd)), dtype)
    qp = jnp.arange(Tk - Tq, Tk)
    kp = jnp.arange(Tk)
    kval = jnp.ones(Tk, bool)
    return q, k, v, qp, kp, kval


@pytest.mark.parametrize("causal,window", [(True, None), (True, 9),
                                           (False, None)])
@pytest.mark.parametrize("shapes", [(1, 16, 16, 4, 4, 8),
                                    (2, 33, 47, 8, 2, 16),
                                    (3, 5, 64, 6, 3, 32)])
def test_flash_matches_reference(rng, causal, window, shapes):
    B, Tq, Tk, H, KV, hd = shapes
    q, k, v, qp, kp, kval = _mk(rng, B, Tq, Tk, H, KV, hd)
    a = chunked_attention(q, k, v, qp, kp, kval, causal=causal, window=window,
                          q_block=8, kv_block=16)
    b = reference_attention(q, k, v, qp, kp, kval, causal=causal,
                            window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_flash_gradients_match_reference(rng):
    B, Tq, Tk, H, KV, hd = 2, 20, 20, 4, 2, 8
    q, k, v, qp, kp, kval = _mk(rng, B, Tq, Tk, H, KV, hd)

    def loss(fn, q, k, v):
        o = fn(q, k, v, qp, kp, kval, causal=True, window=None)
        return jnp.sum(o * o)

    import functools
    f_flash = functools.partial(chunked_attention, q_block=8, kv_block=8)
    g1 = jax.grad(lambda *a: loss(f_flash, *a), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: loss(reference_attention, *a),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_flash_bf16_tolerance(rng):
    B, Tq, Tk, H, KV, hd = 2, 32, 32, 4, 4, 16
    q, k, v, qp, kp, kval = _mk(rng, B, Tq, Tk, H, KV, hd, jnp.bfloat16)
    a = chunked_attention(q, k, v, qp, kp, kval, causal=True, window=None,
                          q_block=16, kv_block=16)
    b = reference_attention(q, k, v, qp, kp, kval, causal=True, window=None)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=3e-2)


@settings(max_examples=15, deadline=None)
@given(Tq=st.integers(1, 40), Tk=st.integers(1, 60),
       qb=st.integers(1, 16), kb=st.integers(1, 16),
       causal=st.booleans(), seed=st.integers(0, 50))
def test_flash_property_sweep(Tq, Tk, qb, kb, causal, seed):
    if causal and Tq > Tk:
        Tq = Tk
    rng = np.random.default_rng(seed)
    q, k, v, qp, kp, kval = _mk(rng, 1, Tq, Tk, 2, 2, 8)
    a = chunked_attention(q, k, v, qp, kp, kval, causal=causal, window=None,
                          q_block=qb, kv_block=kb)
    b = reference_attention(q, k, v, qp, kp, kval, causal=causal, window=None)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


def test_decode_matches_full_attention_last_row(rng):
    """Single-query decode over a cache == last row of full attention."""
    B, T, H, KV, hd = 2, 17, 4, 2, 8
    q, k, v, qp, kp, kval = _mk(rng, B, T, T, H, KV, hd)
    full = reference_attention(q, k, v, kp, kp, kval, causal=True,
                               window=None)
    cache = KVCache(k=k, v=v,
                    positions=jnp.tile(kp[None], (B, 1)),
                    valid=jnp.ones((B, T), bool))
    o = decode_attention(q[:, -1:], cache.k, cache.v, cache.positions,
                         cache.valid, jnp.full((B,), T - 1), window=None)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_ring_cache_update_wraps(rng):
    cache = KVCache.init(1, 4, 1, 2, jnp.float32)
    for pos in range(6):
        kv = jnp.full((1, 1, 1, 2), float(pos))
        cache = cache.update(kv, kv, jnp.array([pos]))
    # slots hold positions 4,5,2,3 (pos%4)
    np.testing.assert_array_equal(np.asarray(cache.positions[0]),
                                  [4, 5, 2, 3])
    assert bool(cache.valid.all())


@pytest.mark.parametrize("window", [None, 16])
def test_triangular_tile_skipping_matches_reference(rng, window):
    """sequential_positions=True must be numerically identical (it only
    skips fully-masked tiles)."""
    B, T, H, KV, hd = 2, 50, 4, 2, 8
    q, k, v, qp, kp, kval = _mk(rng, B, T, T, H, KV, hd)
    a = chunked_attention(q, k, v, qp, kp, kval, causal=True, window=window,
                          q_block=8, kv_block=8, sequential_positions=True)
    b = reference_attention(q, k, v, qp, kp, kval, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
    # gradients too
    f = lambda q, k, v: jnp.sum(chunked_attention(
        q, k, v, qp, kp, kval, causal=True, window=window, q_block=8,
        kv_block=8, sequential_positions=True) ** 2)
    g = lambda q, k, v: jnp.sum(reference_attention(
        q, k, v, qp, kp, kval, causal=True, window=window) ** 2)
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-4)


def test_triangular_tile_count():
    from repro.models.layers.attention import _tri_tile_list
    # causal square: n(n+1)/2 tiles
    assert len(_tri_tile_list(8, 8, 64, 64, 512, 512, causal=True,
                              window=None, sequential=True)) == 36
    # window of one block: ~2 tiles per row
    t = _tri_tile_list(8, 8, 64, 64, 512, 512, causal=True, window=64,
                       sequential=True)
    assert len(t) <= 16
    # non-sequential: full grid
    assert len(_tri_tile_list(8, 8, 64, 64, 512, 512, causal=True,
                              window=None, sequential=False)) == 64
