import numpy as np
import pytest

# NB: no XLA_FLAGS here on purpose — tests and benches must see ONE device;
# only launch/dryrun.py forces the 512-device placeholder platform.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def small_graph_setup():
    """Shared synthetic corpus + affinity graph + meta-batch plan."""
    from repro.core import build_affinity_graph, plan_meta_batches
    from repro.data import make_corpus

    corpus = make_corpus(1200, n_classes=8, input_dim=48, manifold_dim=6,
                         seed=0)
    graph = build_affinity_graph(corpus.X, k=10)
    plan = plan_meta_batches(graph, batch_size=192, n_classes=8, seed=0)
    return corpus, graph, plan
