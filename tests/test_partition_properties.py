"""Property-based lockdown of the vectorized multilevel partitioner.

For random graphs, the partition invariants must hold unconditionally
(every node labeled, strict balance cap, cut arithmetic exact, cut
invariant under node relabeling), and on the affinity-graph domain the
vectorized partitioner's edge-cut must stay within 5% of the seed
per-node-loop implementation on identical seeds.
"""
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import build_affinity_graph
from repro.core.partition import (edge_cut, partition_graph,
                                  partition_graph_loop,
                                  partition_permutation)


def random_sparse_graph(n: int, m: int, seed: int) -> sp.csr_matrix:
    """Random symmetric weighted graph — possibly disconnected, possibly
    with isolated nodes (the invariants must survive all of that)."""
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, size=m)
    c = rng.integers(0, n, size=m)
    keep = r != c
    r, c = r[keep], c[keep]
    w = rng.uniform(0.1, 1.0, size=len(r))
    W = sp.csr_matrix((np.r_[w, w], (np.r_[r, c], np.r_[c, r])),
                      shape=(n, n))
    W.sum_duplicates()
    return W


def brute_force_cut(W: sp.csr_matrix, labels: np.ndarray) -> float:
    """O(n^2) dense recount of the cut, independent of edge_cut's path."""
    D = np.asarray(W.todense())
    total = 0.0
    n = len(labels)
    for i in range(n):
        for j in range(i + 1, n):
            if labels[i] != labels[j]:
                total += D[i, j]
    return total


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 90), mult=st.integers(1, 4),
       k=st.integers(2, 8), seed=st.integers(0, 10))
def test_partition_invariants_on_random_graphs(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    tol = 0.3
    res = partition_graph(W, k, tol=tol, seed=seed)
    # Every node labeled, ids in range, sizes account for every node.
    assert res.labels.shape == (n,)
    assert res.labels.min() >= 0 and res.labels.max() < max(k, 1)
    assert res.sizes.sum() == n
    assert res.n_parts == k
    # Strict balance: at most max(floor(n/k*(1+tol)), ceil(n/k)) per part.
    cap = max(int(np.floor(n / k * (1 + tol))), int(np.ceil(n / k)))
    assert res.sizes.max() <= cap
    # The reported cut is the real cut.
    np.testing.assert_allclose(res.cut, edge_cut(W, res.labels), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 60), mult=st.integers(1, 3),
       k=st.integers(2, 5), seed=st.integers(0, 5))
def test_edge_cut_matches_brute_force(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    res = partition_graph(W, k, tol=0.3, seed=seed)
    np.testing.assert_allclose(res.cut, brute_force_cut(W, res.labels),
                               rtol=1e-8, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 120), k=st.integers(2, 6), seed=st.integers(0, 5))
def test_vectorized_cut_within_5pct_of_seed_loop(n, k, seed):
    """On identical seeds over the affinity-graph domain, the vectorized
    partitioner's cut is never more than 5% worse than the seed loop's."""
    X = np.random.default_rng(seed).normal(size=(n, 4))
    g = build_affinity_graph(X, k=4)
    lo = partition_graph_loop(g.W, k, tol=0.3, seed=seed)
    ve = partition_graph(g.W, k, tol=0.3, seed=seed)
    assert ve.cut <= 1.05 * lo.cut + 1e-9


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 80), mult=st.integers(1, 3),
       k=st.integers(2, 6), seed=st.integers(0, 8))
def test_cut_is_invariant_under_node_relabeling(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    res = partition_graph(W, k, tol=0.3, seed=seed)
    perm = np.random.default_rng(seed + 1).permutation(n)
    Wp = W[perm][:, perm].tocsr()
    np.testing.assert_allclose(edge_cut(Wp, res.labels[perm]),
                               edge_cut(W, res.labels), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), mult=st.integers(1, 3),
       k=st.integers(2, 6), seed=st.integers(0, 8))
def test_partition_is_deterministic_per_seed(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    a = partition_graph(W, k, tol=0.3, seed=seed)
    b = partition_graph(W, k, tol=0.3, seed=seed)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_partition_permutation_groups_labels():
    labels = np.array([2, 0, 1, 0, 2, 1, 1])
    perm = partition_permutation(labels)
    assert sorted(perm) == list(range(7))
    assert (np.diff(labels[perm]) >= 0).all()


def test_partition_handles_degenerate_shapes():
    W = random_sparse_graph(12, 30, 0)
    one = partition_graph(W, 1)
    assert one.n_parts == 1 and one.cut == 0.0 and one.sizes.sum() == 12
    many = partition_graph(W, 20, seed=0)
    assert many.labels.max() < 20 and many.sizes.sum() == 12
    empty = partition_graph(sp.csr_matrix((8, 8)), 2, seed=0)
    assert empty.sizes.sum() == 8
