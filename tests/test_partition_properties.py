"""Property-based lockdown of the vectorized multilevel partitioner.

For random graphs, the partition invariants must hold unconditionally
(every node labeled, strict balance cap, cut arithmetic exact, cut
invariant under node relabeling), and on the affinity-graph domain the
vectorized partitioner's edge-cut must stay within 5% of the seed
per-node-loop implementation on identical seeds.  Hierarchy-reuse
replans (``partition_graph(..., reuse=h)``) must satisfy the same
invariants — strict balance cap, determinism per seed, cut within 5% of
a fresh same-seed partition — on arbitrary random graphs.
"""
import numpy as np
import pytest
import scipy.sparse as sp

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import build_affinity_graph
from repro.core.partition import (HierarchyCache, edge_cut, partition_graph,
                                  partition_graph_loop, partition_hierarchy,
                                  partition_permutation)


def random_sparse_graph(n: int, m: int, seed: int) -> sp.csr_matrix:
    """Random symmetric weighted graph — possibly disconnected, possibly
    with isolated nodes (the invariants must survive all of that)."""
    rng = np.random.default_rng(seed)
    r = rng.integers(0, n, size=m)
    c = rng.integers(0, n, size=m)
    keep = r != c
    r, c = r[keep], c[keep]
    w = rng.uniform(0.1, 1.0, size=len(r))
    W = sp.csr_matrix((np.r_[w, w], (np.r_[r, c], np.r_[c, r])),
                      shape=(n, n))
    W.sum_duplicates()
    return W


def brute_force_cut(W: sp.csr_matrix, labels: np.ndarray) -> float:
    """O(n^2) dense recount of the cut, independent of edge_cut's path."""
    D = np.asarray(W.todense())
    total = 0.0
    n = len(labels)
    for i in range(n):
        for j in range(i + 1, n):
            if labels[i] != labels[j]:
                total += D[i, j]
    return total


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 90), mult=st.integers(1, 4),
       k=st.integers(2, 8), seed=st.integers(0, 10))
def test_partition_invariants_on_random_graphs(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    tol = 0.3
    res = partition_graph(W, k, tol=tol, seed=seed)
    # Every node labeled, ids in range, sizes account for every node.
    assert res.labels.shape == (n,)
    assert res.labels.min() >= 0 and res.labels.max() < max(k, 1)
    assert res.sizes.sum() == n
    assert res.n_parts == k
    # Strict balance: at most max(floor(n/k*(1+tol)), ceil(n/k)) per part.
    cap = max(int(np.floor(n / k * (1 + tol))), int(np.ceil(n / k)))
    assert res.sizes.max() <= cap
    # The reported cut is the real cut.
    np.testing.assert_allclose(res.cut, edge_cut(W, res.labels), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(10, 60), mult=st.integers(1, 3),
       k=st.integers(2, 5), seed=st.integers(0, 5))
def test_edge_cut_matches_brute_force(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    res = partition_graph(W, k, tol=0.3, seed=seed)
    np.testing.assert_allclose(res.cut, brute_force_cut(W, res.labels),
                               rtol=1e-8, atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(20, 120), k=st.integers(2, 6), seed=st.integers(0, 5))
def test_vectorized_cut_within_5pct_of_seed_loop(n, k, seed):
    """On identical seeds over the affinity-graph domain, the vectorized
    partitioner's cut is never more than 5% worse than the seed loop's."""
    X = np.random.default_rng(seed).normal(size=(n, 4))
    g = build_affinity_graph(X, k=4)
    lo = partition_graph_loop(g.W, k, tol=0.3, seed=seed)
    ve = partition_graph(g.W, k, tol=0.3, seed=seed)
    assert ve.cut <= 1.05 * lo.cut + 1e-9


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 80), mult=st.integers(1, 3),
       k=st.integers(2, 6), seed=st.integers(0, 8))
def test_cut_is_invariant_under_node_relabeling(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    res = partition_graph(W, k, tol=0.3, seed=seed)
    perm = np.random.default_rng(seed + 1).permutation(n)
    Wp = W[perm][:, perm].tocsr()
    np.testing.assert_allclose(edge_cut(Wp, res.labels[perm]),
                               edge_cut(W, res.labels), rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), mult=st.integers(1, 3),
       k=st.integers(2, 6), seed=st.integers(0, 8))
def test_partition_is_deterministic_per_seed(n, mult, k, seed):
    W = random_sparse_graph(n, mult * n, seed)
    a = partition_graph(W, k, tol=0.3, seed=seed)
    b = partition_graph(W, k, tol=0.3, seed=seed)
    np.testing.assert_array_equal(a.labels, b.labels)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(10, 120), mult=st.integers(1, 3),
       k=st.integers(2, 8), seed=st.integers(0, 8))
def test_hierarchy_reuse_satisfies_partition_invariants(n, mult, k, seed):
    """A reuse replan obeys the same contract as a fresh partition: every
    node labeled, strict balance cap, determinism per seed, and a cut no
    more than 5% worse than the fresh same-seed partition.  (At these
    sizes — below the warm-path threshold — reuse falls through to the
    fresh computation; the warm path itself is covered by the affinity-
    domain test below on n > 2048 graphs.)"""
    W = random_sparse_graph(n, mult * n, seed)
    tol = 0.3
    h = partition_hierarchy(W, k, tol=tol, seed=seed)
    res = partition_graph(W, k, tol=tol, seed=seed + 1, temperature=0.5,
                          reuse=h)
    again = partition_graph(W, k, tol=tol, seed=seed + 1, temperature=0.5,
                            reuse=h)
    np.testing.assert_array_equal(res.labels, again.labels)
    assert res.labels.shape == (n,)
    assert res.labels.min() >= 0 and res.labels.max() < k
    assert res.sizes.sum() == n
    cap = max(int(np.floor(n / k * (1 + tol))), int(np.ceil(n / k)))
    assert res.sizes.max() <= cap
    np.testing.assert_allclose(res.cut, edge_cut(W, res.labels), rtol=1e-9)
    fresh = partition_graph(W, k, tol=tol, seed=seed + 1, temperature=0.5)
    assert res.cut <= 1.05 * fresh.cut + 1e-9


@settings(max_examples=6, deadline=None)
@given(n=st.integers(2100, 2800), k=st.sampled_from([8, 40, 150]),
       seed=st.integers(0, 5))
def test_warm_path_reuse_invariants_on_affinity_graphs(n, k, seed):
    """The *incremental* replan path (n above the warm threshold, so no
    fall-through) keeps the full contract on the affinity-graph domain:
    strict balance cap, determinism per seed, exact cut arithmetic, and
    cut within 5% of the fresh same-seed tempered partition — across both
    the gentle-top-redraw (large k) and frozen-chain/perturbation-only
    (small k) fidelity regimes."""
    X = np.random.default_rng(seed).normal(size=(n, 6))
    g = build_affinity_graph(X, k=6)
    tol = 0.2
    h = partition_hierarchy(g.W, k, tol=tol, seed=seed)
    res = partition_graph(g.W, k, tol=tol, seed=seed + 1, temperature=0.5,
                          reuse=h)
    again = partition_graph(g.W, k, tol=tol, seed=seed + 1,
                            temperature=0.5, reuse=h)
    np.testing.assert_array_equal(res.labels, again.labels)
    assert res.sizes.sum() == n
    cap = max(int(np.floor(n / k * (1 + tol))), int(np.ceil(n / k)))
    assert res.sizes.max() <= cap
    np.testing.assert_allclose(res.cut, edge_cut(g.W, res.labels),
                               rtol=1e-9)
    fresh = partition_graph(g.W, k, tol=tol, seed=seed + 1,
                            temperature=0.5)
    assert res.cut <= 1.05 * fresh.cut + 1e-9


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 100), k=st.integers(2, 6), seed=st.integers(0, 6))
def test_hierarchy_is_pure_of_build_time(n, k, seed):
    """Two independently built hierarchies (same args) drive bit-identical
    reuse replans — the purity that keeps jump-resume exact."""
    X = np.random.default_rng(seed).normal(size=(n, 4))
    g = build_affinity_graph(X, k=4)
    h1 = partition_hierarchy(g.W, k, tol=0.3, seed=seed)
    h2 = partition_hierarchy(g.W, k, tol=0.3, seed=seed)
    a = partition_graph(g.W, k, tol=0.3, seed=seed + 3, temperature=0.5,
                        reuse=h1)
    b = partition_graph(g.W, k, tol=0.3, seed=seed + 3, temperature=0.5,
                        reuse=h2)
    np.testing.assert_array_equal(a.labels, b.labels)


def test_reuse_rejects_mismatched_hierarchy():
    W = random_sparse_graph(60, 180, 0)
    h = partition_hierarchy(W, 4, tol=0.3, seed=0)
    with pytest.raises(ValueError, match="k=4"):
        partition_graph(W, 5, tol=0.3, seed=0, reuse=h)
    other = random_sparse_graph(61, 180, 1)
    with pytest.raises(ValueError, match="different graph"):
        partition_graph(other, 4, tol=0.3, seed=0, reuse=h)
    with pytest.raises(ValueError, match="tol"):
        partition_graph(W, 4, tol=0.1, seed=0, reuse=h)
    # A HierarchyCache transparently builds the right hierarchy per k.
    cache = HierarchyCache(W, tol=0.3, seed=0)
    res = partition_graph(W, 5, tol=0.3, seed=0, reuse=cache)
    assert res.sizes.sum() == 60


@pytest.mark.parametrize("k", [2, 313])
def test_rcm_chop_distributes_remainder(k):
    """Regression: the RCM chop must not let the last part absorb the
    remainder when n % k != 0 (unit weights: sizes differ by at most 1)
    or when node weights vary (every part within one heaviest-node weight
    of the ideal)."""
    from repro.core.partition import _rcm_chop

    n = 1291 if k == 313 else 11           # both indivisible by k
    rng = np.random.default_rng(0)
    r = rng.integers(0, n, size=4 * n)
    c = rng.integers(0, n, size=4 * n)
    keep = r != c
    r, c = r[keep], c[keep]
    w = rng.uniform(0.1, 1.0, size=len(r))
    W = sp.csr_matrix((np.r_[w, w], (np.r_[r, c], np.r_[c, r])),
                      shape=(n, n))
    W.sum_duplicates()
    labels = _rcm_chop(W, np.ones(n), k)
    sizes = np.bincount(labels, minlength=k)
    assert sizes.min() >= 1
    assert sizes.max() - sizes.min() <= 1, \
        f"unit-weight chop unbalanced: {sizes.min()}..{sizes.max()}"
    node_w = rng.uniform(1.0, 8.0, size=n)
    labels = _rcm_chop(W, node_w, k)
    weights = np.bincount(labels, weights=node_w, minlength=k)
    ideal = node_w.sum() / k
    assert np.bincount(labels, minlength=k).min() >= 1
    # Adaptive boundaries: every chunk lands within half a heaviest-node
    # weight of the (remaining-weight) ideal — the greedy fixed-target
    # chop drifted to ~1.4x ideal here.
    assert weights.max() <= ideal + 0.5 * node_w.max() + 1e-9, \
        f"weighted chop tail-heavy: max {weights.max():.2f} vs ideal " \
        f"{ideal:.2f}"


def test_partition_permutation_groups_labels():
    labels = np.array([2, 0, 1, 0, 2, 1, 1])
    perm = partition_permutation(labels)
    assert sorted(perm) == list(range(7))
    assert (np.diff(labels[perm]) >= 0).all()


def test_partition_handles_degenerate_shapes():
    W = random_sparse_graph(12, 30, 0)
    one = partition_graph(W, 1)
    assert one.n_parts == 1 and one.cut == 0.0 and one.sizes.sum() == 12
    many = partition_graph(W, 20, seed=0)
    assert many.labels.max() < 20 and many.sizes.sum() == 12
    empty = partition_graph(sp.csr_matrix((8, 8)), 2, seed=0)
    assert empty.sizes.sum() == 8
