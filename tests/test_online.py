"""Online affinity refresh + dynamic corpus ingestion (``repro.online``)."""
import numpy as np
import pytest

from repro.core.affinity import (build_affinity_graph, evict_nodes,
                                 insert_nodes)
from repro.core.metabatch import plan_meta_batches
from repro.core.partition import HierarchyCache, extend_partition
from repro.data import make_corpus
from repro.data.pipeline import MetaBatchStream, _epoch_groups
from repro.online import (OnlineManager, edge_churn, edge_set,
                          embedding_knn_graph, scatter_epoch_embeddings)


def _setup(n=600, d=24, C=6, k=8, seed=0, **stream_kw):
    rng = np.random.default_rng(seed)
    corpus = make_corpus(n, n_classes=C, input_dim=d, manifold_dim=4,
                         seed=seed)
    graph = build_affinity_graph(corpus.X, k=k)
    plan = plan_meta_batches(graph, batch_size=25, n_classes=C, seed=seed)
    stream = MetaBatchStream(corpus, graph, plan, n_workers=2,
                             record_indices=True, seed=seed, **stream_kw)
    return rng, corpus, graph, plan, stream


def _manager(stream, corpus, graph, *, embed_fn=None, partitioner=None,
             **cfg_kw):
    from repro.api.config import OnlineConfig
    cfg = OnlineConfig(**cfg_kw)
    return OnlineManager(stream, corpus, graph, cfg, batch_size=25,
                         n_classes=corpus.n_classes, embed_fn=embed_fn,
                         partitioner=partitioner, seed=0)


# ----------------------------------------------------------- graph builder
def test_embedding_knn_graph_deterministic():
    rng = np.random.default_rng(0)
    E = rng.normal(size=(300, 16)).astype(np.float32)
    a = embedding_knn_graph(E, k=6)
    b = embedding_knn_graph(E, k=6)
    assert a.sigma == b.sigma
    assert (a.W != b.W).nnz == 0          # bit-identical sparse weights


def test_embedding_knn_graph_host_matches_device():
    """Satellite: self-tuning sigma (and hence weights) must agree across
    construction backends — distances are pinned to f32 on both paths."""
    rng = np.random.default_rng(1)
    E = rng.normal(size=(96, 8)).astype(np.float32)
    host = embedding_knn_graph(E, k=5, backend="host")
    dev = embedding_knn_graph(E, k=5, backend="device")
    assert host.sigma == pytest.approx(dev.sigma, rel=1e-6)
    assert host.W.nnz == dev.W.nnz
    np.testing.assert_allclose(host.W.toarray(), dev.W.toarray(),
                               rtol=1e-5, atol=1e-7)


def test_embedding_knn_graph_per_node_bandwidth():
    rng = np.random.default_rng(2)
    # Two clusters with very different density: local scaling keeps the
    # sparse cluster's weights alive where a global sigma crushes them.
    tight = rng.normal(size=(100, 8)).astype(np.float32) * 0.05
    loose = rng.normal(size=(100, 8)).astype(np.float32) * 5.0 + 50.0
    E = np.concatenate([tight, loose])
    g_global = embedding_knn_graph(E, k=5, bandwidth="global")
    g_local = embedding_knn_graph(E, k=5, bandwidth="per_node")
    assert g_local.W.shape == g_global.W.shape
    loose_w = g_local.W[100:, 100:].data
    assert loose_w.size and loose_w.mean() > g_global.W[100:, 100:].data.mean()
    with pytest.raises(ValueError, match="bandwidth"):
        embedding_knn_graph(E, k=5, bandwidth="learned")


def test_edge_churn_bounds():
    rng = np.random.default_rng(3)
    E = rng.normal(size=(200, 8)).astype(np.float32)
    g = embedding_knn_graph(E, k=5)
    assert edge_churn(g, g) == 0.0
    far = embedding_knn_graph(
        rng.normal(size=(200, 8)).astype(np.float32), k=5)
    assert 0.0 < edge_churn(g, far) <= 1.0


# ----------------------------------------------------------- insert / evict
def test_insert_then_evict_restores_edge_set():
    """Satellite: inserting nodes and evicting the same nodes is an exact
    no-op on the surviving graph."""
    rng = np.random.default_rng(4)
    X = rng.normal(size=(300, 12)).astype(np.float32)
    graph = build_affinity_graph(X, k=6)
    X_new = rng.normal(size=(20, 12)).astype(np.float32)
    g2 = insert_nodes(graph, X, X_new)
    assert g2.n_nodes == 320
    # every inserted node is connected
    assert (np.diff(g2.W[300:].indptr) > 0).all()
    g3 = evict_nodes(g2, np.arange(300, 320))
    assert g3.n_nodes == 300
    assert edge_set(g3) == edge_set(graph)
    assert (g3.W != graph.W).nnz == 0


def test_graph_insert_evict_methods_delegate():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(100, 8)).astype(np.float32)
    graph = build_affinity_graph(X, k=4)
    X_new = rng.normal(size=(7, 8)).astype(np.float32)
    g2 = graph.insert(X, X_new)
    assert (g2.W != insert_nodes(graph, X, X_new).W).nnz == 0
    g3 = g2.evict(np.arange(100, 107))
    assert (g3.W != graph.W).nnz == 0


def test_extend_partition_respects_cap_and_touches_only_tail():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(400, 12)).astype(np.float32)
    graph = build_affinity_graph(X, k=6)
    plan = plan_meta_batches(graph, batch_size=25, n_classes=8, seed=0)
    labels = plan.mini_block_labels
    k_parts = int(labels.max()) + 1
    g2 = insert_nodes(graph, X, rng.normal(size=(32, 12)).astype(np.float32))
    res = extend_partition(g2.W, labels, k_parts, tol=0.15)
    n = g2.n_nodes
    cap = max(int(n / k_parts * 1.15), -(-n // k_parts))
    assert res.labels.shape == (n,)
    assert res.sizes.max() <= cap
    # deterministic
    res2 = extend_partition(g2.W, labels, k_parts, tol=0.15)
    np.testing.assert_array_equal(res.labels, res2.labels)


# -------------------------------------------------------------- scatter
def test_scatter_epoch_embeddings_last_write_wins():
    caps = np.stack([np.full((2, 3, 4), 1.0, np.float32),
                     np.full((2, 3, 4), 2.0, np.float32)])
    indices = [[np.array([0, 1]), np.array([2])],
               [np.array([0]), np.array([3, 4])]]
    E, seen = scatter_epoch_embeddings(caps, indices, 6)
    assert seen.tolist() == [True, True, True, True, True, False]
    assert E[0, 0] == 2.0      # step-1 capture overwrites step-0
    assert E[1, 0] == 1.0
    assert (E[5] == 0).all()
    with pytest.raises(ValueError, match="index groups"):
        scatter_epoch_embeddings(caps, indices[:1], 6)


# -------------------------------------------------------------- manager
def test_manager_refresh_swaps_graph_and_is_deterministic():
    _, corpus, graph, plan, stream = _setup()
    mgr = _manager(stream, corpus, graph, refresh_every=2)
    rng = np.random.default_rng(7)
    proj = rng.normal(size=(corpus.X.shape[1], 16)).astype(np.float32)
    E = corpus.X @ proj
    assert mgr.refresh(1, E)
    assert stream.snapshot()[1] is mgr.graph
    assert mgr.stats["refreshes"] == 1
    assert mgr.embedding_space

    # a second, independent manager over identical inputs produces the
    # bit-identical graph and plan: refresh is pure in (inputs, seed)
    _, corpus2, graph2, plan2, stream2 = _setup()
    mgr2 = _manager(stream2, corpus2, graph2, refresh_every=2)
    assert mgr2.refresh(1, E)
    assert (mgr.graph.W != mgr2.graph.W).nnz == 0
    p1, p2 = stream.snapshot()[0], stream2.snapshot()[0]
    np.testing.assert_array_equal(p1.mini_block_labels, p2.mini_block_labels)
    assert all((a == b).all()
               for a, b in zip(p1.meta_batches, p2.meta_batches))


def test_manager_insert_uses_delta_path_only():
    """Acceptance: a 32-node insert never triggers a full partition
    rebuild — the full-path partitioner is booby-trapped, the swapped-in
    hierarchy cache records zero builds, and stats stay delta-only."""
    _, corpus, graph, plan, stream = _setup()
    cache = HierarchyCache(graph.W, tol=0.15, coarsen_to=60, seed=0)
    with stream._lock:
        stream._hierarchy = cache

    def trap(*a, **k):
        raise AssertionError("full partition_graph rebuild on insert path")

    mgr = _manager(stream, corpus, graph, refresh_every=2, partitioner=trap)
    rng = np.random.default_rng(8)
    idx = mgr.insert(rng.normal(size=(32, corpus.X.shape[1]))
                     .astype(np.float32))
    np.testing.assert_array_equal(idx, np.arange(600, 632))
    assert mgr.stats == {"refreshes": 0, "delta_refines": 0,
                         "full_rebuilds": 0, "inserts": 1, "evictions": 0,
                         "rejected": 0}
    new_hier = stream.snapshot()[3]
    assert new_hier is not cache
    assert new_hier.builds == 0          # lazily swapped in, never built
    assert stream.snapshot()[2].n == 632
    assert not stream.snapshot()[2].label_mask[600:].any()

    # evict the same nodes: graph back to the original edge set
    assert mgr.evict(idx)
    assert edge_set(mgr.graph) == edge_set(graph)
    assert stream.snapshot()[2].n == 600
    assert mgr.stats["evictions"] == 1 and mgr.stats["full_rebuilds"] == 0


def test_manager_stream_serves_after_swap():
    _, corpus, graph, plan, stream = _setup()
    mgr = _manager(stream, corpus, graph, refresh_every=2)
    steps_before = sum(1 for _ in stream.epoch(epoch=0, n_epochs=4))
    rng = np.random.default_rng(9)
    E = corpus.X @ rng.normal(size=(corpus.X.shape[1], 16)).astype(np.float32)
    assert mgr.refresh(1, E)
    steps_after = sum(1 for _ in stream.epoch(epoch=2, n_epochs=4))
    assert steps_after == steps_before
    assert stream.swaps >= 1


def test_manager_requires_recorded_indices():
    _, corpus, graph, plan, stream = _setup()
    stream.record_indices = False
    stream.last_epoch_indices = None
    mgr = _manager(stream, corpus, graph, refresh_every=1)
    with pytest.raises(RuntimeError, match="record_indices"):
        mgr.on_epoch_end(0, {"p": 1}, np.zeros((1, 2, 3, 4), np.float32))


# ------------------------------------------------------------ config layer
def test_online_config_validation():
    from repro.api.config import (BatchConfig, ExperimentConfig,
                                  OnlineConfig)
    assert not OnlineConfig().active
    assert OnlineConfig(refresh_every=3).active
    with pytest.raises(ValueError, match="refresh_every"):
        OnlineConfig(refresh_every=-1)
    with pytest.raises(ValueError, match="bandwidth"):
        OnlineConfig(bandwidth="learned")
    with pytest.raises(ValueError, match="churn_threshold"):
        OnlineConfig(churn_threshold=1.5)
    with pytest.raises(ValueError, match="metabatch_stream"):
        ExperimentConfig(online=OnlineConfig(refresh_every=2))
    with pytest.raises(ValueError, match="tap"):
        ExperimentConfig(
            batch=BatchConfig(pipeline="metabatch_stream"),
            online=OnlineConfig(refresh_every=2, tap=7))
    cfg = ExperimentConfig(batch=BatchConfig(pipeline="metabatch_stream"),
                           online=OnlineConfig(refresh_every=2))
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


# ---------------------------------------------------------- end-to-end run
def _tiny_online_config(n_epochs=4):
    from repro.api import (BatchConfig, DataConfig, ExecutionConfig,
                           ExperimentConfig, GraphConfig, OnlineConfig,
                           TrainConfig)
    return ExperimentConfig(
        data=DataConfig(n=400, n_classes=5, input_dim=16, manifold_dim=4,
                        label_ratio=0.2, test_fraction=0.1),
        graph=GraphConfig(k=6),
        batch=BatchConfig(pipeline="metabatch_stream", batch_size=20),
        train=TrainConfig(n_epochs=n_epochs, n_workers=2, hidden_dim=32,
                          n_hidden=2, dropout=0.0),
        execution=ExecutionConfig(scan_chunk=4, prefetch=0),
        online=OnlineConfig(refresh_every=2))


@pytest.mark.slow
def test_experiment_online_refresh_end_to_end():
    """Acceptance: OnlineConfig(refresh_every=2) trains end to end and the
    graph the stream serves is provably rebuilt from live embeddings."""
    from repro.api import Experiment
    exp = Experiment(_tiny_online_config())
    exp.build()
    input_edges = edge_set(exp.graph)
    res = exp.run()
    assert exp.online is not None
    assert exp.online.stats["refreshes"] == 2        # epochs 1 and 3
    assert exp.online.embedding_space
    served = exp.pipeline.stream.snapshot()[1]
    assert edge_set(served) != input_edges           # not the feature graph
    assert len(res.history) == 4


@pytest.mark.slow
def test_experiment_online_refresh_bit_reproducible():
    """Acceptance: the refresh at epoch e is a pure function of
    (params, corpus, OnlineConfig, seed) — two identical runs serve
    bit-identical graphs."""
    from repro.api import Experiment
    graphs = []
    for _ in range(2):
        exp = Experiment(_tiny_online_config(n_epochs=2))
        exp.run()
        graphs.append(exp.pipeline.stream.snapshot()[1])
    a, b = graphs
    assert a.sigma == b.sigma
    assert (a.W != b.W).nnz == 0


# --------------------------------------------------- epoch coverage (sat. 2)
@pytest.mark.parametrize("n,k", [(7, 2), (10, 3), (5, 5), (9, 4)])
def test_epoch_groups_cover_every_index(n, k):
    order = np.random.default_rng(0).permutation(n)
    groups = list(_epoch_groups(order, k))
    assert all(len(g) == k for g in groups)
    seen = np.concatenate(groups) if groups else np.empty(0, int)
    assert set(seen.tolist()) == set(range(n))
    # every index exactly once, except wrap-padding on the final group
    assert len(groups) == -(-n // k)


def test_epoch_groups_small_n_yields_nothing():
    assert list(_epoch_groups(np.arange(3), 4)) == []


def test_stream_epoch_visits_all_meta_batches_nondivisible():
    """Satellite: with n_meta % n_workers != 0 the tail meta-batches must
    still be served (wrap-padded), not silently dropped."""
    _, corpus, graph, plan, stream = _setup(n=625)
    n_meta = len(plan.meta_batches)
    assert n_meta % 2 == 1, "setup must produce an odd meta-batch count"
    steps = sum(1 for _ in stream.epoch(epoch=0, n_epochs=1))
    assert steps == -(-n_meta // 2)
    visited = np.concatenate(
        [np.concatenate(g) for g in stream.last_epoch_indices])
    assert np.unique(visited).size == corpus.n
