"""Statistical lockdown of the stochastic layer (§2.1–§2.2).

Deterministic seeds, no hypothesis: these run in the minimal container.

  * NeighborSampler empirical draw frequencies converge to the Eq.-6
    probabilities (chi-square bound over >= 10k draws);
  * meta-batch label entropy ~= global label entropy (§2.1's claim);
  * re-partitioning: different epoch seeds yield distinct plans, identical
    seeds are bit-reproducible.
"""
import numpy as np
import pytest
from scipy import stats as sps

from repro.core import build_affinity_graph, plan_meta_batches
from repro.core.metabatch import (NeighborSampler, epoch_plan_seed,
                                  resynthesize_plan)
from repro.core.partition import partition_graph_loop
from repro.core.stats import batch_label_entropy, entropy_distribution
from repro.data import make_corpus


@pytest.fixture(scope="module")
def stream_setup():
    corpus = make_corpus(1200, n_classes=8, input_dim=48, manifold_dim=6,
                         seed=0)
    graph = build_affinity_graph(corpus.X, k=10)
    plan = plan_meta_batches(graph, batch_size=192, n_classes=8, seed=0)
    return corpus, graph, plan


# ----------------------------------------------------------- Eq.-6 sampler
def test_neighbor_sampler_frequencies_converge_to_eq6(stream_setup):
    _, _, plan = stream_setup
    sampler = NeighborSampler(plan.batch_edges, seed=7)
    # Densest row: most neighbours, hardest multinomial to match.
    i = int(np.argmax(np.diff(plan.batch_edges.indptr)))
    nbrs, p = sampler.probs(i)
    assert len(nbrs) >= 2
    n_draws = 20_000
    draws = np.array([sampler.sample(i) for _ in range(n_draws)])
    observed = np.array([(draws == j).sum() for j in nbrs])
    assert observed.sum() == n_draws          # every draw is a neighbour
    expected = p * n_draws
    chi2 = float(((observed - expected) ** 2 / expected).sum())
    # 99.9th percentile bound: a correct sampler fails 1/1000 seeds; this
    # seed is fixed, so the test is deterministic.
    assert chi2 < sps.chi2.ppf(0.999, df=len(nbrs) - 1)


def test_neighbor_sampler_identical_seeds_reproduce(stream_setup):
    _, _, plan = stream_setup
    a = NeighborSampler(plan.batch_edges, seed=3)
    b = NeighborSampler(plan.batch_edges, seed=3)
    assert [a.sample(0) for _ in range(50)] == [b.sample(0)
                                               for _ in range(50)]


# ----------------------------------------------------- §2.1 entropy claim
def test_meta_batch_entropy_matches_global_within_tolerance(stream_setup):
    corpus, _, plan = stream_setup
    glob = batch_label_entropy(corpus.y, np.arange(corpus.n),
                               corpus.n_classes)
    e_meta = entropy_distribution(corpus.y, plan.meta_batches,
                                  corpus.n_classes)
    # §2.1: meta-batches recover the global label entropy.
    assert abs(e_meta.mean() - glob) <= 0.15 * glob
    assert e_meta.min() > 0.5 * glob


# ------------------------------------------------- re-partitioning stream
def test_epoch_plan_seed_stream_is_deterministic_and_decorrelated():
    seeds = [epoch_plan_seed(42, e) for e in range(32)]
    assert seeds == [epoch_plan_seed(42, e) for e in range(32)]
    assert len(set(seeds)) == 32                 # no collisions in-stream
    other = [epoch_plan_seed(43, e) for e in range(32)]
    assert set(seeds).isdisjoint(other)


def test_resynthesis_identical_seeds_bit_reproducible(stream_setup):
    _, graph, _ = stream_setup
    kw = dict(epoch=3, base_seed=11, temperature=0.5)
    a = resynthesize_plan(graph, 192, 8, **kw)
    b = resynthesize_plan(graph, 192, 8, **kw)
    np.testing.assert_array_equal(a.mini_block_labels, b.mini_block_labels)
    assert len(a.meta_batches) == len(b.meta_batches)
    for ma, mb in zip(a.meta_batches, b.meta_batches):
        np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(a.batch_edges.indices,
                                  b.batch_edges.indices)
    np.testing.assert_array_equal(a.batch_edges.data, b.batch_edges.data)


@pytest.mark.parametrize("temperature", [0.0, 0.5])
def test_resynthesis_distinct_across_epochs(stream_setup, temperature):
    _, graph, _ = stream_setup
    plans = [resynthesize_plan(graph, 192, 8, epoch=e, base_seed=0,
                               temperature=temperature) for e in (1, 2, 3)]
    for a, b in ((0, 1), (0, 2), (1, 2)):
        # The *plan* differs every epoch: block-to-meta-batch grouping is
        # re-drawn even when the partition itself is stable.
        meta_a = plans[a].meta_of_block[plans[a].mini_block_labels]
        meta_b = plans[b].meta_of_block[plans[b].mini_block_labels]
        assert (meta_a != meta_b).any()
        if temperature > 0:
            # Gumbel-perturbed matching re-draws the partition too.
            assert (plans[a].mini_block_labels
                    != plans[b].mini_block_labels).any()
    for p in plans:    # each plan still covers the dataset exactly once
        allidx = np.concatenate(p.meta_batches)
        assert sorted(allidx) == list(range(graph.n_nodes))


def test_resynthesis_rejects_temperature_on_loop_partitioner(stream_setup):
    _, graph, _ = stream_setup
    with pytest.raises(ValueError, match="temperature"):
        resynthesize_plan(graph, 192, 8, epoch=1, temperature=0.5,
                          partitioner=partition_graph_loop)
    # temperature=0 is fine with any partitioner.
    plan = resynthesize_plan(graph, 192, 8, epoch=1, temperature=0.0,
                             partitioner=partition_graph_loop)
    assert plan.n_meta > 0
