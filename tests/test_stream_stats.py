"""Statistical lockdown of the stochastic layer (§2.1–§2.2).

Deterministic seeds, no hypothesis: these run in the minimal container.

  * NeighborSampler empirical draw frequencies converge to the Eq.-6
    probabilities (chi-square bound over >= 10k draws);
  * meta-batch label entropy ~= global label entropy (§2.1's claim);
  * re-partitioning: different epoch seeds yield distinct plans, identical
    seeds are bit-reproducible.
"""
import numpy as np
import pytest
from scipy import stats as sps

from repro.core import build_affinity_graph, plan_meta_batches
from repro.core.metabatch import (NeighborSampler, epoch_plan_seed,
                                  resynthesize_plan)
from repro.core.partition import HierarchyCache, partition_graph_loop
from repro.core.stats import batch_label_entropy, entropy_distribution
from repro.data import make_corpus


@pytest.fixture(scope="module")
def stream_setup():
    corpus = make_corpus(1200, n_classes=8, input_dim=48, manifold_dim=6,
                         seed=0)
    graph = build_affinity_graph(corpus.X, k=10)
    plan = plan_meta_batches(graph, batch_size=192, n_classes=8, seed=0)
    return corpus, graph, plan


# ----------------------------------------------------------- Eq.-6 sampler
def test_neighbor_sampler_frequencies_converge_to_eq6(stream_setup):
    _, _, plan = stream_setup
    sampler = NeighborSampler(plan.batch_edges, seed=7)
    # Densest row: most neighbours, hardest multinomial to match.
    i = int(np.argmax(np.diff(plan.batch_edges.indptr)))
    nbrs, p = sampler.probs(i)
    assert len(nbrs) >= 2
    n_draws = 20_000
    draws = np.array([sampler.sample(i) for _ in range(n_draws)])
    observed = np.array([(draws == j).sum() for j in nbrs])
    assert observed.sum() == n_draws          # every draw is a neighbour
    expected = p * n_draws
    chi2 = float(((observed - expected) ** 2 / expected).sum())
    # 99.9th percentile bound: a correct sampler fails 1/1000 seeds; this
    # seed is fixed, so the test is deterministic.
    assert chi2 < sps.chi2.ppf(0.999, df=len(nbrs) - 1)


def test_neighbor_sampler_identical_seeds_reproduce(stream_setup):
    _, _, plan = stream_setup
    a = NeighborSampler(plan.batch_edges, seed=3)
    b = NeighborSampler(plan.batch_edges, seed=3)
    assert [a.sample(0) for _ in range(50)] == [b.sample(0)
                                               for _ in range(50)]


# ----------------------------------------------------- §2.1 entropy claim
def test_meta_batch_entropy_matches_global_within_tolerance(stream_setup):
    corpus, _, plan = stream_setup
    glob = batch_label_entropy(corpus.y, np.arange(corpus.n),
                               corpus.n_classes)
    e_meta = entropy_distribution(corpus.y, plan.meta_batches,
                                  corpus.n_classes)
    # §2.1: meta-batches recover the global label entropy.
    assert abs(e_meta.mean() - glob) <= 0.15 * glob
    assert e_meta.min() > 0.5 * glob


# ------------------------------------------------- re-partitioning stream
def test_epoch_plan_seed_stream_is_deterministic_and_decorrelated():
    seeds = [epoch_plan_seed(42, e) for e in range(32)]
    assert seeds == [epoch_plan_seed(42, e) for e in range(32)]
    assert len(set(seeds)) == 32                 # no collisions in-stream
    other = [epoch_plan_seed(43, e) for e in range(32)]
    assert set(seeds).isdisjoint(other)


def test_resynthesis_identical_seeds_bit_reproducible(stream_setup):
    _, graph, _ = stream_setup
    kw = dict(epoch=3, base_seed=11, temperature=0.5)
    a = resynthesize_plan(graph, 192, 8, **kw)
    b = resynthesize_plan(graph, 192, 8, **kw)
    np.testing.assert_array_equal(a.mini_block_labels, b.mini_block_labels)
    assert len(a.meta_batches) == len(b.meta_batches)
    for ma, mb in zip(a.meta_batches, b.meta_batches):
        np.testing.assert_array_equal(ma, mb)
    np.testing.assert_array_equal(a.batch_edges.indices,
                                  b.batch_edges.indices)
    np.testing.assert_array_equal(a.batch_edges.data, b.batch_edges.data)


@pytest.mark.parametrize("temperature", [0.0, 0.5])
def test_resynthesis_distinct_across_epochs(stream_setup, temperature):
    _, graph, _ = stream_setup
    plans = [resynthesize_plan(graph, 192, 8, epoch=e, base_seed=0,
                               temperature=temperature) for e in (1, 2, 3)]
    for a, b in ((0, 1), (0, 2), (1, 2)):
        # The *plan* differs every epoch: block-to-meta-batch grouping is
        # re-drawn even when the partition itself is stable.
        meta_a = plans[a].meta_of_block[plans[a].mini_block_labels]
        meta_b = plans[b].meta_of_block[plans[b].mini_block_labels]
        assert (meta_a != meta_b).any()
        if temperature > 0:
            # Gumbel-perturbed matching re-draws the partition too.
            assert (plans[a].mini_block_labels
                    != plans[b].mini_block_labels).any()
    for p in plans:    # each plan still covers the dataset exactly once
        allidx = np.concatenate(p.meta_batches)
        assert sorted(allidx) == list(range(graph.n_nodes))


def test_resynthesis_rejects_temperature_on_loop_partitioner(stream_setup):
    _, graph, _ = stream_setup
    with pytest.raises(ValueError, match="temperature"):
        resynthesize_plan(graph, 192, 8, epoch=1, temperature=0.5,
                          partitioner=partition_graph_loop)
    # temperature=0 is fine with any partitioner.
    plan = resynthesize_plan(graph, 192, 8, epoch=1, temperature=0.0,
                             partitioner=partition_graph_loop)
    assert plan.n_meta > 0


# ------------------------------------------- hierarchy-reuse replans
@pytest.fixture(scope="module")
def reuse_setup():
    """Large enough (n > 2048) that the warm incremental replan path
    engages rather than falling back to a fresh partition."""
    corpus = make_corpus(3000, n_classes=8, input_dim=32, manifold_dim=6,
                         seed=1)
    graph = build_affinity_graph(corpus.X, k=10)
    cache = HierarchyCache(graph.W, tol=0.15, seed=0)
    return corpus, graph, cache


def _plans_equal(a, b) -> bool:
    if (a.mini_block_labels != b.mini_block_labels).any():
        return False
    if len(a.meta_batches) != len(b.meta_batches):
        return False
    return all((ma == mb).all()
               for ma, mb in zip(a.meta_batches, b.meta_batches))


def test_reuse_resynthesis_bit_reproducible_and_pure(reuse_setup):
    _, graph, cache = reuse_setup
    kw = dict(epoch=3, base_seed=11, temperature=0.5)
    a = resynthesize_plan(graph, 192, 8, reuse=cache, **kw)
    b = resynthesize_plan(graph, 192, 8, reuse=cache, **kw)
    assert _plans_equal(a, b)
    # Purity: a freshly built cache (as a jump-resumed stream would hold)
    # yields the exact same plan — reuse never depends on build history.
    fresh_cache = HierarchyCache(graph.W, tol=0.15, seed=0)
    c = resynthesize_plan(graph, 192, 8, reuse=fresh_cache, **kw)
    assert _plans_equal(a, c)
    np.testing.assert_array_equal(a.batch_edges.indices,
                                  c.batch_edges.indices)
    np.testing.assert_array_equal(a.batch_edges.data, c.batch_edges.data)


def test_reuse_resynthesis_distinct_across_epochs_and_covers(reuse_setup):
    _, graph, cache = reuse_setup
    plans = [resynthesize_plan(graph, 192, 8, epoch=e, base_seed=0,
                               temperature=0.5, reuse=cache)
             for e in (1, 2, 3)]
    for a, b in ((0, 1), (0, 2), (1, 2)):
        # Gumbel-perturbed top-level redraw + warm-path perturbation:
        # the partition itself differs every epoch, not just the grouping.
        assert (plans[a].mini_block_labels
                != plans[b].mini_block_labels).any()
    for p in plans:    # each plan still covers the dataset exactly once
        allidx = np.concatenate(p.meta_batches)
        assert sorted(allidx) == list(range(graph.n_nodes))


def _eq6_mean_entropy(plan) -> float:
    """Mean Shannon entropy of the Eq.-6 neighbour distribution per row."""
    E = plan.batch_edges
    hs = []
    for i in range(plan.n_meta):
        w = E.data[E.indptr[i]: E.indptr[i + 1]]
        tot = w.sum()
        if tot > 0:
            p = w / tot
            p = p[p > 0]
            hs.append(float(-(p * np.log(p)).sum()))
    return float(np.mean(hs))


def test_reuse_and_fresh_block_sampling_entropy_indistinguishable(
        reuse_setup):
    """The incremental replan must not collapse the Eq.-6 neighbour
    distribution: across epochs, reuse plans and from-scratch plans carry
    statistically indistinguishable block-sampling entropy."""
    _, graph, cache = reuse_setup
    epochs = range(1, 7)
    h_fresh = np.array([_eq6_mean_entropy(
        resynthesize_plan(graph, 192, 8, epoch=e, base_seed=0,
                          temperature=0.5)) for e in epochs])
    h_reuse = np.array([_eq6_mean_entropy(
        resynthesize_plan(graph, 192, 8, epoch=e, base_seed=0,
                          temperature=0.5, reuse=cache)) for e in epochs])
    # Means within 10% of each other and both well inside the other's
    # observed range (same distribution up to sampling noise).
    assert abs(h_fresh.mean() - h_reuse.mean()) <= 0.1 * h_fresh.mean()
    spread = 3 * max(h_fresh.std(), h_reuse.std()) + 0.05 * h_fresh.mean()
    assert abs(h_fresh.mean() - h_reuse.mean()) <= spread


def test_warm_replan_partition_invariants(reuse_setup):
    """The *incremental* replan path (n > 2048, so no fresh-path fallback)
    satisfies the partition contract: every node labeled, strict balance
    cap, exact cut arithmetic, determinism per seed, and cut within 5% of
    a fresh same-seed tempered partition."""
    from repro.core.partition import edge_cut, partition_graph

    _, graph, cache = reuse_setup
    n = graph.n_nodes
    assert n > 2048                    # warm path engages
    k, tol = 125, 0.15
    h = cache.get(k)
    assert h.levels >= 1               # a real multilevel chain is cached
    for seed in (1, 5):
        res = partition_graph(graph.W, k, tol=tol, seed=seed,
                              temperature=0.5, reuse=h)
        again = partition_graph(graph.W, k, tol=tol, seed=seed,
                                temperature=0.5, reuse=h)
        np.testing.assert_array_equal(res.labels, again.labels)
        assert res.labels.shape == (n,)
        assert res.sizes.sum() == n
        cap = max(int(np.floor(n / k * (1 + tol))), int(np.ceil(n / k)))
        assert res.sizes.max() <= cap
        np.testing.assert_allclose(res.cut, edge_cut(graph.W, res.labels),
                                   rtol=1e-9)
        fresh = partition_graph(graph.W, k, tol=tol, seed=seed,
                                temperature=0.5)
        assert res.cut <= 1.05 * fresh.cut + 1e-9


def test_delta_refine_survives_dense_table_cap():
    """Above the dense conn-table cap (n*k > 8M) a delta-seeded refine
    must stay restricted to the active rows, not fall back to full-graph
    passes — and still respect the capacity cap it is given."""
    import scipy.sparse as sp

    from repro.core.partition import _refine_vec, edge_cut

    rng = np.random.default_rng(0)
    n, k = 9000, 1000                  # n*k = 9M > _DENSE_ROUNDS_LIMIT
    m = 6 * n
    r = rng.integers(0, n, size=m)
    c = rng.integers(0, n, size=m)
    keep = r != c
    w = rng.uniform(0.1, 1.0, size=keep.sum())
    W = sp.csr_matrix((np.r_[w, w], (np.r_[r[keep], c[keep]],
                                     np.r_[c[keep], r[keep]])), shape=(n, n))
    W.sum_duplicates()
    labels = rng.integers(0, k, size=n)
    node_w = np.ones(n)
    cap = float(int(np.ceil(n / k)) + 3)
    touched = rng.choice(n, size=200, replace=False)
    before = edge_cut(W, labels)
    out = _refine_vec(W, node_w, labels.copy(), k, tol=0.15, passes=2,
                      max_w=cap, seed_touched=touched)
    assert out.shape == (n,)
    assert out.min() >= 0 and out.max() < k
    sizes = np.bincount(out, minlength=k)
    grew = sizes > np.bincount(labels, minlength=k)
    assert sizes[grew].max(initial=0) <= cap   # moves respected the cap
    assert edge_cut(W, out) <= before + 1e-9   # monotone improvement
    # Determinism of the restricted path.
    out2 = _refine_vec(W, node_w, labels.copy(), k, tol=0.15, passes=2,
                       max_w=cap, seed_touched=touched)
    np.testing.assert_array_equal(out, out2)


def test_reuse_rejects_incapable_partitioner(reuse_setup):
    _, graph, cache = reuse_setup
    with pytest.raises(ValueError, match="reuse"):
        resynthesize_plan(graph, 192, 8, epoch=1, temperature=0.0,
                          partitioner=partition_graph_loop, reuse=cache)
