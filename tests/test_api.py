"""The config-driven ``repro.api`` layer: configs, registries, Experiment."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (AFFINITY, PAIRWISE, PARTITIONER, PIPELINE,
                       BatchConfig, DataConfig, Experiment, ExperimentConfig,
                       ObjectiveConfig, Registry, TrainConfig,
                       resolve_pairwise)
from repro.core.ssl_loss import SSLHyper


def tiny_config(**objective_kw) -> ExperimentConfig:
    return ExperimentConfig(
        data=DataConfig(n=400, n_classes=6, input_dim=32, manifold_dim=5,
                        label_ratio=0.1),
        batch=BatchConfig(batch_size=96),
        objective=ObjectiveConfig(gamma=0.5, kappa=1e-4, weight_decay=1e-5,
                                  **objective_kw),
        train=TrainConfig(n_epochs=2, dropout=0.0, base_lr=5e-3,
                          hidden_dim=64, n_hidden=2))


# ------------------------------------------------------------------- configs
def test_config_roundtrip_identity():
    cfg = tiny_config(pairwise="ref")
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


def test_config_roundtrip_defaults_and_partial_dict():
    assert ExperimentConfig.from_dict({}) == ExperimentConfig()
    cfg = ExperimentConfig.from_dict({"objective": {"gamma": 2.0}})
    assert cfg.objective.gamma == 2.0
    assert cfg.batch == BatchConfig()          # untouched sections default


@pytest.mark.parametrize("section,bad", [
    ("data", {"n": 0}),
    ("data", {"label_ratio": 0.0}),
    ("graph", {"k": -1}),
    ("batch", {"batch_size": 0}),
    ("objective", {"gamma": -0.1}),
    ("train", {"execution": "magic"}),
    ("train", {"n_workers": 0}),
    ("execution", {"scan_chunk": -1}),
    ("execution", {"prefetch": -1}),
    ("execution", {"checkpoint_every": 2}),   # requires checkpoint_dir
    ("execution", {"max_staleness": 0}),
    ("repartition", {"reuse_hierarchy": "yes"}),   # must be a real bool
])
def test_config_validation_rejects(section, bad):
    with pytest.raises(ValueError):
        ExperimentConfig.from_dict({section: bad})


def test_repartition_reuse_hierarchy_knob_roundtrips():
    from repro.api import RepartitionConfig
    cfg = ExperimentConfig.from_dict({
        "batch": {"pipeline": "metabatch_stream"},
        "repartition": {"every_n_epochs": 2, "reuse_hierarchy": False}})
    assert cfg.repartition == RepartitionConfig(every_n_epochs=2,
                                                reuse_hierarchy=False)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert RepartitionConfig().reuse_hierarchy   # incremental by default


def test_experiment_builds_shared_hierarchy_cache():
    """With an active repartition config the Experiment hands the stream a
    HierarchyCache; with reuse disabled (or no repartition) it does not."""
    from repro.api import BatchConfig as BC, RepartitionConfig
    from repro.core.partition import HierarchyCache
    base = tiny_config(pairwise="ref")
    cfg = dataclasses.replace(
        base, batch=dataclasses.replace(base.batch,
                                        pipeline="metabatch_stream"),
        repartition=RepartitionConfig(every_n_epochs=1, seed=5))
    exp = Experiment(cfg).build()
    cache = exp.pipeline.stream._hierarchy
    assert isinstance(cache, HierarchyCache)
    assert cache.seed == 5 and cache.tol == cfg.partition.tol
    # An injected cache (sweeps over one shared graph) is used as-is.
    shared = Experiment(cfg, corpus=exp.corpus, eval_data=exp.eval_data,
                        graph=exp.graph, plan=exp.plan,
                        hierarchy_cache=cache).build()
    assert shared.pipeline.stream._hierarchy is cache
    off = dataclasses.replace(
        cfg, repartition=RepartitionConfig(every_n_epochs=1,
                                           reuse_hierarchy=False))
    assert Experiment(off).build().pipeline.stream._hierarchy is None


def test_execution_config_roundtrip_and_defaults():
    from repro.api import ExecutionConfig
    cfg = ExperimentConfig.from_dict(
        {"execution": {"strategy": "async_ps", "scan_chunk": 4,
                       "max_staleness": 3}})
    assert cfg.execution == ExecutionConfig(strategy="async_ps", scan_chunk=4,
                                            max_staleness=3)
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg
    assert ExecutionConfig().strategy is None   # = infer from TrainConfig
    ExecutionConfig(checkpoint_every=2, checkpoint_dir="/tmp/x")  # coherent


def test_explicit_strategy_overrides_legacy_parallel_shorthand():
    """ExecutionConfig(strategy="sequential") must win over the legacy
    TrainConfig(execution="parallel") shorthand — None means 'infer'."""
    from repro.api import ExecutionConfig
    legacy = dataclasses.replace(tiny_config().train, execution="parallel")
    infer = Experiment(dataclasses.replace(tiny_config(), train=legacy))
    assert infer._strategy() == "sync_mesh"
    explicit = Experiment(dataclasses.replace(
        tiny_config(), train=legacy,
        execution=ExecutionConfig(strategy="sequential")))
    assert explicit._strategy() == "sequential"


def test_graph_batch_pipeline_requires_unshuffled_blocks():
    with pytest.raises(ValueError, match="shuffle_blocks"):
        BatchConfig(pipeline="graph_batch")
    BatchConfig(pipeline="graph_batch", shuffle_blocks=False)  # coherent


def test_config_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown"):
        ExperimentConfig.from_dict({"objective": {"gammma": 1.0}})
    with pytest.raises(ValueError, match="unknown"):
        ExperimentConfig.from_dict({"objectives": {}})


def test_sslhyper_frozen_and_validated():
    h = SSLHyper(1.0, 1e-4, 1e-5)
    with pytest.raises(dataclasses.FrozenInstanceError):
        h.gamma = 2.0
    for bad in (dict(gamma=-1.0), dict(kappa=-1e-9),
                dict(weight_decay=-0.5)):
        with pytest.raises(ValueError):
            SSLHyper(**bad)


# ----------------------------------------------------------------- registries
def test_registry_register_get_and_unknown():
    reg = Registry("demo")
    reg.register("direct", np.sum)
    assert reg.get("direct") is np.sum

    @reg.register("decorated")
    def f():
        return 42

    assert reg.get("decorated") is f
    assert reg.names() == ["decorated", "direct"]
    assert "direct" in reg and "missing" not in reg
    with pytest.raises(KeyError, match="demo.*missing.*decorated"):
        reg.get("missing")


def test_registry_lazy_spec_resolution():
    reg = Registry("lazy")
    reg.register("builder", "repro.core.affinity:build_affinity_graph")
    from repro.core.affinity import build_affinity_graph
    assert reg.get("builder") is build_affinity_graph


def test_default_registries_resolve():
    from repro.api import STRATEGY
    assert callable(AFFINITY.get("knn_rbf"))
    assert callable(PARTITIONER.get("multilevel"))
    for name in ("meta_batch", "graph_batch", "random_batch"):
        assert callable(PIPELINE.get(name))
    for name in ("ref", "pallas", "fused", "auto"):
        assert callable(PAIRWISE.get(name))
    for name in ("sequential", "sync_mesh", "async_ps"):
        assert callable(STRATEGY.get(name))


def test_pairwise_auto_falls_back_to_ref_off_tpu(rng, monkeypatch):
    """Off-TPU, the "auto" entry must compute exactly what "ref" computes."""
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    assert jax.default_backend() != "tpu"   # CPU container invariant
    logp = jax.nn.log_softmax(jnp.asarray(rng.normal(size=(24, 7)),
                                          jnp.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(24, 24))), jnp.float32)
    auto = PAIRWISE.get("auto")(logp, W)
    want = PAIRWISE.get("ref")(logp, W)
    assert float(auto) == float(want)       # same code path, bit-identical


def test_pairwise_pallas_matches_ref(rng):
    logp = jax.nn.log_softmax(jnp.asarray(rng.normal(size=(48, 11)),
                                          jnp.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(48, 48))), jnp.float32)
    got = PAIRWISE.get("pallas")(logp, W)
    want = PAIRWISE.get("ref")(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=3e-5)


def test_resolve_pairwise_passthrough():
    assert resolve_pairwise(None) is None
    assert resolve_pairwise(np.sum) is np.sum
    assert resolve_pairwise("ref") is PAIRWISE.get("ref")


def test_pairwise_accepts_resolved_callable_and_shim_is_gone(rng):
    """PR 1 deprecated the ``pairwise_impl=`` raw-callable kwarg "for one
    release"; this is that release.  Callables now travel through the one
    ``pairwise=`` parameter (resolve once, pass down)."""
    import inspect

    from repro.core.ssl_loss import ssl_objective
    logits = jnp.asarray(rng.normal(size=(16, 5)), jnp.float32)
    labels = jnp.zeros(16, jnp.int32)
    mask = jnp.ones(16, jnp.float32)
    W = jnp.asarray(np.abs(rng.normal(size=(16, 16))), jnp.float32)
    hyp = SSLHyper(0.1, 0.01, 0.0)
    by_callable, _ = ssl_objective(logits, labels, mask, W, hyp,
                                   pairwise=PAIRWISE.get("ref"))
    by_name, _ = ssl_objective(logits, labels, mask, W, hyp, pairwise="ref")
    assert float(by_callable) == float(by_name)
    with pytest.raises(TypeError):
        ssl_objective(logits, labels, mask, W, hyp,
                      pairwise_impl=PAIRWISE.get("ref"))
    # ...and the kwarg is gone from the whole chain, not just ssl_objective.
    from repro.core.ssl_loss import graph_regularizer
    from repro.train.train_step import dnn_ssl_loss, dnn_ssl_step, lm_loss
    from repro.train.trainer import train_dnn_ssl
    for fn in (graph_regularizer, dnn_ssl_loss, dnn_ssl_step, lm_loss,
               train_dnn_ssl):
        assert "pairwise_impl" not in inspect.signature(fn).parameters, fn


# ----------------------------------------------------------------- experiment
@pytest.fixture(scope="module")
def ref_result():
    return Experiment(tiny_config(pairwise="ref")).run()


def test_experiment_run_produces_structured_result(ref_result):
    cfg = tiny_config(pairwise="ref")
    assert ref_result.config == cfg
    assert len(ref_result.history) == cfg.train.n_epochs
    assert ref_result.final["epoch"] == cfg.train.n_epochs - 1
    assert np.isfinite(ref_result.final["loss/total"])
    assert ref_result.seconds > 0
    assert ref_result.params is not None
    assert ref_result.best("eval/acc") >= ref_result.history[0]["eval/acc"]


def test_experiment_matches_handwired_trainer(ref_result):
    """Experiment.run() must equal the hand-assembled pipeline it replaced."""
    from repro.core.metabatch import plan_meta_batches
    from repro.data import MetaBatchPipeline
    from repro.models.dnn import DNNConfig
    from repro.optim import adagrad
    from repro.train.trainer import train_dnn_ssl

    cfg = tiny_config(pairwise="ref")
    exp = Experiment(cfg).build()    # reuse the same corpus/graph/plan
    pipe = MetaBatchPipeline(exp.corpus, exp.graph, exp.plan, n_workers=1,
                             seed=cfg.data.seed)
    res = train_dnn_ssl(
        pipe.epoch,
        cfg=DNNConfig(input_dim=32, hidden_dim=64, n_hidden=2, n_classes=6,
                      dropout=0.0),
        hyper=SSLHyper(0.5, 1e-4, 1e-5), n_epochs=2, base_lr=5e-3,
        dropout=0.0, eval_data=exp.eval_data, seed=0, opt=adagrad(),
        pairwise="ref")
    for got, want in zip(ref_result.history, res.history):
        np.testing.assert_allclose(got["loss/total"], want["loss/total"],
                                   rtol=1e-6)
        np.testing.assert_allclose(got["eval/acc"], want["eval/acc"],
                                   atol=1e-12)


def test_pallas_config_matches_ref_config(ref_result):
    """Selecting the kernel purely via config must not change the losses."""
    res_pal = Experiment(tiny_config(pairwise="pallas")).run()
    for a, b in zip(ref_result.history, res_pal.history):
        np.testing.assert_allclose(a["loss/total"], b["loss/total"],
                                   rtol=1e-4)
        np.testing.assert_allclose(a["eval/acc"], b["eval/acc"], atol=5e-2)


def test_random_batch_pipeline_via_config():
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        batch=BatchConfig(pipeline="random_batch", batch_size=96))
    res = Experiment(cfg).run()
    assert len(res.history) == 2
    assert np.isfinite(res.final["loss/total"])


def test_random_batch_rejects_oversized_batches():
    """batch_size*n_workers > n used to hang the generator forever."""
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        batch=BatchConfig(pipeline="random_batch", batch_size=512))
    with pytest.raises(ValueError, match="batch_size"):
        Experiment(cfg).build()


def test_zero_batch_epoch_warns_instead_of_crashing():
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        train=dataclasses.replace(tiny_config().train, n_workers=64,
                                  n_epochs=1))
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res = Experiment(cfg).run()
    assert res.history == []
    assert any("no batches" in str(w.message) for w in caught)


def test_parallel_execution_matches_sequential(ref_result):
    """On one device the ("data",) mesh path must be numerically inert."""
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        train=dataclasses.replace(tiny_config().train,
                                  execution="parallel"))
    res = Experiment(cfg).run()
    for a, b in zip(ref_result.history, res.history):
        np.testing.assert_allclose(a["loss/total"], b["loss/total"],
                                   rtol=1e-6)


def test_sync_mesh_strategy_by_name_matches_sequential(ref_result):
    """Selecting the engine strategy via ExecutionConfig (with a non-trivial
    scan_chunk) must equal the plain sequential run on one device."""
    from repro.api import ExecutionConfig
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        execution=ExecutionConfig(strategy="sync_mesh", scan_chunk=2))
    res = Experiment(cfg).run()
    for a, b in zip(ref_result.history, res.history):
        np.testing.assert_allclose(a["loss/total"], b["loss/total"],
                                   rtol=1e-6)


def test_async_ps_strategy_via_config_runs_and_learns():
    """The §4 stale-gradient regime is registry-selectable end to end."""
    from repro.api import ExecutionConfig
    cfg = dataclasses.replace(
        tiny_config(pairwise="ref"),
        # 4 epochs: stale gradients make single-epoch deltas noisy — the
        # learning signal needs a slightly longer horizon to dominate.
        train=dataclasses.replace(tiny_config().train, n_workers=4,
                                  n_epochs=4),
        execution=ExecutionConfig(strategy="async_ps", max_staleness=2))
    res = Experiment(cfg).run()
    assert len(res.history) == cfg.train.n_epochs
    assert res.history[-1]["loss/total"] < res.history[0]["loss/total"]
    assert np.isfinite(res.final["loss/total"])
