"""End-to-end behaviour tests for the paper's system.

The headline claim (graph-SSL ≫ supervised at low label ratios) is verified
quantitatively in ``benchmarks/bench_label_ratio.py``; here we check the
training loop's mechanics quickly: losses fall, the graph term acts, the
parallel decomposition is equivalent to sequential averaging.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSLHyper, build_affinity_graph, plan_meta_batches
from repro.data import MetaBatchPipeline, drop_labels, make_corpus
from repro.models.dnn import DNNConfig, init_dnn
from repro.train import train_dnn_ssl
from repro.train.train_step import dnn_ssl_loss


@pytest.fixture(scope="module")
def ssl_setup():
    full = make_corpus(1600, n_classes=8, input_dim=48, manifold_dim=6,
                       seed=0)
    corpus = dataclasses.replace(
        full, X=full.X[:1200], y=full.y[:1200],
        label_mask=full.label_mask[:1200])
    labeled = drop_labels(corpus, 0.02, seed=1)
    graph = build_affinity_graph(corpus.X, k=10)
    plan = plan_meta_batches(graph, batch_size=192, n_classes=8, seed=0)
    test = (full.X[1200:], full.y[1200:])
    return labeled, graph, plan, test


def test_training_reduces_loss_and_graph_term(ssl_setup):
    labeled, graph, plan, test = ssl_setup
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
    cfg = DNNConfig(input_dim=48, hidden_dim=96, n_hidden=2, n_classes=8,
                    dropout=0.0)
    res = train_dnn_ssl(pipe.epoch, cfg=cfg,
                        hyper=SSLHyper(0.3, 1e-4, 1e-5), n_epochs=6,
                        dropout=0.0, base_lr=5e-3, eval_data=test, seed=0)
    losses = [h["loss/total"] for h in res.history]
    assert losses[-1] < losses[0]
    accs = [h["eval/acc"] for h in res.history]
    assert accs[-1] > accs[0]
    assert accs[-1] > 0.4


def test_ssl_training_improves_over_supervised(ssl_setup):
    """The paper's core claim, small-scale: at 2% labels the graph
    regularizer buys accuracy over the supervised-only baseline."""
    labeled, graph, plan, test = ssl_setup
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
    cfg = DNNConfig(input_dim=48, hidden_dim=256, n_hidden=3, n_classes=8,
                    dropout=0.0)
    kw = dict(n_epochs=10, dropout=0.0, base_lr=1e-2, eval_data=test, seed=0)
    ssl = train_dnn_ssl(pipe.epoch, cfg=cfg, hyper=SSLHyper(1.0, 1e-4, 1e-5),
                        **kw)
    sup = train_dnn_ssl(pipe.epoch, cfg=cfg, hyper=SSLHyper(0.0, 0.0, 1e-5),
                        **kw)
    acc_ssl = max(h["eval/acc"] for h in ssl.history)
    acc_sup = max(h["eval/acc"] for h in sup.history)
    assert acc_ssl > acc_sup + 0.03, (acc_ssl, acc_sup)


def test_parallel_decomposition_equals_sequential_average(ssl_setup):
    """§2.3: the k-worker loss is the mean of per-worker losses — a step on
    k stacked batches equals averaging the k gradients (sync SGD)."""
    labeled, graph, plan, test = ssl_setup
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=2, seed=0)
    batch = next(iter(pipe.epoch()))
    jb = {k: jnp.asarray(v) for k, v in dataclasses.asdict(batch).items()
          if v is not None}
    cfg = DNNConfig(input_dim=48, hidden_dim=32, n_hidden=1, n_classes=8)
    hyper = SSLHyper(0.1, 1e-4, 0.0)
    params = init_dnn(cfg, jax.random.PRNGKey(0))

    loss2, _ = dnn_ssl_loss(params, jb, cfg, hyper)
    per = []
    for w in range(2):
        sub = {k: v[w : w + 1] for k, v in jb.items()}
        li, _ = dnn_ssl_loss(params, sub, cfg, hyper)
        per.append(float(li))
    np.testing.assert_allclose(float(loss2), np.mean(per), rtol=1e-6)

    g2 = jax.grad(lambda p: dnn_ssl_loss(p, jb, cfg, hyper)[0])(params)
    g_avg = jax.tree.map(
        lambda *gs: sum(gs) / 2,
        *[jax.grad(lambda p: dnn_ssl_loss(
            p, {k: v[w : w + 1] for k, v in jb.items()}, cfg, hyper)[0]
        )(params) for w in range(2)])
    for a, b in zip(jax.tree.leaves(g2), jax.tree.leaves(g_avg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_pallas_pairwise_callable_plugs_into_training(ssl_setup):
    """The Pallas kernel is a drop-in ``pairwise=`` callable for the SSL
    objective (raw callables travel through the same parameter as registry
    names — the separate ``pairwise_impl`` shim is gone)."""
    from repro.kernels import graph_reg_pairwise
    labeled, graph, plan, test = ssl_setup
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
    batch = next(iter(pipe.epoch()))
    jb = {k: jnp.asarray(v) for k, v in dataclasses.asdict(batch).items()
          if v is not None}
    cfg = DNNConfig(input_dim=48, hidden_dim=32, n_hidden=1, n_classes=8)
    hyper = SSLHyper(0.1, 1e-4, 0.0)
    params = init_dnn(cfg, jax.random.PRNGKey(0))
    l_ref, _ = dnn_ssl_loss(params, jb, cfg, hyper)
    import functools
    impl = functools.partial(graph_reg_pairwise, use_pallas=True)
    l_ker, _ = dnn_ssl_loss(params, jb, cfg, hyper, pairwise=impl)
    np.testing.assert_allclose(float(l_ker), float(l_ref), rtol=1e-4)


def test_async_sgd_converges(ssl_setup):
    """§4 future-work variant: async (stale-gradient) SSL training still
    learns at small staleness."""
    from repro.train.async_trainer import train_dnn_ssl_async
    from repro.train.trainer import evaluate_dnn
    labeled, graph, plan, test = ssl_setup
    pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
    cfg = DNNConfig(input_dim=48, hidden_dim=96, n_hidden=2, n_classes=8,
                    dropout=0.0)
    params, hist = train_dnn_ssl_async(
        pipe.epoch, cfg=cfg, hyper=SSLHyper(0.3, 1e-4, 1e-5), n_epochs=5,
        n_workers=4, max_staleness=2, base_lr=5e-3, seed=0,
        eval_fn=lambda p: evaluate_dnn(p, *test))
    accs = [h["eval/acc"] for h in hist]
    assert accs[-1] > accs[0]
    assert accs[-1] > 0.4
