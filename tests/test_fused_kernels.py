"""Fused single-pass regularizer (fwd + tiled VJP) and streaming top-k.

No hypothesis dependency — unlike tests/test_kernels.py this module must run
in the minimal container, because it guards the fused kernels' gradient
semantics on non-tile-aligned shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentConfig, ObjectiveConfig, PAIRWISE, resolve_pairwise
from repro.core.ssl_loss import SSLHyper, graph_regularizer, ssl_objective
from repro.kernels import ref
from repro.kernels.graph_reg import (graph_reg_bwd_pallas,
                                     graph_reg_cross_pallas,
                                     graph_reg_fused_pallas)
from repro.kernels.ops import (graph_regularizer_auto, graph_regularizer_fused,
                               knn_topk)
from repro.kernels.pairwise import knn_topk_pallas
from repro.kernels.tuning import TileSpec, select_tiles


def _problem(rng, B, C, density=0.3):
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = np.abs(rng.normal(size=(B, B))) * (rng.random((B, B)) < density)
    return logp, jnp.asarray(W, jnp.float32)


# ------------------------------------------------------------ forward value
@pytest.mark.parametrize("B,C", [(16, 32), (33, 70), (96, 200), (128, 512),
                                 (130, 700), (257, 39)])
def test_fused_forward_matches_oracle(rng, B, C):
    """Single-sweep fused kernel == γ·cross − Σ(κ+γ·deg)·H on padded and
    unpadded shapes (B, C not multiples of the tile sizes)."""
    logp, W = _problem(rng, B, C)
    gamma, kappa = 0.7, 0.013
    got = graph_reg_fused_pallas(logp, W, gamma, kappa, bi=32, bj=64, bc=128)
    want = ref.graph_regularizer_ref(logp, W, gamma, kappa)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_fused_cross_mode_matches_pairwise_oracle(rng):
    logp, W = _problem(rng, 70, 50)
    got = graph_reg_cross_pallas(logp, W, bi=32, bj=32, bc=32)
    want = ref.graph_reg_pairwise_ref(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------- gradients
@pytest.mark.parametrize("B,C", [(48, 90), (33, 70), (130, 150)])
def test_fused_vjp_matches_autodiff_of_oracle(rng, B, C):
    """Tiled analytic VJP == jax.grad of the jnp oracle, on shapes where B
    and C are NOT multiples of bi/bj/bc (the padding edge case)."""
    logp, W = _problem(rng, B, C)
    gamma, kappa = 0.31, 2e-3
    f = lambda lp, w: graph_regularizer_fused(  # noqa: E731
        lp, w, gamma, kappa, tiles=TileSpec(bi=32, bj=64, bc=64))
    g = lambda lp, w: ref.graph_regularizer_ref(lp, w, gamma, kappa)  # noqa: E731
    for argnum in (0, 1):
        got = jax.grad(f, argnums=argnum)(logp, W)
        want = jax.grad(g, argnums=argnum)(logp, W)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-6)


def test_cross_vjp_matches_autodiff_of_oracle(rng):
    """The "pallas" (cross-only) entry's tiled backward on unaligned shapes."""
    logp, W = _problem(rng, 41, 67)
    impl = PAIRWISE.get("pallas")
    for argnum in (0, 1):
        got = jax.grad(lambda lp, w: impl(lp, w), argnums=argnum)(logp, W)
        want = jax.grad(lambda lp, w: ref.graph_reg_pairwise_ref(lp, w),
                        argnums=argnum)(logp, W)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-6)


def test_bwd_kernel_cotangent_scaling(rng):
    """dL/dx under cotangent g must be g·(dL/dx under cotangent 1)."""
    logp, W = _problem(rng, 24, 17)
    d1, dW1 = graph_reg_bwd_pallas(logp, W, 1.0, gamma=0.5, kappa=1e-3,
                                   ent_weight=0.5, bi=16, bj=16, bc=16)
    d3, dW3 = graph_reg_bwd_pallas(logp, W, 3.0, gamma=0.5, kappa=1e-3,
                                   ent_weight=0.5, bi=16, bj=16, bc=16)
    np.testing.assert_allclose(np.asarray(d3), 3.0 * np.asarray(d1),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(dW3), 3.0 * np.asarray(dW1),
                               rtol=1e-5)


# --------------------------------------------------- dispatch / registry
def test_graph_regularizer_dispatches_full_kernel(rng):
    logp, W = _problem(rng, 50, 23)
    got = graph_regularizer(logp, W, 0.9, 1e-3, pairwise="fused")
    want = graph_regularizer(logp, W, 0.9, 1e-3, pairwise=None)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_auto_full_regularizer_off_tpu_is_oracle(rng, monkeypatch):
    monkeypatch.delenv("REPRO_FORCE_PALLAS", raising=False)
    logp, W = _problem(rng, 30, 12)
    got = graph_regularizer_auto(logp, W, 0.4, 1e-2)
    want = ref.graph_regularizer_ref(logp, W, 0.4, 1e-2)
    assert float(got) == float(want)


def test_ssl_objective_fused_matches_ref(rng):
    logits = jnp.asarray(rng.normal(size=(37, 9)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 9, size=37), jnp.int32)
    mask = jnp.asarray(rng.random(37) < 0.3, jnp.float32)
    W = jnp.asarray(np.abs(rng.normal(size=(37, 37))), jnp.float32)
    hyp = SSLHyper(0.5, 1e-3, 0.0)
    fused, m_fused = ssl_objective(logits, labels, mask, W, hyp,
                                   pairwise="fused")
    want, m_want = ssl_objective(logits, labels, mask, W, hyp, pairwise="ref")
    np.testing.assert_allclose(float(fused), float(want), rtol=1e-5)
    np.testing.assert_allclose(float(m_fused["loss/graph"]),
                               float(m_want["loss/graph"]), rtol=1e-5)


def test_resolve_pairwise_tiles_wrapping_keeps_markers():
    tiled = resolve_pairwise("fused", tiles=TileSpec(bi=32))
    assert getattr(tiled, "full_regularizer", False)
    assert getattr(tiled, "accepts_tiles", False)
    # The oracle ignores tile hints entirely.
    assert resolve_pairwise("ref", tiles=TileSpec(bi=32)) is PAIRWISE.get("ref")


def test_fused_selectable_from_experiment_config(rng):
    cfg = ExperimentConfig(objective=ObjectiveConfig(
        gamma=0.5, pairwise="fused", tile_bi=32, tile_bj=32, tile_bc=64))
    impl = resolve_pairwise(cfg.objective.pairwise, tiles=cfg.objective.tiles())
    logp, W = _problem(rng, 29, 13)
    got = graph_regularizer(logp, W, cfg.objective.gamma, cfg.objective.kappa,
                            pairwise=impl)
    want = ref.graph_regularizer_ref(logp, W, cfg.objective.gamma,
                                     cfg.objective.kappa)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


def test_objective_config_validates_tiles():
    with pytest.raises(ValueError, match="tile_bi"):
        ObjectiveConfig(tile_bi=0)
    cfg = ExperimentConfig(objective=ObjectiveConfig(tile_bc=256))
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg


# ------------------------------------------------------------- tile tuning
def test_select_tiles_pinned_beats_table():
    auto = select_tiles("graph_reg", rows=256, backend="cpu")
    assert auto.bi and auto.bj and auto.bc
    pinned = select_tiles("graph_reg", rows=256, backend="cpu",
                          pinned=TileSpec(bi=64))
    assert pinned.bi == 64
    assert pinned.bj == auto.bj and pinned.bc == auto.bc


def test_select_tiles_shape_buckets():
    small = select_tiles("graph_reg", rows=256, backend="tpu")
    large = select_tiles("graph_reg", rows=4096, backend="tpu")
    assert small.bc == 256 and large.bi == 256


def test_tilespec_rejects_bad_dims():
    with pytest.raises(ValueError):
        TileSpec(bi=-8)


# -------------------------------------------------------- streaming top-k
@pytest.mark.parametrize("N,M,D,k", [(40, 40, 16, 5), (130, 257, 100, 10),
                                     (33, 65, 7, 3)])
def test_knn_topk_kernel_matches_dense_oracle(rng, N, M, D, k):
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    y = x if N == M else jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    ex = N == M
    d2, idx = knn_topk_pallas(x, y, k, exclude_self=ex, bi=32, bj=64, bd=32)
    d2r, idxr = ref.knn_topk_ref(x, y, k, exclude_self=ex)
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d2r),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idxr))


def test_knn_topk_rejects_impossible_k(rng):
    x = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    with pytest.raises(ValueError, match="k must be"):
        knn_topk_pallas(x, x, 8, exclude_self=True)


def test_streaming_host_knn_matches_dense(rng):
    """Column-streamed host search == single-tile search == dense oracle."""
    from repro.core.affinity import knn_edges
    X = rng.normal(size=(150, 20)).astype(np.float32)
    src_a, dst_a, d_a = knn_edges(X, 6, col_block=37)    # many column chunks
    src_b, dst_b, d_b = knn_edges(X, 6, col_block=10_000)  # one chunk
    np.testing.assert_array_equal(src_a, src_b)
    np.testing.assert_array_equal(dst_a, dst_b)
    np.testing.assert_allclose(d_a, d_b)
    d2r, idxr = ref.knn_topk_ref(jnp.asarray(X), jnp.asarray(X), 6,
                                 exclude_self=True)
    np.testing.assert_array_equal(dst_a.reshape(150, 6), np.asarray(idxr))


def test_affinity_graph_device_backend_matches_host(rng):
    from repro.core.affinity import build_affinity_graph
    X = rng.normal(size=(120, 16)).astype(np.float32)
    g_host = build_affinity_graph(X, k=5)
    g_dev = build_affinity_graph(X, k=5, backend="device")
    assert g_host.sigma == pytest.approx(g_dev.sigma, rel=1e-4)
    np.testing.assert_allclose(g_host.W.toarray(), g_dev.W.toarray(),
                               rtol=1e-4, atol=1e-6)


def test_knn_edges_rejects_unknown_backend(rng):
    from repro.core.affinity import knn_edges
    with pytest.raises(ValueError, match="backend"):
        knn_edges(rng.normal(size=(10, 3)), 2, backend="gpu")


def test_knn_topk_ops_fallback_matches_kernel(rng):
    x = jnp.asarray(rng.normal(size=(20, 6)), jnp.float32)
    d2a, idxa = knn_topk(x, x, 4, exclude_self=True, use_pallas=False)
    d2b, idxb = knn_topk(x, x, 4, exclude_self=True, use_pallas=True)
    np.testing.assert_allclose(np.asarray(d2a), np.asarray(d2b),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(idxa), np.asarray(idxb))


# ------------------------------------------------- no B×B outside kernels
def test_fused_grad_materializes_no_bxb_outside_kernels(rng):
    """The fwd+bwd jaxpr of the fused path must contain no (B, B)-shaped
    intermediate produced by anything but a pallas kernel (the historical
    fallback rebuilt P·logPᵀ with full-size jnp matmuls)."""
    from repro.analysis import count_bxb_intermediates
    B = 64   # tile-aligned: padding adds no (B, B) reshapes either way
    logp, W = _problem(rng, B, 39)
    fused = lambda lp: graph_regularizer_fused(lp, W, 0.5, 1e-3)  # noqa: E731
    oracle = lambda lp: ref.graph_regularizer_ref(lp, W, 0.5, 1e-3)  # noqa: E731
    n_fused = count_bxb_intermediates(jax.grad(fused), logp, B=B)
    n_ref = count_bxb_intermediates(jax.grad(oracle), logp, B=B)
    assert n_fused == 0
    assert n_ref > 0
