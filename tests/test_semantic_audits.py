"""Tests for the semantic audit tier (R/W/D/S passes + waivers).

Every rule is exercised as a twin: a known-bad fixture the pass must
flag and a known-good twin it must not.  The R-pass twins include a
reconstruction of the pre-PR-9 decode-prefill bug (the unsplit sampling
key reused across prefill steps, re-split only in the decode loop) —
the bug family this tier exists to catch mechanically.
"""
from __future__ import annotations

import os
import textwrap
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis import (
    EntryPoint,
    Finding,
    analyze_rng,
    apply_waivers,
    audit_entry_determinism,
    audit_entry_rng,
    audit_entry_sharding,
    audit_races,
    audit_seeded_modules,
    check_launch_races,
    check_layout,
    check_tile_list,
    scan_waivers,
    stale_waiver_findings,
)
from repro.analysis.sharding_audit import _check_donated_shardings
from repro.analysis.vmem_audit import Block, Launch
from repro.core.metabatch import layout_from_occupancy

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _rules(findings):
    return sorted(f.rule for f in findings)


def _rng(fn, *args):
    closed = jax.make_jaxpr(fn)(*args)
    return analyze_rng(closed, where="fixture")


# ============================================================ R-pass (rng)
class TestRngAudit:
    def test_r001_key_reuse_flagged(self):
        def bad():
            key = jax.random.PRNGKey(0)
            return jax.random.normal(key, (2,)), \
                jax.random.uniform(key, (2,))

        findings, metrics = _rng(bad)
        assert "R001" in _rules(findings)
        assert metrics["draws"] == 2

    def test_r001_split_before_each_draw_clean(self):
        def good():
            key = jax.random.PRNGKey(0)
            k1, k2 = jax.random.split(key)
            return jax.random.normal(k1, (2,)), \
                jax.random.uniform(k2, (2,))

        findings, metrics = _rng(good)
        assert findings == []
        assert metrics["draws"] == 2

    def test_r002_unsplit_scan_carry_flagged(self):
        def bad(x):
            def body(k, xi):
                return k, xi + jax.random.normal(k, ())
            _, ys = jax.lax.scan(body, jax.random.PRNGKey(0), x)
            return ys

        findings, metrics = _rng(bad, jnp.arange(4.0))
        assert "R002" in _rules(findings)
        # the carried key is drawn once per iteration -> R001 too
        assert "R001" in _rules(findings)
        assert metrics["draws"] == 4          # scan-weighted consumption

    def test_r002_split_inside_body_clean(self):
        def good(x):
            def body(k, xi):
                k, sub = jax.random.split(k)
                return k, xi + jax.random.normal(sub, ())
            _, ys = jax.lax.scan(body, jax.random.PRNGKey(0), x)
            return ys

        findings, _ = _rng(good, jnp.arange(4.0))
        assert findings == []

    def test_r003_dropped_split_flagged(self):
        def bad():
            rng, _ = jax.random.split(jax.random.PRNGKey(0))
            return rng                        # sibling never drawn from

        findings, _ = _rng(bad)
        assert _rules(findings) == ["R003"]

    def test_r003_consumed_sibling_clean(self):
        def good():
            rng, sub = jax.random.split(jax.random.PRNGKey(0))
            return rng, jax.random.normal(sub, ())

        findings, _ = _rng(good)
        assert findings == []

    # -- the pre-PR-9 decode-prefill bug, reconstructed ------------------
    def test_prefill_key_reuse_canary(self):
        """The old generate shape: sample during prefill with the unsplit
        key (discarding the sample), then re-split in the decode loop.
        The R-pass must flag both the reuse and the discarded entropy."""
        def old_generate(emb):
            key = jax.random.PRNGKey(0)
            for t in range(emb.shape[0]):     # prefill: sample & discard
                _ = jax.random.categorical(key, emb[t])
            toks, logits = [], emb[-1]
            for _s in range(3):               # decode: split per step
                key, sub = jax.random.split(key)
                toks.append(jax.random.categorical(sub, logits))
            return jnp.stack(toks)

        findings, metrics = _rng(old_generate, jnp.zeros((2, 7)))
        rules = _rules(findings)
        assert "R001" in rules                # unsplit key drawn twice
        assert "R003" in rules                # draws discarded
        assert metrics["dead_draws"] == 2

    def test_fixed_prefill_clean(self):
        def new_generate(emb):
            key = jax.random.PRNGKey(0)       # prefill: cache only, no RNG
            toks, logits = [], emb[-1]
            for _s in range(3):
                key, sub = jax.random.split(key)
                toks.append(jax.random.categorical(sub, logits))
            return jnp.stack(toks)

        findings, metrics = _rng(new_generate, jnp.zeros((2, 7)))
        assert findings == []
        assert metrics["dead_draws"] == 0

    def test_registered_serve_entry_clean(self):
        """The real serve/decode.generate entry traces clean and actually
        exercises the pass (keys split per decode step)."""
        from repro.analysis.entrypoints import serve_decode_generate

        findings, metrics = audit_entry_rng(serve_decode_generate)
        assert findings == []
        assert metrics["splits_traced"] >= 3
        assert metrics["draws"] >= 3


# =========================================================== W-pass (race)
class TestRaceAudit:
    def _launch(self, accum_axes):
        out = Block("out", (8, 8), "out",
                    index_map=lambda i, j: (i, 0),
                    accum_axes=accum_axes)
        return Launch("k", "fwd", (4, 3), (out,))

    def test_w001_undeclared_revisit_flagged(self):
        findings = check_launch_races(self._launch(()), where="t")
        assert _rules(findings) == ["W001"]

    def test_w001_declared_accum_axis_clean(self):
        findings = check_launch_races(self._launch((1,)), where="t")
        assert findings == []

    def test_w002_duplicate_tile(self):
        findings = check_tile_list([0, 0, 1], [1, 1, 0], [1, 1, 1], 2,
                                   where="t", name="l")
        assert "W002" in _rules(findings)

    def test_w003_unsorted_major(self):
        findings = check_tile_list([1, 0], [0, 0], [1, 1], 2,
                                   where="t", name="l")
        assert "W003" in _rules(findings)

    def test_w004_unvisited_line(self):
        findings = check_tile_list([0, 0], [0, 1], [1, 1], 2,
                                   where="t", name="l")
        assert _rules(findings) == ["W004"]

    def test_w004_occupancy_mismatch(self):
        occ = np.array([[True, True], [False, True]])
        findings = check_tile_list([0, 1], [0, 1], [1, 1], 2,
                                   occ=occ, where="t", name="l")
        assert "W004" in _rules(findings)

    def test_sentinel_and_padding_clean(self):
        # line 1 empty -> (1, 0, valid=0) sentinel; tail pad repeats it.
        findings = check_tile_list([0, 1, 1], [0, 0, 0], [1, 0, 0], 2,
                                   where="t", name="l")
        assert findings == []

    def test_seeded_layout_clean_and_corrupted_duplicate_flagged(self):
        rng = np.random.default_rng(0)
        occ = rng.random((6, 6)) < 0.35
        layout = layout_from_occupancy(occ, 16, list_len=48)
        assert check_layout(layout, where="t") == []

        rows = np.array(layout.rows)
        cols = np.array(layout.cols)
        idx = np.nonzero(np.array(layout.valid))[0]
        rows[idx[1]], cols[idx[1]] = rows[idx[0]], cols[idx[0]]
        findings = check_tile_list(rows, cols, layout.valid, layout.nt,
                                   where="t", name="l")
        assert "W002" in _rules(findings)

    def test_full_pass_clean_on_repo(self):
        findings, metrics = audit_races()
        assert findings == []
        assert metrics["launches_checked"] > 0
        assert metrics["tiles_proven_race_free"] > 0

    def test_blocksparse_validate_kwarg(self):
        from repro.kernels.ops import graph_regularizer_blocksparse

        W = np.kron(np.eye(3), np.ones((2, 2))).astype(np.float32)
        occ = W.reshape(3, 2, 3, 2).any((1, 3))
        layout = layout_from_occupancy(occ, 2)
        logp = jnp.log(jnp.full((6, 4), 0.25))
        out = graph_regularizer_blocksparse(
            logp, jnp.asarray(W), 1e-3, 1e-4, layout=layout, validate=True)
        assert np.isfinite(float(out))

        arrs = [np.array(a) for a in layout.arrays()]
        idx = np.nonzero(arrs[2])[0]
        arrs[0][idx[1]], arrs[1][idx[1]] = arrs[0][idx[0]], arrs[1][idx[0]]
        with pytest.raises(ValueError, match="W002"):
            graph_regularizer_blocksparse(
                logp, jnp.asarray(W), 1e-3, 1e-4,
                layout=tuple(arrs), validate=True)


# ==================================================== D-pass (determinism)
class TestDeterminismAudit:
    def _segment_entry(self, **kw):
        x = jnp.ones((8,), jnp.float32)
        idx = jnp.zeros((8,), jnp.int32)

        def f(x, idx):
            return jax.ops.segment_sum(x, idx, num_segments=4)

        return EntryPoint("seg", lambda: (f, (x, idx)), **kw)

    def test_d001_unordered_float_scatter_flagged(self):
        findings, metrics = audit_entry_determinism(self._segment_entry())
        assert _rules(findings) == ["D001"]
        assert metrics["scatters_checked"] == 1

    def test_d001_opt_out_entry_clean(self):
        findings, _ = audit_entry_determinism(
            self._segment_entry(deterministic=False))
        assert findings == []

    def test_d001_unique_indices_clean(self):
        x = jnp.ones((4,), jnp.float32)

        def f(x):
            return jnp.zeros(4).at[jnp.arange(4)].add(
                x, unique_indices=True)

        entry = EntryPoint("uniq", lambda: (f, (x,)))
        findings, _ = audit_entry_determinism(entry)
        assert findings == []

    def test_d001_int_scatter_clean(self):
        x = jnp.ones((8,), jnp.int32)
        idx = jnp.zeros((8,), jnp.int32)

        def f(x, idx):
            return jax.ops.segment_sum(x, idx, num_segments=4)

        entry = EntryPoint("iseg", lambda: (f, (x, idx)))
        findings, _ = audit_entry_determinism(entry)
        assert findings == []

    def _host(self, tmp_path, source, used=None):
        (tmp_path / "m.py").write_text(textwrap.dedent(source))
        return audit_seeded_modules({"m": "m.py"}, root=str(tmp_path),
                                    used=used)

    def test_d002_set_iteration_flagged(self, tmp_path):
        findings, _ = self._host(tmp_path, """
            def plan(items):
                pool = set(items)
                out = []
                for x in pool:
                    out.append(x)
                return out
        """)
        assert _rules(findings) == ["D002"]

    def test_d002_sorted_iteration_clean(self, tmp_path):
        findings, _ = self._host(tmp_path, """
            def plan(items):
                pool = set(items)
                out = []
                for x in sorted(pool):
                    out.append(x)
                return out
        """)
        assert findings == []

    def test_d002_tiebreak_and_materialization(self, tmp_path):
        findings, _ = self._host(tmp_path, """
            def pick(items, deg):
                pool = set(items)
                seed = max(pool, key=lambda u: deg[u])
                order = list(pool)
                first = pool.pop()
                return seed, order, first
        """)
        assert _rules(findings) == ["D002", "D002", "D002"]

    def test_d003_global_entropy_flagged(self, tmp_path):
        findings, _ = self._host(tmp_path, """
            import random
            import time
            import numpy as np

            def noisy():
                np.random.seed(0)
                a = random.random()
                g = np.random.default_rng()
                h = np.random.default_rng(int(time.time()))
                return a, g, h
        """)
        assert _rules(findings) == ["D003", "D003", "D003", "D003"]

    def test_d003_seeded_generator_clean(self, tmp_path):
        findings, _ = self._host(tmp_path, """
            import numpy as np

            def quiet(seed):
                g = np.random.default_rng(seed)
                return g.random(4)
        """)
        assert findings == []

    def test_line_waiver_suppresses_and_is_recorded(self, tmp_path):
        used: set = set()
        findings, metrics = self._host(tmp_path, """
            def plan(items):
                pool = set(items)
                out = []
                # audit: safe(D002): int-set order is stable in CPython
                for x in pool:
                    out.append(x)
                return out
        """, used=used)
        assert findings == []
        assert metrics["suppressed"] == 1
        assert len(used) == 1

    def test_seeded_modules_clean_on_repo(self):
        used: set = set()
        findings, metrics = audit_seeded_modules(root=REPO_ROOT, used=used)
        assert findings == []
        assert metrics["seeded_modules_scanned"] == 5
        # partition.py carries two waived D002 sites with reasons on record
        assert metrics["suppressed"] >= 2
        assert used


# ====================================================== S-pass (sharding)
class TestShardingAudit:
    def setup_method(self):
        self.mesh = jax.make_mesh((1,), ("data",))
        self.x = jnp.ones((4,), jnp.float32)

    def _psum_fn(self):
        def f(x):
            return shard_map(lambda a: jax.lax.psum(a, "data"),
                             mesh=self.mesh, in_specs=P("data"),
                             out_specs=P(), check_rep=False)(x)
        return f

    def test_s001_undeclared_axis_flagged(self):
        entry = EntryPoint("sh", lambda: (self._psum_fn(), (self.x,)))
        findings, metrics = audit_entry_sharding(entry)
        assert _rules(findings) == ["S001"]
        assert metrics["collectives_audited"] == 1

    def test_s001_declared_axis_clean(self):
        entry = EntryPoint("sh", lambda: (self._psum_fn(), (self.x,)),
                           mesh_axes=("data",))
        findings, _ = audit_entry_sharding(entry)
        assert findings == []

    def _gather_in_scan_fn(self):
        def body_fn(x):
            def body(c, s):
                return c + jax.lax.all_gather(s, "data").sum(), 0.0
            out, _ = jax.lax.scan(body, 0.0, x)
            return out

        def f(x):
            return shard_map(body_fn, mesh=self.mesh, in_specs=P("data"),
                             out_specs=P(), check_rep=False)(x)
        return f

    def test_s002_gather_in_loop_flagged(self):
        entry = EntryPoint("sh", lambda: (self._gather_in_scan_fn(),
                                          (self.x,)),
                           mesh_axes=("data",))
        findings, _ = audit_entry_sharding(entry)
        assert _rules(findings) == ["S002"]

    def test_s002_opt_in_clean(self):
        entry = EntryPoint("sh", lambda: (self._gather_in_scan_fn(),
                                          (self.x,)),
                           mesh_axes=("data",),
                           allow_loop_collectives=("psum", "all_gather"))
        findings, _ = audit_entry_sharding(entry)
        assert findings == []

    def test_s003_donation_sharding_mismatch(self):
        sharded = NamedSharding(self.mesh, P("data"))
        replicated = NamedSharding(self.mesh, P())
        entry = SimpleNamespace(name="e")

        findings: list = []
        _check_donated_shardings(SimpleNamespace(params={
            "donated_invars": (True,), "in_shardings": (sharded,),
            "out_shardings": (replicated,), "name": "chunk"}),
            entry, findings)
        assert _rules(findings) == ["S003"]

        for out_sh in (sharded, None):   # fixpoint / wildcard: clean
            clean: list = []
            _check_donated_shardings(SimpleNamespace(params={
                "donated_invars": (True,), "in_shardings": (sharded,),
                "out_shardings": (out_sh,), "name": "chunk"}),
                entry, clean)
            assert clean == []


# ================================================= waivers / A001 / CLI
class TestWaivers:
    def test_scoped_waiver_matches_where_glob(self, tmp_path):
        src = "# audit: safe(R001@engine_*): replay is intentional here\n"
        path = tmp_path / "w.py"
        path.write_text(src)
        waivers = scan_waivers(str(path), relpath="w.py")
        assert len(waivers) == 1 and waivers[0].scope == "engine_*"

        hit = Finding("rng", "R001", "engine_capture", "m")
        miss = Finding("rng", "R001", "serve_decode_generate", "m")
        used: set = set()
        kept = apply_waivers([hit, miss], waivers, used=used)
        assert kept == [miss]
        assert used == {waivers[0].key}

    def test_stale_waiver_becomes_a001(self, tmp_path):
        path = tmp_path / "w.py"
        path.write_text("# audit: safe(D002): no longer needed\n")
        waivers = scan_waivers(str(path), relpath="w.py")

        stale = stale_waiver_findings(waivers, set(), ("determinism",))
        assert _rules(stale) == ["A001"]
        # not stale if its pass family did not run, or if it was used
        assert stale_waiver_findings(waivers, set(), ("vmem",)) == []
        assert stale_waiver_findings(
            waivers, {waivers[0].key}, ("determinism",)) == []


def test_cli_only_alias_and_github_format(tmp_path, monkeypatch, capsys):
    from repro.analysis import cli

    bad = Finding("vmem", "V001", "tuning[0]:rbf", "footprint too big",
                  line=7, path="src/repro/kernels/tuning.py")

    def fake_vmem(report):
        report.extend("vmem", [bad], {"rows_checked": 1})

    monkeypatch.setattr(cli, "_run_vmem", fake_vmem)
    args = ["--only", "vmem", "--format", "github",
            "--report", str(tmp_path / "report.json"),
            "--baseline", str(tmp_path / "baseline.json")]
    assert cli.main(args) == 1
    out = capsys.readouterr().out
    assert ("::error file=src/repro/kernels/tuning.py,line=7::"
            "[V001] tuning[0]:rbf: footprint too big") in out


def test_cli_race_pass_clean_on_repo(tmp_path):
    from repro.analysis import cli

    assert cli.main(["--only", "race",
                     "--report", str(tmp_path / "report.json"),
                     "--baseline", str(tmp_path / "baseline.json")]) == 0


def test_cli_rejects_unknown_pass():
    from repro.analysis import cli

    with pytest.raises(SystemExit):
        cli.main(["--only", "nonsense"])
