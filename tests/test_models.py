"""Per-architecture smoke tests (reduced variants, §ARCHITECTURES) and
train/prefill/decode consistency across all families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, INPUT_SHAPES, config_for_shape, get_config
from repro.core.ssl_loss import SSLHyper
from repro.models import transformer as tf
from repro.models.config import ATTN, ATTN_SWA
from repro.optim import adagrad
from repro.train.train_step import lm_train_step


def _inputs(cfg, B=2, T=16, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    kw = {}
    if cfg.modality_tokens:
        kw["modality_embeds"] = jax.random.normal(
            key, (B, cfg.modality_tokens, cfg.modality_dim), jnp.float32)
    return toks, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced variant: one forward + one SSL train step, shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 16
    toks, kw = _inputs(cfg, B, T)
    out = tf.forward(params, cfg, toks, remat=False, **kw)
    assert out["logits"].shape == (B, T, cfg.vocab_size)
    assert out["pooled_logits"].shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(out["logits"]).any())

    opt = adagrad()
    opt_state = opt.init(params)
    batch = {
        "tokens": toks, "targets": jnp.roll(toks, -1, 1),
        "loss_mask": jnp.ones((B, T), jnp.float32),
        "W": jnp.ones((1, B, B), jnp.float32)
             - jnp.eye(B)[None],
        "seq_labels": jnp.zeros((1, B), jnp.int32),
        "seq_label_mask": jnp.ones((1, B), jnp.float32),
    }
    batch.update(kw)
    hyper = SSLHyper(gamma=1e-2, kappa=1e-3, weight_decay=0.0)
    new_params, _, metrics = jax.jit(
        lambda p, s, b: lm_train_step(p, s, b, cfg=cfg, hyper=hyper, opt=opt,
                                      lr=jnp.float32(1e-3)))(
        params, opt_state, batch)
    assert np.isfinite(float(metrics["loss/total"]))
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:  # capacity dispatch differs between batch sizes; disable drops
        cfg = dataclasses.replace(cfg, capacity_factor=float(2 * cfg.n_experts))
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 12
    toks, kw = _inputs(cfg, B, T, seed=2)
    out = tf.forward(params, cfg, toks, remat=False, **kw)
    _, cache = tf.prefill(params, cfg, toks[:, :-1], cache_len=T + 4, **kw)
    logits, _ = tf.decode_step(params, cfg, cache, toks[:, -1:],
                               jnp.full((B,), T - 1, jnp.int32))
    a = np.asarray(out["logits"][:, -1], np.float32)
    b = np.asarray(logits[:, 0], np.float32)
    tol = 3e-2 if cfg.dtype == "bfloat16" else 2e-3
    assert np.abs(a - b).max() / (np.abs(a).std() + 1e-9) < tol, arch


def test_prefill_logits_equal_forward_logits():
    cfg = get_config("qwen2-1.5b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(3))
    toks, _ = _inputs(cfg, 2, 16, seed=3)
    out = tf.forward(params, cfg, toks, remat=False)
    pre, _ = tf.prefill(params, cfg, toks)
    np.testing.assert_allclose(np.asarray(pre["logits"]),
                               np.asarray(out["logits"]), atol=1e-4)


def test_long_context_config_switches_to_sliding_window():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        cfg_long = config_for_shape(cfg, INPUT_SHAPES["long_500k"])
        assert ATTN not in cfg_long.block_pattern, arch
        if any(k == ATTN_SWA for k in cfg_long.block_pattern):
            assert cfg_long.sliding_window is not None


def test_param_counts_match_spec_sizes():
    expect = {
        "qwen2-1.5b": 1.5e9, "kimi-k2-1t-a32b": 1.0e12,
        "qwen1.5-0.5b": 0.5e9, "xlstm-125m": 125e6,
        "musicgen-large": 2.4e9, "yi-9b": 9e9,
        "llama-3.2-vision-90b": 90e9, "jamba-1.5-large-398b": 398e9,
        "mixtral-8x7b": 47e9, "phi4-mini-3.8b": 3.8e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).param_count()
        assert 0.6 * target < n < 1.45 * target, (arch, n, target)


def test_remat_forward_matches_no_remat():
    cfg = get_config("yi-9b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(4))
    toks, _ = _inputs(cfg, 2, 16, seed=4)
    a = tf.forward(params, cfg, toks, remat=False)["logits"]
    b = tf.forward(params, cfg, toks, remat=True)["logits"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_abstract_params_match_real_params():
    cfg = get_config("mixtral-8x7b").reduced()
    real = tf.init_params(cfg, jax.random.PRNGKey(0))
    abstract = tf.abstract_params(cfg)
    ra = jax.tree.map(lambda a: (a.shape, str(a.dtype)), real)
    ab = jax.tree.map(lambda a: (a.shape, str(a.dtype)), abstract)
    assert ra == ab
