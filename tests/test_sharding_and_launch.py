"""Sharding spec rules (pure) + a subprocess mini dry-run on 8 host devices."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.launch import hlo_analysis
from repro.sharding.specs import spec_for_cache, spec_for_param


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_MP = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def _norm(spec):
    """PartitionSpec equality ignoring trailing Nones."""
    t = tuple(spec)
    while t and t[-1] is None:
        t = t[:-1]
    return t


def test_dp_replicates_everything():
    assert _norm(spec_for_param("embed/table", (151936, 1536), MESH,
                                "dp")) == ()
    assert _norm(spec_for_param("superblocks/0/attn/wq", (28, 1536, 12, 128),
                                MESH, "dp")) == ()


def test_fsdp_shards_largest_divisible_dim():
    s = spec_for_param("embed/table", (151936, 1536), MESH, "fsdp")
    assert _norm(s) == ("data",)                # vocab % 16 == 0
    s = spec_for_param("superblocks/0/mlp/wu", (28, 1536, 8960), MESH, "fsdp")
    assert _norm(s) == (None, None, "data")     # skips the scan dim; d_ff largest
    # non-divisible everything -> replicated
    s = spec_for_param("x/odd", (7, 13), MESH, "fsdp")
    assert _norm(s) == ()


def test_fsdp_tp_assigns_model_axis_by_name():
    s = spec_for_param("superblocks/0/mlp/wu", (28, 1536, 8960), MESH,
                       "fsdp_tp")
    assert _norm(s) == (None, "data", "model")  # tp on d_ff, fsdp on d
    s = spec_for_param("superblocks/0/moe/wu", (32, 8, 4096, 14336), MESH,
                       "fsdp_tp")
    assert s[1] is None and "model" not in (s[1],)  # experts=8 not divisible
    s = spec_for_param("superblocks/0/moe/wu", (60, 384, 7168, 2048), MESH,
                       "fsdp_tp")
    assert _norm(s) == (None, "model", "data")  # expert-parallel (384 % 16)
    s = spec_for_param("lm_head", (4096, 64000), MESH, "fsdp_tp")
    assert _norm(s) == ("data", "model")
    s = spec_for_param("superblocks/0/attn/wq", (48, 4096, 32, 128), MESH,
                       "fsdp_tp")
    assert _norm(s) == (None, "data", "model")  # heads on model


def test_multipod_fsdp_uses_pod_and_data():
    # vocab gets tensor parallel, d_model gets ZeRO over (pod, data)
    s = spec_for_param("embed/table", (151936, 1536), MESH_MP, "fsdp_tp")
    assert _norm(s) == ("model", ("pod", "data"))
    s = spec_for_param("superblocks/0/mlp/wu", (28, 1536, 8960), MESH_MP,
                       "fsdp_tp")
    assert _norm(s) == (None, ("pod", "data"), "model")


def test_cache_specs_batch_vs_sequence_sharding():
    # decode_32k: batch 128 divisible -> batch on data
    s = spec_for_cache("layers/0/k", (32, 128, 32768, 8, 128), MESH, 128,
                       "fsdp_tp")
    assert s[1] == "data"
    # long_500k: batch 1 -> shard the sequence dim instead
    s = spec_for_cache("layers/0/k", (32, 1, 524288, 8, 128), MESH, 1,
                       "fsdp_tp")
    assert s[1] is None and s[2] == "data"


def test_hlo_analysis_trip_counting():
    """Tiny scan of matmuls: analyzer must multiply by known trip count."""
    import jax.numpy as jnp

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jnp.ones((64, 64)); w = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    costs = hlo_analysis.analyze_hlo(compiled.as_text())
    want = 7 * 2 * 64 ** 3
    assert costs.flops == pytest.approx(want, rel=0.05), costs.flops


def test_hlo_analysis_collectives_parse():
    txt = """HloModule m, num_partitions=4
ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %p = f32[8,16]{1,0} parameter(0)
  ROOT %ar = f32[8,16]{1,0} all-reduce(%p), to_apply=%add
}
%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}
"""
    costs = hlo_analysis.analyze_hlo(txt)
    assert costs.count_by_op["all-reduce"] == 1
    assert costs.bytes_by_op["all-reduce"] == 8 * 16 * 4


@pytest.mark.slow
def test_mini_dryrun_subprocess():
    """End-to-end: lower+compile one arch/shape on an 8-device host mesh."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.launch.inputs import input_specs
mesh = jax.make_mesh((4, 2), ("data", "model"))
spec = input_specs("qwen1.5-0.5b", "decode_32k", mesh, "fsdp_tp")
with mesh:
    compiled = jax.jit(spec["fn"], donate_argnums=spec["donate"]).lower(*spec["args"]).compile()
ca = compiled.cost_analysis()
ca = ca[0] if isinstance(ca, (list, tuple)) else ca  # list-of-dicts pre-0.5
print(json.dumps({"ok": True, "flops": ca.get("flops", 0)}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert json.loads(out.stdout.strip().splitlines()[-1])["ok"]
