"""Tests for the static audit toolkit (repro.analysis).

Each pass is exercised against a corpus of known-bad snippets it must
flag and known-good twins it must not — the analyzers are themselves
code under test, not just the code they audit.
"""
from __future__ import annotations

import json
import textwrap

import jax
import jax.numpy as jnp

from repro.analysis import (
    AuditReport,
    EntryPoint,
    Finding,
    VMEM_BUDGET_BYTES,
    audit_entry,
    audit_file,
    audit_paths,
    check_launch,
    check_tiles,
    count_bxb_intermediates,
    load_baseline,
    save_baseline,
    unbaselined,
    validate_tuning_table,
    vmem_footprint_bytes,
)
from repro.analysis.concurrency_audit import DEFAULT_TARGETS
from repro.analysis.vmem_audit import Block, Launch
from repro.kernels.tuning import DEFAULT_TILE_TABLE, TileSpec


def _rules(findings):
    return sorted(f.rule for f in findings)


# =========================================================== jaxpr auditor
class TestJaxprAudit:
    B = 64

    def _logp_W(self):
        logp = jax.nn.log_softmax(jnp.zeros((self.B, 39), jnp.float32), -1)
        return logp, jnp.ones((self.B, self.B), jnp.float32)

    def test_bad_dense_bxb_flagged(self):
        logp, W = self._logp_W()

        def f(logp, W):
            p = jnp.exp(logp)
            return -jnp.sum(W * (p @ logp.T))     # dense B×B product

        entry = EntryPoint("bad", lambda: (f, (logp, W)),
                           B=self.B, expect_bxb=0)
        findings, metrics = audit_entry(entry)
        assert "J002" in _rules(findings)
        assert metrics["bxb_outside_kernels"] >= 1

    def test_good_fused_twin_clean(self):
        from repro.kernels.ops import graph_regularizer_fused

        logp, W = self._logp_W()

        def f(logp, W):
            return graph_regularizer_fused(logp, W, 0.5, 1e-3)

        entry = EntryPoint("good", lambda: (jax.grad(f), (logp, W)),
                           B=self.B, expect_bxb=0)
        findings, metrics = audit_entry(entry)
        assert findings == []
        assert metrics["bxb_outside_kernels"] == 0

    def test_canary_guards_the_counter(self):
        logp, W = self._logp_W()
        entry = EntryPoint("canary", lambda: ((lambda lp, w: lp.sum()),
                                              (logp, W)),
                           B=self.B, expect_bxb=None, canary_min_bxb=3)
        findings, _ = audit_entry(entry)
        assert _rules(findings) == ["J000"]

    def test_bf16_promotion_flagged_and_twin_clean(self):
        x = jnp.zeros((64, 64), jnp.bfloat16)

        def bad(x):
            return x.astype(jnp.float32) @ x.astype(jnp.float32).T

        bad_f, _ = audit_entry(EntryPoint(
            "promo", lambda: (bad, (x,)), compute_dtype="bfloat16"))
        assert "J003" in _rules(bad_f)
        good_f, _ = audit_entry(EntryPoint(
            "promo_ok", lambda: ((lambda x: x * jnp.bfloat16(2)), (x,)),
            compute_dtype="bfloat16"))
        assert good_f == []

    def test_f64_leak_flagged(self):
        from jax.experimental import enable_x64

        x = jnp.zeros((8, 8), jnp.float32)
        with enable_x64():
            findings, _ = audit_entry(EntryPoint(
                "leak", lambda: ((lambda x: x.astype(jnp.float64) * 2.0),
                                 (x,))))
        assert "J003" in _rules(findings)

    def test_callback_inside_scan_flagged(self):
        def bad(x):
            def body(c, _):
                jax.debug.print("step {}", c)
                return c + 1, None
            return jax.lax.scan(body, x, None, length=4)[0]

        def good(x):
            jax.debug.print("before the loop {}", x)   # outside the scan
            def body(c, _):
                return c + 1, None
            return jax.lax.scan(body, x, None, length=4)[0]

        x = jnp.float32(0)
        bad_f, _ = audit_entry(EntryPoint("cb", lambda: (bad, (x,))))
        assert "J004" in _rules(bad_f)
        good_f, _ = audit_entry(EntryPoint("cb_ok", lambda: (good, (x,))))
        assert good_f == []

    def test_captured_constant_flagged(self):
        big = jnp.ones((512, 512), jnp.float32)       # 1 MiB closure const

        findings, metrics = audit_entry(EntryPoint(
            "const", lambda: ((lambda x: x @ big),
                              (jnp.zeros((4, 512)),))))
        assert "J006" in _rules(findings)
        assert metrics["captured_const_bytes"] >= big.nbytes

    def test_donation_check(self):
        def inner(c, b):
            return c + b

        jitted = jax.jit(inner)                       # no donate_argnums
        donated = jax.jit(inner, donate_argnums=0)
        args = (jnp.zeros((4,)), jnp.ones((4,)))

        bad_f, bad_m = audit_entry(EntryPoint(
            "nodonate", lambda: ((lambda c, b: jitted(c, b)), args),
            donate=("inner", None)))
        assert "J005" in _rules(bad_f) and bad_m["carry_donated"] is False

        good_f, good_m = audit_entry(EntryPoint(
            "donate", lambda: ((lambda c, b: donated(c, b)), args),
            donate=("inner", None)))
        assert good_f == [] and good_m["carry_donated"] is True

    def test_registered_entry_points_hold_their_contracts(self):
        from repro.api.registry import AUDIT

        by_name = {}
        for name in AUDIT:
            findings, metrics = audit_entry(AUDIT.get(name))
            assert findings == [], (name, [f.format() for f in findings])
            by_name[name] = metrics
        # The acceptance numbers: fused Eq.-3/4 fwd+bwd at 0 dense B×B,
        # the jnp reference at >= 3, every engine carry donated.
        assert by_name["graph_reg_fused"]["bxb_outside_kernels"] == 0
        assert by_name["graph_reg_ref"]["bxb_outside_kernels"] >= 3
        assert by_name["ssl_objective"]["bxb_outside_kernels"] == 0
        assert by_name["knn_topk"]["bxb_outside_kernels"] == 0
        for strat in ("sequential", "sync_mesh", "async_ps"):
            assert by_name[f"engine_{strat}"]["carry_donated"] is True

    def test_counter_reexported_from_benchmarks(self):
        from benchmarks.bench_kernels import (
            count_bxb_intermediates as bench_counter)

        assert bench_counter is count_bxb_intermediates


# ===================================================== VMEM/tiling checker
class TestVmemAudit:
    def test_default_tuning_table_validates_clean(self):
        findings, metrics = validate_tuning_table()
        assert findings == [], [f.format() for f in findings]
        assert metrics["rows_checked"] == len(DEFAULT_TILE_TABLE)
        for kernel, worst in metrics["worst_footprint_bytes"].items():
            assert worst <= VMEM_BUDGET_BYTES, kernel

    def test_oversubscribed_tiles_flagged_and_twin_clean(self):
        bad = check_tiles("graph_reg", TileSpec(bi=1024, bj=1024, bc=2048),
                          where="corpus")
        assert "V001" in _rules(bad)
        assert vmem_footprint_bytes(
            "graph_reg", TileSpec(bi=1024, bj=1024, bc=2048)) \
            > VMEM_BUDGET_BYTES
        good = check_tiles("graph_reg", TileSpec(bi=128, bj=128, bc=512),
                           where="corpus")
        assert good == []

    def test_unaligned_tiles_flagged_on_tpu_rows_only(self):
        bad = check_tiles("rbf", TileSpec(bi=100, bj=130, bd=256),
                          where="corpus")
        assert set(_rules(bad)) == {"V002"}
        # An explicitly non-TPU row skips the lane/sublane rule.
        cpu = check_tiles("rbf", TileSpec(bi=100, bj=130, bd=256),
                          where="corpus", backend="cpu")
        assert "V002" not in _rules(cpu)

    def test_shadowed_row_and_missing_model(self):
        table = [
            ("graph_reg", None, None, TileSpec(bi=128, bj=128, bc=512)),
            ("graph_reg", "tpu", 512, TileSpec(bi=128, bj=128, bc=256)),
            ("mystery", None, None, TileSpec()),
        ]
        findings, _ = validate_tuning_table(table)
        assert _rules(findings) == ["V004", "V005"]

    def test_out_of_bounds_index_map_flagged(self):
        launch = Launch("demo", "fwd", (4, 2), (
            Block("x", (128, 128), "in",
                  index_map=lambda i, j: (i + 1, j),
                  array_shape=(512, 256)),
        ))
        findings = check_launch(launch, where="corpus")
        assert "V003" in _rules(findings)
        ok = Launch("demo", "fwd", (4, 2), (
            Block("x", (128, 128), "in", index_map=lambda i, j: (i, j),
                  array_shape=(512, 256)),
        ))
        assert check_launch(ok, where="corpus") == []

    def test_footprint_double_buffers_io_but_not_scratch(self):
        launch = Launch("demo", "fwd", (1,), (
            Block("in", (128, 128), "in"),
            Block("out", (128, 128), "out"),
            Block("scratch", (128, 128), "scratch"),
        ))
        tile = 128 * 128 * 4
        assert launch.footprint_bytes() == 2 * tile + 2 * tile + tile


# ======================================================== concurrency lint
def _lint(tmp_path, source):
    path = tmp_path / "snippet.py"
    path.write_text(textwrap.dedent(source))
    findings, _ = audit_file(str(path), where="snippet")
    return findings


class TestConcurrencyAudit:
    def test_unlocked_guarded_attribute_flagged(self, tmp_path):
        findings = _lint(tmp_path, """
            import threading
            class S:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0
                def bump(self):
                    with self._lock:
                        self.count += 1
                def peek(self):
                    return self.count
        """)
        assert _rules(findings) == ["C001"]
        assert findings[0].detail == "count@peek"

    def test_locked_twin_clean(self, tmp_path):
        findings = _lint(tmp_path, """
            import threading
            class S:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0
                def bump(self):
                    with self._lock:
                        self.count += 1
                def peek(self):
                    with self._lock:
                        return self.count
        """)
        assert findings == []

    def test_nested_fn_under_lock_is_not_locked(self, tmp_path):
        # A thread target *defined* inside a with-lock runs later, without
        # the lock — its accesses must still be flagged.
        findings = _lint(tmp_path, """
            import threading
            class S:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.state = 0
                def read(self):
                    with self._lock:
                        return self.state
                def sneaky(self):
                    with self._lock:
                        def later():
                            self.state += 1
                        return later
        """)
        assert _rules(findings) == ["C001"]
        assert findings[0].detail == "state@sneaky"

    def test_unjoined_nondaemon_thread_flagged(self, tmp_path):
        bad = _lint(tmp_path, """
            import threading
            def go():
                t = threading.Thread(target=print)
                t.start()
        """)
        assert _rules(bad) == ["C002"]
        good = _lint(tmp_path, """
            import threading
            def go():
                t = threading.Thread(target=print)
                t.start()
                t.join()
        """)
        assert good == []

    def test_publication_without_happens_before_flagged(self, tmp_path):
        bad = _lint(tmp_path, """
            import threading
            def go():
                box = {}
                def work():
                    box["x"] = 1
                t = threading.Thread(target=work, daemon=True)
                t.start()
                return box["x"]
        """)
        assert _rules(bad) == ["C003"]
        good = _lint(tmp_path, """
            import threading
            def go():
                box = {}
                def work():
                    box["x"] = 1
                t = threading.Thread(target=work, daemon=True)
                t.start()
                t.join()
                return box["x"]
        """)
        assert good == []

    def test_suppression_marker_waives_named_rule_only(self, tmp_path):
        src = """
            import threading
            class S:
                def __init__(self):
                    self._lock = threading.Lock()
                    self.count = 0
                def bump(self):
                    with self._lock:
                        self.count += 1
                def peek(self):
                    return self.count  # audit: safe({rule}): stats only
        """
        waived = _lint(tmp_path, src.format(rule="C001"))
        assert waived == []
        wrong_rule = _lint(tmp_path, src.format(rule="C002"))
        assert _rules(wrong_rule) == ["C001"]

    def test_repo_threaded_modules_are_clean(self):
        findings, metrics = audit_paths(DEFAULT_TARGETS, root=".")
        assert findings == [], [f.format() for f in findings]
        stream = metrics["files"]["src/repro/data/pipeline.py"]
        guarded = stream["classes"]["MetaBatchStream"]["guarded"]
        # The PR-5 handoff state is now lock-published.
        assert {"plan", "_pending", "_plan_epoch", "_failed"} <= set(guarded)


# ================================================ findings / baseline gate
class TestBaselineGate:
    def test_fingerprint_is_stable_across_lines(self):
        a = Finding("vmem", "V001", "tuning[0]:rbf", "msg", line=10)
        b = Finding("vmem", "V001", "tuning[0]:rbf", "other msg", line=99)
        assert a.fingerprint == b.fingerprint

    def test_baseline_roundtrip_and_gate(self, tmp_path):
        path = str(tmp_path / "baseline.json")
        known = Finding("jaxpr", "J002", "x", "known")
        fresh = Finding("jaxpr", "J002", "y", "fresh")
        save_baseline(path, [known])
        baseline = load_baseline(path)
        assert unbaselined([known, fresh], baseline) == [fresh]
        assert load_baseline(str(tmp_path / "missing.json")) == set()

    def test_info_findings_do_not_gate(self):
        report = AuditReport()
        report.extend("vmem", [Finding("vmem", "V001", "x", "m",
                                       severity="info")])
        assert report.gating == []

    def test_report_serializes_new_findings(self, tmp_path):
        report = AuditReport()
        f = Finding("vmem", "V001", "x", "m")
        report.extend("vmem", [f], {"rows_checked": 1})
        path = str(tmp_path / "report.json")
        report.write(path, baseline=set())
        data = json.loads(open(path).read())
        assert data["new_findings"] == [f.fingerprint]
        assert data["metrics"]["vmem/rows_checked"] == 1


# ------------------------------------------------------------------- CLI
def test_cli_clean_run_exits_zero(tmp_path, capsys):
    from repro.analysis.cli import main

    report = str(tmp_path / "report.json")
    baseline = str(tmp_path / "baseline.json")
    assert main(["--passes", "vmem,concurrency", "--report", report,
                 "--baseline", baseline]) == 0
    data = json.loads(open(report).read())
    assert data["passes"]["vmem"]["findings"] == 0

def test_cli_gates_on_unbaselined_findings(tmp_path, monkeypatch):
    from repro.analysis import cli

    bad_finding = Finding("vmem", "V001", "corpus", "too big")

    def fake_vmem(report):
        report.extend("vmem", [bad_finding], {"rows_checked": 1})

    monkeypatch.setattr(cli, "_run_vmem", fake_vmem)
    report = str(tmp_path / "report.json")
    baseline = str(tmp_path / "baseline.json")
    args = ["--passes", "vmem", "--report", report, "--baseline", baseline]
    assert cli.main(args) == 1                      # new finding -> fail
    assert cli.main(args + ["--update-baseline"]) == 0
    assert cli.main(args) == 0                      # accepted -> pass
