"""Chaos suite for the resilience layer (repro.resilience + engine wiring).

Covers the five injection sites and every defense:

  * seeded :class:`FaultPlan` determinism and the consume-on-fire injector
    ledger;
  * the in-scan non-finite guard — exact skipped-step accounting, the
    halt-after-K-consecutive policy, and the bit-reproducibility of a
    guarded run;
  * checkpoint integrity — atomic writes survive a mid-write crash,
    checksum sidecars catch truncation and bit rot, resume falls back past
    a corrupt LATEST target bit-identically, ``keep_last`` retention;
  * the thread supervisor — deterministic backoff schedule, recovery /
    exhaustion ledger, the hang watchdog;
  * ``MetaBatchStream`` replan failures — supervised retries, deduped
    warnings, disable-after-K so a broken partitioner cannot spin a
    warning + thread per epoch;
  * async_ps over-stale worker dropping (completes + deterministic);
  * the full three-phase chaos driver (all sites, corrupt-LATEST resume).
"""
import dataclasses
import os
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.config import RepartitionConfig, ResilienceConfig
from repro.core import SSLHyper, build_affinity_graph, plan_meta_batches
from repro.data import MetaBatchPipeline, drop_labels, make_corpus
from repro.data.pipeline import make_metabatch_stream_pipeline
from repro.models.dnn import DNNConfig
from repro.resilience import (FaultEvent, FaultInjector, FaultPlan,
                              InjectedFault, NonFiniteHaltError, RetryPolicy,
                              Supervisor, SupervisorTimeout, all_finite)
from repro.train import train_dnn_ssl
from repro.train.checkpoint import (CheckpointCorruptError,
                                    _atomic_write_bytes, atomic_write_text,
                                    load_checkpoint, save_checkpoint)

CFG = DNNConfig(input_dim=24, hidden_dim=32, n_hidden=2, n_classes=6,
                dropout=0.0)
HYPER = SSLHyper(0.3, 1e-4, 1e-5)


@pytest.fixture(scope="module")
def small_setup():
    corpus = make_corpus(300, n_classes=6, input_dim=24, manifold_dim=4,
                         seed=0)
    labeled = drop_labels(corpus, 0.2, seed=1)
    graph = build_affinity_graph(corpus.X, k=8)
    plan = plan_meta_batches(graph, batch_size=64, n_classes=6, seed=0)
    return labeled, graph, plan


def pipeline_of(setup, n_workers: int = 1):
    labeled, graph, plan = setup
    return MetaBatchPipeline(labeled, graph, plan, n_workers=n_workers,
                             seed=0).epoch


def params_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(jax.device_get(a)),
                               jax.tree.leaves(jax.device_get(b))))


# ------------------------------------------------------------ fault plans
def test_fault_plan_is_a_pure_function_of_seed():
    kw = dict(n_epochs=4, steps_per_epoch=10)
    a = FaultPlan.from_seed(3, **kw)
    b = FaultPlan.from_seed(3, **kw)
    assert a == b
    assert FaultPlan.from_seed(4, **kw) != a
    assert {e.site for e in a.events} == {"batch", "prefetch", "replan",
                                          "checkpoint", "worker"}
    # Checkpoints are labelled by completed-epoch count — never epoch 0.
    assert all(e.epoch >= 1 for e in a.for_site("checkpoint"))


def test_injector_rejects_colliding_plan_and_fires_once():
    ev = FaultEvent("replan", epoch=1, mode="fail")
    with pytest.raises(ValueError, match="colliding"):
        FaultInjector(FaultPlan(events=(ev, ev)))
    inj = FaultInjector(FaultPlan(events=(ev,)))
    with pytest.raises(InjectedFault):
        inj.maybe_fail("replan", epoch=1)
    inj.maybe_fail("replan", epoch=1)          # consumed — no re-fire
    assert [f["site"] for f in inj.fired()] == ["replan"]
    assert inj.pending() == []


@pytest.mark.parametrize("mode,bad", [("nan", np.isnan), ("inf", np.isinf)])
def test_on_batch_poisons_the_planned_step_only(mode, bad):
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent("batch", epoch=0, step=1, mode=mode),)))
    batch = {"x": np.ones((4, 3), np.float32), "valid": np.ones(4, bool)}
    clean = inj.on_batch(batch, epoch=0, step=0)
    assert np.array_equal(clean["x"], batch["x"])
    poisoned = inj.on_batch(batch, epoch=0, step=1)
    assert bad(poisoned["x"]).all()
    assert np.isfinite(batch["x"]).all()       # original untouched


def test_wrap_put_crashes_once_then_keeps_chunk_coordinates():
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent("prefetch", epoch=0, step=1, mode="crash"),
        FaultEvent("prefetch", epoch=0, step=2, mode="crash"),)))
    seen = []
    put = inj.wrap_put(seen.append, epoch=0)
    put("c0")
    with pytest.raises(InjectedFault):
        put("c1")                              # index did NOT advance
    put("c1")                                  # retry at the same chunk
    with pytest.raises(InjectedFault):
        put("c2")                              # later event kept its slot
    put("c2")
    assert seen == ["c0", "c1", "c2"]


# ------------------------------------------------------- non-finite guard
def test_all_finite_skips_integer_leaves():
    assert bool(all_finite({"i": jnp.arange(3), "x": jnp.ones(2)}))
    assert not bool(all_finite({"i": jnp.arange(3),
                                "x": jnp.array([1.0, np.nan])}))
    assert bool(all_finite({"i": jnp.arange(3)}))      # nothing inexact


def guarded_run(setup, injector, *, n_epochs=2, resilience=None, **kw):
    res = ResilienceConfig(nonfinite_guard=True) \
        if resilience is None else resilience
    return train_dnn_ssl(
        pipeline_of(setup), cfg=CFG, hyper=HYPER, n_epochs=n_epochs,
        dropout=0.0, base_lr=5e-3, seed=0, pairwise="ref", scan_chunk=2,
        resilience=res, injector=injector, **kw)


def test_guard_skips_exactly_the_poisoned_steps(small_setup):
    events = (FaultEvent("batch", epoch=0, step=1, mode="nan"),
              FaultEvent("batch", epoch=1, step=0, mode="inf"))
    res = guarded_run(small_setup, FaultInjector(FaultPlan(events)))
    # skipped_total is cumulative (threaded through the scan carry).
    assert [int(h["guard/skipped_total"]) for h in res.history] == [1, 2]
    assert all(np.isfinite(leaf).all()
               for leaf in jax.tree.leaves(jax.device_get(res.params)))
    # Guarded recovery is bit-reproducible: same plan, same params.
    again = guarded_run(small_setup, FaultInjector(FaultPlan(events)))
    assert params_equal(res.params, again.params)


def test_without_guard_a_poisoned_batch_corrupts_params(small_setup):
    events = (FaultEvent("batch", epoch=0, step=1, mode="nan"),)
    res = guarded_run(small_setup, FaultInjector(FaultPlan(events)),
                      n_epochs=1, resilience=ResilienceConfig())
    assert not all(np.isfinite(leaf).all()
                   for leaf in jax.tree.leaves(jax.device_get(res.params)))


def test_halt_after_consecutive_nonfinite_steps(small_setup):
    events = tuple(FaultEvent("batch", epoch=0, step=s, mode="nan")
                   for s in (0, 1, 2))
    res = ResilienceConfig(nonfinite_guard=True, halt_after_consecutive=3)
    with pytest.raises(NonFiniteHaltError, match="3 consecutive"):
        train_dnn_ssl(
            pipeline_of(small_setup), cfg=CFG, hyper=HYPER, n_epochs=1,
            dropout=0.0, base_lr=5e-3, seed=0, pairwise="ref", scan_chunk=1,
            resilience=res, injector=FaultInjector(FaultPlan(events)))


# ------------------------------------------------- checkpoint integrity
def test_atomic_write_survives_a_mid_write_crash(tmp_path):
    path = str(tmp_path / "LATEST")
    atomic_write_text(path, "ckpt_00001")

    def torn(f):
        f.write(b"ckpt_000")           # partial bytes, then the crash
        raise OSError("disk pulled")

    with pytest.raises(OSError, match="disk pulled"):
        _atomic_write_bytes(path, torn)
    with open(path) as f:
        assert f.read() == "ckpt_00001"        # old bytes fully intact
    assert not os.path.exists(path + ".tmp")   # no debris left behind


@pytest.mark.parametrize("corrupt", ["truncate", "bitflip"])
def test_checksum_catches_corruption(tmp_path, corrupt):
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "step": np.int32(7)}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree)
    assert os.path.exists(path + ".npz.sha256")
    loaded = load_checkpoint(path, tree)
    assert np.array_equal(loaded["w"], tree["w"])

    size = os.path.getsize(path + ".npz")
    if corrupt == "truncate":
        os.truncate(path + ".npz", size // 2)
    else:
        with open(path + ".npz", "r+b") as f:
            f.seek(size // 2)
            byte = f.read(1)
            f.seek(size // 2)
            f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.raises(CheckpointCorruptError, match="sha256 mismatch"):
        load_checkpoint(path, tree)


def test_unreadable_archive_is_wrapped_even_without_sidecar(tmp_path):
    tree = {"w": np.ones(3, np.float32)}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, tree, checksum=False)
    assert not os.path.exists(path + ".npz.sha256")
    os.truncate(path + ".npz", 4)
    with pytest.raises(CheckpointCorruptError, match="unreadable"):
        load_checkpoint(path, tree)


def test_keep_last_prunes_old_checkpoints(small_setup, tmp_path):
    train_dnn_ssl(
        pipeline_of(small_setup), cfg=CFG, hyper=HYPER, n_epochs=3,
        dropout=0.0, base_lr=5e-3, seed=0, pairwise="ref",
        checkpoint_every=1, checkpoint_dir=str(tmp_path),
        resilience=ResilienceConfig(keep_last=2))
    names = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert names == ["ckpt_00002.npz", "ckpt_00003.npz"]
    assert sorted(f for f in os.listdir(tmp_path)
                  if f.endswith(".sha256")) == ["ckpt_00002.npz.sha256",
                                                "ckpt_00003.npz.sha256"]
    with open(tmp_path / "LATEST") as f:
        assert f.read() == "ckpt_00003"


def test_resume_falls_back_past_corrupt_latest_bit_identically(
        small_setup, tmp_path):
    kw = dict(cfg=CFG, hyper=HYPER, dropout=0.2, base_lr=5e-3, seed=0,
              pairwise="ref")
    uninterrupted = train_dnn_ssl(pipeline_of(small_setup), n_epochs=4, **kw)
    train_dnn_ssl(pipeline_of(small_setup), n_epochs=2, checkpoint_every=1,
                  checkpoint_dir=str(tmp_path), **kw)
    # Rot the checkpoint LATEST points at; its sidecar keeps the good hash.
    target = tmp_path / "ckpt_00002.npz"
    with open(target, "r+b") as f:
        f.seek(os.path.getsize(target) // 2)
        byte = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([byte[0] ^ 0xFF]))
    with pytest.warns(UserWarning, match="falling back to the next newest"):
        resumed = train_dnn_ssl(
            pipeline_of(small_setup), n_epochs=4, checkpoint_every=1,
            checkpoint_dir=str(tmp_path), resume=True, **kw)
    # Fell back to ckpt_00001 and replayed epochs 1..3 — bit-identical.
    assert params_equal(resumed.params, uninterrupted.params)


# ------------------------------------------------------------- supervisor
def test_supervisor_retries_with_a_deterministic_schedule():
    sleeps_a, sleeps_b = [], []
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] < 3:
            raise RuntimeError("transient")
        return "ok"

    policy = RetryPolicy(max_retries=3, backoff_base=0.1, backoff_max=1.0,
                         seed=11)
    sup = Supervisor(policy, name="t", sleep=sleeps_a.append)
    assert sup.call(flaky, key="job") == "ok"
    assert [e["status"] for e in sup.events()] == ["retrying", "retrying",
                                                   "recovered"]
    # The backoff schedule is a pure function of (seed, key, attempt).
    assert sleeps_a == [policy.delay("job", 0), policy.delay("job", 1)]
    attempts["n"] = 0
    Supervisor(policy, name="t", sleep=sleeps_b.append).call(flaky, key="job")
    assert sleeps_b == sleeps_a
    assert RetryPolicy(seed=12).delay("job", 0) != policy.delay("job", 0)


def test_supervisor_reraises_after_exhaustion():
    sup = Supervisor(RetryPolicy(max_retries=2, backoff_base=0.0,
                                 backoff_max=0.0), sleep=lambda _: None)

    def broken():
        raise ValueError("permanently broken")

    with pytest.raises(ValueError, match="permanently broken"):
        sup.call(broken, key="job")
    statuses = [e["status"] for e in sup.events()]
    assert statuses == ["retrying", "retrying", "exhausted"]


def test_supervisor_watchdog_abandons_hung_attempts():
    sup = Supervisor(RetryPolicy(max_retries=1, backoff_base=0.0,
                                 backoff_max=0.0, hang_timeout=0.05),
                     sleep=lambda _: None)
    with pytest.raises(SupervisorTimeout):
        sup.call(time.sleep, 5.0, key="hang")
    assert [e["status"] for e in sup.events()] == ["retrying", "exhausted"]


def test_supervisor_nonretryable_exceptions_propagate_immediately():
    sup = Supervisor(RetryPolicy(max_retries=3), sleep=lambda _: None)

    def wrong():
        raise TypeError("not transient")

    with pytest.raises(TypeError):
        sup.call(wrong, key="job", retryable=(ValueError,))
    assert sup.events() == []          # never entered the retry path


# ----------------------------------------- stream replan dedupe/disable
@pytest.fixture(scope="module")
def stream_setup():
    corpus = make_corpus(600, n_classes=6, input_dim=24, manifold_dim=4,
                         seed=0)
    graph = build_affinity_graph(corpus.X, k=8)
    plan = plan_meta_batches(graph, batch_size=96, n_classes=6, seed=0)
    return corpus, graph, plan


def failing_stream(setup, **kw):
    corpus, graph, plan = setup
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=5)
    pipeline = make_metabatch_stream_pipeline(
        corpus, graph, plan, seed=0, with_neighbor=False,
        repartition=rep, **kw)
    return pipeline


def test_replan_disable_after_consecutive_failures(stream_setup):
    pipeline = failing_stream(stream_setup, max_replan_failures=2)
    stream = pipeline.stream

    def broken(epoch):
        raise RuntimeError("partitioner down")

    stream._synthesize = broken
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        for epoch in range(5):
            for _ in pipeline(epoch=epoch):
                pass
    texts = [str(w.message) for w in caught]
    fails = [t for t in texts if "partitioner down" in t]
    # One warning per failed target until the trip — then silence, not a
    # warning + replan thread per epoch forever.
    assert len(fails) == 2
    assert "consecutive failure 2" in fails[-1]
    assert sum("disabling background replans" in t for t in texts) == 1
    assert stream._replan_disabled
    assert stream.swaps == 0


def test_supervised_replan_recovers_transient_failure_silently(stream_setup):
    sup = Supervisor(RetryPolicy(max_retries=2, backoff_base=0.0,
                                 backoff_max=0.0), sleep=lambda _: None)
    pipeline = failing_stream(stream_setup, supervisor=sup)
    stream = pipeline.stream
    real, boom = stream._synthesize, {"left": 1}

    def flaky(epoch):
        if boom["left"] > 0:
            boom["left"] -= 1
            raise RuntimeError("transient blip")
        return real(epoch)

    stream._synthesize = flaky
    with warnings.catch_warnings():
        warnings.simplefilter("error")         # any warning fails the test
        for epoch in range(2):
            for _ in pipeline(epoch=epoch):
                pass
    assert stream.swaps == 1                   # epoch-1 replan landed
    assert any(e["status"] == "recovered" for e in sup.events())


# --------------------------------------------------- async_ps drop path
def test_async_ps_drops_overstale_worker_and_stays_deterministic(
        small_setup):
    events = (FaultEvent("worker", epoch=0, step=1, mode="dead", worker=1),)
    kw = dict(cfg=CFG, hyper=HYPER, n_epochs=2, dropout=0.0, base_lr=5e-3,
              seed=0, pairwise="ref", strategy="async_ps", n_workers=3,
              scan_chunk=2, max_staleness=2,
              resilience=ResilienceConfig(drop_overstale=True))
    res = train_dnn_ssl(pipeline_of(small_setup),
                        injector=FaultInjector(FaultPlan(events)), **kw)
    assert len(res.history) == 2
    assert sum(h.get("async/dropped", 0.0) for h in res.history) > 0
    again = train_dnn_ssl(pipeline_of(small_setup),
                          injector=FaultInjector(FaultPlan(events)), **kw)
    assert params_equal(res.params, again.params)


def test_before_chunk_is_inert_for_strategies_without_bump_age():
    inj = FaultInjector(FaultPlan(events=(
        FaultEvent("worker", epoch=0, step=0, mode="dead"),)))
    carry = object()
    assert inj.before_chunk(object(), carry, epoch=0, chunk=0) is carry
    assert len(inj.pending()) == 1             # stays armed, shows pending


# ------------------------------------------------- full chaos (3 phases)
def test_chaos_run_recovers_every_site_bit_identically(tmp_path):
    """The CI chaos-smoke contract: all five sites fire, every phase
    completes, guard skip counts match the plan exactly, and resuming
    past the corrupted LATEST is bit-identical to the uninterrupted run."""
    from repro.resilience.chaos import run_chaos

    report = run_chaos(seed=7, workdir=str(tmp_path))
    assert report["all_sites_fired"]
    assert report["skip_counts_match"]
    assert report["resume_bit_identical"]
    assert report["ok"]
    sites_fired = {f["site"]
                   for f in report["phases"]["uninterrupted"]["fired"]}
    assert sites_fired == {"batch", "prefetch", "replan", "checkpoint",
                           "worker"}


def test_chaos_plan_unique_keys_across_seeds():
    """The collision-shift in chaos_plan yields a valid plan (unique
    (site, epoch, step) keys) for any seed, not just the CI default."""
    from repro.resilience.chaos import chaos_plan

    for seed in range(20):
        plan = chaos_plan(seed, steps_per_epoch=7, chunks_per_epoch=4)
        keys = [e.key() for e in plan.events]
        assert len(keys) == len(set(keys)), seed
        FaultInjector(plan)                    # arms without raising
