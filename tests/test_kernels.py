"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.graph_reg import graph_reg_pairwise_pallas
from repro.kernels.ops import graph_reg_pairwise
from repro.kernels.pairwise import rbf_affinity_pallas


@pytest.mark.parametrize("B,C", [(16, 32), (64, 100), (128, 512),
                                 (130, 700), (33, 1000), (256, 256)])
def test_graph_reg_kernel_matches_oracle(rng, B, C):
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = np.abs(rng.normal(size=(B, B))) * (rng.random((B, B)) < 0.2)
    W = jnp.asarray(W, jnp.float32)
    got = graph_reg_pairwise_pallas(logp, W, interpret=True)
    want = ref.graph_reg_pairwise_ref(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=3e-5)


@pytest.mark.parametrize("bi,bj,bc", [(32, 32, 64), (128, 64, 128),
                                      (16, 128, 32)])
def test_graph_reg_kernel_block_shape_invariance(rng, bi, bj, bc):
    B, C = 96, 200
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), jnp.float32)
    got = graph_reg_pairwise_pallas(logp, W, bi=bi, bj=bj, bc=bc,
                                    interpret=True)
    want = ref.graph_reg_pairwise_ref(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=3e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_graph_reg_dtypes(rng, dtype):
    B, C = 64, 128
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32)).astype(dtype)
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), dtype)
    got = graph_reg_pairwise_pallas(logp, W, interpret=True)
    want = ref.graph_reg_pairwise_ref(logp.astype(jnp.float32),
                                      W.astype(jnp.float32))
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(float(got), float(want), rtol=tol)


def test_graph_reg_custom_vjp_matches_autodiff(rng):
    B, C = 48, 90
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), jnp.float32)
    g1 = jax.grad(lambda lp: graph_reg_pairwise(lp, W, use_pallas=True))(logp)
    g2 = jax.grad(lambda lp: ref.graph_reg_pairwise_ref(lp, W))(logp)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-6)
    gw1 = jax.grad(lambda w: graph_reg_pairwise(logp, w, use_pallas=True))(W)
    gw2 = jax.grad(lambda w: ref.graph_reg_pairwise_ref(logp, w))(W)
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw2), rtol=1e-4,
                               atol=1e-6)


@pytest.mark.parametrize("N,M,D", [(32, 32, 16), (64, 64, 351), (130, 70, 64),
                                   (33, 257, 100), (128, 128, 256)])
def test_rbf_affinity_kernel_matches_oracle(rng, N, M, D):
    x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(M, D)), jnp.float32)
    got = rbf_affinity_pallas(x, y, 2.0, interpret=True)
    want = ref.rbf_affinity_ref(x, y, 2.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_rbf_matches_host_graph_construction(rng):
    """Device kernel agrees with the host-side numpy path used for the graph."""
    from repro.core.affinity import pairwise_sq_dists
    x = rng.normal(size=(60, 30)).astype(np.float32)
    sigma = 1.7
    d = np.sqrt(pairwise_sq_dists(x, x))
    want = np.exp(-d / (2 * sigma * sigma))
    got = rbf_affinity_pallas(jnp.asarray(x), jnp.asarray(x), sigma,
                              interpret=True)
    # host path is float64, kernel is float32; sqrt near zero amplifies noise
    np.testing.assert_allclose(np.asarray(got), want, rtol=5e-3, atol=5e-4)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(2, 64), C=st.integers(2, 128), seed=st.integers(0, 20))
def test_graph_reg_property_sweep(B, C, seed):
    rng = np.random.default_rng(seed)
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), jnp.float32)
    got = graph_reg_pairwise_pallas(logp, W, bi=16, bj=16, bc=32,
                                    interpret=True)
    want = ref.graph_reg_pairwise_ref(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,T,H,KV,hd,bq,bk",
                         [(2, 64, 4, 2, 32, 16, 16),
                          (1, 100, 4, 4, 16, 32, 32),
                          (2, 48, 8, 2, 64, 16, 8)])
def test_pallas_flash_fwd_matches_reference(rng, B, T, H, KV, hd, bq, bk):
    """MXU-tiled flash forward == O(T²) oracle (interpret mode)."""
    from repro.kernels.flash_attention import flash_attention_gqa_pallas
    from repro.models.layers.attention import reference_attention
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), jnp.float32)
    got = flash_attention_gqa_pallas(q, k, v, causal=True, bq=bq, bk=bk,
                                     interpret=True)
    pos = jnp.arange(T)
    want = reference_attention(q, k, v, pos, pos, jnp.ones(T, bool),
                               causal=True, window=None)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
