"""Eq. 2 / Eq. 3 objective: decomposition identity, masking, gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.ssl_loss import (SSLHyper, entropy, graph_regularizer,
                                 pairwise_cross_entropy_term, ssl_objective,
                                 ssl_objective_kl_form)


def _rand_batch(rng, B=24, C=7, label_frac=0.4):
    logits = jnp.asarray(rng.normal(size=(B, C)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, C, B))
    mask = jnp.asarray((rng.random(B) < label_frac).astype(np.float32))
    W = np.abs(rng.normal(size=(B, B))) * (rng.random((B, B)) < 0.25)
    W = (W + W.T) / 2
    np.fill_diagonal(W, 0.0)
    return logits, labels, mask, jnp.asarray(W, jnp.float32)


def test_eq3_equals_eq2_up_to_constants(rng):
    """Eq. 3 is Eq. 2 minus θ-constants ⇒ identical gradients."""
    logits, labels, mask, W = _rand_batch(rng)
    hyp = SSLHyper(gamma=0.05, kappa=0.01, weight_decay=0.0)
    g3 = jax.grad(lambda lg: ssl_objective(lg, labels, mask, W, hyp,
                                           reduction="sum")[0])(logits)
    g2 = jax.grad(lambda lg: ssl_objective_kl_form(lg, labels, mask, W,
                                                   hyp))(logits)
    np.testing.assert_allclose(np.asarray(g3), np.asarray(g2), atol=1e-5)


def test_graph_term_is_nonnegative_kl(rng):
    """γΣ w_ij D(p_i‖p_j) ≥ 0; with κ=0 the regularizer is a weighted KL."""
    logits, _, _, W = _rand_batch(rng)
    logp = jax.nn.log_softmax(logits)
    val = graph_regularizer(logp, W, gamma=1.0, kappa=0.0)
    assert float(val) >= -1e-5


def test_graph_term_zero_for_identical_predictions(rng):
    B, C = 16, 5
    logits = jnp.tile(jnp.asarray(rng.normal(size=(1, C)), jnp.float32),
                      (B, 1))
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), jnp.float32)
    logp = jax.nn.log_softmax(logits)
    val = graph_regularizer(logp, W, gamma=1.0, kappa=0.0)
    np.testing.assert_allclose(float(val), 0.0, atol=1e-4)


def test_unlabeled_points_ignored_by_supervised_term(rng):
    logits, labels, _, W = _rand_batch(rng)
    hyp = SSLHyper(gamma=0.0, kappa=0.0, weight_decay=0.0)
    zero_mask = jnp.zeros(logits.shape[0])
    loss, _ = ssl_objective(logits, labels, zero_mask, W, hyp,
                            reduction="sum")
    np.testing.assert_allclose(float(loss), 0.0, atol=1e-6)
    # gradient w.r.t. unlabeled rows is zero when γ=κ=0
    one_mask = jnp.zeros(logits.shape[0]).at[0].set(1.0)
    g = jax.grad(lambda lg: ssl_objective(lg, labels, one_mask, W, hyp,
                                          reduction="sum")[0])(logits)
    np.testing.assert_allclose(np.asarray(g)[1:], 0.0, atol=1e-7)


def test_entropy_regularizer_favors_uniform(rng):
    """κ-term: gradient step on −κH should push toward uniform (higher H)."""
    logits = jnp.asarray(rng.normal(size=(8, 6)) * 3, jnp.float32)
    labels = jnp.zeros(8, jnp.int32)
    mask = jnp.zeros(8)
    W = jnp.zeros((8, 8))
    hyp = SSLHyper(gamma=0.0, kappa=1.0, weight_decay=0.0)
    loss_fn = lambda lg: ssl_objective(lg, labels, mask, W, hyp,
                                       reduction="sum")[0]
    g = jax.grad(loss_fn)(logits)
    stepped = logits - 0.5 * g
    h0 = entropy(jax.nn.log_softmax(logits)).mean()
    h1 = entropy(jax.nn.log_softmax(stepped)).mean()
    assert float(h1) > float(h0)


def test_pairwise_term_matmul_identity(rng):
    """−ΣW⊙(P·logPᵀ) equals the explicit double loop."""
    logits, _, _, W = _rand_batch(rng, B=12, C=5)
    logp = np.asarray(jax.nn.log_softmax(logits))
    p = np.exp(logp)
    ref = sum(W[i, j] * -(p[i] * logp[j]).sum()
              for i in range(12) for j in range(12))
    val = pairwise_cross_entropy_term(jnp.asarray(logp), W)
    np.testing.assert_allclose(float(val), float(ref), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(2, 32), C=st.integers(2, 20), seed=st.integers(0, 100))
def test_gradient_finite_everywhere(B, C, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(B, C)) * 5, jnp.float32)
    labels = jnp.asarray(rng.integers(0, C, B))
    mask = jnp.asarray((rng.random(B) < 0.5).astype(np.float32))
    W = jnp.asarray(np.abs(rng.normal(size=(B, B))), jnp.float32)
    hyp = SSLHyper(gamma=0.1, kappa=0.01, weight_decay=1e-4)
    loss, _ = ssl_objective(logits, labels, mask, W, hyp, params={"w": logits})
    g = jax.grad(lambda lg: ssl_objective(lg, labels, mask, W, hyp)[0])(logits)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(g)).all()
