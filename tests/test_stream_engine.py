"""Engine integration of the "metabatch_stream" pipeline.

The scan-compiled engine must consume exactly what the host-side plan
prescribes: same visited-index multiset at every scan_chunk, no dropped or
duplicated batches across a re-partition swap, and the Eq.-7 per-worker
shard decomposition consumed exactly under sync_mesh.

Index tracing: the test corpus stores ``index + 1`` in feature column 0, so
a counting step function recovers each batch's node indices on device
(padding rows carry 0 and a False valid mask) and accumulates visit counts
in the scan carry.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.config import RepartitionConfig
from repro.core import build_affinity_graph, plan_meta_batches
from repro.core.metabatch import build_mini_blocks
from repro.data import make_corpus
from repro.data.pipeline import make_metabatch_stream_pipeline
from repro.optim import constant_lr
from repro.train.engine import Engine, TrainState, data_mesh

N = 600
N_CLASSES = 6
BATCH = 96


@pytest.fixture(scope="module")
def stream_setup():
    corpus = make_corpus(N, n_classes=N_CLASSES, input_dim=24,
                         manifold_dim=4, seed=0)
    graph = build_affinity_graph(corpus.X, k=8)
    plan = plan_meta_batches(graph, batch_size=BATCH, n_classes=N_CLASSES,
                             seed=0)
    # Trace indices through the engine: feature 0 becomes index + 1.
    X = corpus.X.copy()
    X[:, 0] = np.arange(N) + 1
    traced = dataclasses.replace(corpus, X=X)
    return traced, graph, plan


def stream_factory(setup, **kw):
    corpus, graph, plan = setup
    kw.setdefault("seed", 0)
    return make_metabatch_stream_pipeline(corpus, graph, plan, **kw)


def counting_step(n: int):
    """Engine step_fn accumulating per-worker node-visit counts."""

    def step(state: TrainState, batch, lr):
        idx = jnp.round(batch["x"][..., 0]).astype(jnp.int32)   # (k, P)
        valid = batch["valid"].astype(jnp.float32)
        counts = state.params["counts"]                          # (k, n+1)
        counts = jax.vmap(lambda c, i, v: c.at[i].add(v))(counts, idx,
                                                          valid)
        new = dataclasses.replace(
            state, params={"counts": counts}, step=state.step + 1)
        return new, {"steps": jnp.float32(1.0)}

    return step


def run_engine(pipeline, *, n_workers=1, n_epochs=1, scan_chunk=1,
               strategy="sequential", mesh=None):
    state = TrainState.create(
        {"counts": jnp.zeros((n_workers, N + 1))}, {},
        jax.random.PRNGKey(0))
    eng = Engine(counting_step(N), strategy=strategy, mesh=mesh,
                 scan_chunk=scan_chunk, prefetch=2)
    res = eng.run(pipeline, state=state, n_epochs=n_epochs,
                  lr_schedule=constant_lr(1e-3))
    return np.asarray(res.state.params["counts"])


def host_counts(setup, *, n_workers=1, n_epochs=1, **kw):
    """The host-side reference: an identical stream walked directly."""
    pipeline = stream_factory(setup, n_workers=n_workers,
                              record_indices=True, **kw)
    counts = np.zeros((n_workers, N + 1))
    for e in range(n_epochs):
        for _ in pipeline(epoch=e):
            pass
        for group in pipeline.stream.last_epoch_indices:
            for w, idx in enumerate(group):
                np.add.at(counts[w], idx + 1, 1.0)
    return counts


# ----------------------------------------------- visited-index multiset
@pytest.mark.parametrize("scan_chunk", [0, 1, 3])
def test_engine_visits_exactly_the_host_side_plan(stream_setup, scan_chunk):
    got = run_engine(stream_factory(stream_setup), scan_chunk=scan_chunk,
                     n_epochs=2)
    want = host_counts(stream_setup, n_epochs=2)
    np.testing.assert_array_equal(got, want)
    assert got[:, 1:].sum() > 0                   # something was visited
    assert got[:, 0].sum() == 0.0                 # padding never counted


# ----------------------------------------------- re-partition swap safety
def test_repartition_swap_drops_and_duplicates_nothing(stream_setup):
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=9)
    pipeline = stream_factory(stream_setup, with_neighbor=False,
                              repartition=rep, record_indices=True)
    stream = pipeline.stream
    for e in range(3):
        seen = np.concatenate([idx for group in _drain(pipeline, e)
                               for idx in group])
        # Without neighbours each epoch covers the *current* plan's nodes
        # exactly once — a swapped-in plan must neither drop nor duplicate.
        assert sorted(seen) == list(range(N)), f"epoch {e}"
    assert stream.swaps == 2          # plans swapped in at epochs 1 and 2


def _drain(pipeline, epoch):
    for _ in pipeline(epoch=epoch):
        pass
    return pipeline.stream.last_epoch_indices


def test_repartition_runs_through_engine_and_stays_deterministic(
        stream_setup):
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=4)
    got = run_engine(stream_factory(stream_setup, repartition=rep),
                     n_epochs=3, scan_chunk=2)
    want = host_counts(stream_setup, n_epochs=3, repartition=rep)
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------------- Eq.-7 sharding
def test_sync_mesh_two_workers_consume_eq7_shards_exactly(stream_setup):
    got = run_engine(stream_factory(stream_setup, n_workers=2),
                     n_workers=2, n_epochs=2, scan_chunk=1,
                     strategy="sync_mesh", mesh=data_mesh(2))
    want = host_counts(stream_setup, n_workers=2, n_epochs=2)
    # Per-worker equality: each worker consumed exactly its Eq.-7 shard of
    # the meta-batch pairs, not merely the union.
    np.testing.assert_array_equal(got, want)
    assert got[0, 1:].sum() > 0 and got[1, 1:].sum() > 0
    # The two shards are different work, not replicas.
    assert (got[0] != got[1]).any()


# -------------------------------------------------- epoch purity / resume
def test_stream_is_epoch_pure_jumping_matches_sequential(stream_setup):
    """Jumping straight to epoch e (checkpoint resume) must reproduce the
    exact batches an uninterrupted sequential walk emits at epoch e."""
    rep = RepartitionConfig(every_n_epochs=2, matching_temperature=0.5,
                            seed=6)
    seq = stream_factory(stream_setup, repartition=rep,
                         record_indices=True)
    for e in range(5):
        seq_idx = _drain(seq, e)
    jump = stream_factory(stream_setup, repartition=rep,
                          record_indices=True)
    jump_idx = _drain(jump, 4)            # fresh stream, straight to e=4
    assert len(jump_idx) == len(seq_idx)
    for a, b in zip(seq_idx, jump_idx):
        for wa, wb in zip(a, b):
            np.testing.assert_array_equal(wa, wb)
    assert jump.stream._plan_epoch == 4   # the epoch-4 plan was installed


def test_stream_skips_replans_past_the_horizon(stream_setup):
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=2)
    pipeline = stream_factory(stream_setup, repartition=rep)
    for e in range(3):
        for _ in pipeline(epoch=e, n_epochs=3):
            pass
    # Epoch 2 is the last: no background plan for epoch 3 was launched.
    assert pipeline.stream._pending is None
    assert pipeline.stream.swaps == 2


# ---------------------------------------- replan failure & retry re-arm
def test_replan_failure_surfaces_exception_and_rearms(stream_setup):
    """A failing replan warns with the exception type AND text, the epoch
    keeps the old plan, and a later successful swap re-arms the retry so
    a transient failure cannot pin the stream to a stale plan forever."""
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=5)
    pipeline = stream_factory(stream_setup, with_neighbor=False,
                              repartition=rep)
    stream = pipeline.stream
    real = stream._synthesize
    boom = {"active": True}

    def flaky(epoch):
        if boom["active"]:
            raise RuntimeError("disk on fire")
        return real(epoch)

    stream._synthesize = flaky
    for _ in pipeline(epoch=0):        # launches bg replan for epoch 1
        pass
    with pytest.warns(UserWarning, match="RuntimeError: disk on fire"):
        for _ in pipeline(epoch=1):
            pass
    assert stream.swaps == 0           # old plan kept
    assert 1 in stream._failed
    boom["active"] = False             # the transient failure clears
    with pytest.warns(UserWarning, match="disk on fire"):
        # Epoch 2 still collects the failed background attempt launched
        # while the failure was active; it relaunches healthy for epoch 3.
        for _ in pipeline(epoch=2):
            pass
    for _ in pipeline(epoch=3):
        pass
    assert stream.swaps >= 1
    assert stream._failed == set()     # successful swap re-armed the retry


def test_stream_reuse_degrades_with_warning_on_incapable_partitioner(
        stream_setup):
    from repro.core.partition import partition_graph_loop
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.0,
                            seed=0, reuse_hierarchy=True)
    with pytest.warns(UserWarning, match="reuse"):
        pipeline = stream_factory(stream_setup, repartition=rep,
                                  partitioner=partition_graph_loop)
    assert pipeline.stream._hierarchy is None     # degraded, not broken
    for _ in pipeline(epoch=0):
        pass


def test_stream_reuse_hierarchy_cache_is_built_and_injectable(stream_setup):
    from repro.core.partition import HierarchyCache
    _, graph, _ = stream_setup
    rep = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                            seed=3)
    pipeline = stream_factory(stream_setup, repartition=rep)
    assert isinstance(pipeline.stream._hierarchy, HierarchyCache)
    # An injected cache (e.g. the Experiment's shared one) is used as-is.
    cache = HierarchyCache(graph.W, tol=0.15, seed=3)
    pipeline2 = stream_factory(stream_setup, repartition=rep,
                               hierarchy_cache=cache)
    assert pipeline2.stream._hierarchy is cache
    # Off switch: no cache is built.
    rep_off = RepartitionConfig(every_n_epochs=1, matching_temperature=0.5,
                                seed=3, reuse_hierarchy=False)
    pipeline3 = stream_factory(stream_setup, repartition=rep_off)
    assert pipeline3.stream._hierarchy is None


def test_stream_with_reuse_stays_epoch_pure(stream_setup):
    """Jump-resume equals sequential with hierarchy reuse enabled — the
    cache is pure of when it was built."""
    rep = RepartitionConfig(every_n_epochs=2, matching_temperature=0.5,
                            seed=8, reuse_hierarchy=True)
    seq = stream_factory(stream_setup, repartition=rep,
                         record_indices=True)
    for e in range(4):
        seq_idx = _drain(seq, e)
    jump = stream_factory(stream_setup, repartition=rep,
                          record_indices=True)
    jump_idx = _drain(jump, 3)
    assert len(jump_idx) == len(seq_idx)
    for a, b in zip(seq_idx, jump_idx):
        for wa, wb in zip(a, b):
            np.testing.assert_array_equal(wa, wb)


# ------------------------------------------------ degenerate-plan guard
def test_build_mini_blocks_rejects_batch_smaller_than_classes(stream_setup):
    _, graph, _ = stream_setup
    with pytest.raises(ValueError, match="single-node"):
        build_mini_blocks(graph, batch_size=4, n_classes=N_CLASSES)
    # boundary: batch_size == n_classes is allowed (blocks of ~1 node are
    # the caller's explicit choice there, not a silent degeneration)
    res = build_mini_blocks(graph, batch_size=N_CLASSES,
                            n_classes=N_CLASSES)
    assert res.sizes.sum() == N
