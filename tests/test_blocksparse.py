"""Block-sparse Eq.-3/4 regularizer: layout construction, kernel semantics,
tuning-table persistence, and the config→pipeline→train_step threading.

Runs in the minimal container (no hypothesis): these tests guard the
block-sparse kernels' gradient semantics on non-tile-aligned shapes, the
bitwise dense-equivalence contract on full masks, and the BlockLayout
padding conventions the kernels assume.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import PAIRWISE, resolve_pairwise
from repro.core.metabatch import (BlockLayout, block_layout,
                                  concat_batch_indices, plan_layout_budget,
                                  tile_occupancy)
from repro.core.ssl_loss import SSLHyper, graph_regularizer
from repro.kernels import ref
from repro.kernels.ops import (graph_regularizer_blocksparse,
                               graph_regularizer_fused)
from repro.kernels.tuning import (TileSpec, build_table, load_tile_table,
                                  save_tile_table)

GAMMA, KAPPA = 0.31, 2e-3


def _problem(rng, B, C, bt, density=0.5):
    """(logp, W, layout): W zeroed outside a random symmetric tile mask."""
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = np.abs(rng.normal(size=(B, B))).astype(np.float32)
    W = (W + W.T) / 2
    nt = -(-B // bt)
    occ = rng.random((nt, nt)) < density
    occ = occ | occ.T
    mask = np.kron(occ, np.ones((bt, bt), bool))[:B, :B]
    W = np.where(mask, W, 0.0).astype(np.float32)
    return logp, jnp.asarray(W), block_layout(W, bt), mask


def _bsp(logp, W, layout, bt, bc=16):
    return graph_regularizer_blocksparse(
        logp, W, GAMMA, KAPPA, layout=layout,
        tiles=TileSpec(bi=bt, bc=bc))


def _oracle(logp, W):
    return ref.graph_regularizer_ref(logp, W, GAMMA, KAPPA)


# ------------------------------------------------------------------- kernel
@pytest.mark.parametrize("B,C,bt", [(77, 23, 32), (128, 39, 32),
                                    (130, 70, 64), (96, 8, 32)])
def test_forward_matches_oracle_unaligned(rng, B, C, bt):
    """Compacted-grid forward == jnp oracle on shapes where B and C are
    NOT multiples of the tile sizes (sentinel + padding conventions)."""
    logp, W, lay, _ = _problem(rng, B, C, bt)
    got = _bsp(logp, W, lay, bt)
    want = _oracle(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@pytest.mark.parametrize("B,C,bt", [(77, 23, 32), (130, 70, 64)])
def test_vjp_matches_autodiff_of_oracle(rng, B, C, bt):
    """Analytic two-pass VJP == jax.grad of the oracle: dL/dlogp exactly,
    dL/dW on the occupied tiles (off-mask dW is structurally zero)."""
    logp, W, lay, mask = _problem(rng, B, C, bt)
    f = lambda lp, w: _bsp(lp, w, lay, bt)  # noqa: E731
    glp, gw = jax.grad(f, argnums=(0, 1))(logp, W)
    glp_o, gw_o = jax.grad(_oracle, argnums=(0, 1))(logp, W)
    np.testing.assert_allclose(np.asarray(glp), np.asarray(glp_o),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gw)[mask],
                               np.asarray(gw_o)[mask],
                               rtol=1e-4, atol=1e-6)
    assert np.all(np.asarray(gw)[~mask] == 0.0), \
        "dW must be zero on structurally-zero tiles"


def test_full_mask_bitwise_equals_dense_fused(rng):
    """On a fully-occupied multi-tile grid the block-sparse kernels visit
    the same tiles in the same order as the dense fused kernels — value
    and both gradients must match *bitwise*, not just approximately."""
    B, C, bt, bc = 128, 16, 32, 8
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = np.abs(rng.normal(size=(B, B))).astype(np.float32)
    W = jnp.asarray((W + W.T) / 2)
    lay = block_layout(np.asarray(W), bt)
    assert lay.density == 1.0 and lay.nt > 1
    f_b = lambda lp, w: _bsp(lp, w, lay, bt, bc)  # noqa: E731
    f_d = lambda lp, w: graph_regularizer_fused(  # noqa: E731
        lp, w, GAMMA, KAPPA, tiles=TileSpec(bi=bt, bj=bt, bc=bc))
    vb, (glp_b, gw_b) = jax.value_and_grad(f_b, argnums=(0, 1))(logp, W)
    vd, (glp_d, gw_d) = jax.value_and_grad(f_d, argnums=(0, 1))(logp, W)
    for got, want in [(vb, vd), (glp_b, glp_d), (gw_b, gw_d)]:
        assert np.array_equal(
            np.asarray(got, np.float32).view(np.int32),
            np.asarray(want, np.float32).view(np.int32))


def test_single_tile_grid_falls_back_to_dense(rng):
    """nt == 1 has nothing to skip: the entry must route to the dense
    fused kernel (bitwise-identical result)."""
    B, C, bt = 64, 8, 64
    logp, W, lay, _ = _problem(rng, B, C, bt, density=1.1)
    assert lay.nt == 1
    got = _bsp(logp, W, lay, bt, bc=8)
    want = graph_regularizer_fused(logp, W, GAMMA, KAPPA,
                                   tiles=TileSpec(bi=bt, bj=bt, bc=8))
    assert np.array_equal(np.asarray(got, np.float32).view(np.int32),
                          np.asarray(want, np.float32).view(np.int32))


def test_empty_mask_keeps_entropy_term(rng):
    """All-zero W: every tile row is sentinel-only, the pairwise terms
    vanish, and only the κ·H(p) entropy term survives — with gradients."""
    B, C, bt = 96, 8, 32
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = jnp.zeros((B, B), jnp.float32)
    lay = block_layout(np.zeros((B, B), np.float32), bt)
    assert lay.n_active == 0 and lay.list_len >= lay.nt   # sentinels kept
    got, (glp, gw) = jax.value_and_grad(
        lambda lp, w: _bsp(lp, w, lay, bt, bc=8), argnums=(0, 1))(logp, W)
    want = _oracle(logp, W)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
    glp_o = jax.grad(_oracle)(logp, W)
    np.testing.assert_allclose(np.asarray(glp), np.asarray(glp_o),
                               rtol=1e-4, atol=1e-6)
    assert np.all(np.asarray(gw) == 0.0)


def test_empty_tile_row_inside_sparse_mask(rng):
    """A mask whose middle tile row/column is entirely empty still writes
    that row's outputs (the sentinel convention) and matches the oracle."""
    B, C, bt = 96, 10, 32
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    W = np.abs(np.random.default_rng(3).normal(size=(B, B)))
    W = ((W + W.T) / 2).astype(np.float32)
    occ = np.zeros((3, 3), bool)
    occ[0, 0] = occ[2, 2] = occ[0, 2] = occ[2, 0] = True   # row/col 1 empty
    mask = np.kron(occ, np.ones((bt, bt), bool))
    W = np.where(mask, W, 0.0).astype(np.float32)
    lay = block_layout(W, bt)
    f = lambda lp, w: _bsp(lp, w, lay, bt, bc=8)  # noqa: E731
    got, glp = jax.value_and_grad(f)(logp, jnp.asarray(W))
    np.testing.assert_allclose(float(got), float(_oracle(logp, W)),
                               rtol=1e-5)
    glp_o = jax.grad(_oracle)(logp, jnp.asarray(W))
    np.testing.assert_allclose(np.asarray(glp), np.asarray(glp_o),
                               rtol=1e-4, atol=1e-6)
    # The empty tile row's dlogp rows are pure entropy-term gradients —
    # finite, not garbage from an unvisited output block.
    assert np.all(np.isfinite(np.asarray(glp)))


def test_vmap_over_stacked_layouts(rng):
    """Per-worker layouts stack along a leading axis and ride through vmap
    (the dnn_ssl_loss path); grad-under-vmap works too."""
    B, C, bt, k = 64, 8, 32, 3
    logps, Ws, lays = [], [], []
    for _ in range(k):
        logp, W, lay, _ = _problem(rng, B, C, bt)
        logps.append(np.asarray(logp))
        Ws.append(np.asarray(W))
        lays.append(lay)
    # Stacking requires the pipeline's shared static list length.
    shared = max(lay.list_len for lay in lays)
    lays = [block_layout(Ws[i], bt, list_len=shared).arrays()
            for i in range(k)]
    stacked = [jnp.asarray(np.stack([a[i] for a in lays]))
               for i in range(7)]
    tiles = TileSpec(bi=bt, bc=8)

    def per_worker(lp, w, *lay):
        return graph_regularizer_blocksparse(lp, w, GAMMA, KAPPA,
                                             layout=tuple(lay), tiles=tiles)

    out = jax.vmap(per_worker)(jnp.asarray(np.stack(logps)),
                               jnp.asarray(np.stack(Ws)), *stacked)
    want = [float(per_worker(jnp.asarray(logps[i]), jnp.asarray(Ws[i]),
                             *lays[i])) for i in range(k)]
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
    g = jax.vmap(jax.grad(per_worker, argnums=(0, 1)))(
        jnp.asarray(np.stack(logps)), jnp.asarray(np.stack(Ws)), *stacked)
    assert g[0].shape == (k, B, C) and g[1].shape == (k, B, B)


def test_zero_bxb_intermediates_both_directions(rng):
    """The whole point: fwd+bwd at B=64 materializes no dense B×B array
    outside Pallas kernels (the bwd stages through a (B, C) buffer only)."""
    from repro.analysis import count_bxb_intermediates

    B, C, bt = 64, 8, 16
    logp, W, lay, _ = _problem(rng, B, C, bt)
    n = count_bxb_intermediates(
        jax.grad(lambda lp: _bsp(lp, W, lay, bt, bc=8)), logp, B=B)
    assert n == 0


# ------------------------------------------------------------------- layout
def test_layout_deterministic_and_exact(rng):
    """Same W → identical layout arrays; occupancy is exact (a tile is
    active iff it holds a nonzero)."""
    _, W, lay1, mask = _problem(rng, 96, 8, 32)
    lay2 = block_layout(np.asarray(W), 32)
    for a, b in zip(lay1.arrays(), lay2.arrays()):
        assert np.array_equal(a, b)
    occ = tile_occupancy(np.asarray(W), 32)
    assert lay1.n_active == int(occ.sum())
    assert lay1.density == pytest.approx(occ.mean())
    # Row-major list enumerates exactly the active tiles (valid=1 entries).
    active = {(int(r), int(c)) for r, c, v in
              zip(lay1.rows, lay1.cols, lay1.valid) if v}
    assert active == {(i, j) for i, j in zip(*np.nonzero(occ))}


def test_layout_list_len_padding_and_overflow(rng):
    """list_len pins the static shape: padding repeats the last entry with
    valid=0; a budget smaller than the natural length raises."""
    _, W, lay, _ = _problem(rng, 96, 8, 32)
    n = lay.list_len
    padded = block_layout(np.asarray(W), 32, list_len=n + 8)
    assert padded.list_len == n + 8
    assert np.array_equal(padded.rows[:n], lay.rows)
    assert np.all(padded.valid[n:] == 0)
    assert np.all(padded.rows[n:] == lay.rows[n - 1])   # repeats last entry
    # Padding must not change the kernel's answer.
    logp = jax.nn.log_softmax(jnp.zeros((96, 8), jnp.float32))
    np.testing.assert_allclose(float(_bsp(logp, W, padded, 32, bc=8)),
                               float(_bsp(logp, W, lay, 32, bc=8)),
                               rtol=1e-6)
    with pytest.raises(ValueError):
        block_layout(np.asarray(W), 32, list_len=max(1, n - 8))


def test_plan_layout_budget_covers_every_batch(small_graph_setup):
    """The static budget is an upper bound on the natural tile-list length
    of every [M_r, M_s] batch the plan can emit — layouts built at the
    budget never raise."""
    corpus, graph, plan = small_graph_setup
    bt, pad = 64, 448
    budget = plan_layout_budget(plan, graph, bt, pad)
    assert budget % 8 == 0
    Wd = graph.W.toarray()
    coo = plan.batch_edges.tocoo()
    pairs = [(i, None) for i in range(plan.n_meta)]
    pairs += [(int(i), int(j)) for i, j in zip(coo.row, coo.col)]
    for i, j in pairs[:12]:
        idx = concat_batch_indices(plan, i, j)
        sub = Wd[np.ix_(idx, idx)]
        P = np.zeros((pad, pad), np.float32)
        P[:len(idx), :len(idx)] = sub
        lay = block_layout(P, bt, list_len=budget)   # must not raise
        assert isinstance(lay, BlockLayout) and lay.list_len == budget


# ------------------------------------------------------------ tuning table
def test_build_table_canonical_order_and_dup_rejection():
    spec = TileSpec(bi=128, bc=256)
    rows = [("k", None, None, spec), ("k", "tpu", None, spec),
            ("k", "tpu", 512, spec)]
    table = build_table(rows)
    assert [r[1:3] for r in table] == [("tpu", 512), ("tpu", None),
                                       (None, None)]
    with pytest.raises(ValueError, match="duplicate"):
        build_table(rows + [("k", "tpu", 512, TileSpec(bi=8))])


def test_save_load_tile_table_roundtrip(tmp_path):
    path = str(tmp_path / "tuned.json")
    rows = [("graph_reg_blocksparse", "cpu", None, TileSpec(bi=128, bc=256)),
            ("graph_reg", "cpu", None, TileSpec(bi=128, bj=128, bc=256))]
    save_tile_table(path, rows)
    assert load_tile_table(path) == build_table(rows)


def test_save_tile_table_rejects_audit_errors(tmp_path):
    """A TPU-reachable row with a misaligned tile must fail the write-time
    V002 check — the sweep can never persist a gate-rejected table."""
    path = str(tmp_path / "bad.json")
    with pytest.raises(ValueError, match="audit errors"):
        save_tile_table(path, [("graph_reg", "tpu", None,
                                TileSpec(bi=100, bj=128, bc=256))])
    assert not (tmp_path / "bad.json").exists()


# ---------------------------------------------------------------- threading
def test_registry_entry_and_resolver(rng):
    impl = PAIRWISE.get("blocksparse")
    assert impl.full_regularizer and impl.accepts_layout
    logp, W, lay, _ = _problem(rng, 64, 8, 32)
    resolved = resolve_pairwise("blocksparse",
                                tiles=TileSpec(bi=32, bc=8))
    assert getattr(resolved, "accepts_layout", False)
    got = resolved(logp, W, GAMMA, KAPPA, layout=lay.arrays())
    want = _bsp(logp, W, lay, 32, bc=8)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-6)


def test_graph_regularizer_layout_dispatch(rng):
    """ssl_loss.graph_regularizer hands the layout to layout-aware impls
    and the result matches the oracle on the same W."""
    logp, W, lay, _ = _problem(rng, 64, 8, 32)
    impl = resolve_pairwise("blocksparse", tiles=TileSpec(bi=32, bc=8))
    got = graph_regularizer(logp, W, GAMMA, KAPPA, pairwise=impl,
                            layout=lay.arrays())
    np.testing.assert_allclose(float(got), float(_oracle(logp, W)),
                               rtol=1e-5)


def test_dnn_ssl_loss_threads_tile_keys(rng):
    """A batch carrying the tile_* keys reaches the block-sparse kernel
    through the vmap and matches the jnp-oracle loss on the same batch."""
    from repro.models.dnn import DNNConfig, init_dnn
    from repro.train.train_step import dnn_ssl_loss

    B, C, bt, d = 64, 4, 32, 16
    cfg = DNNConfig(input_dim=d, hidden_dim=32, n_hidden=1, n_classes=C,
                    dropout=0.0)
    params = init_dnn(cfg, jax.random.PRNGKey(0))
    _, W, lay, _ = _problem(rng, B, C, bt)
    batch = {
        "x": jnp.asarray(rng.normal(size=(1, B, d)), jnp.float32),
        "y": jnp.zeros((1, B), jnp.int32),
        "label_mask": jnp.ones((1, B), jnp.float32),
        "W": jnp.asarray(W)[None],
        "valid": jnp.ones((1, B), jnp.float32),
    }
    keys = ("tile_rows", "tile_cols", "tile_valid", "tile_crows",
            "tile_ccols", "tile_cvalid", "tile_occ")
    batch_tiles = dict(batch)
    for k, a in zip(keys, lay.arrays()):
        batch_tiles[k] = jnp.asarray(a)[None]
    hyper = SSLHyper(gamma=GAMMA, kappa=KAPPA)
    impl = resolve_pairwise("blocksparse", tiles=TileSpec(bi=bt, bc=4))
    loss_bsp, _ = dnn_ssl_loss(params, batch_tiles, cfg, hyper,
                               pairwise=impl)
    loss_ref, _ = dnn_ssl_loss(params, batch, cfg, hyper, pairwise="ref")
    np.testing.assert_allclose(float(loss_bsp), float(loss_ref), rtol=1e-5)


def test_config_guards():
    """blocksparse without a layout, or a conflicting tile_bi, is rejected
    at config construction — not silently degraded per step."""
    from repro.api import BatchConfig, ExperimentConfig, ObjectiveConfig

    with pytest.raises(ValueError, match="layout_bt"):
        ExperimentConfig(objective=ObjectiveConfig(pairwise="blocksparse"))
    with pytest.raises(ValueError, match="tile_bi"):
        ExperimentConfig(batch=BatchConfig(layout_bt=64),
                         objective=ObjectiveConfig(tile_bi=128))
    cfg = ExperimentConfig(
        batch=BatchConfig(layout_bt=64),
        objective=ObjectiveConfig(pairwise="blocksparse"))
    assert cfg.batch.layout_bt == 64
    cfg2 = dataclasses.replace(cfg)
    assert cfg2.batch.layout_bt == 64
