"""Partition wall-clock + cut quality: seed per-node loop vs vectorized.

The tentpole claim of the vectorized multilevel partitioner is that graph
preprocessing stops dominating wall-clock at realistic N — the per-node
Python loops of the seed implementation (heavy-edge matching, greedy region
growing, FM refinement) become numpy/scipy batched array ops, cheap enough
to re-run *between epochs* (the stochastic re-partitioning stream).

For each (N, B) point this benchmark partitions the same k-NN affinity
graph into ``k = N·M/B`` mini-blocks (the §2.1 block count at n_classes
M=16) with BOTH implementations on identical seeds and records median
seconds, edge-cut and the cut ratio; it also times one full §2 plan
re-synthesis (``resynthesize_plan`` — the per-epoch cost the streaming
pipeline pays).  ``run(json_path=...)`` dumps machine-readable records plus
the headline ``speedup_at_10k`` / ``cut_ratio_at_10k``.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.affinity import build_affinity_graph
from repro.core.metabatch import resynthesize_plan
from repro.core.partition import partition_graph, partition_graph_loop

M = 16           # n_classes in the §2.1 block-count formula k = N*M/B
KNN = 10         # the paper's affinity graph degree
TOL = 0.15       # build_mini_blocks default balance tolerance


def _graph(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 16)).astype(np.float32)
    return build_affinity_graph(X, k=KNN)


def _median_seconds(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True, json_path: str | None = None) -> list[str]:
    # B=2048 is the paper's §3 protocol batch size (its headline row);
    # B=512 is this repo's BatchConfig default (many small blocks — the
    # adversarial regime for the vectorized path).
    points = [(2000, 512), (10000, 2048), (10000, 512)]
    if not quick:
        points += [(10000, 1024), (20000, 2048)]
    loop_reps, vec_reps = (2, 3) if quick else (3, 5)
    records, rows = [], []
    for n, B in points:
        k = n * M // B
        g = _graph(n)
        lo_box: dict = {}
        ve_box: dict = {}

        def run_loop():
            lo_box["res"] = partition_graph_loop(g.W, k, tol=TOL, seed=0)

        def run_vec():
            ve_box["res"] = partition_graph(g.W, k, tol=TOL, seed=0)

        t_loop = _median_seconds(run_loop, loop_reps)
        t_vec = _median_seconds(run_vec, vec_reps)
        lo, ve = lo_box["res"], ve_box["res"]
        ratio = ve.cut / max(lo.cut, 1e-12)
        speedup = t_loop / t_vec
        rec = {
            "n": n, "B": B, "k": k, "nnz": int(g.W.nnz),
            "loop_seconds": t_loop, "vec_seconds": t_vec,
            "speedup": speedup,
            "loop_cut": float(lo.cut), "vec_cut": float(ve.cut),
            "cut_ratio": ratio,
            "loop_max_size": int(lo.sizes.max()),
            "vec_max_size": int(ve.sizes.max()),
        }
        records.append(rec)
        rows.append(f"partition/loop_n{n}_B{B},{t_loop * 1e6:.0f},"
                    f"cut={lo.cut:.0f}")
        rows.append(f"partition/vec_n{n}_B{B},{t_vec * 1e6:.0f},"
                    f"speedup={speedup:.1f}x cut_ratio={ratio:.3f}")
    # Per-epoch re-synthesis cost (what the streaming pipeline pays on its
    # background thread each re-partition epoch).
    n_re, B_re = (10000, 512)
    g = _graph(n_re)
    t_replan = _median_seconds(
        lambda: resynthesize_plan(g, B_re, M, epoch=1, base_seed=0,
                                  temperature=0.5, tol=TOL),
        2 if quick else 3)
    rows.append(f"partition/replan_n{n_re}_B{B_re},{t_replan * 1e6:.0f},"
                f"per_epoch_resynthesis")
    # Headline: the paper-protocol row (N=10k, B=2048); the repo-default
    # B=512 row rides along so the many-small-blocks regime is tracked too.
    at_10k = next(r for r in records if r["n"] == 10000 and r["B"] == 2048)
    at_10k_512 = next(r for r in records
                      if r["n"] == 10000 and r["B"] == 512)
    rows.append(f"partition/speedup_at_10k,,{at_10k['speedup']:.2f}x")
    rows.append(
        f"partition/speedup_at_10k_B512,,{at_10k_512['speedup']:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "records": records,
                "speedup_at_10k": at_10k["speedup"],
                "cut_ratio_at_10k": at_10k["cut_ratio"],
                "speedup_at_10k_B512": at_10k_512["speedup"],
                "cut_ratio_at_10k_B512": at_10k_512["cut_ratio"],
                "replan_seconds_at_10k": t_replan,
                "target_speedup": 10.0,
                "target_cut_ratio": 1.05,
            }, f, indent=2)
    return rows
