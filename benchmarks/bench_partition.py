"""Partition wall-clock + cut quality: seed per-node loop vs vectorized.

The tentpole claim of the vectorized multilevel partitioner is that graph
preprocessing stops dominating wall-clock at realistic N — the per-node
Python loops of the seed implementation (heavy-edge matching, greedy region
growing, FM refinement) become numpy/scipy batched array ops, cheap enough
to re-run *between epochs* (the stochastic re-partitioning stream).

For each (N, B) point this benchmark partitions the same k-NN affinity
graph into ``k = N·M/B`` mini-blocks (the §2.1 block count at n_classes
M=16) with BOTH implementations on identical seeds and records median
seconds, edge-cut and the cut ratio.  It also times one full §2 plan
re-synthesis (``resynthesize_plan`` — the per-epoch cost the streaming
pipeline pays) **from scratch and with a cached coarsening hierarchy**
(``reuse=HierarchyCache`` — the incremental-replan fast path), and verifies
the reuse plans are bit-reproducible per ``(seed, epoch)``.

``run(json_path=...)`` dumps machine-readable records plus the headline
``speedup_at_10k`` / ``cut_ratio_at_10k`` (B=2048, the paper's §3 batch)
and ``speedup_at_10k_B512`` / ``cut_ratio_at_10k_B512`` (the repo-default
many-small-blocks regime).  Targets are **enforced**: the run raises if
any ratio-based gate regresses, so CI fails instead of silently recording
a regression — at BOTH batch sizes, and for the hierarchy-reuse replan.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.affinity import build_affinity_graph
from repro.core.metabatch import resynthesize_plan
from repro.core.partition import (HierarchyCache, partition_graph,
                                  partition_graph_loop)

M = 16           # n_classes in the §2.1 block-count formula k = N*M/B
KNN = 10         # the paper's affinity graph degree
TOL = 0.15       # build_mini_blocks default balance tolerance

# Ratio-based gates (machine-speed independent); enforced by run().
TARGET_SPEEDUP = 10.0            # headline: loop/vec at N=10k, B=2048
TARGET_SPEEDUP_B512 = 6.0        # loop/vec at N=10k, B=512 (repo default)
TARGET_CUT_RATIO = 1.05          # vec cut / loop cut, both regimes
TARGET_REPLAN_REUSE_SPEEDUP = 3.0  # headline (committed runs hit 3.2x+)
# Enforced floors sit below the headline targets where the committed
# margin is thin: a different CPU generation / BLAS build can shave
# 10-20% off a wall-clock ratio with no code change, and the hard gates
# must catch real regressions without flaking on hardware.  B=512's 6x
# target has >70% committed headroom, so it IS its own floor (and the
# reuse replan must ALSO always be strictly faster than from-scratch).
ENFORCED_SPEEDUP_FLOOR = 8.0       # B=2048 floor under the 10x headline
ENFORCED_REPLAN_REUSE_FLOOR = 2.0  # reuse floor under the 3x headline


def _graph(n: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 16)).astype(np.float32)
    return build_affinity_graph(X, k=KNN)


def _median_seconds(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _plans_identical(a, b) -> bool:
    if (a.mini_block_labels != b.mini_block_labels).any():
        return False
    if len(a.meta_batches) != len(b.meta_batches):
        return False
    return all((ma == mb).all()
               for ma, mb in zip(a.meta_batches, b.meta_batches))


def run(quick: bool = True, json_path: str | None = None,
        replan_json_path: str | None = None) -> list[str]:
    # B=2048 is the paper's §3 protocol batch size (its headline row);
    # B=512 is this repo's BatchConfig default (many small blocks — the
    # adversarial regime for the vectorized path).
    points = [(2000, 512), (10000, 2048), (10000, 512)]
    if not quick:
        points += [(10000, 1024), (20000, 2048)]
    pair_reps = 3 if quick else 5
    records, rows = [], []
    for n, B in points:
        k = n * M // B
        g = _graph(n)
        # Interleave loop/vec timing pairs and gate on the median of the
        # PER-PAIR ratios: background load (CI neighbours, the rest of
        # the bench) then hits both sides of every ratio equally, where
        # separate measurement phases let a load swing fake a 2x
        # speedup change.
        loop_times, vec_times, pair_ratios = [], [], []
        for _ in range(pair_reps):
            t0 = time.perf_counter()
            lo = partition_graph_loop(g.W, k, tol=TOL, seed=0)
            loop_times.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            ve = partition_graph(g.W, k, tol=TOL, seed=0)
            vec_times.append(time.perf_counter() - t0)
            pair_ratios.append(loop_times[-1] / vec_times[-1])
        t_loop = float(np.median(loop_times))
        t_vec = float(np.median(vec_times))
        ratio = ve.cut / max(lo.cut, 1e-12)
        speedup = float(np.median(pair_ratios))
        rec = {
            "n": n, "B": B, "k": k, "nnz": int(g.W.nnz),
            "loop_seconds": t_loop, "vec_seconds": t_vec,
            "speedup": speedup,
            "loop_cut": float(lo.cut), "vec_cut": float(ve.cut),
            "cut_ratio": ratio,
            "loop_max_size": int(lo.sizes.max()),
            "vec_max_size": int(ve.sizes.max()),
        }
        records.append(rec)
        rows.append(f"partition/loop_n{n}_B{B},{t_loop * 1e6:.0f},"
                    f"cut={lo.cut:.0f}")
        rows.append(f"partition/vec_n{n}_B{B},{t_vec * 1e6:.0f},"
                    f"speedup={speedup:.1f}x cut_ratio={ratio:.3f}")
    # Per-epoch re-synthesis cost (what the streaming pipeline pays on its
    # background thread each re-partition epoch): from scratch vs with the
    # cached coarsening hierarchy.
    n_re, B_re = (10000, 512)
    replan_reps = 5 if quick else 7
    g = _graph(n_re)
    replan_kw = dict(base_seed=0, temperature=0.5, tol=TOL)
    cache = HierarchyCache(g.W, tol=TOL, seed=0)
    k_re = n_re * M // B_re
    t_build = _median_seconds(lambda: cache.get(k_re), 1)  # built once
    # Interleave the from-scratch and reuse timings so background load
    # (CI neighbours, the rest of the bench) hits both sides equally —
    # the gate below is on their *ratio*.
    fresh_times, reuse_times = [], []
    for _ in range(replan_reps):
        t0 = time.perf_counter()
        resynthesize_plan(g, B_re, M, epoch=1, **replan_kw)
        fresh_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        resynthesize_plan(g, B_re, M, epoch=1, reuse=cache, **replan_kw)
        reuse_times.append(time.perf_counter() - t0)
    t_replan = float(np.median(fresh_times))
    t_replan_reuse = float(np.median(reuse_times))
    reuse_speedup = t_replan / t_replan_reuse
    # Bit-reproducibility of reuse plans per (seed, epoch): same inputs →
    # identical plans, including via a freshly built cache (purity).
    p1 = resynthesize_plan(g, B_re, M, epoch=2, reuse=cache, **replan_kw)
    p2 = resynthesize_plan(g, B_re, M, epoch=2, reuse=cache, **replan_kw)
    p3 = resynthesize_plan(g, B_re, M, epoch=2,
                           reuse=HierarchyCache(g.W, tol=TOL, seed=0),
                           **replan_kw)
    reproducible = _plans_identical(p1, p2) and _plans_identical(p1, p3)
    rows.append(f"partition/replan_n{n_re}_B{B_re},{t_replan * 1e6:.0f},"
                f"per_epoch_resynthesis")
    rows.append(f"partition/replan_reuse_n{n_re}_B{B_re},"
                f"{t_replan_reuse * 1e6:.0f},"
                f"reuse_speedup={reuse_speedup:.1f}x "
                f"hierarchy_build={t_build * 1e6:.0f}us "
                f"bit_reproducible={reproducible}")
    # Headline: the paper-protocol row (N=10k, B=2048) and the repo-default
    # B=512 row — BOTH regimes are gated so neither can silently regress.
    at_10k = next(r for r in records if r["n"] == 10000 and r["B"] == 2048)
    at_10k_512 = next(r for r in records
                      if r["n"] == 10000 and r["B"] == 512)
    rows.append(f"partition/speedup_at_10k,,{at_10k['speedup']:.2f}x")
    rows.append(
        f"partition/speedup_at_10k_B512,,{at_10k_512['speedup']:.2f}x")
    rows.append(
        f"partition/replan_reuse_speedup_at_10k,,{reuse_speedup:.2f}x")
    replan_summary = {
        "n": n_re, "B": B_re, "k": k_re,
        "replan_seconds_at_10k": t_replan,
        "replan_reuse_seconds_at_10k": t_replan_reuse,
        "replan_reuse_speedup": reuse_speedup,
        "hierarchy_build_seconds": t_build,
        "reuse_bit_reproducible": bool(reproducible),
        "target_replan_reuse_speedup": TARGET_REPLAN_REUSE_SPEEDUP,
        "enforced_replan_reuse_floor": ENFORCED_REPLAN_REUSE_FLOOR,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump({
                "records": records,
                "speedup_at_10k": at_10k["speedup"],
                "cut_ratio_at_10k": at_10k["cut_ratio"],
                "speedup_at_10k_B512": at_10k_512["speedup"],
                "cut_ratio_at_10k_B512": at_10k_512["cut_ratio"],
                **replan_summary,
                "target_speedup": TARGET_SPEEDUP,
                "target_speedup_B512": TARGET_SPEEDUP_B512,
                "target_cut_ratio": TARGET_CUT_RATIO,
                "enforced_speedup_floor": ENFORCED_SPEEDUP_FLOOR,
            }, f, indent=2)
    if replan_json_path:
        with open(replan_json_path, "w") as f:
            json.dump(replan_summary, f, indent=2)
    # --- gates (ratio-based, so they hold across machine speeds) ---------
    failures = []
    if at_10k["speedup"] < ENFORCED_SPEEDUP_FLOOR:
        failures.append(
            f"B=2048 speedup {at_10k['speedup']:.2f}x < enforced floor "
            f"{ENFORCED_SPEEDUP_FLOOR}x (headline target {TARGET_SPEEDUP}x)")
    if at_10k_512["speedup"] < TARGET_SPEEDUP_B512:
        failures.append(
            f"B=512 speedup {at_10k_512['speedup']:.2f}x < "
            f"{TARGET_SPEEDUP_B512}x")
    for rec in (at_10k, at_10k_512):
        if rec["cut_ratio"] > TARGET_CUT_RATIO:
            failures.append(
                f"B={rec['B']} cut ratio {rec['cut_ratio']:.3f} > "
                f"{TARGET_CUT_RATIO}")
    if t_replan_reuse >= t_replan:
        failures.append(
            f"hierarchy-reuse replan ({t_replan_reuse:.3f}s) not faster "
            f"than from-scratch ({t_replan:.3f}s)")
    if reuse_speedup < ENFORCED_REPLAN_REUSE_FLOOR:
        failures.append(
            f"replan reuse speedup {reuse_speedup:.2f}x < enforced floor "
            f"{ENFORCED_REPLAN_REUSE_FLOOR}x (headline target "
            f"{TARGET_REPLAN_REUSE_SPEEDUP}x)")
    if not reproducible:
        failures.append("reuse plans not bit-reproducible per (seed, epoch)")
    if failures:
        raise RuntimeError(
            "partition benchmark gates failed: " + "; ".join(failures))
    return rows
