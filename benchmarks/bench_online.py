"""Online refresh latency + dynamic-ingestion throughput.

The online loop's viability claim is that keeping the affinity graph
synced to the live model costs an epoch-boundary blip, not an epoch:
an embedding-space rebuild is one streaming top-k plus a *delta* repair
of the existing partition, and a node insert is a (m × n) streaming
top-k plus a local label repair — never a from-scratch
``partition_graph``.

For an N-node corpus this benchmark times

* ``refresh`` — :func:`repro.online.embedding_knn_graph` over an (N, H)
  embedding matrix plus the delta-repair + re-grouped plan (the
  ``OnlineManager.refresh`` low-churn path, end to end);
* ``insert`` — :func:`repro.core.affinity.insert_nodes` +
  :func:`repro.core.partition.extend_partition` + plan re-grouping for a
  32-row batch, reported as rows/s ingestion throughput;
* ``evict`` — the symmetric removal + repair for the same batch.

``run(json_path=...)`` also dumps machine-readable records
(``BENCH_online.json`` in CI) so the refresh-latency trajectory
survives across PRs.  Pure host-path smoke — no gates, no device code.
"""
from __future__ import annotations

import json
import time

import numpy as np

from repro.core.affinity import evict_nodes, insert_nodes
from repro.core.metabatch import plan_from_labels, plan_meta_batches
from repro.core.partition import extend_partition, repair_partition
from repro.online import edge_churn, embedding_knn_graph

KNN = 10
M = 16            # n_classes
BATCH = 512       # plan batch size
INSERT = 32       # ingestion batch (OnlineConfig.insert_batch default)


def _corpus_and_embeddings(n: int, h: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 32)).astype(np.float32)
    # Embeddings = noisy linear view of the features: realistic churn
    # (same coarse geometry, perturbed neighbourhoods), not a degenerate
    # identical-graph rebuild.
    E = (X @ rng.normal(size=(32, h)).astype(np.float32)
         + 0.1 * rng.normal(size=(n, h)).astype(np.float32))
    return X, E


def _median_seconds(fn, repeats: int) -> float:
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def run(quick: bool = True, json_path: str | None = None):
    n = 2000 if quick else 10_000
    repeats = 3 if quick else 5
    X, E = _corpus_and_embeddings(n)
    graph = embedding_knn_graph(X, k=KNN)
    plan = plan_meta_batches(graph, batch_size=BATCH, n_classes=M, seed=0)
    labels = plan.mini_block_labels
    k_parts = int(labels.max()) + 1
    new_graph = embedding_knn_graph(E, k=KNN)
    churn = edge_churn(graph, new_graph)

    def do_refresh():
        g = embedding_knn_graph(E, k=KNN)
        res = repair_partition(g.W, labels, k_parts, tol=0.15,
                               touched=None, passes=2)
        plan_from_labels(g, res.labels, BATCH, M, seed=1)

    def do_insert():
        rng = np.random.default_rng(1)
        g2 = insert_nodes(graph, X, rng.normal(
            size=(INSERT, X.shape[1])).astype(np.float32))
        res = extend_partition(g2.W, labels, k_parts, tol=0.15)
        plan_from_labels(g2, res.labels, BATCH, M, seed=2)
        return g2

    g2 = do_insert()

    def do_evict():
        g3 = evict_nodes(g2, np.arange(n, n + INSERT))
        res = repair_partition(g3.W, labels, k_parts, tol=0.15)
        plan_from_labels(g3, res.labels, BATCH, M, seed=3)

    t_refresh = _median_seconds(do_refresh, repeats)
    t_insert = _median_seconds(do_insert, repeats)
    t_evict = _median_seconds(do_evict, repeats)
    ins_per_s = INSERT / t_insert if t_insert > 0 else float("inf")

    records = {
        "n": n,
        "knn": KNN,
        "insert_batch": INSERT,
        "edge_churn": churn,
        "refresh_seconds": t_refresh,
        "insert_seconds": t_insert,
        "evict_seconds": t_evict,
        "insert_rows_per_s": ins_per_s,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2)

    yield f"online_refresh_n{n},{t_refresh * 1e6:.0f},churn={churn:.3f}"
    yield (f"online_insert_{INSERT}_n{n},{t_insert * 1e6:.0f},"
           f"rows_per_s={ins_per_s:.0f}")
    yield f"online_evict_{INSERT}_n{n},{t_evict * 1e6:.0f},"
