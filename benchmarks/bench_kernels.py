"""Kernel micro-benchmarks: fused Pallas graph-regularizer and RBF-affinity
vs their jnp oracles (interpret mode on CPU — correctness-representative,
not TPU timings), plus the jnp oracle timings that the trainer uses on CPU.

Implementations are looked up from the ``repro.api`` PAIRWISE registry —
the same path the trainer takes when a config says ``pairwise="ref"`` or
``"pallas"``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import PAIRWISE
from repro.kernels import ref

from .common import timeit


def run(quick: bool = True) -> list[str]:
    rng = np.random.default_rng(0)
    rows = []
    impl_ref = PAIRWISE.get("ref")
    impl_pallas = PAIRWISE.get("pallas")
    for B, C in [(512, 39), (1024, 39)] + ([] if quick else [(2048, 39)]):
        logp = jax.nn.log_softmax(
            jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
        W = jnp.asarray(np.abs(rng.normal(size=(B, B)))
                        * (rng.random((B, B)) < 0.05), jnp.float32)
        f_ref = jax.jit(impl_ref)
        t_ref = timeit(lambda: f_ref(logp, W).block_until_ready())
        rows.append(f"kernel/graph_reg_ref_B{B},{t_ref:.1f},jnp_oracle")
        if quick:
            t_pal = timeit(
                lambda: impl_pallas(logp, W).block_until_ready(), repeats=2)
            rows.append(
                f"kernel/graph_reg_pallas_B{B},{t_pal:.1f},interpret_mode")
    for N, D in [(1024, 351)]:
        x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        f_ref = jax.jit(lambda a: ref.rbf_affinity_ref(a, a, 2.0))
        t_ref = timeit(lambda: f_ref(x).block_until_ready())
        rows.append(f"kernel/rbf_ref_N{N},{t_ref:.1f},jnp_oracle")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
