"""Kernel micro-benchmarks: fused Pallas graph-regularizer and streaming
top-k vs their jnp oracles (interpret mode on CPU — correctness-
representative, not TPU timings), plus the jnp oracle timings that the
trainer uses on CPU.

Times the *forward* and the *fwd+bwd* (``jax.value_and_grad`` w.r.t. logp)
paths for ref vs fused, and counts (B, B)-shaped intermediates materialized
outside Pallas kernels — the fused path must show zero (the whole point of
the tiled analytic VJP).  ``run(json_path=...)`` additionally dumps the
records as machine-readable JSON so the perf trajectory is tracked across
PRs (``benchmarks/run.py`` writes ``BENCH_kernels.json``).

Implementations are looked up from the ``repro.api`` PAIRWISE registry —
the same path the trainer takes when a config says ``pairwise="ref"`` or
``"fused"``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import count_bxb_intermediates
from repro.api import PAIRWISE
from repro.kernels import ref

from .common import timeit

__all__ = ["count_bxb_intermediates", "run"]   # re-export: counter lives in
#                                                repro.analysis now


def _graph_reg_records(quick: bool) -> list[dict]:
    rng = np.random.default_rng(0)
    gamma, kappa = 1.0, 1e-4
    recs = []
    impls = {
        "ref": lambda lp, w: ref.graph_regularizer_ref(lp, w, gamma, kappa),
        "fused": lambda lp, w, _f=PAIRWISE.get("fused"): _f(lp, w, gamma,
                                                           kappa),
    }
    shapes = [(512, 39), (1024, 39)] + ([] if quick else [(2048, 39)])
    for B, C in shapes:
        logp = jax.nn.log_softmax(
            jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
        W = jnp.asarray(np.abs(rng.normal(size=(B, B)))
                        * (rng.random((B, B)) < 0.05), jnp.float32)
        for name, impl in impls.items():
            if name == "fused" and B > 1024 and jax.default_backend() != "tpu":
                continue   # interpret-mode grid sweeps get slow at B≥2048
            fwd = jax.jit(impl)
            grad = jax.jit(jax.value_and_grad(impl))
            repeats = 2 if name == "fused" else 5
            t_fwd = timeit(lambda: fwd(logp, W).block_until_ready(),
                           repeats=repeats)
            t_bwd = timeit(
                lambda: grad(logp, W)[1].block_until_ready(),
                repeats=repeats)
            recs.append({
                "kernel": "graph_reg", "impl": name, "B": B, "C": C,
                "fwd_us": round(t_fwd, 1), "fwd_bwd_us": round(t_bwd, 1),
                "bxb_outside_kernels": count_bxb_intermediates(
                    jax.grad(lambda lp: impl(lp, W)), logp, B=B),
                "mode": ("interpret" if name == "fused"
                         and jax.default_backend() != "tpu" else
                         jax.default_backend()),
            })
    return recs


def _topk_records(quick: bool) -> list[dict]:
    from repro.kernels.pairwise import knn_topk_pallas

    rng = np.random.default_rng(0)
    recs = []
    for N, D, k in [(1024, 351, 10)]:
        x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        f_ref = jax.jit(lambda a: ref.rbf_affinity_ref(a, a, 2.0))
        t_dense = timeit(lambda: f_ref(x).block_until_ready())
        recs.append({"kernel": "rbf_dense", "impl": "ref", "N": N, "D": D,
                     "fwd_us": round(t_dense, 1),
                     "mode": jax.default_backend()})
        f_topk = jax.jit(lambda a: ref.knn_topk_ref(a, a, k,
                                                    exclude_self=True))
        t_topk_ref = timeit(lambda: f_topk(x)[0].block_until_ready())
        recs.append({"kernel": "knn_topk", "impl": "ref", "N": N, "D": D,
                     "k": k, "fwd_us": round(t_topk_ref, 1),
                     "mode": jax.default_backend()})
        if quick:
            t_stream = timeit(
                lambda: knn_topk_pallas(x, x, k, exclude_self=True)[0]
                .block_until_ready(), repeats=2)
            recs.append({"kernel": "knn_topk", "impl": "pallas_stream",
                         "N": N, "D": D, "k": k,
                         "fwd_us": round(t_stream, 1),
                         "mode": ("interpret"
                                  if jax.default_backend() != "tpu"
                                  else "tpu")})
    return recs


def run(quick: bool = True, json_path: str | None = None) -> list[str]:
    recs = _graph_reg_records(quick) + _topk_records(quick)
    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"backend": jax.default_backend(), "records": recs},
                      fh, indent=2)
    rows = []
    for r in recs:
        shape = f"B{r['B']}" if "B" in r else f"N{r['N']}"
        rows.append(f"kernel/{r['kernel']}_{r['impl']}_{shape},"
                    f"{r['fwd_us']:.1f},"
                    + (f"fwd_bwd={r['fwd_bwd_us']:.1f}us;"
                       f"bxb={r['bxb_outside_kernels']}"
                       if "fwd_bwd_us" in r else r["mode"]))
    return rows


if __name__ == "__main__":
    print("\n".join(run(json_path="BENCH_kernels.json")))
