"""Kernel micro-benchmarks: fused + block-sparse Pallas graph-regularizer
and streaming top-k vs their jnp oracles, plus the jnp oracle timings the
trainer uses on CPU.

Timings are only perf-meaningful on a **compiled** Pallas backend
(TPU/GPU).  Everywhere else the Pallas kernels run in interpret mode —
those records are correctness smoke, carry ``"compiled": false``, and the
JSON is stamped ``"interpret_only": true`` so CI can never gate a speedup
claim on them.  What *is* backend-independent is the FLOP model: the
block-sparse density sweep records an analytic per-record ``flops_model``
whose ratio to the dense sweep equals the tile density exactly — the
Eq.-3/4 work saved by the compacted grid, provable without a TPU.

Times the *forward* and the *fwd+bwd* (``jax.value_and_grad`` w.r.t. logp)
paths, and counts (B, B)-shaped intermediates materialized outside Pallas
kernels — the fused and block-sparse paths must show zero (the whole point
of the tiled analytic VJP).  ``run(json_path=...)`` dumps the records as
machine-readable JSON so the perf trajectory is tracked across PRs
(``benchmarks/run.py`` writes ``BENCH_kernels.json``).

CLI (``python -m benchmarks.bench_kernels``):

  * no flags — full record sweep, writes ``BENCH_kernels.json``;
  * ``--smoke-blocksparse`` — seeded dense ≡ block-sparse bitwise check on
    a multi-tile full-mask grid plus an oracle check on a sparse mask;
  * ``--autotune [--dry-run] [--out PATH]`` — sweep tile candidates per
    kernel on the *current* backend and persist the winners through
    ``repro.kernels.tuning.save_tile_table`` (rows tagged with the
    measured backend; validated against the V001–V004 audits at write
    time).  ``--dry-run`` skips timing and writes the first candidates —
    CI uses it to prove the sweep plumbing end to end.

Implementations are looked up from the ``repro.api`` PAIRWISE registry —
the same path the trainer takes when a config says ``pairwise="fused"`` or
``"blocksparse"``.
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import count_bxb_intermediates
from repro.api import PAIRWISE
from repro.core.metabatch import block_layout
from repro.kernels import ref
from repro.kernels.tuning import TileSpec, save_tile_table

from .common import timeit

__all__ = ["count_bxb_intermediates", "run", "autotune", "smoke_blocksparse"]

#: Backends whose Pallas timings are real kernel launches.
_COMPILED_BACKENDS = ("tpu", "gpu")

#: Stamped into the JSON next to ``interpret_only``.
INTERPRET_NOTE = (
    "Pallas records with compiled=false ran in interpret mode: correctness "
    "smoke only, never a basis for speedup claims. Use flops_model for "
    "density-proportionality; compare timings only between compiled=true "
    "records.")


def _backend() -> str:
    return jax.default_backend()


def _pallas_compiled() -> bool:
    return _backend() in _COMPILED_BACKENDS


def _pallas_mode() -> str:
    return _backend() if _pallas_compiled() else "interpret"


def _bsp_flops_model(n_active: int, bt: int, classes: int, bc: int) -> int:
    """Analytic MXU-contraction FLOPs for one fwd+bwd block-sparse sweep.

    Each of the four passes (fwd accumulation, bwd bterm, bwd dL/dlogp,
    bwd dL/dW) performs one 2·bt·bt·bc-FLOP contraction per active tile
    per class chunk, so the total is exactly proportional to the number
    of active tiles — i.e. to the layout density.
    """
    bc = min(bc, classes)
    n_chunks = -(-classes // bc)
    return 4 * n_active * n_chunks * 2 * bt * bt * bc


def _graph_reg_records(quick: bool) -> list[dict]:
    rng = np.random.default_rng(0)
    gamma, kappa = 1.0, 1e-4
    recs = []
    impls = {
        "ref": lambda lp, w: ref.graph_regularizer_ref(lp, w, gamma, kappa),
        "fused": lambda lp, w, _f=PAIRWISE.get("fused"): _f(lp, w, gamma,
                                                           kappa),
    }
    shapes = [(512, 39), (1024, 39)] + ([] if quick else [(2048, 39)])
    for B, C in shapes:
        logp = jax.nn.log_softmax(
            jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
        W = jnp.asarray(np.abs(rng.normal(size=(B, B)))
                        * (rng.random((B, B)) < 0.05), jnp.float32)
        for name, impl in impls.items():
            if name == "fused" and B > 1024 and not _pallas_compiled():
                continue   # interpret-mode grid sweeps get slow at B≥2048
            fwd = jax.jit(impl)
            grad = jax.jit(jax.value_and_grad(impl))
            repeats = 2 if name == "fused" else 5
            t_fwd = timeit(lambda: fwd(logp, W).block_until_ready(),
                           repeats=repeats)
            t_bwd = timeit(
                lambda: grad(logp, W)[1].block_until_ready(),
                repeats=repeats)
            mode = _pallas_mode() if name == "fused" else _backend()
            recs.append({
                "kernel": "graph_reg", "impl": name, "B": B, "C": C,
                "fwd_us": round(t_fwd, 1), "fwd_bwd_us": round(t_bwd, 1),
                "bxb_outside_kernels": count_bxb_intermediates(
                    jax.grad(lambda lp: impl(lp, W)), logp, B=B),
                "mode": mode,
                "compiled": mode != "interpret",
            })
    return recs


def _occ_cases(nt: int) -> list[tuple[str, np.ndarray]]:
    """Symmetric occupancy masks at increasing block density."""
    idx = np.arange(nt)
    return [
        ("diag", np.eye(nt, dtype=bool)),
        ("band", np.abs(np.subtract.outer(idx, idx)) <= 1),
        ("full", np.ones((nt, nt), dtype=bool)),
    ]


def _blocksparse_records(quick: bool) -> list[dict]:
    """Density sweep: dense-fused vs block-sparse at fixed shape.

    The compacted grid's work (and, on a compiled backend, its time) must
    track ``flops_model`` — proportional to the tile density, with the
    ``full`` case matching the dense model exactly.
    """
    rng = np.random.default_rng(0)
    gamma, kappa = 1.0, 1e-4
    B, C, bt, bc = 512, 39, 128, 512
    nt = B // bt
    bsp = PAIRWISE.get("blocksparse")
    fused = PAIRWISE.get("fused")
    tiles_b = TileSpec(bi=bt, bc=bc)
    tiles_d = TileSpec(bi=bt, bj=bt, bc=bc)
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    base = np.abs(rng.normal(size=(B, B))).astype(np.float32)
    base = (base + base.T) / 2
    dense_flops = _bsp_flops_model(nt * nt, bt, C, bc)
    recs = []
    for name, occ in _occ_cases(nt):
        mask = np.kron(occ, np.ones((bt, bt), dtype=bool))
        W_np = np.where(mask, base, 0.0).astype(np.float32)
        W = jnp.asarray(W_np)
        lay = block_layout(W_np, bt).arrays()

        def impl(lp, w):
            return bsp(lp, w, gamma, kappa, layout=lay, tiles=tiles_b)

        fwd = jax.jit(impl)
        grad = jax.jit(jax.value_and_grad(impl))
        t_fwd = timeit(lambda: fwd(logp, W).block_until_ready(), repeats=2)
        t_bwd = timeit(lambda: grad(logp, W)[1].block_until_ready(),
                       repeats=2)
        n_active = int(occ.sum())
        flops = _bsp_flops_model(n_active, bt, C, bc)
        recs.append({
            "kernel": "graph_reg_blocksparse", "impl": "blocksparse",
            "B": B, "C": C, "bt": bt, "case": name,
            "n_active_tiles": n_active,
            "density": round(n_active / (nt * nt), 4),
            "fwd_us": round(t_fwd, 1), "fwd_bwd_us": round(t_bwd, 1),
            "flops_model": flops,
            "flops_frac_of_dense": round(flops / dense_flops, 4),
            "bxb_outside_kernels": count_bxb_intermediates(
                jax.grad(lambda lp: impl(lp, W)), logp, B=B),
            "mode": _pallas_mode(),
            "compiled": _pallas_compiled(),
        })
    # Dense-fused baseline on the full mask: the density-1.0 reference the
    # sweep's flops_frac_of_dense is normalized against.
    W = jnp.asarray(base)

    def impl_d(lp, w):
        return fused(lp, w, gamma, kappa, tiles=tiles_d)

    fwd = jax.jit(impl_d)
    grad = jax.jit(jax.value_and_grad(impl_d))
    t_fwd = timeit(lambda: fwd(logp, W).block_until_ready(), repeats=2)
    t_bwd = timeit(lambda: grad(logp, W)[1].block_until_ready(), repeats=2)
    recs.append({
        "kernel": "graph_reg_blocksparse", "impl": "fused",
        "B": B, "C": C, "bt": bt, "case": "dense_baseline",
        "n_active_tiles": nt * nt, "density": 1.0,
        "fwd_us": round(t_fwd, 1), "fwd_bwd_us": round(t_bwd, 1),
        "flops_model": dense_flops, "flops_frac_of_dense": 1.0,
        "bxb_outside_kernels": count_bxb_intermediates(
            jax.grad(lambda lp: impl_d(lp, W)), logp, B=B),
        "mode": _pallas_mode(),
        "compiled": _pallas_compiled(),
    })
    return recs


def _topk_records(quick: bool) -> list[dict]:
    from repro.kernels.pairwise import knn_topk_pallas

    rng = np.random.default_rng(0)
    recs = []
    for N, D, k in [(1024, 351, 10)]:
        x = jnp.asarray(rng.normal(size=(N, D)), jnp.float32)
        f_ref = jax.jit(lambda a: ref.rbf_affinity_ref(a, a, 2.0))
        t_dense = timeit(lambda: f_ref(x).block_until_ready())
        recs.append({"kernel": "rbf_dense", "impl": "ref", "N": N, "D": D,
                     "fwd_us": round(t_dense, 1),
                     "mode": _backend(), "compiled": True})
        f_topk = jax.jit(lambda a: ref.knn_topk_ref(a, a, k,
                                                    exclude_self=True))
        t_topk_ref = timeit(lambda: f_topk(x)[0].block_until_ready())
        recs.append({"kernel": "knn_topk", "impl": "ref", "N": N, "D": D,
                     "k": k, "fwd_us": round(t_topk_ref, 1),
                     "mode": _backend(), "compiled": True})
        if quick:
            t_stream = timeit(
                lambda: knn_topk_pallas(x, x, k, exclude_self=True)[0]
                .block_until_ready(), repeats=2)
            recs.append({"kernel": "knn_topk", "impl": "pallas_stream",
                         "N": N, "D": D, "k": k,
                         "fwd_us": round(t_stream, 1),
                         "mode": _pallas_mode(),
                         "compiled": _pallas_compiled()})
    return recs


def run(quick: bool = True, json_path: str | None = None) -> list[str]:
    recs = (_graph_reg_records(quick) + _blocksparse_records(quick)
            + _topk_records(quick))
    if json_path:
        with open(json_path, "w") as fh:
            json.dump({"backend": _backend(),
                       "interpret_only": not _pallas_compiled(),
                       "note": INTERPRET_NOTE,
                       "records": recs},
                      fh, indent=2)
    rows = []
    for r in recs:
        shape = f"B{r['B']}" if "B" in r else f"N{r['N']}"
        if "case" in r:
            shape += f"_{r['case']}"
        if "fwd_bwd_us" in r:
            derived = (f"fwd_bwd={r['fwd_bwd_us']:.1f}us;"
                       f"bxb={r['bxb_outside_kernels']}")
            if "density" in r:
                derived += f";density={r['density']:g}"
        else:
            derived = r["mode"]
        rows.append(f"kernel/{r['kernel']}_{r['impl']}_{shape},"
                    f"{r['fwd_us']:.1f},{derived}")
    return rows


# ---------------------------------------------------------------- autotune
#: Candidate tile specs per kernel, swept by ``--autotune`` on the current
#: backend.  All block-sparse candidates share bi (= the layout's bt): the
#: tile edge is fixed by the batch pipeline, only the class chunk is free.
_AUTOTUNE_CANDIDATES: dict[str, tuple[TileSpec, ...]] = {
    "graph_reg": (TileSpec(bi=128, bj=128, bc=256),
                  TileSpec(bi=128, bj=128, bc=512)),
    "graph_reg_blocksparse": (TileSpec(bi=128, bc=256),
                              TileSpec(bi=128, bc=512)),
}

_AUTOTUNE_SHAPE = (512, 39)   # representative (B, C) sweep shape


def _autotune_time(kernel: str, ts: TileSpec, logp, W, lay) -> float:
    gamma, kappa = 1.0, 1e-4
    if kernel == "graph_reg":
        f = PAIRWISE.get("fused")

        def impl(lp):
            return f(lp, W, gamma, kappa, tiles=ts)
    else:
        f = PAIRWISE.get("blocksparse")

        def impl(lp):
            return f(lp, W, gamma, kappa, layout=lay, tiles=ts)

    grad = jax.jit(jax.value_and_grad(impl))
    return timeit(lambda: grad(logp)[1].block_until_ready(), repeats=2)


def autotune(out_path: str = "TUNED_tiles.json", *,
             dry_run: bool = False) -> list[tuple]:
    """Measure tile candidates on the current backend and persist winners.

    Rows are tagged with the *measured* backend — a table tuned in
    interpret mode only ever matches interpret-mode (CPU) runs, so tuned
    interpret timings can never leak into TPU tile selection.  With
    ``dry_run=True`` nothing is timed: the first candidate per kernel is
    written, exercising the full sweep → ``save_tile_table`` → V001–V004
    validation path (what CI runs).
    """
    backend = _backend()
    rng = np.random.default_rng(0)
    B, C = _AUTOTUNE_SHAPE
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    base = np.abs(rng.normal(size=(B, B))).astype(np.float32)
    W_np = ((base + base.T) / 2).astype(np.float32)
    W = jnp.asarray(W_np)
    rows_out = []
    for kernel, cands in _AUTOTUNE_CANDIDATES.items():
        lay = (block_layout(W_np, cands[0].bi).arrays()
               if kernel == "graph_reg_blocksparse" else None)
        best, best_t = cands[0], None
        for ts in cands:
            if dry_run:
                print(f"autotune[{kernel}] {ts} -> dry-run (not timed)")
                continue
            t = _autotune_time(kernel, ts, logp, W, lay)
            print(f"autotune[{kernel}] {ts} -> {t:.1f}us")
            if best_t is None or t < best_t:
                best, best_t = ts, t
        label = "first candidate" if dry_run else f"{best_t:.1f}us"
        print(f"autotune[{kernel}] winner ({backend}): {best} [{label}]")
        rows_out.append((kernel, backend, None, best))
    save_tile_table(out_path, rows_out)
    print(f"wrote {out_path} ({len(rows_out)} rows, backend={backend}, "
          f"validated V001-V004)")
    return rows_out


# ------------------------------------------------------------------- smoke
def smoke_blocksparse() -> None:
    """Seeded dense ≡ block-sparse equivalence smoke (the CI gate).

    Full mask on a multi-tile grid: fwd, dL/dlogp and dL/dW must match the
    dense fused kernel *bitwise* (same tiles, same accumulation order).
    Sparse mask: value and dL/dlogp must match the jnp oracle over the
    full W, and dL/dW must agree on the mask and be zero off it.
    """
    from repro.kernels.ops import (graph_regularizer_blocksparse,
                                   graph_regularizer_fused)

    rng = np.random.default_rng(7)
    gamma, kappa = 1e-3, 1e-4
    B, C, bt, bc = 128, 16, 32, 8
    tiles_b = TileSpec(bi=bt, bc=bc)
    tiles_d = TileSpec(bi=bt, bj=bt, bc=bc)
    logp = jax.nn.log_softmax(
        jnp.asarray(rng.normal(size=(B, C)), jnp.float32))
    base = rng.random((B, B)).astype(np.float32)
    W_np = ((base + base.T) / 2).astype(np.float32)
    W = jnp.asarray(W_np)
    lay = block_layout(W_np, bt).arrays()

    def f_b(lp, w):
        return graph_regularizer_blocksparse(lp, w, gamma, kappa,
                                             layout=lay, tiles=tiles_b)

    def f_d(lp, w):
        return graph_regularizer_fused(lp, w, gamma, kappa, tiles=tiles_d)

    vb, (glp_b, gw_b) = jax.value_and_grad(f_b, argnums=(0, 1))(logp, W)
    vd, (glp_d, gw_d) = jax.value_and_grad(f_d, argnums=(0, 1))(logp, W)

    def bitwise(a, b) -> bool:
        return bool(np.array_equal(
            np.asarray(a, np.float32).view(np.int32),
            np.asarray(b, np.float32).view(np.int32)))

    ok_f, ok_lp, ok_w = bitwise(vb, vd), bitwise(glp_b, glp_d), \
        bitwise(gw_b, gw_d)
    print(f"full-mask B={B} bt={bt} (grid {B // bt}x{B // bt}): "
          f"fwd bitwise={ok_f} dlogp bitwise={ok_lp} dW bitwise={ok_w}")
    if not (ok_f and ok_lp and ok_w):
        raise SystemExit("blocksparse smoke FAILED: dense/blocksparse "
                         "bitwise mismatch on full mask")

    # Sparse mask vs the jnp oracle.
    nt = B // bt
    occ = np.eye(nt, dtype=bool)
    occ[0, nt - 1] = occ[nt - 1, 0] = True
    mask = np.kron(occ, np.ones((bt, bt), dtype=bool))
    Ws_np = np.where(mask, W_np, 0.0).astype(np.float32)
    Ws = jnp.asarray(Ws_np)
    lay_s = block_layout(Ws_np, bt).arrays()

    def f_s(lp, w):
        return graph_regularizer_blocksparse(lp, w, gamma, kappa,
                                             layout=lay_s, tiles=tiles_b)

    vs, (glp_s, gw_s) = jax.value_and_grad(f_s, argnums=(0, 1))(logp, Ws)
    vo, (glp_o, gw_o) = jax.value_and_grad(
        lambda lp, w: ref.graph_regularizer_ref(lp, w, gamma, kappa),
        argnums=(0, 1))(logp, Ws)
    ok_v = bool(np.allclose(vs, vo, rtol=1e-5, atol=1e-6))
    ok_g = bool(np.allclose(glp_s, glp_o, rtol=1e-5, atol=1e-6))
    ok_gw = bool(np.allclose(np.asarray(gw_s)[mask],
                             np.asarray(gw_o)[mask],
                             rtol=1e-5, atol=1e-6))
    ok_z = bool(np.all(np.asarray(gw_s)[~mask] == 0.0))
    dens = occ.sum() / occ.size
    print(f"sparse-mask density={dens:.3f}: value={ok_v} dlogp={ok_g} "
          f"dW(on-mask)={ok_gw} dW(off-mask zero)={ok_z}")
    if not (ok_v and ok_g and ok_gw and ok_z):
        raise SystemExit("blocksparse smoke FAILED: oracle mismatch on "
                         "sparse mask")
    print("blocksparse smoke OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke-blocksparse", action="store_true",
                    help="seeded dense==blocksparse equivalence check")
    ap.add_argument("--autotune", action="store_true",
                    help="sweep tile candidates, write the tuned table")
    ap.add_argument("--dry-run", action="store_true",
                    help="with --autotune: skip timing, still write+validate")
    ap.add_argument("--out", default="TUNED_tiles.json",
                    help="tile-table path for --autotune")
    ap.add_argument("--full", action="store_true",
                    help="bench the slow large shapes too (quick=False)")
    cli = ap.parse_args()
    if cli.smoke_blocksparse:
        smoke_blocksparse()
    elif cli.autotune:
        autotune(cli.out, dry_run=cli.dry_run)
    else:
        print("\n".join(run(quick=not cli.full,
                            json_path="BENCH_kernels.json")))
