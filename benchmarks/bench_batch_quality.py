"""Paper Figs. 1c, 2a, 2b: batch connectivity and entropy distributions.

Reproduces the three batch-quality claims:
  * Fig 1c — random batches have ~zero within-batch connectivity, graph
    batches don't;
  * Fig 2a — meta-batch label entropy ≈ global entropy ≫ graph-batch entropy;
  * Fig 2b — meta-batches keep the mini-block connectivity mean with ~1/K
    the variance.
"""
from __future__ import annotations

import numpy as np

from repro.core.stats import (batch_label_entropy, connectivity_distribution,
                              entropy_distribution, random_batches)

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, _, graph, plan = corpus_and_graph()
    rng = np.random.default_rng(0)
    rand = random_batches(corpus.n, plan.batch_size, rng=rng)
    blocks = [np.where(plan.mini_block_labels == b)[0]
              for b in range(plan.mini_block_labels.max() + 1)]

    c_rand = connectivity_distribution(graph, rand)
    c_mini = connectivity_distribution(graph, blocks)
    c_meta = connectivity_distribution(graph, plan.meta_batches)
    e_mini = entropy_distribution(corpus.y, blocks, corpus.n_classes)
    e_meta = entropy_distribution(corpus.y, plan.meta_batches,
                                  corpus.n_classes)
    e_glob = batch_label_entropy(corpus.y, np.arange(corpus.n),
                                 corpus.n_classes)
    rows = [
        f"fig1c/connectivity_random,{c_rand.mean()*1e6:.1f},mean={c_rand.mean():.4f}",
        f"fig1c/connectivity_metabatch,{c_meta.mean()*1e6:.1f},mean={c_meta.mean():.4f}",
        f"fig2a/entropy_graphbatch,{e_mini.mean()*1e6:.1f},mean={e_mini.mean():.3f}",
        f"fig2a/entropy_metabatch,{e_meta.mean()*1e6:.1f},mean={e_meta.mean():.3f}",
        f"fig2a/entropy_global,{e_glob*1e6:.1f},nats={e_glob:.3f}",
        f"fig2b/conn_var_mini,{c_mini.std()*1e6:.1f},std={c_mini.std():.4f}",
        f"fig2b/conn_var_meta,{c_meta.std()*1e6:.1f},std={c_meta.std():.4f}",
        f"fig2b/var_reduction,{(c_mini.var()/max(c_meta.var(),1e-12))*1e6:.1f},"
        f"ratio={c_mini.var()/max(c_meta.var(),1e-12):.1f}x",
    ]
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
