"""Paper Figs. 3b/3c: k-worker synchronous data-parallel SSL training.

Fig 3b: with the paper's lr-scaling rule (0.001·k, reset after 10 epochs),
more workers reach higher validation accuracy per epoch despite fewer
updates.  Fig 3c (wall-clock speedup) cannot be measured on this 1-core CPU
container — the k workers are mathematically exact (vmapped k-batch steps,
test_system.py proves equivalence to per-worker gradient averaging) but
execute serially here; we report per-epoch accuracy plus the modeled
speedup = k / (sync overhead 2×) from the paper's observed constant.
"""
from __future__ import annotations

import numpy as np

from repro.core import SSLHyper
from repro.data import MetaBatchPipeline, drop_labels
from repro.models.dnn import DNNConfig
from repro.train import train_dnn_ssl

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan = corpus_and_graph()
    labeled = drop_labels(corpus, 0.05, seed=1)   # the paper's 5% scenario
    workers = [1, 2, 4] if quick else [1, 2, 4, 8]
    epochs = 6 if quick else 15
    cfg = DNNConfig(input_dim=128, hidden_dim=512, n_hidden=3,
                    n_classes=corpus.n_classes, dropout=0.0)
    rows = []
    for k in workers:
        pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=k, seed=0)
        res = train_dnn_ssl(pipe.epoch, cfg=cfg,
                            hyper=SSLHyper(1.0, 1e-4, 1e-5),
                            n_epochs=epochs, n_workers=k, base_lr=1e-3,
                            lr_reset_epochs=10, dropout=0.0,
                            eval_data=test, seed=0)
        acc = [h["eval/acc"] for h in res.history]
        secs = sum(h["seconds"] for h in res.history)
        rows.append(f"fig3b/workers={k},{secs*1e6/epochs:.0f},"
                    f"acc_by_epoch={'|'.join(f'{a:.3f}' for a in acc)}")
        rows.append(f"fig3c/workers={k},0,modeled_speedup={k/2.0:.1f}x")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
