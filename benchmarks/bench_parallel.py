"""Paper Figs. 3b/3c: k-worker synchronous data-parallel SSL training.

Fig 3b: with the paper's lr-scaling rule (0.001·k, reset after 10 epochs),
more workers reach higher validation accuracy per epoch despite fewer
updates.  Fig 3c (wall-clock speedup) cannot be measured on this 1-core CPU
container — the k workers are mathematically exact (vmapped k-batch steps,
test_system.py proves equivalence to per-worker gradient averaging) but
execute serially here; we report per-epoch accuracy plus the modeled
speedup = k / (sync overhead 2×) from the paper's observed constant.

Each worker count is the same ``ExperimentConfig`` with a different
``TrainConfig.n_workers``.
"""
from __future__ import annotations

import dataclasses

from repro.api import (Experiment, ExperimentConfig, ObjectiveConfig,
                       TrainConfig)
from repro.data import drop_labels

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan = corpus_and_graph()
    labeled = drop_labels(corpus, 0.05, seed=1)   # the paper's 5% scenario
    workers = [1, 2, 4] if quick else [1, 2, 4, 8]
    epochs = 6 if quick else 15
    base = ExperimentConfig(
        objective=ObjectiveConfig(gamma=1.0, kappa=1e-4, weight_decay=1e-5),
        train=TrainConfig(n_epochs=epochs, base_lr=1e-3, lr_reset_epochs=10,
                          dropout=0.0, hidden_dim=512, n_hidden=3))
    rows = []
    for k in workers:
        cfg = dataclasses.replace(
            base, name=f"parallel-{k}w",
            train=dataclasses.replace(base.train, n_workers=k))
        res = Experiment(cfg, corpus=labeled, eval_data=test, graph=graph,
                         plan=plan).run()
        acc = [h["eval/acc"] for h in res.history]
        secs = sum(h["seconds"] for h in res.history)
        rows.append(f"fig3b/workers={k},{secs*1e6/epochs:.0f},"
                    f"acc_by_epoch={'|'.join(f'{a:.3f}' for a in acc)}")
        rows.append(f"fig3c/workers={k},0,modeled_speedup={k/2.0:.1f}x")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
