"""Roofline table from the dry-run sweep (deliverable g).

Reads experiments/dryrun/*.json and prints, per (arch × shape × mesh ×
strategy): the three roofline terms, the dominant bottleneck, and the
useful-FLOPs ratio.  ``benchmarks.run`` embeds the single-pod fsdp_tp table.
"""
from __future__ import annotations

import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["qwen1.5-0.5b", "xlstm-125m", "qwen2-1.5b", "phi4-mini-3.8b",
              "musicgen-large", "yi-9b", "mixtral-8x7b",
              "llama-3.2-vision-90b", "jamba-1.5-large-398b",
              "kimi-k2-1t-a32b"]


def load(out_dir: str = "experiments/dryrun") -> list[dict]:
    recs = []
    for f in glob.glob(os.path.join(out_dir, "*.json")):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def table(recs: list[dict], *, mesh: str = "single_pod_16x16",
          strategy: str = "fsdp_tp") -> str:
    rows = [r for r in recs if r.get("status") == "ok"
            and r["mesh"] == mesh and r["strategy"] == strategy]
    rows.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    out = [f"# mesh={mesh} strategy={strategy}",
           f"{'arch':<22}{'shape':<13}{'compute_s':>11}{'memory_s':>11}"
           f"{'collect_s':>11}  {'dominant':<13}{'useful':>7}{'HBM/chip':>10}"]
    for r in rows:
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        mem = r.get("memory_analysis", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0))
        out.append(
            f"{r['arch']:<22}{r['shape']:<13}"
            f"{t['compute_s']:>11.4f}{t['memory_s']:>11.4f}"
            f"{t['collective_s']:>11.4f}  {t['dominant'][:-2]:<13}"
            f"{(ratio if ratio else 0):>7.2f}{hbm/1e9:>9.1f}G")
    return "\n".join(out)


def csv_rows(recs: list[dict]) -> list[str]:
    """``name,us_per_call,derived`` rows for benchmarks.run: us_per_call is
    the dominant roofline term (the modeled step time)."""
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            continue
        t = r["roofline"]
        dom = max(t["compute_s"], t["memory_s"], t["collective_s"])
        name = (f"roofline/{r['arch']}/{r['shape']}/"
                f"{r['mesh'].split('_')[0]}/{r['strategy']}")
        rows.append(f"{name},{dom * 1e6:.1f},dominant={t['dominant']}")
    return rows


def main() -> None:
    recs = load()
    print(table(recs))
    print()
    print(table(recs, mesh="multi_pod_2x16x16"))
    print()
    print(table(recs, strategy="dp"))


if __name__ == "__main__":
    main()
