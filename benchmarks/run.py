"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``--full`` runs the paper's full
label-ratio grid and worker counts; default is the quick profile.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: quality,label,ablation,"
                         "parallel,kernels,train,partition,online,roofline")
    args = ap.parse_args()
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    sections = []
    if only is None or "quality" in only:
        from benchmarks import bench_batch_quality
        sections.append(("batch_quality(fig1c,2a,2b)",
                         lambda: bench_batch_quality.run(quick)))
    if only is None or "label" in only:
        from benchmarks import bench_label_ratio
        sections.append(("label_ratio(fig3a)",
                         lambda: bench_label_ratio.run(quick)))
    if only is None or "ablation" in only:
        from benchmarks import bench_batching_ablation
        sections.append(("batching_ablation(§2)",
                         lambda: bench_batching_ablation.run(quick)))
    if only is None or "parallel" in only:
        from benchmarks import bench_parallel
        sections.append(("parallel(fig3b,3c)",
                         lambda: bench_parallel.run(quick)))
    if only is None or "kernels" in only:
        from benchmarks import bench_kernels
        # Timings also land in BENCH_kernels.json (machine-readable: fwd and
        # fwd+bwd for ref vs fused) so the perf trajectory survives across PRs.
        sections.append(("kernels", lambda: bench_kernels.run(
            quick, json_path="BENCH_kernels.json")))
    if only is None or "train" in only:
        from benchmarks import bench_train
        # Training throughput lands in BENCH_train.json (python-loop vs the
        # scan-compiled engine, per strategy) — the loop-speed trajectory.
        sections.append(("train(engine)", lambda: bench_train.run(
            quick, json_path="BENCH_train.json")))
    if only is None or "partition" in only:
        from benchmarks import bench_partition
        # Partition wall-clock lands in BENCH_partition.json (seed loop vs
        # vectorized at matched seeds, cut ratios, per-epoch replan cost
        # from-scratch AND with hierarchy reuse); the replan summary also
        # lands in BENCH_partition_replan.json.  Both B=2048 and B=512 run
        # in smoke mode, and the ratio gates raise on regression (the
        # section then fails the job).
        sections.append(("partition(loop_vs_vec)", lambda: bench_partition.run(
            quick, json_path="BENCH_partition.json",
            replan_json_path="BENCH_partition_replan.json")))
    if only is None or "online" in only:
        from benchmarks import bench_online
        # Refresh latency + insert/evict throughput land in
        # BENCH_online.json — the cost trajectory of keeping the graph
        # synced to the live model.
        sections.append(("online(refresh+ingest)", lambda: bench_online.run(
            quick, json_path="BENCH_online.json")))
    if only is None or "roofline" in only:
        from benchmarks import bench_roofline

        def roofline_rows():
            recs = bench_roofline.load()
            return bench_roofline.csv_rows(
                [r for r in recs if r["mesh"] == "single_pod_16x16"
                 and r["strategy"] == "fsdp_tp"])
        sections.append(("roofline(dry-run)", roofline_rows))

    print("name,us_per_call,derived")
    ok = True
    for name, fn in sections:
        print(f"# --- {name} ---")
        try:
            for row in fn():
                print(row)
        except Exception:  # noqa: BLE001
            ok = False
            print(f"# SECTION FAILED: {name}", file=sys.stderr)
            traceback.print_exc()
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
