"""Batching-strategy ablation (paper §2's central trade-off).

The paper motivates meta-batches by two failure modes it compares against:
  * random batches  — SGD-friendly but the graph regularizer is inert
    (no within-batch edges, Fig 1a);
  * pure graph-partitioned batches — regularizer active but gradients
    biased (homogeneous, low-entropy batches → poor convergence, §2).
Meta-batches must beat BOTH under the same SSL objective.  Each strategy is
just a different ``BatchConfig.pipeline`` registry name on an otherwise
identical ``ExperimentConfig``.
"""
from __future__ import annotations

import dataclasses

from repro.api import (BatchConfig, Experiment, ExperimentConfig,
                       ObjectiveConfig, TrainConfig)
from repro.data import drop_labels

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan_meta = corpus_and_graph()
    labeled = drop_labels(corpus, 0.05, seed=1)
    epochs = 8 if quick else 16
    base = ExperimentConfig(
        objective=ObjectiveConfig(gamma=1.0, kappa=1e-4, weight_decay=1e-5),
        train=TrainConfig(n_epochs=epochs, base_lr=1e-2, dropout=0.0,
                          hidden_dim=512, n_hidden=3))
    variants = {
        # The paper's method: reuse the shared shuffled plan.
        "metabatch": (BatchConfig(pipeline="meta_batch", batch_size=512),
                      plan_meta),
        # Consecutive mini-blocks, no neighbour: plan rebuilt un-shuffled.
        "graphbatch": (BatchConfig(pipeline="graph_batch", batch_size=512,
                                   shuffle_blocks=False), None),
        # Random batches; plan pins batch size + epoch length for parity.
        "random": (BatchConfig(pipeline="random_batch", batch_size=512),
                   plan_meta),
    }
    rows = []
    for name, (batch_cfg, plan) in variants.items():
        cfg = dataclasses.replace(base, name=name, batch=batch_cfg)
        res = Experiment(cfg, corpus=labeled, eval_data=test, graph=graph,
                         plan=plan).run()
        acc = res.best("eval/acc")
        secs = sum(h["seconds"] for h in res.history)
        rows.append(f"ablation/{name}@0.05,{secs*1e6/epochs:.0f},acc={acc:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
