"""Batching-strategy ablation (paper §2's central trade-off).

The paper motivates meta-batches by two failure modes it compares against:
  * random batches  — SGD-friendly but the graph regularizer is inert
    (no within-batch edges, Fig 1a);
  * pure graph-partitioned batches — regularizer active but gradients
    biased (homogeneous, low-entropy batches → poor convergence, §2).
Meta-batches must beat BOTH under the same SSL objective.
"""
from __future__ import annotations

from repro.core import SSLHyper, plan_meta_batches
from repro.data import MetaBatchPipeline, drop_labels, random_batch_pipeline
from repro.models.dnn import DNNConfig
from repro.train import train_dnn_ssl

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan_meta = corpus_and_graph()
    labeled = drop_labels(corpus, 0.05, seed=1)
    plan_graph = plan_meta_batches(graph, batch_size=512,
                                   n_classes=corpus.n_classes, seed=0,
                                   shuffle_blocks=False)
    epochs = 8 if quick else 16
    cfg = DNNConfig(input_dim=128, hidden_dim=512, n_hidden=3,
                    n_classes=corpus.n_classes, dropout=0.0)
    hyper = SSLHyper(1.0, 1e-4, 1e-5)

    def rand_epoch():
        it = random_batch_pipeline(labeled, graph, 512, seed=0)
        return (next(it) for _ in range(len(plan_meta.meta_batches)))

    pipes = {
        "metabatch": MetaBatchPipeline(labeled, graph, plan_meta,
                                       seed=0).epoch,
        "graphbatch": MetaBatchPipeline(labeled, graph, plan_graph,
                                        with_neighbor=False, seed=0).epoch,
        "random": rand_epoch,
    }
    rows = []
    for name, epoch_fn in pipes.items():
        res = train_dnn_ssl(epoch_fn, cfg=cfg, hyper=hyper, n_epochs=epochs,
                            dropout=0.0, base_lr=1e-2, eval_data=test, seed=0)
        acc = max(h["eval/acc"] for h in res.history)
        secs = sum(h["seconds"] for h in res.history)
        rows.append(f"ablation/{name}@0.05,{secs*1e6/epochs:.0f},acc={acc:.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
