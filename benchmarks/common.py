"""Shared benchmark fixtures: the synthetic TIMIT-like corpus + graph."""
from __future__ import annotations

import dataclasses
import functools
import time

import numpy as np

from repro.core import build_affinity_graph, plan_meta_batches
from repro.data import drop_labels, make_corpus


@functools.lru_cache(maxsize=2)
def corpus_and_graph(n: int = 6000, n_classes: int = 20, batch: int = 512,
                     seed: int = 0):
    """Train/test split sharing one generative manifold (paper §3 protocol)."""
    full = make_corpus(int(n * 1.25), n_classes=n_classes, input_dim=128,
                       manifold_dim=10, seed=seed)
    train = dataclasses.replace(
        full, X=full.X[:n], y=full.y[:n], label_mask=full.label_mask[:n])
    test = (full.X[n:], full.y[n:])
    graph = build_affinity_graph(train.X, k=10)
    plan = plan_meta_batches(graph, batch_size=batch, n_classes=n_classes,
                             seed=seed)
    return train, test, graph, plan


def timeit(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
