"""Shared benchmark fixtures, built through the ``repro.api`` layer."""
from __future__ import annotations

import functools
import time

import numpy as np

from repro.api import BatchConfig, DataConfig, Experiment, ExperimentConfig


@functools.lru_cache(maxsize=2)
def corpus_and_graph(n: int = 6000, n_classes: int = 20, batch: int = 512,
                     seed: int = 0):
    """Train/test split sharing one generative manifold (paper §3 protocol).

    Returns ``(train_corpus, test, graph, plan)`` — the fully-labeled train
    corpus (benchmarks drop labels per scenario), the held-out eval pair,
    the affinity graph, and the shared meta-batch plan.
    """
    cfg = ExperimentConfig(
        data=DataConfig(n=n, n_classes=n_classes, input_dim=128,
                        manifold_dim=10, label_ratio=1.0,
                        test_fraction=0.25, seed=seed),
        batch=BatchConfig(batch_size=batch))
    exp = Experiment(cfg).build()
    return exp.corpus, exp.eval_data, exp.graph, exp.plan


def timeit(fn, *, repeats: int = 5, warmup: int = 1) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))
