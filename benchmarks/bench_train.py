"""Training-loop throughput: python-stepped loop vs the scan-compiled engine.

The tentpole claim of the unified engine is a faster hot loop: compiling an
epoch into one donated ``lax.scan`` removes per-step Python dispatch and
per-step state copies.  This benchmark measures steps/sec and epoch seconds
at a fixed small SSL shape (where dispatch overhead is a real fraction of
the step — exactly the regime the paper's 4×2000 DNN occupies on CPU) for:

  * ``python_loop``       — the seed repo's loop: one jitted step per batch;
  * ``engine_scan``       — sequential strategy, whole-epoch scan;
  * ``engine_scan_chunk`` — sequential strategy, 10-step chunks;
  * ``engine_sync_mesh``  — the mesh strategy (1-device mesh here: measures
    placement overhead, not parallel speedup);
  * ``engine_scan_chunk10_guarded`` — chunk-10 with the resilience layer's
    non-finite guard compiled in (halt policy off).

The guard is sold as near-free (the hot scan body is unchanged; one
finiteness reduction per chunk, one scalar fetch per window of chunks,
and poisoned windows replay from a backup), so the bench *gates* it:
unguarded/guarded single-epoch runs are timed in interleaved pairs,
``guard_overhead_frac`` is the median per-pair ratio minus one, and the
section raises if it exceeds ``GUARD_OVERHEAD_LIMIT`` (5% steps/sec).

``run(json_path=...)`` dumps machine-readable records (plus the headline
``speedup_scan_vs_python`` and ``guard_overhead_frac``) so the
training-throughput trajectory is tracked across PRs the same way
BENCH_kernels.json tracks kernels.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ssl_loss import SSLHyper
from repro.models.dnn import DNNConfig, init_dnn
from repro.optim import adagrad, constant_lr
from repro.train.engine import Engine, TrainState, data_mesh, lift_step
from repro.train.train_step import dnn_ssl_step

CFG = DNNConfig(input_dim=64, hidden_dim=128, n_hidden=2, n_classes=10,
                dropout=0.0)
HYPER = SSLHyper(1.0, 1e-4, 1e-5)
B = 128          # concatenated meta-batch rows
LR = 1e-3
GUARD_OVERHEAD_LIMIT = 0.05      # non-finite guard must stay under 5%


def _make_batches(n_steps: int, seed: int = 0) -> list[dict]:
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_steps):
        W = np.abs(rng.normal(size=(1, B, B))).astype(np.float32) / B
        out.append({
            "x": rng.normal(size=(1, B, CFG.input_dim)).astype(np.float32),
            "y": rng.integers(0, CFG.n_classes, (1, B)).astype(np.int32),
            "label_mask": (rng.random((1, B)) < 0.1).astype(np.float32),
            "W": (W + np.swapaxes(W, 1, 2)) / 2,
            "valid": np.ones((1, B), bool),
        })
    return out


def _median_epoch_seconds(epoch_times: list[float]) -> float:
    return float(np.median(epoch_times))


def _time_python_loop(batches: list[dict], n_epochs: int) -> float:
    """The seed trainer's structure: host loop, one jitted call per step."""
    opt = adagrad()
    params = init_dnn(CFG, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    step_fn = jax.jit(
        lambda p, s, b, lr: dnn_ssl_step(p, s, b, cfg=CFG, hyper=HYPER,
                                         opt=opt, lr=lr, pairwise=None))
    lr = jnp.float32(LR)
    times = []
    for epoch in range(n_epochs + 1):           # epoch 0 = compile warmup
        t0 = time.perf_counter()
        ms = []
        for batch in batches:
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, jb, lr)
            ms.append(metrics)
        _ = [float(m["loss/total"]) for m in ms]   # block, as the seed did
        if epoch:
            times.append(time.perf_counter() - t0)
    return _median_epoch_seconds(times)


def _time_engine(batches: list[dict], n_epochs: int, *, strategy: str,
                 scan_chunk: int, resilience=None) -> float:
    opt = adagrad()
    params = init_dnn(CFG, jax.random.PRNGKey(0))
    state = TrainState.create(params, opt.init(params), jax.random.PRNGKey(0))

    step_fn = lift_step(
        lambda p, o, batch, lr: dnn_ssl_step(p, o, batch, cfg=CFG,
                                             hyper=HYPER, opt=opt, lr=lr,
                                             pairwise=None))

    mesh = data_mesh(1) if strategy == "sync_mesh" else None
    engine = Engine(step_fn, strategy=strategy, mesh=mesh,
                    scan_chunk=scan_chunk, prefetch=2,
                    resilience=resilience)
    res = engine.run(lambda: iter(batches), state=state,
                     n_epochs=n_epochs + 1, lr_schedule=constant_lr(LR))
    return _median_epoch_seconds([r["seconds"] for r in res.history[1:]])


def _guard_overhead(batches: list[dict], n_epochs: int,
                    pairs: int = 8) -> tuple[float, float, float]:
    """Interleaved unguarded/guarded timing pairs at the chunk-10 shape.

    Both engines are built once (compiled code reused across samples),
    then single-epoch runs alternate off/on; the gate statistic is the
    **median per-pair ratio** minus one.  Adjacent-in-time pairing cancels
    slow machine drift and the median tames transient spikes — a real
    regression shows up in most pairs, one noisy neighbor does not.
    """
    from repro.api.config import ResilienceConfig

    opt = adagrad()
    step_fn = lift_step(
        lambda p, o, batch, lr: dnn_ssl_step(p, o, batch, cfg=CFG,
                                             hyper=HYPER, opt=opt, lr=lr,
                                             pairwise=None))
    engines = {
        "off": Engine(step_fn, strategy="sequential", scan_chunk=10,
                      prefetch=2),
        "on": Engine(step_fn, strategy="sequential", scan_chunk=10,
                     prefetch=2,
                     resilience=ResilienceConfig(nonfinite_guard=True)),
    }

    def epoch_seconds(which: str) -> float:
        params = init_dnn(CFG, jax.random.PRNGKey(0))
        state = TrainState.create(params, opt.init(params),
                                  jax.random.PRNGKey(0))
        res = engines[which].run(lambda: iter(batches), state=state,
                                 n_epochs=1, lr_schedule=constant_lr(LR))
        return res.history[0]["seconds"]

    for which in engines:               # compile warmup, not timed
        epoch_seconds(which)
    samples = [(epoch_seconds("off"), epoch_seconds("on"))
               for _ in range(pairs)]
    overhead = float(np.median([on / off for off, on in samples])) - 1.0
    return (min(off for off, _ in samples),
            min(on for _, on in samples), overhead)


def run(quick: bool = True, json_path: str | None = None) -> list[str]:
    n_steps = 100 if quick else 300
    n_epochs = 3 if quick else 5
    batches = _make_batches(n_steps)
    variants = [
        ("python_loop", lambda: _time_python_loop(batches, n_epochs)),
        ("engine_scan", lambda: _time_engine(batches, n_epochs,
                                             strategy="sequential",
                                             scan_chunk=0)),
        ("engine_scan_chunk10", lambda: _time_engine(batches, n_epochs,
                                                     strategy="sequential",
                                                     scan_chunk=10)),
        ("engine_sync_mesh", lambda: _time_engine(batches, n_epochs,
                                                  strategy="sync_mesh",
                                                  scan_chunk=0)),
    ]
    records, rows = [], []
    for name, fn in variants:
        secs = fn()
        sps = n_steps / secs
        records.append({"name": name, "epoch_seconds": secs,
                        "steps_per_sec": sps, "n_steps": n_steps,
                        "batch_rows": B, "hidden_dim": CFG.hidden_dim,
                        "backend": jax.default_backend()})
        rows.append(f"train/{name},{secs / n_steps * 1e6:.1f},"
                    f"steps_per_sec={sps:.1f}")
    _, guarded_secs, overhead = _guard_overhead(batches, n_epochs)
    records.append({"name": "engine_scan_chunk10_guarded",
                    "epoch_seconds": guarded_secs,
                    "steps_per_sec": n_steps / guarded_secs,
                    "n_steps": n_steps, "batch_rows": B,
                    "hidden_dim": CFG.hidden_dim,
                    "backend": jax.default_backend()})
    rows.append(f"train/engine_scan_chunk10_guarded,"
                f"{guarded_secs / n_steps * 1e6:.1f},"
                f"guard_overhead={overhead * 100:.1f}%")
    by_name = {r["name"]: r for r in records}
    speedup = (by_name["engine_scan"]["steps_per_sec"]
               / by_name["python_loop"]["steps_per_sec"])
    rows.append(f"train/speedup_scan_vs_python,,{speedup:.2f}x")
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"records": records,
                       "speedup_scan_vs_python": speedup,
                       "guard_overhead_frac": overhead}, f, indent=2)
    if overhead > GUARD_OVERHEAD_LIMIT:
        raise RuntimeError(
            f"non-finite guard costs {overhead * 100:.1f}% steps/sec at the "
            f"chunk-10 shape (limit {GUARD_OVERHEAD_LIMIT * 100:.0f}%) — "
            "the guard must stay effectively free")
    return rows
