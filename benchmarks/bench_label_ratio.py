"""Paper Fig. 3a: test accuracy vs label ratio, SSL vs fully-supervised.

The paper's headline claim: graph-regularized SSL significantly beats the
fully-supervised baseline when labels are scarce, and converges to it as the
ratio approaches 100%.  Ratios follow §3 ({2, 5, 10, 30, 50, 100}%; quick
mode uses {2, 10, 50}%).
"""
from __future__ import annotations

import numpy as np

from repro.core import SSLHyper
from repro.data import MetaBatchPipeline, drop_labels
from repro.models.dnn import DNNConfig
from repro.train import train_dnn_ssl

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan = corpus_and_graph()
    ratios = [0.02, 0.10, 0.50] if quick else [0.02, 0.05, 0.10, 0.30, 0.50,
                                               1.00]
    epochs = 10 if quick else 20
    cfg = DNNConfig(input_dim=128, hidden_dim=512, n_hidden=3,
                    n_classes=corpus.n_classes, dropout=0.0)
    rows = []
    for ratio in ratios:
        labeled = drop_labels(corpus, ratio, seed=1)
        pipe = MetaBatchPipeline(labeled, graph, plan, n_workers=1, seed=0)
        accs = {}
        for name, hyper in [("ssl", SSLHyper(1.0, 1e-4, 1e-5)),
                            ("supervised", SSLHyper(0.0, 0.0, 1e-5))]:
            res = train_dnn_ssl(pipe.epoch, cfg=cfg, hyper=hyper,
                                n_epochs=epochs, dropout=0.0, base_lr=1e-2,
                                eval_data=test, seed=0)
            accs[name] = max(h["eval/acc"] for h in res.history)
            secs = sum(h["seconds"] for h in res.history)
            rows.append(
                f"fig3a/{name}@{ratio:.2f},{secs*1e6/epochs:.0f},"
                f"acc={accs[name]:.4f}")
        rows.append(
            f"fig3a/ssl_gain@{ratio:.2f},0,"
            f"delta={accs['ssl']-accs['supervised']:+.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
