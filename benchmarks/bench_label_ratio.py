"""Paper Fig. 3a: test accuracy vs label ratio, SSL vs fully-supervised.

The paper's headline claim: graph-regularized SSL significantly beats the
fully-supervised baseline when labels are scarce, and converges to it as the
ratio approaches 100%.  Ratios follow §3 ({2, 5, 10, 30, 50, 100}%; quick
mode uses {2, 10, 50}%).  Each point is one ``repro.api.Experiment`` sharing
the corpus, graph and meta-batch plan across the grid.
"""
from __future__ import annotations

import dataclasses

from repro.api import (BatchConfig, Experiment, ExperimentConfig,
                       ObjectiveConfig, TrainConfig)
from repro.data import drop_labels

from .common import corpus_and_graph


def run(quick: bool = True) -> list[str]:
    corpus, test, graph, plan = corpus_and_graph()
    ratios = [0.02, 0.10, 0.50] if quick else [0.02, 0.05, 0.10, 0.30, 0.50,
                                               1.00]
    epochs = 10 if quick else 20
    base = ExperimentConfig(
        batch=BatchConfig(batch_size=512),
        train=TrainConfig(n_epochs=epochs, base_lr=1e-2, dropout=0.0,
                          hidden_dim=512, n_hidden=3))
    objectives = {
        "ssl": ObjectiveConfig(gamma=1.0, kappa=1e-4, weight_decay=1e-5),
        "supervised": ObjectiveConfig(gamma=0.0, kappa=0.0,
                                      weight_decay=1e-5),
    }
    rows = []
    for ratio in ratios:
        labeled = drop_labels(corpus, ratio, seed=1)
        accs = {}
        for name, obj in objectives.items():
            cfg = dataclasses.replace(base, name=name, objective=obj)
            res = Experiment(cfg, corpus=labeled, eval_data=test,
                             graph=graph, plan=plan).run()
            accs[name] = res.best("eval/acc")
            secs = sum(h["seconds"] for h in res.history)
            rows.append(
                f"fig3a/{name}@{ratio:.2f},{secs*1e6/epochs:.0f},"
                f"acc={accs[name]:.4f}")
        rows.append(
            f"fig3a/ssl_gain@{ratio:.2f},0,"
            f"delta={accs['ssl']-accs['supervised']:+.4f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
