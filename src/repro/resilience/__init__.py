"""Fault injection + recovery for the distributed training stack.

``faults``     — seeded, deterministic :class:`FaultPlan`/:class:`FaultInjector`
                 covering all five injection sites (poisoned batch, prefetch
                 crash/hang, replan failure, corrupt checkpoint, over-stale
                 async worker).
``guard``      — the in-scan non-finite guard primitives the engine uses to
                 skip poisoned updates (and halt after K consecutive skips).
``supervisor`` — bounded-retry/backoff/hang-timeout wrapper for host-side
                 background work (prefetch producer, replan builder).
``chaos``      — the end-to-end chaos driver behind the CI smoke step
                 (``python -m repro.resilience.chaos``); imported lazily to
                 keep this package free of ``repro.api`` import cycles.
"""
from repro.resilience.faults import (FaultEvent, FaultInjector, FaultPlan,
                                     InjectedFault, SITES)
from repro.resilience.guard import NonFiniteHaltError, all_finite, guard_init
from repro.resilience.supervisor import (RetryPolicy, Supervisor,
                                         SupervisorTimeout)

__all__ = [
    "SITES",
    "FaultEvent",
    "FaultPlan",
    "FaultInjector",
    "InjectedFault",
    "NonFiniteHaltError",
    "all_finite",
    "guard_init",
    "RetryPolicy",
    "Supervisor",
    "SupervisorTimeout",
]
