"""End-to-end chaos driver: one seeded run, faults at every site.

``run_chaos`` builds a small but real experiment — async_ps parameter
server (k=3 workers), streaming meta-batch pipeline with per-epoch
re-partitioning, non-finite guard, per-epoch checkpoints — and drives it
through a fault plan that hits **all five** injection sites:

  * a NaN- and an inf-poisoned batch (guard must skip exactly those steps),
  * a prefetch-producer crash and a hang (supervisor retry + watchdog),
  * a replan failure (supervisor retry; degrade path stays bit-stable),
  * a corrupted checkpoint — the one LATEST points at (resume must fall
    back to the newest valid checkpoint),
  * a dead async worker (snapshot aged past max_staleness; drop_overstale
    must zero its gradient and renormalize survivors).

Three phases prove the recovery contract:

  A. *uninterrupted* — the full plan, epochs 0..n-1 straight through;
  B. *interrupted*   — a fresh injector with the SAME plan, stopped right
     after the corrupted checkpoint is written;
  C. *resume*        — a fresh injector with the SAME plan again,
     ``resume=True``: LATEST's target is corrupt, the engine falls back
     one checkpoint and replays — re-firing the replayed epochs' events —
     to the same final epoch.

The acceptance assertions (also in ``tests/test_resilience.py``):
every phase completes without intervention, the guard's skipped-step
count equals the planned poisoned-batch count exactly, and phase C's
final parameters are **bit-identical** to phase A's.

CLI (the CI chaos-smoke step)::

    python -m repro.resilience.chaos --seed 7 --report CHAOS_report.json
"""
from __future__ import annotations

import argparse
import json
import sys
import tempfile

import jax
import numpy as np

from repro.resilience.faults import FaultEvent, FaultInjector, FaultPlan

__all__ = ["chaos_config", "chaos_plan", "run_chaos", "main"]

N_EPOCHS = 4
CORRUPT_AT = 2          # checkpoint (completed-epoch count) to corrupt


def chaos_config(workdir: str, *, seed: int = 7):
    """The chaos experiment: small corpus, async_ps k=3, streaming
    re-partitioning every epoch, guard + checksums + drop_overstale on,
    a checkpoint every epoch, supervised retries with a hang watchdog."""
    from repro.api import (BatchConfig, DataConfig, ExecutionConfig,
                           ExperimentConfig, ObjectiveConfig,
                           RepartitionConfig, ResilienceConfig, TrainConfig)
    return ExperimentConfig(
        name="chaos",
        data=DataConfig(n=400, n_classes=8, input_dim=32, manifold_dim=6,
                        label_ratio=0.2, test_fraction=0.0, seed=seed),
        batch=BatchConfig(pipeline="metabatch_stream", batch_size=64),
        repartition=RepartitionConfig(every_n_epochs=1, seed=seed),
        objective=ObjectiveConfig(pairwise="ref"),
        train=TrainConfig(n_epochs=N_EPOCHS, n_workers=3, dropout=0.0,
                          seed=seed),
        execution=ExecutionConfig(strategy="async_ps", scan_chunk=2,
                                  prefetch=2, max_staleness=2,
                                  checkpoint_every=1,
                                  checkpoint_dir=workdir),
        resilience=ResilienceConfig(nonfinite_guard=True,
                                    checkpoint_checksums=True,
                                    max_retries=2, backoff_base=0.0,
                                    backoff_max=0.0, hang_timeout=0.25,
                                    drop_overstale=True, seed=seed))


def chaos_plan(seed: int, *, steps_per_epoch: int,
               chunks_per_epoch: int) -> FaultPlan:
    """≥1 event per site, coordinates a pure function of ``seed``.  The
    corrupted checkpoint is pinned at ``CORRUPT_AT`` (so the resume phase
    has both a corrupt LATEST target and epochs left to replay); other
    coordinates are drawn from the run grid."""
    import dataclasses

    rng = np.random.default_rng([int(seed), 0xC4A05])

    def ep(lo=0):   # an epoch with training still ahead of it
        return int(rng.integers(lo, N_EPOCHS))

    candidates = (
        FaultEvent("batch", epoch=ep(), step=int(
            rng.integers(0, steps_per_epoch)), mode="nan"),
        FaultEvent("batch", epoch=ep(), step=int(
            rng.integers(0, steps_per_epoch)), mode="inf"),
        FaultEvent("prefetch", epoch=ep(), step=int(
            rng.integers(0, chunks_per_epoch)), mode="crash"),
        FaultEvent("prefetch", epoch=ep(), step=int(
            rng.integers(0, chunks_per_epoch)), mode="hang", arg=0.6),
        FaultEvent("replan", epoch=ep(lo=1), mode="fail"),
        FaultEvent("checkpoint", epoch=CORRUPT_AT, mode="truncate"),
        FaultEvent("worker", epoch=ep(), step=int(
            rng.integers(0, chunks_per_epoch)), mode="dead",
            worker=int(rng.integers(0, 3))),
    )
    # Same-site draws can collide on (epoch, step) — shift deterministically
    # to the next free step so any seed yields a valid (unique-key) plan.
    grids = {"batch": steps_per_epoch, "prefetch": chunks_per_epoch}
    seen, events = set(), []
    for e in candidates:
        while e.key() in seen:
            g = grids.get(e.site, 1)
            e = dataclasses.replace(
                e, step=(e.step + 1) % g,
                epoch=e.epoch if g > 1 else e.epoch % N_EPOCHS + 1)
        seen.add(e.key())
        events.append(e)
    return FaultPlan(events=tuple(events))


def _run_phase(cfg, plan, *, shared, n_epochs=None, resume=False):
    """One experiment run with a FRESH injector armed from ``plan`` (so
    resume replays re-fire the replayed epochs' events identically)."""
    import dataclasses

    from repro.api import Experiment
    if n_epochs is not None or resume:
        cfg = dataclasses.replace(
            cfg,
            train=dataclasses.replace(
                cfg.train, n_epochs=n_epochs or cfg.train.n_epochs),
            execution=dataclasses.replace(cfg.execution, resume=resume))
    injector = FaultInjector(plan)
    exp = Experiment(cfg, injector=injector, **shared)
    result = exp.run()
    return result, injector


def _params_equal(a, b) -> bool:
    leaves_a = jax.tree_util.tree_leaves(jax.device_get(a))
    leaves_b = jax.tree_util.tree_leaves(jax.device_get(b))
    return len(leaves_a) == len(leaves_b) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(leaves_a, leaves_b))


def run_chaos(seed: int = 7, *, workdir: str | None = None) -> dict:
    """Run the three phases; return the machine-readable chaos report."""
    import os

    from repro.api import Experiment

    tmp = None
    if workdir is None:
        tmp = tempfile.TemporaryDirectory(prefix="chaos-")
        workdir = tmp.name
    try:
        dir_a = os.path.join(workdir, "uninterrupted")
        dir_b = os.path.join(workdir, "interrupted")
        cfg_a = chaos_config(dir_a, seed=seed)
        # Build data/graph/plan once and share across phases: phase
        # equality must come from determinism of the *training* stack, not
        # from accidentally comparing different corpora.
        base = Experiment(cfg_a).build()
        shared = {"corpus": base.corpus, "eval_data": base.eval_data,
                  "graph": base.graph, "plan": base.plan,
                  "hierarchy_cache": base.hierarchy_cache}
        steps = base.plan.n_meta            # async_ps: 1-worker batches
        chunks = -(-steps // cfg_a.execution.scan_chunk)
        plan = chaos_plan(seed, steps_per_epoch=steps,
                          chunks_per_epoch=chunks)

        res_a, inj_a = _run_phase(cfg_a, plan, shared=shared)
        cfg_b = chaos_config(dir_b, seed=seed)
        res_b, inj_b = _run_phase(cfg_b, plan, shared=shared,
                                  n_epochs=CORRUPT_AT)
        res_c, inj_c = _run_phase(cfg_b, plan, shared=shared, resume=True)

        planned_skips = sum(
            1 for e in plan.events if e.site == "batch")
        skipped_a = int(res_a.history[-1]["guard/skipped_total"])
        skipped_c = int(res_c.history[-1]["guard/skipped_total"])
        bit_identical = _params_equal(res_a.params, res_c.params)
        all_sites_fired = set(
            f["site"] for f in inj_a.fired()) == set(
            e.site for e in plan.events)
        report = {
            "seed": seed,
            "plan": plan.to_json(),
            "phases": {
                "uninterrupted": {"epochs": len(res_a.history),
                                  "fired": inj_a.fired(),
                                  "skipped_total": skipped_a},
                "interrupted": {"epochs": len(res_b.history),
                                "fired": inj_b.fired()},
                "resume": {"epochs": len(res_c.history),
                           "fired": inj_c.fired(),
                           "skipped_total": skipped_c},
            },
            "planned_poisoned_batches": planned_skips,
            "all_sites_fired": all_sites_fired,
            "skip_counts_match": (skipped_a == planned_skips
                                  and skipped_c == planned_skips),
            "resume_bit_identical": bit_identical,
        }
        report["ok"] = bool(all_sites_fired
                            and report["skip_counts_match"]
                            and bit_identical
                            and len(res_a.history) == N_EPOCHS
                            and len(res_c.history) == N_EPOCHS)
        return report
    finally:
        if tmp is not None:
            tmp.cleanup()


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.resilience.chaos",
        description="Seeded chaos run: inject faults at every site, "
                    "assert recovery + bit-identical corrupt-resume.")
    parser.add_argument("--seed", type=int, default=7)
    parser.add_argument("--report", default="CHAOS_report.json")
    parser.add_argument("--workdir", default=None,
                        help="checkpoint scratch dir (default: a tempdir)")
    args = parser.parse_args(argv)
    report = run_chaos(args.seed, workdir=args.workdir)
    with open(args.report, "w") as f:
        json.dump(report, f, indent=2)
    fired = sum(len(p["fired"]) for p in report["phases"].values())
    print(f"chaos seed={args.seed}: {fired} faults fired across "
          f"{len(report['plan'])} planned sites; "
          f"skip_counts_match={report['skip_counts_match']} "
          f"resume_bit_identical={report['resume_bit_identical']} "
          f"-> {args.report}")
    if not report["ok"]:
        print("chaos run FAILED acceptance checks", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
