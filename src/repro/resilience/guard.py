"""In-scan non-finite guard primitives.

The engine runs a two-speed guard.  The hot path scans the *plain* step
body — zero per-step additions — and runs :func:`all_finite` once per
chunk over the final carry and the stacked per-step metrics (the stacking
gives per-step visibility, so a transient non-finite the carry later
masks still trips it), folding the result into a ``tainted`` flag.  The
run loop fetches the guard scalars once per *window* of chunks; only a
tainted window (the rare case) is replayed from its window-start backup
with the *strict* body, which keeps the previous carry (params,
opt_state, rng, step — all of it) on each poisoned step, as if the batch
had never been drawn, and recomputes the exact skip accounting.  Clean
windows therefore pay one finiteness reduction per chunk and one scalar
fetch per window.  The guard state threaded through the carry is::

    (skipped_total, consecutive, worst_consecutive, tainted)

three int32 scalars plus a bool.  ``skipped_total`` lands in the epoch
history, ``worst_consecutive`` is a running maximum the engine checks on
host at window boundaries to realize the halt-after-K-consecutive policy
(:class:`NonFiniteHaltError`) without a per-step device sync.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["NonFiniteHaltError", "all_finite", "guard_init"]


class NonFiniteHaltError(RuntimeError):
    """Raised by the engine when ``halt_after_consecutive`` or more steps
    in a row produced a non-finite update (the data or the optimization is
    broken, not one unlucky batch)."""


def guard_init():
    """Fresh ``(skipped_total, consecutive, worst_consecutive, tainted)``
    state.  Four *distinct* arrays: the engine donates the carry, and
    donating one aliased buffer twice is an XLA error."""
    return (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32), jnp.zeros((), jnp.bool_))


def all_finite(tree) -> jax.Array:
    """Scalar bool: every inexact-dtype leaf of ``tree`` is fully finite.

    Integer/bool leaves (step counters, ages, schedules) are skipped —
    they cannot hold NaN/inf and ``jnp.isfinite`` rejects some of them.

    """
    checks = [jnp.all(jnp.isfinite(leaf))
              for leaf in jax.tree_util.tree_leaves(tree)
              if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.inexact)]
    if not checks:
        return jnp.bool_(True)
    return functools.reduce(jnp.logical_and, checks)
