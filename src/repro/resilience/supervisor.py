"""Thread supervision: bounded retries, deterministic backoff, hang timeouts.

The training stack runs three kinds of host-side background work — the
engine's prefetch producer, the ``MetaBatchStream`` replan builder, and
checkpoint I/O — and before this module a single exception or hang in any
of them either killed the run or stalled it forever.  :class:`Supervisor`
wraps such calls with a retry policy:

  * **bounded retries** — up to ``max_retries`` re-attempts of the failed
    call; the last exception is re-raised when they exhaust, so callers
    keep their existing degrade path (the stream keeps the old plan, the
    engine surfaces the prefetch error);
  * **exponential backoff with deterministic jitter** — the delay before
    attempt ``a`` is ``min(backoff_max, backoff_base·2^a)`` scaled by a
    jitter factor that is a pure function of ``(seed, key, a)``, so two
    runs with the same seed sleep the same schedule (bit-reproducible
    chaos tests included);
  * **hang timeout** — with ``hang_timeout`` set, each attempt runs on a
    disposable daemon worker thread and :class:`SupervisorTimeout` fires
    if it does not finish in time (the hung attempt is abandoned; the
    retry runs clean).

Every attempt outcome is recorded (under a lock — the supervisor is shared
across producer/builder threads) and exposed via :meth:`Supervisor.events`
for the chaos report.
"""
from __future__ import annotations

import queue
import threading
import time
import zlib
from dataclasses import dataclass

import numpy as np

__all__ = ["RetryPolicy", "Supervisor", "SupervisorTimeout"]


class SupervisorTimeout(RuntimeError):
    """An attempt exceeded the policy's ``hang_timeout`` and was abandoned."""


@dataclass(frozen=True)
class RetryPolicy:
    """How a supervised call is retried.  ``max_retries=0`` means one
    attempt, no retry; ``hang_timeout=None`` disables the watchdog (the
    call runs inline on the calling thread — the fast path)."""

    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_max: float = 2.0
    jitter: float = 0.5          # delay is scaled by 1 + jitter·u, u ∈ [0,1)
    hang_timeout: float | None = None
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0 or self.backoff_max < self.backoff_base:
            raise ValueError(
                f"need 0 <= backoff_base <= backoff_max, got "
                f"({self.backoff_base}, {self.backoff_max})")
        if not 0 <= self.jitter:
            raise ValueError(f"jitter must be >= 0, got {self.jitter}")
        if self.hang_timeout is not None and self.hang_timeout <= 0:
            raise ValueError(
                f"hang_timeout must be positive or None, "
                f"got {self.hang_timeout}")

    def delay(self, key: str, attempt: int) -> float:
        """Backoff before re-attempt ``attempt`` (0-based) of call ``key``
        — a pure function of ``(seed, key, attempt)``."""
        base = min(self.backoff_max, self.backoff_base * (2.0 ** attempt))
        u = np.random.default_rng(
            [self.seed, zlib.crc32(key.encode()), attempt]).random()
        return float(base * (1.0 + self.jitter * u))


class Supervisor:
    """Applies a :class:`RetryPolicy` to host-side calls.

    One supervisor may be shared by several threads (the engine hands the
    same instance to every epoch's prefetch producer); the attempt log is
    lock-published.
    """

    def __init__(self, policy: RetryPolicy | None = None, *,
                 name: str = "supervisor", sleep=time.sleep):
        self.policy = policy or RetryPolicy()
        self.name = name
        self._sleep = sleep
        self._lock = threading.Lock()
        self._events: list[dict] = []

    @classmethod
    def from_config(cls, resilience, *, name: str = "supervisor",
                    sleep=time.sleep) -> "Supervisor":
        """Build from any object with ResilienceConfig-shaped attributes."""
        return cls(RetryPolicy(
            max_retries=int(getattr(resilience, "max_retries", 3)),
            backoff_base=float(getattr(resilience, "backoff_base", 0.05)),
            backoff_max=float(getattr(resilience, "backoff_max", 2.0)),
            hang_timeout=getattr(resilience, "hang_timeout", None),
            seed=int(getattr(resilience, "seed", 0))),
            name=name, sleep=sleep)

    # ------------------------------------------------------------- attempts
    def _attempt(self, fn, args, kwargs):
        timeout = self.policy.hang_timeout
        if timeout is None:
            return fn(*args, **kwargs)
        out: queue.Queue = queue.Queue(maxsize=1)

        def work():
            try:
                out.put(("ok", fn(*args, **kwargs)))
            except BaseException as e:  # noqa: BLE001 — re-raised below
                out.put(("err", e))

        t = threading.Thread(target=work, daemon=True,
                             name=f"{self.name}-attempt")
        t.start()
        try:
            kind, value = out.get(timeout=timeout)
        except queue.Empty:
            raise SupervisorTimeout(
                f"{self.name}: call exceeded hang_timeout={timeout}s; "
                "abandoning the attempt") from None
        if kind == "err":
            raise value
        return value

    def _record(self, key: str, attempt: int, status: str,
                error: BaseException | None = None,
                delay: float | None = None) -> None:
        row = {"key": key, "attempt": attempt, "status": status}
        if error is not None:
            row["error"] = f"{type(error).__name__}: {error}"
        if delay is not None:
            row["delay"] = delay
        with self._lock:
            self._events.append(row)

    def events(self) -> list[dict]:
        """Snapshot of the attempt log (chaos report material)."""
        with self._lock:
            return [dict(e) for e in self._events]

    # ----------------------------------------------------------------- call
    def call(self, fn, *args, key: str = "", retryable=(Exception,), **kw):
        """Run ``fn(*args, **kw)`` under the policy.

        Exceptions matching ``retryable`` (and timeouts) trigger backoff +
        retry; when retries exhaust, the last exception is re-raised so the
        caller's own degrade path takes over.  Non-retryable exceptions
        (``KeyboardInterrupt`` et al.) propagate immediately.
        """
        key = key or getattr(fn, "__name__", "call")
        retryable = tuple(retryable) + (SupervisorTimeout,)
        last: BaseException | None = None
        for attempt in range(self.policy.max_retries + 1):
            try:
                result = self._attempt(fn, args, kw)
            except retryable as e:
                last = e
                if attempt == self.policy.max_retries:
                    self._record(key, attempt, "exhausted", error=e)
                    raise
                delay = self.policy.delay(key, attempt)
                self._record(key, attempt, "retrying", error=e, delay=delay)
                if delay > 0:
                    self._sleep(delay)
            else:
                if attempt or last is not None:
                    self._record(key, attempt, "recovered")
                return result
        raise AssertionError("unreachable")  # pragma: no cover
