"""Seeded, deterministic fault injection for the training stack.

A :class:`FaultPlan` is a pure function of its seed: the same ``(seed,
n_epochs, steps_per_epoch)`` always yields the same schedule of
:class:`FaultEvent`\\ s, so a chaos run is exactly reproducible — including
across a checkpoint resume, where a *fresh* injector built from the same
plan re-arms every event and the replayed epochs re-fire identically.

Five injection sites, one per failure mode the resilience layer defends:

========== ==================== =========================================
site       event coordinates    what fires
========== ==================== =========================================
batch      (epoch, step)        batch tensor filled with NaN/inf → the
                                step's gradients go non-finite (exercises
                                the in-scan guard)
prefetch   (epoch, chunk)       the producer's device-put raises
                                ``InjectedFault`` (mode "crash") or stalls
                                ``arg`` seconds then raises (mode "hang",
                                for the supervisor's watchdog)
replan     (epoch,)             ``MetaBatchStream``'s partitioner raises
                                for that target epoch
checkpoint (epoch,)             the just-saved ``.npz`` is truncated to
                                half its bytes or gets a flipped byte
worker     (epoch, chunk)       an async_ps worker's snapshot age is
                                pushed past ``max_staleness`` (dead /
                                straggler worker)
========== ==================== =========================================

Events are *consumed on fire* under a lock (hooks are called from the
engine thread, the prefetch producer, and replan builders concurrently);
a supervisor retry of the same call therefore succeeds — exactly the
transient-fault shape the defenses target.  :meth:`FaultInjector.report`
returns the plan / fired / pending ledger for the chaos artifact.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time

import numpy as np

__all__ = ["SITES", "FaultEvent", "FaultPlan", "FaultInjector",
           "InjectedFault"]

SITES = ("batch", "prefetch", "replan", "checkpoint", "worker")


class InjectedFault(RuntimeError):
    """The exception every injected crash raises — chaos tests assert on
    this type so a real bug can never masquerade as an injection."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``step`` is the epoch-local batch index for
    ``batch`` events, the epoch-local chunk index for ``prefetch`` /
    ``worker`` events, and 0 for per-epoch sites."""

    site: str
    epoch: int
    step: int = 0
    mode: str = ""
    arg: float = 0.0
    worker: int = 0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"expected one of {SITES}")

    def key(self) -> tuple:
        return (self.site, self.epoch, self.step)


_DEFAULT_MODES = {
    "batch": ("nan", "inf"),
    "prefetch": ("crash",),
    "replan": ("fail",),
    "checkpoint": ("truncate", "bitflip"),
    "worker": ("dead",),
}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable schedule of events.  Build explicitly from events, or
    derive deterministically with :meth:`from_seed`."""

    events: tuple[FaultEvent, ...]

    @classmethod
    def from_seed(cls, seed: int, *, n_epochs: int, steps_per_epoch: int,
                  sites=SITES, per_site: int = 1) -> "FaultPlan":
        """``per_site`` events per site, coordinates drawn without
        replacement from the run's (epoch, step) grid — a pure function of
        ``seed`` and the shape arguments.

        ``checkpoint`` events use epochs 1..n_epochs (a checkpoint saved
        *after* epoch e is labelled e); everything else uses 0-based
        epochs.  ``batch``/``prefetch``/``worker`` steps are drawn from
        ``steps_per_epoch`` (callers pass the chunk count for the chunk-
        indexed sites).
        """
        if n_epochs < 1 or steps_per_epoch < 1:
            raise ValueError("need n_epochs >= 1 and steps_per_epoch >= 1")
        events: list[FaultEvent] = []
        for site in sites:
            rng = np.random.default_rng([int(seed), SITES.index(site)])
            modes = _DEFAULT_MODES[site]
            per_epoch = steps_per_epoch if site in ("batch", "prefetch",
                                                    "worker") else 1
            grid = n_epochs * per_epoch
            picks = rng.choice(grid, size=min(per_site, grid), replace=False)
            for i, flat in enumerate(sorted(int(p) for p in picks)):
                epoch, step = divmod(flat, per_epoch)
                if site == "checkpoint":
                    epoch += 1          # labelled by completed-epoch count
                events.append(FaultEvent(
                    site=site, epoch=epoch, step=step,
                    mode=modes[i % len(modes)],
                    arg=0.0, worker=int(rng.integers(0, 8))))
        return cls(events=tuple(events))

    def for_site(self, site: str) -> tuple[FaultEvent, ...]:
        return tuple(e for e in self.events if e.site == site)

    def to_json(self) -> list[dict]:
        return [dataclasses.asdict(e) for e in self.events]


class FaultInjector:
    """Arms a :class:`FaultPlan` and exposes one hook per site.

    Thread-safe: the armed table and the fired ledger are only touched
    under ``_lock`` (engine thread + prefetch producer + replan builder
    all call in)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._armed = {e.key(): e for e in plan.events}
        if len(self._armed) != len(plan.events):
            raise ValueError("fault plan has colliding (site, epoch, step) "
                             "coordinates; events must be unique")
        self._fired: list[dict] = []

    # -------------------------------------------------------------- ledger
    def _take(self, site: str, epoch: int, step: int = 0,
              **detail) -> FaultEvent | None:
        with self._lock:
            ev = self._armed.pop((site, int(epoch), int(step)), None)
            if ev is not None:
                self._fired.append(
                    {**dataclasses.asdict(ev), **detail})
        return ev

    def fired(self) -> list[dict]:
        with self._lock:
            return [dict(e) for e in self._fired]

    def pending(self) -> list[FaultEvent]:
        with self._lock:
            return sorted(self._armed.values(),
                          key=lambda e: (e.epoch, e.step, e.site))

    def report(self) -> dict:
        return {"plan": self.plan.to_json(), "fired": self.fired(),
                "pending": [dataclasses.asdict(e) for e in self.pending()]}

    # --------------------------------------------------------------- hooks
    def take(self, site: str, *, epoch: int, step: int = 0
             ) -> FaultEvent | None:
        """Consume and return the event armed at these coordinates
        (``None`` when nothing is armed) — for callers that need the
        event's payload to apply (and possibly re-apply, e.g. on a guarded
        chunk replay) its effect themselves."""
        return self._take(site, epoch, step)

    def on_batch(self, batch: dict, *, epoch: int, step: int) -> dict:
        """Engine hook: poison this step's batch if an event is armed."""
        ev = self._take("batch", epoch, step)
        if ev is None:
            return batch
        out = dict(batch)
        key = "x" if "x" in out else next(
            (k for k, v in out.items()
             if np.issubdtype(np.asarray(v).dtype, np.floating)), None)
        if key is None:     # nothing poisonable — record and pass through
            return out
        arr = np.array(out[key], copy=True)
        arr[...] = np.nan if ev.mode != "inf" else np.inf
        out[key] = arr
        return out

    def wrap_put(self, put, *, epoch: int):
        """Wrap the prefetch producer's device-put.  The chunk index only
        advances on a *successful* put, so a supervisor retry of a failed
        chunk re-runs at the same coordinate (where the event is already
        consumed) and later events keep their planned positions."""
        state = {"i": 0}

        def injected_put(chunk):
            with self._lock:
                i = state["i"]
            ev = self._take("prefetch", epoch, i)
            if ev is not None:
                if ev.mode == "hang":
                    time.sleep(ev.arg or 1.0)
                raise InjectedFault(
                    f"injected prefetch {ev.mode} (epoch {epoch}, "
                    f"chunk {i})")
            out = put(chunk)
            with self._lock:
                state["i"] = i + 1
            return out

        return injected_put

    def maybe_fail(self, site: str, *, epoch: int, step: int = 0) -> None:
        """Raise :class:`InjectedFault` if an event is armed here (the
        replan hook; usable for any raise-style site)."""
        ev = self._take(site, epoch, step)
        if ev is not None:
            raise InjectedFault(
                f"injected {site} failure (epoch {epoch}, step {step})")

    def after_checkpoint(self, path: str, *, epoch: int) -> None:
        """Corrupt the just-written checkpoint file in place (simulated
        torn write / bit rot).  The checksum sidecar keeps the *good*
        digest, so verification must catch this on load."""
        ev = self._take("checkpoint", epoch, 0, path=os.path.basename(path))
        if ev is None:
            return
        size = os.path.getsize(path)
        if ev.mode == "bitflip":
            with open(path, "r+b") as f:
                f.seek(size // 2)
                byte = f.read(1)
                f.seek(size // 2)
                f.write(bytes([byte[0] ^ 0xFF]))
        else:
            os.truncate(path, size // 2)

    def before_chunk(self, strategy, carry, *, epoch: int, chunk: int):
        """Engine hook, called with the *strategy* carry before each chunk:
        pushes an async_ps worker's age past ``max_staleness`` when a
        ``worker`` event is armed.  Strategies opt in by exposing
        ``bump_age(carry, worker, amount)``; others are left untouched
        (the event stays armed and shows up as pending in the report)."""
        if not hasattr(strategy, "bump_age"):
            return carry
        ev = self._take("worker", epoch, chunk)
        if ev is None:
            return carry
        return strategy.bump_age(carry, ev.worker, ev.arg)
