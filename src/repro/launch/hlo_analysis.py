"""Roofline-term extraction from compiled dry-run artifacts.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a scanned
61-layer model under-reports FLOPs ~61×.  This module re-derives trip-aware
costs from the optimized HLO text:

  * parse every computation and build the call graph
    (``body=/condition=/calls=/to_apply=`` edges);
  * multiply each computation's execution count by its callers' counts and
    the ``known_trip_count`` annotation of while ops;
  * FLOPs:   2·prod(out_dims)·prod(contracting_dims) per ``dot`` (+conv),
             trip-weighted;
  * traffic: operand + output bytes of every materializing top-level op
    (fusions, dots, copies, collectives, scatter/gather, DUS) — a model of
    HBM traffic under XLA fusion (fusion internals are free);
  * collectives: bytes moved = max(Σ operand, Σ output) per collective op,
    trip-weighted, split by kind.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_COMP_DEF_RE = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(")
_OP_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALL_RE = re.compile(r"(body|condition|calls|to_apply)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_OPERAND_RE = re.compile(r"\(([^)]*)\)")
_KIND_RE = re.compile(
    r"^(?:\([^=]*\)|\S+)\s+"
    r"([a-z][a-z0-9\-]*(?:-start|-done)?)\(")

# Ops whose operands/outputs hit HBM (everything else assumed fused away).
# Layout/elementwise ops (transpose/reshape/broadcast/convert/...) are
# normally fused on TPU — counting them as HBM round-trips wildly overstates
# traffic, so only ops that genuinely materialize buffers are included.
_MATERIALIZING = {
    "fusion", "dot", "convolution", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "sort",
    "custom-call", "select-and-scatter", "reduce-window",
} | set(COLLECTIVE_OPS)
_FREE = {"bitcast", "parameter", "constant", "get-tuple-element", "tuple",
         "after-all", "partition-id", "replica-id"}


def _first_shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class HloCosts:
    flops: float
    traffic_bytes: float
    collective_bytes: float
    bytes_by_op: dict
    count_by_op: dict

    def as_dict(self):
        return dataclasses.asdict(self)


def analyze_hlo(hlo_text: str) -> HloCosts:  # noqa: C901 — one-pass parser
    lines = hlo_text.splitlines()
    # ---- pass 1: computations, ops, shapes, call edges -------------------
    comps: dict[str, list[tuple[str, str, str]]] = {}  # name -> [(op_name, kind, rest)]
    shape_of: dict[str, str] = {}
    call_edges: list[tuple[str, str, str, int]] = []   # (src, dst, via, trip)
    entry = None
    current = None
    for ln in lines:
        if ln and not ln.startswith(" "):
            m = _COMP_DEF_RE.match(ln)
            if m and ln.rstrip().endswith("{"):
                current = m.group(1)
                comps[current] = []
                if ln.startswith("ENTRY"):
                    entry = current
            continue
        if current is None:
            continue
        m = _OP_DEF_RE.match(ln)
        if not m:
            continue
        op_name, rest = m.group(1), m.group(2)
        shape_of[op_name] = rest.split(" ", 1)[0] if rest else ""
        km = _KIND_RE.match(rest)
        kind = km.group(1) if km else "unknown"
        comps[current].append((op_name, kind, rest))
        trip = 1
        tm = _TRIP_RE.search(rest)
        if tm:
            trip = int(tm.group(1))
        for via, dst in _CALL_RE.findall(rest):
            call_edges.append(
                (current, dst, via, trip if via in ("body", "condition") else 1))

    # ---- pass 2: execution multipliers via call-graph propagation --------
    mult: dict[str, float] = defaultdict(float)
    fusion_called: set[str] = set()
    if entry:
        mult[entry] = 1.0
    edges_from: dict[str, list[tuple[str, str, int]]] = defaultdict(list)
    for src, dst, via, trip in call_edges:
        edges_from[src].append((dst, via, trip))
        if via in ("calls", "to_apply"):
            fusion_called.add(dst)
    # topological-ish propagation (HLO call graphs are acyclic) — iterate to
    # fixpoint (#comps is small).
    for _ in range(64):
        changed = False
        new_mult = defaultdict(float)
        if entry:
            new_mult[entry] = 1.0
        for src, outs in edges_from.items():
            if mult[src] == 0:
                continue
            for dst, via, trip in outs:
                new_mult[dst] += mult[src] * trip
        if entry:
            new_mult[entry] = 1.0
        if dict(new_mult) != dict(mult):
            mult = new_mult
            changed = True
        if not changed:
            break

    # ---- pass 3: costs ----------------------------------------------------
    flops = 0.0
    traffic = 0.0
    coll_bytes = {k: 0.0 for k in COLLECTIVE_OPS}
    coll_count = {k: 0 for k in COLLECTIVE_OPS}

    def operand_names(rest: str) -> list[str]:
        """Operand op-names of ``kind(...)``.  Operands may be bare (``%p``)
        or carry their shape (``f32[64,64]{1,0} %get-tuple-element.4``);
        shapes contain commas, so split on the ``%`` sigil, not ``,``."""
        m = _OPERAND_RE.search(rest[rest.index("("):] if "(" in rest else "")
        if not m:
            return []
        return re.findall(r"%([\w\.\-]+)", m.group(1))

    def operand_bytes(rest: str) -> int:
        return sum(_first_shape_bytes(shape_of[name])
                   for name in operand_names(rest) if name in shape_of)

    for comp, ops in comps.items():
        w = mult.get(comp, 0.0)
        if w == 0.0:
            continue
        in_fusion = comp in fusion_called
        for op_name, kind, rest in ops:
            base = kind.replace("-start", "").replace("-done", "")
            if base == "dot":
                out_dims = _parse_dims(rest)
                cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
                names = operand_names(rest)
                lhs_name = names[0] if names else None
                contract = 1
                if cm and lhs_name and lhs_name in shape_of:
                    lhs_dims = _parse_dims(shape_of[lhs_name])
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            contract *= lhs_dims[int(d)]
                n_out = 1
                for d in out_dims:
                    n_out *= d
                flops += w * 2.0 * n_out * contract
            if base in COLLECTIVE_OPS and not kind.endswith("-done"):
                b = max(_first_shape_bytes(rest.split(" metadata")[0]
                                           .split("), ")[0]),
                        operand_bytes(rest))
                coll_bytes[base] += w * b
                coll_count[base] += 1
            if not in_fusion and base in _MATERIALIZING:
                traffic += w * (_first_shape_bytes(shape_of[op_name])
                                + operand_bytes(rest))
    return HloCosts(
        flops=flops, traffic_bytes=traffic,
        collective_bytes=sum(coll_bytes.values()),
        bytes_by_op=coll_bytes, count_by_op=coll_count)


def roofline_terms(flops: float, bytes_accessed: float,
                   collective_bytes: float, *, chips: int,
                   peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> dict:
    """The three §Roofline terms, in seconds (whole-step, all chips).

    flops/bytes are whole-program (all-chips) totals; dividing by
    chips×per-chip-rate gives the balanced per-step time of each resource.
    """
    compute_s = flops / (chips * peak_flops)
    memory_s = bytes_accessed / (chips * hbm_bw)
    collective_s = collective_bytes / (chips * ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant
    return terms
