"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination and record memory / cost / collective analysis.

The two os.environ lines below MUST run before any jax import (jax locks the
device count on first init); 512 placeholder host devices back the production
meshes (16×16 single pod, 2×16×16 multi-pod).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single --strategy dp
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import gzip
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, INPUT_SHAPES, config_for_shape, get_config
from repro.launch import hlo_analysis
from repro.launch.inputs import input_specs
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            strategy: str, ssl: bool = True,
            hlo_path: str | None = None) -> dict:
    """Lower + compile one combination; return the roofline record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    spec = input_specs(arch, shape_name, mesh, strategy, ssl=ssl)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(spec["fn"], donate_argnums=spec.get("donate", ()))
        lowered = jitted.lower(*spec["args"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    if hlo_path:
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    # Trip-aware per-chip costs (the SPMD module is the per-device program;
    # cost_analysis counts while bodies once — analyze_hlo fixes both).
    costs = hlo_analysis.analyze_hlo(hlo)

    terms = hlo_analysis.roofline_terms(
        costs.flops, costs.traffic_bytes, costs.collective_bytes, chips=1,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW)

    # Useful-FLOPs reference: 6·N_active·D for train, 2·N_active·B for decode.
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else 1)
    if shape.kind == "train":
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        model_flops = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        model_flops = 2.0 * n_active * tokens

    mem_rec = {}
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)

    model_flops_per_chip = model_flops / chips
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "strategy": strategy, "chips": int(chips),
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # Per-chip, trip-aware (see hlo_analysis):
        "flops_per_chip": costs.flops,
        "traffic_bytes_per_chip": costs.traffic_bytes,
        "collective_bytes_per_chip": costs.collective_bytes,
        "collectives": {"bytes_by_op": costs.bytes_by_op,
                        "count_by_op": costs.count_by_op},
        # Raw XLA numbers (while bodies counted once) for reference:
        "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes_accessed":
                                  float(cost.get("bytes accessed", 0.0))},
        "roofline": terms,
        "model_flops_global": model_flops,
        "useful_flops_ratio": ((model_flops_per_chip / costs.flops)
                               if costs.flops else None),
        "memory_analysis": mem_rec,
        "params_total": cfg.param_count(),
        "params_active": n_active,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--strategy", default="fsdp_tp",
                    choices=["dp", "fsdp", "fsdp_tp"])
    ap.add_argument("--no-ssl", action="store_true",
                    help="lower the supervised-only step (paper baseline)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh(es)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    combos = []
    meshes = (["single", "multi"] if args.all
              else [args.mesh])
    archs = ARCH_IDS if args.arch is None else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape is None else [args.shape]
    for m in meshes:
        for a in archs:
            for s in shapes:
                combos.append((a, s, m))

    for arch, shape_name, mesh_kind in combos:
        tag = f"{arch}__{shape_name}__{mesh_kind}__{args.strategy}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            rec = run_one(arch, shape_name, multi_pod=(mesh_kind == "multi"),
                          strategy=args.strategy, ssl=not args.no_ssl,
                          hlo_path=os.path.join(args.out, tag + ".hlo.gz"))
        except Exception as e:  # noqa: BLE001 — record the failure and go on
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "strategy": args.strategy, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-4000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" compile={rec['compile_s']}s dominant={r['dominant']}"
                     f" compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s"
                     f" coll={r['collective_s']:.4f}s")
        print(f"[{status}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
