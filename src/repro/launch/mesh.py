"""Production mesh definitions (functions — importing never touches jax
device state; the dry-run sets XLA_FLAGS before calling these).

Target hardware: TPU v5e pods, 256 chips/pod.
  single-pod:  (16, 16)      axes ("data", "model")
  multi-pod:   (2, 16, 16)   axes ("pod", "data", "model")
"""
from __future__ import annotations

import jax

SINGLE_POD_SHAPE = (16, 16)
MULTI_POD_SHAPE = (2, 16, 16)

# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, multi_pod: bool = False,
                    data: int = 2, model: int = 2) -> jax.sharding.Mesh:
    """Tiny mesh with the same axis names — used by CI-scale sharding tests."""
    if multi_pod:
        return jax.make_mesh((2, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
