"""Production training launcher: ``--arch <id> --shape train_4k --sharding …``.

On real TPU pods this drives the full job; on this CPU container ``--dry-run``
(the default when no accelerator is present) lowers and compiles the exact
production step (see dryrun.py), while ``--smoke`` runs real steps on a
reduced variant — the same code path end to end.

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --dry-run
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--sharding", default="fsdp_tp",
                    choices=["dp", "fsdp", "fsdp_tp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="run real steps on the reduced variant (CPU)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--scan-chunk", type=int, default=5,
                    help="steps per compiled lax.scan chunk (0 = all)")
    ap.add_argument("--ssl", action="store_true", default=True)
    args = ap.parse_args()

    if args.smoke:
        _run_smoke(args)
        return
    # Dry-run path: delegate (sets XLA_FLAGS before jax import).
    import subprocess
    import sys
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--strategy", args.sharding,
           "--mesh", "multi" if args.multi_pod else "single"]
    raise SystemExit(subprocess.call(cmd))


def _run_smoke(args) -> None:
    """Real steps on the reduced variant, through the SAME scan-compiled
    engine the SSL trainers use — one epoch of ``--steps`` synthetic
    batches, compiled in ``--scan-chunk``-step donated scans with
    host→device prefetch."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.ssl_loss import SSLHyper
    from repro.models import transformer as tf
    from repro.optim import adagrad, constant_lr
    from repro.train.engine import Engine, TrainState, lift_step
    from repro.train.train_step import lm_train_step

    cfg = get_config(args.arch).reduced()
    print(f"[smoke] {cfg.name}: {cfg.param_count()/1e6:.2f}M params")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adagrad()
    hyper = SSLHyper(1e-2, 1e-3, 0.0) if args.ssl else None
    state = TrainState.create(params, opt.init(params), jax.random.PRNGKey(0))
    B, T = 4, 32
    rng = np.random.default_rng(0)

    step_fn = lift_step(
        lambda p, o, batch, lr: lm_train_step(p, o, batch, cfg=cfg,
                                              hyper=hyper, opt=opt, lr=lr))

    def epoch():
        for _ in range(args.steps):
            toks = rng.integers(0, cfg.vocab_size, (B, T + 1),
                                dtype=np.int32)
            batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                     "loss_mask": np.ones((B, T), np.float32),
                     "W": np.ones((1, B, B), np.float32),
                     "seq_labels": np.zeros((1, B), np.int32),
                     "seq_label_mask": np.ones((1, B), np.float32)}
            if cfg.modality_tokens:
                batch["modality_embeds"] = np.zeros(
                    (B, cfg.modality_tokens, cfg.modality_dim), np.float32)
            yield batch

    engine = Engine(step_fn, strategy="sequential",
                    scan_chunk=args.scan_chunk, prefetch=2)
    t0 = time.time()
    res = engine.run(epoch, state=state, n_epochs=1,
                     lr_schedule=constant_lr(1e-3))
    row = res.history[-1]
    dt = time.time() - t0
    print(f"  {args.steps} steps in {dt:.2f}s "
          f"({args.steps / dt:.2f} steps/s, scan_chunk={args.scan_chunk}) "
          f"mean loss={row['loss/total']:.4f}")
    print(f"[smoke] done — global step {int(res.state.step)}, "
          "loss finite and decreasing expected")


if __name__ == "__main__":
    main()
