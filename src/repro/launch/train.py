"""Production training launcher: ``--arch <id> --shape train_4k --sharding …``.

On real TPU pods this drives the full job; on this CPU container ``--dry-run``
(the default when no accelerator is present) lowers and compiles the exact
production step (see dryrun.py), while ``--smoke`` runs real steps on a
reduced variant — the same code path end to end.

  PYTHONPATH=src python -m repro.launch.train --arch mixtral-8x7b --smoke
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --dry-run
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--sharding", default="fsdp_tp",
                    choices=["dp", "fsdp", "fsdp_tp"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="run real steps on the reduced variant (CPU)")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--ssl", action="store_true", default=True)
    args = ap.parse_args()

    if args.smoke:
        _run_smoke(args)
        return
    # Dry-run path: delegate (sets XLA_FLAGS before jax import).
    import subprocess
    import sys
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--strategy", args.sharding,
           "--mesh", "multi" if args.multi_pod else "single"]
    raise SystemExit(subprocess.call(cmd))


def _run_smoke(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core.ssl_loss import SSLHyper
    from repro.models import transformer as tf
    from repro.optim import adagrad
    from repro.train.train_step import lm_train_step

    cfg = get_config(args.arch).reduced()
    print(f"[smoke] {cfg.name}: {cfg.param_count()/1e6:.2f}M params")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adagrad()
    opt_state = opt.init(params)
    hyper = SSLHyper(1e-2, 1e-3, 0.0) if args.ssl else None
    B, T = 4, 32
    rng = np.random.default_rng(0)

    @jax.jit
    def step(params, opt_state, batch):
        return lm_train_step(params, opt_state, batch, cfg=cfg, hyper=hyper,
                             opt=opt, lr=jnp.float32(1e-3))

    for i in range(args.steps):
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)))
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                 "loss_mask": jnp.ones((B, T), jnp.float32),
                 "W": jnp.ones((1, B, B), jnp.float32),
                 "seq_labels": jnp.zeros((1, B), jnp.int32),
                 "seq_label_mask": jnp.ones((1, B), jnp.float32)}
        if cfg.modality_tokens:
            batch["modality_embeds"] = jnp.zeros(
                (B, cfg.modality_tokens, cfg.modality_dim), jnp.float32)
        t0 = time.time()
        params, opt_state, metrics = step(params, opt_state, batch)
        print(f"  step {i}: loss={float(metrics['loss/total']):.4f} "
              f"({time.time()-t0:.2f}s)")
    print("[smoke] done — loss finite and decreasing expected")


if __name__ == "__main__":
    main()
