"""ShapeDtypeStruct stand-ins for every model input — the dry-run's inputs.

``input_specs(arch, shape, mesh, strategy)`` returns (fn, args) where ``fn``
is the step to lower (train_step or serve_step) and ``args`` are
sharding-annotated ShapeDtypeStructs: weak-type-correct, shardable, and never
allocated.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, InputShape, config_for_shape, get_config
from repro.core.ssl_loss import SSLHyper
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.optim import adagrad
from repro.serve.decode import serve_step
from repro.sharding import specs as sh
from repro.train.train_step import lm_train_step

SSL_GROUPS = 16          # G concatenated meta-batches per global train batch


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def _replicated(mesh, tree):
    r = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=r), tree)


def train_inputs(cfg: ModelConfig, shape: InputShape, mesh, strategy: str,
                 *, ssl: bool = True) -> dict[str, Any]:
    B, T = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, T), jnp.int32),
        "targets": _sds((B, T), jnp.int32),
        "loss_mask": _sds((B, T), jnp.float32),
    }
    if ssl:
        G = min(SSL_GROUPS, B)
        b = B // G
        batch.update(
            W=_sds((G, b, b), jnp.float32),
            seq_labels=_sds((G, b), jnp.int32),
            seq_label_mask=_sds((G, b), jnp.float32),
        )
    if cfg.modality_tokens:
        batch["modality_embeds"] = _sds(
            (B, cfg.modality_tokens, cfg.modality_dim), jnp.bfloat16)
    bshard = sh.train_batch_shardings(batch, mesh)
    batch = sh.with_shardings(batch, bshard)

    params = tf.abstract_params(cfg)
    pshard = sh.param_shardings(params, mesh, strategy)
    params = sh.with_shardings(params, pshard)

    opt = adagrad()
    opt_state = jax.eval_shape(opt.init, params)
    oshard = sh.param_shardings(opt_state, mesh, strategy)
    opt_state = sh.with_shardings(opt_state, oshard)

    hyper = SSLHyper(gamma=1e-3, kappa=1e-4, weight_decay=0.0) if ssl else None
    ba = sh.batch_axes(mesh)
    act = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(ba if len(ba) > 1 else ba[0],
                                         None, None))

    def step(params, opt_state, batch):
        return lm_train_step(params, opt_state, batch, cfg=cfg, hyper=hyper,
                             opt=opt, lr=jnp.float32(1e-3),
                             act_sharding=act)

    return {"fn": step, "args": (params, opt_state, batch),
            "donate": (0, 1)}


def prefill_inputs(cfg: ModelConfig, shape: InputShape, mesh,
                   strategy: str) -> dict[str, Any]:
    """Inference-prefill: full-sequence forward that fills the decode cache."""
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.modality_tokens:
        batch["modality_embeds"] = _sds(
            (B, cfg.modality_tokens, cfg.modality_dim), jnp.bfloat16)
    bshard = sh.train_batch_shardings(batch, mesh)
    batch = sh.with_shardings(batch, bshard)
    params = tf.abstract_params(cfg)
    params = sh.with_shardings(params,
                               sh.param_shardings(params, mesh, strategy))
    ba = sh.batch_axes(mesh)
    act = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(ba if len(ba) > 1 else ba[0],
                                         None, None))

    def step(params, batch):
        return tf.prefill(params, cfg, batch["tokens"],
                          modality_embeds=batch.get("modality_embeds"),
                          act_sharding=act)

    return {"fn": step, "args": (params, batch), "donate": ()}


def decode_inputs(cfg: ModelConfig, shape: InputShape, mesh,
                  strategy: str) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: tf.init_cache(cfg, B, S))
    cshard = sh.cache_shardings(cache, mesh, B, strategy)
    cache = sh.with_shardings(cache, cshard)

    params = tf.abstract_params(cfg)
    pshard = sh.param_shardings(params, mesh, strategy)
    params = sh.with_shardings(params, pshard)

    ba = sh.batch_axes(mesh)
    bn = 1
    for a in ba:
        bn *= mesh.shape[a]
    tok_spec = (jax.sharding.PartitionSpec(ba if len(ba) > 1 else ba[0])
                if B % bn == 0 and B >= bn else jax.sharding.PartitionSpec())
    tok_sh = jax.sharding.NamedSharding(mesh, tok_spec)
    tokens = _sds((B, 1), jnp.int32, tok_sh)
    pos = _sds((B,), jnp.int32, tok_sh)
    key = _replicated(mesh, jax.eval_shape(lambda: jax.random.PRNGKey(0)))

    bdim = (ba if len(ba) > 1 else ba[0]) if (B % bn == 0 and B >= bn) else None
    act = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(bdim, None, None))

    def step(params, cache, tokens, pos, key):
        return serve_step(params, cfg, cache, tokens, pos, key,
                          temperature=0.0, act_sharding=act)

    return {"fn": step, "args": (params, cache, tokens, pos, key),
            "donate": (1,)}


def input_specs(arch: str, shape_name: str, mesh, strategy: str = "fsdp_tp",
                *, ssl: bool = True) -> dict[str, Any]:
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for_shape(get_config(arch), shape)
    if shape.kind == "train":
        return train_inputs(cfg, shape, mesh, strategy, ssl=ssl)
    if shape.kind == "prefill":
        return prefill_inputs(cfg, shape, mesh, strategy)
    return decode_inputs(cfg, shape, mesh, strategy)
