"""Run an experiment from a JSON config file.

    PYTHONPATH=src python -m repro.api.run --config exp.json
    PYTHONPATH=src python -m repro.api.run --epochs 4   # all-defaults run

``--dump-config`` prints the fully-resolved config (defaults included) as
JSON and exits — the printed document round-trips through ``--config``.
"""
from __future__ import annotations

import argparse
import json

from repro.api import Experiment, ExperimentConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--config", default=None,
                    help="path to an ExperimentConfig JSON file")
    ap.add_argument("--epochs", type=int, default=None,
                    help="override train.n_epochs")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the resolved config as JSON and exit")
    args = ap.parse_args()

    if args.config:
        with open(args.config) as fh:
            cfg = ExperimentConfig.from_dict(json.load(fh))
    else:
        cfg = ExperimentConfig()
    if args.epochs is not None:
        import dataclasses
        cfg = dataclasses.replace(
            cfg, train=dataclasses.replace(cfg.train, n_epochs=args.epochs))
    if args.dump_config:
        print(json.dumps(cfg.to_dict(), indent=2))
        return

    result = Experiment(cfg).run()
    for row in result.history:
        acc = f" eval/acc={row['eval/acc']:.4f}" if "eval/acc" in row else ""
        print(f"epoch {row['epoch']:3d}: loss={row['loss/total']:.4f}"
              f" lr={row['lr']:.4g}{acc}")
    print(f"[{cfg.name}] {len(result.history)} epochs "
          f"in {result.seconds:.1f}s")


if __name__ == "__main__":
    main()
