"""String-keyed component registries for the experiment layer.

Every pluggable stage of the paper's pipeline — affinity-graph construction,
balanced partitioning, batch synthesis, the pairwise Hc(p_i,p_j) kernel, and
the optimizer — is looked up by name here, in the style of the xFormers
factory pattern: configs carry *names*, registries map names to callables,
and new scenarios register a component instead of forking the wiring.

Default entries are **lazy** ``"module:attr"`` import specs, resolved (and
cached) on first :meth:`Registry.get`.  That keeps this module import-light
and lets low-level packages (``repro.core``, ``repro.train``) resolve names
through it without circular imports.

Registering a new component::

    from repro.api.registry import AFFINITY

    @AFFINITY.register("cosine_knn")
    def build_cosine_graph(X, *, k=10, **kw):
        ...

    # or, keeping the import lazy:
    AFFINITY.register("cosine_knn", "mypkg.graphs:build_cosine_graph")
"""
from __future__ import annotations

import functools
import importlib
from typing import Any, Callable, Iterable

__all__ = [
    "Registry",
    "AFFINITY",
    "AUDIT",
    "PARTITIONER",
    "PIPELINE",
    "PAIRWISE",
    "OPTIMIZER",
    "STRATEGY",
    "resolve_pairwise",
]


class Registry:
    """A named string→component table with lazy ``"module:attr"`` entries."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Any] = {}

    # -- registration -----------------------------------------------------
    def register(self, name: str, component: Any = None):
        """Register ``component`` under ``name``.

        Usable three ways: directly (``reg.register("x", fn)``), with a lazy
        import spec (``reg.register("x", "pkg.mod:fn")``), or as a decorator
        (``@reg.register("x")``).  Re-registering a name overwrites it (so
        callers can shadow a default implementation).
        """
        if component is None:
            def deco(fn):
                self._entries[name] = fn
                return fn
            return deco
        self._entries[name] = component
        return component

    # -- lookup -----------------------------------------------------------
    def get(self, name: str) -> Any:
        """Resolve ``name``; raises ``KeyError`` listing known names."""
        if name not in self._entries:
            raise KeyError(
                f"unknown {self.kind} component {name!r}; "
                f"registered: {self.names()}")
        entry = self._entries[name]
        if isinstance(entry, str):  # lazy "module:attr" spec
            mod_name, _, attr = entry.partition(":")
            entry = getattr(importlib.import_module(mod_name), attr)
            self._entries[name] = entry
        return entry

    def names(self) -> list[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterable[str]:
        return iter(self.names())

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, names={self.names()})"


# --------------------------------------------------------------------------
# Default registries.  Specs are lazy so importing repro.api stays cheap.
# --------------------------------------------------------------------------

#: ``(X, *, k, sigma, ...) -> AffinityGraph``
AFFINITY = Registry("affinity")
AFFINITY.register("knn_rbf", "repro.core.affinity:build_affinity_graph")

#: ``(W, n_parts, *, tol, coarsen_to, seed) -> PartitionResult``
#:   * ``"multilevel"``      — the vectorized multilevel partitioner (also
#:     accepts ``temperature=`` for stochastic re-partitioning);
#:   * ``"multilevel_loop"`` — the seed per-node-loop implementation, kept
#:     as the quality/semantics reference.
PARTITIONER = Registry("partitioner")
PARTITIONER.register("multilevel", "repro.core.partition:partition_graph")
PARTITIONER.register("multilevel_loop",
                     "repro.core.partition:partition_graph_loop")

#: ``(corpus, graph, plan, *, n_workers, seed, ...) -> epoch_fn`` where
#: ``epoch_fn()`` yields device-ready ``SSLBatch``es for one epoch.
PIPELINE = Registry("pipeline")
PIPELINE.register("meta_batch", "repro.data.pipeline:make_meta_batch_pipeline")
PIPELINE.register("graph_batch",
                  "repro.data.pipeline:make_graph_batch_pipeline")
PIPELINE.register("random_batch",
                  "repro.data.pipeline:make_random_batch_pipeline")
#: ``"metabatch_stream"`` — the §2 stream as a first-class stage: meta-batch
#: pairs assembled on demand, with optional between-epoch stochastic
#: re-partitioning on a background thread (``RepartitionConfig``); its epoch
#: factory takes ``epoch=`` so scheduling survives checkpoint resume.
PIPELINE.register("metabatch_stream",
                  "repro.data.pipeline:make_metabatch_stream_pipeline")

#: ``(logp, W) -> scalar`` computing the Eq.-3/4 contraction
#: ``Σ_ij W_ij · Hc(p_i, p_j)`` — or, for entries carrying the
#: ``full_regularizer`` marker, ``(logp, W, γ, κ) -> scalar`` computing the
#: *entire* regularizer (cross + degrees + entropy) in one kernel sweep.
#:   * ``"ref"``    — the pure-jnp cross-term oracle (always available);
#:   * ``"pallas"`` — the MXU-tiled cross-term kernel with its tiled
#:     analytic VJP (interpret mode off-TPU);
#:   * ``"fused"``  — the single-pass fused regularizer kernel (fwd + tiled
#:     VJP), unconditionally Pallas;
#:   * ``"blocksparse"`` — the tile-skipping fused kernel driven by a
#:     ``BlockLayout`` (``layout=`` kwarg); falls back to ``"fused"`` when
#:     no layout is supplied;
#:   * ``"auto"``   — on TPU backends ``"blocksparse"`` when a layout is
#:     available, else ``"fused"``; the jnp oracle elsewhere.
PAIRWISE = Registry("pairwise")
PAIRWISE.register("ref", "repro.kernels.ref:graph_reg_pairwise_ref")
PAIRWISE.register("pallas", "repro.kernels.ops:graph_reg_pairwise_pallas_vjp")
PAIRWISE.register("fused", "repro.kernels.ops:graph_regularizer_fused")
PAIRWISE.register("blocksparse",
                  "repro.kernels.ops:graph_regularizer_blocksparse")
PAIRWISE.register("auto", "repro.kernels.ops:graph_regularizer_auto")

#: ``(engine) -> strategy`` execution strategies for the unified training
#: engine (:mod:`repro.train.engine`) — how the scan body maps work onto
#: devices:
#:   * ``"sequential"`` — single-device execution;
#:   * ``"sync_mesh"``  — params replicated over a ``("data",)`` mesh, each
#:     chunk's worker axis sharded over it (the paper's synchronous k-worker
#:     SGD, pjit inserting the gradient all-reduce);
#:   * ``"async_ps"``   — the §4 stale-gradient parameter-server simulation
#:     (snapshots + round-robin schedule inside the scan body).
STRATEGY = Registry("strategy")
STRATEGY.register("sequential", "repro.train.engine:SequentialStrategy")
STRATEGY.register("sync_mesh", "repro.train.engine:SyncMeshStrategy")
STRATEGY.register("async_ps", "repro.train.engine:AsyncPSStrategy")

#: Audited entry points of the static-analysis toolkit
#: (:mod:`repro.analysis`): each name resolves to a
#: ``repro.analysis.jaxpr_audit.EntryPoint`` — how to trace one compiled
#: surface and what contracts its jaxpr must satisfy.  The CLI
#: (``python -m repro.analysis``) audits every registered name; register a
#: new entry here to put a new compiled path under the CI gate.
AUDIT = Registry("audit")
AUDIT.register("graph_reg_fused", "repro.analysis.entrypoints:graph_reg_fused")
AUDIT.register("graph_reg_blocksparse",
               "repro.analysis.entrypoints:graph_reg_blocksparse")
AUDIT.register("graph_reg_ref", "repro.analysis.entrypoints:graph_reg_ref")
AUDIT.register("knn_topk", "repro.analysis.entrypoints:knn_topk")
AUDIT.register("online_refresh",
               "repro.analysis.entrypoints:online_refresh")
AUDIT.register("ssl_objective", "repro.analysis.entrypoints:ssl_objective")
AUDIT.register("engine_sequential",
               "repro.analysis.entrypoints:engine_sequential")
AUDIT.register("engine_sync_mesh",
               "repro.analysis.entrypoints:engine_sync_mesh")
AUDIT.register("engine_async_ps",
               "repro.analysis.entrypoints:engine_async_ps")
AUDIT.register("engine_capture",
               "repro.analysis.entrypoints:engine_capture")
AUDIT.register("serve_decode_generate",
               "repro.analysis.entrypoints:serve_decode_generate")

#: ``(**hyper) -> repro.optim.Optimizer``
OPTIMIZER = Registry("optimizer")
OPTIMIZER.register("adagrad", "repro.optim:adagrad")
OPTIMIZER.register("adam", "repro.optim:adam")
OPTIMIZER.register("sgd", "repro.optim:sgd")


def resolve_pairwise(
    pairwise: str | Callable | None,
    *,
    tiles=None,
) -> Callable | None:
    """Resolve a pairwise-kernel *name* to its implementation.

    ``None`` (use the caller's inline oracle) and already-resolved callables
    pass through unchanged, so call sites can accept either form.

    ``tiles`` (a ``repro.kernels.tuning.TileSpec``, e.g. from
    ``ObjectiveConfig.tiles()``) pins kernel block sizes: entries that
    advertise ``accepts_tiles`` are wrapped so every call carries the spec;
    entries that don't (the jnp oracle) ignore it.
    """
    if pairwise is None or callable(pairwise):
        return pairwise
    impl = PAIRWISE.get(pairwise)
    if tiles is not None and getattr(impl, "accepts_tiles", False):
        @functools.wraps(impl)   # copies full_regularizer/accepts_tiles too
        def tiled(*args, _impl=impl, _tiles=tiles, **kw):
            kw.setdefault("tiles", _tiles)
            return _impl(*args, **kw)
        return tiled
    return impl
