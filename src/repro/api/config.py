"""Frozen, validated, serializable experiment configs.

One ``ExperimentConfig`` captures everything the paper's pipeline needs —
corpus synthesis, affinity graph, balanced partition, meta-batch synthesis,
the Eq.-3 objective, and the training loop — as plain data.  Components are
referenced *by name* and resolved through ``repro.api.registry``, so a config
is a complete, hashable, JSON-round-trippable description of an experiment:

    cfg = ExperimentConfig(objective=ObjectiveConfig(gamma=1.0))
    assert ExperimentConfig.from_dict(cfg.to_dict()) == cfg

Every sub-config validates its fields in ``__post_init__`` (fail at
construction, not three layers deep in the trainer).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

__all__ = [
    "DataConfig",
    "GraphConfig",
    "PartitionConfig",
    "BatchConfig",
    "RepartitionConfig",
    "ObjectiveConfig",
    "TrainConfig",
    "ExecutionConfig",
    "ResilienceConfig",
    "OnlineConfig",
    "ExperimentConfig",
]


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(msg)


def _from_dict(cls, d: dict[str, Any]):
    """Reconstruct a (flat) dataclass from a dict, rejecting unknown keys."""
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - names
    _require(not unknown,
             f"{cls.__name__}: unknown keys {sorted(unknown)}; "
             f"expected a subset of {sorted(names)}")
    return cls(**d)


@dataclass(frozen=True)
class DataConfig:
    """Synthetic TIMIT-like corpus (``repro.data.make_corpus``) + label drop.

    ``n`` training points plus ``round(n * test_fraction)`` held-out test
    points are drawn from one generative manifold (the paper's §3 protocol);
    ``label_ratio`` of the training labels stay visible (§3: 2%–100%).
    """

    n: int = 4000
    n_classes: int = 16
    input_dim: int = 128
    manifold_dim: int = 10
    structure: str = "filaments"
    label_ratio: float = 0.02
    test_fraction: float = 0.25
    seed: int = 0

    def __post_init__(self):
        _require(self.n > 0, f"n must be positive, got {self.n}")
        _require(self.n_classes > 1, "need at least 2 classes")
        _require(self.input_dim > 0 and self.manifold_dim > 0,
                 "dims must be positive")
        _require(self.structure in ("filaments", "blobs"),
                 f"unknown structure {self.structure!r}")
        _require(0.0 < self.label_ratio <= 1.0,
                 f"label_ratio must be in (0, 1], got {self.label_ratio}")
        _require(0.0 <= self.test_fraction < 1.0,
                 f"test_fraction must be in [0, 1), got {self.test_fraction}")


@dataclass(frozen=True)
class GraphConfig:
    """k-NN affinity graph (paper §3): ``builder`` names an AFFINITY entry.

    ``construction`` picks the streaming top-k search backend: ``"host"``
    (numpy, column-streamed) or ``"device"`` (the Pallas streaming top-k
    kernel) — both exact, neither materializes the N×N distance matrix.
    """

    builder: str = "knn_rbf"
    k: int = 10
    sigma: float | None = None    # None = self-tuning bandwidth
    construction: str = "host"

    def __post_init__(self):
        _require(self.k > 0, f"k must be positive, got {self.k}")
        _require(self.sigma is None or self.sigma > 0,
                 f"sigma must be positive or None, got {self.sigma}")
        _require(self.construction in ("host", "device"),
                 f"construction must be 'host' or 'device', "
                 f"got {self.construction!r}")


@dataclass(frozen=True)
class PartitionConfig:
    """Balanced min-edge-cut partition (paper §1.1, Fig. 1b)."""

    method: str = "multilevel"    # PARTITIONER registry entry
    tol: float = 0.15             # balance tolerance
    coarsen_to: int = 60          # nodes-per-part target to stop coarsening

    def __post_init__(self):
        _require(self.tol >= 0, f"tol must be >= 0, got {self.tol}")
        _require(self.coarsen_to > 0,
                 f"coarsen_to must be positive, got {self.coarsen_to}")


@dataclass(frozen=True)
class BatchConfig:
    """Meta-batch synthesis (paper §2) and the training-batch pipeline.

    ``pipeline`` names a PIPELINE registry entry: ``"meta_batch"`` (the
    paper's method, static plan), ``"metabatch_stream"`` (the same §2
    stream as a first-class stage, required for ``RepartitionConfig``),
    ``"graph_batch"`` (pure partitioned batches — the §2 low-entropy
    baseline; pair with ``shuffle_blocks=False``), or ``"random_batch"``
    (the Fig.-1a regime).
    """

    pipeline: str = "meta_batch"
    batch_size: int = 512
    with_neighbor: bool = True    # concatenate the Eq.-6 sampled neighbour
    shuffle_blocks: bool = True   # random mini-block grouping (§2.1 step 2)
    pad_factor: float = 2.4
    pad_headroom: float = 1.25    # metabatch_stream: pinned-pad slack so
                                  # re-partitioned plans fit jitted shapes
    layout_bt: int | None = None  # tile edge of the per-batch BlockLayout
                                  # (block-sparse regularizer); None = no
                                  # layout attached, dense kernels only

    def __post_init__(self):
        _require(self.batch_size > 0,
                 f"batch_size must be positive, got {self.batch_size}")
        _require(self.pad_factor >= 1.0,
                 f"pad_factor must be >= 1, got {self.pad_factor}")
        _require(self.pad_headroom >= 1.0,
                 f"pad_headroom must be >= 1, got {self.pad_headroom}")
        _require(self.layout_bt is None
                 or (isinstance(self.layout_bt, int) and self.layout_bt > 0),
                 f"layout_bt must be a positive int or None, "
                 f"got {self.layout_bt!r}")
        _require(not (self.pipeline == "graph_batch" and self.shuffle_blocks),
                 "pipeline='graph_batch' is the consecutive-mini-block "
                 "baseline; set shuffle_blocks=False (shuffled blocks would "
                 "silently turn it into neighbour-less meta-batches)")


@dataclass(frozen=True)
class RepartitionConfig:
    """Stochastic re-partitioning of the §2 meta-batch plan between epochs.

    Requires ``BatchConfig.pipeline="metabatch_stream"``.  Every
    ``every_n_epochs`` epochs a background thread re-synthesizes the whole
    plan — balanced partition with ``matching_temperature``-perturbed
    (Gumbel) coarsening, fresh mini-block grouping, fresh Eq.-6 batch graph
    — under a deterministic per-epoch seed stream derived from ``seed``, and
    the engine's next epoch consumes it without a device sync.
    ``every_n_epochs=0`` (default) keeps the plan static.

    ``reuse_hierarchy`` (default True) caches the partitioner's coarsening
    hierarchy across epochs: each replan re-draws only the chain's top
    levels plus a temperature-scaled perturbation and re-runs refinement
    around the delta, instead of rebuilding the whole multilevel chain
    from scratch.  Plans stay bit-reproducible per ``(seed, epoch)`` —
    the hierarchy is a pure function of the graph and this config, never
    of the epoch.  Set False to force from-scratch replans (also the
    automatic fallback when the configured partitioner does not accept
    ``reuse=``).
    """

    every_n_epochs: int = 0
    matching_temperature: float = 0.5
    seed: int = 0
    reuse_hierarchy: bool = True

    def __post_init__(self):
        _require(self.every_n_epochs >= 0,
                 f"every_n_epochs must be >= 0, got {self.every_n_epochs}")
        _require(self.matching_temperature >= 0,
                 f"matching_temperature must be >= 0, "
                 f"got {self.matching_temperature}")
        _require(isinstance(self.reuse_hierarchy, bool),
                 f"reuse_hierarchy must be a bool, "
                 f"got {self.reuse_hierarchy!r}")

    @property
    def active(self) -> bool:
        return self.every_n_epochs > 0


@dataclass(frozen=True)
class ObjectiveConfig:
    """Eq.-2/3 hyper-parameters plus the pairwise-kernel selection.

    ``pairwise`` names a PAIRWISE registry entry — ``"ref"`` (jnp oracle),
    ``"pallas"`` (tiled cross-term kernel), ``"fused"`` (single-pass fused
    regularizer kernel, fwd + tiled VJP), ``"blocksparse"`` (the fused
    kernel over a compacted active-tile grid; needs
    ``BatchConfig.layout_bt``) or ``"auto"`` (on TPU: block-sparse when
    the pipeline supplies a layout, else fused; jnp oracle elsewhere).
    ``gamma=kappa=0`` recovers the fully-supervised baseline.

    ``tile_bi``/``tile_bj``/``tile_bc`` pin kernel block sizes (rows ×
    affinity-columns × class-chunk); ``None`` auto-selects from the
    ``repro.kernels.tuning`` shape/backend table.
    """

    gamma: float = 1.0            # graph-regularizer weight γ
    kappa: float = 1e-4           # entropy-regularizer weight κ
    weight_decay: float = 1e-5    # ℓ2 weight λ
    pairwise: str = "auto"
    tile_bi: int | None = None
    tile_bj: int | None = None
    tile_bc: int | None = None

    def __post_init__(self):
        _require(self.gamma >= 0 and self.kappa >= 0
                 and self.weight_decay >= 0,
                 "gamma, kappa and weight_decay must all be >= 0, got "
                 f"({self.gamma}, {self.kappa}, {self.weight_decay})")
        for name in ("tile_bi", "tile_bj", "tile_bc"):
            v = getattr(self, name)
            _require(v is None or (isinstance(v, int) and v > 0),
                     f"{name} must be a positive int or None, got {v!r}")

    def hyper(self):
        """The ``repro.core.ssl_loss.SSLHyper`` this config describes."""
        from repro.core.ssl_loss import SSLHyper
        return SSLHyper(gamma=self.gamma, kappa=self.kappa,
                        weight_decay=self.weight_decay)

    def tiles(self):
        """The pinned-tile ``TileSpec`` (or None when fully auto)."""
        if self.tile_bi is None and self.tile_bj is None \
                and self.tile_bc is None:
            return None
        from repro.kernels.tuning import TileSpec
        return TileSpec(bi=self.tile_bi, bj=self.tile_bj, bc=self.tile_bc)


@dataclass(frozen=True)
class TrainConfig:
    """Model size, optimizer and loop settings (paper §3 protocol).

    ``execution="sequential"`` runs the vmapped k-worker step on the default
    device; ``"parallel"`` additionally shards the leading worker axis over a
    ``("data",)`` mesh of the available devices — the launcher's pjit
    pattern, which *is* the paper's synchronous k-worker SGD.  (Back-compat
    shorthand: ``"parallel"`` selects the engine's ``"sync_mesh"`` strategy
    unless ``ExecutionConfig.strategy`` overrides it.)
    """

    n_epochs: int = 10
    n_workers: int = 1
    execution: str = "sequential"
    base_lr: float = 1e-3
    lr_reset_epochs: int = 10     # paper: lr = base·k for 10 epochs, then base
    dropout: float = 0.2
    optimizer: str = "adagrad"    # OPTIMIZER registry entry
    hidden_dim: int = 512
    n_hidden: int = 3
    seed: int = 0

    def __post_init__(self):
        _require(self.n_epochs >= 0,
                 f"n_epochs must be >= 0, got {self.n_epochs}")
        _require(self.n_workers >= 1,
                 f"n_workers must be >= 1, got {self.n_workers}")
        _require(self.execution in ("sequential", "parallel"),
                 f"execution must be 'sequential' or 'parallel', "
                 f"got {self.execution!r}")
        _require(self.base_lr > 0, f"base_lr must be > 0, got {self.base_lr}")
        _require(self.lr_reset_epochs >= 1, "lr_reset_epochs must be >= 1")
        _require(0.0 <= self.dropout < 1.0,
                 f"dropout must be in [0, 1), got {self.dropout}")
        _require(self.hidden_dim > 0 and self.n_hidden >= 1,
                 "model dims must be positive")


@dataclass(frozen=True)
class ExecutionConfig:
    """How the unified engine executes the loop (see ``repro.train.engine``).

    ``strategy`` names a STRATEGY registry entry (``"sequential"``,
    ``"sync_mesh"``, ``"async_ps"``); ``None`` (the default) infers it from
    the legacy ``TrainConfig.execution`` shorthand — an *explicit* name
    always wins.  ``scan_chunk`` steps are compiled into one donated
    ``lax.scan`` (0 = the whole epoch in one scan — fastest, but stages
    every batch of the epoch at once; the bounded default keeps memory
    flat).  ``prefetch`` chunks are staged host→device ahead of compute (0
    turns prefetching off).  ``checkpoint_every > 0`` saves the full engine
    carry every N epochs into ``checkpoint_dir``; ``resume=True`` restores
    the newest checkpoint exactly (rng and step included).
    ``max_staleness`` is the ``async_ps`` worker lag in server steps.
    """

    strategy: str | None = None
    scan_chunk: int = 16
    prefetch: int = 2
    checkpoint_every: int = 0
    checkpoint_dir: str | None = None
    resume: bool = False
    max_staleness: int = 2

    def __post_init__(self):
        _require(self.strategy is None
                 or (isinstance(self.strategy, str) and self.strategy != ""),
                 f"strategy must be a non-empty name or None (= infer from "
                 f"TrainConfig.execution), got {self.strategy!r}")
        _require(self.scan_chunk >= 0,
                 f"scan_chunk must be >= 0, got {self.scan_chunk}")
        _require(self.prefetch >= 0,
                 f"prefetch must be >= 0, got {self.prefetch}")
        _require(self.checkpoint_every >= 0,
                 f"checkpoint_every must be >= 0, got {self.checkpoint_every}")
        _require(self.checkpoint_every == 0 or self.checkpoint_dir,
                 "checkpoint_every > 0 requires checkpoint_dir")
        _require(self.max_staleness >= 1,
                 f"max_staleness must be >= 1, got {self.max_staleness}")


@dataclass(frozen=True)
class ResilienceConfig:
    """Failure semantics for the engine/stream/checkpoint layers
    (see ``repro.resilience`` and README "Failure semantics").

    ``nonfinite_guard`` arms a two-speed non-finite guard: the hot scan
    body is unchanged, each chunk ends with one finiteness reduction, and
    the engine resolves windows of ``guard_window`` chunks with a single
    guard-scalar fetch.  A window that saw NaN/inf is replayed from its
    start with a strict body that skips exactly the poisoned updates
    (params/opt_state/rng/step untouched, as if the batch had never been
    drawn), counting into ``guard/skipped_total`` in the history; with
    ``halt_after_consecutive=K > 0`` a ``NonFiniteHaltError`` is raised
    on host once K steps in a row were skipped (checked at window edges).
    Larger ``guard_window`` amortizes the fetch further but retains that
    many placed chunks (device batches) for a possible replay.

    ``checkpoint_checksums`` writes/verifies a ``.sha256`` sidecar per
    checkpoint; a corrupt LATEST target then falls back to the newest
    valid checkpoint on resume.  ``keep_last=N > 0`` prunes all but the
    newest N checkpoints after each save.

    ``max_retries``/``backoff_base``/``backoff_max`` parameterize the
    thread supervisor for the prefetch producer and the replan builder
    (deterministic jitter derives from ``seed``).  ``hang_timeout`` is the
    per-attempt watchdog for the prefetch producer's device-put (a fast
    operation — a fraction of a second is generous); the replan builder
    gets its own ``replan_hang_timeout`` budget, since a legitimate
    re-synthesis takes orders of magnitude longer than a device-put.
    ``max_replan_failures`` consecutive failed replan targets disable
    background re-partitioning (plan stays static) instead of spinning a
    warning+thread per epoch.

    ``drop_overstale`` makes ``async_ps`` drop gradients from workers
    whose snapshot age exceeds ``max_staleness`` (dead/straggler) and
    renormalize the survivors' contribution.
    """

    nonfinite_guard: bool = False
    guard_window: int = 4
    halt_after_consecutive: int = 0
    checkpoint_checksums: bool = True
    keep_last: int = 0
    max_retries: int = 3
    backoff_base: float = 0.05
    backoff_max: float = 2.0
    hang_timeout: float | None = None
    replan_hang_timeout: float | None = None
    drop_overstale: bool = False
    max_replan_failures: int = 3
    seed: int = 0

    def __post_init__(self):
        _require(self.guard_window >= 1,
                 f"guard_window must be >= 1, got {self.guard_window}")
        _require(self.halt_after_consecutive >= 0,
                 f"halt_after_consecutive must be >= 0, "
                 f"got {self.halt_after_consecutive}")
        _require(self.halt_after_consecutive == 0 or self.nonfinite_guard,
                 "halt_after_consecutive > 0 requires nonfinite_guard=True "
                 "(the halt policy counts guard-skipped steps)")
        _require(self.keep_last >= 0,
                 f"keep_last must be >= 0, got {self.keep_last}")
        _require(self.max_retries >= 0,
                 f"max_retries must be >= 0, got {self.max_retries}")
        _require(0 <= self.backoff_base <= self.backoff_max,
                 f"need 0 <= backoff_base <= backoff_max, got "
                 f"({self.backoff_base}, {self.backoff_max})")
        _require(self.hang_timeout is None or self.hang_timeout > 0,
                 f"hang_timeout must be positive or None, "
                 f"got {self.hang_timeout}")
        _require(self.replan_hang_timeout is None
                 or self.replan_hang_timeout > 0,
                 f"replan_hang_timeout must be positive or None, "
                 f"got {self.replan_hang_timeout}")
        _require(self.max_replan_failures >= 0,
                 f"max_replan_failures must be >= 0, "
                 f"got {self.max_replan_failures}")


@dataclass(frozen=True)
class OnlineConfig:
    """Embedding-space graph refresh + dynamic corpus (``repro.online``).

    ``refresh_every=N > 0`` turns the loop on: during every N-th epoch the
    engine captures the model's hidden activations (``tap`` selects the
    hidden layer, negative = from the top) and at the epoch boundary the
    affinity graph is rebuilt over those embeddings and lock-published to
    the streaming pipeline — the graph tracks the *model's* similarity
    rather than the frozen input features (Bai et al. 1511.06104).  When
    edge churn is at most ``churn_threshold`` the existing partition is
    delta-repaired around the changed edges; above it the plan is
    re-synthesized from scratch.

    ``bandwidth="per_node"`` swaps the global self-tuning sigma for
    Zelnik-Manor local scaling (per-node k-th-NN bandwidth — the learned-
    bandwidth option of Sharma & Jones 2306.07098); ``k=None`` inherits
    ``GraphConfig.k``.  ``insert_batch`` is the default chunk size for
    :meth:`repro.online.OnlineManager.insert` callers.  Requires
    ``BatchConfig.pipeline="metabatch_stream"`` — only the streaming
    pipeline can swap graphs between epochs.
    """

    refresh_every: int = 0
    tap: int = -1                 # hidden layer to capture (negative = top)
    insert_batch: int = 32
    churn_threshold: float = 0.25
    bandwidth: str = "global"
    k: int | None = None          # None = inherit GraphConfig.k
    backend: str = "host"         # top-k search backend for the refresh

    def __post_init__(self):
        _require(self.refresh_every >= 0,
                 f"refresh_every must be >= 0, got {self.refresh_every}")
        _require(self.insert_batch > 0,
                 f"insert_batch must be positive, got {self.insert_batch}")
        _require(0.0 <= self.churn_threshold <= 1.0,
                 f"churn_threshold must be in [0, 1], "
                 f"got {self.churn_threshold}")
        _require(self.bandwidth in ("global", "per_node"),
                 f"bandwidth must be 'global' or 'per_node', "
                 f"got {self.bandwidth!r}")
        _require(self.k is None or (isinstance(self.k, int) and self.k > 0),
                 f"k must be a positive int or None, got {self.k!r}")
        _require(self.backend in ("host", "device"),
                 f"backend must be 'host' or 'device', got {self.backend!r}")

    @property
    def active(self) -> bool:
        return self.refresh_every > 0


@dataclass(frozen=True)
class ExperimentConfig:
    """The single config object an ``Experiment`` runs from."""

    name: str = "ssl"
    data: DataConfig = field(default_factory=DataConfig)
    graph: GraphConfig = field(default_factory=GraphConfig)
    partition: PartitionConfig = field(default_factory=PartitionConfig)
    batch: BatchConfig = field(default_factory=BatchConfig)
    repartition: RepartitionConfig = field(
        default_factory=RepartitionConfig)
    objective: ObjectiveConfig = field(default_factory=ObjectiveConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    execution: ExecutionConfig = field(default_factory=ExecutionConfig)
    resilience: ResilienceConfig = field(default_factory=ResilienceConfig)
    online: OnlineConfig = field(default_factory=OnlineConfig)

    def __post_init__(self):
        _require(not (self.online.active
                      and self.batch.pipeline != "metabatch_stream"),
                 f"online.refresh_every={self.online.refresh_every} requires "
                 f"batch.pipeline='metabatch_stream' (got "
                 f"{self.batch.pipeline!r}); only the streaming pipeline "
                 "can swap graphs between epochs")
        _require(not (self.online.active
                      and not -self.train.n_hidden
                      <= self.online.tap < self.train.n_hidden),
                 f"online.tap={self.online.tap} out of range for "
                 f"n_hidden={self.train.n_hidden} hidden layers")
        _require(not (self.repartition.active
                      and self.batch.pipeline != "metabatch_stream"),
                 f"repartition.every_n_epochs="
                 f"{self.repartition.every_n_epochs} requires "
                 f"batch.pipeline='metabatch_stream' (got "
                 f"{self.batch.pipeline!r}); only the streaming pipeline "
                 "can swap plans between epochs")
        _require(self.batch.layout_bt is None
                 or self.objective.tile_bi is None
                 or self.batch.layout_bt == self.objective.tile_bi,
                 f"batch.layout_bt={self.batch.layout_bt} and "
                 f"objective.tile_bi={self.objective.tile_bi} disagree; the "
                 "block-sparse kernel's tile edge must match the layout the "
                 "pipeline builds (leave tile_bi unset to inherit layout_bt)")
        _require(not (self.objective.pairwise == "blocksparse"
                      and self.batch.layout_bt is None),
                 "objective.pairwise='blocksparse' without batch.layout_bt "
                 "would silently run the dense fused path every step; set "
                 "layout_bt (or use pairwise='auto')")

    @classmethod
    def _sections(cls) -> dict[str, type]:
        """Section name → sub-config class, derived from the field list
        (every section field is declared with ``default_factory=<class>``)."""
        return {f.name: f.default_factory for f in dataclasses.fields(cls)
                if f.default_factory is not dataclasses.MISSING}

    def to_dict(self) -> dict[str, Any]:
        """Plain nested-dict form (JSON/YAML-safe)."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "ExperimentConfig":
        """Inverse of :meth:`to_dict`; missing sections take defaults,
        unknown sections or keys raise ``ValueError``."""
        sections = cls._sections()
        unknown = set(d) - set(sections) - {"name"}
        _require(not unknown,
                 f"ExperimentConfig: unknown sections {sorted(unknown)}")
        kw: dict[str, Any] = {}
        if "name" in d:
            kw["name"] = d["name"]
        for sec, sec_cls in sections.items():
            if sec in d:
                val = d[sec]
                kw[sec] = (val if isinstance(val, sec_cls)
                           else _from_dict(sec_cls, dict(val)))
        return cls(**kw)
