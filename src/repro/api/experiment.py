"""The ``Experiment`` runner — one entry point for every paper scenario.

``Experiment(config).run()`` drives the whole pipeline from an
``ExperimentConfig``: corpus → affinity graph → balanced partition →
meta-batch synthesis → Eq.-3 objective → sequential or k-worker
data-parallel SGD — every stage resolved by name through the registries in
``repro.api.registry``.  The hand-wired entry points in ``examples/`` and
``benchmarks/`` are thin shells over this class.

Pre-built artifacts (a labeled corpus, a shared affinity graph, a reusable
meta-batch plan) can be injected through the constructor so sweeps — e.g.
the Fig.-3a label-ratio grid — don't re-run graph construction per point::

    exp = Experiment(cfg, corpus=labeled, eval_data=test,
                     graph=graph, plan=plan)
    result = exp.run()
"""
from __future__ import annotations

import dataclasses
import inspect
import time
from typing import Any, Callable

import numpy as np

from repro.api.config import ExperimentConfig
from repro.api.registry import (AFFINITY, OPTIMIZER, PARTITIONER, PIPELINE,
                                resolve_pairwise)

__all__ = ["Experiment", "ExperimentResult"]


@dataclasses.dataclass
class ExperimentResult:
    """Structured output of one :meth:`Experiment.run`."""

    config: ExperimentConfig
    history: list[dict]       # per-epoch metric rows from the trainer
    final: dict               # last epoch's row ({} if no epoch produced one)
    seconds: float            # wall-clock for the training loop
    params: Any = None        # trained model parameters (pytree)

    def best(self, key: str = "eval/acc") -> float:
        """Best value of ``key`` across epochs (e.g. peak test accuracy)."""
        vals = [h[key] for h in self.history if key in h]
        if not vals:
            raise KeyError(f"metric {key!r} not present in history")
        return max(vals)


class Experiment:
    """Config-driven experiment: ``build()`` assembles, ``run()`` trains."""

    def __init__(
        self,
        config: ExperimentConfig,
        *,
        corpus=None,
        eval_data: tuple[np.ndarray, np.ndarray] | None = None,
        graph=None,
        plan=None,
        hierarchy_cache=None,
        injector=None,
    ):
        self.config = config
        self.corpus = corpus          # SyntheticCorpus (labels already dropped)
        self.eval_data = eval_data    # (X_test, y_test) or None
        self.graph = graph            # AffinityGraph
        self.plan = plan              # MetaBatchPlan
        self.hierarchy_cache = hierarchy_cache  # shared HierarchyCache
        self.injector = injector      # repro.resilience.FaultInjector (chaos)
        self.pipeline: Callable | None = None   # epoch-factory callable
        self.online = None            # repro.online.OnlineManager when active
        self._built = False

    # ------------------------------------------------------------------ build
    def build(self) -> "Experiment":
        """Assemble corpus, graph, plan and batch pipeline (idempotent)."""
        if self._built:
            return self
        cfg = self.config
        if self.corpus is None:
            self.corpus, self.eval_data = self._make_data()
        if self.graph is None:
            builder = AFFINITY.get(cfg.graph.builder)
            # Only forward the construction backend to builders that take
            # it — custom AFFINITY entries keep the bare (X, k=, sigma=)
            # contract from the registry docs.
            try:
                params = inspect.signature(builder).parameters
                takes_backend = ("backend" in params or any(
                    p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values()))
            except (TypeError, ValueError):   # non-introspectable callable
                takes_backend = False
            if takes_backend:
                kw = {"backend": cfg.graph.construction}
            elif cfg.graph.construction != "host":
                raise ValueError(
                    f"graph.construction={cfg.graph.construction!r} but "
                    f"AFFINITY builder {cfg.graph.builder!r} does not "
                    f"accept a backend= argument")
            else:
                kw = {}
            self.graph = builder(self.corpus.X, k=cfg.graph.k,
                                 sigma=cfg.graph.sigma, **kw)
        needs_plan = cfg.batch.pipeline != "random_batch"
        if self.plan is None and needs_plan:
            from repro.core.metabatch import plan_meta_batches
            self.plan = plan_meta_batches(
                self.graph, batch_size=cfg.batch.batch_size,
                n_classes=self.corpus.n_classes, seed=cfg.data.seed,
                tol=cfg.partition.tol,
                shuffle_blocks=cfg.batch.shuffle_blocks,
                partitioner=PARTITIONER.get(cfg.partition.method),
                coarsen_to=cfg.partition.coarsen_to)
        factory = PIPELINE.get(cfg.batch.pipeline)
        # The async parameter-server regime consumes 1-worker batches
        # round-robin (k lives in the engine strategy, not the pipeline).
        pipeline_workers = (1 if self._strategy() == "async_ps"
                            else cfg.train.n_workers)
        # Extra keys are swallowed by factories that don't need them (the
        # uniform ``**_`` contract): the stream pipeline consumes the
        # re-partitioning config and the partition settings it re-runs with.
        self.pipeline = factory(
            self.corpus, self.graph, self.plan,
            batch_size=cfg.batch.batch_size,
            n_workers=pipeline_workers,
            with_neighbor=cfg.batch.with_neighbor,
            pad_factor=cfg.batch.pad_factor,
            pad_headroom=cfg.batch.pad_headroom,
            seed=cfg.data.seed,
            repartition=cfg.repartition,
            partitioner=PARTITIONER.get(cfg.partition.method),
            tol=cfg.partition.tol,
            coarsen_to=cfg.partition.coarsen_to,
            shuffle_blocks=cfg.batch.shuffle_blocks,
            hierarchy_cache=self._hierarchy_cache(),
            supervisor=self._replan_supervisor(),
            fault_injector=self.injector,
            record_indices=cfg.online.active,
            layout_bt=cfg.batch.layout_bt)
        if cfg.online.active:
            self.online = self._make_online_manager()
        self._built = True
        return self

    def _make_online_manager(self):
        """The ``repro.online.OnlineManager`` bound to this experiment's
        stream: refreshes the affinity graph from captured embeddings every
        ``online.refresh_every`` epochs and serves :meth:`insert`/
        :meth:`evict` for dynamic corpora."""
        from repro.online import OnlineManager
        cfg = self.config
        return OnlineManager(
            self.pipeline.stream, self.corpus, self.graph, cfg.online,
            batch_size=cfg.batch.batch_size,
            n_classes=self.corpus.n_classes,
            tol=cfg.partition.tol, coarsen_to=cfg.partition.coarsen_to,
            shuffle_blocks=cfg.batch.shuffle_blocks,
            partitioner=PARTITIONER.get(cfg.partition.method),
            embed_fn=self._embed_fn(), seed=cfg.data.seed)

    def _embed_fn(self):
        """Chunked clean forward to the tapped hidden layer — fills capture
        gaps and embeds freshly inserted rows."""
        import jax
        import jax.numpy as jnp
        from repro.models.dnn import dnn_hidden
        tap = self.config.online.tap

        hidden = jax.jit(lambda p, x: dnn_hidden(p, x, layer=tap))

        def embed(params, X, batch: int = 4096):
            outs = [np.asarray(hidden(params, jnp.asarray(X[s: s + batch])))
                    for s in range(0, len(X), batch)]
            return np.concatenate(outs) if outs else np.empty((0, 0))
        return embed

    def _replan_supervisor(self):
        """Supervisor for the stream's replan builder (None when retries
        are configured off — the stream then degrades on first failure).
        Uses ``replan_hang_timeout``, not the prefetch ``hang_timeout``:
        a real re-synthesis takes far longer than a device-put."""
        r = self.config.resilience
        if r.max_retries <= 0:
            return None
        from repro.resilience.supervisor import RetryPolicy, Supervisor
        return Supervisor(RetryPolicy(
            max_retries=r.max_retries, backoff_base=r.backoff_base,
            backoff_max=r.backoff_max, hang_timeout=r.replan_hang_timeout,
            seed=r.seed), name="replan")

    def _hierarchy_cache(self):
        """``HierarchyCache`` for hierarchy-reuse replans: the injected one
        when the constructor got ``hierarchy_cache=`` (sweeps over one
        shared graph pass the same cache so the coarsening chain is built
        once across all points), otherwise built fresh for this
        experiment.  ``None`` when re-partitioning is off, reuse is
        disabled, or the configured partitioner can't honor it (the
        stream then replans from scratch)."""
        cfg = self.config
        if not (cfg.repartition.active and cfg.repartition.reuse_hierarchy):
            return None
        from repro.introspect import accepts_kwarg
        if not accepts_kwarg(PARTITIONER.get(cfg.partition.method), "reuse"):
            return None
        if self.hierarchy_cache is not None:
            return self.hierarchy_cache
        from repro.core.partition import HierarchyCache
        return HierarchyCache(
            self.graph.W, tol=cfg.partition.tol,
            coarsen_to=cfg.partition.coarsen_to,
            seed=cfg.repartition.seed)

    def _strategy(self) -> str:
        """Effective STRATEGY name: an explicit ``ExecutionConfig.strategy``
        always wins; ``None`` falls back to the legacy
        ``TrainConfig.execution`` shorthand ("parallel" → "sync_mesh")."""
        strategy = self.config.execution.strategy
        if strategy is None:
            strategy = ("sync_mesh"
                        if self.config.train.execution == "parallel"
                        else "sequential")
        return strategy

    def _make_data(self):
        """Synthesize the train corpus + held-out test split from the config."""
        from repro.data import drop_labels, make_corpus

        d = self.config.data
        n_total = d.n + int(round(d.n * d.test_fraction))
        full = make_corpus(n_total, n_classes=d.n_classes,
                           input_dim=d.input_dim,
                           manifold_dim=d.manifold_dim,
                           structure=d.structure, seed=d.seed)
        train = dataclasses.replace(
            full, X=full.X[: d.n], y=full.y[: d.n],
            label_mask=full.label_mask[: d.n])
        eval_data = ((full.X[d.n:], full.y[d.n:])
                     if n_total > d.n else None)
        if d.label_ratio < 1.0:
            train = drop_labels(train, d.label_ratio, seed=d.seed + 1)
        return train, eval_data

    # -------------------------------------------------------------------- run
    def run(self) -> ExperimentResult:
        """Train end to end and return the structured result."""
        self.build()
        from repro.models.dnn import DNNConfig
        from repro.train.trainer import train_dnn_ssl

        cfg = self.config
        t = cfg.train
        ex = cfg.execution
        model_cfg = DNNConfig(
            input_dim=self.corpus.X.shape[1], hidden_dim=t.hidden_dim,
            n_hidden=t.n_hidden, n_classes=self.corpus.n_classes,
            dropout=t.dropout)
        strategy = self._strategy()
        # Resolve the pairwise kernel once here (with any pinned tile sizes
        # from the config) and hand the callable down — nothing below this
        # point touches the registry again.  A pipeline-built block layout
        # fixes the kernel's square tile edge: pin bi to layout_bt so the
        # block-sparse kernel's grid matches the layout the batches carry
        # (config validation already rejects a conflicting tile_bi).
        tiles = cfg.objective.tiles()
        if cfg.batch.layout_bt is not None:
            from repro.kernels.tuning import TileSpec
            tiles = tiles or TileSpec()
            if tiles.bi is None:
                tiles = dataclasses.replace(tiles, bi=cfg.batch.layout_bt)
        pairwise = resolve_pairwise(cfg.objective.pairwise, tiles=tiles)
        capture_fn = capture_epochs = on_epoch_end = None
        if self.online is not None:
            from repro.models.dnn import dnn_hidden
            import jax
            tap = cfg.online.tap

            def capture_fn(params, batch):
                # batch["x"] is (k_workers, P, d); tap the hidden layer
                # per worker row — stacked by the scan into (steps, k, P, H).
                return jax.vmap(
                    lambda xb: dnn_hidden(params, xb, layer=tap))(batch["x"])

            capture_epochs = self.online.capture_epoch
            on_epoch_end = self.online.on_epoch_end
        t0 = time.time()
        res = train_dnn_ssl(
            self.pipeline,
            cfg=model_cfg,
            hyper=cfg.objective.hyper(),
            n_epochs=t.n_epochs,
            n_workers=t.n_workers,
            base_lr=t.base_lr,
            lr_reset_epochs=t.lr_reset_epochs,
            dropout=t.dropout,
            eval_data=self.eval_data,
            seed=t.seed,
            opt=OPTIMIZER.get(t.optimizer)(),
            pairwise=pairwise,
            strategy=strategy,
            scan_chunk=ex.scan_chunk,
            prefetch=ex.prefetch,
            max_staleness=ex.max_staleness,
            checkpoint_every=ex.checkpoint_every,
            checkpoint_dir=ex.checkpoint_dir,
            resume=ex.resume,
            resilience=cfg.resilience,
            injector=self.injector,
            capture_fn=capture_fn,
            capture_epochs=capture_epochs,
            on_epoch_end=on_epoch_end)
        seconds = time.time() - t0
        final = res.history[-1] if res.history else {}
        return ExperimentResult(config=cfg, history=res.history,
                                final=final, seconds=seconds,
                                params=res.params)
