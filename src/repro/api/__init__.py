"""Public experiment layer: config-driven, registry-backed entry point.

Typical use::

    from repro.api import Experiment, ExperimentConfig, ObjectiveConfig

    cfg = ExperimentConfig(objective=ObjectiveConfig(gamma=1.0))
    result = Experiment(cfg).run()
    print(result.best("eval/acc"))

Components (affinity builders, partitioners, batch pipelines, pairwise
kernels, optimizers) are selected by name in the config and resolved through
the registries in :mod:`repro.api.registry`; register new implementations
there instead of forking the wiring.
"""
from .config import (BatchConfig, DataConfig, ExecutionConfig,
                     ExperimentConfig, GraphConfig, ObjectiveConfig,
                     OnlineConfig, PartitionConfig, RepartitionConfig,
                     ResilienceConfig, TrainConfig)
from .experiment import Experiment, ExperimentResult
from .registry import (AFFINITY, OPTIMIZER, PAIRWISE, PARTITIONER, PIPELINE,
                       STRATEGY, Registry, resolve_pairwise)

__all__ = [
    "ExperimentConfig", "DataConfig", "GraphConfig", "PartitionConfig",
    "BatchConfig", "RepartitionConfig", "ObjectiveConfig", "TrainConfig",
    "ExecutionConfig", "ResilienceConfig", "OnlineConfig",
    "Experiment", "ExperimentResult",
    "Registry", "AFFINITY", "PARTITIONER", "PIPELINE", "PAIRWISE",
    "OPTIMIZER", "STRATEGY", "resolve_pairwise",
]
