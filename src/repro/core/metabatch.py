"""Meta-batch synthesis and stochastic neighbour regularization (paper §2).

Implements the heuristic of §2.1 verbatim:

  1. Given N points, batch size B (memory constraint) and M classes,
     partition the affinity graph into ``N*M/B`` mini-blocks of ~``B/M``
     nodes each (balanced min edge-cut).
  2. Each meta-batch = M mini-blocks drawn at random (without replacement
     within an epoch) → size ~B, entropy ≈ global label entropy, and
     ``E[C_meta] >= E[C_mini]`` with ``Var[C_meta] = Var[C_mini]/K``.

and §2.2: the induced meta-batch graph ``G_M`` with edge weight
``|C_ij|`` (# affinity edges between members of meta-batches i and j), from
which a neighbour meta-batch is drawn with probability
``p_ij = |C_ij| / sum_j |C_ij|`` (Eq. 6) each step; the loss is computed on
the concatenated batch ``[M_r, M_s]`` (§2.3).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from repro.introspect import accepts_kwarg

from .affinity import AffinityGraph
from .partition import PartitionResult, edge_cut, partition_graph

__all__ = ["MetaBatchPlan", "build_mini_blocks", "synthesize_meta_batches",
           "batch_graph", "NeighborSampler", "concat_batch_indices",
           "plan_meta_batches", "plan_from_labels", "epoch_plan_seed",
           "resynthesize_plan", "BlockLayout", "tile_occupancy",
           "layout_from_occupancy", "block_layout", "plan_layout_budget"]


@dataclasses.dataclass(frozen=True)
class MetaBatchPlan:
    """Static preprocessing output consumed by the training loop."""

    mini_block_labels: np.ndarray          # mini-block id per node
    meta_batches: list[np.ndarray]         # node indices per meta-batch
    meta_of_block: np.ndarray              # meta-batch id per mini-block
    batch_edges: sp.csr_matrix             # |C_ij| weights of G_M (Eq. 6)
    batch_size: int
    n_classes: int

    @property
    def n_meta(self) -> int:
        return len(self.meta_batches)


def build_mini_blocks(
    graph: AffinityGraph,
    batch_size: int,
    n_classes: int,
    *,
    tol: float = 0.15,
    seed: int = 0,
    partitioner=None,
    coarsen_to: int = 60,
    reuse=None,
) -> PartitionResult:
    """Step 1: partition into N*M/B balanced mini-blocks of ~B/M nodes.

    ``partitioner`` is any ``(W, n_parts, *, tol, coarsen_to, seed) ->
    PartitionResult`` callable (PARTITIONER registry entries qualify);
    default is the built-in multilevel scheme.  ``reuse`` (a
    ``PartitionHierarchy`` or ``HierarchyCache``) is forwarded to
    partitioners that accept it — the incremental-replan fast path.
    """
    if batch_size < n_classes:
        # n_blocks would exceed n and the clamp below would silently hand
        # back single-node "blocks": no graph structure inside any block,
        # meta-batches degenerate to random batches.
        raise ValueError(
            f"batch_size={batch_size} < n_classes={n_classes}: each "
            f"meta-batch draws M=n_classes mini-blocks of ~B/M nodes, so "
            f"B/M < 1 produces degenerate single-node mini-blocks. "
            f"Increase batch_size to at least n_classes (ideally many "
            f"times it) or reduce n_classes.")
    n = graph.n_nodes
    n_blocks = max(1, int(round(n * n_classes / batch_size)))
    n_blocks = min(n_blocks, n)  # can't have more blocks than nodes
    part = partitioner or partition_graph
    kw = {}
    if reuse is not None:
        if not accepts_kwarg(part, "reuse"):
            raise ValueError(
                f"hierarchy reuse requested but partitioner "
                f"{getattr(part, '__name__', part)!r} does not accept a "
                f"reuse= argument; use the vectorized 'multilevel' "
                f"partitioner or disable reuse_hierarchy")
        kw["reuse"] = reuse
    return part(graph.W, n_blocks, tol=tol, coarsen_to=coarsen_to, seed=seed,
                **kw)


def synthesize_meta_batches(
    mini_blocks: PartitionResult,
    n_classes: int,
    *,
    rng: np.random.Generator,
    shuffle_blocks: bool = True,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Step 2: group M randomly-drawn mini-blocks into each meta-batch.

    Mini-blocks are drawn *without replacement* so every node appears in
    exactly one meta-batch per synthesis (an epoch covers the data once).
    ``shuffle_blocks=False`` groups CONSECUTIVE mini-blocks instead — that
    is the paper's 'pure graph-partitioned batch' baseline (§2: homogeneous,
    low-entropy, biased gradients), kept for the ablation benchmark.
    Returns (meta_batches, meta_of_block).
    """
    k = mini_blocks.n_parts
    order = rng.permutation(k) if shuffle_blocks else np.arange(k)
    groups = [order[s : s + n_classes] for s in range(0, k, n_classes)]
    # Fold a trailing undersized group into the previous one (keeps ~B size).
    if len(groups) > 1 and len(groups[-1]) < max(2, n_classes // 2):
        groups[-2] = np.concatenate([groups[-2], groups[-1]])
        groups.pop()
    # One stable argsort groups every block's (ascending) members at once —
    # the k-times-np.where scan this replaces was a visible slice of the
    # per-epoch replan cost in the many-small-blocks regime.
    by_block = np.argsort(mini_blocks.labels, kind="stable")
    counts = np.bincount(mini_blocks.labels, minlength=k)
    starts = np.concatenate(([0], np.cumsum(counts)))
    members_of_block = [by_block[starts[b] : starts[b + 1]] for b in range(k)]
    meta_batches = [
        np.concatenate([members_of_block[b] for b in g]) for g in groups
    ]
    meta_of_block = np.empty(k, dtype=np.int64)
    for mi, g in enumerate(groups):
        meta_of_block[g] = mi
    return meta_batches, meta_of_block


def batch_graph(
    graph: AffinityGraph, meta_of_node: np.ndarray, n_meta: int
) -> sp.csr_matrix:
    """Induced meta-batch graph G_M with integer edge weights |C_ij| (§2.2)."""
    coo = graph.W.tocoo()
    r = meta_of_node[coo.row]
    c = meta_of_node[coo.col]
    keep = r != c
    if n_meta <= 2048:
        # |C_ij| is a *count* of crossing affinity edges: one bincount over
        # the flattened (i, j) key replaces the duplicate-summing CSR
        # assembly (a visible slice of the per-epoch replan cost).
        key = r[keep].astype(np.int64) * n_meta + c[keep]
        counts = np.bincount(key, minlength=n_meta * n_meta)
        # Each unique node pair was counted twice (W symmetric) -> halve.
        E = sp.csr_matrix((counts / 2.0).reshape(n_meta, n_meta))
        E.eliminate_zeros()
        return E.tocsr()
    ones = np.ones(keep.sum())
    E = sp.csr_matrix((ones, (r[keep], c[keep])), shape=(n_meta, n_meta))
    E.sum_duplicates()
    E.data = E.data / 2.0
    return E.tocsr()


def plan_meta_batches(
    graph: AffinityGraph,
    batch_size: int,
    n_classes: int,
    *,
    seed: int = 0,
    tol: float = 0.15,
    shuffle_blocks: bool = True,
    partitioner=None,
    coarsen_to: int = 60,
    reuse=None,
) -> MetaBatchPlan:
    """One-shot preprocessing: mini-blocks -> meta-batches -> batch graph."""
    rng = np.random.default_rng(seed)
    mini = build_mini_blocks(graph, batch_size, n_classes, tol=tol, seed=seed,
                             partitioner=partitioner, coarsen_to=coarsen_to,
                             reuse=reuse)
    metas, meta_of_block = synthesize_meta_batches(
        mini, n_classes, rng=rng, shuffle_blocks=shuffle_blocks)
    meta_of_node = meta_of_block[mini.labels]
    E = batch_graph(graph, meta_of_node, len(metas))
    return MetaBatchPlan(
        mini_block_labels=mini.labels,
        meta_batches=metas,
        meta_of_block=meta_of_block,
        batch_edges=E,
        batch_size=batch_size,
        n_classes=n_classes,
    )


def plan_from_labels(
    graph: AffinityGraph,
    labels: np.ndarray,
    batch_size: int,
    n_classes: int,
    *,
    seed: int = 0,
    shuffle_blocks: bool = True,
) -> MetaBatchPlan:
    """Re-group an *existing* mini-block labeling into a fresh plan.

    The online insert/evict and low-churn refresh paths already hold
    delta-repaired labels (``repair_partition`` / ``extend_partition``) —
    this skips the partitioner entirely and runs only the §2.2 grouping:
    shuffled mini-block → meta-batch assignment plus the induced batch
    graph, deterministic per ``(labels, seed)``.
    """
    labels = np.asarray(labels, dtype=np.int64)
    if labels.shape[0] != graph.n_nodes:
        raise ValueError(
            f"labels cover {labels.shape[0]} nodes, graph has "
            f"{graph.n_nodes}")
    n_parts = int(labels.max()) + 1 if labels.size else 0
    mini = PartitionResult(
        labels=labels, n_parts=n_parts,
        cut=edge_cut(graph.W, labels),
        sizes=np.bincount(labels, minlength=n_parts))
    rng = np.random.default_rng(seed)
    metas, meta_of_block = synthesize_meta_batches(
        mini, n_classes, rng=rng, shuffle_blocks=shuffle_blocks)
    meta_of_node = meta_of_block[labels]
    E = batch_graph(graph, meta_of_node, len(metas))
    return MetaBatchPlan(
        mini_block_labels=labels,
        meta_batches=metas,
        meta_of_block=meta_of_block,
        batch_edges=E,
        batch_size=batch_size,
        n_classes=n_classes,
    )


def epoch_plan_seed(base_seed: int, epoch: int) -> int:
    """Deterministic per-epoch seed stream for stochastic re-partitioning.

    Derived through ``np.random.SeedSequence([base_seed, epoch])`` so the
    epoch seeds are decorrelated (not just ``base_seed + epoch``) while
    identical ``(base_seed, epoch)`` pairs stay bit-reproducible across
    processes and platforms.
    """
    ss = np.random.SeedSequence([int(base_seed), int(epoch)])
    return int(ss.generate_state(1, dtype=np.uint32)[0])


def resynthesize_plan(
    graph: AffinityGraph,
    batch_size: int,
    n_classes: int,
    *,
    epoch: int,
    base_seed: int = 0,
    temperature: float = 0.0,
    tol: float = 0.15,
    shuffle_blocks: bool = True,
    partitioner=None,
    coarsen_to: int = 60,
    reuse=None,
) -> MetaBatchPlan:
    """Plan for one epoch of the stochastic re-partitioning stream (§2).

    A pure function of ``(graph, config, base_seed, epoch)``: identical
    inputs yield bit-identical plans (safe to compute on a background
    thread), while different epochs draw a fresh partition from the
    ``temperature``-perturbed matching distribution — batch composition
    stays stochastic across epochs, as the abstract's "enough
    stochasticity for SGD" requires.

    ``temperature`` is forwarded to the partitioner only when its signature
    accepts it (the built-in vectorized partitioner does); requesting
    ``temperature > 0`` from a partitioner that cannot honor it raises.

    ``reuse`` hands the partitioner a cached coarsening hierarchy (a
    ``PartitionHierarchy`` or ``HierarchyCache``): the replan skips the
    frozen fine-level coarsening and re-draws only the top of the chain
    plus the initial partition and refinement.  The hierarchy is itself a
    pure function of ``(graph, k, config, seed)`` — never of the epoch —
    so reuse keeps the bit-reproducibility contract: identical
    ``(base_seed, epoch)`` pairs yield identical plans no matter when (or
    whether) the hierarchy was built.
    """
    part = partitioner or partition_graph
    if temperature != 0.0:
        if not accepts_kwarg(part, "temperature"):
            raise ValueError(
                f"matching_temperature={temperature} but partitioner "
                f"{getattr(part, '__name__', part)!r} does not accept a "
                f"temperature= argument; use the vectorized 'multilevel' "
                f"partitioner or set matching_temperature=0")
        import functools
        part = functools.partial(part, temperature=temperature)
    return plan_meta_batches(
        graph, batch_size=batch_size, n_classes=n_classes,
        seed=epoch_plan_seed(base_seed, epoch), tol=tol,
        shuffle_blocks=shuffle_blocks, partitioner=part,
        coarsen_to=coarsen_to, reuse=reuse)


class NeighborSampler:
    """Samples a neighbour meta-batch with p_ij = |C_ij| / sum_j |C_ij| (Eq. 6)."""

    def __init__(self, batch_edges: sp.csr_matrix, *, seed: int = 0):
        self.E = batch_edges.tocsr()
        self.rng = np.random.default_rng(seed)

    def probs(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """Neighbour ids and their selection probabilities for meta-batch i."""
        s, e = self.E.indptr[i], self.E.indptr[i + 1]
        nbrs = self.E.indices[s:e]
        w = self.E.data[s:e]
        tot = w.sum()
        if tot <= 0 or len(nbrs) == 0:
            return np.array([], dtype=np.int64), np.array([])
        return nbrs, w / tot

    def sample(self, i: int) -> int | None:
        nbrs, p = self.probs(i)
        if len(nbrs) == 0:
            return None
        return int(self.rng.choice(nbrs, p=p))


def concat_batch_indices(
    plan: MetaBatchPlan, i: int, j: int | None
) -> np.ndarray:
    """Node indices of the concatenated batch M_c = [M_r, M_s] (§2.3)."""
    if j is None:
        return plan.meta_batches[i]
    return np.concatenate([plan.meta_batches[i], plan.meta_batches[j]])


# --------------------------------------------------------------------------
# Block-sparse tile layout (consumed by kernels/graph_reg blocksparse path)
#
# After §2 partitioning the concatenated-batch affinity block W is
# block-structured: most bt×bt tiles off the mini-block diagonal are exact
# structural zeros.  A ``BlockLayout`` records which tiles are occupied as
#   * a dense (nt, nt) int32 occupancy mask, and
#   * two padded active-tile index lists — row-major (CSR-style, drives the
#     forward / dL/dlogp kernels) and column-major (CSC-style, drives the
#     Wᵀ·P pass of the VJP) — each entry an (row, col, valid) triple.
# Both lists share one static length so jitted kernel shapes never change
# across batches; the padding convention is part of the kernel contract:
#   * every EMPTY tile row (resp. column) still gets one sentinel entry
#     (row, 0, valid=0) so the row's output block is visited and written
#     (Pallas only flushes an output block when the grid visits it), and
#   * length padding repeats the LAST entry with valid=0 — same (row, col)
#     as the real tail, so no new accumulation strip starts and the
#     strip-finalize predicate fires exactly once, at the final pad tile.
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Static tile-occupancy layout of one padded batch affinity block."""

    bt: int                    # square tile edge (rows == cols per tile)
    nt: int                    # number of tiles per side (padded B / bt)
    n_active: int              # occupied tiles (<= nt*nt)
    rows: np.ndarray           # (T,) int32 — row-major list: tile row ids
    cols: np.ndarray           # (T,) int32 — row-major list: tile col ids
    valid: np.ndarray          # (T,) int32 — 1 = real tile, 0 = sentinel/pad
    crows: np.ndarray          # (T,) int32 — col-major list: tile row ids
    ccols: np.ndarray          # (T,) int32 — col-major list: tile col ids
    cvalid: np.ndarray         # (T,) int32
    occ: np.ndarray            # (nt, nt) int32 occupancy mask

    @property
    def list_len(self) -> int:
        return int(self.rows.shape[0])

    @property
    def density(self) -> float:
        """Fraction of tiles occupied — the FLOP ratio vs the dense sweep."""
        return self.n_active / float(self.nt * self.nt)

    def arrays(self) -> tuple[np.ndarray, ...]:
        """The 7-tuple the kernels consume (order is the ops contract)."""
        return (self.rows, self.cols, self.valid,
                self.crows, self.ccols, self.cvalid, self.occ)


def tile_occupancy(W: np.ndarray, bt: int) -> np.ndarray:
    """(nt, nt) bool mask: tile (i, j) is True iff any W entry in it is != 0.

    Exact occupancy — the block-sparse regularizer over this mask equals
    the dense regularizer bit-for-bit semantics-wise (a skipped tile is an
    all-zero tile, contributing nothing to any Eq.-3/4 term).
    """
    if W.ndim != 2 or W.shape[0] != W.shape[1]:
        raise ValueError(f"W must be square, got shape {W.shape}")
    B = W.shape[0]
    nt = -(-B // bt)
    P = nt * bt
    if P != B:
        Wp = np.zeros((P, P), dtype=W.dtype)
        Wp[:B, :B] = W
    else:
        Wp = W
    return Wp.reshape(nt, bt, nt, bt).any(axis=(1, 3))


def _tile_list(occ: np.ndarray, *, by_col: bool) -> tuple[np.ndarray, ...]:
    """Active-tile (rows, cols, valid) in row-major or col-major order,
    with one (major, 0, valid=0) sentinel per empty major line."""
    nt = occ.shape[0]
    if by_col:
        c, r = np.nonzero(occ.T)        # sorted by col, then row
        major = c
    else:
        r, c = np.nonzero(occ)          # sorted by row, then col
        major = r
    present = np.zeros(nt, dtype=bool)
    present[major] = True
    missing = np.flatnonzero(~present)
    zeros = np.zeros(len(missing), dtype=np.int64)
    if by_col:
        rows = np.concatenate([r, zeros])
        cols = np.concatenate([c, missing])
        order = np.argsort(cols, kind="stable")
    else:
        rows = np.concatenate([r, missing])
        cols = np.concatenate([c, zeros])
        order = np.argsort(rows, kind="stable")
    valid = np.concatenate([np.ones(len(r), dtype=np.int32),
                            np.zeros(len(missing), dtype=np.int32)])
    return (rows[order].astype(np.int32), cols[order].astype(np.int32),
            valid[order])


def _pad_tile_list(rows, cols, valid, n: int):
    """Pad to length n by repeating the last entry with valid=0."""
    cur = len(rows)
    if cur > n:
        raise ValueError(
            f"tile list length {cur} exceeds the pinned layout budget {n}; "
            f"raise the budget (plan_layout_budget headroom) or the tile "
            f"size")
    if cur == n:
        return rows, cols, valid
    pad = n - cur
    rows = np.concatenate([rows, np.full(pad, rows[-1], dtype=np.int32)])
    cols = np.concatenate([cols, np.full(pad, cols[-1], dtype=np.int32)])
    valid = np.concatenate([valid, np.zeros(pad, dtype=np.int32)])
    return rows, cols, valid


def layout_from_occupancy(
    occ: np.ndarray, bt: int, *, list_len: int | None = None
) -> BlockLayout:
    """Build the padded index lists from a boolean (nt, nt) occupancy mask."""
    occ = np.asarray(occ, dtype=bool)
    if occ.ndim != 2 or occ.shape[0] != occ.shape[1]:
        raise ValueError(f"occ must be square, got shape {occ.shape}")
    nt = occ.shape[0]
    rows, cols, valid = _tile_list(occ, by_col=False)
    crows, ccols, cvalid = _tile_list(occ, by_col=True)
    n = max(len(rows), len(crows)) if list_len is None else int(list_len)
    rows, cols, valid = _pad_tile_list(rows, cols, valid, n)
    crows, ccols, cvalid = _pad_tile_list(crows, ccols, cvalid, n)
    return BlockLayout(
        bt=int(bt), nt=nt, n_active=int(occ.sum()),
        rows=rows, cols=cols, valid=valid,
        crows=crows, ccols=ccols, cvalid=cvalid,
        occ=occ.astype(np.int32))


def block_layout(
    W: np.ndarray, bt: int, *, list_len: int | None = None
) -> BlockLayout:
    """BlockLayout of a (padded) dense batch affinity block W."""
    return layout_from_occupancy(tile_occupancy(W, bt), bt,
                                 list_len=list_len)


def plan_layout_budget(
    plan: MetaBatchPlan,
    graph: AffinityGraph,
    bt: int,
    pad: int,
    *,
    with_neighbor: bool = True,
    headroom: float = 1.25,
) -> int:
    """Static tile-list length covering every batch this plan can emit.

    Walks every Eq.-6 support pair (r, s) with |C_rs| > 0 (plus the
    neighbourless singletons) and computes the exact padded-tile list
    length of the assembled [M_r, M_s] batch — active tiles plus one
    sentinel per empty tile row/column.  The max over pairs, scaled by
    ``headroom`` (slack for re-partitioned plans) and rounded up to a
    multiple of 8, is the shared static list length the jitted kernels
    are shaped with.  Pure host-side preprocessing — nothing here runs
    per training step.
    """
    nt = -(-pad // bt)
    W = graph.W.tocsr()
    pairs: list[tuple[int, int | None]] = [(i, None)
                                           for i in range(plan.n_meta)]
    if with_neighbor:
        coo = plan.batch_edges.tocoo()
        pairs += [(int(i), int(j)) for i, j in zip(coo.row, coo.col)]
    need = nt  # floor: an all-empty mask still carries nt sentinels
    for i, j in pairs:
        idx = concat_batch_indices(plan, i, j)
        sub = W[idx][:, idx].tocoo()
        if sub.nnz == 0:
            continue
        tr = sub.row // bt
        tc = sub.col // bt
        n_active = len(np.unique(tr.astype(np.int64) * nt + tc))
        n_csr = n_active + (nt - len(np.unique(tr)))
        n_csc = n_active + (nt - len(np.unique(tc)))
        need = max(need, n_csr, n_csc)
    return int(np.ceil(need * headroom / 8.0) * 8)
