"""Meta-batch synthesis and stochastic neighbour regularization (paper §2).

Implements the heuristic of §2.1 verbatim:

  1. Given N points, batch size B (memory constraint) and M classes,
     partition the affinity graph into ``N*M/B`` mini-blocks of ~``B/M``
     nodes each (balanced min edge-cut).
  2. Each meta-batch = M mini-blocks drawn at random (without replacement
     within an epoch) → size ~B, entropy ≈ global label entropy, and
     ``E[C_meta] >= E[C_mini]`` with ``Var[C_meta] = Var[C_mini]/K``.

and §2.2: the induced meta-batch graph ``G_M`` with edge weight
``|C_ij|`` (# affinity edges between members of meta-batches i and j), from
which a neighbour meta-batch is drawn with probability
``p_ij = |C_ij| / sum_j |C_ij|`` (Eq. 6) each step; the loss is computed on
the concatenated batch ``[M_r, M_s]`` (§2.3).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

from .affinity import AffinityGraph
from .partition import PartitionResult, partition_graph

__all__ = ["MetaBatchPlan", "build_mini_blocks", "synthesize_meta_batches",
           "batch_graph", "NeighborSampler", "concat_batch_indices"]


@dataclasses.dataclass(frozen=True)
class MetaBatchPlan:
    """Static preprocessing output consumed by the training loop."""

    mini_block_labels: np.ndarray          # mini-block id per node
    meta_batches: list[np.ndarray]         # node indices per meta-batch
    meta_of_block: np.ndarray              # meta-batch id per mini-block
    batch_edges: sp.csr_matrix             # |C_ij| weights of G_M (Eq. 6)
    batch_size: int
    n_classes: int

    @property
    def n_meta(self) -> int:
        return len(self.meta_batches)


def build_mini_blocks(
    graph: AffinityGraph,
    batch_size: int,
    n_classes: int,
    *,
    tol: float = 0.15,
    seed: int = 0,
    partitioner=None,
    coarsen_to: int = 60,
) -> PartitionResult:
    """Step 1: partition into N*M/B balanced mini-blocks of ~B/M nodes.

    ``partitioner`` is any ``(W, n_parts, *, tol, coarsen_to, seed) ->
    PartitionResult`` callable (PARTITIONER registry entries qualify);
    default is the built-in multilevel scheme.
    """
    n = graph.n_nodes
    n_blocks = max(1, int(round(n * n_classes / batch_size)))
    n_blocks = min(n_blocks, n)  # can't have more blocks than nodes
    part = partitioner or partition_graph
    return part(graph.W, n_blocks, tol=tol, coarsen_to=coarsen_to, seed=seed)


def synthesize_meta_batches(
    mini_blocks: PartitionResult,
    n_classes: int,
    *,
    rng: np.random.Generator,
    shuffle_blocks: bool = True,
) -> tuple[list[np.ndarray], np.ndarray]:
    """Step 2: group M randomly-drawn mini-blocks into each meta-batch.

    Mini-blocks are drawn *without replacement* so every node appears in
    exactly one meta-batch per synthesis (an epoch covers the data once).
    ``shuffle_blocks=False`` groups CONSECUTIVE mini-blocks instead — that
    is the paper's 'pure graph-partitioned batch' baseline (§2: homogeneous,
    low-entropy, biased gradients), kept for the ablation benchmark.
    Returns (meta_batches, meta_of_block).
    """
    k = mini_blocks.n_parts
    order = rng.permutation(k) if shuffle_blocks else np.arange(k)
    groups = [order[s : s + n_classes] for s in range(0, k, n_classes)]
    # Fold a trailing undersized group into the previous one (keeps ~B size).
    if len(groups) > 1 and len(groups[-1]) < max(2, n_classes // 2):
        groups[-2] = np.concatenate([groups[-2], groups[-1]])
        groups.pop()
    members_of_block = [np.where(mini_blocks.labels == b)[0] for b in range(k)]
    meta_batches = [
        np.concatenate([members_of_block[b] for b in g]) for g in groups
    ]
    meta_of_block = np.empty(k, dtype=np.int64)
    for mi, g in enumerate(groups):
        meta_of_block[g] = mi
    return meta_batches, meta_of_block


def batch_graph(
    graph: AffinityGraph, meta_of_node: np.ndarray, n_meta: int
) -> sp.csr_matrix:
    """Induced meta-batch graph G_M with integer edge weights |C_ij| (§2.2)."""
    coo = graph.W.tocoo()
    r = meta_of_node[coo.row]
    c = meta_of_node[coo.col]
    keep = r != c
    ones = np.ones(keep.sum())
    E = sp.csr_matrix((ones, (r[keep], c[keep])), shape=(n_meta, n_meta))
    E.sum_duplicates()
    # Each unique node pair was counted twice (W symmetric) -> halve.
    E.data = E.data / 2.0
    return E.tocsr()


def plan_meta_batches(
    graph: AffinityGraph,
    batch_size: int,
    n_classes: int,
    *,
    seed: int = 0,
    tol: float = 0.15,
    shuffle_blocks: bool = True,
    partitioner=None,
    coarsen_to: int = 60,
) -> MetaBatchPlan:
    """One-shot preprocessing: mini-blocks -> meta-batches -> batch graph."""
    rng = np.random.default_rng(seed)
    mini = build_mini_blocks(graph, batch_size, n_classes, tol=tol, seed=seed,
                             partitioner=partitioner, coarsen_to=coarsen_to)
    metas, meta_of_block = synthesize_meta_batches(
        mini, n_classes, rng=rng, shuffle_blocks=shuffle_blocks)
    meta_of_node = meta_of_block[mini.labels]
    E = batch_graph(graph, meta_of_node, len(metas))
    return MetaBatchPlan(
        mini_block_labels=mini.labels,
        meta_batches=metas,
        meta_of_block=meta_of_block,
        batch_edges=E,
        batch_size=batch_size,
        n_classes=n_classes,
    )


class NeighborSampler:
    """Samples a neighbour meta-batch with p_ij = |C_ij| / sum_j |C_ij| (Eq. 6)."""

    def __init__(self, batch_edges: sp.csr_matrix, *, seed: int = 0):
        self.E = batch_edges.tocsr()
        self.rng = np.random.default_rng(seed)

    def probs(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        """Neighbour ids and their selection probabilities for meta-batch i."""
        s, e = self.E.indptr[i], self.E.indptr[i + 1]
        nbrs = self.E.indices[s:e]
        w = self.E.data[s:e]
        tot = w.sum()
        if tot <= 0 or len(nbrs) == 0:
            return np.array([], dtype=np.int64), np.array([])
        return nbrs, w / tot

    def sample(self, i: int) -> int | None:
        nbrs, p = self.probs(i)
        if len(nbrs) == 0:
            return None
        return int(self.rng.choice(nbrs, p=p))


def concat_batch_indices(
    plan: MetaBatchPlan, i: int, j: int | None
) -> np.ndarray:
    """Node indices of the concatenated batch M_c = [M_r, M_s] (§2.3)."""
    if j is None:
        return plan.meta_batches[i]
    return np.concatenate([plan.meta_batches[i], plan.meta_batches[j]])
