"""Balanced min-edge-cut graph partitioning (METIS replacement, paper §1.1).

The paper uses METIS's multilevel k-way scheme [Karypis & Kumar 1998] to
split the affinity graph into balanced blocks, which re-permutes the affinity
matrix into a dense block-diagonal form (Fig. 1b).  METIS is not available in
this container and the brief requires every substrate to be built, so this is
a from-scratch multilevel partitioner with the same three phases:

  1. **Coarsening** — heavy-edge matching collapses the graph level by level.
  2. **Initial partitioning** — seeded growth on the coarsest graph toward a
     balanced target weight.
  3. **Uncoarsening + refinement** — project labels back up, then
     Fiduccia–Mattheyses-style boundary passes move nodes to reduce edge-cut
     subject to a balance tolerance.

Two implementations share that scheme and the ``PartitionResult`` contract:

  * :func:`partition_graph` — the **vectorized** default: every phase is
    numpy/scipy batched array ops (mutual-heaviest handshake matching via
    argsort over permuted priorities, CSR-segment reductions for FM gain
    computation, capacity-limited batched moves), so partition wall-clock
    stays flat in the Python interpreter and scales to the ROADMAP's
    corpus-sized graphs.  It also exposes ``temperature`` — Gumbel-perturbed
    matching weights — which is the stochastic re-partitioning stream's
    entropy knob (§2: "enough stochasticity for SGD").
  * :func:`partition_graph_loop` — the original per-node-loop implementation,
    kept verbatim as the quality/semantics reference: the property-based
    suite asserts the vectorized cut stays within 5% of it on identical
    seeds, and ``benchmarks/bench_partition.py`` tracks the speedup.

Host-side preprocessing (numpy/scipy); the vectorized path is cheap enough
to run *between epochs* (see ``repro.data.pipeline.MetaBatchStream``).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import connected_components

__all__ = [
    "PartitionResult",
    "partition_graph",
    "partition_graph_loop",
    "edge_cut",
    "partition_permutation",
]


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    labels: np.ndarray        # part id per node, shape (n,)
    n_parts: int
    cut: float                # total weight of cut edges
    sizes: np.ndarray         # nodes per part


def edge_cut(W: sp.csr_matrix, labels: np.ndarray) -> float:
    """Total weight of edges crossing parts (each undirected edge once)."""
    coo = W.tocoo()
    mask = labels[coo.row] != labels[coo.col]
    return float(coo.data[mask].sum()) / 2.0


# ===========================================================================
# Seed per-node-loop implementation (reference; see partition_graph_loop).
# ===========================================================================
def _heavy_edge_matching(
    W: sp.csr_matrix, node_w: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """One level of heavy-edge matching. Returns coarse-node id per node."""
    n = W.shape[0]
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    indptr, indices, data = W.indptr, W.indices, W.data
    for u in order:
        if match[u] != -1:
            continue
        best, best_w = -1, -np.inf
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v != u and match[v] == -1 and data[e] > best_w:
                best, best_w = v, data[e]
        match[u] = u if best == -1 else best
        if best != -1:
            match[best] = u
    # Assign coarse ids: one per matched pair / singleton.
    coarse = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for u in range(n):
        if coarse[u] == -1:
            coarse[u] = nxt
            v = match[u]
            if v != u and coarse[v] == -1:
                coarse[v] = nxt
            nxt += 1
    return coarse


def _contract(
    W: sp.csr_matrix, node_w: np.ndarray, coarse: np.ndarray
) -> tuple[sp.csr_matrix, np.ndarray]:
    """Collapse matched nodes: sum duplicate (coarse-row, coarse-col) edge
    weights, dropping the diagonal — one radix argsort + segment reduction,
    with the CSR assembled directly from the sorted unique keys."""
    nc = int(coarse.max()) + 1
    coo = W.tocoo()
    r, c = coarse[coo.row], coarse[coo.col]
    keep = r != c
    r, c, d = r[keep], c[keep], coo.data[keep]
    nw = np.zeros(nc, dtype=node_w.dtype)
    np.add.at(nw, coarse, node_w)
    if len(d) == 0:       # every edge collapsed into a coarse self-loop
        return sp.csr_matrix((nc, nc)), nw
    key = r.astype(np.int64) * nc + c
    o = np.argsort(key, kind="stable")
    ks, ds = key[o], d[o]
    starts = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]])
    sums = np.add.reduceat(ds, starts)
    uk = ks[starts]
    ur, uc = uk // nc, (uk % nc).astype(np.int32)
    indptr = np.zeros(nc + 1, dtype=np.int64)
    np.cumsum(np.bincount(ur, minlength=nc), out=indptr[1:])
    Wc = sp.csr_matrix((sums, uc, indptr), shape=(nc, nc))
    return Wc, nw


def _region_grow(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Greedy seeded growth into k parts targeting equal node weight."""
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = node_w.sum() / k
    indptr, indices, data = W.indptr, W.indices, W.data
    struct_deg = np.diff(indptr)
    unassigned = set(range(n))
    for part in range(k - 1):
        if not unassigned:
            break
        # Seed: highest-degree unassigned node (well-connected core).
        seed = max(unassigned, key=lambda u: struct_deg[u])
        labels[seed] = part
        unassigned.discard(seed)
        size = node_w[seed]
        # Frontier scores: connection weight to this part.
        gain: dict[int, float] = {}
        for e in range(indptr[seed], indptr[seed + 1]):
            v = indices[e]
            if labels[v] == -1:
                gain[v] = gain.get(v, 0.0) + data[e]
        while size < target and gain:
            u = max(gain, key=gain.get)
            del gain[u]
            if labels[u] != -1:
                continue
            labels[u] = part
            unassigned.discard(u)
            size += node_w[u]
            for e in range(indptr[u], indptr[u + 1]):
                v = indices[e]
                if labels[v] == -1:
                    gain[v] = gain.get(v, 0.0) + data[e]
    # Everything left goes to the last part; stragglers get folded in below.
    for u in unassigned:
        labels[u] = k - 1
    return labels


def _refine(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    tol: float,
    passes: int = 4,
) -> np.ndarray:
    """FM-style boundary refinement: greedy gain moves under balance."""
    n = W.shape[0]
    indptr, indices, data = W.indptr, W.indices, W.data
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    max_w = node_w.sum() / k * (1.0 + tol)
    min_w = node_w.sum() / k * (1.0 - tol)
    for _ in range(passes):
        moved = 0
        for u in range(n):
            lu = labels[u]
            if part_w[lu] - node_w[u] < min_w:
                continue
            # Connection weight to each adjacent part.
            conn: dict[int, float] = {}
            for e in range(indptr[u], indptr[u + 1]):
                lv = labels[indices[e]]
                conn[lv] = conn.get(lv, 0.0) + data[e]
            internal = conn.get(lu, 0.0)
            best_part, best_gain = lu, 0.0
            for p, w in conn.items():
                if p == lu or part_w[p] + node_w[u] > max_w:
                    continue
                gain = w - internal
                if gain > best_gain:
                    best_part, best_gain = p, gain
            if best_part != lu:
                part_w[lu] -= node_w[u]
                part_w[best_part] += node_w[u]
                labels[u] = best_part
                moved += 1
        if moved == 0:
            break
    return labels


def _rebalance(labels: np.ndarray, node_w: np.ndarray, k: int, tol: float,
               W: sp.csr_matrix) -> np.ndarray:
    """Hard balance pass: move lowest-connectivity nodes out of oversized parts."""
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    target = node_w.sum() / k
    max_w = target * (1.0 + tol)
    indptr, indices, data = W.indptr, W.indices, W.data
    for p in np.argsort(-part_w):
        while part_w[p] > max_w:
            members = np.where(labels == p)[0]
            # Pick member with least internal connectivity to evict.
            best_u, best_int = -1, np.inf
            for u in members:
                internal = 0.0
                for e in range(indptr[u], indptr[u + 1]):
                    if labels[indices[e]] == p:
                        internal += data[e]
                if internal < best_int:
                    best_u, best_int = u, internal
            dest = int(np.argmin(part_w))
            if dest == p:
                break
            part_w[p] -= node_w[best_u]
            part_w[dest] += node_w[best_u]
            labels[best_u] = dest
    return labels


def partition_graph_loop(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
) -> PartitionResult:
    """The seed per-node-loop multilevel partitioner (quality reference).

    Same contract as :func:`partition_graph`; every phase iterates node by
    node in the interpreter, so it is O(n) Python dispatches per level —
    kept for the property-based equivalence suite and the partition
    benchmark, not for production paths.
    """
    if k <= 1:
        labels = np.zeros(W.shape[0], dtype=np.int64)
        return PartitionResult(labels, 1, 0.0, np.array([W.shape[0]]))
    rng = np.random.default_rng(seed)
    n0 = W.shape[0]
    graphs = [(W.tocsr(), np.ones(n0))]
    maps: list[np.ndarray] = []
    # --- coarsening ---
    while graphs[-1][0].shape[0] > max(coarsen_to * k, 2 * k):
        Wc0, nw0 = graphs[-1]
        coarse = _heavy_edge_matching(Wc0, nw0, rng)
        if coarse.max() + 1 >= Wc0.shape[0]:  # matching stalled
            break
        Wc, nw = _contract(Wc0, nw0, coarse)
        graphs.append((Wc, nw))
        maps.append(coarse)
    # --- initial partition on coarsest ---
    Wc, nw = graphs[-1]
    labels = _region_grow(Wc, nw, k, rng)
    labels = _refine(Wc, nw, labels, k, tol)
    # --- uncoarsen + refine ---
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        Wl, nwl = graphs[level]
        labels = _refine(Wl, nwl, labels, k, tol)
    Wf, nwf = graphs[0]
    labels = _rebalance(labels, nwf, k, tol, Wf)
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


# ===========================================================================
# Vectorized implementation (the default partition_graph).
# ===========================================================================
_COARSE_STOP = 512      # never coarsen below this many nodes


def _sym_edges(W: sp.spmatrix):
    """(row, col, w) with self-loops dropped; weights as float64."""
    coo = W.tocoo()
    keep = coo.row != coo.col
    return (coo.row[keep].astype(np.int64), coo.col[keep].astype(np.int64),
            coo.data[keep].astype(np.float64))


def _heavy_edge_coarsen(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    rng: np.random.Generator,
    temperature: float = 0.0,
    w_cap: float | None = None,
) -> np.ndarray:
    """One coarsening level: heavy-edge *star* contraction, fully batched.

    Every node points at its heaviest usable neighbour (one CSR-segment
    ``maximum.reduceat`` with a symmetric permuted-priority tie-break
    instead of the loop version's random visit order), and the weakly
    connected components of that best-neighbour forest collapse into coarse
    nodes — a C-level ``connected_components`` call.  Stars and chains give
    ~3× reduction per level versus ~1.8× for pairwise matching, so half the
    levels exist at all.

    ``w_cap`` bounds coarse-node weight — METIS's vertex-weight limit,
    which keeps coarse nodes small relative to the part target so the
    coarsest partition can still be balanced: edges whose merged endpoint
    weight exceeds it are unusable, and any over-heavy component is split
    into cap-sized chunks.

    ``temperature > 0`` multiplies edge weights by ``exp(T·Gumbel)`` before
    the argmax — the stochastic re-partitioning knob: identical seeds stay
    bit-reproducible while different seeds explore distinct coarsenings.
    """
    n = W.shape[0]
    indptr, indices, data = W.indptr, W.indices, W.data
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(n), deg)
    key = data.astype(np.float64)
    if temperature > 0.0:
        g = -np.log(-np.log(rng.uniform(1e-12, 1.0 - 1e-12, size=len(key))))
        key = key * np.exp(temperature * g)
    prio = rng.permutation(n).astype(np.float64)
    scale = float(key.max()) if len(key) else 1.0
    # Symmetric tie-break, distinct within a node's edge list: makes the
    # per-row argmax a strict total order.
    key = key + (1e-9 * scale / max(n, 1)) * (prio[rows] + prio[indices])
    valid = rows != indices
    if w_cap is not None:
        valid &= (node_w[rows] + node_w[indices]) <= w_cap
    keym = np.where(valid, key, -np.inf)
    if len(rows) == 0:
        return np.arange(n, dtype=np.int64)
    seg_start = np.flatnonzero(np.r_[True, rows[1:] != rows[:-1]])
    rowmax = np.maximum.reduceat(keym, seg_start)
    hit = keym == np.repeat(rowmax,
                            np.diff(np.r_[seg_start, len(rows)]))
    hit &= np.isfinite(keym)               # rows with no usable edge at all
    hr, hc = rows[hit], indices[hit]
    if len(hr) == 0:                       # every edge blocked (w_cap): stall
        return np.arange(n, dtype=np.int64)
    hfirst = np.r_[True, hr[1:] != hr[:-1]]
    bu, bv = hr[hfirst], hc[hfirst]        # best-neighbour forest edges
    F = sp.csr_matrix((np.ones(len(bu)), (bu, bv)), shape=(n, n))
    _, comp = connected_components(F, directed=True, connection="weak")
    comp = comp.astype(np.int64)
    if w_cap is not None:
        cw = np.bincount(comp, weights=node_w)
        if (cw > w_cap).any():
            # Split over-heavy components into cap-sized weight chunks.
            o = np.argsort(comp, kind="stable")
            cs = comp[o]
            wo = node_w[o].astype(np.float64)
            starts = np.flatnonzero(np.r_[True, cs[1:] != cs[:-1]])
            cum = np.cumsum(wo)
            base = np.repeat(cum[starts] - wo[starts],
                             np.diff(np.r_[starts, n]))
            sub = ((cum - base - 0.5 * wo) // w_cap).astype(np.int64)
            keyc = cs * (int(sub.max()) + 1) + sub
            split = np.unique(keyc, return_inverse=True)[1]
            comp = np.empty(n, dtype=np.int64)
            comp[o] = split
    return comp


def _adjacency(W: sp.csr_matrix, nodes: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Concatenated (neighbours, weights) of ``nodes`` — a batched CSR
    gather replacing per-node ``indptr`` loops."""
    indptr, indices, data = W.indptr, W.indices, W.data
    cnt = indptr[nodes + 1] - indptr[nodes]
    total = int(cnt.sum())
    if total == 0:
        return (np.empty(0, dtype=indices.dtype),
                np.empty(0, dtype=data.dtype))
    offs = (np.repeat(indptr[nodes], cnt)
            + np.arange(total)
            - np.repeat(np.cumsum(cnt) - cnt, cnt))
    return indices[offs], data[offs]


def _region_grow_seq(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Seeded growth into k parts, one part at a time (small graphs).

    Same scheme as the loop version — grow each part from a high-degree
    seed by strongest connection until it reaches the balance target — but
    each absorption is a batched CSR adjacency gather + argmax instead of
    per-node dict bookkeeping.
    """
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = float(node_w.sum()) / k
    struct_deg = np.diff(W.indptr).astype(np.float64)
    jitter = rng.random(n) if jitter_seeds else np.zeros(n)
    conn = np.zeros(n)
    for part in range(k - 1):
        avail = labels == -1
        if not avail.any():
            break
        ua = np.flatnonzero(avail)
        seed = int(ua[np.argmax(struct_deg[ua] + 0.5 * jitter[ua])])
        labels[seed] = part
        size = float(node_w[seed])
        conn.fill(0.0)
        newly = np.array([seed])
        while size < target:
            nb, wv = _adjacency(W, newly)
            if len(nb):
                np.add.at(conn, nb, wv)
            cand = np.flatnonzero((labels == -1) & (conn > 0))
            if len(cand) == 0:
                break
            score = conn[cand]
            if jitter_seeds:
                # Multiplicative noise on the frontier scores: restarts
                # explore genuinely different growth trajectories, not
                # just different tie-breaks.
                score = score * (1.0 + 0.25 * rng.random(len(cand)))
            top = cand[np.argmax(score), None]
            labels[top] = part
            size += float(node_w[top].sum())
            newly = top
    rest = np.flatnonzero(labels == -1)
    labels[rest] = k - 1
    return labels


def _region_grow_flood(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Simultaneous seeded growth: all k parts flood their frontiers at once
    (larger coarse graphs — rounds scale with diameter, not node count).

    Each round every open part absorbs its strongest-connected frontier
    nodes up to its remaining weight budget (per-part cumulative-weight
    prefix).  Coarser than the sequential grower, but the coarse graph is
    exactly where FM-style refinement can repair the difference.
    """
    n = W.shape[0]
    row, col, w = _sym_edges(W)
    labels = np.full(n, -1, dtype=np.int64)
    target = float(node_w.sum()) / k
    deg = np.zeros(n)
    np.add.at(deg, row, w)
    jit = rng.random(n)
    seed_score = deg + (0.25 * deg.mean() * jit if jitter_seeds else 0.0)
    seeds = np.argsort(-seed_score, kind="stable")[:k]
    labels[seeds] = np.arange(k)
    part_w = node_w[seeds].astype(np.float64).copy()
    # conn is maintained IN PLACE: assigned rows and closed-part columns
    # are sunk to -inf when they change, so each round's argmax is the only
    # O(nk) op left.
    conn = np.zeros((n, k))
    conn[seeds] = -np.inf
    open_cols = np.ones(k, dtype=bool)
    new = seeds
    newf = np.zeros(n, dtype=bool)
    arange_n = np.arange(n)
    for _ in range(n):          # safety cap; terminates in ~diameter rounds
        if len(new):
            newf[:] = False
            newf[new] = True
            m = newf[col]
            np.add.at(conn, (row[m], labels[col[m]]), w[m])
            conn[new] = -np.inf
        closing = open_cols & (part_w >= target)
        if closing.any():
            conn[:, closing] = -np.inf
            open_cols &= ~closing
        avail = labels == -1
        if not avail.any():
            break
        if not open_cols.any():
            break
        best_p = conn.argmax(axis=1)
        best_v = conn[arange_n, best_p]
        cand = np.flatnonzero(avail & (best_v > 0))
        if len(cand) == 0:
            # Disconnected frontier: seed the lightest open part with the
            # best-connected unassigned node.
            ua = np.flatnonzero(avail)
            u = int(ua[np.argmax(deg[ua])])
            p = int(np.argmin(np.where(open_cols, part_w, np.inf)))
            labels[u] = p
            part_w[p] += node_w[u]
            conn[u] = -np.inf
            new = np.array([u])
            continue
        p_c, v_c = best_p[cand], best_v[cand]
        o = np.lexsort((-v_c, p_c))
        ps, cs = p_c[o], cand[o]
        wseg = node_w[cs].astype(np.float64)
        cw = np.cumsum(wseg)
        starts = np.flatnonzero(np.r_[True, ps[1:] != ps[:-1]])
        base = np.repeat(cw[starts] - wseg[starts],
                         np.diff(np.r_[starts, len(ps)]))
        first = np.zeros(len(ps), dtype=bool)
        first[starts] = True
        # Budget prefix per part; the single best candidate is always
        # admitted so a nearly-full part cannot stall the flood.
        ok = ((cw - base) <= (target - part_w)[ps]) | first
        acc, accp = cs[ok], ps[ok]
        labels[acc] = accp
        np.add.at(part_w, accp, node_w[acc])
        new = acc
    rest = np.flatnonzero(labels == -1)
    if len(rest):
        labels[rest] = np.resize(np.argsort(part_w, kind="stable"), len(rest))
    return labels


def _rcm_chop(W: sp.csr_matrix, node_w: np.ndarray, k: int) -> np.ndarray:
    """Chop the reverse-Cuthill–McKee order into k weight-balanced chunks —
    a C-level bandwidth-reducing traversal, so consecutive chunks are
    spatially coherent.  Deterministic (no rng)."""
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    n = W.shape[0]
    order = reverse_cuthill_mckee(W.astype(np.float64), symmetric_mode=True)
    target = float(node_w.sum()) / k
    cum = np.cumsum(node_w[order]) - 0.5 * node_w[order]
    labels = np.empty(n, dtype=np.int64)
    labels[order] = np.minimum((cum / target).astype(np.int64), k - 1)
    return labels


def _region_grow_vec(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Initial k-way partition: exact sequential growth where it is cheap
    (small graphs, where cut quality is decided here) and simultaneous
    flooding above that (large coarse graphs, where refinement dominates
    final quality anyway)."""
    n = W.shape[0]
    if k >= n:
        return np.arange(n, dtype=np.int64)
    if n <= 256:
        return _region_grow_seq(W, node_w, k, rng, jitter_seeds)
    return _region_grow_flood(W, node_w, k, rng, jitter_seeds)


def _budget_prefix(parts: np.ndarray, gains: np.ndarray, weights: np.ndarray,
                   budget: np.ndarray) -> np.ndarray:
    """Per-part best-gain-first prefix whose cumulative weight fits budget.

    Returns a boolean mask (aligned with the inputs) selecting, within each
    part, the highest-gain entries whose running weight stays within
    ``budget[part]`` — the batched equivalent of FM's one-at-a-time
    capacity check.
    """
    o = np.lexsort((-gains, parts))
    ps, ws = parts[o], weights[o]
    cw = np.cumsum(ws)
    starts = np.flatnonzero(np.r_[True, ps[1:] != ps[:-1]])
    base = np.repeat(cw[starts] - ws[starts], np.diff(np.r_[starts, len(ps)]))
    ok = np.zeros(len(parts), dtype=bool)
    ok[o] = (cw - base) <= budget[ps]
    return ok


_POLISH_LIMIT = 2048     # steepest-descent polish only below this many nodes


def _one_hot(labels: np.ndarray, k: int) -> sp.csr_matrix:
    """n×k one-hot part-indicator matrix (one entry per row)."""
    n = len(labels)
    return sp.csr_matrix(
        (np.ones(n), labels, np.arange(n + 1, dtype=np.int64)), shape=(n, k))


def _conn_table(W: sp.csr_matrix, labels: np.ndarray, k: int):
    """Per-(node, adjacent part) connection sums via one C-level spgemm:
    ``W @ one_hot(labels)``.  Returns (cu, cp, gain, own, internal) — the
    batched FM gain table — plus the internal-weight vector from which the
    current edge-cut falls out for free:
    ``cut = (W.sum() - internal.sum()) / 2``."""
    n = W.shape[0]
    conn = W @ _one_hot(labels, k)          # CSR (n, k), nnz ≤ E + n
    cu = np.repeat(np.arange(n), np.diff(conn.indptr))
    cp = conn.indices.astype(np.int64)
    sums = conn.data
    own = cp == labels[cu]
    internal = np.zeros(n)
    internal[cu[own]] = sums[own]
    return cu, cp, sums - internal[cu], own, internal


_FM_LIMIT = 512          # full FM polish (lock + hill-climb) below this


def _polish_vec(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    max_w: float,
    min_w: float,
    max_moves: int,
) -> np.ndarray:
    """Single-move polish with the gain table as one batched reduction.

    Below ``_FM_LIMIT`` nodes this is genuine Fiduccia–Mattheyses: per pass
    every node moves at most once (locked afterwards), the best *available*
    move is applied even at negative gain (hill-climbing out of local
    minima), and the pass rolls back to the best cut it saw.  Above it,
    only positive-gain steepest-descent moves are taken (monotone), capped
    at ``max_moves`` — the batched passes of :func:`_refine_vec` have done
    the bulk of the work there already."""
    n = W.shape[0]
    if W.nnz == 0 or k <= 1:
        return labels
    labels = labels.copy()
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    fm = n <= _FM_LIMIT
    n_passes = 2 if fm else 1
    for _ in range(n_passes):
        locked = np.zeros(n, dtype=bool)
        cur_cut = 0.0                      # tracked as a delta from start
        best_cut, best_labels = 0.0, labels.copy()
        improved = False
        for _ in range(min(max_moves, n) if fm else max_moves):
            cu, cp, gain, own, _internal = _conn_table(W, labels, k)
            elig = ((~own) & (~locked[cu])
                    & (part_w[cp] + node_w[cu] <= max_w)
                    & (part_w[labels[cu]] - node_w[cu] >= min_w))
            if not fm:
                elig &= gain > 1e-12
            if not elig.any():
                break
            i = np.flatnonzero(elig)[np.argmax(gain[elig])]
            u, d, g = int(cu[i]), int(cp[i]), float(gain[i])
            part_w[labels[u]] -= node_w[u]
            part_w[d] += node_w[u]
            labels[u] = d
            locked[u] = True
            cur_cut -= g                   # moving u changes the cut by -g
            if cur_cut < best_cut - 1e-12:
                best_cut, best_labels = cur_cut, labels.copy()
                improved = True
        labels = best_labels               # roll back past the best state
        part_w = np.zeros(k)
        np.add.at(part_w, labels, node_w)
        if not improved:
            break
    return labels


def _refine_vec(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    tol: float,
    passes: int = 8,
    max_w: float | None = None,
    polish: bool = True,
) -> np.ndarray:
    """Batched FM-style refinement: all positive-gain boundary moves at once.

    Per pass: per-(node, adjacent-part) connection weights via one
    CSR-segment reduction over boundary-incident edges, best move per node
    by segment argmax, then capacity-limited batched application
    (:func:`_budget_prefix` on both the receiving and the losing side, so a
    balanced labeling stays balanced).  Greedy simultaneous moves can
    overshoot, so the best labeling seen is tracked and returned.
    """
    n = W.shape[0]
    if k <= 1 or W.nnz == 0:
        return labels
    total = float(node_w.sum())
    W_sum = float(W.sum())
    if max_w is None:
        max_w = total / k * (1.0 + tol)
    min_w = min(total / k * (1.0 - tol), max_w)
    labels = labels.copy()
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    best_cut, best_labels = np.inf, labels
    stale = 0
    for _ in range(passes + 1):            # +1: last table just scores
        cu, cp, gain, own, internal = _conn_table(W, labels, k)
        cut = (W_sum - float(internal.sum())) / 2.0
        if cut < best_cut * (1.0 - 1e-3) - 1e-12:
            best_cut, best_labels, stale = cut, labels.copy(), 0
        elif cut < best_cut - 1e-12:      # tiny gain: keep it but wind down
            best_cut, best_labels = cut, labels.copy()
            stale += 1
        else:
            stale += 1
        if stale >= 2:
            break
        elig = ((~own) & (gain > 1e-12)
                & (part_w[cp] + node_w[cu] <= max_w)
                & (part_w[labels[cu]] - node_w[cu] >= min_w))
        if not elig.any():
            break
        g_e, u_e, d_e = gain[elig], cu[elig], cp[elig]
        o2 = np.lexsort((g_e, u_e))
        last = np.flatnonzero(np.r_[u_e[o2][1:] != u_e[o2][:-1], True])
        mv = o2[last]                      # best destination per node
        u_m, d_m, g_m = u_e[mv], d_e[mv], g_e[mv]
        keep_m = (_budget_prefix(d_m, g_m, node_w[u_m], max_w - part_w)
                  & _budget_prefix(labels[u_m], g_m, node_w[u_m],
                                   part_w - min_w))
        u_m, d_m = u_m[keep_m], d_m[keep_m]
        if len(u_m) == 0:
            break
        np.add.at(part_w, labels[u_m], -node_w[u_m])
        np.add.at(part_w, d_m, node_w[u_m])
        labels[u_m] = d_m
    # FM polish pays one full gain-table rebuild per move — affordable only
    # while node AND edge counts are small (coarse star-contracted graphs
    # can be near-dense, so n alone is not enough), and with a move budget
    # that shrinks as the edge list grows.
    if polish and n <= _FM_LIMIT and W.nnz <= 12_000:
        moves = min(n, max(64, 1_500_000 // max(W.nnz, 1)))
        best_labels = _polish_vec(W, node_w, best_labels, k, max_w, min_w,
                                  max_moves=moves)
    return best_labels


def _rebalance_vec(W: sp.csr_matrix, labels: np.ndarray, k: int,
                   cap: int) -> np.ndarray:
    """Strict balance: every part ends with at most ``cap`` (unit-weight)
    members.  Evicts the lowest-internal-connectivity members of oversized
    parts into under-capacity slots in one batched round (feasible because
    ``k * cap >= n``)."""
    n = len(labels)
    counts = np.bincount(labels, minlength=k)
    excess = counts - cap
    if not (excess > 0).any():
        return labels
    labels = labels.copy()
    internal = _conn_table(W, labels, k)[4]
    o = np.lexsort((internal, labels))     # per part, weakest members first
    ls = labels[o]
    starts = np.flatnonzero(np.r_[True, ls[1:] != ls[:-1]])
    rank = np.arange(n) - np.repeat(starts, np.diff(np.r_[starts, n]))
    evict = o[rank < np.maximum(excess, 0)[ls]]
    slots = np.repeat(np.arange(k), np.clip(cap - counts, 0, None))
    labels[evict] = slots[: len(evict)]
    return labels


def partition_graph(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
    temperature: float = 0.0,
    refine_passes: int = 8,
    restarts: int | None = None,
) -> PartitionResult:
    """Vectorized multilevel balanced k-way min-cut partition (the default).

    Same contract as :func:`partition_graph_loop`, with every phase running
    as batched numpy/scipy array ops.  Differences that matter:

    * coarsening continues down to ``max(2k, 128)`` nodes regardless of
      ``coarsen_to`` (refinement at every level is cheap here, and a small
      coarsest graph makes the initial partition nearly free);
    * the initial partition is multi-restarted (``restarts``) on the
      coarsest graph, keeping the best cut;
    * ``temperature > 0`` Gumbel-perturbs the matching weights, giving a
      *stochastic* family of partitions over seeds — the re-partitioning
      stream's entropy source (identical seeds stay bit-reproducible);
    * the final labeling is strictly balanced: every part holds at most
      ``max(floor(n/k·(1+tol)), ceil(n/k))`` nodes.
    """
    n0 = W.shape[0]
    if k <= 1:
        labels = np.zeros(n0, dtype=np.int64)
        return PartitionResult(labels, 1, 0.0, np.array([n0]))
    if n0 <= k:
        labels = np.arange(n0, dtype=np.int64)
        return PartitionResult(labels, k, edge_cut(W, labels),
                               np.bincount(labels, minlength=k))
    rng = np.random.default_rng(seed)
    graphs: list[tuple[sp.csr_matrix, np.ndarray]] = [(W.tocsr(),
                                                       np.ones(n0))]
    maps: list[np.ndarray] = []
    stop = max(2 * k, _COARSE_STOP)
    # METIS-style vertex-weight cap: coarse nodes stay small relative to
    # the balance target, so the coarsest partition can still be balanced
    # (and the final strict rebalance stays a trimming pass, not a rewrite).
    w_cap = n0 / k / 4.0
    while graphs[-1][0].shape[0] > stop:
        Wc0, nw0 = graphs[-1]
        coarse = _heavy_edge_coarsen(Wc0, nw0, rng, temperature, w_cap)
        if coarse.max() + 1 >= 0.97 * Wc0.shape[0]:   # coarsening stalled
            break
        graphs.append(_contract(Wc0, nw0, coarse))
        maps.append(coarse)
    Wc, nw = graphs[-1]
    # The lavish tier — sequential growth, many restarts, per-restart FM
    # polish — only where the coarsest graph is genuinely tiny; its cost
    # scales with coarse edges, which star contraction densifies.
    small_coarsest = Wc.shape[0] <= 256 and Wc.nnz <= 8_000
    if restarts is None:
        # Restarts only touch the coarsest graph: spend more of them where
        # they are nearly free and the FM polish can exploit a better
        # start; above that, refinement decides quality, not the start.
        restarts = 8 if small_coarsest else 2
    # Dense flood growth allocates an (n, k) frontier matrix — if
    # coarsening stalled and the "coarsest" graph is still huge, skip the
    # grown candidates and rely on the RCM chop + refinement instead of
    # risking an O(nk) memory blowup.
    grow_ok = small_coarsest or Wc.shape[0] * k <= 20_000_000
    best: tuple[float, np.ndarray] | None = None
    for r in range(-1, max(1, restarts)):
        if r >= 0 and not grow_ok:
            break
        if r < 0:
            # Extra candidate: chop the reverse-Cuthill–McKee order into k
            # weight-balanced chunks — a layered start qualitatively unlike
            # the grown ones (it rescues bisections whose grown starts all
            # refine into the same local minimum).
            lab = _rcm_chop(Wc, nw, k)
        else:
            # Restart 0 grows from pure max-degree seeds (the loop
            # version's choice); later restarts jitter the seed order for
            # diversity.  Restarts refine without polish; the winner gets it.
            lab = _region_grow_vec(Wc, nw, k,
                                   np.random.default_rng([seed, r]),
                                   jitter_seeds=r > 0)
        # Small coarsest graphs polish inside every restart (cheap, and
        # candidate ranking then matches final quality); large ones rank on
        # batched-refine cuts and only the winner is polished.
        lab = _refine_vec(Wc, nw, lab, k, tol,
                          passes=refine_passes if small_coarsest else 4,
                          polish=small_coarsest)
        c = edge_cut(Wc, lab)
        if best is None or c < best[0]:
            best = (c, lab)
    labels = best[1] if small_coarsest else _refine_vec(
        Wc, nw, best[1], k, tol, passes=4)
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        Wl, nwl = graphs[level]
        if level == 0:
            break                # finest level refines once, after rebalance
        # Refinement effort tapers with level size: quality is decided on
        # the small coarse levels (cheap passes), while the big fine levels
        # only get a touch-up — their boundary is already shaped
        # (measured: <0.2% cut change there).
        nl = Wl.shape[0]
        labels = _refine_vec(
            Wl, nwl, labels, k, tol,
            passes=refine_passes if nl <= _FM_LIMIT
            else min(refine_passes, 5 if nl <= _POLISH_LIMIT else 4))
    Wf, nwf = graphs[0]
    target = n0 / k
    cap = max(int(np.floor(target * (1.0 + tol))), int(np.ceil(target)))
    labels = _rebalance_vec(Wf, labels, k, cap)
    labels = _refine_vec(Wf, nwf, labels, k, tol,
                         passes=refine_passes if n0 <= _POLISH_LIMIT else 5,
                         max_w=float(cap))
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


def partition_permutation(labels: np.ndarray) -> np.ndarray:
    """Stable permutation grouping nodes by part (Fig. 1b re-permutation).

    ``perm[new_index] = old_index``.
    """
    return np.argsort(labels, kind="stable")
