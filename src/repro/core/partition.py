"""Balanced min-edge-cut graph partitioning (METIS replacement, paper §1.1).

The paper uses METIS's multilevel k-way scheme [Karypis & Kumar 1998] to
split the affinity graph into balanced blocks, which re-permutes the affinity
matrix into a dense block-diagonal form (Fig. 1b).  METIS is not available in
this container and the brief requires every substrate to be built, so this is
a from-scratch multilevel partitioner with the same three phases:

  1. **Coarsening** — heavy-edge matching collapses the graph level by level.
  2. **Initial partitioning** — greedy region growing on the coarsest graph
     (seeded BFS that grows each part toward a balanced target weight).
  3. **Uncoarsening + refinement** — project labels back up, then
     Fiduccia–Mattheyses-style boundary passes move nodes to reduce edge-cut
     subject to a balance tolerance.

Host-side preprocessing (numpy/scipy), executed once before training.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = ["PartitionResult", "partition_graph", "edge_cut", "partition_permutation"]


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    labels: np.ndarray        # part id per node, shape (n,)
    n_parts: int
    cut: float                # total weight of cut edges
    sizes: np.ndarray         # nodes per part


def edge_cut(W: sp.csr_matrix, labels: np.ndarray) -> float:
    """Total weight of edges crossing parts (each undirected edge once)."""
    coo = W.tocoo()
    mask = labels[coo.row] != labels[coo.col]
    return float(coo.data[mask].sum()) / 2.0


def _heavy_edge_matching(
    W: sp.csr_matrix, node_w: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """One level of heavy-edge matching. Returns coarse-node id per node."""
    n = W.shape[0]
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    indptr, indices, data = W.indptr, W.indices, W.data
    for u in order:
        if match[u] != -1:
            continue
        best, best_w = -1, -np.inf
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v != u and match[v] == -1 and data[e] > best_w:
                best, best_w = v, data[e]
        match[u] = u if best == -1 else best
        if best != -1:
            match[best] = u
    # Assign coarse ids: one per matched pair / singleton.
    coarse = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for u in range(n):
        if coarse[u] == -1:
            coarse[u] = nxt
            v = match[u]
            if v != u and coarse[v] == -1:
                coarse[v] = nxt
            nxt += 1
    return coarse


def _contract(
    W: sp.csr_matrix, node_w: np.ndarray, coarse: np.ndarray
) -> tuple[sp.csr_matrix, np.ndarray]:
    nc = int(coarse.max()) + 1
    coo = W.tocoo()
    r, c = coarse[coo.row], coarse[coo.col]
    keep = r != c
    Wc = sp.csr_matrix((coo.data[keep], (r[keep], c[keep])), shape=(nc, nc))
    Wc.sum_duplicates()
    nw = np.zeros(nc, dtype=node_w.dtype)
    np.add.at(nw, coarse, node_w)
    return Wc.tocsr(), nw


def _region_grow(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Greedy seeded growth into k parts targeting equal node weight."""
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = node_w.sum() / k
    indptr, indices, data = W.indptr, W.indices, W.data
    struct_deg = np.diff(indptr)
    unassigned = set(range(n))
    for part in range(k - 1):
        if not unassigned:
            break
        # Seed: highest-degree unassigned node (well-connected core).
        seed = max(unassigned, key=lambda u: struct_deg[u])
        labels[seed] = part
        unassigned.discard(seed)
        size = node_w[seed]
        # Frontier scores: connection weight to this part.
        gain: dict[int, float] = {}
        for e in range(indptr[seed], indptr[seed + 1]):
            v = indices[e]
            if labels[v] == -1:
                gain[v] = gain.get(v, 0.0) + data[e]
        while size < target and gain:
            u = max(gain, key=gain.get)
            del gain[u]
            if labels[u] != -1:
                continue
            labels[u] = part
            unassigned.discard(u)
            size += node_w[u]
            for e in range(indptr[u], indptr[u + 1]):
                v = indices[e]
                if labels[v] == -1:
                    gain[v] = gain.get(v, 0.0) + data[e]
    # Everything left goes to the last part; stragglers get folded in below.
    for u in unassigned:
        labels[u] = k - 1
    return labels


def _refine(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    tol: float,
    passes: int = 4,
) -> np.ndarray:
    """FM-style boundary refinement: greedy gain moves under balance."""
    n = W.shape[0]
    indptr, indices, data = W.indptr, W.indices, W.data
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    max_w = node_w.sum() / k * (1.0 + tol)
    min_w = node_w.sum() / k * (1.0 - tol)
    for _ in range(passes):
        moved = 0
        for u in range(n):
            lu = labels[u]
            if part_w[lu] - node_w[u] < min_w:
                continue
            # Connection weight to each adjacent part.
            conn: dict[int, float] = {}
            for e in range(indptr[u], indptr[u + 1]):
                lv = labels[indices[e]]
                conn[lv] = conn.get(lv, 0.0) + data[e]
            internal = conn.get(lu, 0.0)
            best_part, best_gain = lu, 0.0
            for p, w in conn.items():
                if p == lu or part_w[p] + node_w[u] > max_w:
                    continue
                gain = w - internal
                if gain > best_gain:
                    best_part, best_gain = p, gain
            if best_part != lu:
                part_w[lu] -= node_w[u]
                part_w[best_part] += node_w[u]
                labels[u] = best_part
                moved += 1
        if moved == 0:
            break
    return labels


def _rebalance(labels: np.ndarray, node_w: np.ndarray, k: int, tol: float,
               W: sp.csr_matrix) -> np.ndarray:
    """Hard balance pass: move lowest-connectivity nodes out of oversized parts."""
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    target = node_w.sum() / k
    max_w = target * (1.0 + tol)
    indptr, indices, data = W.indptr, W.indices, W.data
    for p in np.argsort(-part_w):
        while part_w[p] > max_w:
            members = np.where(labels == p)[0]
            # Pick member with least internal connectivity to evict.
            best_u, best_int = -1, np.inf
            for u in members:
                internal = 0.0
                for e in range(indptr[u], indptr[u + 1]):
                    if labels[indices[e]] == p:
                        internal += data[e]
                if internal < best_int:
                    best_u, best_int = u, internal
            dest = int(np.argmin(part_w))
            if dest == p:
                break
            part_w[p] -= node_w[best_u]
            part_w[dest] += node_w[best_u]
            labels[best_u] = dest
    return labels


def partition_graph(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
) -> PartitionResult:
    """Multilevel balanced k-way min-cut partition of a sparse graph."""
    if k <= 1:
        labels = np.zeros(W.shape[0], dtype=np.int64)
        return PartitionResult(labels, 1, 0.0, np.array([W.shape[0]]))
    rng = np.random.default_rng(seed)
    n0 = W.shape[0]
    graphs = [(W.tocsr(), np.ones(n0))]
    maps: list[np.ndarray] = []
    # --- coarsening ---
    while graphs[-1][0].shape[0] > max(coarsen_to * k, 2 * k):
        Wc0, nw0 = graphs[-1]
        coarse = _heavy_edge_matching(Wc0, nw0, rng)
        if coarse.max() + 1 >= Wc0.shape[0]:  # matching stalled
            break
        Wc, nw = _contract(Wc0, nw0, coarse)
        graphs.append((Wc, nw))
        maps.append(coarse)
    # --- initial partition on coarsest ---
    Wc, nw = graphs[-1]
    labels = _region_grow(Wc, nw, k, rng)
    labels = _refine(Wc, nw, labels, k, tol)
    # --- uncoarsen + refine ---
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        Wl, nwl = graphs[level]
        labels = _refine(Wl, nwl, labels, k, tol)
    Wf, nwf = graphs[0]
    labels = _rebalance(labels, nwf, k, tol, Wf)
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


def partition_permutation(labels: np.ndarray) -> np.ndarray:
    """Stable permutation grouping nodes by part (Fig. 1b re-permutation).

    ``perm[new_index] = old_index``.
    """
    return np.argsort(labels, kind="stable")
