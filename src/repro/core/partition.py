"""Balanced min-edge-cut graph partitioning (METIS replacement, paper §1.1).

The paper uses METIS's multilevel k-way scheme [Karypis & Kumar 1998] to
split the affinity graph into balanced blocks, which re-permutes the affinity
matrix into a dense block-diagonal form (Fig. 1b).  METIS is not available in
this container and the brief requires every substrate to be built, so this is
a from-scratch multilevel partitioner with the same three phases:

  1. **Coarsening** — heavy-edge matching collapses the graph level by level.
  2. **Initial partitioning** — seeded growth on the coarsest graph toward a
     balanced target weight.
  3. **Uncoarsening + refinement** — project labels back up, then
     Fiduccia–Mattheyses-style boundary passes move nodes to reduce edge-cut
     subject to a balance tolerance.

Two implementations share that scheme and the ``PartitionResult`` contract:

  * :func:`partition_graph` — the **vectorized** default: every phase is
    numpy/scipy batched array ops (mutual-heaviest handshake matching via
    argsort over permuted priorities, CSR-segment reductions for FM gain
    computation, capacity-limited batched moves), so partition wall-clock
    stays flat in the Python interpreter and scales to the ROADMAP's
    corpus-sized graphs.  It also exposes ``temperature`` — Gumbel-perturbed
    matching weights — which is the stochastic re-partitioning stream's
    entropy knob (§2: "enough stochasticity for SGD").
  * :func:`partition_graph_loop` — the original per-node-loop implementation,
    kept verbatim as the quality/semantics reference: the property-based
    suite asserts the vectorized cut stays within 5% of it on identical
    seeds, and ``benchmarks/bench_partition.py`` tracks the speedup.

Host-side preprocessing (numpy/scipy); the vectorized path is cheap enough
to run *between epochs* (see ``repro.data.pipeline.MetaBatchStream``).
"""
from __future__ import annotations

import dataclasses
import threading

import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import connected_components

__all__ = [
    "PartitionResult",
    "PartitionHierarchy",
    "HierarchyCache",
    "partition_graph",
    "partition_graph_loop",
    "partition_hierarchy",
    "edge_cut",
    "partition_permutation",
    "repair_partition",
    "extend_partition",
]


@dataclasses.dataclass(frozen=True)
class PartitionResult:
    labels: np.ndarray        # part id per node, shape (n,)
    n_parts: int
    cut: float                # total weight of cut edges
    sizes: np.ndarray         # nodes per part


def edge_cut(W: sp.csr_matrix, labels: np.ndarray) -> float:
    """Total weight of edges crossing parts (each undirected edge once)."""
    coo = W.tocoo()
    mask = labels[coo.row] != labels[coo.col]
    return float(coo.data[mask].sum()) / 2.0


# ===========================================================================
# Seed per-node-loop implementation (reference; see partition_graph_loop).
# ===========================================================================
def _heavy_edge_matching(
    W: sp.csr_matrix, node_w: np.ndarray, rng: np.random.Generator
) -> np.ndarray:
    """One level of heavy-edge matching. Returns coarse-node id per node."""
    n = W.shape[0]
    match = np.full(n, -1, dtype=np.int64)
    order = rng.permutation(n)
    indptr, indices, data = W.indptr, W.indices, W.data
    for u in order:
        if match[u] != -1:
            continue
        best, best_w = -1, -np.inf
        for e in range(indptr[u], indptr[u + 1]):
            v = indices[e]
            if v != u and match[v] == -1 and data[e] > best_w:
                best, best_w = v, data[e]
        match[u] = u if best == -1 else best
        if best != -1:
            match[best] = u
    # Assign coarse ids: one per matched pair / singleton.
    coarse = np.full(n, -1, dtype=np.int64)
    nxt = 0
    for u in range(n):
        if coarse[u] == -1:
            coarse[u] = nxt
            v = match[u]
            if v != u and coarse[v] == -1:
                coarse[v] = nxt
            nxt += 1
    return coarse


def _contract(
    W: sp.csr_matrix, node_w: np.ndarray, coarse: np.ndarray
) -> tuple[sp.csr_matrix, np.ndarray]:
    """Collapse matched nodes: sum duplicate (coarse-row, coarse-col) edge
    weights, dropping the diagonal — one radix argsort + segment reduction,
    with the CSR assembled directly from the sorted unique keys."""
    nc = int(coarse.max()) + 1
    coo = W.tocoo()
    r, c = coarse[coo.row], coarse[coo.col]
    keep = r != c
    r, c, d = r[keep], c[keep], coo.data[keep]
    nw = np.zeros(nc, dtype=node_w.dtype)
    np.add.at(nw, coarse, node_w)
    if len(d) == 0:       # every edge collapsed into a coarse self-loop
        return sp.csr_matrix((nc, nc)), nw
    key = r.astype(np.int64) * nc + c
    o = np.argsort(key, kind="stable")
    ks, ds = key[o], d[o]
    starts = np.flatnonzero(np.r_[True, ks[1:] != ks[:-1]])
    sums = np.add.reduceat(ds, starts)
    uk = ks[starts]
    ur, uc = uk // nc, (uk % nc).astype(np.int32)
    indptr = np.zeros(nc + 1, dtype=np.int64)
    np.cumsum(np.bincount(ur, minlength=nc), out=indptr[1:])
    Wc = sp.csr_matrix((sums, uc, indptr), shape=(nc, nc))
    return Wc, nw


def _region_grow(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator
) -> np.ndarray:
    """Greedy seeded growth into k parts targeting equal node weight."""
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = node_w.sum() / k
    indptr, indices, data = W.indptr, W.indices, W.data
    struct_deg = np.diff(indptr)
    unassigned = set(range(n))
    for part in range(k - 1):
        if not unassigned:
            break
        # Seed: highest-degree unassigned node (well-connected core).
        # audit: safe(D002): int-set iteration is deterministic in CPython
        seed = max(unassigned, key=lambda u: struct_deg[u])
        labels[seed] = part
        unassigned.discard(seed)
        size = node_w[seed]
        # Frontier scores: connection weight to this part.
        gain: dict[int, float] = {}
        for e in range(indptr[seed], indptr[seed + 1]):
            v = indices[e]
            if labels[v] == -1:
                gain[v] = gain.get(v, 0.0) + data[e]
        while size < target and gain:
            u = max(gain, key=gain.get)
            del gain[u]
            if labels[u] != -1:
                continue
            labels[u] = part
            unassigned.discard(u)
            size += node_w[u]
            for e in range(indptr[u], indptr[u + 1]):
                v = indices[e]
                if labels[v] == -1:
                    gain[v] = gain.get(v, 0.0) + data[e]
    # Everything left goes to the last part; stragglers get folded in below.
    # audit: safe(D002): every member gets the same label — order-free
    for u in unassigned:
        labels[u] = k - 1
    return labels


def _refine(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    tol: float,
    passes: int = 4,
) -> np.ndarray:
    """FM-style boundary refinement: greedy gain moves under balance."""
    n = W.shape[0]
    indptr, indices, data = W.indptr, W.indices, W.data
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    max_w = node_w.sum() / k * (1.0 + tol)
    min_w = node_w.sum() / k * (1.0 - tol)
    for _ in range(passes):
        moved = 0
        for u in range(n):
            lu = labels[u]
            if part_w[lu] - node_w[u] < min_w:
                continue
            # Connection weight to each adjacent part.
            conn: dict[int, float] = {}
            for e in range(indptr[u], indptr[u + 1]):
                lv = labels[indices[e]]
                conn[lv] = conn.get(lv, 0.0) + data[e]
            internal = conn.get(lu, 0.0)
            best_part, best_gain = lu, 0.0
            for p, w in conn.items():
                if p == lu or part_w[p] + node_w[u] > max_w:
                    continue
                gain = w - internal
                if gain > best_gain:
                    best_part, best_gain = p, gain
            if best_part != lu:
                part_w[lu] -= node_w[u]
                part_w[best_part] += node_w[u]
                labels[u] = best_part
                moved += 1
        if moved == 0:
            break
    return labels


def _rebalance(labels: np.ndarray, node_w: np.ndarray, k: int, tol: float,
               W: sp.csr_matrix) -> np.ndarray:
    """Hard balance pass: move lowest-connectivity nodes out of oversized parts."""
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    target = node_w.sum() / k
    max_w = target * (1.0 + tol)
    indptr, indices, data = W.indptr, W.indices, W.data
    for p in np.argsort(-part_w):
        while part_w[p] > max_w:
            members = np.where(labels == p)[0]
            # Pick member with least internal connectivity to evict.
            best_u, best_int = -1, np.inf
            for u in members:
                internal = 0.0
                for e in range(indptr[u], indptr[u + 1]):
                    if labels[indices[e]] == p:
                        internal += data[e]
                if internal < best_int:
                    best_u, best_int = u, internal
            dest = int(np.argmin(part_w))
            if dest == p:
                break
            part_w[p] -= node_w[best_u]
            part_w[dest] += node_w[best_u]
            labels[best_u] = dest
    return labels


def partition_graph_loop(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
) -> PartitionResult:
    """The seed per-node-loop multilevel partitioner (quality reference).

    Same contract as :func:`partition_graph`; every phase iterates node by
    node in the interpreter, so it is O(n) Python dispatches per level —
    kept for the property-based equivalence suite and the partition
    benchmark, not for production paths.
    """
    if k <= 1:
        labels = np.zeros(W.shape[0], dtype=np.int64)
        return PartitionResult(labels, 1, 0.0, np.array([W.shape[0]]))
    rng = np.random.default_rng(seed)
    n0 = W.shape[0]
    graphs = [(W.tocsr(), np.ones(n0))]
    maps: list[np.ndarray] = []
    # --- coarsening ---
    while graphs[-1][0].shape[0] > max(coarsen_to * k, 2 * k):
        Wc0, nw0 = graphs[-1]
        coarse = _heavy_edge_matching(Wc0, nw0, rng)
        if coarse.max() + 1 >= Wc0.shape[0]:  # matching stalled
            break
        Wc, nw = _contract(Wc0, nw0, coarse)
        graphs.append((Wc, nw))
        maps.append(coarse)
    # --- initial partition on coarsest ---
    Wc, nw = graphs[-1]
    labels = _region_grow(Wc, nw, k, rng)
    labels = _refine(Wc, nw, labels, k, tol)
    # --- uncoarsen + refine ---
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        Wl, nwl = graphs[level]
        labels = _refine(Wl, nwl, labels, k, tol)
    Wf, nwf = graphs[0]
    labels = _rebalance(labels, nwf, k, tol, Wf)
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


# ===========================================================================
# Vectorized implementation (the default partition_graph).
# ===========================================================================
_COARSE_STOP = 512      # never coarsen below this many nodes


def _sym_edges(W: sp.spmatrix):
    """(row, col, w) with self-loops dropped; weights as float64."""
    coo = W.tocoo()
    keep = coo.row != coo.col
    return (coo.row[keep].astype(np.int64), coo.col[keep].astype(np.int64),
            coo.data[keep].astype(np.float64))


def _heavy_edge_coarsen(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    rng: np.random.Generator,
    temperature: float = 0.0,
    w_cap: float | None = None,
) -> np.ndarray:
    """One coarsening level: heavy-edge *star* contraction, fully batched.

    Every node points at its heaviest usable neighbour (one CSR-segment
    ``maximum.reduceat`` with a symmetric permuted-priority tie-break
    instead of the loop version's random visit order), and the weakly
    connected components of that best-neighbour forest collapse into coarse
    nodes — a C-level ``connected_components`` call.  Stars and chains give
    ~3× reduction per level versus ~1.8× for pairwise matching, so half the
    levels exist at all.

    ``w_cap`` bounds coarse-node weight — METIS's vertex-weight limit,
    which keeps coarse nodes small relative to the part target so the
    coarsest partition can still be balanced: edges whose merged endpoint
    weight exceeds it are unusable, and any over-heavy component is split
    into cap-sized chunks.

    ``temperature > 0`` multiplies edge weights by ``exp(T·Gumbel)`` before
    the argmax — the stochastic re-partitioning knob: identical seeds stay
    bit-reproducible while different seeds explore distinct coarsenings.
    """
    n = W.shape[0]
    indptr, indices, data = W.indptr, W.indices, W.data
    deg = np.diff(indptr)
    rows = np.repeat(np.arange(n), deg)
    key = data.astype(np.float64)
    if temperature > 0.0:
        g = -np.log(-np.log(rng.uniform(1e-12, 1.0 - 1e-12, size=len(key))))
        key = key * np.exp(temperature * g)
    prio = rng.permutation(n).astype(np.float64)
    scale = float(key.max()) if len(key) else 1.0
    # Symmetric tie-break, distinct within a node's edge list: makes the
    # per-row argmax a strict total order.
    key = key + (1e-9 * scale / max(n, 1)) * (prio[rows] + prio[indices])
    valid = rows != indices
    if w_cap is not None:
        valid &= (node_w[rows] + node_w[indices]) <= w_cap
    keym = np.where(valid, key, -np.inf)
    if len(rows) == 0:
        return np.arange(n, dtype=np.int64)
    seg_start = np.flatnonzero(np.r_[True, rows[1:] != rows[:-1]])
    rowmax = np.maximum.reduceat(keym, seg_start)
    hit = keym == np.repeat(rowmax,
                            np.diff(np.r_[seg_start, len(rows)]))
    hit &= np.isfinite(keym)               # rows with no usable edge at all
    hr, hc = rows[hit], indices[hit]
    if len(hr) == 0:                       # every edge blocked (w_cap): stall
        return np.arange(n, dtype=np.int64)
    hfirst = np.r_[True, hr[1:] != hr[:-1]]
    bu, bv = hr[hfirst], hc[hfirst]        # best-neighbour forest edges
    F = sp.csr_matrix((np.ones(len(bu)), (bu, bv)), shape=(n, n))
    _, comp = connected_components(F, directed=True, connection="weak")
    comp = comp.astype(np.int64)
    if w_cap is not None:
        cw = np.bincount(comp, weights=node_w)
        if (cw > w_cap).any():
            # Split over-heavy components into cap-sized weight chunks.
            o = np.argsort(comp, kind="stable")
            cs = comp[o]
            wo = node_w[o].astype(np.float64)
            starts = np.flatnonzero(np.r_[True, cs[1:] != cs[:-1]])
            cum = np.cumsum(wo)
            base = np.repeat(cum[starts] - wo[starts],
                             np.diff(np.r_[starts, n]))
            sub = ((cum - base - 0.5 * wo) // w_cap).astype(np.int64)
            keyc = cs * (int(sub.max()) + 1) + sub
            split = np.unique(keyc, return_inverse=True)[1]
            comp = np.empty(n, dtype=np.int64)
            comp[o] = split
    return comp


def _adjacency(W: sp.csr_matrix, nodes: np.ndarray
               ) -> tuple[np.ndarray, np.ndarray]:
    """Concatenated (neighbours, weights) of ``nodes`` — a batched CSR
    gather replacing per-node ``indptr`` loops."""
    indptr, indices, data = W.indptr, W.indices, W.data
    cnt = indptr[nodes + 1] - indptr[nodes]
    total = int(cnt.sum())
    if total == 0:
        return (np.empty(0, dtype=indices.dtype),
                np.empty(0, dtype=data.dtype))
    offs = (np.repeat(indptr[nodes], cnt)
            + np.arange(total)
            - np.repeat(np.cumsum(cnt) - cnt, cnt))
    return indices[offs], data[offs]


def _region_grow_seq(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Seeded growth into k parts, one part at a time (small graphs).

    Same scheme as the loop version — grow each part from a high-degree
    seed by strongest connection until it reaches the balance target — but
    each absorption is a batched CSR adjacency gather + argmax instead of
    per-node dict bookkeeping.
    """
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = float(node_w.sum()) / k
    struct_deg = np.diff(W.indptr).astype(np.float64)
    jitter = rng.random(n) if jitter_seeds else np.zeros(n)
    conn = np.zeros(n)
    for part in range(k - 1):
        avail = labels == -1
        if not avail.any():
            break
        ua = np.flatnonzero(avail)
        seed = int(ua[np.argmax(struct_deg[ua] + 0.5 * jitter[ua])])
        labels[seed] = part
        size = float(node_w[seed])
        conn.fill(0.0)
        newly = np.array([seed])
        while size < target:
            nb, wv = _adjacency(W, newly)
            if len(nb):
                np.add.at(conn, nb, wv)
            cand = np.flatnonzero((labels == -1) & (conn > 0))
            if len(cand) == 0:
                break
            score = conn[cand]
            if jitter_seeds:
                # Multiplicative noise on the frontier scores: restarts
                # explore genuinely different growth trajectories, not
                # just different tie-breaks.
                score = score * (1.0 + 0.25 * rng.random(len(cand)))
            top = cand[np.argmax(score), None]
            labels[top] = part
            size += float(node_w[top].sum())
            newly = top
    rest = np.flatnonzero(labels == -1)
    labels[rest] = k - 1
    return labels


def _region_grow_flood(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Simultaneous seeded growth: all k parts flood their frontiers at once
    (larger coarse graphs — rounds scale with diameter, not node count).

    The frontier is **sparse**: per-(node, part) connection weights live in
    COO-style pending arrays (one entry per edge whose source got assigned,
    ≤ E entries total) that are compacted and segment-summed each round —
    no dense ``(n, k)`` matrix, so flood growth survives many-small-blocks
    regimes (k ≳ 300) and stalled coarsenings without an O(nk) memory
    blowup.  Each round every open part absorbs its strongest-connected
    frontier nodes up to its remaining weight budget (per-part
    cumulative-weight prefix).  Coarser than the sequential grower, but the
    coarse graph is exactly where FM-style refinement can repair the
    difference.
    """
    n = W.shape[0]
    labels = np.full(n, -1, dtype=np.int64)
    target = float(node_w.sum()) / k
    row, _, w = _sym_edges(W)
    deg = np.zeros(n)
    np.add.at(deg, row, w)
    jit = rng.random(n)
    seed_score = deg + (0.25 * deg.mean() * jit if jitter_seeds else 0.0)
    seeds = np.argsort(-seed_score, kind="stable")[:k]
    labels[seeds] = np.arange(k)
    part_w = node_w[seeds].astype(np.float64).copy()
    open_parts = np.ones(k, dtype=bool)
    n_left = n - k
    indptr = W.indptr
    # Pending frontier contributions (node, part, weight) — appended when a
    # node is assigned, compacted against assignments/closed parts each
    # round.  Every edge enters at most twice over the whole flood.
    pn: list[np.ndarray] = []
    pp: list[np.ndarray] = []
    pw: list[np.ndarray] = []
    new = seeds
    for _ in range(2 * n + k):  # safety cap; terminates in ~diameter rounds
        if len(new):
            nb, wt = _adjacency(W, new)
            src_part = np.repeat(labels[new], indptr[new + 1] - indptr[new])
            m = labels[nb] == -1
            if m.any():
                pn.append(nb[m])
                pp.append(src_part[m])
                pw.append(wt[m])
        open_parts &= part_w < target
        if n_left == 0 or not open_parts.any():
            break
        # Compact: drop contributions to assigned nodes / from closed parts.
        fn = np.concatenate(pn) if pn else np.empty(0, dtype=np.int64)
        fp = np.concatenate(pp) if pp else np.empty(0, dtype=np.int64)
        fw = np.concatenate(pw) if pw else np.empty(0)
        live = (labels[fn] == -1) & open_parts[fp]
        fn, fp, fw = fn[live], fp[live], fw[live]
        pn, pp, pw = [fn], [fp], [fw]
        if len(fn) == 0:
            # Disconnected frontier: batch-seed the open parts (lightest
            # first) with the best-connected unassigned nodes — one round,
            # not one node per round.
            ua = np.flatnonzero(labels == -1)
            po = np.flatnonzero(open_parts)
            po = po[np.argsort(part_w[po], kind="stable")]
            m_seed = min(len(ua), len(po))
            pick = ua[np.argsort(-deg[ua], kind="stable")[:m_seed]]
            labels[pick] = po[:m_seed]
            np.add.at(part_w, po[:m_seed], node_w[pick])
            n_left -= m_seed
            new = pick
            continue
        # Aggregate duplicate (node, part) keys, then take each node's
        # strongest part — sort-based segment reductions, deterministic.
        # int64 BEFORE the multiply: fn carries the CSR index dtype
        # (int32 below 2^31 nnz) and n*k overflows it at corpus scale.
        key = fn.astype(np.int64) * k + fp
        o = np.argsort(key, kind="stable")
        ks, ws = key[o], fw[o]
        starts = np.flatnonzero(
            np.concatenate(([True], ks[1:] != ks[:-1])))
        sums = np.add.reduceat(ws, starts)
        uk = ks[starts]
        un, up = uk // k, uk % k
        o2 = np.lexsort((sums, un))
        last = np.flatnonzero(
            np.concatenate((un[o2][1:] != un[o2][:-1], [True])))
        sel = o2[last]
        cn, cp, cv = un[sel], up[sel], sums[sel]
        # Budget prefix per part; the single best candidate is always
        # admitted so a nearly-full part cannot stall the flood.
        o3 = np.lexsort((-cv, cp))
        ps, cs = cp[o3], cn[o3]
        wseg = node_w[cs].astype(np.float64)
        cw = np.cumsum(wseg)
        starts2 = np.flatnonzero(
            np.concatenate(([True], ps[1:] != ps[:-1])))
        base = np.repeat(
            cw[starts2] - wseg[starts2],
            np.diff(np.concatenate((starts2, [len(ps)]))))
        first = np.zeros(len(ps), dtype=bool)
        first[starts2] = True
        ok = ((cw - base) <= (target - part_w)[ps]) | first
        acc, accp = cs[ok], ps[ok]
        labels[acc] = accp
        np.add.at(part_w, accp, node_w[acc])
        n_left -= len(acc)
        new = acc
    rest = np.flatnonzero(labels == -1)
    if len(rest):
        labels[rest] = np.resize(np.argsort(part_w, kind="stable"), len(rest))
    return labels


def _rcm_chop(W: sp.csr_matrix, node_w: np.ndarray, k: int) -> np.ndarray:
    """Chop the reverse-Cuthill–McKee order into k weight-balanced chunks —
    a C-level bandwidth-reducing traversal, so consecutive chunks are
    spatially coherent.  Deterministic (no rng).

    Boundaries are placed *adaptively*: each chunk targets the remaining
    weight over the remaining parts, so rounding drift is redistributed as
    it accrues instead of the last chop absorbing the whole remainder
    (which left badly unbalanced tails when n % k != 0 or node weights
    vary).  Every part gets at least one node.
    """
    from scipy.sparse.csgraph import reverse_cuthill_mckee

    n = W.shape[0]
    order = reverse_cuthill_mckee(W.astype(np.float64), symmetric_mode=True)
    w_o = node_w[order].astype(np.float64)
    cum = np.cumsum(w_o)
    total = float(cum[-1])
    labels = np.empty(n, dtype=np.int64)
    start = 0
    start_w = 0.0
    for p in range(k):
        if p == k - 1:
            end = n
        else:
            tgt = start_w + (total - start_w) / (k - p)
            e = int(np.searchsorted(cum, tgt))
            # Midpoint rule: include the boundary node in this chunk when
            # more than half its weight falls before the target.
            if e < n and cum[e] - 0.5 * w_o[e] <= tgt:
                e += 1
            end = min(max(e, start + 1), n - (k - 1 - p))
        labels[order[start:end]] = p
        start_w = float(cum[end - 1])
        start = end
    return labels


def _region_grow_vec(
    W: sp.csr_matrix, node_w: np.ndarray, k: int, rng: np.random.Generator,
    jitter_seeds: bool = True,
) -> np.ndarray:
    """Initial k-way partition: exact sequential growth where it is cheap
    (small graphs, where cut quality is decided here) and simultaneous
    flooding above that (large coarse graphs, where refinement dominates
    final quality anyway)."""
    n = W.shape[0]
    if k >= n:
        return np.arange(n, dtype=np.int64)
    if n <= 256:
        return _region_grow_seq(W, node_w, k, rng, jitter_seeds)
    return _region_grow_flood(W, node_w, k, rng, jitter_seeds)


def _budget_prefix(parts: np.ndarray, gains: np.ndarray, weights: np.ndarray,
                   budget: np.ndarray) -> np.ndarray:
    """Per-part best-gain-first prefix whose cumulative weight fits budget.

    Returns a boolean mask (aligned with the inputs) selecting, within each
    part, the highest-gain entries whose running weight stays within
    ``budget[part]`` — the batched equivalent of FM's one-at-a-time
    capacity check.
    """
    o = np.lexsort((-gains, parts))
    ps, ws = parts[o], weights[o]
    cw = np.cumsum(ws)
    starts = np.flatnonzero(np.r_[True, ps[1:] != ps[:-1]])
    base = np.repeat(cw[starts] - ws[starts], np.diff(np.r_[starts, len(ps)]))
    ok = np.zeros(len(parts), dtype=bool)
    ok[o] = (cw - base) <= budget[ps]
    return ok


_POLISH_LIMIT = 2048     # steepest-descent polish only below this many nodes


def _one_hot(labels: np.ndarray, k: int) -> sp.csr_matrix:
    """n×k one-hot part-indicator matrix (one entry per row)."""
    n = len(labels)
    return sp.csr_matrix(
        (np.ones(n), labels, np.arange(n + 1, dtype=np.int64)), shape=(n, k))


def _conn_table(W: sp.csr_matrix, labels: np.ndarray, k: int):
    """Per-(node, adjacent part) connection sums via one C-level spgemm:
    ``W @ one_hot(labels)``.  Returns (cu, cp, gain, own, internal) — the
    batched FM gain table — plus the internal-weight vector from which the
    current edge-cut falls out for free:
    ``cut = (W.sum() - internal.sum()) / 2``."""
    n = W.shape[0]
    conn = W @ _one_hot(labels, k)          # CSR (n, k), nnz ≤ E + n
    cu = np.repeat(np.arange(n), np.diff(conn.indptr))
    cp = conn.indices.astype(np.int64)
    sums = conn.data
    own = cp == labels[cu]
    internal = np.zeros(n)
    internal[cu[own]] = sums[own]
    return cu, cp, sums - internal[cu], own, internal


_FM_LIMIT = 512          # full FM polish (lock + hill-climb) below this


def _polish_vec(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    max_w: float,
    min_w: float,
    max_moves: int,
) -> np.ndarray:
    """Single-move polish with the gain table as one batched reduction.

    Below ``_FM_LIMIT`` nodes this is genuine Fiduccia–Mattheyses: per pass
    every node moves at most once (locked afterwards), the best *available*
    move is applied even at negative gain (hill-climbing out of local
    minima), and the pass rolls back to the best cut it saw.  Above it,
    only positive-gain steepest-descent moves are taken (monotone), capped
    at ``max_moves`` — the batched passes of :func:`_refine_vec` have done
    the bulk of the work there already."""
    n = W.shape[0]
    if W.nnz == 0 or k <= 1:
        return labels
    labels = labels.copy()
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    fm = n <= _FM_LIMIT
    n_passes = 2 if fm else 1
    ar = np.arange(n)
    indptr = W.indptr
    for _ in range(n_passes):
        # One dense conn table per pass, maintained incrementally per move
        # (a move only changes its neighbours' rows) — the per-move spgemm
        # rebuild this replaces was the small-graph polish bottleneck.
        # ``masked`` mirrors it with own-column AND per-(node, part)
        # capacity masking applied, so the argmax directly yields each
        # node's best *fitting adjacent* destination (the conn-table pair
        # semantics): a node whose strongest part is full still offers
        # its best feasible move.  A move only changes two part weights,
        # so the mask is maintained column-wise, O(n) per move.
        conn = (W @ _one_hot(labels, k)).toarray()
        fits = part_w[None, :] + node_w[:, None] <= max_w
        masked = np.where(fits & (conn > 0), conn, -np.inf)
        masked[ar, labels] = -np.inf
        locked = np.zeros(n, dtype=bool)
        cur_cut = 0.0                      # tracked as a delta from start
        best_cut, best_labels = 0.0, labels.copy()
        improved = False

        def refresh_col(col):
            feas = (part_w[col] + node_w <= max_w) & (conn[:, col] > 0)
            feas &= labels != col
            masked[:, col] = np.where(feas, conn[:, col], -np.inf)

        for _ in range(min(max_moves, n) if fm else max_moves):
            best_p = masked.argmax(axis=1)
            best_v = masked[ar, best_p]
            own = conn[ar, labels]
            gain = best_v - own
            # best_v > -inf (== adjacent, fitting, not own): hill-climb
            # moves may be negative-gain but never to a part the node has
            # no edge to, and never into a part that cannot take it.
            elig = ((~locked) & np.isfinite(best_v)
                    & (part_w[labels] - node_w >= min_w))
            if not fm:
                elig &= gain > 1e-12
            if not elig.any():
                break
            cand = np.flatnonzero(elig)
            u = int(cand[np.argmax(gain[cand])])
            d, g = int(best_p[u]), float(gain[u])
            old = labels[u]
            part_w[old] -= node_w[u]
            part_w[d] += node_w[u]
            labels[u] = d
            locked[u] = True
            nb = W.indices[indptr[u]: indptr[u + 1]]
            wt = W.data[indptr[u]: indptr[u + 1]]
            np.subtract.at(conn, (nb, np.broadcast_to(old, len(nb))), wt)
            np.add.at(conn, (nb, np.broadcast_to(d, len(nb))), wt)
            refresh_col(old)               # u left: may open + conn changed
            refresh_col(d)                 # u arrived: may close + changed
            cur_cut -= g                   # moving u changes the cut by -g
            if cur_cut < best_cut - 1e-12:
                best_cut, best_labels = cur_cut, labels.copy()
                improved = True
        labels = best_labels               # roll back past the best state
        part_w = np.zeros(k)
        np.add.at(part_w, labels, node_w)
        if not improved:
            break
    return labels


_DENSE_ROUNDS_LIMIT = 8_000_000   # dense (n, k) conn table cap for refine


def _refine_dense_rounds(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    max_w: float,
    min_w: float,
    passes: int,
    seed_touched: np.ndarray | None = None,
) -> np.ndarray:
    """Batched FM rounds over a dense (n, k) conn table, built ONCE.

    The spgemm path (:func:`_refine_vec`'s default) rebuilds the whole
    ``W @ one_hot`` gain table every pass — for the many-small-blocks
    regime (k ≳ 300, parts of a handful of nodes, near-dense coarse
    graphs) that rebuild dominates partition wall-clock.  Here the table
    is materialized once and each round only (a) takes a row-wise argmax,
    (b) applies the capacity-limited batched moves, and (c) *incrementally*
    updates the rows of the moved nodes' neighbours — O(moved-degree) per
    round instead of O(E).  Bounded by ``_DENSE_ROUNDS_LIMIT`` entries so
    corpus-scale fine levels fall back to the spgemm path.
    """
    n = W.shape[0]
    W_sum = float(W.sum())
    labels = labels.copy()
    # float32 end to end: the table is a gain heuristic, not the cut
    # report, and an f32 spgemm + toarray halves the build's memory
    # traffic (the finest-level table is the big one).
    W32 = sp.csr_matrix((W.data.astype(np.float32), W.indices, W.indptr),
                        shape=W.shape)
    oh32 = sp.csr_matrix(
        (np.ones(n, dtype=np.float32), labels,
         np.arange(n + 1, dtype=np.int64)), shape=(n, k))
    flat = np.ascontiguousarray((W32 @ oh32).toarray()).ravel()
    conn = flat.reshape(n, k)
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    ar = np.arange(n)
    indptr = W.indptr
    best_cut, best_labels = np.inf, labels
    best_p = np.zeros(n, dtype=np.int64)
    gain = np.zeros(n)
    # ``seed_touched`` restricts the first sweep to a neighbourhood (the
    # incremental-replan delta); moves expand it round by round, so far
    # regions stay untouched and the refine cost scales with the change.
    touched = ar if seed_touched is None else seed_touched
    stale = 0
    for _ in range(passes + 1):            # +1: last round just scores
        own = conn[ar, labels].astype(np.float64)
        cut = (W_sum - float(own.sum())) / 2.0
        if cut < best_cut * (1.0 - 1e-3) - 1e-12:
            best_cut, best_labels, stale = cut, labels.copy(), 0
        elif cut < best_cut - 1e-12:      # tiny gain: keep it but wind down
            best_cut, best_labels = cut, labels.copy()
            stale += 1
        else:
            stale += 1
        if stale >= 2:
            break
        # Batched rounds: only rows the last round's moves touched can have
        # a new best destination, so the argmax sweep shrinks from O(nk) to
        # O(touched·k) after the first round — capacity eligibility is
        # re-checked against current part weights for every row below.
        if len(touched):
            t_lab = labels[touched]
            ownt = conn[touched, t_lab].copy()
            conn[touched, t_lab] = -np.inf
            best_p[touched] = conn[touched].argmax(axis=1)
            conn[touched, t_lab] = ownt
            gain[touched] = (conn[touched, best_p[touched]].astype(np.float64)
                             - ownt.astype(np.float64))
        elig = ((gain > 1e-6)
                & (part_w[best_p] + node_w <= max_w)
                & (part_w[labels] - node_w >= min_w))
        if not elig.any():
            break
        u_m = np.flatnonzero(elig)
        d_m, g_m = best_p[u_m], gain[u_m]
        keep_m = (_budget_prefix(d_m, g_m, node_w[u_m], max_w - part_w)
                  & _budget_prefix(labels[u_m], g_m, node_w[u_m],
                                   part_w - min_w))
        u_m, d_m = u_m[keep_m], d_m[keep_m]
        if len(u_m) == 0:
            break
        old = labels[u_m]
        np.add.at(part_w, old, -node_w[u_m])
        np.add.at(part_w, d_m, node_w[u_m])
        labels[u_m] = d_m
        # Incremental table update: moving u only changes its neighbours'
        # connection to u's old and new parts (flat 1-D scatter-adds).
        nb, wt32 = _adjacency(W32, u_m)
        cnt = indptr[u_m + 1] - indptr[u_m]
        np.subtract.at(flat, nb * k + np.repeat(old, cnt), wt32)
        np.add.at(flat, nb * k + np.repeat(d_m, cnt), wt32)
        touched = np.unique(np.concatenate((nb, u_m)))
    return best_labels


def _refine_vec(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    tol: float,
    passes: int = 8,
    max_w: float | None = None,
    polish: bool = True,
    seed_touched: np.ndarray | None = None,
) -> np.ndarray:
    """Batched FM-style refinement: all positive-gain boundary moves at once.

    Two table strategies share the same move policy (best destination per
    node, :func:`_budget_prefix` capacity limits on both the receiving and
    the losing side, best labeling seen wins):

    * **dense rounds** (``k >= 32`` and ``n*k`` under
      ``_DENSE_ROUNDS_LIMIT``): one dense conn table built once, then
      incrementally maintained across rounds — the many-small-blocks fast
      path (:func:`_refine_dense_rounds`);
    * **spgemm passes** (everything else): per pass one
      ``W @ one_hot(labels)`` CSR-segment reduction rebuilds the
      per-(node, adjacent-part) table — memory stays O(E) at any k.
    """
    n = W.shape[0]
    if k <= 1 or W.nnz == 0:
        return labels
    total = float(node_w.sum())
    W_sum = float(W.sum())
    if max_w is None:
        max_w = total / k * (1.0 + tol)
    min_w = min(total / k * (1.0 - tol), max_w)
    if (k >= 32 or seed_touched is not None) \
            and n * k <= _DENSE_ROUNDS_LIMIT:
        best_labels = _refine_dense_rounds(W, node_w, labels, k,
                                           max_w, min_w, passes,
                                           seed_touched=seed_touched)
    elif seed_touched is not None:
        # Above the dense-table cap the delta restriction must survive —
        # a full-graph spgemm pass would silently turn the incremental
        # replan back into O(E) work per pass at exactly corpus scale.
        best_labels = _refine_spgemm_rows(W, node_w, labels, k,
                                          max_w, min_w, passes,
                                          seed_touched)
    else:
        best_labels = _refine_spgemm(W, node_w, labels, k, W_sum,
                                     max_w, min_w, passes)
    # FM polish pays one full gain-table rebuild per move — affordable only
    # while node AND edge counts are small (coarse star-contracted graphs
    # can be near-dense, so n alone is not enough), and with a move budget
    # that shrinks as the edge list grows.
    if polish and n <= _FM_LIMIT and W.nnz <= 12_000:
        moves = min(n, max(64, 1_500_000 // max(W.nnz, 1)))
        best_labels = _polish_vec(W, node_w, best_labels, k, max_w, min_w,
                                  max_moves=moves)
    return best_labels


def _refine_spgemm_rows(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    max_w: float,
    min_w: float,
    passes: int,
    seed_touched: np.ndarray,
) -> np.ndarray:
    """Row-restricted spgemm refinement for delta-seeded refines above the
    dense-table memory cap: each pass builds the gain table for the active
    rows only (``W[rows] @ one_hot``), applies the capacity-limited
    positive-gain batched moves, and the movers' neighbourhood becomes the
    next pass's active set — per-pass cost tracks the delta, not E.
    Simultaneous moves against one frozen table can overshoot (two
    neighbours both leave their shared part), so the exact cut delta is
    maintained incrementally from the movers' adjacency and the best
    labeling seen is returned — same rollback contract as the siblings,
    without any full-graph scoring pass.
    """
    labels = labels.copy()
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    active = np.asarray(seed_touched, dtype=np.int64)
    is_mover = np.zeros(W.shape[0], dtype=bool)
    cut_delta = 0.0
    best_delta, best_labels = 0.0, labels.copy()
    for _ in range(passes):
        if len(active) == 0:
            break
        conn = W[active] @ _one_hot(labels, k)      # (m, k) CSR
        cl = np.repeat(np.arange(len(active)), np.diff(conn.indptr))
        cp = conn.indices.astype(np.int64)
        sums = conn.data
        cu = active[cl]
        own = cp == labels[cu]
        internal = np.zeros(len(active))
        internal[cl[own]] = sums[own]
        gain = sums - internal[cl]
        elig = ((~own) & (gain > 1e-12)
                & (part_w[cp] + node_w[cu] <= max_w)
                & (part_w[labels[cu]] - node_w[cu] >= min_w))
        if not elig.any():
            break
        g_e, u_e, d_e = gain[elig], cu[elig], cp[elig]
        o2 = np.lexsort((g_e, u_e))
        last = np.flatnonzero(
            np.concatenate((u_e[o2][1:] != u_e[o2][:-1], [True])))
        mv = o2[last]
        u_m, d_m, g_m = u_e[mv], d_e[mv], g_e[mv]
        keep_m = (_budget_prefix(d_m, g_m, node_w[u_m], max_w - part_w)
                  & _budget_prefix(labels[u_m], g_m, node_w[u_m],
                                   part_w - min_w))
        u_m, d_m = u_m[keep_m], d_m[keep_m]
        if len(u_m) == 0:
            break
        nb, wt = _adjacency(W, u_m)
        cnt = W.indptr[u_m + 1] - W.indptr[u_m]
        src = np.repeat(u_m, cnt)
        cross0 = labels[src] != labels[nb]
        np.add.at(part_w, labels[u_m], -node_w[u_m])
        np.add.at(part_w, d_m, node_w[u_m])
        labels[u_m] = d_m
        cross1 = labels[src] != labels[nb]
        # Mover-mover edges appear in both endpoints' gathers: halve them.
        is_mover[u_m] = True
        half = np.where(is_mover[nb], 0.5, 1.0)
        is_mover[u_m] = False
        cut_delta += float((wt * half * (cross1.astype(np.float64)
                                         - cross0)).sum())
        if cut_delta < best_delta - 1e-12:
            best_delta, best_labels = cut_delta, labels.copy()
        active = np.unique(np.concatenate((u_m, nb)))
    return best_labels


def _refine_spgemm(
    W: sp.csr_matrix,
    node_w: np.ndarray,
    labels: np.ndarray,
    k: int,
    W_sum: float,
    max_w: float,
    min_w: float,
    passes: int,
) -> np.ndarray:
    """The O(E)-memory refinement table path (see :func:`_refine_vec`)."""
    labels = labels.copy()
    part_w = np.zeros(k)
    np.add.at(part_w, labels, node_w)
    best_cut, best_labels = np.inf, labels
    stale = 0
    for _ in range(passes + 1):            # +1: last table just scores
        cu, cp, gain, own, internal = _conn_table(W, labels, k)
        cut = (W_sum - float(internal.sum())) / 2.0
        if cut < best_cut * (1.0 - 1e-3) - 1e-12:
            best_cut, best_labels, stale = cut, labels.copy(), 0
        elif cut < best_cut - 1e-12:      # tiny gain: keep it but wind down
            best_cut, best_labels = cut, labels.copy()
            stale += 1
        else:
            stale += 1
        if stale >= 2:
            break
        elig = ((~own) & (gain > 1e-12)
                & (part_w[cp] + node_w[cu] <= max_w)
                & (part_w[labels[cu]] - node_w[cu] >= min_w))
        if not elig.any():
            break
        g_e, u_e, d_e = gain[elig], cu[elig], cp[elig]
        o2 = np.lexsort((g_e, u_e))
        last = np.flatnonzero(
            np.concatenate((u_e[o2][1:] != u_e[o2][:-1], [True])))
        mv = o2[last]                      # best destination per node
        u_m, d_m, g_m = u_e[mv], d_e[mv], g_e[mv]
        keep_m = (_budget_prefix(d_m, g_m, node_w[u_m], max_w - part_w)
                  & _budget_prefix(labels[u_m], g_m, node_w[u_m],
                                   part_w - min_w))
        u_m, d_m = u_m[keep_m], d_m[keep_m]
        if len(u_m) == 0:
            break
        np.add.at(part_w, labels[u_m], -node_w[u_m])
        np.add.at(part_w, d_m, node_w[u_m])
        labels[u_m] = d_m
    return best_labels


def _rebalance_vec(W: sp.csr_matrix, labels: np.ndarray, k: int,
                   cap: int) -> np.ndarray:
    """Strict balance: every part ends with at most ``cap`` (unit-weight)
    members.  Evicts the lowest-internal-connectivity members of oversized
    parts into under-capacity slots in one batched round (feasible because
    ``k * cap >= n``).  Internal connectivity is gathered for the
    oversized parts' members only — no full gain-table spgemm."""
    counts = np.bincount(labels, minlength=k)
    excess = counts - cap
    if not (excess > 0).any():
        return labels
    labels = labels.copy()
    members = np.flatnonzero((excess > 0)[labels])
    nb, wt = _adjacency(W, members)
    cnt = W.indptr[members + 1] - W.indptr[members]
    seg = np.repeat(np.arange(len(members)), cnt)
    lm = labels[members]
    same = labels[nb] == np.repeat(lm, cnt)
    internal = np.zeros(len(members))
    np.add.at(internal, seg[same], wt[same])
    o = np.lexsort((internal, lm))         # per part, weakest members first
    ms, ls = members[o], lm[o]
    starts = np.flatnonzero(np.concatenate(([True], ls[1:] != ls[:-1])))
    rank = np.arange(len(ms)) - np.repeat(
        starts, np.diff(np.concatenate((starts, [len(ms)]))))
    evict = ms[rank < excess[ls]]
    slots = np.repeat(np.arange(k), np.clip(cap - counts, 0, None))
    labels[evict] = slots[: len(evict)]
    return labels


_PRUNE_DEG = 28           # mean-degree threshold before coarse-graph pruning
_PRUNE_TARGET = 20        # mean degree a pruned coarse graph is cut down to


def _prune_rows(W: sp.csr_matrix, mean_deg: int) -> sp.csr_matrix:
    """Drop the globally weakest edges down to ``mean_deg`` per node, while
    protecting every row's heaviest edge (union-symmetrized).

    Star contraction densifies coarse graphs (mean degree grows every
    level), so refinement and flood growth on them cost as much as the
    finest level.  METIS truncates coarse adjacency for the same reason:
    the dropped edges are the weakest similarities, and the finest level
    still refines against the full graph, so cut quality is repaired
    below.  A single global weight threshold (one ``np.partition``) beats
    a per-row sort; the row-max protection keeps weakly-weighted regions
    connected.  Deterministic (threshold + exact-value comparisons only).
    """
    n = W.shape[0]
    nnz = W.nnz
    target_nnz = mean_deg * n
    if nnz <= target_nnz:
        return W
    data = W.data
    thresh = np.partition(data, nnz - target_nnz)[nnz - target_nnz]
    deg = np.diff(W.indptr)
    rowmax = np.zeros(n, dtype=data.dtype)
    nz = deg > 0
    if nz.any():
        rowmax[nz] = np.maximum.reduceat(data, W.indptr[:-1][nz])
    rows = np.repeat(np.arange(n), deg)
    keep = (data >= thresh) | (data == rowmax[rows])
    P = sp.csr_matrix((data[keep], (rows[keep], W.indices[keep])),
                      shape=W.shape)
    # Union-symmetrize: an edge survives if either endpoint kept it (the
    # input is symmetric, so elementwise max restores symmetry exactly).
    return P.maximum(P.T).tocsr()


def _coarsen_chain(
    graphs: list[tuple[sp.csr_matrix, np.ndarray]],
    maps: list[np.ndarray],
    rng: np.random.Generator,
    stop: int,
    w_cap: float,
    temperature: float,
    max_levels: int | None = None,
) -> None:
    """Extend the multilevel chain in place down to ``stop`` nodes
    (at most ``max_levels`` further contractions when given)."""
    start = len(maps)
    while graphs[-1][0].shape[0] > stop:
        if max_levels is not None and len(maps) - start >= max_levels:
            break
        Wc0, nw0 = graphs[-1]
        coarse = _heavy_edge_coarsen(Wc0, nw0, rng, temperature, w_cap)
        if coarse.max() + 1 >= 0.97 * Wc0.shape[0]:   # coarsening stalled
            break
        Wc, nw = _contract(Wc0, nw0, coarse)
        if Wc.shape[0] and Wc.nnz > _PRUNE_DEG * Wc.shape[0]:
            Wc = _prune_rows(Wc, _PRUNE_TARGET)
        graphs.append((Wc, nw))
        maps.append(coarse)


@dataclasses.dataclass(frozen=True, eq=False)
class PartitionHierarchy:
    """Cached multilevel coarsening state for incremental replans (§2).

    Built once per ``(graph, k)`` by :func:`partition_hierarchy` — with
    *untempered* (temperature-0) matching, so it is a pure function of
    ``(W, k, tol, coarsen_to, seed)`` and never depends on which epoch
    built it.  Besides the contraction chain it caches the build's
    *refined labels* at every level.  ``partition_graph(..., reuse=h)``
    keeps every contraction except the last ``top_levels`` frozen and per
    replan only (1) re-draws the top of the chain with fresh Gumbel noise,
    (2) projects the cached labels through it, perturbs them
    (temperature-scaled) and re-runs refinement around what changed: the
    per-epoch replan of the stochastic re-partitioning stream skips both
    the fine-level coarsening and the from-scratch initial partition while
    staying bit-reproducible per ``(seed, epoch)``.
    """

    graphs: tuple[tuple[sp.csr_matrix, np.ndarray], ...]  # finest→coarsest
    maps: tuple[np.ndarray, ...]       # contraction map per level
    labels: tuple[np.ndarray, ...]     # build's refined labels per level
    k: int
    tol: float
    coarsen_to: int
    seed: int
    top_levels: int = 1                # trailing levels a reuse re-draws

    @property
    def n_nodes(self) -> int:
        return self.graphs[0][0].shape[0]

    @property
    def levels(self) -> int:
        return len(self.maps)

    @property
    def frozen_levels(self) -> int:
        """Index of the deepest level whose contraction is never re-drawn."""
        return max(len(self.maps) - self.top_levels, 0)

    def ancestors(self, level: int) -> np.ndarray:
        """Finest-node → level-``level``-node composed contraction map."""
        anc = np.arange(self.n_nodes, dtype=np.int64)
        for m in self.maps[:level]:
            anc = m[anc]
        return anc


class HierarchyCache:
    """Thread-safe, lazily-built per-``k`` hierarchy store for one graph.

    The streaming pipeline re-partitions with a fixed block count but may
    be shared across plans with different ``k`` (tests, sweeps); the cache
    builds each ``PartitionHierarchy`` on first use — safe to call from the
    background replan thread and the synchronous jump-resume path alike.
    ``partition_graph`` and :func:`repro.core.metabatch.resynthesize_plan`
    accept either a cache or a bare hierarchy as ``reuse=``.
    """

    def __init__(self, W: sp.spmatrix, *, tol: float = 0.1,
                 coarsen_to: int = 60, seed: int = 0, top_levels: int = 1):
        self.W = W.tocsr()
        self.tol = tol
        self.coarsen_to = coarsen_to
        self.seed = seed
        self.top_levels = top_levels
        self._lock = threading.Lock()
        self._by_k: dict[int, PartitionHierarchy] = {}
        # Build/hit accounting: the online insert/refresh paths assert
        # their delta-refines never trigger a hierarchy (re)build.
        self.builds = 0
        self.hits = 0

    def get(self, k: int) -> PartitionHierarchy:
        with self._lock:
            h = self._by_k.get(k)
            if h is None:
                h = partition_hierarchy(
                    self.W, k, tol=self.tol, coarsen_to=self.coarsen_to,
                    seed=self.seed, top_levels=self.top_levels)
                self._by_k[k] = h
                self.builds += 1
            else:
                self.hits += 1
            return h


def partition_hierarchy(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
    top_levels: int = 1,
) -> PartitionHierarchy:
    """Build the frozen coarsening state ``partition_graph`` can reuse.

    Runs one full untempered partition and captures the chain plus the
    refined labels at every level.  Pure function of its arguments, so
    replans that reuse the result stay bit-reproducible per
    ``(seed, epoch)`` no matter when the hierarchy was built — a
    jump-resumed stream and an uninterrupted one construct identical
    state.
    """
    capture: dict = {}
    partition_graph(W, k, tol=tol, coarsen_to=coarsen_to, seed=seed,
                    temperature=0.0, _capture=capture)
    graphs = capture.get("graphs") or [(W.tocsr(), np.ones(W.shape[0]))]
    maps = capture.get("maps") or []
    lab_by_level = capture.get("labels") or {
        0: np.zeros(W.shape[0], dtype=np.int64)}
    labels = tuple(lab_by_level[lvl] for lvl in range(len(maps) + 1))
    return PartitionHierarchy(
        graphs=tuple(graphs), maps=tuple(maps), labels=labels, k=k,
        tol=tol, coarsen_to=coarsen_to, seed=seed, top_levels=top_levels)


def _project_majority(
    lab: np.ndarray, m: np.ndarray, node_w: np.ndarray, k: int
) -> tuple[np.ndarray, np.ndarray]:
    """Labels for the contracted graph: weight-majority vote per coarse
    node.  Also returns the *impure* mask — coarse nodes whose members
    disagreed, i.e. the only places the projection changed anything."""
    nc = int(m.max()) + 1
    total = np.bincount(m, weights=node_w, minlength=nc)
    if nc * k <= _DENSE_ROUNDS_LIMIT:
        votes = np.bincount(m * k + lab, weights=node_w,
                            minlength=nc * k).reshape(nc, k)
        out = votes.argmax(axis=1)
        win = votes[np.arange(nc), out]
    else:
        # Sort-based fallback for huge (nc, k): heaviest (coarse, label)
        # per coarse node wins.
        key = m * k + lab
        o = np.argsort(key, kind="stable")
        ks, ws = key[o], node_w[o]
        starts = np.flatnonzero(np.concatenate(([True], ks[1:] != ks[:-1])))
        sums = np.add.reduceat(ws, starts)
        uk = ks[starts]
        uc, ul = uk // k, uk % k
        o2 = np.lexsort((sums, uc))
        last = np.flatnonzero(
            np.concatenate((uc[o2][1:] != uc[o2][:-1], [True])))
        out = np.zeros(nc, dtype=np.int64)
        out[uc[o2][last]] = ul[o2][last]
        win = np.zeros(nc)
        win[uc[o2][last]] = sums[o2][last]
    return out, win < total - 1e-12


def _perturb_labels(
    W: sp.csr_matrix, labels: np.ndarray, k: int,
    rng: np.random.Generator, frac: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Move a random ``frac`` of nodes to a random neighbour's part.

    The incremental-replan entropy source: a warm-started replan would
    otherwise only vary through the re-drawn top-level contraction, and a
    stalled re-coarsening would collapse every epoch onto the same
    partition.  Refinement cleans up what the perturbation breaks; the
    strict rebalance keeps the balance cap.  Deterministic per rng.
    Returns ``(labels, picked)``.
    """
    n = len(labels)
    m = int(frac * n)
    deg = np.diff(W.indptr)
    cand = np.flatnonzero(deg > 0)
    if m == 0 or len(cand) == 0:
        return labels, np.empty(0, dtype=np.int64)
    pick = rng.choice(cand, size=min(m, len(cand)), replace=False)
    off = (rng.random(len(pick)) * deg[pick]).astype(np.int64)
    nb = W.indices[W.indptr[pick] + off]
    labels = labels.copy()
    labels[pick] = labels[nb]
    return labels, pick


def partition_graph(
    W: sp.csr_matrix,
    k: int,
    *,
    tol: float = 0.1,
    coarsen_to: int = 60,
    seed: int = 0,
    temperature: float = 0.0,
    refine_passes: int = 8,
    restarts: int | None = None,
    reuse: "PartitionHierarchy | HierarchyCache | None" = None,
    _capture: dict | None = None,
) -> PartitionResult:
    """Vectorized multilevel balanced k-way min-cut partition (the default).

    Same contract as :func:`partition_graph_loop`, with every phase running
    as batched numpy/scipy array ops.  Differences that matter:

    * coarsening continues down to ``max(2k, 128)`` nodes regardless of
      ``coarsen_to`` (refinement at every level is cheap here, and a small
      coarsest graph makes the initial partition nearly free);
    * the initial partition is multi-restarted (``restarts``) on the
      coarsest graph, keeping the best cut;
    * ``temperature > 0`` Gumbel-perturbs the matching weights, giving a
      *stochastic* family of partitions over seeds — the re-partitioning
      stream's entropy source (identical seeds stay bit-reproducible);
    * ``reuse`` (a :class:`PartitionHierarchy` or :class:`HierarchyCache`)
      switches to the incremental-replan fast path: the frozen fine-level
      coarsening is skipped, only the chain's top ``top_levels`` are
      re-drawn (fresh Gumbel noise), the cached labels are projected
      through them, perturbed (temperature-scaled) and refinement re-runs
      around what changed;
    * the final labeling is strictly balanced: every part holds at most
      ``max(floor(n/k·(1+tol)), ceil(n/k))`` nodes.
    """
    n0 = W.shape[0]
    if k <= 1:
        labels = np.zeros(n0, dtype=np.int64)
        if _capture is not None:
            _capture.update(graphs=[(W.tocsr(), np.ones(n0))], maps=[],
                            labels={0: labels.copy()})
        return PartitionResult(labels, 1, 0.0, np.array([n0]))
    if n0 <= k:
        labels = np.arange(n0, dtype=np.int64)
        if _capture is not None:
            _capture.update(graphs=[(W.tocsr(), np.ones(n0))], maps=[],
                            labels={0: labels.copy()})
        return PartitionResult(labels, k, edge_cut(W, labels),
                               np.bincount(labels, minlength=k))
    rng = np.random.default_rng(seed)
    stop = max(2 * k, _COARSE_STOP)
    # METIS-style vertex-weight cap: coarse nodes stay small relative to
    # the balance target, so the coarsest partition can still be balanced
    # (and the final strict rebalance stays a trimming pass, not a rewrite).
    w_cap = n0 / k / 4.0
    target = n0 / k
    cap = max(int(np.floor(target * (1.0 + tol))), int(np.ceil(target)))
    if reuse is not None:
        # The warm incremental path engages only where it pays: below
        # ``_POLISH_LIMIT`` nodes a full partition is cheaper than the
        # delta bookkeeping and the lavish small-graph search (restarts +
        # FM polish) wins on cut — so small graphs fall through and the
        # replan is simply the fresh computation (bit-identical to
        # ``reuse=None``, so every reuse invariant holds trivially).  A
        # HierarchyCache is not even resolved then (``get`` would *build*
        # a hierarchy nobody uses); an already-built PartitionHierarchy is
        # still validated so misuse surfaces regardless of graph size.
        if isinstance(reuse, HierarchyCache):
            if n0 <= _POLISH_LIMIT:
                reuse = None
            else:
                reuse = reuse.get(k)
    if reuse is not None:
        if reuse.n_nodes != n0 or reuse.graphs[0][0].nnz != W.nnz:
            raise ValueError(
                f"reuse hierarchy was built for a different graph "
                f"(n={reuse.n_nodes}, nnz={reuse.graphs[0][0].nnz}; "
                f"got n={n0}, nnz={W.nnz})")
        if reuse.k != k:
            raise ValueError(
                f"reuse hierarchy was built for k={reuse.k}, got k={k}; "
                f"build one per block count (HierarchyCache does this)")
        if reuse.tol != tol or reuse.coarsen_to != coarsen_to:
            raise ValueError(
                f"reuse hierarchy was built under tol={reuse.tol}, "
                f"coarsen_to={reuse.coarsen_to} but this call uses "
                f"tol={tol}, coarsen_to={coarsen_to}; mixing configs "
                f"would silently break the pure-function contract")
        if n0 > _POLISH_LIMIT:
            return _replan_incremental(W, k, reuse, rng, stop, w_cap,
                                       temperature, tol, cap)
    graphs: list[tuple[sp.csr_matrix, np.ndarray]] = [(W.tocsr(),
                                                       np.ones(n0))]
    maps: list[np.ndarray] = []
    # Coarsening — the only phase that draws the Gumbel matching noise.
    _coarsen_chain(graphs, maps, rng, stop, w_cap, temperature)
    lab_rec: dict[int, np.ndarray] = {}
    if _capture is not None:
        _capture.update(graphs=list(graphs), maps=list(maps),
                        labels=lab_rec)
    Wc, nw = graphs[-1]
    # The lavish tier — sequential growth, many restarts, per-restart FM
    # polish — only where the coarsest graph is genuinely tiny; its cost
    # scales with coarse edges, which star contraction densifies.
    small_coarsest = Wc.shape[0] <= 256 and Wc.nnz <= 8_000
    if restarts is None:
        # Restarts only touch the coarsest graph: spend more of them where
        # they are nearly free and the FM polish can exploit a better
        # start; above that, refinement decides quality, not the start.
        restarts = 8 if small_coarsest else 2
    best: tuple[float, np.ndarray] | None = None
    for r in range(-1, max(1, restarts)):
        if r < 0:
            # Extra candidate: chop the reverse-Cuthill–McKee order into k
            # weight-balanced chunks — a layered start qualitatively unlike
            # the grown ones (it rescues bisections whose grown starts all
            # refine into the same local minimum).
            lab = _rcm_chop(Wc, nw, k)
        else:
            # Restart 0 grows from pure max-degree seeds (the loop
            # version's choice); later restarts jitter the seed order for
            # diversity.  Restarts refine without polish; the winner gets it.
            lab = _region_grow_vec(Wc, nw, k,
                                   np.random.default_rng([seed, r]),
                                   jitter_seeds=r > 0)
        # Small coarsest graphs polish inside every restart (cheap, and
        # candidate ranking then matches final quality); large ones rank on
        # batched-refine cuts and only the winner is polished.
        lab = _refine_vec(Wc, nw, lab, k, tol,
                          passes=refine_passes if small_coarsest else 4,
                          polish=small_coarsest)
        c = edge_cut(Wc, lab)
        if best is None or c < best[0]:
            best = (c, lab)
    labels = best[1] if small_coarsest else _refine_vec(
        Wc, nw, best[1], k, tol, passes=4)
    if _capture is not None:
        lab_rec[len(maps)] = labels.copy()
    for level in range(len(maps) - 1, -1, -1):
        labels = labels[maps[level]]
        Wl, nwl = graphs[level]
        if level == 0:
            break                # finest level refines once, after rebalance
        # Refinement effort tapers with level size: quality is decided on
        # the small coarse levels (cheap passes), while the big fine levels
        # only get a touch-up — their boundary is already shaped
        # (measured: <0.2% cut change there).
        nl = Wl.shape[0]
        labels = _refine_vec(
            Wl, nwl, labels, k, tol,
            passes=refine_passes if nl <= _FM_LIMIT
            else min(refine_passes, 5 if nl <= _POLISH_LIMIT else 4))
        if _capture is not None:
            lab_rec[level] = labels.copy()
    Wf, nwf = graphs[0]
    labels = _rebalance_vec(Wf, labels, k, cap)
    labels = _refine_vec(Wf, nwf, labels, k, tol,
                         passes=refine_passes if n0 <= _POLISH_LIMIT else 5,
                         max_w=float(cap))
    if _capture is not None:
        lab_rec[0] = labels.copy()
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


def _replan_incremental(
    W: sp.csr_matrix,
    k: int,
    h: PartitionHierarchy,
    rng: np.random.Generator,
    stop: int,
    w_cap: float,
    temperature: float,
    tol: float,
    cap: int,
) -> PartitionResult:
    """The hierarchy-reuse replan (see :func:`partition_graph`).

    Re-draws only the top ``h.top_levels`` contractions with fresh Gumbel
    noise, projects the cached level labels through them
    (weight-majority), perturbs a temperature-scaled fraction of coarse
    nodes, refines the coarsest graph, and pushes the *delta* against the
    cached labeling down to the finest level — where refinement runs
    seeded with only the changed neighbourhood.  Work scales with how much
    the replan actually changed, not with n.
    """
    # Re-draw only top levels whose contraction was *gentle* (≤2× node
    # reduction — the w_cap-bound many-small-blocks regime): the cached
    # labels survive a weight-majority roundtrip through such a level.
    # Deep star contractions (small k leaves w_cap loose) would relabel
    # half the graph in projection, so those levels stay frozen and the
    # per-epoch noise comes from the perturbation alone.
    L = len(h.maps)
    dropped = 0
    while dropped < min(h.top_levels, L):
        hi = h.graphs[L - dropped - 1][0].shape[0]
        lo = h.graphs[L - dropped][0].shape[0]
        if hi > 2.0 * max(lo, 1):
            break
        dropped += 1
    F = L - dropped
    graphs = list(h.graphs[: F + 1])
    maps = list(h.maps[:F])
    base_levels = len(maps)
    _coarsen_chain(graphs, maps, rng, stop, w_cap, temperature,
                   max_levels=dropped)
    lab = h.labels[F]
    for lvl in range(base_levels, len(maps)):
        lab, _ = _project_majority(lab, maps[lvl], graphs[lvl][1], k)
    Wc, _nw = graphs[-1]
    # Perturbation keeps the replan stochastic even when the top-level
    # re-coarsening stalls (w_cap-bound regimes); temperature stays the
    # single entropy knob.  No coarse-level re-refinement: on the pruned
    # near-dense coarse graphs it re-optimizes *globally* (the cached
    # labeling is not a local optimum of the pruned view), relabeling most
    # of the graph and defeating the incremental delta — the delta-seeded
    # finest refine below repairs the perturbation against the true graph
    # instead.
    frac = min(0.25, 0.04 + 0.08 * temperature)
    lab, _picked = _perturb_labels(Wc, lab, k, rng, frac)
    for lvl in range(len(maps) - 1, base_levels - 1, -1):
        lab = lab[maps[lvl]]
    # ``lab`` now lives on level F: apply the delta to the cached finest
    # labeling, so unchanged regions keep their fully-refined assignment.
    changed = lab != h.labels[F]
    anc = h.ancestors(F)
    labels = h.labels[0].copy()
    moved = changed[anc]
    labels[moved] = lab[anc[moved]]
    Wf, nwf = h.graphs[0]
    pre = labels
    labels = _rebalance_vec(Wf, labels, k, cap)
    # Seed the refine with exactly what changed (perturbed chunks +
    # rebalance evictions); moves pull adjacent rows in on their own, so
    # no up-front neighbourhood expansion — the refine cost tracks the
    # delta, not n.
    touched = np.flatnonzero(moved | (labels != pre))
    labels = _refine_vec(Wf, nwf, labels, k, tol, passes=2,
                         max_w=float(cap), seed_touched=touched)
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


def repair_partition(
    W: sp.csr_matrix,
    labels: np.ndarray,
    k: int,
    *,
    tol: float = 0.1,
    touched: np.ndarray | None = None,
    passes: int = 2,
) -> PartitionResult:
    """Locally repair an existing labeling of ``W`` — the online delta path.

    The `_replan_incremental` tail as a public entry: strict rebalance to
    the ``(n, k, tol)`` cap, then delta-seeded refinement around
    ``touched`` (node indices whose incident structure changed — inserted
    nodes, endpoints of refreshed edges, neighbours of evicted nodes) plus
    whatever the rebalance evicted.  Never coarsens, never rebuilds a
    hierarchy: work tracks the delta, not n.  With ``touched=None`` only
    rebalance evictions seed the refine.
    """
    labels = np.asarray(labels, dtype=np.int64)
    n = W.shape[0]
    if labels.shape[0] != n:
        raise ValueError(f"labels cover {labels.shape[0]} nodes, W has {n}")
    cap = max(int(n / k * (1.0 + tol)), -(-n // k))
    node_w = np.ones(n)
    pre = labels
    labels = _rebalance_vec(W, labels, k, cap)
    seed = np.zeros(n, dtype=bool)
    if touched is not None:
        seed[np.asarray(touched, dtype=np.int64)] = True
    seed[labels != pre] = True
    labels = _refine_vec(W, node_w, labels, k, tol, passes=passes,
                         max_w=float(cap),
                         seed_touched=np.flatnonzero(seed))
    sizes = np.bincount(labels, minlength=k)
    return PartitionResult(labels, k, edge_cut(W, labels), sizes)


def extend_partition(
    W: sp.csr_matrix,
    old_labels: np.ndarray,
    k: int,
    *,
    tol: float = 0.1,
    passes: int = 2,
) -> PartitionResult:
    """Partition after node insertion, treating the new rows as a
    "perturbed chunk": no multilevel rebuild, only local repair.

    ``W`` is the patched graph whose first ``len(old_labels)`` rows keep
    their labels; the appended rows (``insert_nodes`` puts them at the
    end) are seeded with their heaviest-neighbour part — the label their
    affinity row most strongly pulls them toward — then
    :func:`repair_partition` rebalances and refines around the insertion
    seam.  New rows with no labeled neighbour fall into the currently
    smallest parts.
    """
    old_labels = np.asarray(old_labels, dtype=np.int64)
    n = W.shape[0]
    n_old = old_labels.shape[0]
    m = n - n_old
    if m < 0:
        raise ValueError(
            f"old_labels cover {n_old} nodes but W has only {n}")
    if m == 0:
        return repair_partition(W, old_labels, k, tol=tol, passes=passes)
    # Heaviest-neighbour seeding against *old* nodes only (new-new edges
    # carry no label information yet).
    sub = W.tocsr()[n_old:, :n_old]
    conn = np.asarray(
        (sub @ _one_hot(old_labels, k)).todense())        # (m, k) weights
    init = np.asarray(conn.argmax(axis=1), dtype=np.int64).ravel()
    orphan = ~(conn.max(axis=1) > 0)
    if orphan.any():
        sizes = np.bincount(old_labels, minlength=k)
        # Round-robin the orphans into the emptiest parts.
        order = np.argsort(sizes, kind="stable")
        init[orphan] = order[np.arange(int(orphan.sum())) % k]
    labels = np.concatenate([old_labels, init])
    return repair_partition(W, labels, k, tol=tol,
                            touched=np.arange(n_old, n), passes=passes)


def partition_permutation(labels: np.ndarray) -> np.ndarray:
    """Stable permutation grouping nodes by part (Fig. 1b re-permutation).

    ``perm[new_index] = old_index``.
    """
    return np.argsort(labels, kind="stable")
