"""k-NN affinity-graph construction (paper §3).

The paper builds a sparse k-NN graph (k=10) over ~1M speech frames with a
ball-tree search, symmetrizes it, and applies an RBF kernel
``w_ij = exp(-||x_i - x_j|| / (2 sigma^2))`` to get edge weights.

Search is exact blocked brute force, *streaming over candidate columns*: for
each row block only one (row_block × col_block) distance tile is live at a
time and a running per-row top-k is merged tile by tile — the N×N (or even
row_block × N) distance matrix is never materialized, which is what keeps
construction feasible on the ROADMAP's path to corpus-scale graphs (graph
construction, not training, is the scale bottleneck — Bai et al. 1511.06104).

Two backends share the same semantics and are validated against each other:

  * ``"host"``   — numpy, column-streamed (this module; the default);
  * ``"device"`` — the Pallas streaming top-k kernel
    (``repro.kernels.pairwise.knn_topk_pallas``), which keeps the running
    top-k in VMEM scratch next to the MXU distance contraction.

Both backends compute distances in float32: the self-tuning ``sigma``
heuristic (and hence every edge weight) is a function of the returned
distances, so the search dtype is pinned rather than inherited from the
input — host-f64 vs device-f32 used to make the *same corpus* produce
different graphs depending on backend.

Dynamic corpora: :func:`insert_nodes` / :func:`evict_nodes` (also exposed as
``AffinityGraph.insert`` / ``.evict``) patch the symmetric CSR incrementally —
a streaming top-k of the new rows against the corpus plus symmetric row
patching — so new users join the live graph without an O(N²) rebuild
(``repro.online`` drives these under traffic).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = [
    "AffinityGraph",
    "pairwise_sq_dists",
    "knn_edges",
    "build_affinity_graph",
    "insert_nodes",
    "evict_nodes",
]


@dataclasses.dataclass(frozen=True)
class AffinityGraph:
    """Symmetric weighted k-NN affinity graph G = (V, E, W) in CSR form."""

    W: sp.csr_matrix          # symmetric affinity weights, zero diagonal
    k: int                    # neighbours requested per node
    sigma: float              # RBF bandwidth actually used

    @property
    def n_nodes(self) -> int:
        return self.W.shape[0]

    @property
    def n_edges(self) -> int:
        return self.W.nnz // 2

    def degrees(self) -> np.ndarray:
        """Weighted degree ``d_i = sum_j w_ij`` (the Eq. 3 coefficient)."""
        return np.asarray(self.W.sum(axis=1)).ravel()

    def neighbor_counts(self) -> np.ndarray:
        """|N_i| — structural neighbour counts (used by Eq. 5 stats)."""
        return np.diff(self.W.indptr)

    def permuted(self, perm: np.ndarray) -> "AffinityGraph":
        """Re-permute the affinity matrix (paper Fig. 1b) by ``perm``.

        ``perm[new_index] = old_index``; rows/cols are reordered so that a
        graph partitioning yields a dense block-diagonal structure.
        """
        P = sp.csr_matrix(
            (np.ones(len(perm)), (np.arange(len(perm)), perm)),
            shape=self.W.shape,
        )
        Wp = (P @ self.W @ P.T).tocsr()
        Wp.sort_indices()
        return AffinityGraph(W=Wp, k=self.k, sigma=self.sigma)

    def dense_block(self, idx: np.ndarray) -> np.ndarray:
        """Dense ``|idx| x |idx|`` affinity sub-block for a (meta-)batch."""
        sub = self.W[idx][:, idx]
        return np.asarray(sub.todense(), dtype=np.float32)

    def insert(self, X: np.ndarray, X_new: np.ndarray,
               **kw) -> "AffinityGraph":
        """See :func:`insert_nodes`."""
        return insert_nodes(self, X, X_new, **kw)

    def evict(self, idx: np.ndarray) -> "AffinityGraph":
        """See :func:`evict_nodes`."""
        return evict_nodes(self, idx)


def pairwise_sq_dists(X: np.ndarray, Y: np.ndarray) -> np.ndarray:
    """Squared euclidean distances, the classic ||x||^2 - 2xy + ||y||^2 form."""
    xx = np.einsum("id,id->i", X, X)[:, None]
    yy = np.einsum("jd,jd->j", Y, Y)[None, :]
    d2 = xx - 2.0 * (X @ Y.T) + yy
    np.maximum(d2, 0.0, out=d2)
    return d2


def _streaming_topk_host(X: np.ndarray, k: int, block: int,
                         col_block: int) -> tuple[np.ndarray, np.ndarray]:
    """Column-streamed exact top-k: running (rows, k) state merged one
    (block × col_block) distance tile at a time; peak memory is one tile
    plus the running state — independent of n along the candidate axis.

    Distances are float32 regardless of the input dtype, matching the
    device backend so the sigma heuristic downstream agrees across the two.
    """
    X = np.ascontiguousarray(X, dtype=np.float32)
    n = X.shape[0]
    offs = np.arange(n)
    return _streaming_topk_rows(X, X, k, block, col_block, self_of_row=offs)


def _streaming_topk_rows(
    Q: np.ndarray, Y: np.ndarray, k: int, block: int, col_block: int,
    *, self_of_row: np.ndarray | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k of query rows ``Q`` against candidate rows ``Y``, streamed
    in (block × col_block) f32 tiles.  ``self_of_row[i]`` (optional) names a
    candidate column excluded for query row i — the self index when Q is a
    row slice of Y, as in the online insert path."""
    Q = np.ascontiguousarray(Q, dtype=np.float32)
    Y = np.ascontiguousarray(Y, dtype=np.float32)
    m, n = Q.shape[0], Y.shape[0]
    qn = np.einsum("id,id->i", Q, Q)
    yn = np.einsum("id,id->i", Y, Y)
    cols = np.empty((m, k), dtype=np.int64)
    dsts = np.empty((m, k), dtype=np.float32)
    for s in range(0, m, block):
        e = min(s + block, m)
        run_d = np.full((e - s, k), np.inf, dtype=np.float32)
        run_i = np.full((e - s, k), -1, dtype=np.int64)
        for cs in range(0, n, col_block):
            ce = min(cs + col_block, n)
            d2 = qn[s:e, None] - 2.0 * (Q[s:e] @ Y[cs:ce].T) + yn[None, cs:ce]
            np.maximum(d2, 0.0, out=d2)
            if self_of_row is not None:
                sc = self_of_row[s:e]
                hit = (sc >= cs) & (sc < ce)
                if hit.any():
                    d2[np.flatnonzero(hit), sc[hit] - cs] = np.inf
            cand_d = np.concatenate([run_d, d2], axis=1)
            cand_i = np.concatenate(
                [run_i, np.broadcast_to(np.arange(cs, ce), d2.shape)], axis=1)
            sel = np.argpartition(cand_d, k - 1, axis=1)[:, :k]
            run_d = np.take_along_axis(cand_d, sel, axis=1)
            run_i = np.take_along_axis(cand_i, sel, axis=1)
        order = np.argsort(run_d, axis=1, kind="stable")
        cols[s:e] = np.take_along_axis(run_i, order, axis=1)
        dsts[s:e] = np.take_along_axis(run_d, order, axis=1)
    return cols, dsts


def _streaming_topk_device(X: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """The Pallas streaming top-k kernel (running top-k in VMEM scratch).

    Calls the kernel unconditionally (interpret mode off-TPU) — falling back
    to the dense jnp oracle here would silently break the "never materialize
    N×M" contract this backend exists for.
    """
    import jax.numpy as jnp

    from repro.kernels.pairwise import knn_topk_pallas

    x = jnp.asarray(np.asarray(X, dtype=np.float32))
    d2, idx = knn_topk_pallas(x, x, k, exclude_self=True)
    return (np.asarray(idx, dtype=np.int64),
            np.asarray(d2, dtype=np.float32))


def knn_edges(
    X: np.ndarray,
    k: int,
    *,
    block: int = 2048,
    col_block: int = 4096,
    backend: str = "host",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact k-NN by blocked brute force, streaming over candidate columns.

    The paper uses an approximate ball-tree (sklearn); for our corpus sizes
    exact blocked search is both simpler and exactly reproducible.  The
    candidate axis is consumed in ``col_block``-wide chunks against a
    running per-row top-k, so no row ever sees more than one distance tile
    at a time.  ``backend="device"`` routes the search through the Pallas
    streaming top-k kernel instead (same semantics, f32 distances).
    Returns (rows, cols, sq_dists) for the directed k-NN edge set (self
    excluded), neighbours sorted nearest-first.
    """
    n = X.shape[0]
    k = min(k, n - 1)
    if backend not in ("host", "device"):
        raise ValueError(
            f"backend must be 'host' or 'device', got {backend!r}")
    if backend == "device":
        cols, dsts = _streaming_topk_device(X, k)
    else:
        cols, dsts = _streaming_topk_host(X, k, block, col_block)
    src = np.repeat(np.arange(n), k)
    return src, cols.ravel(), dsts.ravel()


def build_affinity_graph(
    X: np.ndarray,
    *,
    k: int = 10,
    sigma: float | None = None,
    block: int = 2048,
    col_block: int = 4096,
    backend: str = "host",
) -> AffinityGraph:
    """Build the symmetrized RBF-weighted k-NN graph of the paper.

    ``sigma=None`` uses the self-tuning heuristic: sigma = mean distance to
    the k-th neighbour (the paper does not report its sigma; this is the
    standard choice and is recorded on the returned graph).  The heuristic
    is evaluated on float32 distances on *both* backends, so host and
    device builds agree to f32 round-off.  ``backend`` selects the
    streaming top-k search: ``"host"`` (numpy) or ``"device"`` (Pallas
    kernel) — see :func:`knn_edges`.
    """
    n = X.shape[0]
    src, dst, d2 = knn_edges(X, k, block=block, col_block=col_block,
                             backend=backend)
    dist = np.sqrt(d2)
    if sigma is None:
        kth = dist.reshape(n, -1)[:, -1]
        sigma = float(np.mean(kth)) or 1.0
    w = np.exp(-dist / (2.0 * sigma * sigma))  # paper's kernel: exp(-||.||/2s^2)
    W = sp.csr_matrix((w, (src, dst)), shape=(n, n))
    # Symmetrize: w_ij = max(w_ij, w_ji) keeps weights in the RBF range.
    W = W.maximum(W.T).tocsr()
    W.setdiag(0.0)
    W.eliminate_zeros()
    W.sort_indices()
    return AffinityGraph(W=W, k=k, sigma=sigma)


def insert_nodes(
    graph: AffinityGraph,
    X: np.ndarray,
    X_new: np.ndarray,
    *,
    block: int = 2048,
    col_block: int = 4096,
) -> AffinityGraph:
    """Append ``X_new`` rows to the graph without an O(N²) rebuild.

    Streaming top-k of the new rows against the combined corpus
    ``[X; X_new]`` (self excluded, new rows see each other), weighted with
    the graph's *recorded* sigma, then symmetric row patching via
    ``max(W, Wᵀ)``.  Existing rows keep their edge sets untouched — their
    k-NN lists are not re-run, they only *gain* reverse edges from new
    nodes — so :func:`evict_nodes` of the same rows restores the original
    graph bit-for-bit (the online insert/evict round-trip invariant).

    ``X`` must be the feature (or embedding) matrix the graph was built
    from, one row per existing node.
    """
    n = graph.n_nodes
    if X.shape[0] != n:
        raise ValueError(
            f"X has {X.shape[0]} rows but the graph has {n} nodes")
    X_new = np.atleast_2d(X_new)
    m = X_new.shape[0]
    if m == 0:
        return graph
    Y = np.concatenate(
        [np.asarray(X, np.float32), np.asarray(X_new, np.float32)])
    k = min(graph.k, n + m - 1)
    cols, d2 = _streaming_topk_rows(
        X_new, Y, k, block, col_block, self_of_row=np.arange(n, n + m))
    w = np.exp(-np.sqrt(d2) / (2.0 * graph.sigma * graph.sigma))
    rows = np.repeat(np.arange(m), k)
    new_rows = sp.csr_matrix((w.ravel(), (rows, cols.ravel())),
                             shape=(m, n + m))
    top = sp.hstack([graph.W, sp.csr_matrix((n, m))], format="csr")
    Wd = sp.vstack([top, new_rows], format="csr")
    W2 = Wd.maximum(Wd.T).tocsr()
    W2.setdiag(0.0)
    W2.eliminate_zeros()
    W2.sort_indices()
    return AffinityGraph(W=W2, k=graph.k, sigma=graph.sigma)


def evict_nodes(graph: AffinityGraph, idx: np.ndarray) -> AffinityGraph:
    """Drop nodes ``idx``: symmetric row/col deletion + compact reindexing.

    Surviving node j gets new index ``j - |{i in idx : i < j}|``.  Because
    insertion never rewrites existing rows, evicting exactly the rows a
    prior :func:`insert_nodes` appended returns the original graph.
    """
    idx = np.atleast_1d(np.asarray(idx, dtype=np.int64))
    n = graph.n_nodes
    if idx.size and (idx.min() < 0 or idx.max() >= n):
        raise ValueError(f"evict indices out of range for {n} nodes")
    keep = np.ones(n, dtype=bool)
    keep[idx] = False
    W = graph.W[keep][:, keep].tocsr()
    W.sort_indices()
    return AffinityGraph(W=W, k=graph.k, sigma=graph.sigma)
