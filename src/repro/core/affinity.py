"""k-NN affinity-graph construction (paper §3).

The paper builds a sparse k-NN graph (k=10) over ~1M speech frames with a
ball-tree search, symmetrizes it, and applies an RBF kernel
``w_ij = exp(-||x_i - x_j|| / (2 sigma^2))`` to get edge weights.

Search is exact blocked brute force, *streaming over candidate columns*: for
each row block only one (row_block × col_block) distance tile is live at a
time and a running per-row top-k is merged tile by tile — the N×N (or even
row_block × N) distance matrix is never materialized, which is what keeps
construction feasible on the ROADMAP's path to corpus-scale graphs (graph
construction, not training, is the scale bottleneck — Bai et al. 1511.06104).

Two backends share the same semantics and are validated against each other:

  * ``"host"``   — numpy, column-streamed (this module; the default);
  * ``"device"`` — the Pallas streaming top-k kernel
    (``repro.kernels.pairwise.knn_topk_pallas``), which keeps the running
    top-k in VMEM scratch next to the MXU distance contraction.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = [
    "AffinityGraph",
    "pairwise_sq_dists",
    "knn_edges",
    "build_affinity_graph",
]


@dataclasses.dataclass(frozen=True)
class AffinityGraph:
    """Symmetric weighted k-NN affinity graph G = (V, E, W) in CSR form."""

    W: sp.csr_matrix          # symmetric affinity weights, zero diagonal
    k: int                    # neighbours requested per node
    sigma: float              # RBF bandwidth actually used

    @property
    def n_nodes(self) -> int:
        return self.W.shape[0]

    @property
    def n_edges(self) -> int:
        return self.W.nnz // 2

    def degrees(self) -> np.ndarray:
        """Weighted degree ``d_i = sum_j w_ij`` (the Eq. 3 coefficient)."""
        return np.asarray(self.W.sum(axis=1)).ravel()

    def neighbor_counts(self) -> np.ndarray:
        """|N_i| — structural neighbour counts (used by Eq. 5 stats)."""
        return np.diff(self.W.indptr)

    def permuted(self, perm: np.ndarray) -> "AffinityGraph":
        """Re-permute the affinity matrix (paper Fig. 1b) by ``perm``.

        ``perm[new_index] = old_index``; rows/cols are reordered so that a
        graph partitioning yields a dense block-diagonal structure.
        """
        P = sp.csr_matrix(
            (np.ones(len(perm)), (np.arange(len(perm)), perm)),
            shape=self.W.shape,
        )
        Wp = (P @ self.W @ P.T).tocsr()
        Wp.sort_indices()
        return AffinityGraph(W=Wp, k=self.k, sigma=self.sigma)

    def dense_block(self, idx: np.ndarray) -> np.ndarray:
        """Dense ``|idx| x |idx|`` affinity sub-block for a (meta-)batch."""
        sub = self.W[idx][:, idx]
        return np.asarray(sub.todense(), dtype=np.float32)


def pairwise_sq_dists(X: np.ndarray, Y: np.ndarray) -> np.ndarray:
    """Squared euclidean distances, the classic ||x||^2 - 2xy + ||y||^2 form."""
    xx = np.einsum("id,id->i", X, X)[:, None]
    yy = np.einsum("jd,jd->j", Y, Y)[None, :]
    d2 = xx - 2.0 * (X @ Y.T) + yy
    np.maximum(d2, 0.0, out=d2)
    return d2


def _streaming_topk_host(X: np.ndarray, k: int, block: int,
                         col_block: int) -> tuple[np.ndarray, np.ndarray]:
    """Column-streamed exact top-k: running (rows, k) state merged one
    (block × col_block) distance tile at a time; peak memory is one tile
    plus the running state — independent of n along the candidate axis."""
    n = X.shape[0]
    nrm = np.einsum("id,id->i", X, X)
    cols = np.empty((n, k), dtype=np.int64)
    dsts = np.empty((n, k), dtype=np.float64)
    for s in range(0, n, block):
        e = min(s + block, n)
        run_d = np.full((e - s, k), np.inf)
        run_i = np.full((e - s, k), -1, dtype=np.int64)
        for cs in range(0, n, col_block):
            ce = min(cs + col_block, n)
            d2 = nrm[s:e, None] - 2.0 * (X[s:e] @ X[cs:ce].T) + nrm[None, cs:ce]
            np.maximum(d2, 0.0, out=d2)
            diag = np.arange(max(s, cs), min(e, ce))     # exclude self
            if diag.size:
                d2[diag - s, diag - cs] = np.inf
            cand_d = np.concatenate([run_d, d2], axis=1)
            cand_i = np.concatenate(
                [run_i, np.broadcast_to(np.arange(cs, ce), d2.shape)], axis=1)
            sel = np.argpartition(cand_d, k - 1, axis=1)[:, :k]
            run_d = np.take_along_axis(cand_d, sel, axis=1)
            run_i = np.take_along_axis(cand_i, sel, axis=1)
        order = np.argsort(run_d, axis=1, kind="stable")
        cols[s:e] = np.take_along_axis(run_i, order, axis=1)
        dsts[s:e] = np.take_along_axis(run_d, order, axis=1)
    return cols, dsts


def _streaming_topk_device(X: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """The Pallas streaming top-k kernel (running top-k in VMEM scratch).

    Calls the kernel unconditionally (interpret mode off-TPU) — falling back
    to the dense jnp oracle here would silently break the "never materialize
    N×M" contract this backend exists for.
    """
    import jax.numpy as jnp

    from repro.kernels.pairwise import knn_topk_pallas

    x = jnp.asarray(np.asarray(X, dtype=np.float32))
    d2, idx = knn_topk_pallas(x, x, k, exclude_self=True)
    return (np.asarray(idx, dtype=np.int64),
            np.asarray(d2, dtype=np.float64))


def knn_edges(
    X: np.ndarray,
    k: int,
    *,
    block: int = 2048,
    col_block: int = 4096,
    backend: str = "host",
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact k-NN by blocked brute force, streaming over candidate columns.

    The paper uses an approximate ball-tree (sklearn); for our corpus sizes
    exact blocked search is both simpler and exactly reproducible.  The
    candidate axis is consumed in ``col_block``-wide chunks against a
    running per-row top-k, so no row ever sees more than one distance tile
    at a time.  ``backend="device"`` routes the search through the Pallas
    streaming top-k kernel instead (same semantics, f32 distances).
    Returns (rows, cols, sq_dists) for the directed k-NN edge set (self
    excluded), neighbours sorted nearest-first.
    """
    n = X.shape[0]
    k = min(k, n - 1)
    if backend not in ("host", "device"):
        raise ValueError(
            f"backend must be 'host' or 'device', got {backend!r}")
    if backend == "device":
        cols, dsts = _streaming_topk_device(X, k)
    else:
        cols, dsts = _streaming_topk_host(X, k, block, col_block)
    src = np.repeat(np.arange(n), k)
    return src, cols.ravel(), dsts.ravel()


def build_affinity_graph(
    X: np.ndarray,
    *,
    k: int = 10,
    sigma: float | None = None,
    block: int = 2048,
    col_block: int = 4096,
    backend: str = "host",
) -> AffinityGraph:
    """Build the symmetrized RBF-weighted k-NN graph of the paper.

    ``sigma=None`` uses the self-tuning heuristic: sigma = mean distance to
    the k-th neighbour (the paper does not report its sigma; this is the
    standard choice and is recorded on the returned graph).  ``backend``
    selects the streaming top-k search: ``"host"`` (numpy) or ``"device"``
    (Pallas kernel) — see :func:`knn_edges`.
    """
    n = X.shape[0]
    src, dst, d2 = knn_edges(X, k, block=block, col_block=col_block,
                             backend=backend)
    dist = np.sqrt(d2)
    if sigma is None:
        kth = dist.reshape(n, -1)[:, -1]
        sigma = float(np.mean(kth)) or 1.0
    w = np.exp(-dist / (2.0 * sigma * sigma))  # paper's kernel: exp(-||.||/2s^2)
    W = sp.csr_matrix((w, (src, dst)), shape=(n, n))
    # Symmetrize: w_ij = max(w_ij, w_ji) keeps weights in the RBF range.
    W = W.maximum(W.T).tocsr()
    W.setdiag(0.0)
    W.eliminate_zeros()
    W.sort_indices()
    return AffinityGraph(W=W, k=k, sigma=sigma)
