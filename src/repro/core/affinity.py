"""k-NN affinity-graph construction (paper §3).

The paper builds a sparse k-NN graph (k=10) over ~1M speech frames with a
ball-tree search, symmetrizes it, and applies an RBF kernel
``w_ij = exp(-||x_i - x_j|| / (2 sigma^2))`` to get edge weights.

Graph construction is a one-time *host-side* preprocessing step (paper §1.1),
so this module is numpy/scipy code.  The blocked pairwise-distance inner loop
has a device-side twin in ``repro.kernels.pairwise`` (Pallas) used when the
feature matrix is already on device; both are validated against each other.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp

__all__ = [
    "AffinityGraph",
    "pairwise_sq_dists",
    "knn_edges",
    "build_affinity_graph",
]


@dataclasses.dataclass(frozen=True)
class AffinityGraph:
    """Symmetric weighted k-NN affinity graph G = (V, E, W) in CSR form."""

    W: sp.csr_matrix          # symmetric affinity weights, zero diagonal
    k: int                    # neighbours requested per node
    sigma: float              # RBF bandwidth actually used

    @property
    def n_nodes(self) -> int:
        return self.W.shape[0]

    @property
    def n_edges(self) -> int:
        return self.W.nnz // 2

    def degrees(self) -> np.ndarray:
        """Weighted degree ``d_i = sum_j w_ij`` (the Eq. 3 coefficient)."""
        return np.asarray(self.W.sum(axis=1)).ravel()

    def neighbor_counts(self) -> np.ndarray:
        """|N_i| — structural neighbour counts (used by Eq. 5 stats)."""
        return np.diff(self.W.indptr)

    def permuted(self, perm: np.ndarray) -> "AffinityGraph":
        """Re-permute the affinity matrix (paper Fig. 1b) by ``perm``.

        ``perm[new_index] = old_index``; rows/cols are reordered so that a
        graph partitioning yields a dense block-diagonal structure.
        """
        P = sp.csr_matrix(
            (np.ones(len(perm)), (np.arange(len(perm)), perm)),
            shape=self.W.shape,
        )
        Wp = (P @ self.W @ P.T).tocsr()
        Wp.sort_indices()
        return AffinityGraph(W=Wp, k=self.k, sigma=self.sigma)

    def dense_block(self, idx: np.ndarray) -> np.ndarray:
        """Dense ``|idx| x |idx|`` affinity sub-block for a (meta-)batch."""
        sub = self.W[idx][:, idx]
        return np.asarray(sub.todense(), dtype=np.float32)


def pairwise_sq_dists(X: np.ndarray, Y: np.ndarray) -> np.ndarray:
    """Squared euclidean distances, the classic ||x||^2 - 2xy + ||y||^2 form."""
    xx = np.einsum("id,id->i", X, X)[:, None]
    yy = np.einsum("jd,jd->j", Y, Y)[None, :]
    d2 = xx - 2.0 * (X @ Y.T) + yy
    np.maximum(d2, 0.0, out=d2)
    return d2


def knn_edges(
    X: np.ndarray,
    k: int,
    *,
    block: int = 2048,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact k-NN by blocked brute force.

    The paper uses an approximate ball-tree (sklearn); for our corpus sizes
    exact blocked search is both simpler and exactly reproducible.  Returns
    (rows, cols, sq_dists) for the directed k-NN edge set (self excluded).
    """
    n = X.shape[0]
    k = min(k, n - 1)
    rows = np.empty((n, k), dtype=np.int64)
    dsts = np.empty((n, k), dtype=np.float64)
    for s in range(0, n, block):
        e = min(s + block, n)
        d2 = pairwise_sq_dists(X[s:e], X)
        d2[np.arange(e - s), np.arange(s, e)] = np.inf  # exclude self
        idx = np.argpartition(d2, k - 1, axis=1)[:, :k]
        part = np.take_along_axis(d2, idx, axis=1)
        order = np.argsort(part, axis=1)
        rows[s:e] = np.take_along_axis(idx, order, axis=1)
        dsts[s:e] = np.take_along_axis(part, order, axis=1)
    src = np.repeat(np.arange(n), k)
    return src, rows.ravel(), dsts.ravel()


def build_affinity_graph(
    X: np.ndarray,
    *,
    k: int = 10,
    sigma: float | None = None,
    block: int = 2048,
) -> AffinityGraph:
    """Build the symmetrized RBF-weighted k-NN graph of the paper.

    ``sigma=None`` uses the self-tuning heuristic: sigma = mean distance to
    the k-th neighbour (the paper does not report its sigma; this is the
    standard choice and is recorded on the returned graph).
    """
    n = X.shape[0]
    src, dst, d2 = knn_edges(X, k, block=block)
    dist = np.sqrt(d2)
    if sigma is None:
        kth = dist.reshape(n, -1)[:, -1]
        sigma = float(np.mean(kth)) or 1.0
    w = np.exp(-dist / (2.0 * sigma * sigma))  # paper's kernel: exp(-||.||/2s^2)
    W = sp.csr_matrix((w, (src, dst)), shape=(n, n))
    # Symmetrize: w_ij = max(w_ij, w_ji) keeps weights in the RBF range.
    W = W.maximum(W.T).tocsr()
    W.setdiag(0.0)
    W.eliminate_zeros()
    W.sort_indices()
    return AffinityGraph(W=W, k=k, sigma=sigma)
