"""Batch-quality statistics from the paper (Eq. 5, Figs. 1c/2a/2b).

Within-batch connectivity  c_j = Σ_i |C_i| / Σ_i |N_i|  over members i of
batch j (Eq. 5), and the label-entropy of a batch — the two opposing
qualities (connectivity vs. diversity) the meta-batch heuristic trades off.
Host-side numpy; consumed by the benchmarks that reproduce the figures.
"""
from __future__ import annotations

import numpy as np

from .affinity import AffinityGraph

__all__ = [
    "within_batch_connectivity",
    "batch_label_entropy",
    "connectivity_distribution",
    "entropy_distribution",
    "random_batches",
]


def within_batch_connectivity(graph: AffinityGraph, batch: np.ndarray) -> float:
    """Eq. 5: fraction of members' neighbours that fall inside the batch."""
    in_batch = np.zeros(graph.n_nodes, dtype=bool)
    in_batch[batch] = True
    indptr, indices = graph.W.indptr, graph.W.indices
    n_total = 0
    n_inside = 0
    for u in batch:
        s, e = indptr[u], indptr[u + 1]
        nbrs = indices[s:e]
        n_total += len(nbrs)
        n_inside += int(in_batch[nbrs].sum())
    return n_inside / max(n_total, 1)


def batch_label_entropy(labels: np.ndarray, batch: np.ndarray,
                        n_classes: int) -> float:
    """Shannon entropy (nats) of the label distribution within a batch."""
    counts = np.bincount(labels[batch], minlength=n_classes).astype(np.float64)
    p = counts / counts.sum()
    nz = p[p > 0]
    return float(-(nz * np.log(nz)).sum())


def connectivity_distribution(graph: AffinityGraph,
                              batches: list[np.ndarray]) -> np.ndarray:
    return np.array([within_batch_connectivity(graph, b) for b in batches])


def entropy_distribution(labels: np.ndarray, batches: list[np.ndarray],
                         n_classes: int) -> np.ndarray:
    return np.array([batch_label_entropy(labels, b, n_classes) for b in batches])


def random_batches(n: int, batch_size: int, *,
                   rng: np.random.Generator) -> list[np.ndarray]:
    """Randomly shuffled mini-batches (the paper's baseline batching)."""
    perm = rng.permutation(n)
    return [perm[s : s + batch_size] for s in range(0, n, batch_size)]
