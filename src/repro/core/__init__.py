"""Core implementation of the paper's contribution.

Stochastic graph regularization over affinity graphs for distributed SSL:
affinity-graph construction, balanced min-cut partitioning, meta-batch
synthesis, stochastic neighbour regularization, and the decomposed
graph-regularized objective.
"""
from .affinity import AffinityGraph, build_affinity_graph
from .metabatch import (MetaBatchPlan, NeighborSampler, concat_batch_indices,
                        epoch_plan_seed, plan_meta_batches, resynthesize_plan)
from .partition import (PartitionResult, edge_cut, partition_graph,
                        partition_graph_loop, partition_permutation)
from .ssl_loss import (SSLHyper, entropy, graph_regularizer,
                       pairwise_cross_entropy_term, ssl_objective,
                       ssl_objective_kl_form)

__all__ = [
    "AffinityGraph", "build_affinity_graph",
    "PartitionResult", "partition_graph", "partition_graph_loop",
    "partition_permutation", "edge_cut",
    "MetaBatchPlan", "plan_meta_batches", "resynthesize_plan",
    "epoch_plan_seed", "NeighborSampler", "concat_batch_indices",
    "SSLHyper", "ssl_objective", "ssl_objective_kl_form",
    "graph_regularizer", "pairwise_cross_entropy_term", "entropy",
]
