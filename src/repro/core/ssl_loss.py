"""Graph-regularized semi-supervised objective (paper Eq. 2 / Eq. 3), in JAX.

Eq. 2 (full KL form):

    J(θ) = Σ_{i∈labeled} D(t_i ‖ p_i)
         + γ Σ_{i,j} ω_ij D(p_i ‖ p_j)
         + κ Σ_i D(p_i ‖ u)
         + λ ‖θ‖²

Eq. 3 (entropy/cross-entropy decomposition, constants w.r.t. θ dropped):

    J_i = Hc(t_i, p_i) + γ Σ_j ω_ij Hc(p_i, p_j)
        − (κ + γ Σ_j ω_ij) H(p_i) + λ‖θ‖²

All functions take *logits* and work in log-space for stability.  The dense
``W`` block is the (meta-)batch's affinity sub-matrix — dense by construction
after graph partitioning (paper Fig. 1b); the pairwise contraction
``Σ_ij W_ij Hc(p_i,p_j)`` is the compute hot-spot and has fused Pallas
kernels in ``repro.kernels.graph_reg`` — select by name via
``pairwise="pallas"`` (cross term), ``"fused"`` (the whole regularizer in
one sweep) or ``"auto"`` (fused on TPU, jnp oracle elsewhere), resolved
through the ``repro.api.registry.PAIRWISE`` registry.  ``pairwise=None``
keeps the inline jnp oracle; an already-resolved callable passes through
unchanged (resolve once, pass the callable down).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "SSLHyper",
    "entropy",
    "pairwise_cross_entropy_term",
    "graph_regularizer",
    "ssl_objective",
    "ssl_objective_kl_form",
    "l2_penalty",
]

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SSLHyper:
    """Hyper-parameters of Eq. 2 (γ graph, κ entropy, λ ℓ2).

    Frozen and hashable so it can sit in jit closures / static args; all
    three weights must be non-negative (zero disables the term).
    """

    gamma: float = 1e-3
    kappa: float = 1e-4
    weight_decay: float = 1e-5

    def __post_init__(self):
        for name in ("gamma", "kappa", "weight_decay"):
            v = getattr(self, name)
            if not v >= 0:
                raise ValueError(
                    f"SSLHyper.{name} must be >= 0, got {v!r}")


def _resolve_pairwise(pairwise: str | Callable | None) -> Callable | None:
    """Registry-name lookup (None -> inline jnp oracle).

    Already-resolved callables (and None) short-circuit without touching the
    registry, so callers can resolve once and pass the callable down.
    """
    if pairwise is None or callable(pairwise):
        return pairwise
    from repro.api.registry import resolve_pairwise  # lazy: avoids cycle
    return resolve_pairwise(pairwise)


def entropy(logp: Array) -> Array:
    """Shannon entropy H(p_i) per row from log-probabilities."""
    p = jnp.exp(logp)
    return -jnp.sum(p * logp, axis=-1)


def pairwise_cross_entropy_term(logp: Array, W: Array) -> Array:
    """Σ_ij W_ij · Hc(p_i, p_j)  with  Hc(p_i,p_j) = −Σ_c p_ic log p_jc.

    Computed as a dense matrix product  −Σ (W ⊙ (P · logPᵀ))  — the paper's
    "efficient matrix-matrix multiplication" formulation (§1.1).  This is
    the pure-jnp oracle; the Pallas kernel tiles the same contraction.
    """
    p = jnp.exp(logp)
    S = p @ logp.T                     # S_ij = Σ_c p_ic log p_jc  (B×B)
    return -jnp.sum(W * S)


def graph_regularizer(
    logp: Array,
    W: Array,
    gamma: float,
    kappa: float,
    *,
    pairwise: str | Callable | None = None,
    layout=None,
) -> Array:
    """γ Σ_ij W_ij Hc(p_i,p_j) − (κ + γ Σ_j W_ij) H(p_i)   (Eq. 4 + entropy reg).

    ``pairwise`` selects the contraction implementation by registry name
    ("ref" | "pallas" | "fused" | "auto"); ``None`` uses the inline jnp
    oracle.  Implementations carrying the ``full_regularizer`` marker (the
    fused single-pass kernel) compute the *whole* penalty — cross term, row
    degrees and entropy correction — in one sweep, so the separate jnp
    degree/entropy passes below are skipped entirely.

    ``layout`` is the batch's block-sparsity descriptor — the flat array
    tuple from ``BlockLayout.arrays()`` (or the ``BlockLayout`` itself) —
    forwarded only to implementations advertising ``accepts_layout`` (the
    block-sparse kernel and "auto"); others ignore it.
    Returns the summed (not averaged) penalty over the batch.
    """
    impl = _resolve_pairwise(pairwise)
    if impl is not None and getattr(impl, "full_regularizer", False):
        if layout is not None and getattr(impl, "accepts_layout", False):
            return impl(logp, W, gamma, kappa, layout=layout)
        return impl(logp, W, gamma, kappa)
    impl = impl or pairwise_cross_entropy_term
    cross = impl(logp, W)
    deg = jnp.sum(W, axis=1)                     # Σ_j ω_ij
    h = entropy(logp)
    return gamma * cross - jnp.sum((kappa + gamma * deg) * h)


def l2_penalty(params) -> Array:
    leaves = jax.tree_util.tree_leaves(params)
    return sum(jnp.sum(jnp.square(x)) for x in leaves) if leaves else jnp.float32(0)


def ssl_objective(
    logits: Array,
    labels: Array,
    label_mask: Array,
    W: Array,
    hyper: SSLHyper,
    *,
    params=None,
    pairwise: str | Callable | None = None,
    layout=None,
    reduction: str = "mean",
) -> tuple[Array, dict]:
    """Decomposed Eq.-3 objective over one (concatenated meta-)batch.

    Args:
      logits: (B, C) unnormalized outputs.
      labels: (B,) int class ids; entries where ``label_mask == 0`` ignored.
      label_mask: (B,) {0,1} — 1 for labeled points (semi-supervised).
      W: (B, B) dense affinity block for this batch.
      pairwise: pairwise-kernel registry name ("ref" | "pallas" | "fused" |
        "auto") or a ``(logp, W) -> scalar`` callable; None = inline jnp
        oracle.  "fused"/"auto" compute the whole graph regularizer in one
        Pallas sweep (see ``graph_regularizer``).
      layout: optional block-sparsity descriptor of ``W`` (the array tuple
        from ``BlockLayout.arrays()``), forwarded to layout-aware pairwise
        implementations so the kernel skips structurally-zero tiles.
      reduction: 'sum' is the paper-faithful Eq. 2; 'mean' normalizes the
        supervised term by #labeled and the graph terms by B (scale-stable
        across batch sizes; used by the trainer).

    Returns (loss, metrics-dict).
    """
    # Resolve the registry name exactly once; graph_regularizer passes the
    # already-resolved callable straight through (no second lookup).
    pairwise = _resolve_pairwise(pairwise)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # Supervised term: Hc(t_i, p_i) over labeled points (t one-hot => CE).
    picked = jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=1)[:, 0]
    sup = -jnp.sum(picked * label_mask)
    n_labeled = jnp.maximum(jnp.sum(label_mask), 1.0)
    greg = graph_regularizer(logp, W, hyper.gamma, hyper.kappa,
                             pairwise=pairwise, layout=layout)
    l2 = hyper.weight_decay * l2_penalty(params) if params is not None else jnp.float32(0)
    if reduction == "mean":
        b = logits.shape[0]
        loss = sup / n_labeled + greg / b + l2
    else:
        loss = sup + greg + l2
    metrics = {
        "loss/supervised": sup / n_labeled,
        "loss/graph": greg,
        "loss/l2": l2,
        "acc/labeled": jnp.sum(
            (jnp.argmax(logits, -1) == labels) * label_mask) / n_labeled,
    }
    return loss, metrics


def ssl_objective_kl_form(
    logits: Array,
    labels: Array,
    label_mask: Array,
    W: Array,
    hyper: SSLHyper,
    *,
    params=None,
) -> Array:
    """Literal Eq.-2 KL form (sum reduction) — used to *test* that the Eq.-3
    decomposition equals Eq. 2 up to constants w.r.t. θ."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    n, c = logits.shape
    onehot = jax.nn.one_hot(labels, c)
    # D(t||p) with one-hot t: -log p[label]  (H(t)=0).
    sup = -jnp.sum(jnp.sum(onehot * logp, axis=-1) * label_mask)
    # D(p_i||p_j) = Σ_c p_ic (log p_ic - log p_jc).
    kl_ij = (jnp.sum(p * logp, axis=-1)[:, None]) - (p @ logp.T)
    graph = jnp.sum(W * kl_ij)
    # D(p||u) = log C - H(p).
    ent = jnp.sum(jnp.log(jnp.float32(c)) - entropy(logp))
    l2 = l2_penalty(params) if params is not None else jnp.float32(0)
    return sup + hyper.gamma * graph + hyper.kappa * ent + hyper.weight_decay * l2
