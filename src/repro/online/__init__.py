"""Online graph construction from the live model + dynamic corpus ingestion.

The static reproduction builds its affinity graph once, from input
features.  This package closes the ROADMAP "online graph construction"
item: the graph tracks the *model's* notion of similarity (embedding-space
refresh from activations captured during the scan epoch — Bai et al.
1511.06104) and the corpus is mutable under traffic (incremental node
insert/evict patched through the partitioner's delta-refine path).
"""
from repro.online.refresh import (OnlineManager, edge_churn, edge_set,
                                  embedding_knn_graph, embedding_topk_device,
                                  scatter_epoch_embeddings)

__all__ = [
    "OnlineManager",
    "edge_set",
    "edge_churn",
    "embedding_knn_graph",
    "embedding_topk_device",
    "scatter_epoch_embeddings",
]
