"""Embedding-space affinity refresh + dynamic corpus ingestion.

The paper's production framing (§4) assumes the regularizer tracks the
model's similarity structure as training progresses; Bai et al. (1511.06104)
build the k-NN graph *online* from the evolving network's embeddings.  This
module is that loop for the repo's training stack:

  capture  — the engine's ``capture_fn``/``on_epoch_end`` hook hands this
             module the hidden activations of every step of a refresh
             epoch (stacked scan ys, donation-safe, zero cost off-epoch);
  refresh  — :func:`embedding_knn_graph` re-runs the streaming top-k over
             those activations (host numpy or the Pallas VMEM-scratch
             kernel — never a dense N×N) and rebuilds the RBF weights with
             a self-tuning bandwidth (global sigma, or Zelnik-Manor
             per-node scaling — the learned-bandwidth option of Sharma &
             Jones 2306.07098);
  swap     — the new graph + plan are lock-published to the
             :class:`~repro.data.pipeline.MetaBatchStream` through
             ``swap_graph`` (the replan handoff path), with the partition
             delta-refined around the changed edges when churn is low and
             re-synthesized from scratch when the topology really moved;
  ingest   — :meth:`OnlineManager.insert` / ``.evict`` patch new/departed
             nodes through ``AffinityGraph.insert``/``.evict`` plus the
             partitioner's "perturbed chunk" repair
             (:func:`~repro.core.partition.extend_partition`) — no full
             ``partition_graph`` rebuild, no hierarchy build.

Determinism: a refresh at epoch ``e`` is a pure function of
``(params, corpus, config, seed)`` — the capture, the host/device top-k,
the bandwidth heuristic, and the plan grouping all derive from those
alone, so identical runs produce bit-identical graphs.

Threading: every :class:`OnlineManager` method runs on the training thread
(the engine fires ``on_epoch_end`` between epochs); all cross-thread
publication — to the prefetch producer reading batches, to the background
replan builder — goes through the stream's lock (``snapshot`` in,
``swap_graph`` out).  The manager itself holds no lock and must not be
driven from two threads.
"""
from __future__ import annotations

import numpy as np
import scipy.sparse as sp

from repro.core.affinity import AffinityGraph, knn_edges
from repro.core.metabatch import (epoch_plan_seed, plan_from_labels,
                                  plan_meta_batches)
from repro.core.partition import (HierarchyCache, extend_partition,
                                  repair_partition)

__all__ = [
    "embedding_topk_device",
    "embedding_knn_graph",
    "edge_set",
    "edge_churn",
    "scatter_epoch_embeddings",
    "OnlineManager",
]


def embedding_topk_device(E, k: int):
    """The jax surface of the refresh: streaming top-k of the embedding
    matrix against itself via the Pallas VMEM-scratch kernel.

    This is the audited entry point (``online_refresh`` in the AUDIT
    registry): its jaxpr must contain 0 dense (N, N) intermediates — the
    running top-k lives in kernel scratch, exactly like the construction
    path of PR 2.
    """
    from repro.kernels.pairwise import knn_topk_pallas
    return knn_topk_pallas(E, E, k, exclude_self=True)


def embedding_knn_graph(
    E: np.ndarray,
    *,
    k: int = 10,
    backend: str = "host",
    bandwidth: str = "global",
    block: int = 2048,
    col_block: int = 4096,
) -> AffinityGraph:
    """Symmetrized RBF k-NN graph over an embedding matrix.

    Same streaming construction as :func:`repro.core.affinity.
    build_affinity_graph` (f32 distances, never a dense N×N), with the
    bandwidth selectable:

    * ``"global"``   — one self-tuning sigma (mean k-th-neighbour
      distance), the paper's kernel;
    * ``"per_node"`` — Zelnik-Manor local scaling
      ``w_ij = exp(-d_ij / (2 σ_i σ_j))`` with ``σ_i`` = node i's k-th-NN
      distance: each node's bandwidth adapts to its local embedding
      density (the learned-bandwidth option, Sharma & Jones 2306.07098).
      The recorded ``graph.sigma`` is still the global mean, so inserts
      against a per-node graph stay well-defined.
    """
    if bandwidth not in ("global", "per_node"):
        raise ValueError(
            f"bandwidth must be 'global' or 'per_node', got {bandwidth!r}")
    E = np.asarray(E, dtype=np.float32)
    n = E.shape[0]
    src, dst, d2 = knn_edges(E, k, block=block, col_block=col_block,
                             backend=backend)
    dist = np.sqrt(d2)
    kth = dist.reshape(n, -1)[:, -1]
    sigma = float(np.mean(kth)) or 1.0
    if bandwidth == "global":
        w = np.exp(-dist / (2.0 * sigma * sigma))
    else:
        sig = np.maximum(kth, 1e-12)
        w = np.exp(-dist / (2.0 * sig[src] * sig[dst]))
    W = sp.csr_matrix((w, (src, dst)), shape=(n, n))
    W = W.maximum(W.T).tocsr()
    W.setdiag(0.0)
    W.eliminate_zeros()
    W.sort_indices()
    return AffinityGraph(W=W, k=min(k, n - 1), sigma=sigma)


def edge_set(graph: AffinityGraph) -> set[tuple[int, int]]:
    """The undirected edge set {(i, j) : i < j, w_ij > 0}."""
    coo = sp.triu(graph.W, k=1).tocoo()
    return set(zip(coo.row.tolist(), coo.col.tolist()))


def edge_churn(old: AffinityGraph, new: AffinityGraph) -> float:
    """Topology churn: |symmetric difference| / |union| of the undirected
    edge sets (0 = identical topology, 1 = disjoint).  Weight changes on a
    surviving edge do not count — the partition only sees weights through
    refinement, which the delta path re-runs anyway."""
    a, b = edge_set(old), edge_set(new)
    union = len(a | b)
    return 0.0 if union == 0 else len(a ^ b) / union


def _changed_endpoints(Wa: sp.csr_matrix, Wb: sp.csr_matrix) -> np.ndarray:
    """Nodes incident to any edge present in exactly one of Wa, Wb."""
    Pa = (Wa != 0).astype(np.int8)
    Pb = (Wb != 0).astype(np.int8)
    D = (Pa - Pb).tocoo()
    return np.unique(np.concatenate([D.row, D.col]))


def scatter_epoch_embeddings(
    captures: np.ndarray,
    indices: list[list[np.ndarray]],
    n: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-node embedding matrix from the engine's stacked epoch captures.

    ``captures`` is the ``on_epoch_end`` payload, ``(steps, k, P, H)``;
    ``indices`` is the stream's ``last_epoch_indices`` — per step, per
    worker, the *unpadded* concatenated node indices that batch row held.
    Later steps overwrite earlier ones (a node sampled twice — Eq.-6
    neighbour draws, the wrap-padded tail group — keeps its freshest
    activation); padding rows beyond ``len(idx)`` are dropped.  Returns
    ``(E, seen)`` with ``seen`` marking nodes that appeared at least once
    (callers embed the gaps directly with a clean forward).
    """
    if len(indices) != captures.shape[0]:
        raise ValueError(
            f"{captures.shape[0]} captured steps but {len(indices)} index "
            "groups — was the stream built with record_indices=True?")
    width = captures.shape[-1]
    E = np.zeros((n, width), dtype=np.float32)
    seen = np.zeros(n, dtype=bool)
    for s, group in enumerate(indices):
        for w, idx in enumerate(group):
            E[idx] = np.asarray(captures[s, w][: len(idx)], dtype=np.float32)
            seen[idx] = True
    return E, seen


class OnlineManager:
    """Drives refresh + ingestion against a live :class:`MetaBatchStream`.

    Wire it into the engine via ``capture_epoch`` (as ``capture_epochs=``)
    and ``on_epoch_end``; call :meth:`insert` / :meth:`evict` from the
    serving/ingestion side between epochs.  ``embed_fn(params, X) ->
    (n, H)`` computes embeddings directly (a clean ``dnn_hidden`` forward)
    for nodes the capture missed and for newly inserted rows after the
    graph has moved to embedding space.

    ``stats`` counts refreshes / delta_refines / full_rebuilds / inserts /
    evictions / rejected swaps — the insert acceptance gate asserts
    ``full_rebuilds`` stays 0 and the swapped-in hierarchy cache records 0
    builds.
    """

    def __init__(self, stream, corpus, graph: AffinityGraph, cfg, *,
                 batch_size: int, n_classes: int, tol: float = 0.15,
                 coarsen_to: int = 60, shuffle_blocks: bool = True,
                 partitioner=None, embed_fn=None, seed: int = 0):
        self.stream = stream
        self.corpus = corpus
        self.graph = graph
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.n_classes = int(n_classes)
        self.tol = tol
        self.coarsen_to = coarsen_to
        self.shuffle_blocks = shuffle_blocks
        self.partitioner = partitioner
        self.embed_fn = embed_fn
        self.seed = int(seed)
        self.params = None           # freshest params seen by on_epoch_end
        # Rows the *current* graph was built from: input features until the
        # first refresh, then the captured embedding matrix.
        self.features = np.asarray(corpus.X)
        self.embedding_space = False
        self.last_churn: float | None = None
        self._ops = 0                # insert/evict counter -> plan seeds
        self.stats = {"refreshes": 0, "delta_refines": 0, "full_rebuilds": 0,
                      "inserts": 0, "evictions": 0, "rejected": 0}

    # ------------------------------------------------------------- engine
    def capture_epoch(self, epoch: int) -> bool:
        """Predicate handed to ``Engine.run(capture_epochs=...)``: capture
        during every ``refresh_every``-th epoch (whose end refreshes)."""
        r = int(getattr(self.cfg, "refresh_every", 0) or 0)
        return r > 0 and (epoch + 1) % r == 0

    def on_epoch_end(self, epoch: int, params, captures) -> None:
        """Engine epoch-end hook: assemble the per-node embedding matrix
        from the epoch's captures and refresh the graph from it."""
        self.params = params
        if captures is None or not self.capture_epoch(epoch):
            return
        indices = self.stream.snapshot()[4]
        if indices is None:
            raise RuntimeError(
                "online refresh needs the stream built with "
                "record_indices=True (the Experiment layer does this when "
                "OnlineConfig is active)")
        E, seen = scatter_epoch_embeddings(captures, indices, self.corpus.n)
        if not seen.all():
            missing = np.flatnonzero(~seen)
            if self.embed_fn is None:
                raise RuntimeError(
                    f"{missing.size} nodes were never captured this epoch "
                    "and no embed_fn was provided to fill the gaps")
            E[missing] = self.embed_fn(params, self.corpus.X[missing])
        self.refresh(epoch, E)

    # ------------------------------------------------------------ refresh
    def _fresh_hierarchy(self, graph: AffinityGraph):
        """A lazily-built cache for the new graph — iff the stream was
        using hierarchy reuse (the old cache describes dead topology)."""
        if self.stream.snapshot()[3] is None:
            return None
        return HierarchyCache(
            graph.W, tol=self.tol, coarsen_to=self.coarsen_to,
            seed=self.seed)

    def refresh(self, epoch: int, embeddings: np.ndarray) -> bool:
        """Rebuild the affinity graph from ``embeddings`` and lock-publish
        it (with a matching plan) to the stream.

        Low edge churn (``<= cfg.churn_threshold``) keeps the previous
        mini-block labels and repairs them around the changed-edge
        endpoints (delta path — the partition work tracks the topology
        delta); high churn re-synthesizes the plan from scratch on the new
        graph.  Returns False when the stream rejected the swap (pad/tile
        budget), in which case the old graph stays live.
        """
        cfg = self.cfg
        k = int(getattr(cfg, "k", None) or self.graph.k)
        new_graph = embedding_knn_graph(
            embeddings, k=k,
            backend=getattr(cfg, "backend", "host"),
            bandwidth=getattr(cfg, "bandwidth", "global"))
        churn = edge_churn(self.graph, new_graph)
        seed = epoch_plan_seed(self.seed + 3, epoch)
        prev_plan = self.stream.snapshot()[0]
        labels = prev_plan.mini_block_labels
        delta = churn <= float(getattr(cfg, "churn_threshold", 0.25))
        if delta:
            res = repair_partition(
                new_graph.W, labels, int(labels.max()) + 1, tol=self.tol,
                touched=_changed_endpoints(self.graph.W, new_graph.W))
            plan = plan_from_labels(
                new_graph, res.labels, self.batch_size, self.n_classes,
                seed=seed, shuffle_blocks=self.shuffle_blocks)
        else:
            plan = plan_meta_batches(
                new_graph, self.batch_size, self.n_classes, seed=seed,
                tol=self.tol, shuffle_blocks=self.shuffle_blocks,
                partitioner=self.partitioner, coarsen_to=self.coarsen_to)
        if not self.stream.swap_graph(new_graph, plan,
                                      hierarchy=self._fresh_hierarchy(
                                          new_graph)):
            self.stats["rejected"] += 1
            return False
        self.graph = new_graph
        self.features = np.asarray(embeddings, dtype=np.float32)
        self.embedding_space = True
        self.last_churn = churn
        self.stats["refreshes"] += 1
        self.stats["delta_refines" if delta else "full_rebuilds"] += 1
        return True

    # ------------------------------------------------------------- ingest
    def _embed_new(self, X_new: np.ndarray) -> np.ndarray:
        """Rows for new nodes in the current graph's space: raw features
        before the first refresh, model embeddings (current params) after."""
        if not self.embedding_space:
            return np.asarray(X_new, dtype=np.float32)
        if self.embed_fn is None or self.params is None:
            raise RuntimeError(
                "insert after an embedding-space refresh needs embed_fn "
                "and at least one trained epoch (params)")
        return np.asarray(self.embed_fn(self.params, X_new), np.float32)

    def insert(self, X_new: np.ndarray, y_new=None,
               labeled=None) -> np.ndarray | None:
        """Add new corpus rows to the live graph/plan/stream.

        The PR-5 "perturbed chunk" path end to end: streaming top-k of the
        new rows against the corpus (``AffinityGraph.insert`` — existing
        rows untouched), heaviest-neighbour label seeding + delta-seeded
        refinement (:func:`extend_partition` — never ``partition_graph``),
        plan re-grouped from the repaired labels, and the whole
        (graph, plan, corpus) lock-published at once.  New rows default to
        unlabeled (``label_mask`` False) — the arriving-traffic case.
        Returns the new nodes' indices, or None when the stream rejected
        the swap (plan outgrew the pinned pad — raise pad_headroom).
        """
        import dataclasses
        X_new = np.atleast_2d(np.asarray(X_new))
        m = X_new.shape[0]
        if m == 0:
            return np.empty((0,), dtype=np.int64)
        n_old = self.corpus.n
        new_graph = self.graph.insert(self.features, self._embed_new(X_new))
        prev_plan = self.stream.snapshot()[0]
        labels = prev_plan.mini_block_labels
        res = extend_partition(new_graph.W, labels,
                               int(labels.max()) + 1, tol=self.tol)
        self._ops += 1
        plan = plan_from_labels(
            new_graph, res.labels, self.batch_size, self.n_classes,
            seed=epoch_plan_seed(self.seed + 7919, self._ops),
            shuffle_blocks=self.shuffle_blocks)
        y_new = (np.zeros(m, dtype=self.corpus.y.dtype) if y_new is None
                 else np.asarray(y_new, dtype=self.corpus.y.dtype))
        labeled = (np.zeros(m, dtype=bool) if labeled is None
                   else np.asarray(labeled, dtype=bool))
        corpus = dataclasses.replace(
            self.corpus,
            X=np.concatenate([self.corpus.X,
                              np.asarray(X_new, self.corpus.X.dtype)]),
            y=np.concatenate([self.corpus.y, y_new]),
            label_mask=np.concatenate([self.corpus.label_mask, labeled]))
        if not self.stream.swap_graph(
                new_graph, plan, corpus=corpus,
                hierarchy=self._fresh_hierarchy(new_graph)):
            self.stats["rejected"] += 1
            return None
        self.features = np.concatenate(
            [self.features, self._embed_new(X_new)])
        self.graph = new_graph
        self.corpus = corpus
        self.stats["inserts"] += 1
        return np.arange(n_old, n_old + m)

    def evict(self, idx: np.ndarray) -> bool:
        """Remove nodes from the live graph/plan/corpus (departed users).

        Symmetric row/col deletion, then the same local repair as insert,
        seeded with the evicted nodes' surviving neighbours.  Returns False
        if the stream rejected the swap.
        """
        import dataclasses
        idx = np.atleast_1d(np.asarray(idx, dtype=np.int64))
        if idx.size == 0:
            return True
        n = self.corpus.n
        keep = np.ones(n, dtype=bool)
        keep[idx] = False
        new_index = np.cumsum(keep) - 1
        nbrs = np.unique(self.graph.W[idx].indices)
        touched = new_index[nbrs[keep[nbrs]]]
        new_graph = self.graph.evict(idx)
        prev_plan = self.stream.snapshot()[0]
        labels = prev_plan.mini_block_labels[keep]
        res = repair_partition(new_graph.W, labels,
                               int(prev_plan.mini_block_labels.max()) + 1,
                               tol=self.tol, touched=touched)
        self._ops += 1
        plan = plan_from_labels(
            new_graph, res.labels, self.batch_size, self.n_classes,
            seed=epoch_plan_seed(self.seed + 7919, self._ops),
            shuffle_blocks=self.shuffle_blocks)
        corpus = dataclasses.replace(
            self.corpus, X=self.corpus.X[keep], y=self.corpus.y[keep],
            label_mask=self.corpus.label_mask[keep])
        if not self.stream.swap_graph(
                new_graph, plan, corpus=corpus,
                hierarchy=self._fresh_hierarchy(new_graph)):
            self.stats["rejected"] += 1
            return False
        self.features = self.features[keep]
        self.graph = new_graph
        self.corpus = corpus
        self.stats["evictions"] += 1
        return True
