"""RNG key-lineage auditor (R-pass): dataflow over PRNG keys in jaxprs.

PR 9 fixed a decode-prefill bug — the seed implementation reused the
unsplit sampling key across prefill steps and re-split it in the decode
loop, shifting the key stream by prompt length — by hand.  That bug (and
its whole family) is mechanically detectable from the traced jaxpr: PRNG
keys are ordinary values whose producers (``random_seed`` /
``random_split`` / ``random_fold_in`` / ``random_wrap``) and consumers
(``random_bits``) appear as primitives.  This pass walks every audited
entry point's closed jaxpr tracking *key tokens* from creation to
consumption, across pjit/custom-vjp call boundaries and through scan
carries, and flags:

  * ``R001`` — a key consumed by ≥ 2 random draws (key reuse: identical
    bits drawn twice, or a stream silently correlated).  Consumption of
    an outer key inside a scan body counts once per iteration, so a
    captured key drawn in a loop of length n counts n times.
  * ``R002`` — a key consumed inside a scan body *and* returned in the
    carry unchanged: every iteration draws from the same key.  The fix
    is ``fold_in``/``split`` inside the body (the carried token must
    differ from the one consumed).
  * ``R003`` — entropy discarded: a ``random_split`` none of whose
    results is ever consumed while at least one is dropped outright
    (``rng, _ = split(key)`` advancing a stream nobody draws from), or a
    random draw whose outputs are all dead (the pre-PR-9 prefill pattern:
    sampling during prefill and discarding the sample still shifted the
    stream).

Token identity is value identity: ``random_wrap`` of the same raw
``uint32[2]`` var twice yields ONE token (that is how reuse of an
unsplit key manifests after tracing), while each ``split``/``fold_in``
result is a fresh token.  Branches of ``cond`` are walked like calls, so
a key consumed in two *exclusive* branches counts twice — a deliberate
over-approximation (waivable per entry with ``# audit: safe(R001@...)``).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_audit import EntryPoint

__all__ = ["audit_entry_rng", "analyze_rng", "KeyToken"]

#: Lineage-preserving primitives: output token == input token.
_ALIAS_PRIMS = frozenset({
    "random_unwrap", "squeeze", "reshape", "convert_element_type",
    "transpose", "copy", "device_put", "broadcast_in_dim",
})
#: Extraction of one sub-key from a split family's stacked array.
_EXTRACT_PRIMS = frozenset({"slice", "dynamic_slice", "gather"})

_CONSUME = "random_bits"


@dataclasses.dataclass
class KeyToken:
    """One distinct PRNG key value flowing through the jaxpr."""

    seq: int
    origin: str                       # "seed" | "arg" | "split[i]#f" | ...
    scan_depth: int = 0               # how many scan bodies enclosed creation
    consumed: int = 0                 # total draws (scan-weighted)
    dead_draws: int = 0               # draws whose outputs are all dead
    escaped: bool = False             # reaches the top-level outputs
    derived: bool = False             # split/fold_in applied to it
    family: "_Family | None" = None   # set on random_split result tokens
    parent: "_Family | None" = None


@dataclasses.dataclass
class _Family:
    """One ``random_split`` result: a stacked array of n fresh keys."""

    seq: int
    n_keys: int
    children: dict[int, KeyToken] = dataclasses.field(default_factory=dict)
    whole_used: bool = False          # the stacked array escaped whole


class _State:
    def __init__(self):
        self.tokens: list[KeyToken] = []
        self.families: list[_Family] = []
        self.findings: list[Finding] = []
        self.scan_lengths: list[int] = []   # stack of enclosing scan lengths

    def new_token(self, origin: str) -> KeyToken:
        tok = KeyToken(seq=len(self.tokens), origin=origin,
                       scan_depth=len(self.scan_lengths))
        self.tokens.append(tok)
        return tok

    def consume(self, tok: KeyToken, *, live: bool) -> None:
        # A draw inside scans the token was created OUTSIDE of repeats once
        # per iteration of each of those scans.
        mult = 1
        for length in self.scan_lengths[tok.scan_depth:]:
            mult *= max(1, length)
        tok.consumed += mult
        if not live:
            tok.dead_draws += mult


def _is_dropvar(v) -> bool:
    return isinstance(v, getattr(jax.core, "DropVar", ()))


def _liveness(jaxpr, live_outvars: set) -> list[bool]:
    """Per-eqn liveness via one backward pass.  ``live_outvars`` is the
    subset of ``jaxpr.outvars`` actually needed by the caller."""
    needed = {id(v) for v in jaxpr.outvars
              if not _is_dropvar(v) and id(v) in live_outvars}
    live = [False] * len(jaxpr.eqns)
    for i in range(len(jaxpr.eqns) - 1, -1, -1):
        eqn = jaxpr.eqns[i]
        if any(id(v) in needed for v in eqn.outvars if not _is_dropvar(v)):
            live[i] = True
            for v in eqn.invars:
                if hasattr(v, "aval"):       # skip Literals
                    needed.add(id(v))
    return live


def _sub_jaxpr(eqn):
    """The single body jaxpr of a call-like eqn whose invars map 1:1."""
    for key in ("jaxpr", "call_jaxpr"):
        p = eqn.params.get(key)
        if p is None:
            continue
        sub = p.jaxpr if hasattr(p, "jaxpr") else p
        if hasattr(sub, "eqns"):
            return sub
    return None


def _walk(jaxpr, env: dict, state: _State, *, jaxpr_live: bool,
          live_outvars: set | None = None) -> None:
    """Forward token propagation over one (sub-)jaxpr.

    ``env`` maps var id -> KeyToken for key-carrying values.  ``jaxpr_live``
    False means the whole body is dead (its draws are dead draws).
    """
    if live_outvars is None:
        live_outvars = {id(v) for v in jaxpr.outvars if not _is_dropvar(v)}
    live = _liveness(jaxpr, live_outvars) if jaxpr_live \
        else [False] * len(jaxpr.eqns)

    for i, eqn in enumerate(jaxpr.eqns):
        prim = eqn.primitive.name
        eqn_live = jaxpr_live and live[i]
        if prim == "pallas_call":
            continue

        if prim == "random_seed":
            env[id(eqn.outvars[0])] = state.new_token("seed")
        elif prim == "random_wrap":
            src = eqn.invars[0]
            tok = env.get(id(src))
            if tok is None:
                tok = state.new_token("arg")
                if hasattr(src, "aval"):
                    env[id(src)] = tok   # a second wrap of src reuses it
            env[id(eqn.outvars[0])] = tok
        elif prim == "random_fold_in":
            parent = env.get(id(eqn.invars[0]))
            if parent is not None:
                parent.derived = True
            env[id(eqn.outvars[0])] = state.new_token(
                f"fold_in#{parent.seq if parent else '?'}")
        elif prim == "random_split":
            parent = env.get(id(eqn.invars[0]))
            if parent is None:
                parent = state.new_token("arg")
                if hasattr(eqn.invars[0], "aval"):
                    env[id(eqn.invars[0])] = parent
            parent.derived = True
            shape = eqn.params.get("shape") or \
                getattr(eqn.outvars[0].aval, "shape", (2,))
            fam = _Family(seq=len(state.families), n_keys=int(shape[0]))
            state.families.append(fam)
            tok = state.new_token(f"split#{fam.seq}")
            tok.family = fam
            env[id(eqn.outvars[0])] = tok
        elif prim == _CONSUME:
            tok = env.get(id(eqn.invars[0]))
            if tok is None:
                tok = state.new_token("arg")
                if hasattr(eqn.invars[0], "aval"):
                    env[id(eqn.invars[0])] = tok
            state.consume(tok, live=eqn_live)
        elif prim in _EXTRACT_PRIMS:
            src_tok = env.get(id(eqn.invars[0]))
            if src_tok is None:
                pass
            elif src_tok.family is not None:
                fam = src_tok.family
                idx = None
                if prim == "slice":
                    idx = int(eqn.params["start_indices"][0])
                if idx is not None and idx in fam.children:
                    child = fam.children[idx]
                else:
                    child = state.new_token(
                        f"split[{idx if idx is not None else '?'}]"
                        f"#{fam.seq}")
                    child.parent = fam
                    fam.children[idx if idx is not None
                                 else -1 - len(fam.children)] = child
                if not _is_dropvar(eqn.outvars[0]):
                    env[id(eqn.outvars[0])] = child
            else:
                if not _is_dropvar(eqn.outvars[0]):
                    env[id(eqn.outvars[0])] = src_tok
        elif prim in _ALIAS_PRIMS:
            tok = env.get(id(eqn.invars[0]))
            if tok is not None and not _is_dropvar(eqn.outvars[0]):
                env[id(eqn.outvars[0])] = tok
        elif prim == "scan":
            _walk_scan(eqn, env, state, eqn_live)
        elif prim == "cond":
            branches = eqn.params.get("branches", ())
            for br in branches:
                sub = br.jaxpr if hasattr(br, "jaxpr") else br
                if len(sub.invars) != len(eqn.invars) - 1:
                    continue
                sub_env = dict(env)
                for outer, inner in zip(eqn.invars[1:], sub.invars):
                    tok = env.get(id(outer))
                    if tok is not None:
                        sub_env[id(inner)] = tok
                _walk(sub, sub_env, state, jaxpr_live=eqn_live)
        else:
            sub = _sub_jaxpr(eqn)
            if sub is not None and len(sub.invars) == len(eqn.invars):
                sub_env = dict(env)
                for outer, inner in zip(eqn.invars, sub.invars):
                    tok = env.get(id(outer))
                    if tok is not None:
                        sub_env[id(inner)] = tok
                sub_live = {id(v) for v in sub.outvars
                            if not _is_dropvar(v)} if eqn_live else set()
                _walk(sub, sub_env, state, jaxpr_live=eqn_live,
                      live_outvars=sub_live)
                for outer, inner in zip(eqn.outvars, sub.outvars):
                    tok = sub_env.get(id(inner))
                    if tok is not None and not _is_dropvar(outer):
                        env[id(outer)] = tok
            else:
                # Unknown structure (while, custom ops): recurse for
                # consumption counting with a fresh environment.
                for p in eqn.params.values():
                    for q in (p if isinstance(p, (tuple, list)) else (p,)):
                        body = q.jaxpr if hasattr(q, "jaxpr") else q
                        if hasattr(body, "eqns"):
                            _walk(body, {}, state, jaxpr_live=eqn_live)


def _walk_scan(eqn, env: dict, state: _State, eqn_live: bool) -> None:
    body = eqn.params["jaxpr"]
    sub = body.jaxpr if hasattr(body, "jaxpr") else body
    n_consts = eqn.params.get("num_consts", 0)
    n_carry = eqn.params.get("num_carry", 0)
    length = int(eqn.params.get("length") or 2)

    sub_env: dict = {}
    carry_in: list[KeyToken | None] = []
    for pos, (outer, inner) in enumerate(zip(eqn.invars, sub.invars)):
        tok = env.get(id(outer))
        if pos >= n_consts + n_carry:
            # xs input: each iteration sees a different slice -> a fresh
            # per-iteration token, not the stacked array's.
            tok = state.new_token(f"scan_xs@{pos}") if tok is not None \
                else None
        if tok is not None:
            sub_env[id(inner)] = tok
        if n_consts <= pos < n_consts + n_carry:
            carry_in.append(tok)

    consumed_before = {id(t): t.consumed for t in state.tokens}
    state.scan_lengths.append(length)
    _walk(sub, sub_env, state, jaxpr_live=eqn_live)
    state.scan_lengths.pop()

    # R002: a carry key consumed in the body and returned unchanged.
    for pos in range(n_carry):
        tok_in = carry_in[pos] if pos < len(carry_in) else None
        if tok_in is None:
            continue
        out_tok = sub_env.get(id(sub.outvars[pos]))
        drew = tok_in.consumed > consumed_before.get(id(tok_in), 0)
        if out_tok is tok_in and drew:
            state.findings.append(_finding(
                "R002", f"carry key {tok_in.origin} is drawn from inside "
                "the scan body and carried forward unsplit — every "
                "iteration replays the same stream",
                detail=f"carry{pos}:{tok_in.origin}"))
        # Carry-out token maps to the scan eqn's outvars for the caller.
        if out_tok is not None and pos < len(eqn.outvars) \
                and not _is_dropvar(eqn.outvars[pos]):
            env[id(eqn.outvars[pos])] = out_tok


_WHERE = [""]  # set by analyze_rng for _finding


def _finding(rule: str, message: str, *, detail: str) -> Finding:
    return Finding("rng", rule, _WHERE[0], message, detail=detail)


def analyze_rng(closed, *, where: str) -> tuple[list[Finding], dict]:
    """Run the R-pass over one closed jaxpr."""
    state = _State()
    _WHERE[0] = where
    env: dict = {}
    _walk(closed.jaxpr, env, state, jaxpr_live=True)

    # Escapes: tokens reaching the top-level outputs.
    for v in closed.jaxpr.outvars:
        tok = env.get(id(v))
        if tok is not None:
            tok.escaped = True
            if tok.family is not None:
                tok.family.whole_used = True

    findings = list(state.findings)
    for tok in state.tokens:
        if tok.consumed >= 2:
            findings.append(_finding(
                "R001", f"key {tok.origin} consumed by {tok.consumed} "
                "random draws — split or fold_in before each draw",
                detail=f"{tok.origin}:x{tok.consumed}"))
        if tok.dead_draws:
            findings.append(_finding(
                "R003", f"{tok.dead_draws} random draw(s) from key "
                f"{tok.origin} produce only dead values — the draw still "
                "shifts any shared stream (the pre-PR-9 prefill pattern)",
                detail=f"{tok.origin}:dead-draw"))
    for fam in state.families:
        if fam.whole_used:
            continue
        kids = list(fam.children.values())
        consumed = [k for k in kids if k.consumed > 0]
        used = [k for k in kids
                if k.consumed > 0 or k.escaped or k.derived]
        dropped = [k for k in kids if k not in used]
        if dropped and not consumed:
            findings.append(_finding(
                "R003", f"split#{fam.seq} results dropped without any "
                f"draw ({len(dropped)} of {len(kids)} extracted keys "
                "unused) — the split only discards entropy",
                detail=f"split#{fam.seq}:dropped"))
    metrics = {
        "keys_traced": len(state.tokens),
        "splits_traced": len(state.families),
        "draws": sum(t.consumed for t in state.tokens),
        "dead_draws": sum(t.dead_draws for t in state.tokens),
    }
    return findings, metrics


def audit_entry_rng(entry: EntryPoint, closed: Any | None = None
                    ) -> tuple[list[Finding], dict]:
    """Trace ``entry`` (or reuse a shared trace) and run the R-pass."""
    if closed is None:
        fn, args = entry.build()
        closed = jax.make_jaxpr(fn)(*args)
    return analyze_rng(closed, where=entry.name)
