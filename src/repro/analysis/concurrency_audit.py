"""Concurrency lint: AST pass over the repo's threaded modules.

PRs 3–5 introduced three background threads (engine prefetch,
``MetaBatchStream`` replanning, ``HierarchyCache`` sharing) with no
systematic race checking.  This pass parses the target files and applies
three rules:

  * ``C001`` — *learned lock discipline*: for every class that uses
    ``with self.<...lock...>:`` anywhere, the set of ``self`` attributes
    touched inside those bodies is the class's guarded set; any read or
    write of a guarded attribute outside a lock body (excluding
    ``__init__``, which runs before the object is shared, and methods
    named ``*_locked``, which by convention require the caller to hold
    the lock) is flagged.  Code inside a nested function defined under a
    ``with`` does **not** count as locked — it runs later, without the
    lock.
  * ``C002`` — a non-daemon ``threading.Thread`` that is never
    ``.join()``-ed anywhere in the file (leaks at shutdown, keeps the
    interpreter alive).
  * ``C003`` — *publication without a happens-before edge*: a value a
    thread target writes (a closure box ``box[k] = ...``, an
    ``x.append(...)``, or a ``self`` attribute) that some function reads
    without any happens-before construct (``join``/``wait``/``get``/
    ``acquire``/``result`` call or a ``with <lock>:``) in that function.
    In the spawning function itself only reads *after* the thread is
    created count.

False positives can be waived inline with an auditable marker on the
flagged line or the line above::

    self._fast_path_counter += 1  # audit: safe(C001): monotonic, stats-only

The marker names the rule it waives, so a suppression never silently
covers a different future finding.

The audited-file list is a registry (:data:`THREADED_MODULES` /
:func:`register_threaded_module`): any module that spawns or coordinates
threads registers itself here and is linted by ``python -m repro.analysis
--ci`` from then on — adding a threaded subsystem without audit coverage
should be a one-line diff review question, not a silent gap.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Iterator

from repro.analysis.findings import Finding
from repro.analysis.waivers import apply_waivers, scan_waivers

__all__ = ["audit_file", "audit_paths", "default_targets",
           "register_threaded_module", "DEFAULT_TARGETS", "THREADED_MODULES"]

#: Registry of threaded modules: name -> repo-relative path.  Names give
#: diffs and reports a stable identity; paths are what the pass parses.
THREADED_MODULES: dict[str, str] = {
    "engine": "src/repro/train/engine.py",
    "pipeline": "src/repro/data/pipeline.py",
    "partition": "src/repro/core/partition.py",
    "supervisor": "src/repro/resilience/supervisor.py",
    "faults": "src/repro/resilience/faults.py",
    "online": "src/repro/online/refresh.py",
}


def register_threaded_module(name: str, relpath: str) -> None:
    """Add (or re-point) a module in the concurrency-audit registry."""
    if not name or not relpath:
        raise ValueError("register_threaded_module needs a name and a path")
    THREADED_MODULES[name] = relpath


def default_targets() -> tuple[str, ...]:
    """The registry's current path list (insertion-ordered)."""
    return tuple(THREADED_MODULES.values())


#: Back-compat alias: the registry contents at import time.  Prefer
#: :func:`default_targets`, which sees later registrations.
DEFAULT_TARGETS = default_targets()

_HB_CALLS = frozenset({"join", "wait", "get", "acquire", "result"})
_PUBLISH_CALLS = frozenset({"append", "extend", "put", "add"})


def _walk_own(stmts: Iterable[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function/lambda
    bodies — those execute in a different dynamic context (possibly a
    different thread, and never under an enclosing ``with`` lock)."""
    stack = list(stmts)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue                 # the def itself, never its body
        stack.extend(ast.iter_child_nodes(node))


def _self_attr(node: ast.AST) -> str | None:
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _lock_withs(fn: ast.AST, lock_attrs: set[str]) -> list[ast.With]:
    out = []
    for node in _walk_own(getattr(fn, "body", [])):
        if isinstance(node, ast.With):
            for item in node.items:
                attr = _self_attr(item.context_expr)
                if attr in lock_attrs:
                    out.append(node)
                    break
    return out


def _functions(tree: ast.AST) -> list[ast.FunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _has_happens_before(fn: ast.AST) -> bool:
    for node in _walk_own(getattr(fn, "body", [])):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _HB_CALLS:
            return True
        if isinstance(node, ast.With):
            for item in node.items:
                expr = item.context_expr
                name = _self_attr(expr)
                if name is None and isinstance(expr, ast.Name):
                    name = expr.id
                if name is not None and "lock" in name.lower():
                    return True
    return False


# ---------------------------------------------------------------- C001
def _audit_class(cls: ast.ClassDef, where: str,
                 findings: list[Finding]) -> dict:
    methods = [n for n in cls.body
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    lock_attrs = set()
    for m in methods:
        for node in ast.walk(m):   # locks taken even in nested fns count
            if isinstance(node, ast.With):
                for item in node.items:
                    attr = _self_attr(item.context_expr)
                    if attr is not None and "lock" in attr.lower():
                        lock_attrs.add(attr)
    if not lock_attrs:
        return {"lock_attrs": [], "guarded": []}

    guarded: set[str] = set()
    locked_ids: set[int] = set()
    for m in methods:
        for fn in [m] + [n for n in ast.walk(m)
                         if isinstance(n, (ast.FunctionDef,
                                           ast.AsyncFunctionDef))
                         and n is not m]:
            for w in _lock_withs(fn, lock_attrs):
                for node in _walk_own(w.body):
                    locked_ids.add(id(node))
                    attr = _self_attr(node)
                    if attr is not None and m.name != "__init__":
                        guarded.add(attr)
    guarded -= lock_attrs

    for m in methods:
        if m.name == "__init__" or m.name.endswith("_locked"):
            continue
        for node in ast.walk(m):
            attr = _self_attr(node)
            if (attr in guarded and id(node) not in locked_ids):
                findings.append(Finding(
                    "concurrency", "C001", f"{where}::{cls.name}",
                    f"guarded attribute self.{attr} accessed outside "
                    f"{'/'.join(sorted(lock_attrs))} in {m.name}()",
                    detail=f"{attr}@{m.name}", line=node.lineno))
    return {"lock_attrs": sorted(lock_attrs), "guarded": sorted(guarded)}


# ------------------------------------------------------------ C002/C003
def _is_thread_ctor(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    name = f.id if isinstance(f, ast.Name) else \
        f.attr if isinstance(f, ast.Attribute) else None
    return name == "Thread"


def _kw(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _audit_threads(tree: ast.AST, where: str,
                   findings: list[Finding]) -> int:
    functions = _functions(tree)
    fn_by_name = {f.name: f for f in functions}
    source_joins = {
        node.func.value.id
        for node in ast.walk(tree)
        if isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and node.func.attr == "join"
        and isinstance(node.func.value, ast.Name)
    }
    n_threads = 0
    scopes = [(f, list(_walk_own(f.body))) for f in functions]
    scopes.append((None, [n for n in _walk_own(tree.body)]))
    for spawner, own_nodes in scopes:
        for call in (n for n in own_nodes if _is_thread_ctor(n)):
            n_threads += 1
            # ---- C002: non-daemon, never joined --------------------
            daemon = _kw(call, "daemon")
            is_daemon = isinstance(daemon, ast.Constant) and daemon.value
            var = _assigned_name(call, own_nodes)
            if not is_daemon and (var is None or var not in source_joins):
                findings.append(Finding(
                    "concurrency", "C002", where,
                    "non-daemon Thread "
                    + (f"{var!r} " if var else "")
                    + "is never joined in this file",
                    detail=f"thread@{call.lineno}", line=call.lineno))
            # ---- C003: publication without happens-before ----------
            target = _kw(call, "target")
            target_fn = None
            if isinstance(target, ast.Name):
                target_fn = fn_by_name.get(target.id)
            elif (attr := _self_attr(target)) is not None:
                target_fn = fn_by_name.get(attr)
            if target_fn is None:
                continue
            published = _published_names(target_fn)
            if not published:
                continue
            for reader, reader_nodes in scopes:
                if reader is target_fn or reader is None:
                    continue
                if _has_happens_before(reader):
                    continue
                for kind, name in published:
                    line = _first_read(reader_nodes, kind, name,
                                       after=call.lineno
                                       if reader is spawner else 0)
                    if line is not None:
                        findings.append(Finding(
                            "concurrency", "C003", where,
                            f"{reader.name}() reads "
                            f"{'self.' if kind == 'attr' else ''}{name} "
                            f"published by thread target "
                            f"{target_fn.name}() without a join/wait/"
                            "lock happens-before edge",
                            detail=f"{name}@{reader.name}", line=line))
    return n_threads


def _assigned_name(call: ast.Call, own_nodes) -> str | None:
    for node in own_nodes:
        if isinstance(node, ast.Assign) and node.value is call:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                return tgt.id
    return None


def _published_names(target_fn: ast.AST) -> set[tuple[str, str]]:
    """``("name", box)`` for closure-box stores / mutating calls and
    ``("attr", x)`` for ``self.x`` stores inside the thread target."""
    out: set[tuple[str, str]] = set()
    for node in _walk_own(target_fn.body):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Subscript) \
                        and isinstance(tgt.value, ast.Name):
                    out.add(("name", tgt.value.id))
                attr = _self_attr(tgt)
                if attr is not None:
                    out.add(("attr", attr))
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _PUBLISH_CALLS \
                and isinstance(node.func.value, ast.Name):
            out.add(("name", node.func.value.id))
    return out


def _first_read(reader_nodes, kind: str, name: str, *,
                after: int = 0) -> int | None:
    best = None
    for node in reader_nodes:
        line = getattr(node, "lineno", 0)
        if line <= after:
            continue
        hit = False
        if kind == "name":
            hit = (isinstance(node, ast.Name) and node.id == name
                   and isinstance(node.ctx, ast.Load))
        else:
            hit = (_self_attr(node) == name
                   and isinstance(node.ctx, ast.Load)
                   if isinstance(node, ast.Attribute) else False)
        if hit and (best is None or line < best):
            best = line
    return best


# ---------------------------------------------------------------- entry
def audit_file(path: str, *, where: str | None = None,
               used: set | None = None) -> tuple[list[Finding], dict]:
    """Run all concurrency rules over one Python source file.

    Inline ``# audit: safe(Cxxx)`` line waivers are applied here (shared
    machinery in :mod:`repro.analysis.waivers`); the keys of the markers
    that fired land in ``used`` when given, so the CLI's stale-waiver
    sweep (A001) can account for them.
    """
    with open(path) as fh:
        source = fh.read()
    tree = ast.parse(source, filename=path)
    where = where or path
    findings: list[Finding] = []
    classes = {}
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            classes[node.name] = _audit_class(node, where, findings)
    n_threads = _audit_threads(tree, where, findings)
    # Line waivers match on the finding's path; these findings are all
    # rooted in this file.
    findings = [dataclasses.replace(f, path=where) for f in findings]
    waivers = scan_waivers(path, relpath=where)
    kept = apply_waivers(findings, waivers, used=used)
    metrics = {
        "classes": {name: info for name, info in classes.items()
                    if info["lock_attrs"]},
        "threads_seen": n_threads,
        "suppressed": len(findings) - len(kept),
    }
    return kept, metrics


def audit_paths(paths: Iterable[str] | None = None, *, root: str = ".",
                used: set | None = None) -> tuple[list[Finding], dict]:
    """The concurrency pass entry point: audit every target file.
    ``paths=None`` (default) audits the live :data:`THREADED_MODULES`
    registry, including modules registered after import."""
    import os

    findings: list[Finding] = []
    metrics: dict = {"files": {}}
    for rel in (default_targets() if paths is None else paths):
        path = os.path.join(root, rel)
        file_findings, file_metrics = audit_file(path, where=rel,
                                                 used=used)
        findings.extend(file_findings)
        metrics["files"][rel] = file_metrics
    return findings, metrics
