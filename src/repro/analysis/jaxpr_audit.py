"""Jaxpr auditor: static proofs over the closed jaxprs of entry points.

The paper's efficiency argument (§1.1, §3–§4) is that the Eq.-3/4
regularizer and the streaming graph construction never materialize a dense
B×B (or N×M) intermediate outside a Pallas kernel, and that the training
scan stays free of host syncs.  This pass walks the *traced* jaxpr of each
registered entry point (no execution) and enforces exactly that:

  * ``J001`` — any intermediate at or above a byte threshold produced
    outside a ``pallas_call`` (the generalized form of the historical
    ``count_bxb_intermediates`` spot check);
  * ``J002`` — (B, B)-shaped intermediates beyond the entry's declared
    budget (0 for every fused path; the jnp reference is kept as a canary
    that must still trip the counter — ``J000`` fires if it stops doing
    so, i.e. if the counter itself broke);
  * ``J003`` — silent dtype promotion: float64 anywhere, or widening
    ``convert_element_type`` on non-scalars out of a declared
    low-precision compute dtype (bf16 paths leaking f32);
  * ``J004`` — host callbacks / sync primitives inside scan or while
    bodies (a ``debug_print`` in the engine's scan body would serialize
    every step on a host round-trip);
  * ``J005`` — the engine's chunk jit must donate every carry leaf
    (``donated_invars`` of the named pjit eqn);
  * ``J006`` — large arrays captured as jaxpr *constants* (closure
    capture silently bakes weights into the executable and re-traces on
    every new array identity) instead of arriving as arguments.

``count_bxb_intermediates`` lives here now (moved from
``benchmarks/bench_kernels.py``; the bench re-exports it) with identical
semantics — benchmarks, tests, and the audit share one counter.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.analysis.findings import Finding

__all__ = [
    "EntryPoint",
    "count_bxb_intermediates",
    "audit_entry",
    "trace_entry",
    "iter_eqns",
]

#: Primitives that imply a host round-trip or synchronization; inside a
#: scan/while body each occurrence stalls the whole compiled loop.
CALLBACK_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback_call", "outside_call", "infeed", "outfeed",
    "copy_to_host",
})

_FLOAT_WIDTH = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One audited entry point: how to trace it and what to expect.

    ``build()`` returns ``(fn, args)``; the auditor only traces
    (``jax.make_jaxpr``), it never executes the function.  All thresholds
    are part of the committed registry, so "no unexpected dense growth" is
    a reviewable contract, not a magic constant.
    """

    name: str
    build: Callable[[], tuple[Callable, tuple]]
    #: Exact-shape (B, B) budget: ``B`` enables the counter, ``expect_bxb``
    #: is the allowed count (None = informational only, e.g. the jnp
    #: reference canary).
    B: int | None = None
    expect_bxb: int | None = 0
    #: The reference canary must still *trip* the counter at >= this many
    #: (guards the counter itself against silent breakage).
    canary_min_bxb: int | None = None
    #: J001 byte threshold for any single intermediate outside Pallas.
    dense_bytes: int = 1 << 20
    #: Declared low-precision compute dtype ("bfloat16") for J003, or None.
    compute_dtype: str | None = None
    allow_f64: bool = False
    #: (pjit name, n leading flat invars that must be donated) for J005;
    #: n=None derives the count from the first build() arg (the carry tree).
    donate: tuple[str, int | None] | None = None
    #: J006 threshold for captured constants.
    const_bytes: int = 1 << 20
    #: Mesh axis names this entry is contracted to run under; collectives
    #: binding any other axis flag S001.  None = single-host contract.
    mesh_axes: tuple[str, ...] | None = None
    #: Under the bit-reproducibility contract (D001 applies)?  Entries
    #: that legitimately tolerate last-ulp drift opt out explicitly.
    deterministic: bool = True
    #: Collectives tolerated inside scan/while bodies (S002); reductions
    #: keep their operand shape, gathers do not — hence the default.
    allow_loop_collectives: tuple[str, ...] = ("psum",)


def iter_eqns(jaxpr, *, in_loop: bool = False
              ) -> Iterator[tuple[Any, bool]]:
    """Yield ``(eqn, in_loop)`` over ``jaxpr`` and every sub-jaxpr,
    *except* the bodies of ``pallas_call`` eqns (what a kernel does
    tile-by-tile in VMEM is precisely what the dense rules must not see).
    ``in_loop`` is True inside scan/while bodies.
    """
    for eqn in jaxpr.eqns:
        yield eqn, in_loop
        if eqn.primitive.name == "pallas_call":
            continue
        inner_loop = in_loop or eqn.primitive.name in ("scan", "while")
        for p in eqn.params.values():
            sub = None
            if hasattr(p, "eqns"):            # open Jaxpr
                sub = p
            elif hasattr(p, "jaxpr"):         # ClosedJaxpr
                sub = p.jaxpr
            if sub is not None:
                yield from iter_eqns(sub, in_loop=inner_loop)
            elif isinstance(p, (tuple, list)):
                for q in p:
                    if hasattr(q, "eqns"):
                        yield from iter_eqns(q, in_loop=inner_loop)
                    elif hasattr(q, "jaxpr"):
                        yield from iter_eqns(q.jaxpr, in_loop=inner_loop)


def _live_outvars(eqn):
    drop_var = getattr(jax.core, "DropVar", ())
    return [v for v in eqn.outvars if not isinstance(v, drop_var)]


def count_bxb_intermediates(fn, *args, B: int) -> int:
    """Number of (B, B)-shaped values produced outside Pallas kernels in
    ``fn``'s jaxpr (descending through pjit/custom_vjp calls; a value coming
    straight out of a ``pallas_call`` does not count — the kernel produced
    it tile by tile)."""
    closed = jax.make_jaxpr(fn)(*args)
    return _count_bxb(closed.jaxpr, B)


def _count_bxb(jaxpr, B: int) -> int:
    n = 0
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name in ("pallas_call", "broadcast_in_dim"):
            # Kernel output, or a constant splat (e.g. a zero cotangent) —
            # neither is a materialized product.
            continue
        live = _live_outvars(eqn)
        if not live:
            continue   # dead outputs — DCE removes them before they exist
        if any(hasattr(p, "eqns") or hasattr(p, "jaxpr")
               for p in eqn.params.values()):
            continue   # call-like eqn: outvars just re-bind inner results
        n += sum(1 for v in live
                 if getattr(v.aval, "shape", None) == (B, B))
    return n


def _aval_bytes(aval) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize


def trace_entry(entry: EntryPoint):
    """The entry's closed jaxpr (shared across the jaxpr-walking passes
    so each entry is traced once per CLI run)."""
    fn, args = entry.build()
    return jax.make_jaxpr(fn)(*args)


def audit_entry(entry: EntryPoint, closed: Any | None = None
                ) -> tuple[list[Finding], dict]:
    """Trace ``entry`` (or reuse a shared trace) and return
    ``(findings, metrics)``."""
    fn, args = entry.build()
    if closed is None:
        closed = jax.make_jaxpr(fn)(*args)
    findings: list[Finding] = []
    metrics: dict = {}

    # -- J002 / J000: the exact (B, B) counter --------------------------
    if entry.B is not None:
        n_bxb = _count_bxb(closed.jaxpr, entry.B)
        metrics["bxb_outside_kernels"] = n_bxb
        if entry.expect_bxb is not None and n_bxb > entry.expect_bxb:
            findings.append(Finding(
                "jaxpr", "J002", entry.name,
                f"{n_bxb} (B, B) intermediates outside Pallas kernels "
                f"(budget {entry.expect_bxb}, B={entry.B})",
                detail=f"bxb>{entry.expect_bxb}"))
        if entry.canary_min_bxb is not None and n_bxb < entry.canary_min_bxb:
            findings.append(Finding(
                "jaxpr", "J000", entry.name,
                f"reference canary counted only {n_bxb} (B, B) "
                f"intermediates (expected >= {entry.canary_min_bxb}) — the "
                "counter itself no longer sees dense intermediates",
                detail="canary"))

    # -- Per-eqn rules ---------------------------------------------------
    max_bytes = 0
    dense_hits: dict[str, int] = {}
    promo_hits: dict[str, int] = {}
    callback_hits: dict[str, int] = {}
    donated_ok: bool | None = None
    donate_name, donate_n = entry.donate or (None, 0)
    if donate_name is not None and donate_n is None:
        donate_n = len(jax.tree_util.tree_leaves(args[0]))
    for eqn, in_loop in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if prim == "pallas_call":
            continue
        if in_loop and prim in CALLBACK_PRIMITIVES:
            callback_hits[prim] = callback_hits.get(prim, 0) + 1
        if donate_name is not None and prim == "pjit" \
                and eqn.params.get("name") == donate_name:
            donated = eqn.params.get("donated_invars", ())
            donated_ok = (len(donated) >= donate_n
                          and all(donated[:donate_n]))
        live = _live_outvars(eqn)
        call_like = any(hasattr(p, "eqns") or hasattr(p, "jaxpr")
                        for p in eqn.params.values())
        for v in live:
            nbytes = _aval_bytes(v.aval)
            max_bytes = max(max_bytes, nbytes)
            if (not call_like and prim != "broadcast_in_dim"
                    and nbytes >= entry.dense_bytes):
                key = f"{prim}:{tuple(v.aval.shape)}"
                dense_hits[key] = dense_hits.get(key, 0) + 1
            dt = getattr(v.aval, "dtype", None)
            if dt is not None and dt.name == "float64" \
                    and not entry.allow_f64 and not call_like:
                promo_hits["float64"] = promo_hits.get("float64", 0) + 1
        if prim == "convert_element_type" and entry.compute_dtype:
            src = getattr(eqn.invars[0].aval, "dtype", None)
            dst = getattr(eqn.outvars[0].aval, "dtype", None)
            if (src is not None and dst is not None
                    and src.name == entry.compute_dtype
                    and _FLOAT_WIDTH.get(dst.name, 0)
                    > _FLOAT_WIDTH.get(src.name, 9)
                    and getattr(eqn.outvars[0].aval, "shape", ())):
                key = f"{src.name}->{dst.name}"
                promo_hits[key] = promo_hits.get(key, 0) + 1

    metrics["max_intermediate_bytes"] = max_bytes
    for key, count in sorted(dense_hits.items()):
        findings.append(Finding(
            "jaxpr", "J001", entry.name,
            f"{count}x dense intermediate {key} "
            f">= {entry.dense_bytes} bytes outside Pallas kernels",
            detail=key))
    for key, count in sorted(promo_hits.items()):
        findings.append(Finding(
            "jaxpr", "J003", entry.name,
            f"{count}x silent dtype promotion ({key})", detail=key))
    for prim, count in sorted(callback_hits.items()):
        findings.append(Finding(
            "jaxpr", "J004", entry.name,
            f"{count}x host callback/sync primitive '{prim}' inside a "
            "scan/while body", detail=prim))
    if donate_name is not None:
        metrics["carry_donated"] = bool(donated_ok)
        if donated_ok is None:
            findings.append(Finding(
                "jaxpr", "J005", entry.name,
                f"could not find pjit eqn named {donate_name!r} to verify "
                "carry donation", detail=f"{donate_name}:missing"))
        elif not donated_ok:
            findings.append(Finding(
                "jaxpr", "J005", entry.name,
                f"pjit {donate_name!r} does not donate all "
                f"{donate_n} carry leaves", detail=donate_name))

    # -- J006: captured constants ---------------------------------------
    big_consts = [c for c in closed.consts
                  if getattr(c, "nbytes", 0) >= entry.const_bytes]
    metrics["captured_const_bytes"] = int(
        sum(getattr(c, "nbytes", 0) for c in closed.consts))
    for c in big_consts:
        findings.append(Finding(
            "jaxpr", "J006", entry.name,
            f"array of shape {tuple(np.shape(c))} ({c.nbytes} bytes) "
            "captured as a jaxpr constant — pass it as an argument",
            detail=f"const:{tuple(np.shape(c))}"))
    return findings, metrics
