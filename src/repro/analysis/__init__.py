"""Static audit toolkit: jaxpr contracts, Pallas VMEM/tiling, concurrency.

Three pass families prove the paper's efficiency invariants on every
commit (see the module docstrings for the full rule tables):

  * :mod:`repro.analysis.jaxpr_audit` — traced-jaxpr proofs over the AUDIT
    registry's entry points (no dense B×B outside Pallas, no silent dtype
    promotion, no host callbacks in scan bodies, donated engine carry, no
    captured weight constants);
  * :mod:`repro.analysis.vmem_audit` — static VMEM/tiling models of every
    kernel launch, validating the whole ``kernels/tuning.py`` table;
  * :mod:`repro.analysis.concurrency_audit` — AST lock-discipline /
    thread-lifecycle / publication lint over the threaded modules.

Run ``python -m repro.analysis --ci`` for the gated CI entry point.
"""
from repro.analysis.concurrency_audit import (DEFAULT_TARGETS, audit_file,
                                              audit_paths)
from repro.analysis.findings import (RULES, AuditReport, Finding,
                                     load_baseline, save_baseline,
                                     unbaselined)
from repro.analysis.jaxpr_audit import (EntryPoint, audit_entry,
                                        count_bxb_intermediates, iter_eqns)
from repro.analysis.vmem_audit import (VMEM_BUDGET_BYTES, Block, Launch,
                                       check_launch, check_tiles,
                                       kernel_launches, validate_tuning_table,
                                       vmem_footprint_bytes)

__all__ = [
    "RULES",
    "Finding",
    "AuditReport",
    "load_baseline",
    "save_baseline",
    "unbaselined",
    "EntryPoint",
    "audit_entry",
    "count_bxb_intermediates",
    "iter_eqns",
    "Block",
    "Launch",
    "VMEM_BUDGET_BYTES",
    "kernel_launches",
    "check_launch",
    "check_tiles",
    "validate_tuning_table",
    "vmem_footprint_bytes",
    "DEFAULT_TARGETS",
    "audit_file",
    "audit_paths",
    "build_report",
]


def build_report(*args, **kwargs):
    """Lazy alias for :func:`repro.analysis.cli.build_report`."""
    from repro.analysis.cli import build_report as _build

    return _build(*args, **kwargs)
