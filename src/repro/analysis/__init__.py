"""Static audit toolkit: structural and semantic proofs on every commit.

Seven pass families (see the module docstrings for the full rule tables):

  * :mod:`repro.analysis.jaxpr_audit` — traced-jaxpr proofs over the AUDIT
    registry's entry points (no dense B×B outside Pallas, no silent dtype
    promotion, no host callbacks in scan bodies, donated engine carry, no
    captured weight constants);
  * :mod:`repro.analysis.vmem_audit` — static VMEM/tiling models of every
    kernel launch, validating the whole ``kernels/tuning.py`` table;
  * :mod:`repro.analysis.concurrency_audit` — AST lock-discipline /
    thread-lifecycle / publication lint over the threaded modules;
  * :mod:`repro.analysis.rng_audit` — PRNG key lineage through audited
    jaxprs (reuse, unsplit scan carries, discarded entropy);
  * :mod:`repro.analysis.race_audit` — Pallas write-race proofs from the
    launch models' grids/index maps plus the block-sparse tile-list
    contract (duplicates, strip contiguity, sentinels/coverage);
  * :mod:`repro.analysis.determinism_audit` — unordered float scatters in
    bit-reproducible entries + host nondeterminism in seeded modules;
  * :mod:`repro.analysis.sharding_audit` — collectives vs declared mesh
    axes, loop-body gathers, donated-carry sharding fixpoints.

Inline waivers (``# audit: safe(RULE): reason`` / scoped
``safe(RULE@where-glob)``) are shared machinery in
:mod:`repro.analysis.waivers`; a stale marker is itself a finding (A001).
Run ``python -m repro.analysis --ci`` for the gated CI entry point.
"""
from repro.analysis.concurrency_audit import (DEFAULT_TARGETS, audit_file,
                                              audit_paths)
from repro.analysis.determinism_audit import (SEEDED_MODULES,
                                              audit_entry_determinism,
                                              audit_seeded_modules,
                                              register_seeded_module)
from repro.analysis.findings import (RULES, AuditReport, Finding,
                                     load_baseline, save_baseline,
                                     unbaselined)
from repro.analysis.jaxpr_audit import (EntryPoint, audit_entry,
                                        count_bxb_intermediates, iter_eqns,
                                        trace_entry)
from repro.analysis.race_audit import (audit_races, check_launch_races,
                                       check_layout, check_tile_list)
from repro.analysis.rng_audit import analyze_rng, audit_entry_rng
from repro.analysis.sharding_audit import (COLLECTIVE_PRIMITIVES,
                                           audit_entry_sharding)
from repro.analysis.vmem_audit import (VMEM_BUDGET_BYTES, Block, Launch,
                                       check_launch, check_tiles,
                                       kernel_launches, validate_tuning_table,
                                       vmem_footprint_bytes)
from repro.analysis.waivers import (Waiver, apply_waivers, scan_waivers,
                                    stale_waiver_findings)

__all__ = [
    "RULES",
    "Finding",
    "AuditReport",
    "load_baseline",
    "save_baseline",
    "unbaselined",
    "EntryPoint",
    "audit_entry",
    "trace_entry",
    "count_bxb_intermediates",
    "iter_eqns",
    "Block",
    "Launch",
    "VMEM_BUDGET_BYTES",
    "kernel_launches",
    "check_launch",
    "check_tiles",
    "validate_tuning_table",
    "vmem_footprint_bytes",
    "DEFAULT_TARGETS",
    "audit_file",
    "audit_paths",
    "analyze_rng",
    "audit_entry_rng",
    "audit_races",
    "check_launch_races",
    "check_layout",
    "check_tile_list",
    "audit_entry_determinism",
    "audit_seeded_modules",
    "register_seeded_module",
    "SEEDED_MODULES",
    "audit_entry_sharding",
    "COLLECTIVE_PRIMITIVES",
    "Waiver",
    "scan_waivers",
    "apply_waivers",
    "stale_waiver_findings",
    "build_report",
]


def build_report(*args, **kwargs):
    """Lazy alias for :func:`repro.analysis.cli.build_report`."""
    from repro.analysis.cli import build_report as _build

    return _build(*args, **kwargs)
