"""``python -m repro.analysis`` — run the audit passes and gate on the
committed baseline.

Usage::

    python -m repro.analysis                 # all passes, write report
    python -m repro.analysis --ci            # same + nonzero exit on any
                                             # finding not in the baseline
    python -m repro.analysis --passes vmem   # one pass family
    python -m repro.analysis --update-baseline   # accept current findings

The report (``AUDIT_report.json``) always records every finding plus the
per-pass metrics; the *gate* only fails on error-severity findings whose
stable fingerprint is absent from ``AUDIT_baseline.json``.  Accepting a
finding is therefore an explicit, reviewable commit to the baseline file —
never a side effect of running the tool.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.concurrency_audit import audit_paths
from repro.analysis.findings import (AuditReport, load_baseline,
                                     save_baseline, unbaselined)
from repro.analysis.jaxpr_audit import audit_entry
from repro.analysis.vmem_audit import validate_tuning_table

__all__ = ["build_report", "main", "PASSES"]

PASSES = ("jaxpr", "vmem", "concurrency")


def _repo_root(start: str = ".") -> str:
    """Nearest ancestor holding pyproject.toml (the audit targets are
    repo-relative); falls back to ``start``."""
    d = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def _run_jaxpr(report: AuditReport) -> None:
    from repro.api.registry import AUDIT

    metrics: dict = {}
    findings = []
    for name in AUDIT:
        entry = AUDIT.get(name)
        entry_findings, entry_metrics = audit_entry(entry)
        findings.extend(entry_findings)
        metrics[name] = entry_metrics
    report.extend("jaxpr", findings, {"entries": metrics})


def _run_vmem(report: AuditReport) -> None:
    findings, metrics = validate_tuning_table()
    report.extend("vmem", findings, metrics)


def _run_concurrency(report: AuditReport, root: str) -> None:
    # None = the live THREADED_MODULES registry (supervisor/faults and any
    # later-registered threaded module included) — not a frozen tuple.
    findings, metrics = audit_paths(None, root=root)
    report.extend("concurrency", findings, metrics)


def build_report(passes=PASSES, *, root: str = ".") -> AuditReport:
    """Run the requested pass families and aggregate one report."""
    report = AuditReport()
    if "jaxpr" in passes:
        _run_jaxpr(report)
    if "vmem" in passes:
        _run_vmem(report)
    if "concurrency" in passes:
        _run_concurrency(report, root)
    return report


def _summary_lines(report: AuditReport) -> list[str]:
    lines = []
    entries = report.metrics.get("jaxpr/entries", {})
    for name, m in entries.items():
        bits = []
        if "bxb_outside_kernels" in m:
            bits.append(f"BxB outside kernels: {m['bxb_outside_kernels']}")
        if "carry_donated" in m:
            bits.append(f"carry donated: {m['carry_donated']}")
        if bits:
            lines.append(f"  jaxpr/{name}: " + ", ".join(bits))
    rows = report.metrics.get("vmem/rows_checked")
    if rows is not None:
        worst = report.metrics.get("vmem/worst_footprint_bytes", {})
        budget = report.metrics.get("vmem/budget_bytes", 0)
        peak = ", ".join(f"{k}={v / 2**20:.2f}MiB"
                         for k, v in sorted(worst.items()))
        lines.append(f"  vmem: {rows} tuning rows vs "
                     f"{budget / 2**20:.0f}MiB budget ({peak})")
    files = report.metrics.get("concurrency/files", {})
    if files:
        n_threads = sum(m.get("threads_seen", 0) for m in files.values())
        lines.append(f"  concurrency: {len(files)} files, "
                     f"{n_threads} thread sites audited")
    return lines


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static audits: jaxpr contracts, Pallas VMEM/tiling, "
                    "concurrency lint.")
    parser.add_argument("--passes", default=",".join(PASSES),
                        help="comma-separated subset of: "
                             + ", ".join(PASSES))
    parser.add_argument("--report", default="AUDIT_report.json",
                        help="report output path (default: %(default)s)")
    parser.add_argument("--baseline", default=None,
                        help="baseline path (default: AUDIT_baseline.json "
                             "at the repo root)")
    parser.add_argument("--update-baseline", action="store_true",
                        help="accept all current findings into the baseline"
                             " and exit 0")
    parser.add_argument("--ci", action="store_true",
                        help="CI mode: run everything, write the report, "
                             "exit nonzero on unbaselined findings "
                             "(the default gate — this flag just makes the "
                             "intent explicit in workflows)")
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        parser.error(f"unknown pass(es) {unknown}; choose from {PASSES}")

    root = _repo_root()
    baseline_path = args.baseline or os.path.join(root,
                                                  "AUDIT_baseline.json")
    report = build_report(passes, root=root)

    if args.update_baseline:
        save_baseline(baseline_path, report.gating)
        print(f"baseline updated: {baseline_path} "
              f"({len(report.gating)} accepted findings)")
        return 0

    baseline = load_baseline(baseline_path)
    new = unbaselined(report.gating, baseline)
    report.write(args.report, baseline=baseline)

    for line in _summary_lines(report):
        print(line)
    for f in report.findings:
        tag = "NEW " if f in new else ("info " if f.severity != "error"
                                       else "base ")
        print(f"{tag}{f.format()}")
    print(f"{len(report.findings)} finding(s), {len(new)} not in baseline "
          f"-> {args.report}")
    if new:
        print("FAIL: new findings above; fix them or (if accepted) run "
              "--update-baseline and commit the baseline", file=sys.stderr)
        return 1
    return 0
