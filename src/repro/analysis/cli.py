"""``python -m repro.analysis`` — run the audit passes and gate on the
committed baseline.

Usage::

    python -m repro.analysis                 # all passes, write report
    python -m repro.analysis --ci            # same + nonzero exit on any
                                             # finding not in the baseline
    python -m repro.analysis --only vmem,rng # pass subsets
    python -m repro.analysis --format github # GitHub Actions annotations
    python -m repro.analysis --update-baseline   # accept current findings

Seven pass families: the structural tier (``jaxpr`` shape/donation/
callback contracts, ``vmem`` Pallas footprint/alignment, ``concurrency``
thread lint) and the semantic tier (``rng`` key lineage, ``race`` kernel
write-race/tile-list proofs, ``determinism`` bit-reproducibility,
``sharding`` collective/mesh contracts).  Entry points are traced ONCE
per run and the closed jaxpr is shared by every jaxpr-walking pass.

The report (``AUDIT_report.json``) always records every finding plus the
per-pass metrics; the *gate* only fails on error-severity findings whose
stable fingerprint is absent from ``AUDIT_baseline.json``.  Accepting a
finding is therefore an explicit, reviewable commit to the baseline file —
never a side effect of running the tool.  Inline ``# audit: safe(...)``
waivers are honored across all passes, and a waiver that no longer
suppresses anything is itself flagged (``A001``).
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis.concurrency_audit import audit_paths, default_targets
from repro.analysis.determinism_audit import (audit_entry_determinism,
                                              audit_seeded_modules,
                                              default_seeded_modules)
from repro.analysis.findings import (AuditReport, Finding, load_baseline,
                                     save_baseline, unbaselined)
from repro.analysis.jaxpr_audit import audit_entry, trace_entry
from repro.analysis.race_audit import audit_races
from repro.analysis.rng_audit import audit_entry_rng
from repro.analysis.sharding_audit import audit_entry_sharding
from repro.analysis.vmem_audit import validate_tuning_table
from repro.analysis.waivers import (Waiver, apply_waivers, scan_waivers,
                                    stale_waiver_findings)

__all__ = ["build_report", "main", "PASSES"]

PASSES = ("jaxpr", "vmem", "concurrency", "rng", "race", "determinism",
          "sharding")
#: Pass families that walk traced entry-point jaxprs (shared traces).
_JAXPR_PASSES = frozenset({"jaxpr", "rng", "determinism", "sharding"})
#: Extra waiver-bearing files beyond the threaded/seeded registries
#: (scoped waivers for entry-level findings live next to the entries).
_WAIVER_FILES = ("src/repro/analysis/entrypoints.py",
                 "src/repro/kernels/ops.py")


def _repo_root(start: str = ".") -> str:
    """Nearest ancestor holding pyproject.toml (the audit targets are
    repo-relative); falls back to ``start``."""
    d = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(d, "pyproject.toml")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def _collect_waivers(root: str) -> list[Waiver]:
    """Every inline marker in the audit-covered source files."""
    rels: list[str] = []
    seen: set[str] = set()
    for rel in (tuple(default_targets())
                + tuple(default_seeded_modules().values())
                + _WAIVER_FILES):
        if rel not in seen:
            seen.add(rel)
            rels.append(rel)
    waivers: list[Waiver] = []
    for rel in rels:
        full = os.path.join(root, rel)
        if os.path.exists(full):
            waivers.extend(scan_waivers(full, relpath=rel))
    return waivers


def _traced_entries():
    """[(entry, closed_jaxpr)] for every registered AUDIT entry — traced
    once, shared across all jaxpr-walking passes."""
    from repro.api.registry import AUDIT

    out = []
    for name in AUDIT:
        entry = AUDIT.get(name)
        out.append((entry, trace_entry(entry)))
    return out


def _run_jaxpr(report: AuditReport, entries=None) -> None:
    metrics: dict = {}
    findings = []
    for entry, closed in (_traced_entries() if entries is None else entries):
        entry_findings, entry_metrics = audit_entry(entry, closed)
        findings.extend(entry_findings)
        metrics[entry.name] = entry_metrics
    report.extend("jaxpr", findings, {"entries": metrics})


def _run_vmem(report: AuditReport) -> None:
    findings, metrics = validate_tuning_table()
    report.extend("vmem", findings, metrics)


def _run_concurrency(report: AuditReport, root: str,
                     used: set | None = None) -> None:
    # None = the live THREADED_MODULES registry (supervisor/faults and any
    # later-registered threaded module included) — not a frozen tuple.
    findings, metrics = audit_paths(None, root=root, used=used)
    report.extend("concurrency", findings, metrics)


def _run_rng(report: AuditReport, entries=None) -> None:
    metrics: dict = {}
    findings = []
    for entry, closed in (_traced_entries() if entries is None else entries):
        got, m = audit_entry_rng(entry, closed)
        findings.extend(got)
        metrics[entry.name] = m
    report.extend("rng", findings, {"entries": metrics})


def _run_race(report: AuditReport) -> None:
    findings, metrics = audit_races()
    report.extend("race", findings, metrics)


def _run_determinism(report: AuditReport, root: str, entries=None,
                     used: set | None = None) -> None:
    metrics: dict = {}
    findings = []
    for entry, closed in (_traced_entries() if entries is None else entries):
        got, m = audit_entry_determinism(entry, closed)
        findings.extend(got)
        metrics[entry.name] = m
    host_findings, host_metrics = audit_seeded_modules(root=root, used=used)
    report.extend("determinism", findings + host_findings,
                  {"entries": metrics, **host_metrics})


def _run_sharding(report: AuditReport, entries=None) -> None:
    metrics: dict = {}
    findings = []
    for entry, closed in (_traced_entries() if entries is None else entries):
        got, m = audit_entry_sharding(entry, closed)
        findings.extend(got)
        metrics[entry.name] = m
    report.extend("sharding", findings, {"entries": metrics})


def build_report(passes=PASSES, *, root: str = ".") -> AuditReport:
    """Run the requested pass families and aggregate one report.

    Each pass runs into its own sub-report; findings then flow through the
    central waiver filter (scoped and line markers) before landing in the
    aggregate, and markers that suppressed nothing in any ran pass come
    back as A001 stale-waiver findings.
    """
    report = AuditReport()
    used: set = set()
    waivers = _collect_waivers(root)

    def run(runner, *runner_args):
        sub = AuditReport()
        runner(sub, *runner_args)
        for pass_name, entry in sub.passes.items():
            metrics = {k: v for k, v in entry.items() if k != "findings"}
            pass_findings = [f for f in sub.findings
                             if f.pass_name == pass_name]
            kept = apply_waivers(pass_findings, waivers, used=used)
            report.extend(pass_name, kept, metrics or None)

    entries = _traced_entries() if _JAXPR_PASSES & set(passes) else []
    if "jaxpr" in passes:
        run(_run_jaxpr, entries)
    if "vmem" in passes:
        run(_run_vmem)
    if "concurrency" in passes:
        run(_run_concurrency, root, used)
    if "rng" in passes:
        run(_run_rng, entries)
    if "race" in passes:
        run(_run_race)
    if "determinism" in passes:
        run(_run_determinism, root, entries, used)
    if "sharding" in passes:
        run(_run_sharding, entries)

    stale = stale_waiver_findings(waivers, used, passes)
    report.extend("waivers", stale, {
        "waivers_seen": len(waivers),
        "waivers_used": len(used),
        "waivers_stale": len(stale),
    })
    return report


def _summary_lines(report: AuditReport) -> list[str]:
    lines = []
    entries = report.metrics.get("jaxpr/entries", {})
    for name, m in entries.items():
        bits = []
        if "bxb_outside_kernels" in m:
            bits.append(f"BxB outside kernels: {m['bxb_outside_kernels']}")
        if "carry_donated" in m:
            bits.append(f"carry donated: {m['carry_donated']}")
        if bits:
            lines.append(f"  jaxpr/{name}: " + ", ".join(bits))
    rows = report.metrics.get("vmem/rows_checked")
    if rows is not None:
        worst = report.metrics.get("vmem/worst_footprint_bytes", {})
        budget = report.metrics.get("vmem/budget_bytes", 0)
        peak = ", ".join(f"{k}={v / 2**20:.2f}MiB"
                         for k, v in sorted(worst.items()))
        lines.append(f"  vmem: {rows} tuning rows vs "
                     f"{budget / 2**20:.0f}MiB budget ({peak})")
    files = report.metrics.get("concurrency/files", {})
    if files:
        n_threads = sum(m.get("threads_seen", 0) for m in files.values())
        lines.append(f"  concurrency: {len(files)} files, "
                     f"{n_threads} thread sites audited")
    rng_entries = report.metrics.get("rng/entries", {})
    if rng_entries:
        keys = sum(m.get("keys_traced", 0) for m in rng_entries.values())
        draws = sum(m.get("draws", 0) for m in rng_entries.values())
        lines.append(f"  rng: {len(rng_entries)} entries, {keys} keys "
                     f"traced, {draws} draws")
    launches = report.metrics.get("race/launches_checked")
    if launches is not None:
        lines.append(
            f"  race: {launches} launches checked, "
            f"{report.metrics.get('race/output_blocks_proven', 0)} output "
            f"blocks and {report.metrics.get('race/tiles_proven_race_free', 0)}"
            " tile entries proven race-free")
    det_entries = report.metrics.get("determinism/entries", {})
    if det_entries or report.metrics.get("determinism/seeded_modules_scanned"):
        scatters = sum(m.get("scatters_checked", 0)
                       for m in det_entries.values())
        mods = report.metrics.get("determinism/seeded_modules_scanned", 0)
        lines.append(f"  determinism: {scatters} scatters checked, "
                     f"{mods} seeded modules swept")
    sh_entries = report.metrics.get("sharding/entries", {})
    if sh_entries:
        colls = sum(m.get("collectives_audited", 0)
                    for m in sh_entries.values())
        lines.append(f"  sharding: {len(sh_entries)} entries, "
                     f"{colls} collectives audited")
    seen = report.metrics.get("waivers/waivers_seen")
    if seen:
        lines.append(
            f"  waivers: {seen} seen, "
            f"{report.metrics.get('waivers/waivers_used', 0)} used, "
            f"{report.metrics.get('waivers/waivers_stale', 0)} stale")
    return lines


def _github_annotation(f: Finding) -> str:
    """One GitHub Actions workflow command for a (new) finding."""
    loc = ""
    if f.path:
        loc = f"file={f.path}"
        if f.line:
            loc += f",line={f.line}"
    msg = f"[{f.rule}] {f.where}: {f.message}"
    # Workflow-command escaping for the message payload.
    msg = (msg.replace("%", "%25").replace("\r", "%0D")
              .replace("\n", "%0A"))
    return f"::error {loc}::{msg}" if loc else f"::error::{msg}"


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static audits: jaxpr contracts, Pallas VMEM/tiling, "
                    "concurrency lint, RNG lineage, kernel write-races, "
                    "determinism, sharding/collectives.")
    parser.add_argument("--passes", default=",".join(PASSES),
                        help="comma-separated subset of: "
                             + ", ".join(PASSES))
    parser.add_argument("--only", dest="passes",
                        help="alias for --passes (run a pass subset)")
    parser.add_argument("--report", default="AUDIT_report.json",
                        help="report output path (default: %(default)s)")
    parser.add_argument("--baseline", default=None,
                        help="baseline path (default: AUDIT_baseline.json "
                             "at the repo root)")
    parser.add_argument("--format", choices=("text", "github"),
                        default="text",
                        help="finding output format; 'github' emits "
                             "::error workflow annotations for findings "
                             "not in the baseline")
    parser.add_argument("--update-baseline", action="store_true",
                        help="accept all current findings into the baseline"
                             " and exit 0")
    parser.add_argument("--ci", action="store_true",
                        help="CI mode: run everything, write the report, "
                             "exit nonzero on unbaselined findings "
                             "(the default gate — this flag just makes the "
                             "intent explicit in workflows)")
    args = parser.parse_args(argv)

    passes = tuple(p.strip() for p in args.passes.split(",") if p.strip())
    unknown = [p for p in passes if p not in PASSES]
    if unknown:
        parser.error(f"unknown pass(es) {unknown}; choose from {PASSES}")

    root = _repo_root()
    baseline_path = args.baseline or os.path.join(root,
                                                  "AUDIT_baseline.json")
    report = build_report(passes, root=root)

    if args.update_baseline:
        save_baseline(baseline_path, report.gating)
        print(f"baseline updated: {baseline_path} "
              f"({len(report.gating)} accepted findings)")
        return 0

    baseline = load_baseline(baseline_path)
    new = unbaselined(report.gating, baseline)
    report.write(args.report, baseline=baseline)

    for line in _summary_lines(report):
        print(line)
    for f in report.findings:
        if f in new and args.format == "github":
            print(_github_annotation(f))
            continue
        tag = "NEW " if f in new else ("info " if f.severity != "error"
                                       else "base ")
        print(f"{tag}{f.format()}")
    print(f"{len(report.findings)} finding(s), {len(new)} not in baseline "
          f"-> {args.report}")
    if new:
        print("FAIL: new findings above; fix them or (if accepted) run "
              "--update-baseline and commit the baseline", file=sys.stderr)
        return 1
    return 0
