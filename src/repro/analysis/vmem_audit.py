"""Pallas VMEM / tiling checker: static models of every kernel launch.

Each kernel family in ``repro.kernels`` is mirrored here by a *static
launch model* — the same grid, block shapes, index maps, and scratch
allocations its wrapper builds, computed from a :class:`TileSpec` and a
problem shape without touching a device.  From the model the checker

  * computes the per-grid-step VMEM footprint (input/output blocks count
    **twice** — Pallas double-buffers the HBM↔VMEM pipeline — plus
    scratch) and validates it against the backend budget (``V001``);
  * checks TPU lane/sublane alignment of every table-controlled tile dim:
    a dim used as the last (lane) axis of any block must be a multiple of
    128, any other a multiple of the f32 sublane 8 (``V002``);
  * evaluates every block's index map over the grid corners and rejects
    maps that address past the padded array bounds (``V003``);
  * proves every ``kernels/tuning.py`` row *reachable* under first-match
    (``V004``) and *modeled* (``V005``), so the hand-tuned table cannot
    silently rot.

``validate_tuning_table`` is the pass entry point; ``check_launch`` and
``vmem_footprint_bytes`` are exposed for tests and for validating custom
specs before they ever reach a TPU.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

from repro.analysis.findings import Finding
from repro.kernels.tuning import DEFAULT_TILE_TABLE, TileSpec

__all__ = [
    "Block",
    "Launch",
    "kernel_launches",
    "check_launch",
    "check_tiles",
    "vmem_footprint_bytes",
    "validate_tuning_table",
    "VMEM_BUDGET_BYTES",
]

#: Per-core VMEM (TPU ~16 MiB); the budget the whole per-step working set
#: (double-buffered blocks + scratch) must fit in.
VMEM_BUDGET_BYTES = 16 * 2 ** 20
_LANE, _SUBLANE = 128, 8       # f32 tiling: last dim 128, second-to-last 8


@dataclasses.dataclass(frozen=True)
class Block:
    """One VMEM-resident buffer of a launch: a BlockSpec or a scratch."""

    name: str
    shape: tuple[int, ...]
    kind: str                          # "in" | "out" | "scratch"
    itemsize: int = 4                  # f32/i32 kernels throughout
    #: grid index -> block coordinates (same convention as pl.BlockSpec);
    #: None for scratch buffers (not windowed over an array).
    index_map: Callable[..., tuple[int, ...]] | None = None
    #: padded logical array dims the index map windows over.
    array_shape: tuple[int, ...] | None = None
    #: grid axes along which the kernel REVISITS this (out) block and
    #: accumulates in place — the declared contract the W-pass
    #: (``race_audit``) verifies: any two grid steps mapping to the same
    #: block coordinates must differ only on these axes.
    accum_axes: tuple[int, ...] = ()

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * self.itemsize


@dataclasses.dataclass(frozen=True)
class Launch:
    """Static mirror of one ``pl.pallas_call``: grid + blocks."""

    kernel: str
    variant: str                       # e.g. "fwd", "bwd_dlogp"
    grid: tuple[int, ...]
    blocks: tuple[Block, ...]

    def footprint_bytes(self) -> int:
        """Per-grid-step VMEM working set: 2x in/out (double-buffered
        pipeline) + 1x scratch."""
        total = 0
        for b in self.blocks:
            total += b.nbytes * (1 if b.kind == "scratch" else 2)
        return total


def _ceil_to(n: int, b: int) -> int:
    return -(-n // b) * b


def _fill(kernel: str, tiles: TileSpec) -> tuple[int, int, int, int]:
    """TileSpec with the kernel's own defaults for unset dims (mirrors the
    wrapper defaults in ``repro.kernels``)."""
    defaults = {
        "graph_reg": (128, 128, 512, None),
        "graph_reg_blocksparse": (128, None, 512, None),
        "rbf": (128, 128, None, 256),
        "topk": (128, 512, None, 256),
    }[kernel]
    return tuple(t if t is not None else d
                 for t, d in zip(tiles.astuple(), defaults))


# ---------------------------------------------------------------------------
# Launch models — one per pallas_call in repro.kernels, kept in lockstep
# with the wrappers (grid construction and index maps transcribed).
# ---------------------------------------------------------------------------
def _graph_reg_launches(tiles: TileSpec, *, rows: int, classes: int
                        ) -> list[Launch]:
    bi, bj, bc, _ = _fill("graph_reg", tiles)
    bi, bj, bc = min(bi, rows), min(bj, rows), min(bc, classes)
    Bi, Bj = _ceil_to(rows, bi), _ceil_to(rows, bj)
    Cc = _ceil_to(classes, bc)
    L = max(Bi, Bj)                    # bwd W padding covers both views
    fwd_grid = (Bi // bi, Bj // bj, Cc // bc)
    fwd = Launch("graph_reg", "fwd", fwd_grid, (
        Block("p", (bi, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(Bi, Cc)),
        Block("logp_j", (bj, bc), "in", index_map=lambda i, j, c: (j, c),
              array_shape=(Bj, Cc)),
        Block("logp_i", (bi, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(Bi, Cc)),
        Block("W", (bi, bj), "in", index_map=lambda i, j, c: (i, j),
              array_shape=(Bi, Bj)),
        Block("scalars", (1, 4), "in", index_map=lambda i, j, c: (0, 0),
              array_shape=(1, 4)),
        Block("out", (1, 1), "out", index_map=lambda i, j, c: (0, 0),
              array_shape=(1, 1), accum_axes=(0, 1, 2)),
        Block("acc", (bi, bj), "scratch"),
        Block("deg", (bi, 1), "scratch"),
        Block("ent", (bi, 1), "scratch"),
    ))
    bwd_dlogp_grid = (Bi // bi, Cc // bc, Bj // bj)
    bwd_dlogp = Launch("graph_reg", "bwd_dlogp", bwd_dlogp_grid, (
        Block("W", (bi, bj), "in", index_map=lambda i, c, j: (i, j),
              array_shape=(L, L)),
        Block("Wt", (bj, bi), "in", index_map=lambda i, c, j: (j, i),
              array_shape=(L, L)),
        Block("p_j", (bj, bc), "in", index_map=lambda i, c, j: (j, c),
              array_shape=(Bj, Cc)),
        Block("logp_j", (bj, bc), "in", index_map=lambda i, c, j: (j, c),
              array_shape=(Bj, Cc)),
        Block("p_i", (bi, bc), "in", index_map=lambda i, c, j: (i, c),
              array_shape=(Bi, Cc)),
        Block("logp_i", (bi, bc), "in", index_map=lambda i, c, j: (i, c),
              array_shape=(Bi, Cc)),
        Block("scalars", (1, 4), "in", index_map=lambda i, c, j: (0, 0),
              array_shape=(1, 4)),
        Block("dlogp", (bi, bc), "out", index_map=lambda i, c, j: (i, c),
              array_shape=(Bi, Cc), accum_axes=(2,)),
        Block("a", (bi, bc), "scratch"),
        Block("b", (bi, bc), "scratch"),
        Block("deg", (bi, 1), "scratch"),
    ))
    bwd_dw = Launch("graph_reg", "bwd_dw", fwd_grid, (
        Block("p_i", (bi, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(Bi, Cc)),
        Block("logp_j", (bj, bc), "in", index_map=lambda i, j, c: (j, c),
              array_shape=(Bj, Cc)),
        Block("logp_i", (bi, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(Bi, Cc)),
        Block("scalars", (1, 4), "in", index_map=lambda i, j, c: (0, 0),
              array_shape=(1, 4)),
        Block("dW", (bi, bj), "out", index_map=lambda i, j, c: (i, j),
              array_shape=(Bi, Bj), accum_axes=(2,)),
        Block("acc", (bi, bj), "scratch"),
        Block("ent", (bi, 1), "scratch"),
    ))
    return [fwd, bwd_dlogp, bwd_dw]


def _blocksparse_launches(tiles: TileSpec, *, rows: int, classes: int
                          ) -> list[Launch]:
    """Launch models for the block-sparse regularizer (bi doubles as the
    square tile edge bt).

    The real kernels window W and the row blocks through *scalar-prefetched*
    tile-id lists (data-dependent index maps); the static stand-ins below
    clamp the grid step into the tile-id range [0, nt) — the exact bound
    ``BlockLayout`` guarantees — so the V003 corner sweep exercises both
    the first and the last addressable tile.  The tile-id lists themselves
    live in SMEM (scalar prefetch), not VMEM, and are excluded from the
    footprint.  Representative list length: a fully dense mask (T = nt²),
    the worst case for grid size and the case that must stay bit-equal to
    the dense fused kernel.
    """
    bt, _, bc, _ = _fill("graph_reg_blocksparse", tiles)
    bc = min(bc, classes)
    nt = -(-rows // bt)
    P, Cc = nt * bt, _ceil_to(classes, bc)
    n_c = Cc // bc
    T = nt * nt

    def tid(t):                        # representative in-bounds tile id
        return min(t, nt - 1)

    fwd = Launch("graph_reg_blocksparse", "fwd", (T, n_c), (
        Block("p", (bt, bc), "in", index_map=lambda t, c: (tid(t), c),
              array_shape=(P, Cc)),
        Block("logp_j", (bt, bc), "in", index_map=lambda t, c: (tid(t), c),
              array_shape=(P, Cc)),
        Block("logp_i", (bt, bc), "in", index_map=lambda t, c: (tid(t), c),
              array_shape=(P, Cc)),
        Block("W", (bt, bt), "in",
              index_map=lambda t, c: (tid(t), tid(t)), array_shape=(P, P)),
        Block("scalars", (1, 4), "in", index_map=lambda t, c: (0, 0),
              array_shape=(1, 4)),
        Block("out", (1, 1), "out", index_map=lambda t, c: (0, 0),
              array_shape=(1, 1), accum_axes=(0, 1)),
        Block("acc", (bt, bt), "scratch"),
        Block("deg", (bt, 1), "scratch"),
        Block("ent", (bt, 1), "scratch"),
    ))
    bwd_bterm = Launch("graph_reg_blocksparse", "bwd_bterm", (n_c, T), (
        Block("W", (bt, bt), "in",
              index_map=lambda c, t: (tid(t), tid(t)), array_shape=(P, P)),
        Block("p_j", (bt, bc), "in", index_map=lambda c, t: (tid(t), c),
              array_shape=(P, Cc)),
        Block("bterm", (bt, bc), "out",
              index_map=lambda c, t: (tid(t), c), array_shape=(P, Cc),
              accum_axes=(1,)),
        Block("b", (bt, bc), "scratch"),
    ))
    bwd_dlogp = Launch("graph_reg_blocksparse", "bwd_dlogp", (n_c, T), (
        Block("W", (bt, bt), "in",
              index_map=lambda c, t: (tid(t), tid(t)), array_shape=(P, P)),
        Block("logp_j", (bt, bc), "in", index_map=lambda c, t: (tid(t), c),
              array_shape=(P, Cc)),
        Block("p_i", (bt, bc), "in", index_map=lambda c, t: (tid(t), c),
              array_shape=(P, Cc)),
        Block("logp_i", (bt, bc), "in", index_map=lambda c, t: (tid(t), c),
              array_shape=(P, Cc)),
        Block("bterm", (bt, bc), "in", index_map=lambda c, t: (tid(t), c),
              array_shape=(P, Cc)),
        Block("scalars", (1, 4), "in", index_map=lambda c, t: (0, 0),
              array_shape=(1, 4)),
        Block("dlogp", (bt, bc), "out",
              index_map=lambda c, t: (tid(t), c), array_shape=(P, Cc),
              accum_axes=(1,)),
        Block("a", (bt, bc), "scratch"),
        Block("deg", (bt, 1), "scratch"),
    ))
    bwd_dw = Launch("graph_reg_blocksparse", "bwd_dw", (nt, nt, n_c), (
        Block("p_i", (bt, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(P, Cc)),
        Block("logp_j", (bt, bc), "in", index_map=lambda i, j, c: (j, c),
              array_shape=(P, Cc)),
        Block("logp_i", (bt, bc), "in", index_map=lambda i, j, c: (i, c),
              array_shape=(P, Cc)),
        Block("scalars", (1, 4), "in", index_map=lambda i, j, c: (0, 0),
              array_shape=(1, 4)),
        Block("dW", (bt, bt), "out", index_map=lambda i, j, c: (i, j),
              array_shape=(P, P), accum_axes=(2,)),
        Block("acc", (bt, bt), "scratch"),
        Block("ent", (bt, 1), "scratch"),
    ))
    return [fwd, bwd_bterm, bwd_dlogp, bwd_dw]


def _rbf_launches(tiles: TileSpec, *, rows: int, cols: int, feat: int
                  ) -> list[Launch]:
    bi, bj, _, bd = _fill("rbf", tiles)
    bi, bj, bd = min(bi, rows), min(bj, cols), min(bd, feat)
    Ni, Mj, Dd = _ceil_to(rows, bi), _ceil_to(cols, bj), _ceil_to(feat, bd)
    grid = (Ni // bi, Mj // bj, Dd // bd)
    return [Launch("rbf", "fwd", grid, (
        Block("x", (bi, bd), "in", index_map=lambda i, j, d: (i, d),
              array_shape=(Ni, Dd)),
        Block("y", (bj, bd), "in", index_map=lambda i, j, d: (j, d),
              array_shape=(Mj, Dd)),
        Block("nx", (bi, 1), "in", index_map=lambda i, j, d: (i, 0),
              array_shape=(Ni, 1)),
        Block("ny", (bj, 1), "in", index_map=lambda i, j, d: (j, 0),
              array_shape=(Mj, 1)),
        Block("sigma", (1, 1), "in", index_map=lambda i, j, d: (0, 0),
              array_shape=(1, 1)),
        Block("out", (bi, bj), "out", index_map=lambda i, j, d: (i, j),
              array_shape=(Ni, Mj), accum_axes=(2,)),
        Block("acc", (bi, bj), "scratch"),
    ))]


def _topk_launches(tiles: TileSpec, *, rows: int, cols: int, feat: int,
                   k: int) -> list[Launch]:
    bi, bj, _, bd = _fill("topk", tiles)
    bi, bj, bd = min(bi, rows), min(bj, cols), min(bd, feat)
    Ni, Mj, Dd = _ceil_to(rows, bi), _ceil_to(cols, bj), _ceil_to(feat, bd)
    grid = (Ni // bi, Mj // bj, Dd // bd)
    return [Launch("topk", "fwd", grid, (
        Block("x", (bi, bd), "in", index_map=lambda i, j, d: (i, d),
              array_shape=(Ni, Dd)),
        Block("y", (bj, bd), "in", index_map=lambda i, j, d: (j, d),
              array_shape=(Mj, Dd)),
        Block("nx", (bi, 1), "in", index_map=lambda i, j, d: (i, 0),
              array_shape=(Ni, 1)),
        Block("ny", (bj, 1), "in", index_map=lambda i, j, d: (j, 0),
              array_shape=(Mj, 1)),
        Block("out_d2", (bi, k), "out", index_map=lambda i, j, d: (i, 0),
              array_shape=(Ni, k), accum_axes=(1, 2)),
        Block("out_idx", (bi, k), "out", index_map=lambda i, j, d: (i, 0),
              array_shape=(Ni, k), accum_axes=(1, 2)),
        Block("acc", (bi, bj), "scratch"),
        # The running top-k state and the (bi, k+bj) merge candidate set
        # the kernel concatenates per chunk live in VMEM too.
        Block("best_d2", (bi, k), "scratch"),
        Block("best_idx", (bi, k), "scratch"),
        Block("merge_cand", (2 * bi, k + bj), "scratch"),
    ))]


#: kernel name -> (model fn, which tile dims feed a lane (last) axis, and
#: which only ever feed sublane axes).  Lane dims must be 128-aligned on
#: TPU; sublane dims 8-aligned (f32).
_MODELS: dict[str, dict] = {
    "graph_reg": {"launches": _graph_reg_launches,
                  # bi is a lane dim too: the bwd transposed-W view (bj, bi).
                  "lane": ("bi", "bj", "bc"), "sublane": ()},
    # The square tile edge bt rides bi; it is the last axis of every
    # (bt, bt) W/dW block, so it is lane-constrained like bc.
    "graph_reg_blocksparse": {"launches": _blocksparse_launches,
                              "lane": ("bi", "bc"), "sublane": ()},
    "rbf": {"launches": _rbf_launches,
            "lane": ("bj", "bd"), "sublane": ("bi",)},
    "topk": {"launches": _topk_launches,
             "lane": ("bj", "bd"), "sublane": ("bi",)},
}

#: Representative problem shape per kernel when a table row is unbounded
#: (max_rows=None): large enough to exercise full-size tiles.
_DEFAULT_SHAPES = {
    "graph_reg": dict(rows=4096, classes=39),
    "graph_reg_blocksparse": dict(rows=4096, classes=39),
    "rbf": dict(rows=4096, cols=4096, feat=351),
    "topk": dict(rows=4096, cols=4096, feat=351, k=16),
}


def kernel_launches(kernel: str, tiles: TileSpec, **shape) -> list[Launch]:
    """The static launch models for ``kernel`` at ``tiles`` and ``shape``."""
    if kernel not in _MODELS:
        raise KeyError(f"no VMEM model for kernel {kernel!r}; "
                       f"known: {sorted(_MODELS)}")
    kw = dict(_DEFAULT_SHAPES[kernel])
    kw.update(shape)
    return _MODELS[kernel]["launches"](tiles, **kw)


def vmem_footprint_bytes(kernel: str, tiles: TileSpec, **shape) -> int:
    """Worst per-grid-step VMEM working set over the kernel's launches."""
    return max(ln.footprint_bytes()
               for ln in kernel_launches(kernel, tiles, **shape))


def check_launch(launch: Launch, *, where: str,
                 budget_bytes: int = VMEM_BUDGET_BYTES) -> list[Finding]:
    """V001 + V003 for one launch: budget and index-map bounds.

    Index maps are evaluated at every grid *corner* — the maps Pallas
    kernels use are affine in the grid indices, so an out-of-bounds block
    shows up at a corner if it shows up anywhere.
    """
    findings = []
    fp = launch.footprint_bytes()
    if fp > budget_bytes:
        findings.append(Finding(
            "vmem", "V001", where,
            f"{launch.kernel}/{launch.variant}: per-grid-step VMEM "
            f"footprint {fp / 2**20:.2f} MiB exceeds the "
            f"{budget_bytes / 2**20:.0f} MiB budget "
            f"(grid={launch.grid})",
            detail=launch.variant))
    corners = itertools.product(*[
        sorted({0, g - 1}) for g in launch.grid])
    for corner in corners:
        for b in launch.blocks:
            if b.index_map is None or b.array_shape is None:
                continue
            coords = b.index_map(*corner)
            for axis, (c, blk, dim) in enumerate(
                    zip(coords, b.shape, b.array_shape)):
                start = c * blk
                if start < 0 or start + blk > dim:
                    findings.append(Finding(
                        "vmem", "V003", where,
                        f"{launch.kernel}/{launch.variant}: block "
                        f"{b.name!r} axis {axis} addresses "
                        f"[{start}, {start + blk}) outside padded dim "
                        f"{dim} at grid index {corner}",
                        detail=f"{launch.variant}:{b.name}:{axis}"))
                    break
    return findings


def check_tiles(kernel: str, tiles: TileSpec, *, where: str,
                backend: str | None = "tpu",
                budget_bytes: int = VMEM_BUDGET_BYTES,
                **shape) -> list[Finding]:
    """Full static validation of one (kernel, tiles) combination:
    alignment (V002, TPU-reachable rows only), VMEM budget (V001) and
    index-map bounds (V003)."""
    model = _MODELS.get(kernel)
    if model is None:
        return [Finding("vmem", "V005", where,
                        f"kernel {kernel!r} has no VMEM model — add one to "
                        "repro.analysis.vmem_audit", detail=kernel)]
    findings = []
    if backend in (None, "tpu"):       # row may run on a TPU
        filled = dict(zip(("bi", "bj", "bc", "bd"), _fill(kernel, tiles)))
        for dim in model["lane"]:
            v = filled[dim]
            if v is not None and v % _LANE:
                findings.append(Finding(
                    "vmem", "V002", where,
                    f"{kernel}: tile dim {dim}={v} feeds a lane (last) "
                    f"axis and must be a multiple of {_LANE} on TPU",
                    detail=f"{dim}"))
        for dim in model["sublane"]:
            v = filled[dim]
            if v is not None and v % _SUBLANE:
                findings.append(Finding(
                    "vmem", "V002", where,
                    f"{kernel}: tile dim {dim}={v} feeds a sublane axis "
                    f"and must be a multiple of {_SUBLANE} on TPU (f32)",
                    detail=f"{dim}"))
    for launch in kernel_launches(kernel, tiles, **shape):
        findings.extend(check_launch(launch, where=where,
                                     budget_bytes=budget_bytes))
    return findings


def _row_shadowed(table: Sequence, idx: int) -> int | None:
    """Index of an earlier row that matches every (backend, rows) the row
    at ``idx`` matches — making it unreachable under first-match."""
    kern, be, max_rows, _ = table[idx]
    for early in range(idx):
        k1, be1, mr1, _ = table[early]
        if k1 != kern:
            continue
        be_covers = be1 is None or (be is not None and be1 == be)
        rows_covers = mr1 is None or (max_rows is not None
                                      and max_rows <= mr1)
        if be_covers and rows_covers:
            return early
    return None


def validate_tuning_table(table=DEFAULT_TILE_TABLE, *,
                          budget_bytes: int = VMEM_BUDGET_BYTES
                          ) -> tuple[list[Finding], dict]:
    """The VMEM pass entry point: every table row modeled, reachable,
    aligned, in budget, and in bounds."""
    findings: list[Finding] = []
    worst: dict[str, int] = {}
    for idx, (kernel, backend, max_rows, tiles) in enumerate(table):
        where = f"tuning[{idx}]:{kernel}"
        shadow = _row_shadowed(table, idx)
        if shadow is not None:
            findings.append(Finding(
                "vmem", "V004", where,
                f"row {idx} ({kernel}, backend={backend}, "
                f"max_rows={max_rows}) is shadowed by row {shadow} and can "
                "never match (first-match table)",
                detail=f"shadowed-by-{shadow}"))
        shape = {}
        if max_rows is not None:
            shape["rows"] = max_rows
            if kernel in ("rbf", "topk"):
                shape["cols"] = max_rows
        row_findings = check_tiles(kernel, tiles, where=where,
                                   backend=backend,
                                   budget_bytes=budget_bytes, **shape)
        findings.extend(row_findings)
        if not any(f.rule == "V005" for f in row_findings):
            fp = vmem_footprint_bytes(kernel, tiles, **shape)
            worst[kernel] = max(worst.get(kernel, 0), fp)
    metrics = {
        "rows_checked": len(table),
        "budget_bytes": budget_bytes,
        "worst_footprint_bytes": worst,
    }
    return findings, metrics
