"""The audited entry points: every contract the jaxpr pass proves.

Each :class:`~repro.analysis.jaxpr_audit.EntryPoint` here names one
compiled surface of the repo together with its committed expectations —
the fused Eq.-3/4 forward+backward at **0** dense B×B intermediates, the
jnp reference kept as a canary that must still trip the counter, the
streaming k-NN at zero (N, M) materialization, and one scan-compiled
engine chunk per execution strategy with a fully-donated carry and no
host callbacks in the scan body.

Entries are exposed through the ``repro.api.registry.AUDIT`` registry so
the CLI (and any test) can audit them by name; builders construct tiny
but structurally faithful instances (real kernels, real engine, real
strategies — just small shapes), and nothing here runs device code: the
auditor only traces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_audit import EntryPoint
from repro.train.engine import MESH_AXIS

__all__ = [
    "ENTRY_POINTS",
    "graph_reg_fused",
    "graph_reg_blocksparse",
    "graph_reg_ref",
    "knn_topk",
    "online_refresh",
    "ssl_objective",
    "engine_sequential",
    "engine_sync_mesh",
    "engine_async_ps",
    "engine_capture",
    "serve_decode_generate",
]

_B, _C = 256, 39                      # regularizer block: paper's 39 phones
_GAMMA, _KAPPA = 1e-3, 1e-4


def _logp_W(b: int = _B, c: int = _C):
    logp = jax.nn.log_softmax(jnp.zeros((b, c), jnp.float32), axis=-1)
    W = jnp.ones((b, b), jnp.float32)
    return logp, W


def _build_fused():
    from repro.kernels.ops import graph_regularizer_fused

    def loss_and_grads(logp, W):
        return jax.value_and_grad(
            lambda lp, w: graph_regularizer_fused(lp, w, _GAMMA, _KAPPA),
            argnums=(0, 1))(logp, W)

    return loss_and_grads, _logp_W()


def _build_blocksparse():
    """Block-sparse fwd+bwd on a block-diagonal mask (2 of 4 tiles active).

    The contract matches the dense fused path: 0 dense B×B intermediates
    outside Pallas kernels in either direction — the bwd's (B, C)-shaped
    bterm staging array is the only inter-kernel buffer, and C ≪ B here.
    """
    import numpy as np

    from repro.core.metabatch import block_layout
    from repro.kernels.ops import graph_regularizer_blocksparse

    bt = _B // 2
    Wn = np.zeros((_B, _B), np.float32)
    Wn[:bt, :bt] = 1.0
    Wn[bt:, bt:] = 1.0
    layout = tuple(jnp.asarray(a) for a in block_layout(Wn, bt).arrays())
    logp, _ = _logp_W()
    W = jnp.asarray(Wn)

    def loss_and_grads(logp, W):
        return jax.value_and_grad(
            lambda lp, w: graph_regularizer_blocksparse(
                lp, w, _GAMMA, _KAPPA, layout=layout),
            argnums=(0, 1))(logp, W)

    return loss_and_grads, (logp, W)


def _build_ref():
    from repro.kernels.ref import graph_regularizer_ref

    def loss_and_grads(logp, W):
        return jax.value_and_grad(
            lambda lp, w: graph_regularizer_ref(lp, w, _GAMMA, _KAPPA),
            argnums=(0, 1))(logp, W)

    return loss_and_grads, _logp_W()


def _build_knn():
    from repro.kernels.ops import knn_topk as knn

    n, d, k = _B, 64, 8
    x = jnp.zeros((n, d), jnp.float32)

    def run(x):
        return knn(x, x, k, exclude_self=True, use_pallas=True)

    return run, (x,)


def _build_online_refresh():
    """Embedding-space top-k of the online graph refresh (``repro.online``).

    Same contract as construction-time ``knn_topk``: the refresh must
    never materialize the dense N×N embedding-distance matrix — the
    running top-k lives in the Pallas kernel's VMEM scratch.
    """
    from repro.online import embedding_topk_device

    n, d, k = _B, 64, 8
    e = jnp.zeros((n, d), jnp.float32)

    def run(e):
        return embedding_topk_device(e, k)

    return run, (e,)


def _build_ssl_objective():
    from repro.core.ssl_loss import SSLHyper, ssl_objective as objective

    logp, W = _logp_W()
    labels = jnp.zeros((_B,), jnp.int32)
    mask = jnp.ones((_B,), jnp.float32)
    hyper = SSLHyper(gamma=_GAMMA, kappa=_KAPPA)

    def loss_and_grads(logits, labels, mask, W):
        return jax.value_and_grad(
            lambda lg: objective(lg, labels, mask, W, hyper,
                                 pairwise="fused")[0])(logits)

    return loss_and_grads, (logp, labels, mask, W)


# ------------------------------------------------------------------ engine
def _tiny_problem():
    """Structurally faithful mini instance of the paper's DNN/SSL setup."""
    from repro.core.ssl_loss import SSLHyper
    from repro.models.dnn import DNNConfig, init_dnn
    from repro.optim import sgd

    cfg = DNNConfig(input_dim=16, hidden_dim=32, n_hidden=2, n_classes=5,
                    dropout=0.0)
    params = init_dnn(cfg, jax.random.PRNGKey(0))
    return cfg, params, SSLHyper(gamma=_GAMMA, kappa=_KAPPA), sgd()


def _tiny_batches(s: int = 2, k: int = 1, p: int = 64, d: int = 16):
    """One stacked (S, k, P, ...) scan chunk of synthesized meta-batches."""
    return {
        "x": jnp.zeros((s, k, p, d), jnp.float32),
        "y": jnp.zeros((s, k, p), jnp.int32),
        "label_mask": jnp.ones((s, k, p), jnp.float32),
        "W": jnp.ones((s, k, p, p), jnp.float32),
        "valid": jnp.ones((s, k, p), jnp.float32),
    }


def _build_engine(strategy: str, *, capture: bool = False):
    import dataclasses

    from repro.models.dnn import dnn_hidden
    from repro.train.engine import Engine, TrainState, data_mesh
    from repro.train.train_step import dnn_ssl_grads

    cfg, params, hyper, opt = _tiny_problem()

    def grad_fn(p, batch):
        return dnn_ssl_grads(p, batch, cfg=cfg, hyper=hyper)

    def step_fn(state, batch, lr):
        # fold_in, not split: the carried key advances per step without a
        # split whose sibling nobody draws from (the R003 shape).
        rng = jax.random.fold_in(state.rng, state.step)
        grads, metrics = grad_fn(state.params, batch)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, lr)
        return dataclasses.replace(state, params=new_params,
                                   opt_state=new_opt, rng=rng,
                                   step=state.step + 1), metrics

    kwargs = dict(strategy=strategy)
    if strategy == "sync_mesh":
        kwargs["mesh"] = data_mesh(1)
    if capture:
        kwargs["capture_fn"] = lambda p, b: dnn_hidden(
            p, b["x"].reshape(-1, cfg.input_dim))
    if strategy == "async_ps":
        kwargs.update(grad_fn=grad_fn, opt=opt, n_workers=2)
        kwargs.pop("strategy")
        engine = Engine(strategy=strategy, **kwargs)
    else:
        engine = Engine(step_fn, **kwargs)

    state = TrainState.create(params, opt.init(params),
                              jax.random.PRNGKey(1))
    carry = engine.strategy.init_carry(engine.strategy.place_state(state))
    batches = engine.strategy.place_batch(_tiny_batches())
    lr = jnp.float32(0.1)

    def chunk(carry, batches, lr):
        return engine._chunk_fn(carry, batches, lr, capture)

    return chunk, (carry, batches, lr)


# ------------------------------------------------------------------- serve
def _build_serve_decode():
    """``serve/decode.generate`` under sampling (temperature > 0).

    This is the surface the pre-PR-9 RNG bug lived on — prefill reusing
    the unsplit sampling key — and it sat *outside* the audited set.  The
    R-pass now proves the fixed contract on every run: prefill draws
    nothing, the decode loop consumes exactly one fresh subkey per step.
    Sampling must be on (temperature > 0): at temperature 0 the argmax
    path never consumes the key and the whole stream discipline would be
    vacuously untested.
    """
    from repro.configs import get_config
    from repro.models import transformer as tf
    from repro.serve.decode import generate

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.zeros((1, 3), jnp.int32)

    def run(params, prompt):
        return generate(params, cfg, prompt, steps=3, cache_len=16,
                        temperature=0.7)

    return run, (params, prompt)


# ----------------------------------------------------------------- entries
graph_reg_fused = EntryPoint(
    name="graph_reg_fused", build=_build_fused,
    B=_B, expect_bxb=0)

graph_reg_blocksparse = EntryPoint(
    name="graph_reg_blocksparse", build=_build_blocksparse,
    B=_B, expect_bxb=0)

graph_reg_ref = EntryPoint(
    name="graph_reg_ref", build=_build_ref,
    B=_B, expect_bxb=None, canary_min_bxb=3)

knn_topk = EntryPoint(
    name="knn_topk", build=_build_knn,
    B=_B, expect_bxb=0)

online_refresh = EntryPoint(
    name="online_refresh", build=_build_online_refresh,
    B=_B, expect_bxb=0)

ssl_objective = EntryPoint(
    name="ssl_objective", build=_build_ssl_objective,
    B=_B, expect_bxb=0)

engine_sequential = EntryPoint(
    name="engine_sequential",
    build=lambda: _build_engine("sequential"),
    donate=("_run_chunk", None))

engine_sync_mesh = EntryPoint(
    name="engine_sync_mesh",
    build=lambda: _build_engine("sync_mesh"),
    donate=("_run_chunk", None),
    mesh_axes=(MESH_AXIS,))

engine_async_ps = EntryPoint(
    name="engine_async_ps",
    build=lambda: _build_engine("async_ps"),
    donate=("_run_chunk", None))

engine_capture = EntryPoint(
    name="engine_capture",
    build=lambda: _build_engine("sequential", capture=True),
    donate=("_run_chunk", None))

serve_decode_generate = EntryPoint(
    name="serve_decode_generate",
    build=_build_serve_decode)

#: Audit order (fast kernel traces first, engine traces last).
ENTRY_POINTS = (
    graph_reg_fused,
    graph_reg_blocksparse,
    graph_reg_ref,
    knn_topk,
    online_refresh,
    ssl_objective,
    engine_sequential,
    engine_sync_mesh,
    engine_async_ps,
    engine_capture,
    serve_decode_generate,
)
