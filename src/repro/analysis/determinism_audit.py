"""Determinism auditor (D-pass): device and host nondeterminism.

The paper's stochastic-partition contract (§2, Eq. 6) is *bit*-
reproducible: the same seed must reproduce the same partition, the same
meta-batch schedule, and the same training trajectory.  Two things break
that silently:

  * **Device**: an unordered floating-point ``scatter-add`` (the lowering
    of ``segment_sum`` and friends).  When several updates can land on
    the same output element (``unique_indices=False``) the addition order
    is backend-scheduled, and float addition does not commute in the last
    ulp — results drift across runs/backends.  ``D001`` flags any such
    scatter in an entry point audited under the bit-reproducibility
    contract (``EntryPoint.deterministic``).  Collision-free scatters —
    batched one-update-per-row gathers' transposes — are provably safe
    and stay silent: safety is decided from the dimension numbers (one
    independent update), not from hope.
  * **Host**: Python-level nondeterminism inside the *seeded modules* —
    the partitioner, planner, pipeline, capture/refresh, and fault-plan
    code whose outputs feed the schedule.  ``D002`` flags set-iteration
    order feeding a decision (``for x in someset``, ``max(someset,
    key=...)``, ``someset.pop()``, materializing a set into a list);
    ``D003`` flags wall-clock or global-state RNG (``np.random.*``
    module-level samplers, a seedless ``default_rng()`` /
    ``SeedSequence()`` / ``RandomState()``, the stdlib ``random`` module,
    ``time.*`` feeding an RNG constructor).

Both host rules honor the standard ``# audit: safe(D00x): reason``
line waivers (e.g. iteration over an int set that is deterministic in
CPython is waivable *with the reason on record*).
"""
from __future__ import annotations

import ast
import os
from typing import Any

import jax
import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_audit import EntryPoint, iter_eqns
from repro.analysis.waivers import apply_waivers, scan_waivers

__all__ = [
    "audit_entry_determinism",
    "audit_seeded_modules",
    "register_seeded_module",
    "default_seeded_modules",
    "SEEDED_MODULES",
]

#: Modules whose host-side logic feeds the seeded §2/Eq.-6 pipeline.
#: name -> repo-relative path; extend via :func:`register_seeded_module`.
SEEDED_MODULES: dict[str, str] = {
    "partition": "src/repro/core/partition.py",
    "metabatch": "src/repro/core/metabatch.py",
    "pipeline": "src/repro/data/pipeline.py",
    "online": "src/repro/online/refresh.py",
    "faults": "src/repro/resilience/faults.py",
}


def register_seeded_module(name: str, path: str) -> None:
    """Add a module to the D-pass host sweep (repo-relative path)."""
    SEEDED_MODULES[name] = path


def default_seeded_modules() -> dict[str, str]:
    return dict(SEEDED_MODULES)


# ---------------------------------------------------------------------------
# D001 — unordered float scatter-add in a jaxpr
# ---------------------------------------------------------------------------
_SCATTER_ADD = frozenset({"scatter-add", "scatter-mul"})


def _scatter_independent_updates(eqn) -> int:
    """Number of independent update slices that may collide.

    ``updates`` axes split into window dims (within one update slice) and
    scatter dims (enumerate the slices).  Batching dims pair 1:1 with an
    operand dim — collision-free by construction — so only the remaining
    scatter dims can produce colliding updates.
    """
    dnums = eqn.params["dimension_numbers"]
    updates = eqn.invars[2]
    window = set(dnums.update_window_dims)
    scatter_dims = [d for d in range(updates.aval.ndim) if d not in window]
    batching = len(getattr(dnums, "operand_batching_dims", ()) or ())
    n = 1
    for d in scatter_dims[batching:]:
        n *= updates.aval.shape[d]
    return n


def audit_entry_determinism(entry: EntryPoint, closed: Any | None = None
                            ) -> tuple[list[Finding], dict]:
    """D001 over one audited entry point's jaxpr."""
    if closed is None:
        fn, args = entry.build()
        closed = jax.make_jaxpr(fn)(*args)
    findings: list[Finding] = []
    checked = 0
    for eqn, _ in iter_eqns(closed.jaxpr):
        if eqn.primitive.name not in _SCATTER_ADD:
            continue
        checked += 1
        if not getattr(entry, "deterministic", True):
            continue
        dtype = eqn.outvars[0].aval.dtype
        if not np.issubdtype(dtype, np.floating):
            continue
        if eqn.params.get("unique_indices"):
            continue
        n_indep = _scatter_independent_updates(eqn)
        if n_indep <= 1:
            continue
        findings.append(Finding(
            "determinism", "D001", entry.name,
            f"{eqn.primitive.name} with {n_indep} independent float "
            f"updates and unique_indices=False — addition order is "
            "backend-scheduled, breaking bit reproducibility; use a "
            "sorted/segmented reduction or declare the entry "
            "deterministic=False",
            detail=f"{eqn.primitive.name}:{n_indep}"))
    return findings, {"scatters_checked": checked}


# ---------------------------------------------------------------------------
# D002 / D003 — host-side AST sweep over the seeded modules
# ---------------------------------------------------------------------------
_SET_METHODS = frozenset({
    "union", "intersection", "difference", "symmetric_difference", "copy",
})
_GLOBAL_SAMPLERS = frozenset({
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "choice", "shuffle", "permutation", "uniform", "normal", "bytes",
})
_RNG_CTORS = frozenset({"default_rng", "SeedSequence", "RandomState",
                        "PRNGKey", "key"})


def _dotted(node) -> str | None:
    """'np.random.seed' for nested Attribute/Name chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _FnAudit(ast.NodeVisitor):
    """One function (or module top level): track set-typed names, flag
    order-dependent uses (D002) and unseeded entropy sources (D003)."""

    def __init__(self, fn_name: str, emit) -> None:
        self.fn = fn_name
        self.emit = emit
        self.setish: set[str] = set()

    # -- set-ish expression classification --------------------------------
    def _is_setish(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.setish
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("set", "frozenset"):
                return True
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SET_METHODS:
                return self._is_setish(node.func.value)
        if isinstance(node, ast.BinOp) and isinstance(
                node.op, (ast.BitOr, ast.BitAnd, ast.Sub, ast.BitXor)):
            return self._is_setish(node.left) or self._is_setish(node.right)
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        if self._is_setish(node.value):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.setish.add(t.id)
        else:
            for t in node.targets:
                if isinstance(t, ast.Name):
                    self.setish.discard(t.id)
        self.generic_visit(node)

    # -- D002: order-dependent consumption --------------------------------
    def visit_For(self, node: ast.For) -> None:
        if self._is_setish(node.iter):
            self.emit("D002", node.lineno, self.fn,
                      "for-loop iterates an unordered set — iteration "
                      "order feeds the loop body's decisions",
                      f"{self.fn}:for")
        self.generic_visit(node)

    def visit_ListComp(self, node: ast.ListComp) -> None:
        for gen in node.generators:
            if self._is_setish(gen.iter):
                self.emit("D002", node.lineno, self.fn,
                          "list comprehension materializes an unordered "
                          "set's iteration order", f"{self.fn}:listcomp")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        # max/min with a tie-breaking key over a set; list()/tuple() of a
        # set; someset.pop().
        if isinstance(node.func, ast.Name):
            fid = node.func.id
            if fid in ("max", "min") and node.args \
                    and self._is_setish(node.args[0]) \
                    and any(k.arg == "key" for k in node.keywords):
                self.emit("D002", node.lineno, self.fn,
                          f"{fid}() with a key over an unordered set — "
                          "ties resolve by iteration order",
                          f"{self.fn}:{fid}")
            if fid in ("list", "tuple") and node.args \
                    and self._is_setish(node.args[0]):
                self.emit("D002", node.lineno, self.fn,
                          f"{fid}() materializes an unordered set's "
                          "iteration order", f"{self.fn}:{fid}")
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "pop" and not node.args \
                and self._is_setish(node.func.value):
            self.emit("D002", node.lineno, self.fn,
                      "set.pop() removes an arbitrary element",
                      f"{self.fn}:pop")
        self._check_entropy(node)
        self.generic_visit(node)

    # -- D003: wall-clock / global-state entropy --------------------------
    def _check_entropy(self, node: ast.Call) -> None:
        dotted = _dotted(node.func) or ""
        parts = dotted.split(".")
        if len(parts) >= 3 and parts[0] in ("np", "numpy") \
                and parts[1] == "random" and parts[-1] in _GLOBAL_SAMPLERS:
            self.emit("D003", node.lineno, self.fn,
                      f"{dotted}() draws from the process-global NumPy "
                      "RNG — thread/import order dependent; use a seeded "
                      "Generator", f"{self.fn}:{parts[-1]}")
        elif parts[0] == "random" and len(parts) == 2:
            self.emit("D003", node.lineno, self.fn,
                      f"stdlib {dotted}() uses the global Mersenne "
                      "Twister — not tied to the experiment seed",
                      f"{self.fn}:{parts[-1]}")
        if parts[-1] in _RNG_CTORS:
            if not node.args and not node.keywords \
                    and parts[-1] in ("default_rng", "SeedSequence",
                                      "RandomState"):
                self.emit("D003", node.lineno, self.fn,
                          f"{dotted}() without a seed draws OS entropy",
                          f"{self.fn}:unseeded-{parts[-1]}")
            for arg in list(node.args) + [k.value for k in node.keywords]:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Call):
                        d = _dotted(sub.func) or ""
                        if d.startswith("time."):
                            self.emit("D003", node.lineno, self.fn,
                                      f"{d}() seeds an RNG with "
                                      "wall-clock time",
                                      f"{self.fn}:time-seed")


def _audit_source(source: str, *, where_prefix: str, relpath: str
                  ) -> tuple[list[Finding], int]:
    tree = ast.parse(source)
    findings: list[Finding] = []
    n_fns = 0

    def make_emit(fn_name: str):
        def emit(rule, lineno, fn, msg, disc):
            findings.append(Finding(
                "determinism", rule, f"{where_prefix}::{fn}",
                msg, detail=disc, line=lineno, path=relpath))
        return emit

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            n_fns += 1
            auditor = _FnAudit(node.name, make_emit(node.name))
            for stmt in node.body:
                auditor.visit(stmt)
    return findings, n_fns


def audit_seeded_modules(paths: dict[str, str] | None = None, *,
                         root: str = ".", used: set | None = None
                         ) -> tuple[list[Finding], dict]:
    """The host sub-pass entry point: D002/D003 over the seeded modules.

    Line waivers in the scanned files are applied here (their keys land in
    ``used`` when given, so the CLI can account for stale markers).
    """
    paths = default_seeded_modules() if paths is None else paths
    findings: list[Finding] = []
    suppressed = 0
    scanned = 0
    fns = 0
    for name, rel in sorted(paths.items()):
        full = os.path.join(root, rel)
        if not os.path.exists(full):
            continue
        with open(full) as fh:
            source = fh.read()
        scanned += 1
        got, n_fns = _audit_source(source, where_prefix=rel, relpath=rel)
        fns += n_fns
        waivers = scan_waivers(full, relpath=rel)
        kept = apply_waivers(got, waivers, used=used)
        suppressed += len(got) - len(kept)
        findings.extend(kept)
    metrics = {"seeded_modules_scanned": scanned,
               "functions_scanned": fns,
               "suppressed": suppressed}
    return findings, metrics
