"""Shared waiver machinery for every pass family, plus stale detection.

A finding any pass emits can be waived inline with an auditable marker::

    self._fast_path_counter += 1  # audit: safe(C001): monotonic, stats-only

The marker names the rule it waives and applies to findings on its own
line or the line below it (marker-above-the-code style).  Findings that
have no source line — the jaxpr-level R/D/S rules attach to a traced
entry point, not a file — are waived with the *scoped* form, placed in
any scanned file (conventionally next to the entry's definition in
``repro/analysis/entrypoints.py``)::

    # audit: safe(R003@engine_*): carry key advanced but never drawn from

where the ``@scope`` is an fnmatch pattern over the finding's ``where``.

Markers are extracted with :mod:`tokenize`, so a marker *example* inside
a docstring (like the ones above) is never treated as a live waiver.

Stale-waiver detection (``A001``): after a run, any scanned marker that
waived nothing — and whose rule family's pass actually ran — is itself a
finding, so waivers cannot rot silently after the code they excused is
fixed.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import io
import re
import tokenize
from typing import Iterable

from repro.analysis.findings import Finding

__all__ = [
    "Waiver",
    "scan_waivers",
    "apply_waivers",
    "stale_waiver_findings",
    "RULE_FAMILY_PASS",
]

#: Rule-id prefix -> the pass family that can emit (and therefore waive)
#: it.  A001 only fires for markers whose family's pass actually ran, so
#: running a pass subset never mislabels out-of-scope markers as stale.
RULE_FAMILY_PASS = {
    "J": "jaxpr",
    "V": "vmem",
    "C": "concurrency",
    "R": "rng",
    "W": "race",
    "D": "determinism",
    "S": "sharding",
}

_MARKER_RE = re.compile(
    r"#\s*audit:\s*safe\(\s*([A-Z]\d{3})"      # rule id
    r"(?:\s*@\s*([\w.\[\]:*?/-]+))?\s*\)"      # optional @scope (fnmatch)
    r"(?::\s*(.*))?")                          # optional reason


@dataclasses.dataclass(frozen=True)
class Waiver:
    """One inline ``# audit: safe(...)`` marker."""

    path: str                  # repo-relative file the marker lives in
    line: int
    rule: str                  # e.g. "C001"
    scope: str | None = None   # fnmatch over Finding.where (scoped form)
    reason: str = ""

    @property
    def key(self) -> tuple[str, int, str]:
        return (self.path, self.line, self.rule)


def scan_waivers(path: str, *, relpath: str | None = None) -> list[Waiver]:
    """Extract every live marker from one source file.

    Only real ``COMMENT`` tokens count — a marker shown inside a docstring
    or string literal is documentation, not a waiver.
    """
    with open(path) as fh:
        source = fh.read()
    rel = relpath if relpath is not None else path
    out: list[Waiver] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _MARKER_RE.search(tok.string)
            if m:
                out.append(Waiver(
                    path=rel, line=tok.start[0], rule=m.group(1),
                    scope=m.group(2), reason=(m.group(3) or "").strip()))
    except tokenize.TokenizeError:
        pass                   # unparseable file: no waivers, no crash
    return out


def _matches(w: Waiver, f: Finding) -> bool:
    if w.rule != f.rule:
        return False
    if w.scope is not None:
        return fnmatch.fnmatchcase(f.where, w.scope)
    # Line form: marker on the flagged line or the line above it, in the
    # same file.
    return (f.path is not None and f.line is not None
            and f.path == w.path and f.line in (w.line, w.line + 1))


def apply_waivers(findings: Iterable[Finding], waivers: Iterable[Waiver],
                  *, used: set | None = None) -> list[Finding]:
    """Drop waived findings; record the used markers' keys in ``used``."""
    waivers = list(waivers)
    kept: list[Finding] = []
    for f in findings:
        hit = next((w for w in waivers if _matches(w, f)), None)
        if hit is None:
            kept.append(f)
        elif used is not None:
            used.add(hit.key)
    return kept


def stale_waiver_findings(waivers: Iterable[Waiver], used: set,
                          ran_passes: Iterable[str]) -> list[Finding]:
    """A001 for every unused marker whose rule family's pass ran."""
    ran = set(ran_passes)
    out: list[Finding] = []
    seen: set[str] = set()
    for w in waivers:
        if w.key in used:
            continue
        if RULE_FAMILY_PASS.get(w.rule[:1]) not in ran:
            continue           # its pass did not run; can't call it stale
        f = Finding(
            "waivers", "A001", w.path,
            f"waiver 'audit: safe({w.rule}"
            + (f"@{w.scope}" if w.scope else "")
            + ")' no longer suppresses any finding — remove it",
            detail=f"{w.rule}" + (f"@{w.scope}" if w.scope else ""),
            line=w.line, path=w.path)
        if f.fingerprint in seen:
            continue
        seen.add(f.fingerprint)
        out.append(f)
    return out
