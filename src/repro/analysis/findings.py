"""Structured findings, report serialization, and the CI baseline gate.

Every analysis pass (jaxpr / vmem / concurrency) emits :class:`Finding`
rows.  A finding's :attr:`Finding.fingerprint` is deliberately *stable* —
``pass:rule:where:detail`` with no line numbers or timestamps — so a
committed baseline (``AUDIT_baseline.json``) keeps accepting a known
finding across unrelated edits, while any *new* finding (or a known one
moving to a new site) fails the gate.

The report (``AUDIT_report.json``) carries the findings plus per-pass
metrics ("guarantees": the fused-kernel B×B count, the tuning-table rows
validated, ...) so CI artifacts record the proven invariants, not just
pass/fail.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Iterable

__all__ = [
    "Finding",
    "AuditReport",
    "load_baseline",
    "save_baseline",
    "unbaselined",
]

#: Rule ids, one table for the whole toolkit (docs + tests key off these).
RULES = {
    # jaxpr auditor
    "J000": "auditor self-check failed (reference canary did not trip)",
    "J001": "dense intermediate above the size threshold outside a Pallas "
            "kernel",
    "J002": "(B, B) intermediate materialized outside a Pallas kernel",
    "J003": "silent dtype promotion (f64 leak / widening in a declared "
            "low-precision path)",
    "J004": "host callback or sync primitive inside a scan/while body",
    "J005": "non-donated carry leaf in a jit that must donate its carry",
    "J006": "large array captured as a jaxpr constant instead of an "
            "argument",
    # Pallas VMEM / tiling checker
    "V001": "per-grid-step VMEM footprint exceeds the backend budget",
    "V002": "tile dimension violates TPU lane/sublane alignment",
    "V003": "block index map addresses memory outside the padded array",
    "V004": "tuning-table row shadowed by an earlier first-match row",
    "V005": "tuning-table kernel has no VMEM model (table and models out "
            "of sync)",
    # concurrency lint
    "C001": "lock-guarded attribute accessed outside the lock",
    "C002": "non-daemon thread started but never joined",
    "C003": "value published by a thread body read without a "
            "happens-before edge (join/wait/get/lock)",
    # RNG key-lineage auditor
    "R001": "PRNG key consumed by two or more random draws (key reuse)",
    "R002": "PRNG key consumed inside a scan body and returned in the "
            "carry unsplit",
    "R003": "PRNG entropy discarded: split results dropped without any "
            "draw, or random draws into dead values",
    # Pallas write-race / aliasing auditor
    "W001": "two grid steps write the same output block along a "
            "non-accumulating grid axis",
    "W002": "duplicate active tile entry in a block-sparse tile list "
            "(double accumulation)",
    "W003": "tile list breaks the contiguous accumulation-strip / "
            "tail-padding convention",
    "W004": "tile-list sentinel/coverage violation (output strip never "
            "visited, out-of-range tile, or occupancy mismatch)",
    # determinism auditor
    "D001": "unordered floating-point scatter-add/segment-sum in a "
            "bit-reproducible entry point",
    "D002": "iteration order of an unordered set feeds a decision in a "
            "seeded module",
    "D003": "wall-clock or global-state RNG used in a seeded module",
    # sharding / collective auditor
    "S001": "collective references an axis name outside the entry's "
            "declared mesh axes",
    "S002": "gathering collective inside a scan/while body (implicit "
            "per-step resharding)",
    "S003": "donated carry leaf with mismatched input/output shardings",
    # waiver hygiene
    "A001": "stale waiver: an '# audit: safe(...)' marker that no longer "
            "suppresses any finding",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One structured audit finding.

    ``where`` names the audited unit (an AUDIT entry-point name, a
    ``kernel[row]`` tuning-table coordinate, or ``file::Class.attr``);
    ``detail`` is a short stable discriminator so two findings of the same
    rule at the same site fingerprint apart.  ``line`` and ``path`` (the
    repo-relative source file, when the finding has one) are display/waiver
    metadata and never part of the fingerprint.
    """

    pass_name: str           # "jaxpr" | "vmem" | "concurrency" | "rng" | ...
    rule: str                # e.g. "J001"
    where: str
    message: str
    detail: str = ""
    severity: str = "error"  # "error" gates; "info" is report-only
    line: int | None = None
    path: str | None = None

    @property
    def fingerprint(self) -> str:
        return f"{self.pass_name}:{self.rule}:{self.where}:{self.detail}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint
        d["rule_doc"] = RULES.get(self.rule, "")
        return d

    def format(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return f"[{self.rule}] {loc}: {self.message}"


@dataclasses.dataclass
class AuditReport:
    """Aggregated result of one audit run, JSON-serializable for CI."""

    findings: list[Finding] = dataclasses.field(default_factory=list)
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)
    passes: dict[str, dict] = dataclasses.field(default_factory=dict)

    def extend(self, pass_name: str, findings: Iterable[Finding],
               metrics: dict | None = None) -> None:
        findings = list(findings)
        self.findings.extend(findings)
        entry = self.passes.setdefault(pass_name, {"findings": 0})
        entry["findings"] += sum(1 for f in findings
                                 if f.severity == "error")
        if metrics:
            entry.update(metrics)
            self.metrics.update(
                {f"{pass_name}/{k}": v for k, v in metrics.items()})

    @property
    def gating(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def to_dict(self, *, baseline: set[str] | None = None) -> dict:
        new = unbaselined(self.gating, baseline or set())
        return {
            "version": 1,
            "passes": self.passes,
            "metrics": self.metrics,
            "findings": [f.to_dict() for f in self.findings],
            "baseline_fingerprints": sorted(baseline or ()),
            "new_findings": sorted(f.fingerprint for f in new),
        }

    def write(self, path: str, *, baseline: set[str] | None = None) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(baseline=baseline), fh, indent=2)
            fh.write("\n")


def load_baseline(path: str) -> set[str]:
    """Accepted-finding fingerprints from a committed baseline file.

    A missing file is an empty baseline (the common healthy state), not an
    error — the gate then fails on *any* finding.
    """
    try:
        with open(path) as fh:
            data = json.load(fh)
    except FileNotFoundError:
        return set()
    return set(data.get("fingerprints", []))


def save_baseline(path: str, findings: Iterable[Finding]) -> None:
    fingerprints = sorted({f.fingerprint for f in findings
                           if f.severity == "error"})
    with open(path, "w") as fh:
        json.dump({"fingerprints": fingerprints}, fh, indent=2)
        fh.write("\n")


def unbaselined(findings: Iterable[Finding],
                baseline: set[str]) -> list[Finding]:
    """Findings whose fingerprint the committed baseline does not accept."""
    return [f for f in findings
            if f.severity == "error" and f.fingerprint not in baseline]
