"""Sharding / collective auditor (S-pass).

The multi-host roadmap items (elastic ``sync_mesh`` membership, sharded
graph construction) will layer explicit collectives over the audited
entry points.  This pass is the gate that work builds against: it walks
each audited jaxpr and checks every collective against the entry's
*declared* mesh contract (``EntryPoint.mesh_axes``):

  * ``S001`` — a collective referencing an axis name outside the entry's
    declared mesh axes.  An undeclared axis either crashes at dispatch
    (late, on the big machine) or silently binds to a vmap axis with
    different semantics.  Entries with no ``mesh_axes`` declaration are
    single-host contracts: *any* named collective inside them flags.
  * ``S002`` — a gathering collective (``all_gather`` / ``all_to_all``)
    inside a scan/while body that the entry did not opt into
    (``EntryPoint.allow_loop_collectives``, default allows only the
    reduction ``psum``).  A gather in a loop body re-materializes the
    gathered operand every step — the "implicit resharding" failure mode
    where a sharded carry silently round-trips through HBM per step.
  * ``S003`` — a donation-annotated jit whose donated carry leaf has
    *explicit but different* input and output shardings.  Donation
    aliases the output buffer onto the input; mismatched shardings force
    XLA to silently copy instead, defeating the donation the J005 pass
    already proved present.  Unspecified shardings are wildcards (the
    common fully-delegated case) and never flag.

SPMD note: on single-device meshes (this repo's CI) ``jit``-level
``NamedSharding`` constraints do not appear as jaxpr collectives — the
partitioner inserts them post-lowering — so today's entries prove clean
trivially.  The value is the contract: the moment a ``shard_map``/
``pmap`` chunk fn lands (the roadmap's next step), its collectives are
in the traced jaxpr and audited against the declared mesh.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.analysis.findings import Finding
from repro.analysis.jaxpr_audit import EntryPoint, iter_eqns

__all__ = ["audit_entry_sharding", "COLLECTIVE_PRIMITIVES"]

#: Collective primitives by jaxpr name.
COLLECTIVE_PRIMITIVES = frozenset({
    "psum", "pmax", "pmin", "ppermute", "all_gather", "all_to_all",
    "reduce_scatter", "axis_index", "pbroadcast",
})
#: The gathering subset S002 polices inside loop bodies.
_GATHERING = frozenset({"all_gather", "all_to_all"})


def _axis_names(eqn) -> tuple[str, ...]:
    """Named axes a collective eqn binds (positional/int axes are vmap
    internals, not mesh axes — skipped)."""
    raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(a for a in raw if isinstance(a, str))


def _is_unspecified(sharding) -> bool:
    return sharding is None or \
        type(sharding).__name__ == "UnspecifiedValue"


def _check_donated_shardings(eqn, entry, findings) -> None:
    donated = eqn.params.get("donated_invars")
    in_sh = eqn.params.get("in_shardings")
    out_sh = eqn.params.get("out_shardings")
    if not donated or in_sh is None or out_sh is None:
        return
    name = eqn.params.get("name", "jit")
    for i, d in enumerate(donated):
        if not d or i >= len(in_sh) or i >= len(out_sh):
            continue
        s_in, s_out = in_sh[i], out_sh[i]
        if _is_unspecified(s_in) or _is_unspecified(s_out):
            continue
        if s_in != s_out:
            findings.append(Finding(
                "sharding", "S003", entry.name,
                f"jit {name!r}: donated carry leaf {i} has input "
                f"sharding {s_in} but output sharding {s_out} — the "
                "donation degrades to a copy; make the carry sharding "
                "a fixed point",
                detail=f"{name}:{i}"))


def audit_entry_sharding(entry: EntryPoint, closed: Any | None = None
                         ) -> tuple[list[Finding], dict]:
    """S001/S002/S003 over one audited entry point's jaxpr."""
    if closed is None:
        fn, args = entry.build()
        closed = jax.make_jaxpr(fn)(*args)
    declared = tuple(getattr(entry, "mesh_axes", None) or ())
    allowed_loop = tuple(getattr(entry, "allow_loop_collectives", None)
                         or ("psum",))
    findings: list[Finding] = []
    audited = 0
    for eqn, in_loop in iter_eqns(closed.jaxpr):
        prim = eqn.primitive.name
        if prim == "pjit":
            _check_donated_shardings(eqn, entry, findings)
        if prim not in COLLECTIVE_PRIMITIVES:
            continue
        audited += 1
        for axis in _axis_names(eqn):
            if axis not in declared:
                have = f"declared mesh axes {declared}" if declared \
                    else "no declared mesh axes (single-host contract)"
                findings.append(Finding(
                    "sharding", "S001", entry.name,
                    f"collective {prim!r} binds axis {axis!r} but the "
                    f"entry has {have} — declare the axis in the "
                    "EntryPoint or drop the collective",
                    detail=f"{prim}:{axis}"))
        if in_loop and prim in _GATHERING \
                and prim not in allowed_loop:
            findings.append(Finding(
                "sharding", "S002", entry.name,
                f"gathering collective {prim!r} inside a scan/while body "
                "re-materializes its operand every step (implicit "
                "per-step resharding); hoist it out of the loop or opt "
                "in via allow_loop_collectives",
                detail=f"loop:{prim}"))
    metrics = {"collectives_audited": audited}
    return findings, metrics
