"""Pallas write-race / aliasing auditor (W-pass).

Pallas serializes grid steps on TPU, but the *program* contract the
kernels in ``repro.kernels`` are written against is stricter: a grid step
may revisit an output block only to **accumulate** into it, and the
revisit must happen along grid axes the kernel was written to accumulate
over (the innermost reduction axes, where the kernel zero-initializes on
first visit and finalizes on last visit).  Two grid steps mapping to the
same output block along any *other* axis silently overwrite each other's
partial result — the class of bug that reads as "gradients off by one
block" and never crashes.

This pass proves the absence of that bug from the same static launch
models the V-pass uses (:mod:`repro.analysis.vmem_audit`), extended with
a declared :attr:`Block.accum_axes` contract:

  * ``W001`` — for every output block, every pair of grid steps mapping
    to the same block coordinates must differ **only** on the block's
    declared accumulation axes.  Grids are enumerated exhaustively up to
    ~4k steps and corner/edge-sampled per axis beyond that (the index
    maps in use are affine, so a violation shows up at a sampled point
    if it shows up anywhere).

For the block-sparse kernels the grid is *data-dependent* — a compacted
tile-id list drives the index maps via scalar prefetch — so the proof
obligation moves to the tile lists themselves.  ``check_tile_list``
verifies the full ``BlockLayout`` contract (``repro.core.metabatch``):

  * ``W002`` — no duplicate active ``(row, col)`` entry: a duplicate
    makes the kernel accumulate the same tile twice (double-counted
    Eq.-3/4 terms, bit-diverging from the dense path).
  * ``W003`` — entries sorted by major line with each line one
    contiguous run; sentinels ``(major, 0, valid=0)`` only on empty
    lines; length padding only at the tail, repeating the last entry
    with ``valid=0``.  Together these guarantee each output accumulation
    strip is visited as one contiguous grid range, so the
    first-visit-zero / last-visit-flush predicates fire exactly once.
  * ``W004`` — coverage: every major line in ``[0, nt)`` appears (Pallas
    only flushes an output block the grid visits — a missing sentinel
    leaves stale memory in that strip), all coordinates in range, and
    the valid entries reproduce the occupancy mask exactly.

``audit_races`` is the pass entry point: W001 over every tuning-table
launch model plus the tile-list contract over representative layouts
(dense, block-diagonal, seeded-random, empty).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.analysis.findings import Finding
from repro.analysis.vmem_audit import Launch, kernel_launches, _DEFAULT_SHAPES
from repro.core.metabatch import BlockLayout, layout_from_occupancy

__all__ = [
    "check_launch_races",
    "check_tile_list",
    "check_layout",
    "audit_races",
]

#: Full-enumeration cap; larger grids are corner/edge-sampled per axis.
_FULL_ENUM_CAP = 4096


def _grid_points(grid: tuple[int, ...]):
    total = 1
    for g in grid:
        total *= g
    if total <= _FULL_ENUM_CAP:
        return itertools.product(*[range(g) for g in grid]), True
    axes = [sorted({0, 1, g // 2, g - 2, g - 1} & set(range(g)))
            for g in grid]
    return itertools.product(*axes), False


def check_launch_races(launch: Launch, *, where: str) -> list[Finding]:
    """W001 for one launch: no output block written by two grid steps
    that differ outside the block's declared accumulation axes."""
    findings: list[Finding] = []
    for b in launch.blocks:
        if b.kind != "out" or b.index_map is None:
            continue
        accum = set(b.accum_axes)
        non_accum = [ax for ax in range(len(launch.grid))
                     if ax not in accum]
        points, _ = _grid_points(launch.grid)
        writers: dict[tuple, tuple] = {}   # block coords -> projection seen
        flagged = False
        for pt in points:
            coords = tuple(b.index_map(*pt))
            proj = tuple(pt[ax] for ax in non_accum)
            prev = writers.get(coords)
            if prev is None:
                writers[coords] = proj
            elif prev != proj and not flagged:
                findings.append(Finding(
                    "race", "W001", where,
                    f"{launch.kernel}/{launch.variant}: output block "
                    f"{b.name!r} at coords {coords} is written by grid "
                    f"steps {prev} and {proj} (projected onto "
                    f"non-accumulating axes {non_accum}) — overwrite "
                    "race; declare the axis in accum_axes or fix the "
                    "index map",
                    detail=f"{launch.variant}:{b.name}"))
                flagged = True
    return findings


def check_tile_list(rows, cols, valid, nt: int, *, major: str = "row",
                    occ=None, where: str = "", name: str = ""
                    ) -> list[Finding]:
    """W002/W003/W004 over one padded tile-id list.

    ``major`` is "row" for the CSR-style list (forward / dL/dlogp sweeps)
    and "col" for the CSC-style list (the Wᵀ·P sweep); the sentinel and
    contiguity conventions apply to the major coordinate.
    """
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    valid = np.asarray(valid, dtype=np.int64)
    findings: list[Finding] = []

    def flag(rule: str, msg: str, disc: str) -> None:
        findings.append(Finding("race", rule, where,
                                f"{name}: {msg}", detail=f"{name}:{disc}"))

    T = len(rows)
    if T == 0:
        flag("W004", "empty tile list: no output strip is ever visited",
             "empty")
        return findings
    maj = rows if major == "row" else cols
    mino = cols if major == "row" else rows

    if ((rows < 0) | (rows >= nt) | (cols < 0) | (cols >= nt)).any():
        bad = int(np.argmax((rows < 0) | (rows >= nt)
                            | (cols < 0) | (cols >= nt)))
        flag("W004", f"entry {bad} = ({rows[bad]}, {cols[bad]}) is outside "
             f"the {nt}x{nt} tile grid", "out-of-range")
        return findings

    # Tail padding: trailing valid=0 repeats of the preceding entry.
    core = T
    while (core > 1 and valid[core - 1] == 0
           and rows[core - 1] == rows[core - 2]
           and cols[core - 1] == cols[core - 2]):
        core -= 1

    # W002 — duplicate active tiles double-accumulate.
    pairs = list(zip(rows[:core][valid[:core] == 1],
                     cols[:core][valid[:core] == 1]))
    if len(set(pairs)) < len(pairs):
        seen: set = set()
        dup = next(p for p in pairs if p in seen or seen.add(p))
        flag("W002", f"active tile ({dup[0]}, {dup[1]}) appears twice — "
             "its Eq.-3/4 contribution would be accumulated twice",
             f"dup@{dup[0]},{dup[1]}")

    # W003 — ordering / contiguity / sentinel discipline.
    if (np.diff(maj[:core]) < 0).any():
        flag("W003", "entries are not sorted by major line — an "
             "accumulation strip would be entered twice, re-firing its "
             "first-visit zero-init", "unsorted")
    else:
        for line in np.unique(maj[:core]):
            sel = maj[:core] == line
            minors = mino[:core][sel & (valid[:core] == 1)]
            if (np.diff(minors) <= 0).any():
                flag("W003", f"major line {int(line)} entries are not "
                     "strictly increasing in the minor coordinate",
                     f"minor@{int(line)}")
                break
    line_has_valid = np.zeros(nt, dtype=bool)
    line_has_valid[maj[:core][valid[:core] == 1]] = True
    for i in range(core):
        if valid[i] == 0:
            if mino[i] != 0 or line_has_valid[maj[i]]:
                flag("W003", f"entry {i} = ({rows[i]}, {cols[i]}, valid=0) "
                     "is neither a (major, 0) sentinel on an empty line "
                     "nor tail padding", f"sentinel@{i}")
                break

    # W004 — coverage: every output strip visited, occupancy reproduced.
    visited = np.zeros(nt, dtype=bool)
    visited[maj[:core]] = True
    if not visited.all():
        missing = int(np.argmin(visited))
        flag("W004", f"major line {missing} never visited — its output "
             "block is never flushed (missing sentinel)",
             f"unvisited@{missing}")
    if occ is not None:
        occ = np.asarray(occ).astype(bool)
        want = (set(zip(*np.nonzero(occ))) if major == "row"
                else {(r, c) for c, r in zip(*np.nonzero(occ.T))})
        got = {(int(r), int(c)) for r, c in pairs}
        want = {(int(r), int(c)) for r, c in want}
        if got != want or len(pairs) != int(occ.sum()):
            flag("W004", f"valid entries ({len(pairs)}) do not reproduce "
                 f"the occupancy mask ({int(occ.sum())} occupied tiles)",
             "occ-mismatch")
    return findings


def check_layout(layout: BlockLayout, *, where: str,
                 name: str = "layout") -> list[Finding]:
    """Both padded lists of one :class:`BlockLayout` against the contract."""
    findings = check_tile_list(
        layout.rows, layout.cols, layout.valid, layout.nt,
        major="row", occ=layout.occ, where=where, name=f"{name}.csr")
    findings += check_tile_list(
        layout.crows, layout.ccols, layout.cvalid, layout.nt,
        major="col", occ=layout.occ, where=where, name=f"{name}.csc")
    return findings


def _representative_layouts() -> list[tuple[str, BlockLayout]]:
    nt = 6
    dense = np.ones((nt, nt), dtype=bool)
    block_diag = np.kron(np.eye(nt // 2, dtype=bool),
                         np.ones((2, 2), dtype=bool))
    rng = np.random.default_rng(0)
    random = rng.random((nt, nt)) < 0.35
    empty = np.zeros((nt, nt), dtype=bool)
    return [
        ("dense", layout_from_occupancy(dense, 128)),
        ("block_diag", layout_from_occupancy(block_diag, 128)),
        ("seeded_random", layout_from_occupancy(random, 128,
                                                list_len=48)),
        ("empty", layout_from_occupancy(empty, 128)),
    ]


def audit_races(table=None) -> tuple[list[Finding], dict]:
    """The W-pass entry point: W001 over every tuning-table launch model,
    then the tile-list contract over representative BlockLayouts."""
    if table is None:
        from repro.kernels.tuning import DEFAULT_TILE_TABLE
        table = DEFAULT_TILE_TABLE
    findings: list[Finding] = []
    launches_checked = 0
    blocks_proven = 0
    for idx, (kernel, backend, max_rows, tiles) in enumerate(table):
        shape = {}
        if max_rows is not None:
            shape["rows"] = max_rows
            if kernel in ("rbf", "topk"):
                shape["cols"] = max_rows
        try:
            launches = kernel_launches(kernel, tiles, **shape)
        except KeyError:
            continue               # V005 (no model) is the vmem pass's call
        for launch in launches:
            where = f"tuning[{idx}]:{kernel}/{launch.variant}"
            got = check_launch_races(launch, where=where)
            findings.extend(got)
            launches_checked += 1
            n_out = sum(1 for b in launch.blocks if b.kind == "out")
            blocks_proven += n_out - len({f.detail for f in got})
    tiles_proven = 0
    layouts = _representative_layouts()
    for lname, layout in layouts:
        got = check_layout(layout, where=f"layout:{lname}", name=lname)
        findings.extend(got)
        if not got:
            tiles_proven += 2 * layout.n_active
    metrics = {
        "launches_checked": launches_checked,
        "output_blocks_proven": blocks_proven,
        "layouts_checked": len(layouts),
        "tiles_proven_race_free": tiles_proven,
    }
    return findings, metrics
