"""Blocked pairwise-distance + RBF affinity kernel (graph construction, §3).

Computes the dense affinity tile  w_ij = exp(−‖x_i − x_j‖ / 2σ²)  for a
block of the k-NN candidate matrix:  ‖x_i − x_j‖² = n_i − 2·x_iᵀx_j + n_j
with the inner product tiled over the feature dimension on the MXU and the
row norms passed in precomputed.

  grid = (N/bi, N/bj, D/bd);  VMEM scratch accumulates the (bi, bj) inner-
  product tile over feature chunks; the last chunk applies norms + RBF.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BI = 128
DEFAULT_BJ = 128
DEFAULT_BD = 256


def _pairwise_kernel(x_ref, y_ref, nx_ref, ny_ref, sig_ref, out_ref, acc_ref,
                     *, n_d_blocks: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _finish():
        d2 = nx_ref[...] - 2.0 * acc_ref[...] + ny_ref[...].T
        d2 = jnp.maximum(d2, 0.0)
        sigma = sig_ref[0, 0]
        out_ref[...] = jnp.exp(-jnp.sqrt(d2) / (2.0 * sigma * sigma))


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bd", "interpret"))
def rbf_affinity_pallas(
    x: jax.Array, y: jax.Array, sigma: jax.Array | float, *,
    bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ, bd: int = DEFAULT_BD,
    interpret: bool = True,
) -> jax.Array:
    """Dense RBF affinity block. x: (N, D); y: (M, D) -> (N, M)."""
    N, D = x.shape
    M = y.shape[0]
    bi, bj, bd = min(bi, N), min(bj, M), min(bd, D)
    pi, pj, pd = (-N) % bi, (-M) % bj, (-D) % bd
    xp = jnp.pad(x, ((0, pi), (0, pd)))
    yp = jnp.pad(y, ((0, pj), (0, pd)))
    nx = jnp.sum(xp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    ny = jnp.sum(yp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    grid = ((N + pi) // bi, (M + pj) // bj, (D + pd) // bd)
    sig = jnp.full((1, 1), sigma, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, n_d_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bj, bd), lambda i, j, d: (j, d)),
            pl.BlockSpec((bi, 1), lambda i, j, d: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, d: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, d: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N + pi, M + pj), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32), nx, ny, sig)
    return out[:N, :M]
