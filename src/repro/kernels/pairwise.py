"""Blocked pairwise-distance kernels for graph construction (§3).

Two device paths:

``rbf_affinity_pallas``
    Dense affinity tile  w_ij = exp(−‖x_i − x_j‖ / 2σ²)  for a block of the
    k-NN candidate matrix:  ‖x_i − x_j‖² = n_i − 2·x_iᵀx_j + n_j  with the
    inner product tiled over the feature dimension on the MXU and the row
    norms passed in precomputed.  Materializes the full (N, M) block — fine
    for a (meta-)batch, ruinous for corpus-scale k-NN search.

``knn_topk_pallas``
    Streaming top-k: tiles over *candidate columns* and keeps a running
    per-row top-k (squared distance + column index) in VMEM scratch, so the
    (N, M) distance matrix is never materialized anywhere — the working set
    is one (bi, bj) tile plus the (bi, k) running state.  Per column chunk
    the k best candidates are folded in by k predicated min-extraction
    steps (k ≈ 10 ≪ bj, so the merge is noise next to the MXU contraction).

  grid = (N/bi, M/bj, D/bd);  VMEM scratch accumulates the (bi, bj) inner-
  product tile over feature chunks; the last chunk applies norms (+ RBF or
  the top-k merge).  ``interpret=None`` derives the mode from the backend:
  compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import TileSpec, select_tiles
from .tuning import default_interpret as _default_interpret

DEFAULT_BI = 128
DEFAULT_BJ = 128
DEFAULT_BD = 256

_BIG = 3.4e38                       # "+inf" that survives arithmetic
_BIG_POS = 2 ** 30


def _pairwise_kernel(x_ref, y_ref, nx_ref, ny_ref, sig_ref, out_ref, acc_ref,
                     *, n_d_blocks: int):
    di = pl.program_id(2)

    @pl.when(di == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(di == n_d_blocks - 1)
    def _finish():
        d2 = nx_ref[...] - 2.0 * acc_ref[...] + ny_ref[...].T
        d2 = jnp.maximum(d2, 0.0)
        sigma = sig_ref[0, 0]
        out_ref[...] = jnp.exp(-jnp.sqrt(d2) / (2.0 * sigma * sigma))


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bd", "interpret"))
def rbf_affinity_pallas(
    x: jax.Array, y: jax.Array, sigma: jax.Array | float, *,
    bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ, bd: int = DEFAULT_BD,
    interpret: bool | None = None,
) -> jax.Array:
    """Dense RBF affinity block. x: (N, D); y: (M, D) -> (N, M)."""
    interpret = _default_interpret(interpret)
    N, D = x.shape
    M = y.shape[0]
    bi, bj, bd = min(bi, N), min(bj, M), min(bd, D)
    pi, pj, pd = (-N) % bi, (-M) % bj, (-D) % bd
    xp = jnp.pad(x, ((0, pi), (0, pd)))
    yp = jnp.pad(y, ((0, pj), (0, pd)))
    nx = jnp.sum(xp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    ny = jnp.sum(yp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    grid = ((N + pi) // bi, (M + pj) // bj, (D + pd) // bd)
    sig = jnp.full((1, 1), sigma, jnp.float32)
    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, n_d_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bj, bd), lambda i, j, d: (j, d)),
            pl.BlockSpec((bi, 1), lambda i, j, d: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, d: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j, d: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, d: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N + pi, M + pj), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32), nx, ny, sig)
    return out[:N, :M]


# ---------------------------------------------------------------------------
# Streaming top-k (never materializes the N×M distance matrix).
# ---------------------------------------------------------------------------
def _topk_kernel(x_ref, y_ref, nx_ref, ny_ref, out_d2_ref, out_idx_ref,
                 acc_ref, best_d2_ref, best_idx_ref, *,
                 k: int, n_cols: int, n_j: int, n_d: int,
                 exclude_self: bool, bi: int, bj: int):
    i, j, d = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((j == 0) & (d == 0))
    def _init_best():
        best_d2_ref[...] = jnp.full_like(best_d2_ref, _BIG)
        best_idx_ref[...] = jnp.full_like(best_idx_ref, -1)

    @pl.when(d == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(d == n_d - 1)
    def _merge_chunk():
        d2 = jnp.maximum(nx_ref[...] - 2.0 * acc_ref[...] + ny_ref[...].T,
                         0.0)
        col = j * bj + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
        d2 = jnp.where(col >= n_cols, _BIG, d2)          # padded columns
        if exclude_self:
            row = (i * bi
                   + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0))
            d2 = jnp.where(col == row, _BIG, d2)
        # Fold the chunk into the running top-k: k predicated min-extraction
        # steps over the (bi, k + bj) candidate set (values live in
        # registers/VMEM only — nothing is written back per chunk).
        cand_val = jnp.concatenate([best_d2_ref[...], d2], axis=1)
        cand_idx = jnp.concatenate([best_idx_ref[...], col], axis=1)
        pos = jax.lax.broadcasted_iota(jnp.int32, cand_val.shape, 1)
        new_val, new_idx = [], []
        for _ in range(k):
            m = jnp.min(cand_val, axis=1, keepdims=True)
            # First (lowest-position) occurrence of the minimum — keeps tie
            # order stable, matching lax.top_k on the dense oracle.
            sel = jnp.min(jnp.where(cand_val == m, pos, _BIG_POS),
                          axis=1, keepdims=True)
            hit = pos == sel
            new_val.append(m[:, 0])
            new_idx.append(jnp.sum(jnp.where(hit, cand_idx, 0), axis=1))
            cand_val = jnp.where(hit, _BIG, cand_val)
        best_d2_ref[...] = jnp.stack(new_val, axis=1)
        best_idx_ref[...] = jnp.stack(new_idx, axis=1)

    @pl.when((j == n_j - 1) & (d == n_d - 1))
    def _flush():
        out_d2_ref[...] = best_d2_ref[...]
        out_idx_ref[...] = best_idx_ref[...]


@functools.partial(jax.jit, static_argnames=("k", "exclude_self", "bi", "bj",
                                             "bd", "interpret"))
def _knn_topk(x, y, *, k, exclude_self, bi, bj, bd, interpret):
    N, D = x.shape
    M = y.shape[0]
    pi, pj, pd = (-N) % bi, (-M) % bj, (-D) % bd
    xp = jnp.pad(x, ((0, pi), (0, pd)))
    yp = jnp.pad(y, ((0, pj), (0, pd)))
    nx = jnp.sum(xp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    ny = jnp.sum(yp.astype(jnp.float32) ** 2, axis=1, keepdims=True)
    grid = ((N + pi) // bi, (M + pj) // bj, (D + pd) // bd)
    d2, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k, n_cols=M, n_j=grid[1],
                          n_d=grid[2], exclude_self=exclude_self,
                          bi=bi, bj=bj),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bd), lambda i, j, d: (i, d)),
            pl.BlockSpec((bj, bd), lambda i, j, d: (j, d)),
            pl.BlockSpec((bi, 1), lambda i, j, d: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, d: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bi, k), lambda i, j, d: (i, 0)),
            pl.BlockSpec((bi, k), lambda i, j, d: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N + pi, k), jnp.float32),
            jax.ShapeDtypeStruct((N + pi, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bi, bj), jnp.float32),   # inner-product tile
            pltpu.VMEM((bi, k), jnp.float32),    # running top-k distances
            pltpu.VMEM((bi, k), jnp.int32),      # running top-k indices
        ],
        interpret=interpret,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32), nx, ny)
    return d2[:N], idx[:N]


def knn_topk_pallas(
    x: jax.Array, y: jax.Array, k: int, *,
    exclude_self: bool = False,
    bi: int | None = None, bj: int | None = None, bd: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Streaming k-NN: per-row k smallest squared distances and indices.

    x: (N, D) queries; y: (M, D) candidates → ``(d2, idx)`` of shape (N, k),
    sorted ascending.  ``exclude_self`` masks the diagonal (x is y).  The
    candidate axis is streamed in bj-wide chunks — peak memory is
    O(N·k + bi·bj), independent of M.
    """
    N, D = x.shape
    M = y.shape[0]
    limit = M - 1 if exclude_self else M
    if not 0 < k <= limit:
        raise ValueError(f"k must be in [1, {limit}] for M={M} candidates "
                         f"(exclude_self={exclude_self}), got {k}")
    auto = select_tiles("topk", rows=N, pinned=TileSpec(bi=bi, bj=bj, bd=bd))
    bi = min(auto.bi or DEFAULT_BI, N)
    bj = min(auto.bj or 512, M)
    bd = min(auto.bd or DEFAULT_BD, D)
    return _knn_topk(x, y, k=k, exclude_self=exclude_self, bi=bi, bj=bj,
                     bd=bd, interpret=_default_interpret(interpret))
