"""Fused graph-regularizer kernels (the paper's compute hot-spot, §1.1).

The Eq.-3/4 regularizer over one dense (meta-)batch affinity block is

    L(logp, W) = γ Σ_ij W_ij · Hc(p_i, p_j) − Σ_i (κ + γ Σ_j W_ij) H(p_i)

with Hc(p_i, p_j) = −Σ_c p_ic log p_jc and H(p_i) = −Σ_c p_ic log p_ic.
The paper's efficiency argument is exactly this: graph partitioning makes
the per-batch affinity block W dense, so the regularizer becomes one
matrix-matrix contraction instead of sparse gathers.  On TPU we tile it for
the MXU, and — unlike the historical three-pass path (Pallas cross term,
then jnp degrees, then jnp entropy) — compute *all three terms in a single
grid sweep*:

  grid = (B/bi, B/bj, C/bc), class chunk innermost.  For each (i, j) tile
  the class dimension is accumulated over bc-chunks into a VMEM scratch
  tile (bi × bj, f32); row degrees Σ_j W_ij accumulate once per j-block
  into a (bi, 1) scratch, the per-row entropy accumulates on the j == 0
  pass, and the last chunk of each tile folds everything into the scalar
  output.

The backward pass is analytic and tiled the same way (see
``_reg_bwd_dlogp_kernel`` / ``_reg_bwd_dw_kernel``):

    ∂L/∂logp = γ·[−(P ⊙ (W·logP) + Wᵀ·P)] + (κ + γ·deg) ⊙ P ⊙ (logP + 1)
    ∂L/∂W_ij = −γ·[(P·logPᵀ)_ij + H(p_i)]

so no B×B intermediate is ever materialized outside a kernel.

All kernels take an internal scalar triple ``(gc, κ, ge)`` — cross-term
weight, uniform entropy weight, degree-entropy weight — so the same code
serves both the full regularizer (gc = ge = γ) and the bare pairwise cross
term (gc = 1, κ = ge = 0).

Block sizes default to the ``repro.kernels.tuning`` table — MXU-aligned
(128 lanes) with the class chunk kept wide to amortize the weight-
stationary W tile.  VMEM working set at (128, 128, 512) defaults:
bi·bc + bj·bc + bi·bj + scratch ≈ 0.9 MB.  ``interpret=None`` derives the
mode from the backend: compiled on TPU, interpreter elsewhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tuning import TileSpec, select_tiles
from .tuning import default_interpret as _default_interpret

DEFAULT_BI = 128
DEFAULT_BJ = 128
DEFAULT_BC = 512


def _pad2(a: jax.Array, pr: int, pc: int) -> jax.Array:
    return jnp.pad(a, ((0, pr), (0, pc))) if (pr or pc) else a


def _reg_tiles(B: int, C: int, bi, bj, bc) -> tuple[int, int, int]:
    """Table-selected tiles with explicit overrides, clamped to the shape."""
    auto = select_tiles("graph_reg", rows=B,
                        pinned=TileSpec(bi=bi, bj=bj, bc=bc))
    return (min(auto.bi or DEFAULT_BI, B), min(auto.bj or DEFAULT_BJ, B),
            min(auto.bc or DEFAULT_BC, C))


# ---------------------------------------------------------------------------
# Forward: single-pass fused regularizer.
# ---------------------------------------------------------------------------
def _graph_reg_kernel(p_ref, logp_ref, w_ref, out_ref, acc_ref, *,
                      n_c_blocks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # S_tile += P_i(bi, bc) @ logP_j(bj, bc)^T   — MXU contraction.
    acc_ref[...] += jax.lax.dot_general(
        p_ref[...], logp_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0) & (ci == 0))
    def _init_out():
        out_ref[0, 0] = 0.0

    @pl.when(ci == n_c_blocks - 1)
    def _finish_tile():
        # cross = −Σ W ⊙ S  (accumulated over all (i, j) tiles).
        out_ref[0, 0] += -jnp.sum(w_ref[...] * acc_ref[...])


def _fused_reg_kernel(p_ref, logpj_ref, logpi_ref, w_ref, s_ref, out_ref,
                      acc_ref, deg_ref, ent_ref, *, n_j: int, n_c: int):
    i, j, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0) & (c == 0))
    def _init_out():
        out_ref[0, 0] = 0.0

    @pl.when((j == 0) & (c == 0))
    def _init_row_state():
        deg_ref[...] = jnp.zeros_like(deg_ref)
        ent_ref[...] = jnp.zeros_like(ent_ref)

    @pl.when(c == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # Row degrees: one j-block strip of Σ_j W_ij per tile (W is c-inv).
        deg_ref[...] += jnp.sum(w_ref[...], axis=1, keepdims=True)

    # S_tile += P_i(bi, bc) @ logP_j(bj, bc)^T   — MXU contraction.
    acc_ref[...] += jax.lax.dot_general(
        p_ref[...], logpj_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _entropy_chunk():
        # H(p_i) accumulated over class chunks, once per row block (j == 0).
        ent_ref[...] += -jnp.sum(p_ref[...] * logpi_ref[...], axis=1,
                                 keepdims=True)

    gc = s_ref[0, 0]
    kappa = s_ref[0, 1]
    ge = s_ref[0, 2]

    @pl.when(c == n_c - 1)
    def _finish_tile():
        out_ref[0, 0] += -gc * jnp.sum(w_ref[...] * acc_ref[...])

    @pl.when((j == n_j - 1) & (c == n_c - 1))
    def _finish_row_block():
        # −Σ_i (κ + ge·deg_i)·H(p_i) for this row block; deg/ent complete.
        out_ref[0, 0] += -jnp.sum((kappa + ge * deg_ref[...]) * ent_ref[...])


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bc", "interpret"))
def _fused_reg_forward(
    logp: jax.Array, W: jax.Array, scalars: jax.Array, *,
    bi: int, bj: int, bc: int, interpret: bool,
) -> jax.Array:
    B, C = logp.shape
    pad_i, pad_j, pad_c = (-B) % bi, (-B) % bj, (-C) % bc
    # Padding: p rows/cols pad to 0 (so padded entries kill every product);
    # logp pads to 0 as well — 0·logp and p·0 terms all vanish.
    p = _pad2(jnp.exp(logp), pad_i, pad_c)
    logpj = _pad2(logp, pad_j, pad_c)
    logpi = _pad2(logp, pad_i, pad_c)
    Wp = _pad2(W, pad_i, pad_j)
    grid = ((B + pad_i) // bi, (B + pad_j) // bj, (C + pad_c) // bc)
    out = pl.pallas_call(
        functools.partial(_fused_reg_kernel, n_j=grid[1], n_c=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bj, bc), lambda i, j, c: (j, c)),
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bi, bj), lambda i, j, c: (i, j)),
            pl.BlockSpec((1, 4), lambda i, j, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bi, bj), jnp.float32),   # S tile accumulator
            pltpu.VMEM((bi, 1), jnp.float32),    # row degrees
            pltpu.VMEM((bi, 1), jnp.float32),    # row entropies
        ],
        interpret=interpret,
    )(p.astype(jnp.float32), logpj.astype(jnp.float32),
      logpi.astype(jnp.float32), Wp.astype(jnp.float32), scalars)
    return out[0, 0]


def graph_reg_fused_pallas(
    logp: jax.Array, W: jax.Array, gamma: float, kappa: float, *,
    bi: int | None = None, bj: int | None = None, bc: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Single-pass fused Eq.-3/4 regularizer (cross + degrees + entropy).

    Returns γ Σ_ij W_ij Hc(p_i,p_j) − Σ_i (κ + γ Σ_j W_ij) H(p_i) as one
    scalar from one grid sweep.  logp: (B, C); W: (B, B).
    """
    B, C = logp.shape
    bi, bj, bc = _reg_tiles(B, C, bi, bj, bc)
    scalars = jnp.stack([gamma, kappa, gamma, 0.0]).astype(
        jnp.float32).reshape(1, 4)
    return _fused_reg_forward(logp, W, scalars, bi=bi, bj=bj, bc=bc,
                              interpret=_default_interpret(interpret))


def graph_reg_cross_pallas(
    logp: jax.Array, W: jax.Array, *,
    bi: int | None = None, bj: int | None = None, bc: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Bare cross term Σ_ij W_ij Hc(p_i,p_j) through the fused kernel
    (gc = 1, κ = ge = 0 switches the entropy/degree terms off)."""
    B, C = logp.shape
    bi, bj, bc = _reg_tiles(B, C, bi, bj, bc)
    scalars = jnp.zeros((1, 4), jnp.float32).at[0, 0].set(1.0)
    return _fused_reg_forward(logp, W, scalars, bi=bi, bj=bj, bc=bc,
                              interpret=_default_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bc", "interpret"))
def graph_reg_pairwise_pallas(
    logp: jax.Array, W: jax.Array, *,
    bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ, bc: int = DEFAULT_BC,
    interpret: bool | None = None,
) -> jax.Array:
    """Σ_ij W_ij Hc(p_i, p_j) with p = exp(logp).  logp: (B, C); W: (B, B).

    The original cross-term-only kernel, kept as the minimal reference
    Pallas path; the registry entries now route through the fused kernel.
    """
    interpret = _default_interpret(interpret)
    B, C = logp.shape
    bi, bj, bc = min(bi, B), min(bj, B), min(bc, C)
    pad_i = (-B) % bi
    pad_j = (-B) % bj
    pad_c = (-C) % bc
    # Padding: logp rows padded with 0 (p=exp(0)=1 would corrupt → pad p with
    # 0 instead by padding logp with -inf surrogate handled via exp outside).
    p = jnp.exp(logp)
    if pad_i or pad_c:
        p = jnp.pad(p, ((0, pad_i), (0, pad_c)))             # p rows -> 0
        logp_p = jnp.pad(logp, ((0, pad_j), (0, pad_c)))     # logp·0 = 0
    else:
        logp_p = logp
    Wp = jnp.pad(W, ((0, pad_i), (0, pad_j))) if (pad_i or pad_j) else W
    Bi, Bj, Cc = p.shape[0], logp_p.shape[0], p.shape[1]
    grid = (Bi // bi, Bj // bj, Cc // bc)
    out = pl.pallas_call(
        functools.partial(_graph_reg_kernel, n_c_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bj, bc), lambda i, j, c: (j, c)),
            pl.BlockSpec((bi, bj), lambda i, j, c: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        # VMEM scratch accumulator for the S tile.
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(p.astype(jnp.float32), logp_p.astype(jnp.float32),
      Wp.astype(jnp.float32))
    return out[0, 0]


# ---------------------------------------------------------------------------
# Backward: tiled analytic VJP (no B×B intermediate outside the kernels).
# ---------------------------------------------------------------------------
def _reg_bwd_dlogp_kernel(w_ref, wt_ref, pj_ref, logpj_ref, pi_ref,
                          logpi_ref, s_ref, out_ref, a_ref, b_ref, deg_ref,
                          *, n_j: int):
    c, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init_tile():
        a_ref[...] = jnp.zeros_like(a_ref)
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when((c == 0) & (j == 0))
    def _init_deg():
        deg_ref[...] = jnp.zeros_like(deg_ref)

    # A += W[i-blk, j-blk] @ logP[j-blk, c-blk]        (the W·logP term)
    a_ref[...] += jnp.dot(w_ref[...], logpj_ref[...],
                          preferred_element_type=jnp.float32)
    # B += W[j-blk, i-blk]ᵀ @ P[j-blk, c-blk]          (the Wᵀ·P term)
    b_ref[...] += jax.lax.dot_general(
        wt_ref[...], pj_ref[...],
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(c == 0)
    def _deg_chunk():
        deg_ref[...] += jnp.sum(w_ref[...], axis=1, keepdims=True)

    @pl.when(j == n_j - 1)
    def _finish():
        g, gc, kappa, ge = (s_ref[0, 0], s_ref[0, 1],
                            s_ref[0, 2], s_ref[0, 3])
        p = pi_ref[...]
        coef = kappa + ge * deg_ref[...]
        out_ref[...] = g * (-gc * (p * a_ref[...] + b_ref[...])
                            + coef * p * (logpi_ref[...] + 1.0))


def _reg_bwd_dw_kernel(pi_ref, logpj_ref, logpi_ref, s_ref, out_ref,
                       acc_ref, ent_ref, *, n_c: int):
    j, c = pl.program_id(1), pl.program_id(2)

    @pl.when(c == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((j == 0) & (c == 0))
    def _init_ent():
        ent_ref[...] = jnp.zeros_like(ent_ref)

    # S_tile += P_i(bi, bc) @ logP_j(bj, bc)^T
    acc_ref[...] += jax.lax.dot_general(
        pi_ref[...], logpj_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _entropy_chunk():
        ent_ref[...] += -jnp.sum(pi_ref[...] * logpi_ref[...], axis=1,
                                 keepdims=True)

    @pl.when(c == n_c - 1)
    def _finish():
        g, gc, ge = s_ref[0, 0], s_ref[0, 1], s_ref[0, 3]
        out_ref[...] = -g * (gc * acc_ref[...] + ge * ent_ref[...])


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bc", "interpret"))
def _reg_bwd_dlogp(
    logp: jax.Array, W: jax.Array, scalars: jax.Array, *,
    bi: int, bj: int, bc: int, interpret: bool,
) -> jax.Array:
    """dL/dlogp tiles: grid (B/bi, C/bc, B/bj), contraction block innermost."""
    B, C = logp.shape
    pad_i, pad_j, pad_c = (-B) % bi, (-B) % bj, (-C) % bc
    p = jnp.exp(logp)
    pi, logpi = _pad2(p, pad_i, pad_c), _pad2(logp, pad_i, pad_c)
    pj, logpj = _pad2(p, pad_j, pad_c), _pad2(logp, pad_j, pad_c)
    # W is read through two views — (i, j) blocks and transposed (j, i)
    # blocks — so both axes must cover both block paddings.
    L = max(B + pad_i, B + pad_j)
    Wp = _pad2(W, L - B, L - B)
    grid = ((B + pad_i) // bi, (C + pad_c) // bc, (B + pad_j) // bj)
    out = pl.pallas_call(
        functools.partial(_reg_bwd_dlogp_kernel, n_j=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bj), lambda i, c, j: (i, j)),   # W
            pl.BlockSpec((bj, bi), lambda i, c, j: (j, i)),   # W (transposed)
            pl.BlockSpec((bj, bc), lambda i, c, j: (j, c)),   # P rows j
            pl.BlockSpec((bj, bc), lambda i, c, j: (j, c)),   # logP rows j
            pl.BlockSpec((bi, bc), lambda i, c, j: (i, c)),   # P rows i
            pl.BlockSpec((bi, bc), lambda i, c, j: (i, c)),   # logP rows i
            pl.BlockSpec((1, 4), lambda i, c, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bc), lambda i, c, j: (i, c)),
        out_shape=jax.ShapeDtypeStruct((B + pad_i, C + pad_c), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bi, bc), jnp.float32),   # (W·logP) tile
            pltpu.VMEM((bi, bc), jnp.float32),   # (Wᵀ·P) tile
            pltpu.VMEM((bi, 1), jnp.float32),    # row degrees
        ],
        interpret=interpret,
    )(Wp.astype(jnp.float32), Wp.astype(jnp.float32),
      pj.astype(jnp.float32), logpj.astype(jnp.float32),
      pi.astype(jnp.float32), logpi.astype(jnp.float32), scalars)
    return out[:B, :C]


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bc", "interpret"))
def _reg_bwd_dw(
    logp: jax.Array, scalars: jax.Array, *,
    bi: int, bj: int, bc: int, interpret: bool,
) -> jax.Array:
    """dL/dW tiles: grid (B/bi, B/bj, C/bc), class chunk innermost."""
    B, C = logp.shape
    pad_i, pad_j, pad_c = (-B) % bi, (-B) % bj, (-C) % bc
    p = jnp.exp(logp)
    pi, logpi = _pad2(p, pad_i, pad_c), _pad2(logp, pad_i, pad_c)
    logpj = _pad2(logp, pad_j, pad_c)
    grid = ((B + pad_i) // bi, (B + pad_j) // bj, (C + pad_c) // bc)
    out = pl.pallas_call(
        functools.partial(_reg_bwd_dw_kernel, n_c=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),   # P rows i
            pl.BlockSpec((bj, bc), lambda i, j, c: (j, c)),   # logP rows j
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),   # logP rows i
            pl.BlockSpec((1, 4), lambda i, j, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B + pad_i, B + pad_j), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bi, bj), jnp.float32),   # S tile
            pltpu.VMEM((bi, 1), jnp.float32),    # row entropies
        ],
        interpret=interpret,
    )(pi.astype(jnp.float32), logpj.astype(jnp.float32),
      logpi.astype(jnp.float32), scalars)
    return out[:B, :B]


def graph_reg_bwd_pallas(
    logp: jax.Array, W: jax.Array, g: jax.Array, *,
    gamma: float, kappa: float, ent_weight: float,
    bi: int | None = None, bj: int | None = None, bc: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Tiled analytic VJP of the fused regularizer: (dlogp, dW).

    ``gamma`` weights the cross term, ``kappa`` the uniform entropy term and
    ``ent_weight`` the degree-weighted entropy term (γ for the full
    regularizer, 0 for the bare cross term).  ``g`` is the output cotangent.
    """
    B, C = logp.shape
    bi, bj, bc = _reg_tiles(B, C, bi, bj, bc)
    interpret = _default_interpret(interpret)
    scalars = jnp.stack(
        [jnp.asarray(g, jnp.float32), jnp.float32(gamma),
         jnp.float32(kappa), jnp.float32(ent_weight)]).reshape(1, 4)
    dlogp = _reg_bwd_dlogp(logp, W, scalars, bi=bi, bj=bj, bc=bc,
                           interpret=interpret)
    dW = _reg_bwd_dw(logp, scalars, bi=bi, bj=bj, bc=bc,
                     interpret=interpret)
    return dlogp, dW


# ---------------------------------------------------------------------------
# Block-sparse variant: compacted grid over active tiles only.
#
# The §2 meta-batch W is block-structured — most bt×bt tiles are exact
# structural zeros.  A ``repro.core.metabatch.BlockLayout`` supplies
# scalar-prefetched active-tile index lists (row-major for the forward /
# dL/dlogp sweeps, column-major for the Wᵀ·P pass) so the grid is
# (n_listed_tiles, C/bc) instead of (B/bt)² × C/bc: MXU work scales with
# occupied tiles.  Accumulation order within every row strip is identical
# to the dense fused sweep (j ascending, then class chunks), so a fully
# dense occupancy mask reproduces the dense kernels bit for bit.
#
# Layout padding contract (see metabatch.BlockLayout): every empty tile
# row/column carries one valid=0 sentinel so its output block is still
# visited and written, and length padding repeats the last entry with
# valid=0 so no new strip starts and each strip finalizes exactly once.
# ---------------------------------------------------------------------------
DEFAULT_BT = 128


def _bsp_tiles(B: int, C: int, bt, bc) -> tuple[int, int]:
    """Table-selected (bt, bc) with explicit overrides; bt is never clamped
    to B — it must match the tile size the BlockLayout was built with."""
    auto = select_tiles("graph_reg_blocksparse", rows=B,
                        pinned=TileSpec(bi=bt, bc=bc))
    return (auto.bi or DEFAULT_BT), min(auto.bc or DEFAULT_BC, C)


def _bsp_check_layout(B: int, bt: int, nt: int) -> None:
    if -(-B // bt) != nt:
        raise ValueError(
            f"BlockLayout tile grid ({nt}×{nt}) does not match "
            f"ceil(B/bt) = ceil({B}/{bt}) = {-(-B // bt)}; the layout must "
            f"be built with the same tile size the kernel runs with "
            f"(pin ObjectiveConfig.tile_bt / tiles.bi consistently)")


def _bsp_fwd_kernel(rows_ref, cols_ref, valid_ref, p_ref, logpj_ref,
                    logpi_ref, w_ref, s_ref, out_ref, acc_ref, deg_ref,
                    ent_ref, *, n_t: int, n_c: int):
    t, c = pl.program_id(0), pl.program_id(1)
    row = rows_ref[t]
    first = (t == 0) | (rows_ref[jnp.maximum(t - 1, 0)] != row)
    last = (t == n_t - 1) | (rows_ref[jnp.minimum(t + 1, n_t - 1)] != row)
    live = valid_ref[t] == 1

    @pl.when((t == 0) & (c == 0))
    def _init_out():
        out_ref[0, 0] = 0.0

    @pl.when(first & (c == 0))
    def _init_row_state():
        deg_ref[...] = jnp.zeros_like(deg_ref)
        ent_ref[...] = jnp.zeros_like(ent_ref)

    @pl.when(c == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live & (c == 0))
    def _deg_chunk():
        deg_ref[...] += jnp.sum(w_ref[...], axis=1, keepdims=True)

    @pl.when(live)
    def _cross_chunk():
        # S_tile += P_i(bt, bc) @ logP_j(bt, bc)^T — skipped on sentinels.
        acc_ref[...] += jax.lax.dot_general(
            p_ref[...], logpj_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(first)
    def _entropy_chunk():
        # H(p_i) once per row strip — NOT gated on `live`: an empty tile
        # row's sentinel still owes the κ-weighted entropy of its rows.
        ent_ref[...] += -jnp.sum(p_ref[...] * logpi_ref[...], axis=1,
                                 keepdims=True)

    gc = s_ref[0, 0]
    kappa = s_ref[0, 1]
    ge = s_ref[0, 2]

    @pl.when(live & (c == n_c - 1))
    def _finish_tile():
        out_ref[0, 0] += -gc * jnp.sum(w_ref[...] * acc_ref[...])

    @pl.when(last & (c == n_c - 1))
    def _finish_row_strip():
        out_ref[0, 0] += -jnp.sum((kappa + ge * deg_ref[...]) * ent_ref[...])


@functools.partial(jax.jit, static_argnames=("bt", "bc", "interpret"))
def _bsp_forward(
    logp: jax.Array, W: jax.Array, rows: jax.Array, cols: jax.Array,
    valid: jax.Array, scalars: jax.Array, *,
    bt: int, bc: int, interpret: bool,
) -> jax.Array:
    B, C = logp.shape
    nt = -(-B // bt)
    pad_r, pad_c = nt * bt - B, (-C) % bc
    p = _pad2(jnp.exp(logp), pad_r, pad_c).astype(jnp.float32)
    logpp = _pad2(logp, pad_r, pad_c).astype(jnp.float32)
    Wp = _pad2(W, pad_r, pad_r).astype(jnp.float32)
    T = rows.shape[0]
    n_c = (C + pad_c) // bc
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, n_c),
        in_specs=[
            pl.BlockSpec((bt, bc), lambda t, c, rows, cols, valid:
                         (rows[t], c)),
            pl.BlockSpec((bt, bc), lambda t, c, rows, cols, valid:
                         (cols[t], c)),
            pl.BlockSpec((bt, bc), lambda t, c, rows, cols, valid:
                         (rows[t], c)),
            pl.BlockSpec((bt, bt), lambda t, c, rows, cols, valid:
                         (rows[t], cols[t])),
            pl.BlockSpec((1, 4), lambda t, c, rows, cols, valid: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda t, c, rows, cols, valid:
                               (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((bt, bt), jnp.float32),   # S tile accumulator
            pltpu.VMEM((bt, 1), jnp.float32),    # row degrees
            pltpu.VMEM((bt, 1), jnp.float32),    # row entropies
        ],
    )
    out = pl.pallas_call(
        functools.partial(_bsp_fwd_kernel, n_t=T, n_c=n_c),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(rows, cols, valid, p, logpp, logpp, Wp, scalars)
    return out[0, 0]


def graph_reg_blocksparse_pallas(
    logp: jax.Array, W: jax.Array,
    rows: jax.Array, cols: jax.Array, valid: jax.Array,
    gamma: float, kappa: float, *, ent_weight: float | None = None,
    bt: int | None = None, bc: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Block-sparse fused Eq.-3/4 regularizer over the active tiles only.

    ``rows``/``cols``/``valid`` are the row-major active-tile list of a
    ``BlockLayout`` built with the same ``bt``.  Semantically equal to the
    dense fused kernel whenever the layout's occupancy covers every
    nonzero of W (exact by ``tile_occupancy`` construction); bit-identical
    to it on a fully dense mask.
    """
    B, C = logp.shape
    bt, bc = _bsp_tiles(B, C, bt, bc)
    ge = gamma if ent_weight is None else ent_weight
    scalars = jnp.stack([gamma, kappa, ge, 0.0]).astype(
        jnp.float32).reshape(1, 4)
    return _bsp_forward(logp, W, rows, cols, valid, scalars,
                        bt=bt, bc=bc,
                        interpret=_default_interpret(interpret))


def _bsp_bterm_kernel(crows_ref, ccols_ref, cvalid_ref, w_ref, pj_ref,
                      out_ref, b_ref, *, n_t: int):
    t = pl.program_id(1)
    col = ccols_ref[t]
    first = (t == 0) | (ccols_ref[jnp.maximum(t - 1, 0)] != col)
    last = (t == n_t - 1) | (ccols_ref[jnp.minimum(t + 1, n_t - 1)] != col)
    live = cvalid_ref[t] == 1

    @pl.when(first)
    def _init():
        b_ref[...] = jnp.zeros_like(b_ref)

    @pl.when(live)
    def _acc():
        # B += W[j-blk, i-blk]ᵀ @ P[j-blk, c-blk] — same contraction (and
        # j-ascending order per output block) as the dense dlogp kernel.
        b_ref[...] += jax.lax.dot_general(
            w_ref[...], pj_ref[...],
            (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(last)
    def _write():
        out_ref[...] = b_ref[...]


def _bsp_dlogp_kernel(rows_ref, cols_ref, valid_ref, w_ref, logpj_ref,
                      pi_ref, logpi_ref, bterm_ref, s_ref, out_ref,
                      a_ref, deg_ref, *, n_t: int):
    t = pl.program_id(1)
    row = rows_ref[t]
    first = (t == 0) | (rows_ref[jnp.maximum(t - 1, 0)] != row)
    last = (t == n_t - 1) | (rows_ref[jnp.minimum(t + 1, n_t - 1)] != row)
    live = valid_ref[t] == 1

    @pl.when(first)
    def _init():
        a_ref[...] = jnp.zeros_like(a_ref)
        # deg is recomputed per class chunk (same adds, same j order as
        # the dense kernel's persisted scratch — bit-identical result).
        deg_ref[...] = jnp.zeros_like(deg_ref)

    @pl.when(live)
    def _acc():
        # A += W[i-blk, j-blk] @ logP[j-blk, c-blk]
        a_ref[...] += jnp.dot(w_ref[...], logpj_ref[...],
                              preferred_element_type=jnp.float32)
        deg_ref[...] += jnp.sum(w_ref[...], axis=1, keepdims=True)

    @pl.when(last)
    def _finish():
        g, gc, kappa, ge = (s_ref[0, 0], s_ref[0, 1],
                            s_ref[0, 2], s_ref[0, 3])
        p = pi_ref[...]
        coef = kappa + ge * deg_ref[...]
        out_ref[...] = g * (-gc * (p * a_ref[...] + bterm_ref[...])
                            + coef * p * (logpi_ref[...] + 1.0))


def _bsp_dw_kernel(occ_ref, pi_ref, logpj_ref, logpi_ref, s_ref, out_ref,
                   acc_ref, ent_ref, *, n_t: int, n_c: int):
    i, j, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    live = occ_ref[i * n_t + j] == 1

    @pl.when(c == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((j == 0) & (c == 0))
    def _init_ent():
        ent_ref[...] = jnp.zeros_like(ent_ref)

    @pl.when(live)
    def _acc():
        # The MXU contraction is the only per-tile cost that matters and
        # is skipped on unoccupied tiles; the (dense) dW output block is
        # still written every tile so every gradient entry is defined.
        acc_ref[...] += jax.lax.dot_general(
            pi_ref[...], logpj_ref[...],
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _entropy_chunk():
        ent_ref[...] += -jnp.sum(pi_ref[...] * logpi_ref[...], axis=1,
                                 keepdims=True)

    @pl.when(c == n_c - 1)
    def _finish():
        g, gc, ge = s_ref[0, 0], s_ref[0, 1], s_ref[0, 3]
        val = -g * (gc * acc_ref[...] + ge * ent_ref[...])
        out_ref[...] = jnp.where(live, val, jnp.zeros_like(val))


@functools.partial(jax.jit, static_argnames=("bt", "bc", "interpret"))
def _bsp_bwd(
    logp: jax.Array, W: jax.Array,
    rows: jax.Array, cols: jax.Array, valid: jax.Array,
    crows: jax.Array, ccols: jax.Array, cvalid: jax.Array,
    occ: jax.Array, scalars: jax.Array, *,
    bt: int, bc: int, interpret: bool,
) -> tuple[jax.Array, jax.Array]:
    B, C = logp.shape
    nt = occ.shape[0]
    _bsp_check_layout(B, bt, nt)
    pad_r, pad_c = nt * bt - B, (-C) % bc
    p = _pad2(jnp.exp(logp), pad_r, pad_c).astype(jnp.float32)
    logpp = _pad2(logp, pad_r, pad_c).astype(jnp.float32)
    Wp = _pad2(W, pad_r, pad_r).astype(jnp.float32)
    P, Cc = nt * bt, C + pad_c
    T = rows.shape[0]
    n_c = Cc // bc
    # Pass 1 — column-major sweep: bterm[i-blk, c-blk] = Σ_j Wᵀ·P.
    bterm = pl.pallas_call(
        functools.partial(_bsp_bterm_kernel, n_t=T),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_c, T),
            in_specs=[
                pl.BlockSpec((bt, bt), lambda c, t, cr, cc, cv:
                             (cr[t], cc[t])),
                pl.BlockSpec((bt, bc), lambda c, t, cr, cc, cv:
                             (cr[t], c)),
            ],
            out_specs=pl.BlockSpec((bt, bc), lambda c, t, cr, cc, cv:
                                   (cc[t], c)),
            scratch_shapes=[pltpu.VMEM((bt, bc), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((P, Cc), jnp.float32),
        interpret=interpret,
    )(crows, ccols, cvalid, Wp, p)
    # Pass 2 — row-major sweep folds A = W·logP, degrees and bterm into
    # the dlogp tiles.
    dlogp = pl.pallas_call(
        functools.partial(_bsp_dlogp_kernel, n_t=T),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(n_c, T),
            in_specs=[
                pl.BlockSpec((bt, bt), lambda c, t, rows, cols, valid:
                             (rows[t], cols[t])),
                pl.BlockSpec((bt, bc), lambda c, t, rows, cols, valid:
                             (cols[t], c)),
                pl.BlockSpec((bt, bc), lambda c, t, rows, cols, valid:
                             (rows[t], c)),
                pl.BlockSpec((bt, bc), lambda c, t, rows, cols, valid:
                             (rows[t], c)),
                pl.BlockSpec((bt, bc), lambda c, t, rows, cols, valid:
                             (rows[t], c)),
                pl.BlockSpec((1, 4), lambda c, t, rows, cols, valid:
                             (0, 0)),
            ],
            out_specs=pl.BlockSpec((bt, bc), lambda c, t, rows, cols, valid:
                                   (rows[t], c)),
            scratch_shapes=[
                pltpu.VMEM((bt, bc), jnp.float32),   # (W·logP) tile
                pltpu.VMEM((bt, 1), jnp.float32),    # row degrees
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((P, Cc), jnp.float32),
        interpret=interpret,
    )(rows, cols, valid, Wp, logpp, p, logpp, bterm, scalars)
    # dW — predicated-dense grid: MXU work only on occupied tiles, but
    # every (dense) output tile is written so the gradient is defined.
    dw = pl.pallas_call(
        functools.partial(_bsp_dw_kernel, n_t=nt, n_c=n_c),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nt, nt, n_c),
            in_specs=[
                pl.BlockSpec((bt, bc), lambda i, j, c, occf: (i, c)),
                pl.BlockSpec((bt, bc), lambda i, j, c, occf: (j, c)),
                pl.BlockSpec((bt, bc), lambda i, j, c, occf: (i, c)),
                pl.BlockSpec((1, 4), lambda i, j, c, occf: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bt, bt), lambda i, j, c, occf: (i, j)),
            scratch_shapes=[
                pltpu.VMEM((bt, bt), jnp.float32),   # S tile
                pltpu.VMEM((bt, 1), jnp.float32),    # row entropies
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((P, P), jnp.float32),
        interpret=interpret,
    )(occ.reshape(-1), p, logpp, logpp, scalars)
    if pad_r:
        dw = dw[:B, :B]
    if pad_c or pad_r:
        dlogp = dlogp[:B, :C]
    return dlogp, dw


def graph_reg_blocksparse_bwd_pallas(
    logp: jax.Array, W: jax.Array, g: jax.Array,
    rows: jax.Array, cols: jax.Array, valid: jax.Array,
    crows: jax.Array, ccols: jax.Array, cvalid: jax.Array,
    occ: jax.Array, *,
    gamma: float, kappa: float, ent_weight: float,
    bt: int | None = None, bc: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Block-sparse tiled analytic VJP: (dlogp, dW).

    Same scalar convention as ``graph_reg_bwd_pallas``; the index lists
    and occupancy mask come from the same ``BlockLayout`` as the forward.
    """
    B, C = logp.shape
    bt, bc = _bsp_tiles(B, C, bt, bc)
    scalars = jnp.stack(
        [jnp.asarray(g, jnp.float32), jnp.float32(gamma),
         jnp.float32(kappa), jnp.float32(ent_weight)]).reshape(1, 4)
    return _bsp_bwd(logp, W, rows, cols, valid, crows, ccols, cvalid, occ,
                    scalars, bt=bt, bc=bc,
                    interpret=_default_interpret(interpret))
