"""Fused graph-regularizer kernel (the paper's compute hot-spot, §1.1).

Computes the weighted pairwise cross-entropy contraction of Eq. 3/4:

    cross(P, logP, W) = Σ_ij W_ij · Hc(p_i, p_j) = −Σ_ij W_ij (P · logPᵀ)_ij

The paper's efficiency argument is exactly this: graph partitioning makes the
per-batch affinity block W dense, so the regularizer becomes a matrix-matrix
product instead of sparse gathers.  On TPU we tile it for the MXU:

  grid = (B/bi, B/bj, C/bc);  for each (i, j) output tile, the class
  dimension is accumulated over bc-sized chunks into a VMEM scratch tile
  (bi × bj, f32), and on the last chunk the tile is contracted with the
  W tile into a scalar accumulator.

All tile dims default to 128/512 — MXU-aligned (128 lanes) with the class
chunk kept wide to amortize the weight-stationary W tile.  VMEM working set:
bi·bc + bj·bc + bi·bj + bi·bj(scratch) floats ≈ 0.9 MB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BI = 128
DEFAULT_BJ = 128
DEFAULT_BC = 512


def _graph_reg_kernel(p_ref, logp_ref, w_ref, out_ref, acc_ref, *,
                      n_c_blocks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # S_tile += P_i(bi, bc) @ logP_j(bj, bc)^T   — MXU contraction.
    acc_ref[...] += jax.lax.dot_general(
        p_ref[...], logp_ref[...],
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0) & (ci == 0))
    def _init_out():
        out_ref[0, 0] = 0.0

    @pl.when(ci == n_c_blocks - 1)
    def _finish_tile():
        # cross = −Σ W ⊙ S  (accumulated over all (i, j) tiles).
        out_ref[0, 0] += -jnp.sum(w_ref[...] * acc_ref[...])


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bc", "interpret"))
def graph_reg_pairwise_pallas(
    logp: jax.Array, W: jax.Array, *,
    bi: int = DEFAULT_BI, bj: int = DEFAULT_BJ, bc: int = DEFAULT_BC,
    interpret: bool = True,
) -> jax.Array:
    """Σ_ij W_ij Hc(p_i, p_j) with p = exp(logp).  logp: (B, C); W: (B, B)."""
    B, C = logp.shape
    bi, bj, bc = min(bi, B), min(bj, B), min(bc, C)
    pad_i = (-B) % bi
    pad_j = (-B) % bj
    pad_c = (-C) % bc
    # Padding: logp rows padded with 0 (p=exp(0)=1 would corrupt → pad p with
    # 0 instead by padding logp with -inf surrogate handled via exp outside).
    p = jnp.exp(logp)
    if pad_i or pad_c:
        p = jnp.pad(p, ((0, pad_i), (0, pad_c)))             # p rows -> 0
        logp_p = jnp.pad(logp, ((0, pad_j), (0, pad_c)))     # logp·0 = 0
    else:
        logp_p = logp
    Wp = jnp.pad(W, ((0, pad_i), (0, pad_j))) if (pad_i or pad_j) else W
    Bi, Bj, Cc = p.shape[0], logp_p.shape[0], p.shape[1]
    grid = (Bi // bi, Bj // bj, Cc // bc)
    out = pl.pallas_call(
        functools.partial(_graph_reg_kernel, n_c_blocks=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bc), lambda i, j, c: (i, c)),
            pl.BlockSpec((bj, bc), lambda i, j, c: (j, c)),
            pl.BlockSpec((bi, bj), lambda i, j, c: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, c: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        # VMEM scratch accumulator for the S tile.
        scratch_shapes=[pltpu.VMEM((bi, bj), jnp.float32)],
        interpret=interpret,
    )(p.astype(jnp.float32), logp_p.astype(jnp.float32),
      Wp.astype(jnp.float32))
    return out[0, 0]
