"""Jit'd public wrappers around the Pallas kernels, with analytic VJPs.

``graph_reg_pairwise`` is a drop-in ``pairwise_impl`` for
``repro.core.ssl_loss.ssl_objective``: forward runs the fused Pallas kernel
(TPU; ``interpret=True`` on CPU), backward uses the closed form

    T(logp, W)          = −Σ_ij W_ij Σ_c exp(logp_ic)·logp_jc
    ∂T/∂logp            = −(P ⊙ (W·logP)) − Wᵀ·P
    ∂T/∂W               = −P·logPᵀ

(two matmuls — no need to rematerialize kernel internals).

Selection: ``use_pallas=None`` (default) picks Pallas on TPU backends and the
jnp oracle elsewhere; the env var ``REPRO_FORCE_PALLAS=1`` forces the kernel
(interpret mode) for validation runs.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from . import ref
from .graph_reg import graph_reg_pairwise_pallas
from .pairwise import rbf_affinity_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _want_pallas(use_pallas: bool | None) -> bool:
    if use_pallas is not None:
        return use_pallas
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return _on_tpu()


@jax.custom_vjp
def _graph_reg_fwd_primal(logp, W):
    return graph_reg_pairwise_pallas(logp, W, interpret=not _on_tpu())


def _graph_reg_vjp_fwd(logp, W):
    out = graph_reg_pairwise_pallas(logp, W, interpret=not _on_tpu())
    return out, (logp, W)


def _graph_reg_vjp_bwd(res, g):
    logp, W = res
    p = jnp.exp(logp)
    dlogp = -(p * (W @ logp) + W.T @ p) * g
    dW = -(p @ logp.T) * g
    return dlogp, dW


_graph_reg_fwd_primal.defvjp(_graph_reg_vjp_fwd, _graph_reg_vjp_bwd)


def graph_reg_pairwise(logp: jax.Array, W: jax.Array, *,
                       use_pallas: bool | None = None) -> jax.Array:
    """Fused Σ_ij W_ij·Hc(p_i,p_j); the PAIRWISE registry's ``"auto"`` entry."""
    if _want_pallas(use_pallas):
        return _graph_reg_fwd_primal(logp, W)
    return ref.graph_reg_pairwise_ref(logp, W)


def graph_reg_pairwise_pallas_vjp(logp: jax.Array, W: jax.Array) -> jax.Array:
    """The fused Pallas kernel with its analytic VJP, unconditionally
    (interpret mode off-TPU) — the PAIRWISE registry's ``"pallas"`` entry."""
    return _graph_reg_fwd_primal(logp, W)


def rbf_affinity(x: jax.Array, y: jax.Array, sigma, *,
                 use_pallas: bool | None = None) -> jax.Array:
    """Dense RBF affinity block (graph construction device path)."""
    if _want_pallas(use_pallas):
        return rbf_affinity_pallas(x, y, sigma, interpret=not _on_tpu())
    return ref.rbf_affinity_ref(x, y, sigma)
