"""Jit'd public wrappers around the Pallas kernels, with analytic VJPs.

The graph-regularizer entries are what the ``repro.api`` PAIRWISE registry
points at.  Two calling conventions share one kernel family:

  * cross-term only (historical PAIRWISE signature): ``fn(logp, W)`` returns
    ``Σ_ij W_ij·Hc(p_i, p_j)``;
  * full regularizer (``fn.full_regularizer`` is set): ``fn(logp, W, γ, κ)``
    returns the whole Eq.-3/4 penalty
    ``γ·Σ W_ij Hc(p_i,p_j) − Σ_i (κ + γ·Σ_j W_ij)·H(p_i)``
    from a *single* fused kernel sweep — ``repro.core.ssl_loss`` detects the
    marker and skips its separate degree/entropy passes.

Forward and backward both run tiled Pallas kernels (TPU compiled;
interpret mode elsewhere); the closed-form cotangents

    ∂L/∂logp = γ·[−(P ⊙ (W·logP) + Wᵀ·P)] + (κ + γ·deg) ⊙ P ⊙ (logP + 1)
    ∂L/∂W    = −γ·(P·logPᵀ + H(p)·1ᵀ)

are computed tile-by-tile, so no B×B intermediate is materialized outside
a kernel in either direction (the historical fallback re-built ``P·logPᵀ``
with full-size jnp matmuls).

Selection: ``"auto"`` picks the fused Pallas path on TPU backends and the
jnp oracle elsewhere; the env var ``REPRO_FORCE_PALLAS=1`` forces the
kernels (interpret mode) for validation runs.  γ and κ ride as *static*
(nondiff) arguments — they come from the frozen ``SSLHyper``/config, never
from a traced tensor.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from . import ref
from .graph_reg import (graph_reg_blocksparse_bwd_pallas,
                        graph_reg_blocksparse_pallas, graph_reg_bwd_pallas,
                        graph_reg_cross_pallas, graph_reg_fused_pallas)
from .pairwise import knn_topk_pallas, rbf_affinity_pallas
from .tuning import TileSpec

__all__ = [
    "graph_reg_pairwise",
    "graph_reg_pairwise_pallas_vjp",
    "graph_regularizer_fused",
    "graph_regularizer_blocksparse",
    "graph_regularizer_auto",
    "rbf_affinity",
    "knn_topk",
]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _want_pallas(use_pallas: bool | None) -> bool:
    if use_pallas is not None:
        return use_pallas
    if os.environ.get("REPRO_FORCE_PALLAS") == "1":
        return True
    return _on_tpu()


def _tile_kwargs(tiles: TileSpec | None) -> dict:
    return tiles.kwargs("bi", "bj", "bc") if tiles is not None else {}


# ---------------------------------------------------------------------------
# One custom_vjp covers the whole family: the scalar triple
# (gamma, kappa, ent_weight) selects cross-only (1, 0, 0) or the full
# regularizer (γ, κ, γ).  All three — plus the tile spec — are nondiff
# static arguments, so the VJP only produces (dlogp, dW).
# ---------------------------------------------------------------------------
@partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _reg_primal(logp, W, gamma, kappa, ent_weight, tiles):
    if ent_weight == 0.0 and kappa == 0.0 and gamma == 1.0:
        return graph_reg_cross_pallas(logp, W, **_tile_kwargs(tiles))
    return graph_reg_fused_pallas(logp, W, gamma, kappa,
                                  **_tile_kwargs(tiles))


def _reg_vjp_fwd(logp, W, gamma, kappa, ent_weight, tiles):
    return _reg_primal(logp, W, gamma, kappa, ent_weight, tiles), (logp, W)


def _reg_vjp_bwd(gamma, kappa, ent_weight, tiles, res, g):
    logp, W = res
    dlogp, dW = graph_reg_bwd_pallas(
        logp, W, g, gamma=gamma, kappa=kappa, ent_weight=ent_weight,
        **_tile_kwargs(tiles))
    return dlogp, dW


_reg_primal.defvjp(_reg_vjp_fwd, _reg_vjp_bwd)


def graph_reg_pairwise_pallas_vjp(
        logp: jax.Array, W: jax.Array, *,
        tiles: TileSpec | None = None) -> jax.Array:
    """Σ_ij W_ij·Hc(p_i,p_j) via the Pallas kernel with its tiled analytic
    VJP, unconditionally (interpret mode off-TPU) — the PAIRWISE registry's
    ``"pallas"`` entry."""
    return _reg_primal(logp, W, 1.0, 0.0, 0.0, tiles)


graph_reg_pairwise_pallas_vjp.accepts_tiles = True


def graph_reg_pairwise(logp: jax.Array, W: jax.Array, *,
                       use_pallas: bool | None = None,
                       tiles: TileSpec | None = None) -> jax.Array:
    """Cross term with backend auto-selection (Pallas on TPU, oracle off)."""
    if _want_pallas(use_pallas):
        return _reg_primal(logp, W, 1.0, 0.0, 0.0, tiles)
    return ref.graph_reg_pairwise_ref(logp, W)


graph_reg_pairwise.accepts_tiles = True


def graph_regularizer_fused(
        logp: jax.Array, W: jax.Array,
        gamma: float | None = None, kappa: float | None = None, *,
        tiles: TileSpec | None = None) -> jax.Array:
    """The single-pass fused regularizer kernel — the registry's ``"fused"``
    entry.  Called with (logp, W, γ, κ) it returns the *entire* Eq.-3/4
    penalty in one sweep; called with just (logp, W) it degrades to the
    bare cross term (PAIRWISE signature compatibility).

    γ/κ must be Python floats (they are static kernel parameters); pass
    hyper-parameters from ``SSLHyper``/``ObjectiveConfig``, not tracers.
    """
    if gamma is None:
        return _reg_primal(logp, W, 1.0, 0.0, 0.0, tiles)
    gamma, kappa = float(gamma), float(kappa or 0.0)
    return _reg_primal(logp, W, gamma, kappa, gamma, tiles)


graph_regularizer_fused.full_regularizer = True
graph_regularizer_fused.accepts_tiles = True


# ---------------------------------------------------------------------------
# Block-sparse path: same regularizer, compacted grid over active tiles.
# The BlockLayout index arrays are *traced* integer operands (their
# cotangents are None); only the scalar triple + tile spec stay nondiff
# static, exactly as in the dense custom_vjp above.
# ---------------------------------------------------------------------------
def _bsp_tile_kwargs(tiles: TileSpec | None) -> dict:
    if tiles is None:
        return {}
    out = {}
    if tiles.bi is not None:
        out["bt"] = tiles.bi
    if tiles.bc is not None:
        out["bc"] = tiles.bc
    return out


@partial(jax.custom_vjp, nondiff_argnums=(9, 10, 11, 12))
def _bsp_primal(logp, W, rows, cols, valid, crows, ccols, cvalid, occ,
                gamma, kappa, ent_weight, tiles):
    return graph_reg_blocksparse_pallas(
        logp, W, rows, cols, valid, gamma, kappa, ent_weight=ent_weight,
        **_bsp_tile_kwargs(tiles))


def _bsp_vjp_fwd(logp, W, rows, cols, valid, crows, ccols, cvalid, occ,
                 gamma, kappa, ent_weight, tiles):
    out = _bsp_primal(logp, W, rows, cols, valid, crows, ccols, cvalid,
                      occ, gamma, kappa, ent_weight, tiles)
    return out, (logp, W, rows, cols, valid, crows, ccols, cvalid, occ)


def _bsp_vjp_bwd(gamma, kappa, ent_weight, tiles, res, g):
    logp, W, rows, cols, valid, crows, ccols, cvalid, occ = res
    dlogp, dW = graph_reg_blocksparse_bwd_pallas(
        logp, W, g, rows, cols, valid, crows, ccols, cvalid, occ,
        gamma=gamma, kappa=kappa, ent_weight=ent_weight,
        **_bsp_tile_kwargs(tiles))
    return (dlogp, dW, None, None, None, None, None, None, None)


_bsp_primal.defvjp(_bsp_vjp_fwd, _bsp_vjp_bwd)


def _validate_layout(layout) -> None:
    """Run the W-pass tile-list checks on a concrete layout; raise on any
    violation.  Traced layouts (inside jit) are skipped — they have no
    values to check."""
    import numpy as np

    from repro.analysis.race_audit import check_layout, check_tile_list

    arrays = layout.arrays() if hasattr(layout, "arrays") else tuple(layout)
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return
    if hasattr(layout, "arrays"):
        findings = check_layout(layout, where="blocksparse.layout")
    else:
        rows, cols, valid, _, _, _, occ = (np.asarray(a) for a in arrays)
        findings = check_tile_list(rows, cols, valid, occ.shape[0],
                                   occ=occ, where="blocksparse.layout",
                                   name="tile_list")
    if findings:
        lines = "; ".join(f"[{f.rule}] {f.message}" for f in findings)
        raise ValueError(f"blocksparse layout failed W-pass audit: {lines}")


def graph_regularizer_blocksparse(
        logp: jax.Array, W: jax.Array,
        gamma: float | None = None, kappa: float | None = None, *,
        layout=None, tiles: TileSpec | None = None,
        validate: bool = False) -> jax.Array:
    """The ``"blocksparse"`` registry entry: tile-skipping fused Eq.-3/4
    regularizer driven by a ``repro.core.metabatch.BlockLayout``.

    ``layout`` is the layout's 7-array tuple ``(rows, cols, valid, crows,
    ccols, cvalid, occ)`` (``BlockLayout.arrays()``) — numpy or traced jnp
    arrays both work; they ride through the custom_vjp as nondifferentiated
    operands.  Without a layout the call degrades to the dense fused path,
    so the entry is safe to select unconditionally.

    ``validate=True`` runs the W-pass tile-list checker
    (:func:`repro.analysis.race_audit.check_layout`) on the layout before
    launching and raises ``ValueError`` on any W-rule violation —
    duplicate tiles double-count their contribution, out-of-order strips
    break the CSR prefetch walk.  Only concrete (host) layouts can be
    checked; traced layouts under jit are skipped silently, so validate
    at layout-construction time, outside the compiled path.
    """
    if layout is None:
        return graph_regularizer_fused(logp, W, gamma, kappa, tiles=tiles)
    if validate:
        _validate_layout(layout)
    if hasattr(layout, "arrays"):   # a BlockLayout instance
        layout = layout.arrays()
    rows, cols, valid, crows, ccols, cvalid, occ = layout
    if occ.shape[-1] == 1:
        # A 1×1 tile grid has no tiles to skip — the dense fused kernel is
        # the same work without the scalar-prefetch machinery.  (It also
        # sidesteps a compiler corner: on a single-step grid XLA contracts
        # the two final scalar accumulations differently across the two
        # kernel structures, costing 1 ulp of bit-equality.)
        bt = tiles.bi if tiles is not None else None
        bc = tiles.bc if tiles is not None else None
        dense_tiles = (TileSpec(bi=bt, bj=bt, bc=bc)
                       if (bt is not None or bc is not None) else None)
        return graph_regularizer_fused(logp, W, gamma, kappa,
                                       tiles=dense_tiles)
    if gamma is None:
        gamma, kappa, ent_weight = 1.0, 0.0, 0.0
    else:
        gamma, kappa = float(gamma), float(kappa or 0.0)
        ent_weight = gamma
    return _bsp_primal(logp, W, rows, cols, valid, crows, ccols, cvalid,
                       occ, gamma, kappa, ent_weight, tiles)


graph_regularizer_blocksparse.full_regularizer = True
graph_regularizer_blocksparse.accepts_tiles = True
graph_regularizer_blocksparse.accepts_layout = True


def graph_regularizer_auto(
        logp: jax.Array, W: jax.Array,
        gamma: float | None = None, kappa: float | None = None, *,
        use_pallas: bool | None = None,
        tiles: TileSpec | None = None, layout=None) -> jax.Array:
    """The ``"auto"`` registry entry: block-sparse Pallas kernels when the
    pipeline supplied a BlockLayout, the dense fused kernels otherwise —
    on TPU backends; the jnp oracle elsewhere (the layout's occupancy is
    exact, so the oracle over the full W computes the same value).  Same
    dual signature as ``graph_regularizer_fused``."""
    if _want_pallas(use_pallas):
        if layout is not None:
            return graph_regularizer_blocksparse(logp, W, gamma, kappa,
                                                 layout=layout, tiles=tiles)
        return graph_regularizer_fused(logp, W, gamma, kappa, tiles=tiles)
    if gamma is None:
        return ref.graph_reg_pairwise_ref(logp, W)
    return ref.graph_regularizer_ref(logp, W, gamma, kappa or 0.0)


graph_regularizer_auto.full_regularizer = True
graph_regularizer_auto.accepts_tiles = True
graph_regularizer_auto.accepts_layout = True


def rbf_affinity(x: jax.Array, y: jax.Array, sigma, *,
                 use_pallas: bool | None = None) -> jax.Array:
    """Dense RBF affinity block (graph construction device path)."""
    if _want_pallas(use_pallas):
        return rbf_affinity_pallas(x, y, sigma)   # interpret derived inside
    return ref.rbf_affinity_ref(x, y, sigma)


def knn_topk(x: jax.Array, y: jax.Array, k: int, *,
             exclude_self: bool = False,
             use_pallas: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """Per-row k smallest squared distances + indices (graph construction).

    Pallas path streams candidate columns and never materializes (N, M);
    the oracle fallback builds the dense matrix (fine for small corpora).
    """
    if _want_pallas(use_pallas):
        return knn_topk_pallas(x, y, k, exclude_self=exclude_self)
    return ref.knn_topk_ref(x, y, k, exclude_self=exclude_self)
