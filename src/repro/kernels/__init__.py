from .flash_attention import flash_attention_gqa_pallas
from .ops import (graph_reg_pairwise, graph_regularizer_auto,
                  graph_regularizer_fused, knn_topk, rbf_affinity)
from .tuning import TileSpec, select_tiles
