from .flash_attention import flash_attention_gqa_pallas
from .ops import graph_reg_pairwise, rbf_affinity
