"""Block-size selection for the Pallas kernels (shape + backend → tiles).

Every kernel in this package is tiled over a grid whose block sizes trade
VMEM working set against grid-step overhead.  The right tiles depend on the
problem shape *and* the backend: on TPU the MXU wants 128-lane-aligned
blocks and a wide accumulation chunk; in interpret mode (CPU validation)
fewer, fatter grid steps dominate wall time.

``DEFAULT_TILE_TABLE`` encodes the default rules as ordered
``(kernel, backend, max_rows, TileSpec)`` rows — first match wins, with
``backend=None`` / ``max_rows=None`` rows acting as wildcards.  Callers go
through :func:`select_tiles`, which also lets a config *pin* individual
dims (a pinned dim always wins over the table).

Tables are built through :func:`build_table`, which canonicalizes the row
order (backend-specific before wildcard, tighter ``max_rows`` bounds
first) and rejects duplicate match keys — so an unreachable (shadowed)
row is impossible by construction, not just flagged after the fact by the
V004 audit.  Measured tables from the ``benchmarks/bench_kernels.py
--autotune`` sweep are persisted with :func:`save_tile_table` (which
validates every row through the analysis V001–V004 checks at write time)
and activated via the ``REPRO_TUNED_TILES`` environment variable or an
explicit ``table=`` argument.

Tile dims (not every kernel uses all four):

  * ``bi`` — output/row block (rows of ``logp`` / ``x``); doubles as the
    square tile edge ``bt`` for the block-sparse regularizer;
  * ``bj`` — column block of the affinity matrix / candidate set;
  * ``bc`` — class-dimension accumulation chunk (graph regularizer);
  * ``bd`` — feature-dimension accumulation chunk (pairwise distances).
"""
from __future__ import annotations

import dataclasses
import json
import os

__all__ = ["TileSpec", "DEFAULT_TILE_TABLE", "select_tiles",
           "default_interpret", "build_table", "save_tile_table",
           "load_tile_table", "active_tile_table"]


def default_interpret(interpret: bool | None) -> bool:
    """The one backend→interpret policy: ``None`` means compiled on TPU,
    interpreter everywhere else (CPU validation containers)."""
    if interpret is None:
        import jax
        return jax.default_backend() != "tpu"
    return interpret

_DIMS = ("bi", "bj", "bc", "bd")


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """Block sizes for one kernel launch; ``None`` means "auto-select".

    Frozen + hashable so it can ride through ``jax.jit`` static arguments
    and ``custom_vjp`` nondiff arguments unchanged.
    """

    bi: int | None = None
    bj: int | None = None
    bc: int | None = None
    bd: int | None = None

    def __post_init__(self):
        for name in _DIMS:
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v <= 0):
                raise ValueError(
                    f"TileSpec.{name} must be a positive int or None, "
                    f"got {v!r}")

    def astuple(self) -> tuple[int | None, ...]:
        return (self.bi, self.bj, self.bc, self.bd)

    def merged_over(self, auto: "TileSpec") -> "TileSpec":
        """Overlay: this spec's pinned (non-None) dims win over ``auto``."""
        return TileSpec(*(p if p is not None else a
                          for p, a in zip(self.astuple(), auto.astuple())))

    def kwargs(self, *dims: str) -> dict[str, int]:
        """The non-None subset of ``dims`` as kernel keyword arguments."""
        out = {}
        for d in dims:
            v = getattr(self, d)
            if v is not None:
                out[d] = v
        return out


def build_table(rows) -> tuple[tuple[str, str | None, int | None, TileSpec],
                               ...]:
    """Canonicalize table rows so first-match-wins cannot shadow a row.

    Sort key per kernel: backend-specific rows before ``backend=None``
    wildcards, then ``max_rows`` ascending with ``None`` (any row count)
    last.  Under that order an earlier row never covers a later row's
    match set — the V004 "unreachable row" finding is impossible by
    construction.  Duplicate ``(kernel, backend, max_rows)`` keys raise.
    """
    rows = list(rows)
    for row in rows:
        kern, be, max_rows, tiles = row
        if not isinstance(kern, str) or not isinstance(tiles, TileSpec):
            raise ValueError(f"malformed tuning row {row!r}")
        if max_rows is not None and (not isinstance(max_rows, int)
                                     or max_rows <= 0):
            raise ValueError(f"max_rows must be a positive int or None in "
                             f"{row!r}")
    seen = set()
    for kern, be, max_rows, _ in rows:
        key = (kern, be, max_rows)
        if key in seen:
            raise ValueError(f"duplicate tuning row for {key}")
        seen.add(key)

    def order(row):
        kern, be, max_rows, _ = row
        return (kern, be is None, be or "",
                max_rows is None, max_rows or 0)

    return tuple(sorted(rows, key=order))


#: Ordered first-match-wins rules: (kernel, backend, max_rows, tiles).
#: ``backend=None`` matches any backend; ``max_rows=None`` any row count.
DEFAULT_TILE_TABLE: tuple[tuple[str, str | None, int | None, TileSpec], ...] = build_table((
    # Fused graph regularizer: (bi, bj) tiles of the B×B affinity block,
    # bc-wide class chunks accumulated into the VMEM S tile.
    ("graph_reg", "tpu", 512,  TileSpec(bi=128, bj=128, bc=256)),
    ("graph_reg", "tpu", 2048, TileSpec(bi=128, bj=128, bc=512)),
    ("graph_reg", "tpu", None, TileSpec(bi=256, bj=128, bc=512)),
    # Interpret/CPU validation: keep the MXU shape but the narrow chunk —
    # grid-step count dominates, not VMEM pressure.
    ("graph_reg", None,  None, TileSpec(bi=128, bj=128, bc=512)),
    # Block-sparse graph regularizer: square bt×bt tiles (bi doubles as
    # bt — it must match the BlockLayout the batch pipeline built).
    ("graph_reg_blocksparse", "tpu", None, TileSpec(bi=128, bc=512)),
    ("graph_reg_blocksparse", None,  None, TileSpec(bi=128, bc=512)),
    # Dense RBF affinity block.
    ("rbf", "tpu", 1024, TileSpec(bi=128, bj=128, bd=256)),
    ("rbf", "tpu", None, TileSpec(bi=256, bj=128, bd=256)),
    ("rbf", None,  None, TileSpec(bi=128, bj=128, bd=256)),
    # Streaming top-k: wide candidate-column sweeps amortize the per-chunk
    # top-k merge; the running (bi, k) state stays resident in VMEM.
    ("topk", "tpu", None, TileSpec(bi=128, bj=512, bd=256)),
    ("topk", None,  None, TileSpec(bi=128, bj=512, bd=256)),
))


def save_tile_table(path: str, rows, *, validate: bool = True) -> None:
    """Persist a measured tile table (JSON), validated at write time.

    ``rows`` is an iterable of ``(kernel, backend, max_rows, TileSpec)``.
    The table is canonicalized through :func:`build_table` and — unless
    ``validate=False`` — every row is checked against the static VMEM
    budget / alignment / index-map-bounds / reachability audits
    (V001–V004) before anything is written: a sweep can never persist a
    table the analysis gate would reject.
    """
    table = build_table(rows)
    if validate:
        from repro.analysis.vmem_audit import validate_tuning_table
        findings, _ = validate_tuning_table(table=table)
        errors = [f for f in findings if f.severity == "error"]
        if errors:
            lines = "; ".join(f"{f.rule}: {f.message}" for f in errors)
            raise ValueError(
                f"refusing to write tuning table with audit errors: {lines}")
    payload = {
        "format": 1,
        "rows": [
            {"kernel": kern, "backend": be, "max_rows": max_rows,
             "tiles": {d: v for d, v in zip(_DIMS, tiles.astuple())
                       if v is not None}}
            for kern, be, max_rows, tiles in table
        ],
    }
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
        fh.write("\n")


def load_tile_table(path: str) -> tuple:
    """Load a table written by :func:`save_tile_table` (canonical order)."""
    with open(path) as fh:
        payload = json.load(fh)
    if payload.get("format") != 1:
        raise ValueError(f"unknown tile-table format in {path!r}: "
                         f"{payload.get('format')!r}")
    return build_table(
        (r["kernel"], r["backend"], r["max_rows"], TileSpec(**r["tiles"]))
        for r in payload["rows"])


_TUNED_CACHE: dict = {"path": None, "table": None}


def active_tile_table() -> tuple:
    """The table :func:`select_tiles` consults by default.

    ``REPRO_TUNED_TILES=<path>`` prepends a measured table (written by the
    bench ``--autotune`` sweep) in front of the built-in defaults — tuned
    rows win for the shapes they cover, defaults backstop the rest.
    """
    path = os.environ.get("REPRO_TUNED_TILES")
    if not path:
        return DEFAULT_TILE_TABLE
    if _TUNED_CACHE["path"] != path:
        _TUNED_CACHE["path"] = path
        _TUNED_CACHE["table"] = load_tile_table(path) + DEFAULT_TILE_TABLE
    return _TUNED_CACHE["table"]


def select_tiles(
    kernel: str,
    *,
    rows: int,
    backend: str | None = None,
    pinned: TileSpec | None = None,
    table=None,
) -> TileSpec:
    """Pick block sizes for ``kernel`` at ``rows`` problem rows.

    ``backend=None`` reads ``jax.default_backend()``.  ``pinned`` dims (from
    an ``ExperimentConfig``) override whatever the table selects; unknown
    kernels fall back to the pinned values alone.  ``table=None`` consults
    :func:`active_tile_table` (tuned rows, then the defaults).
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    if table is None:
        table = active_tile_table()
    auto = TileSpec()
    for kern, be, max_rows, tiles in table:
        if kern != kernel:
            continue
        if be is not None and be != backend:
            continue
        if max_rows is not None and rows > max_rows:
            continue
        auto = tiles
        break
    return pinned.merged_over(auto) if pinned is not None else auto
