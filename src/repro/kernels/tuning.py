"""Block-size selection for the Pallas kernels (shape + backend → tiles).

Every kernel in this package is tiled over a grid whose block sizes trade
VMEM working set against grid-step overhead.  The right tiles depend on the
problem shape *and* the backend: on TPU the MXU wants 128-lane-aligned
blocks and a wide accumulation chunk; in interpret mode (CPU validation)
fewer, fatter grid steps dominate wall time.

``DEFAULT_TILE_TABLE`` encodes the hand-tuned defaults as ordered
``(kernel, backend, max_rows, TileSpec)`` rules — first match wins, with
``backend=None`` / ``max_rows=None`` rows acting as wildcards.  Callers go
through :func:`select_tiles`, which also lets a config *pin* individual
dims (a pinned dim always wins over the table).

Tile dims (not every kernel uses all four):

  * ``bi`` — output/row block (rows of ``logp`` / ``x``);
  * ``bj`` — column block of the affinity matrix / candidate set;
  * ``bc`` — class-dimension accumulation chunk (graph regularizer);
  * ``bd`` — feature-dimension accumulation chunk (pairwise distances).
"""
from __future__ import annotations

import dataclasses

__all__ = ["TileSpec", "DEFAULT_TILE_TABLE", "select_tiles",
           "default_interpret"]


def default_interpret(interpret: bool | None) -> bool:
    """The one backend→interpret policy: ``None`` means compiled on TPU,
    interpreter everywhere else (CPU validation containers)."""
    if interpret is None:
        import jax
        return jax.default_backend() != "tpu"
    return interpret

_DIMS = ("bi", "bj", "bc", "bd")


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """Block sizes for one kernel launch; ``None`` means "auto-select".

    Frozen + hashable so it can ride through ``jax.jit`` static arguments
    and ``custom_vjp`` nondiff arguments unchanged.
    """

    bi: int | None = None
    bj: int | None = None
    bc: int | None = None
    bd: int | None = None

    def __post_init__(self):
        for name in _DIMS:
            v = getattr(self, name)
            if v is not None and (not isinstance(v, int) or v <= 0):
                raise ValueError(
                    f"TileSpec.{name} must be a positive int or None, "
                    f"got {v!r}")

    def astuple(self) -> tuple[int | None, ...]:
        return (self.bi, self.bj, self.bc, self.bd)

    def merged_over(self, auto: "TileSpec") -> "TileSpec":
        """Overlay: this spec's pinned (non-None) dims win over ``auto``."""
        return TileSpec(*(p if p is not None else a
                          for p, a in zip(self.astuple(), auto.astuple())))

    def kwargs(self, *dims: str) -> dict[str, int]:
        """The non-None subset of ``dims`` as kernel keyword arguments."""
        out = {}
        for d in dims:
            v = getattr(self, d)
            if v is not None:
                out[d] = v
        return out


#: Ordered first-match-wins rules: (kernel, backend, max_rows, tiles).
#: ``backend=None`` matches any backend; ``max_rows=None`` any row count.
DEFAULT_TILE_TABLE: tuple[tuple[str, str | None, int | None, TileSpec], ...] = (
    # Fused graph regularizer: (bi, bj) tiles of the B×B affinity block,
    # bc-wide class chunks accumulated into the VMEM S tile.
    ("graph_reg", "tpu", 512,  TileSpec(bi=128, bj=128, bc=256)),
    ("graph_reg", "tpu", 2048, TileSpec(bi=128, bj=128, bc=512)),
    ("graph_reg", "tpu", None, TileSpec(bi=256, bj=128, bc=512)),
    # Interpret/CPU validation: keep the MXU shape but the narrow chunk —
    # grid-step count dominates, not VMEM pressure.
    ("graph_reg", None,  None, TileSpec(bi=128, bj=128, bc=512)),
    # Dense RBF affinity block.
    ("rbf", "tpu", 1024, TileSpec(bi=128, bj=128, bd=256)),
    ("rbf", "tpu", None, TileSpec(bi=256, bj=128, bd=256)),
    ("rbf", None,  None, TileSpec(bi=128, bj=128, bd=256)),
    # Streaming top-k: wide candidate-column sweeps amortize the per-chunk
    # top-k merge; the running (bi, k) state stays resident in VMEM.
    ("topk", "tpu", None, TileSpec(bi=128, bj=512, bd=256)),
    ("topk", None,  None, TileSpec(bi=128, bj=512, bd=256)),
)


def select_tiles(
    kernel: str,
    *,
    rows: int,
    backend: str | None = None,
    pinned: TileSpec | None = None,
    table=DEFAULT_TILE_TABLE,
) -> TileSpec:
    """Pick block sizes for ``kernel`` at ``rows`` problem rows.

    ``backend=None`` reads ``jax.default_backend()``.  ``pinned`` dims (from
    an ``ExperimentConfig``) override whatever the table selects; unknown
    kernels fall back to the pinned values alone.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    auto = TileSpec()
    for kern, be, max_rows, tiles in table:
        if kern != kernel:
            continue
        if be is not None and be != backend:
            continue
        if max_rows is not None and rows > max_rows:
            continue
        auto = tiles
        break
    return pinned.merged_over(auto) if pinned is not None else auto
