"""Pure-jnp oracles for every kernel in this package.

Each function is the semantic ground truth its Pallas twin is tested
against (tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def graph_reg_pairwise_ref(logp: jax.Array, W: jax.Array) -> jax.Array:
    """Σ_ij W_ij Hc(p_i, p_j) = −Σ W ⊙ (P·logPᵀ);  logp: (B, C), W: (B, B)."""
    p = jnp.exp(logp)
    return -jnp.sum(W * (p @ logp.T))


def graph_regularizer_ref(logp: jax.Array, W: jax.Array,
                          gamma: float, kappa: float) -> jax.Array:
    """Full Eq.-3/4 regularizer oracle (the fused kernel's ground truth):

        γ Σ_ij W_ij Hc(p_i,p_j) − Σ_i (κ + γ Σ_j W_ij) H(p_i)
    """
    p = jnp.exp(logp)
    cross = -jnp.sum(W * (p @ logp.T))
    deg = jnp.sum(W, axis=1)
    h = -jnp.sum(p * logp, axis=-1)
    return gamma * cross - jnp.sum((kappa + gamma * deg) * h)


def rbf_affinity_ref(x: jax.Array, y: jax.Array, sigma) -> jax.Array:
    """exp(−‖x_i − y_j‖ / 2σ²) dense block;  x: (N, D), y: (M, D)."""
    xx = jnp.sum(x.astype(jnp.float32) ** 2, 1)[:, None]
    yy = jnp.sum(y.astype(jnp.float32) ** 2, 1)[None, :]
    d2 = jnp.maximum(xx - 2.0 * x.astype(jnp.float32) @ y.astype(jnp.float32).T + yy, 0.0)
    return jnp.exp(-jnp.sqrt(d2) / (2.0 * jnp.float32(sigma) ** 2))


def knn_topk_ref(x: jax.Array, y: jax.Array, k: int, *,
                 exclude_self: bool = False) -> tuple[jax.Array, jax.Array]:
    """k smallest squared distances per row, via the dense (N, M) matrix.

    Returns ``(d2, idx)`` of shape (N, k), sorted ascending per row — the
    ground truth the streaming top-k kernel never materializes.
    """
    xx = jnp.sum(x.astype(jnp.float32) ** 2, 1)[:, None]
    yy = jnp.sum(y.astype(jnp.float32) ** 2, 1)[None, :]
    d2 = jnp.maximum(
        xx - 2.0 * x.astype(jnp.float32) @ y.astype(jnp.float32).T + yy, 0.0)
    if exclude_self:
        n = min(x.shape[0], y.shape[0])
        d2 = d2.at[jnp.arange(n), jnp.arange(n)].set(jnp.inf)
    neg, idx = jax.lax.top_k(-d2, k)
    return -neg, idx
