"""Pure-jnp oracles for every kernel in this package.

Each function is the semantic ground truth its Pallas twin is tested
against (tests/test_kernels.py sweeps shapes/dtypes with assert_allclose).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def graph_reg_pairwise_ref(logp: jax.Array, W: jax.Array) -> jax.Array:
    """Σ_ij W_ij Hc(p_i, p_j) = −Σ W ⊙ (P·logPᵀ);  logp: (B, C), W: (B, B)."""
    p = jnp.exp(logp)
    return -jnp.sum(W * (p @ logp.T))


def rbf_affinity_ref(x: jax.Array, y: jax.Array, sigma) -> jax.Array:
    """exp(−‖x_i − y_j‖ / 2σ²) dense block;  x: (N, D), y: (M, D)."""
    xx = jnp.sum(x.astype(jnp.float32) ** 2, 1)[:, None]
    yy = jnp.sum(y.astype(jnp.float32) ** 2, 1)[None, :]
    d2 = jnp.maximum(xx - 2.0 * x.astype(jnp.float32) @ y.astype(jnp.float32).T + yy, 0.0)
    return jnp.exp(-jnp.sqrt(d2) / (2.0 * jnp.float32(sigma) ** 2))
