"""Pallas flash-attention forward kernel (TPU deployment path).

The pure-jnp flash path (models/layers/attention.py) is the portable
implementation with a custom VJP; this kernel is its MXU-tiled twin for the
forward/serving hot-spot: one (q-block × kv-block) tile per grid step with
the online-softmax state held in VMEM scratch across the innermost kv axis.

  grid = (B·H, Tq/bq, Tk/bk)      (kv innermost → scratch accumulates)
  q tile (bq, hd), k/v tiles (bk, hd) in VMEM; causal masking from block
  indices via 2-D iota (positions are sequential by contract, as in the
  triangular-tiling jnp path).

Validated in interpret mode against ``reference_attention`` (tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 256


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      n_kv_blocks: int, causal: bool, bq: int, bk: int,
                      q_offset: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        qpos = q_offset + i * bq + jax.lax.broadcasted_iota(
            jnp.int32, (bq, bk), 0)
        kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n_kv_blocks - 1)
    def _emit():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_fwd_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                               causal: bool = True, bq: int = DEFAULT_BQ,
                               bk: int = DEFAULT_BK,
                               interpret: bool = True) -> jax.Array:
    """q: (BH, Tq, hd); k, v: (BH, Tk, hd) — heads folded into the batch.

    Sequential positions assumed (q row t has absolute position
    Tk − Tq + t); use the GQA wrapper below for (B, T, H, hd) layouts.
    """
    BH, Tq, hd = q.shape
    Tk = k.shape[1]
    scale = hd ** -0.5
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    pq, pk2 = (-Tq) % bq_, (-Tk) % bk_
    # Padded kv columns are only excluded by the causal mask (their absolute
    # positions exceed every real q position); non-causal calls must be
    # pre-padded by the caller.
    assert causal or pk2 == 0, "non-causal requires Tk % bk == 0"
    qp = jnp.pad(q * scale, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk2), (0, 0)))
    # Padded kv columns must never win the softmax: push their keys to 0 and
    # mask via the causal iota (padded q rows are sliced off afterwards);
    # for non-causal, mask by padding k with a large negative last feature…
    # simplest robust choice: pad v with zeros and rely on explicit masking:
    vp = jnp.pad(v, ((0, 0), (0, pk2), (0, 0)))
    grid = (BH, (Tq + pq) // bq_, (Tk + pk2) // bk_)
    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, n_kv_blocks=grid[2],
                          causal=causal, bq=bq_, bk=bk_,
                          q_offset=Tk - Tq),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk_, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk_, hd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, hd), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Tq]


def flash_attention_gqa_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                               causal: bool = True, bq: int = DEFAULT_BQ,
                               bk: int = DEFAULT_BK,
                               interpret: bool = True) -> jax.Array:
    """GQA wrapper. q: (B, T, H, hd); k, v: (B, Tk, KV, hd) -> (B, T, H, hd).

    Note: valid for Tq == Tk (train/prefill) with sequential positions;
    padded-kv correctness relies on causal masking, so require causal=True
    when Tk % bk != 0."""
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, hd)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Tk, hd)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Tk, hd)
    of = flash_attention_fwd_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                    interpret=interpret)
    return of.reshape(B, H, Tq, hd).transpose(0, 2, 1, 3)
