"""Training-batch pipeline: meta-batches -> device-ready arrays.

Each step yields the concatenated batch ``M_c = [M_r, M_s]`` of §2.3:
features, labels, label mask, and the dense affinity sub-block ``W`` for the
concatenated index set.  For ``k``-worker data parallelism, each step packs
``k`` independent concatenated batches along a leading axis — the launcher
shards that axis over the mesh's data dimension, which *is* the paper's
parallel decomposition.

Batches are padded to a fixed size (2B) so shapes are static under jit;
padding rows carry zero affinity and zero label mask.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.core.affinity import AffinityGraph
from repro.core.metabatch import MetaBatchPlan, NeighborSampler
from repro.data.synthetic_timit import SyntheticCorpus

__all__ = ["SSLBatch", "MetaBatchPipeline", "random_batch_pipeline",
           "make_meta_batch_pipeline", "make_graph_batch_pipeline",
           "make_random_batch_pipeline"]


@dataclasses.dataclass(frozen=True)
class SSLBatch:
    x: np.ndarray            # (k, P, d)    P = padded concat-batch size
    y: np.ndarray            # (k, P)
    label_mask: np.ndarray   # (k, P) float {0,1}
    W: np.ndarray            # (k, P, P) dense affinity block
    valid: np.ndarray        # (k, P) bool (padding indicator)


def _pad_to(a: np.ndarray, size: int, axis: int = 0) -> np.ndarray:
    pad = size - a.shape[axis]
    if pad <= 0:
        return a[(slice(None),) * axis + (slice(0, size),)]
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


class MetaBatchPipeline:
    """Iterates (meta-batch, sampled-neighbour) pairs for k workers."""

    def __init__(self, corpus: SyntheticCorpus, graph: AffinityGraph,
                 plan: MetaBatchPlan, *, n_workers: int = 1,
                 pad_factor: float = 2.4, with_neighbor: bool = True,
                 seed: int = 0):
        self.corpus = corpus
        self.graph = graph
        self.plan = plan
        self.k = n_workers
        self.with_neighbor = with_neighbor
        self.sampler = NeighborSampler(plan.batch_edges, seed=seed)
        self.rng = np.random.default_rng(seed + 1)
        # Static padded size: max meta-batch + max neighbour, rounded up.
        mmax = max(len(m) for m in plan.meta_batches)
        self.pad = int(np.ceil(
            (2 * mmax if with_neighbor else mmax) / 64) * 64)

    def _one(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        j = self.sampler.sample(i) if self.with_neighbor else None
        main = self.plan.meta_batches[i]
        idx = (main if j is None
               else np.concatenate([main, self.plan.meta_batches[j]]))
        return idx, main

    def epoch(self) -> Iterator[SSLBatch]:
        """One pass over all meta-batches, k at a time."""
        order = self.rng.permutation(self.plan.n_meta)
        for s in range(0, len(order) - self.k + 1, self.k):
            group = order[s : s + self.k]
            xs, ys, ms, Ws, vs = [], [], [], [], []
            for i in group:
                idx, _ = self._one(int(i))
                P = self.pad
                x = _pad_to(self.corpus.X[idx], P)
                y = _pad_to(self.corpus.y[idx], P)
                lm = _pad_to(
                    self.corpus.label_mask[idx].astype(np.float32), P)
                W = _pad_to(_pad_to(self.graph.dense_block(idx), P, 0), P, 1)
                v = _pad_to(np.ones(len(idx), bool), P)
                xs.append(x); ys.append(y); ms.append(lm); Ws.append(W); vs.append(v)
            yield SSLBatch(x=np.stack(xs), y=np.stack(ys),
                           label_mask=np.stack(ms), W=np.stack(Ws),
                           valid=np.stack(vs))


# ---------------------------------------------------------------------------
# PIPELINE-registry factories.  Uniform signature
#   (corpus, graph, plan, *, batch_size, n_workers, seed, ...) -> epoch_fn
# so the experiment layer can swap batching strategies by config name.
# ---------------------------------------------------------------------------
def make_meta_batch_pipeline(corpus, graph, plan, *, n_workers: int = 1,
                             seed: int = 0, with_neighbor: bool = True,
                             pad_factor: float = 2.4, **_):
    """The paper's method (§2): meta-batches + Eq.-6 sampled neighbours."""
    return MetaBatchPipeline(corpus, graph, plan, n_workers=n_workers,
                             pad_factor=pad_factor,
                             with_neighbor=with_neighbor, seed=seed).epoch


def make_graph_batch_pipeline(corpus, graph, plan, *, n_workers: int = 1,
                              seed: int = 0, pad_factor: float = 2.4, **_):
    """Pure graph-partitioned batches — the §2 low-entropy baseline.

    Pair with a plan built with ``shuffle_blocks=False`` so each batch is a
    run of consecutive (homogeneous) mini-blocks.
    """
    return MetaBatchPipeline(corpus, graph, plan, n_workers=n_workers,
                             pad_factor=pad_factor, with_neighbor=False,
                             seed=seed).epoch


def make_random_batch_pipeline(corpus, graph, plan=None, *,
                               batch_size: int | None = None,
                               n_workers: int = 1, seed: int = 0,
                               steps_per_epoch: int | None = None, **_):
    """Randomly shuffled batches (Fig. 1a regime) as an epoch factory.

    ``plan`` is optional (no partitioning needed); when present it pins the
    batch size and epoch length to the meta-batch pipeline's for apples-to-
    apples ablations.
    """
    bs = batch_size or (plan.batch_size if plan is not None else 512)
    if corpus.n < bs * n_workers:
        raise ValueError(
            f"random_batch pipeline needs n >= batch_size * n_workers "
            f"({corpus.n} < {bs} * {n_workers}); shrink the batch or the "
            "worker count")
    if steps_per_epoch is None:
        steps_per_epoch = (plan.n_meta // n_workers if plan is not None
                           else max(1, corpus.n // (bs * n_workers)))
    it = random_batch_pipeline(corpus, graph, bs, n_workers=n_workers,
                               seed=seed)

    def epoch():
        return (next(it) for _ in range(steps_per_epoch))

    return epoch


def random_batch_pipeline(corpus: SyntheticCorpus, graph: AffinityGraph,
                          batch_size: int, *, n_workers: int = 1,
                          seed: int = 0) -> Iterator[SSLBatch]:
    """Baseline: randomly shuffled batches (paper's Fig. 1a regime) — the
    affinity block is still looked up, but is near-empty by construction."""
    rng = np.random.default_rng(seed)
    n = corpus.n
    if n < batch_size * n_workers:
        # The per-epoch loop below would never yield — fail loudly instead
        # of spinning through permutations forever.
        raise ValueError(
            f"corpus too small for the requested batches: "
            f"n={n} < batch_size*n_workers={batch_size * n_workers}")
    P = int(np.ceil(batch_size / 64) * 64)
    while True:
        perm = rng.permutation(n)
        for s in range(0, n - batch_size * n_workers + 1,
                       batch_size * n_workers):
            xs, ys, ms, Ws, vs = [], [], [], [], []
            for w in range(n_workers):
                idx = perm[s + w * batch_size : s + (w + 1) * batch_size]
                xs.append(_pad_to(corpus.X[idx], P))
                ys.append(_pad_to(corpus.y[idx], P))
                ms.append(_pad_to(corpus.label_mask[idx].astype(np.float32), P))
                Ws.append(_pad_to(_pad_to(graph.dense_block(idx), P, 0), P, 1))
                vs.append(_pad_to(np.ones(len(idx), bool), P))
            yield SSLBatch(x=np.stack(xs), y=np.stack(ys),
                           label_mask=np.stack(ms), W=np.stack(Ws),
                           valid=np.stack(vs))
