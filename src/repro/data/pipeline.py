"""Training-batch pipeline: meta-batches -> device-ready arrays.

Each step yields the concatenated batch ``M_c = [M_r, M_s]`` of §2.3:
features, labels, label mask, and the dense affinity sub-block ``W`` for the
concatenated index set.  For ``k``-worker data parallelism, each step packs
``k`` independent concatenated batches along a leading axis — the launcher
shards that axis over the mesh's data dimension, which *is* the paper's
Eq.-7 parallel decomposition.

Batches are padded to a fixed size (2B) so shapes are static under jit;
padding rows carry zero affinity and zero label mask.

Two meta-batch pipelines share the assembly code:

  * :class:`MetaBatchPipeline` — the static plan, fixed for the whole run;
  * :class:`MetaBatchStream`  — the streaming stage ("metabatch_stream" in
    the PIPELINE registry): between epochs a background thread re-runs the
    §2 synthesis (partition → mini-blocks → meta-batches → batch graph)
    with a fresh epoch seed and Gumbel-perturbed matching, and the new plan
    is swapped in at the epoch boundary — host-side only, no device sync —
    so batch composition stays stochastic across epochs as the paper's
    SGD argument requires.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Iterator

import numpy as np

from repro.core.affinity import AffinityGraph
from repro.core.metabatch import (MetaBatchPlan, NeighborSampler,
                                  block_layout, epoch_plan_seed,
                                  plan_layout_budget, resynthesize_plan)
from repro.core.partition import HierarchyCache
from repro.core.partition import partition_graph as partition_graph_default
from repro.data.synthetic_timit import SyntheticCorpus
from repro.introspect import accepts_kwarg

__all__ = ["SSLBatch", "MetaBatchPipeline", "MetaBatchStream",
           "random_batch_pipeline", "make_meta_batch_pipeline",
           "make_graph_batch_pipeline", "make_random_batch_pipeline",
           "make_metabatch_stream_pipeline"]


@dataclasses.dataclass(frozen=True)
class SSLBatch:
    x: np.ndarray            # (k, P, d)    P = padded concat-batch size
    y: np.ndarray            # (k, P)
    label_mask: np.ndarray   # (k, P) float {0,1}
    W: np.ndarray            # (k, P, P) dense affinity block
    valid: np.ndarray        # (k, P) bool (padding indicator)
    # Optional block-sparse layout of W (``BlockLayout.arrays()`` per
    # worker, stacked along k) — present only when the pipeline was built
    # with ``layout_bt``; ``None`` fields are dropped before the batch
    # reaches a device (``engine._as_host_dict``).
    tile_rows: np.ndarray | None = None    # (k, T) int32, row-major list
    tile_cols: np.ndarray | None = None    # (k, T) int32
    tile_valid: np.ndarray | None = None   # (k, T) int32 {0,1}
    tile_crows: np.ndarray | None = None   # (k, T) int32, col-major list
    tile_ccols: np.ndarray | None = None   # (k, T) int32
    tile_cvalid: np.ndarray | None = None  # (k, T) int32 {0,1}
    tile_occ: np.ndarray | None = None     # (k, nt, nt) int32 occupancy


def _pad_to(a: np.ndarray, size: int, axis: int = 0) -> np.ndarray:
    pad = size - a.shape[axis]
    if pad <= 0:
        return a[(slice(None),) * axis + (slice(0, size),)]
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return np.pad(a, widths)


def _assemble(corpus: SyntheticCorpus, graph: AffinityGraph,
              idx: np.ndarray, P: int, *, layout_bt: int | None = None,
              layout_len: int | None = None):
    """Padded (x, y, label_mask, W, valid) arrays for one concat batch.

    With ``layout_bt`` the tuple is extended by the 7 ``BlockLayout``
    arrays of the padded W (``layout_len`` pins the static tile-list
    length so every batch of the run shares one jitted shape).  This runs
    on the pipeline/prefetch producer thread — zero per-step layout work
    on the training path.
    """
    W = _pad_to(_pad_to(graph.dense_block(idx), P, 0), P, 1)
    base = (_pad_to(corpus.X[idx], P),
            _pad_to(corpus.y[idx], P),
            _pad_to(corpus.label_mask[idx].astype(np.float32), P),
            W,
            _pad_to(np.ones(len(idx), bool), P))
    if layout_bt is None:
        return base
    return base + block_layout(W, layout_bt, list_len=layout_len).arrays()


def _stack_group(parts) -> SSLBatch:
    cols = [np.stack(c) for c in zip(*parts)]
    return SSLBatch(*cols)   # 5 base columns, +7 tile columns with a layout


def _epoch_groups(order: np.ndarray, k: int) -> Iterator[np.ndarray]:
    """Consecutive groups of ``k`` meta-batch ids covering *all* of ``order``.

    A tail of ``len(order) % k`` ids is padded by wrap-around from the head
    of the permutation (those head ids train twice that epoch) — never
    silently dropped: the order is permuted per epoch, so dropping the tail
    would starve a random node subset of gradient every epoch.  With fewer
    than ``k`` ids no group is yielded (wrap-around there would duplicate a
    meta-batch *within* one group; the engine already warns on an empty
    epoch).
    """
    n = len(order)
    for s in range(0, n - k + 1, k):
        yield order[s : s + k]
    tail = n % k
    if tail and n >= k:
        yield np.concatenate([order[n - tail:], order[: k - tail]])


class MetaBatchPipeline:
    """Iterates (meta-batch, sampled-neighbour) pairs for k workers."""

    def __init__(self, corpus: SyntheticCorpus, graph: AffinityGraph,
                 plan: MetaBatchPlan, *, n_workers: int = 1,
                 pad_factor: float = 2.4, with_neighbor: bool = True,
                 seed: int = 0, layout_bt: int | None = None):
        self.corpus = corpus
        self.graph = graph
        self.plan = plan
        self.k = n_workers
        self.with_neighbor = with_neighbor
        self.sampler = NeighborSampler(plan.batch_edges, seed=seed)
        self.rng = np.random.default_rng(seed + 1)
        # Static padded size: max meta-batch + max neighbour, rounded up.
        mmax = max(len(m) for m in plan.meta_batches)
        self.pad = int(np.ceil(
            (2 * mmax if with_neighbor else mmax) / 64) * 64)
        # Static plan => the exact tile-list budget is known up front (no
        # headroom needed: the plan never changes).
        self.layout_bt = layout_bt
        self.layout_len = (None if layout_bt is None else plan_layout_budget(
            plan, graph, layout_bt, self.pad, with_neighbor=with_neighbor,
            headroom=1.0))

    def _one(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        j = self.sampler.sample(i) if self.with_neighbor else None
        main = self.plan.meta_batches[i]
        idx = (main if j is None
               else np.concatenate([main, self.plan.meta_batches[j]]))
        return idx, main

    def epoch(self) -> Iterator[SSLBatch]:
        """One pass over all meta-batches, k at a time (tail wrap-padded)."""
        order = self.rng.permutation(self.plan.n_meta)
        for group in _epoch_groups(order, self.k):
            parts = []
            for i in group:
                idx, _ = self._one(int(i))
                parts.append(_assemble(self.corpus, self.graph, idx,
                                       self.pad, layout_bt=self.layout_bt,
                                       layout_len=self.layout_len))
            yield _stack_group(parts)


class MetaBatchStream:
    """First-class streaming meta-batch stage with stochastic
    re-partitioning (PIPELINE registry name ``"metabatch_stream"``).

    Per epoch it yields the same Eq.-6/§2.3 (meta-batch, sampled-neighbour)
    concat batches as :class:`MetaBatchPipeline`, k workers wide (the Eq.-7
    decomposition lives on the leading axis).  With an active
    ``repartition`` config, while epoch ``e`` trains, a background thread
    re-synthesizes the plan for the next re-partition epoch — vectorized
    partition with ``matching_temperature``-perturbed coarsening, fresh
    mini-block grouping, fresh batch graph — and the swap happens at the
    epoch boundary on the host: the engine's prefetch iterator simply reads
    the new plan, no device sync, no shape change (the pad is pinned with
    ``pad_headroom`` so jitted shapes survive every swap; a plan that would
    not fit is rejected with a warning and the previous plan is kept).
    With ``repartition.reuse_hierarchy`` (the default) the partitioner's
    coarsening hierarchy is cached across epochs (``HierarchyCache``) and
    each replan runs incrementally — top-level Gumbel redraw + perturbed
    cached labels + delta-seeded refinement — instead of from scratch.
    A replan that raises warns with the exception type and text and keeps
    the previous plan; a later successful swap re-arms the retry for
    previously failed targets.  With a ``supervisor`` each synthesis gets
    bounded retries with backoff first, and ``max_replan_failures``
    consecutive failed targets disable background replans for the rest of
    the run (one final warning, plan static) instead of spinning a thread
    and repeating the same warning every retry window.

    Determinism: the plan for epoch ``e`` is a pure function of
    ``(graph, config, repartition.seed, e)`` and the per-epoch batch order
    and neighbour draws derive from ``(seed, e)``, so identical seeds are
    bit-reproducible — run to run, with or without the background thread.

    Thread-safety: each epoch's generator body runs on whatever thread
    consumes it (under the engine that is the *prefetch producer* thread,
    a different one every epoch), while the replan builder runs on its own
    thread.  All mutable stream state — ``plan``, ``graph``, ``corpus``,
    ``_hierarchy``, ``_pending``, ``_plan_epoch``, ``swaps``, ``_failed``,
    ``_epoch_counter``, ``last_epoch_indices`` — is therefore published
    under ``_lock``; the builder thread snapshots the swappable
    graph/hierarchy under the lock at synthesis start (batch size and class
    count are construction-time immutables).

    Online refresh / dynamic corpora: :meth:`swap_graph` lock-publishes a
    whole new ``(graph, plan[, corpus][, hierarchy])`` tuple through the
    same path replans use — the epoch that starts next reads the new graph
    and plan together (``repro.online`` drives this from the engine's
    epoch-end hook).
    """

    def __init__(self, corpus: SyntheticCorpus, graph: AffinityGraph,
                 plan: MetaBatchPlan, *, n_workers: int = 1,
                 with_neighbor: bool = True, seed: int = 0,
                 repartition=None, partitioner=None, tol: float = 0.15,
                 coarsen_to: int = 60, shuffle_blocks: bool = True,
                 pad_headroom: float = 1.25, record_indices: bool = False,
                 hierarchy_cache: HierarchyCache | None = None,
                 supervisor=None, fault_injector=None,
                 max_replan_failures: int = 3,
                 layout_bt: int | None = None):
        self.corpus = corpus
        self.graph = graph
        self.plan = plan
        self.k = n_workers
        self.with_neighbor = with_neighbor
        self.seed = seed
        self.repartition = repartition
        self.partitioner = partitioner
        # Resilience collaborators (construction-time immutables): the
        # supervisor retries/backs off each synthesis attempt, the fault
        # injector arms deterministic replan failures for chaos tests, and
        # ``max_replan_failures`` consecutive failed *targets* disable
        # background re-partitioning entirely (one final warning) so a
        # persistently broken partitioner stops spinning a thread — and
        # emitting an identical warning — every retry window.
        self.supervisor = supervisor
        self.fault_injector = fault_injector
        self.max_replan_failures = int(max_replan_failures)
        self.tol = tol
        self.coarsen_to = coarsen_to
        self.shuffle_blocks = shuffle_blocks
        self.record_indices = record_indices
        self.last_epoch_indices: list[list[np.ndarray]] | None = None
        self.swaps = 0                     # plans swapped in so far
        every = getattr(repartition, "every_n_epochs", 0) if repartition \
            else 0
        self.every = int(every)
        self._hierarchy: HierarchyCache | None = None
        if self.every > 0:
            # Fail at construction, not as a once-per-epoch warning from
            # the background thread: an incapable partitioner would leave
            # the plan silently static forever.
            temp = getattr(repartition, "matching_temperature", 0.0)
            if temp != 0.0 and not accepts_kwarg(
                    partitioner or partition_graph_default, "temperature"):
                raise ValueError(
                    f"repartition.matching_temperature={temp} but the "
                    f"configured partitioner does not accept temperature=; "
                    f"use the vectorized 'multilevel' partitioner or set "
                    f"matching_temperature=0")
            if getattr(repartition, "reuse_hierarchy", True):
                # Hierarchy-cached incremental replans (the default).  The
                # cache is a pure function of (graph, partition config,
                # repartition seed) — never of the epoch — so plans stay
                # bit-reproducible per (seed, epoch) regardless of when it
                # is first (lazily) built.  A partitioner without reuse=
                # support degrades to from-scratch replans with a warning,
                # not an error: reuse is an optimization, not semantics.
                if accepts_kwarg(partitioner or partition_graph_default,
                                 "reuse"):
                    self._hierarchy = hierarchy_cache or HierarchyCache(
                        graph.W, tol=tol, coarsen_to=coarsen_to,
                        seed=int(getattr(repartition, "seed", 0)))
                else:
                    warnings.warn(
                        "repartition.reuse_hierarchy=True but the "
                        "configured partitioner does not accept reuse=; "
                        "replans will run from scratch", stacklevel=2)
        mmax = max(len(m) for m in plan.meta_batches)
        base = 2 * mmax if with_neighbor else mmax
        headroom = pad_headroom if self.every > 0 else 1.0
        self.pad = int(np.ceil(base * headroom / 64) * 64)
        # Tile-list budget pinned like the pad: with re-partitioning on,
        # ``pad_headroom`` also buys slack for denser re-planned layouts;
        # ``_fits`` rejects a plan that would overflow either pin.
        self.layout_bt = layout_bt
        self.layout_len = (None if layout_bt is None else plan_layout_budget(
            plan, graph, layout_bt, self.pad, with_neighbor=with_neighbor,
            headroom=headroom))
        # Snapshots for the builder thread: replans preserve batch size and
        # class count, so the thread never reads the swappable ``plan``.
        self._batch_size = plan.batch_size
        self._n_classes = plan.n_classes
        self._lock = threading.Lock()
        self._epoch_counter = 0
        self._plan_epoch = 0               # epoch the current plan targets
        self._failed: set[int] = set()     # targets that failed to swap
        self._pending: tuple[int, threading.Thread, dict] | None = None
        self._consec_failures = 0          # distinct targets failed in a row
        self._replan_disabled = False      # tripped at max_replan_failures

    # ------------------------------------------------------------ internals
    def _fits(self, plan: MetaBatchPlan, graph: AffinityGraph) -> bool:
        mmax = max(len(m) for m in plan.meta_batches)
        if (2 * mmax if self.with_neighbor else mmax) > self.pad:
            return False
        if self.layout_bt is not None:
            need = plan_layout_budget(
                plan, graph, self.layout_bt, self.pad,
                with_neighbor=self.with_neighbor, headroom=1.0)
            if need > self.layout_len:
                return False
        return True

    def _synthesize(self, epoch: int) -> MetaBatchPlan:
        # Runs on the builder thread: snapshots the swappable
        # graph/hierarchy under the lock, then synthesizes lock-free (it
        # never reads the swappable ``plan`` — batch size and class count
        # are construction-time immutables).
        if self.fault_injector is not None:
            self.fault_injector.maybe_fail("replan", epoch=epoch)
        with self._lock:
            graph, hierarchy = self.graph, self._hierarchy
        rep = self.repartition
        return resynthesize_plan(
            graph, self._batch_size, self._n_classes,
            epoch=epoch, base_seed=getattr(rep, "seed", 0),
            temperature=getattr(rep, "matching_temperature", 0.0),
            tol=self.tol, shuffle_blocks=self.shuffle_blocks,
            partitioner=self.partitioner, coarsen_to=self.coarsen_to,
            reuse=hierarchy)

    def _call_synthesize(self, epoch: int) -> MetaBatchPlan:
        """One supervised synthesis: with a supervisor, transient failures
        are retried with backoff before the degrade path ever fires."""
        if self.supervisor is not None:
            return self.supervisor.call(self._synthesize, epoch,
                                        key=f"replan@{epoch}")
        return self._synthesize(epoch)

    def _note_failure(self, target: int, err: BaseException, *,
                      stacklevel: int) -> None:
        """Degrade: keep the previous plan, count the failure, and trip the
        disable switch after ``max_replan_failures`` consecutive ones."""
        with self._lock:
            self._failed.add(target)
            self._consec_failures += 1
            n = self._consec_failures
            tripped = (self.max_replan_failures > 0
                       and n >= self.max_replan_failures
                       and not self._replan_disabled)
            if tripped:
                self._replan_disabled = True
        warnings.warn(
            f"re-partitioning for epoch {target} failed with "
            f"{type(err).__name__}: {err}; keeping the previous plan "
            f"(consecutive failure {n})", stacklevel=stacklevel + 1)
        if tripped:
            warnings.warn(
                f"{n} consecutive re-partitioning failures: disabling "
                "background replans for the rest of the run (the current "
                "plan stays static); fix the partitioner and restart to "
                "re-enable", stacklevel=stacklevel + 1)

    def _launch(self, target_epoch: int) -> None:
        box: dict = {}

        def work():
            try:
                box["plan"] = self._call_synthesize(target_epoch)
            except BaseException as e:  # noqa: BLE001 — surfaced at swap
                box["error"] = e

        t = threading.Thread(target=work, daemon=True,
                             name="metabatch-repartition")
        t.start()
        # Lock-publish the handoff: the epoch that collects this pending
        # tuple runs on a *different* prefetch-producer thread, so the
        # write must be visible there (the join in ``_collect`` then
        # orders the builder's box contents).
        with self._lock:
            self._pending = (target_epoch, t, box)

    def _next_target(self, epoch: int) -> int:
        """First re-partition epoch strictly after ``epoch``."""
        return (epoch // self.every + 1) * self.every

    def _swap_in(self, plan: MetaBatchPlan, target: int) -> bool:
        with self._lock:
            graph = self.graph
        if not self._fits(plan, graph):
            warnings.warn(
                f"re-partitioned plan for epoch {target} exceeds the "
                f"pinned pad {self.pad} or tile-list budget "
                f"{self.layout_len} (raise pad_headroom — "
                f"BatchConfig.pad_headroom in the config API); keeping the "
                "previous plan", stacklevel=4)
            return False
        with self._lock:
            self.plan = plan
            self._plan_epoch = target
            self.swaps += 1
            # A successful swap re-arms the retry for previously-failed
            # targets: a transient failure (OOM on the background thread, a
            # flaky data mount) must not pin those epochs to the stale plan
            # forever once the stream has proven healthy again.  It also
            # resets the consecutive-failure count feeding the disable
            # threshold — only an *unbroken* run of failures disables.
            self._failed.clear()
            self._consec_failures = 0
        return True

    def _collect(self, epoch: int) -> None:
        """Swap in the background plan scheduled for ``epoch``, if any."""
        with self._lock:
            pending = self._pending
            if pending is None or pending[0] != epoch:
                return
            self._pending = None
        _, t, box = pending
        t.join()   # happens-before: orders the builder's writes to box
        if "error" in box:
            self._note_failure(epoch, box["error"], stacklevel=3)
            return
        if not self._swap_in(box["plan"], epoch):
            with self._lock:
                self._failed.add(epoch)

    # ------------------------------------------------------------- online
    def snapshot(self) -> tuple:
        """One-lock read of the swappable state the online manager needs:
        ``(plan, graph, corpus, hierarchy, last_epoch_indices)``."""
        with self._lock:
            return (self.plan, self.graph, self.corpus, self._hierarchy,
                    self.last_epoch_indices)

    def swap_graph(self, graph: AffinityGraph, plan: MetaBatchPlan, *,
                   corpus: SyntheticCorpus | None = None,
                   hierarchy: HierarchyCache | None = None) -> bool:
        """Lock-publish a new affinity graph (and plan built against it).

        The online-refresh / insert / evict handoff, sharing the replan
        swap discipline: the epoch that starts next reads the new
        ``(graph, plan, corpus)`` together, mid-epoch generators keep their
        snapshots, and a plan that would overflow the pinned pad/tile-list
        budget is rejected with a warning (``False``; the stream keeps the
        old graph).  ``corpus`` rides along for dynamic ingestion (insert/
        evict change the node set).  ``hierarchy`` replaces the replan
        cache — pass a fresh (lazily built) :class:`HierarchyCache` for the
        new graph, or ``None`` to drop caching until the next refresh; the
        old cache's levels describe the old topology and must not survive.
        Any in-flight background replan is discarded: it was synthesized
        against the graph this call replaces.
        """
        if not self._fits(plan, graph):
            warnings.warn(
                f"online graph swap rejected: plan exceeds the pinned pad "
                f"{self.pad} or tile-list budget {self.layout_len} (raise "
                f"pad_headroom); keeping the previous graph", stacklevel=2)
            return False
        with self._lock:
            self.graph = graph
            self.plan = plan
            if corpus is not None:
                self.corpus = corpus
            self._hierarchy = hierarchy
            self._pending = None
            self.swaps += 1
            self._failed.clear()
            self._consec_failures = 0
        return True

    # ----------------------------------------------------------------- epoch
    def epoch(self, epoch: int | None = None,
              n_epochs: int | None = None) -> Iterator[SSLBatch]:
        """One pass over the *current* plan's meta-batches, k at a time.

        Epoch-pure: ``epoch`` pins the epoch index (the engine passes it)
        and any epoch's batches are reproducible from that index alone —
        jumping straight to epoch ``e`` (checkpoint resume) synthesizes the
        plan the uninterrupted run would have been using.  When omitted, an
        internal counter advances by one per call.  ``n_epochs`` bounds the
        run so no background plan is computed past the final epoch.
        """
        with self._lock:
            e = self._epoch_counter if epoch is None else int(epoch)
            self._epoch_counter = e + 1
        if self.every > 0:
            self._collect(e)
            target = (e // self.every) * self.every
            with self._lock:
                need_sync = (target > 0 and self._plan_epoch != target
                             and target not in self._failed
                             and not self._replan_disabled)
                if need_sync:
                    self._pending = None
            if need_sync:
                # Jumped over the swap epoch (resume, or out-of-order
                # call): synthesize the plan epoch ``e`` should be using,
                # synchronously.
                try:
                    plan = self._call_synthesize(target)
                except Exception as err:  # noqa: BLE001 — degrade like bg
                    self._note_failure(target, err, stacklevel=2)
                else:
                    if not self._swap_in(plan, target):
                        with self._lock:
                            self._failed.add(target)
            nxt = self._next_target(e)
            with self._lock:
                may_launch = (self._pending is None
                              and not self._replan_disabled
                              and (n_epochs is None or nxt < n_epochs))
            # Epochs are consumed one at a time, so only this generator
            # launches — the lock above is for visibility, not exclusion.
            if may_launch:
                self._launch(nxt)
        with self._lock:
            # One snapshot for the whole epoch: plan, graph and corpus swap
            # together (replans and online refreshes), never mid-epoch.
            plan, graph, corpus = self.plan, self.graph, self.corpus
        sampler = NeighborSampler(
            plan.batch_edges, seed=epoch_plan_seed(self.seed + 1, e))
        order_rng = np.random.default_rng([self.seed, 2, e])
        order = order_rng.permutation(plan.n_meta)
        recorded: list[list[np.ndarray]] = []
        for group in _epoch_groups(order, self.k):
            parts, idxs = [], []
            for i in group:
                j = sampler.sample(int(i)) if self.with_neighbor else None
                main = plan.meta_batches[int(i)]
                idx = (main if j is None else np.concatenate(
                    [main, plan.meta_batches[j]]))
                idxs.append(idx)
                parts.append(_assemble(corpus, graph, idx,
                                       self.pad, layout_bt=self.layout_bt,
                                       layout_len=self.layout_len))
            if self.record_indices:
                recorded.append(idxs)
            yield _stack_group(parts)
        if self.record_indices:
            with self._lock:
                self.last_epoch_indices = recorded


# ---------------------------------------------------------------------------
# PIPELINE-registry factories.  Uniform signature
#   (corpus, graph, plan, *, batch_size, n_workers, seed, ...) -> epoch_fn
# so the experiment layer can swap batching strategies by config name.
# ---------------------------------------------------------------------------
def make_meta_batch_pipeline(corpus, graph, plan, *, n_workers: int = 1,
                             seed: int = 0, with_neighbor: bool = True,
                             pad_factor: float = 2.4,
                             layout_bt: int | None = None, **_):
    """The paper's method (§2): meta-batches + Eq.-6 sampled neighbours."""
    return MetaBatchPipeline(corpus, graph, plan, n_workers=n_workers,
                             pad_factor=pad_factor,
                             with_neighbor=with_neighbor, seed=seed,
                             layout_bt=layout_bt).epoch


def make_graph_batch_pipeline(corpus, graph, plan, *, n_workers: int = 1,
                              seed: int = 0, pad_factor: float = 2.4,
                              layout_bt: int | None = None, **_):
    """Pure graph-partitioned batches — the §2 low-entropy baseline.

    Pair with a plan built with ``shuffle_blocks=False`` so each batch is a
    run of consecutive (homogeneous) mini-blocks.
    """
    return MetaBatchPipeline(corpus, graph, plan, n_workers=n_workers,
                             pad_factor=pad_factor, with_neighbor=False,
                             seed=seed, layout_bt=layout_bt).epoch


def make_metabatch_stream_pipeline(corpus, graph, plan, *,
                                   n_workers: int = 1, seed: int = 0,
                                   with_neighbor: bool = True,
                                   repartition=None, partitioner=None,
                                   tol: float = 0.15, coarsen_to: int = 60,
                                   shuffle_blocks: bool = True,
                                   pad_headroom: float = 1.25,
                                   record_indices: bool = False,
                                   hierarchy_cache=None, supervisor=None,
                                   fault_injector=None,
                                   max_replan_failures: int = 3,
                                   layout_bt: int | None = None, **_):
    """The §2 stream as a first-class pipeline: NeighborSampler + meta-batch
    assembly feeding the engine directly, with optional between-epoch
    stochastic re-partitioning (``repartition`` = a ``RepartitionConfig``-
    shaped object: every_n_epochs / matching_temperature / seed).

    The returned epoch factory accepts optional ``epoch=`` / ``n_epochs=``
    keywords — the engine passes the true epoch index (so re-partition
    scheduling stays exact across checkpoint resume, with no replay drain)
    and the horizon (so no plan is pre-computed past the final epoch) —
    and exposes the underlying :class:`MetaBatchStream` as ``.stream``
    (tests, introspection).
    """
    stream = MetaBatchStream(
        corpus, graph, plan, n_workers=n_workers, seed=seed,
        with_neighbor=with_neighbor, repartition=repartition,
        partitioner=partitioner, tol=tol, coarsen_to=coarsen_to,
        shuffle_blocks=shuffle_blocks, pad_headroom=pad_headroom,
        record_indices=record_indices, hierarchy_cache=hierarchy_cache,
        supervisor=supervisor, fault_injector=fault_injector,
        max_replan_failures=max_replan_failures, layout_bt=layout_bt)

    def epoch_fn(epoch: int | None = None, n_epochs: int | None = None):
        return stream.epoch(epoch=epoch, n_epochs=n_epochs)

    epoch_fn.stream = stream
    return epoch_fn


def make_random_batch_pipeline(corpus, graph, plan=None, *,
                               batch_size: int | None = None,
                               n_workers: int = 1, seed: int = 0,
                               steps_per_epoch: int | None = None, **_):
    """Randomly shuffled batches (Fig. 1a regime) as an epoch factory.

    ``plan`` is optional (no partitioning needed); when present it pins the
    batch size and epoch length to the meta-batch pipeline's for apples-to-
    apples ablations.
    """
    bs = batch_size or (plan.batch_size if plan is not None else 512)
    if corpus.n < bs * n_workers:
        raise ValueError(
            f"random_batch pipeline needs n >= batch_size * n_workers "
            f"({corpus.n} < {bs} * {n_workers}); shrink the batch or the "
            "worker count")
    if steps_per_epoch is None:
        steps_per_epoch = (plan.n_meta // n_workers if plan is not None
                           else max(1, corpus.n // (bs * n_workers)))
    it = random_batch_pipeline(corpus, graph, bs, n_workers=n_workers,
                               seed=seed)

    def epoch():
        return (next(it) for _ in range(steps_per_epoch))

    return epoch


def random_batch_pipeline(corpus: SyntheticCorpus, graph: AffinityGraph,
                          batch_size: int, *, n_workers: int = 1,
                          seed: int = 0) -> Iterator[SSLBatch]:
    """Baseline: randomly shuffled batches (paper's Fig. 1a regime) — the
    affinity block is still looked up, but is near-empty by construction."""
    rng = np.random.default_rng(seed)
    n = corpus.n
    if n < batch_size * n_workers:
        # The per-epoch loop below would never yield — fail loudly instead
        # of spinning through permutations forever.
        raise ValueError(
            f"corpus too small for the requested batches: "
            f"n={n} < batch_size*n_workers={batch_size * n_workers}")
    P = int(np.ceil(batch_size / 64) * 64)
    while True:
        perm = rng.permutation(n)
        for s in range(0, n - batch_size * n_workers + 1,
                       batch_size * n_workers):
            xs, ys, ms, Ws, vs = [], [], [], [], []
            for w in range(n_workers):
                idx = perm[s + w * batch_size : s + (w + 1) * batch_size]
                xs.append(_pad_to(corpus.X[idx], P))
                ys.append(_pad_to(corpus.y[idx], P))
                ms.append(_pad_to(corpus.label_mask[idx].astype(np.float32), P))
                Ws.append(_pad_to(_pad_to(graph.dense_block(idx), P, 0), P, 1))
                vs.append(_pad_to(np.ones(len(idx), bool), P))
            yield SSLBatch(x=np.stack(xs), y=np.stack(ys),
                           label_mask=np.stack(ms), W=np.stack(Ws),
                           valid=np.stack(vs))
