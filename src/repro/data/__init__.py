from .pipeline import (MetaBatchPipeline, MetaBatchStream, SSLBatch,
                       random_batch_pipeline)
from .synthetic_timit import SyntheticCorpus, drop_labels, make_corpus
from .tokens import lm_batches, make_token_corpus, sequence_features
