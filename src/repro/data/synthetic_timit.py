"""Synthetic TIMIT-like corpus (DESIGN.md §1.6).

TIMIT itself is license-gated, so we generate a corpus with the same
statistical skeleton the paper's method relies on: 351-d frame vectors
(cepstral-coefficient stand-ins) lying near a low-dimensional manifold where
class identity is locally smooth — exactly the manifold assumption that makes
graph-based SSL work.  Frames are drawn from per-class Gaussian mixtures in a
``manifold_dim``-dimensional latent space, embedded into 351-d by a random
linear map plus noise; 39 phone classes by default.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticCorpus", "make_corpus", "drop_labels"]


@dataclasses.dataclass(frozen=True)
class SyntheticCorpus:
    X: np.ndarray            # (n, input_dim) float32
    y: np.ndarray            # (n,) int labels (ground truth, all points)
    label_mask: np.ndarray   # (n,) bool — True where the label is visible
    n_classes: int

    @property
    def n(self) -> int:
        return self.X.shape[0]

    def label_ratio(self) -> float:
        return float(self.label_mask.mean())


def make_corpus(
    n: int = 20_000,
    *,
    n_classes: int = 39,
    input_dim: int = 351,
    manifold_dim: int = 12,
    structure: str = "filaments",   # "filaments" | "blobs"
    modes_per_class: int = 3,
    class_sep: float = 2.0,
    noise: float = 0.25,
    ambient_noise: float = 0.35,
    seed: int = 0,
) -> SyntheticCorpus:
    """``filaments``: each class is a smooth random 1-D curve in the latent
    space (random Fourier series).  This is the regime where graph-based SSL
    matters: a classifier trained on a handful of labels sees only a short
    arc of each filament, while the k-NN graph connects the whole curve —
    label propagation along the graph beats local generalization.  ``blobs``
    (per-class Gaussian mixtures) is kept as an easy control where plain
    supervised training already generalizes.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=n)
    if structure == "filaments":
        K = 4  # Fourier components per class curve
        coef = rng.normal(size=(n_classes, K, manifold_dim)) * 2.0
        phase = rng.uniform(0, 2 * np.pi, size=(n_classes, K))
        freq = rng.uniform(0.5, 2.0, size=(n_classes, K))
        offset = rng.normal(size=(n_classes, manifold_dim)) * class_sep
        t = rng.uniform(0, 2 * np.pi, n)
        z = offset[y] + np.einsum(
            "nk,nkd->nd", np.sin(freq[y] * t[:, None] + phase[y]), coef[y])
        z += rng.normal(size=z.shape) * noise
    elif structure == "blobs":
        centers = rng.normal(size=(n_classes, modes_per_class, manifold_dim))
        centers *= class_sep * 2.0
        mode = rng.integers(0, modes_per_class, size=n)
        z = centers[y, mode] + rng.normal(size=(n, manifold_dim)) * noise
    else:
        raise ValueError(structure)
    # Embed into the ambient (cepstral) space with observation noise.
    A = rng.normal(size=(manifold_dim, input_dim)) / np.sqrt(manifold_dim)
    X = z @ A + rng.normal(size=(n, input_dim)) * ambient_noise
    X = (X - X.mean(0)) / (X.std(0) + 1e-8)
    return SyntheticCorpus(
        X=X.astype(np.float32), y=y.astype(np.int64),
        label_mask=np.ones(n, bool), n_classes=n_classes)


def drop_labels(corpus: SyntheticCorpus, ratio: float, *,
                seed: int = 0) -> SyntheticCorpus:
    """Keep a ``ratio`` fraction of labels (paper §3: 2%..100%), at least one
    per class so the supervised term never starves a class entirely."""
    rng = np.random.default_rng(seed)
    n = corpus.n
    mask = rng.random(n) < ratio
    for c in range(corpus.n_classes):
        cls = np.where(corpus.y == c)[0]
        if len(cls) and not mask[cls].any():
            mask[rng.choice(cls)] = True
    return dataclasses.replace(corpus, label_mask=mask)
