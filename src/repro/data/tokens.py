"""Synthetic LM token pipeline for the assigned architectures.

Generates Zipf-distributed token streams with a latent "topic" per sequence
(so sequence-level affinity graphs — the SSL integration of DESIGN.md §3 —
carry signal: sequences of the same topic are k-NN neighbours in
bag-of-tokens space), plus next-token training batches.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_token_corpus", "lm_batches", "sequence_features"]


def make_token_corpus(n_seqs: int, seq_len: int, vocab: int, *,
                      n_topics: int = 8, seed: int = 0):
    """Returns (tokens (n, T) int32, topic (n,) int)."""
    rng = np.random.default_rng(seed)
    base = 1.0 / np.arange(1, vocab + 1) ** 1.1          # Zipf backbone
    topics = rng.integers(0, n_topics, n_seqs)
    # Each topic boosts a random subset of the vocab.
    boost = np.ones((n_topics, vocab))
    for t in range(n_topics):
        idx = rng.choice(vocab, size=max(vocab // 20, 1), replace=False)
        boost[t, idx] *= 40.0
    toks = np.empty((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        p = base * boost[topics[i]]
        p /= p.sum()
        toks[i] = rng.choice(vocab, size=seq_len, p=p)
    return toks, topics


def sequence_features(tokens: np.ndarray, vocab: int, *,
                      dim: int = 64, seed: int = 0) -> np.ndarray:
    """Bag-of-tokens features projected to ``dim`` — affinity-graph inputs."""
    rng = np.random.default_rng(seed)
    proj = rng.normal(size=(vocab, dim)) / np.sqrt(dim)
    n, T = tokens.shape
    feats = np.zeros((n, dim))
    for i in range(n):
        counts = np.bincount(tokens[i], minlength=vocab)
        feats[i] = counts @ proj / T
    return feats.astype(np.float32)


def lm_batches(tokens: np.ndarray, batch_size: int, *, seed: int = 0):
    """Yield (inputs, targets) next-token batches forever."""
    rng = np.random.default_rng(seed)
    n = tokens.shape[0]
    while True:
        order = rng.permutation(n)
        for s in range(0, n - batch_size + 1, batch_size):
            b = tokens[order[s : s + batch_size]]
            yield b[:, :-1], b[:, 1:]
