from .decode import generate, sample_tokens, serve_step
