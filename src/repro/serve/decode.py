"""Serving loop: batched autoregressive decode with per-layer caches.

``serve_step`` is the unit the decode-shape dry-runs lower: ONE new token for
every sequence in the batch against a KV cache of ``seq_len`` (full cache,
ring buffer for sliding-window layers, O(1) state for SSM/xLSTM layers).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig

Array = jax.Array


def sample_tokens(logits: Array, key, *, temperature: float = 0.0,
                  top_k: int = 0) -> Array:
    """logits: (B, 1, V) -> (B, 1) token ids."""
    lg = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
    lg = lg / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lg, top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg)[:, None].astype(jnp.int32)


def serve_step(params: dict, cfg: ModelConfig, cache: Any, tokens: Array,
               pos: Array, key, *, temperature: float = 0.0,
               act_sharding=None) -> tuple[Array, Any]:
    """One decode step: (B,1) token in -> (B,1) token out + updated cache."""
    logits, cache = tf.decode_step(params, cfg, cache, tokens, pos,
                                   act_sharding=act_sharding)
    next_tok = sample_tokens(logits, key, temperature=temperature)
    return next_tok, cache


def generate(params: dict, cfg: ModelConfig, prompt: Array, *, steps: int,
             cache_len: int, temperature: float = 0.0, seed: int = 0) -> Array:
    """Greedy/sampled generation: prefill via repeated decode (simple path).

    Prefill is pure cache building — the prompt's next tokens are known, so
    no sampling (and no RNG) happens there.  The decode loop then splits a
    fresh subkey per step, which makes the sampled continuation's key
    stream a function of ``seed`` alone, independent of prompt length.

    This key discipline is an audited contract: the ``serve_decode_generate``
    entry in the AUDIT registry traces this function and the R-pass
    (``repro.analysis.rng_audit``) proves no key is consumed twice and no
    split's entropy is drawn and discarded — the exact bug class of the old
    prefill loop, which reused the unsplit key across prefill steps.
    """
    B, Tp = prompt.shape
    cache = tf.init_cache(cfg, B, cache_len)
    key = jax.random.PRNGKey(seed)

    prefill = jax.jit(lambda c, t, p: tf.decode_step(params, cfg, c, t, p)[1])
    step = jax.jit(lambda c, t, p, k: serve_step(
        params, cfg, c, t, p, k, temperature=temperature))

    toks = prompt
    # Feed the prompt token by token (teacher-forced prefill).
    for t in range(Tp - 1):
        cache = prefill(cache, toks[:, t : t + 1],
                        jnp.full((B,), t, jnp.int32))
    cur = toks[:, -1:]
    outs = [toks]
    for t in range(steps):
        key, sub = jax.random.split(key)
        cur, cache = step(cache, cur, jnp.full((B,), Tp - 1 + t, jnp.int32),
                          sub)
        outs.append(cur)
    return jnp.concatenate(outs, axis=1)
