"""Phi-4-mini — dense GQA with RoPE + SwiGLU [arXiv:2412.08905]."""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
    vocab_size=200064, rope_theta=1e4,
    block_pattern=(ATTN,), activation="swiglu", norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2412.08905",
)
