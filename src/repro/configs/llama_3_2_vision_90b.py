"""Llama-3.2-Vision-90B — dense decoder with gated cross-attention image
layers every 5th layer [hf:meta-llama/Llama-3.2-11B-Vision].

The ViT vision encoder + projector is the stubbed frontend (DESIGN.md §3):
input_specs() supplies (B, 1601, 1280) patch embeddings; the in-model
projector maps them to d_model for the cross-attention KV."""
from repro.models.config import ATTN, XATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, rope_theta=5e5,
    block_pattern=(ATTN, ATTN, ATTN, ATTN, XATTN),
    activation="swiglu", norm="rmsnorm",
    modality_tokens=1601, modality_dim=1280,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
