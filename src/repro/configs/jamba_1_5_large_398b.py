"""Jamba-1.5-Large — hybrid Mamba+attention (1:7) with MoE 16e top-2 on
alternate layers [arXiv:2403.19887]. Attention layers use a sliding-window
ring cache in long-context decode; Mamba layers carry O(1) state."""
from repro.models.config import ATTN, MAMBA, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab_size=65536,
    block_pattern=(MAMBA, MAMBA, MAMBA, MAMBA, ATTN, MAMBA, MAMBA, MAMBA),
    n_experts=16, top_k=2, moe_d_ff=24576, moe_every=2,
    moe_dispatch_groups=64,   # grouped dispatch (§Perf)
    mamba_d_state=16, mamba_d_conv=4, mamba_expand=2,
    activation="swiglu", norm="rmsnorm",
    source="arXiv:2403.19887",
)
