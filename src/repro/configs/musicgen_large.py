"""MusicGen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. The EnCodec codec (mel + conv encoder/decoder) is the
stubbed modality frontend: input_specs() supplies token ids from its 2048-entry
codebook directly (DESIGN.md §3)."""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=2048,
    block_pattern=(ATTN,), activation="gelu", norm="layernorm",
    source="arXiv:2306.05284",
)
