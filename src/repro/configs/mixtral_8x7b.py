"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from repro.models.config import ATTN_SWA, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, rope_theta=1e6, sliding_window=4096,
    block_pattern=(ATTN_SWA,),
    n_experts=8, top_k=2, moe_d_ff=14336, moe_every=1,
    moe_dispatch_groups=64,   # grouped dispatch (§Perf iter 2: no cross-shard cumsum)
    activation="swiglu", norm="rmsnorm",
    source="arXiv:2401.04088",
)
