"""Assigned input shapes and shape→config adaptation rules."""
from __future__ import annotations

import dataclasses

from repro.models.config import ATTN, ATTN_SWA, ModelConfig

LONG_CONTEXT_WINDOW = 8192  # sliding window used for long_500k on dense archs


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def config_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt an architecture config to an input shape.

    For ``long_500k`` every full-attention (ATTN) layer becomes sliding-window
    (ATTN_SWA, window 8192) — the sub-quadratic variant required by the brief;
    SSM/linear layers and natively-windowed archs are unchanged.  This keeps
    the decode state bounded (ring KV of `window` slots instead of 524k).
    """
    if shape.name != "long_500k":
        return cfg
    pattern = tuple(ATTN_SWA if k == ATTN else k for k in cfg.block_pattern)
    if pattern == cfg.block_pattern and cfg.sliding_window is not None:
        return cfg
    window = cfg.sliding_window or LONG_CONTEXT_WINDOW
    return dataclasses.replace(cfg, block_pattern=pattern,
                               sliding_window=window)
