"""xLSTM-125M — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0 per assignment: xLSTM blocks carry their own up/down projections
(GeGLU inside the sLSTM block, pre-up-projection inside the mLSTM block)."""
from repro.models.config import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304,
    block_pattern=(SLSTM, MLSTM),
    activation="gelu", norm="layernorm",
    source="arXiv:2405.04517",
)
