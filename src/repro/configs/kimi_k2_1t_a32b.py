"""Kimi K2 — trillion-parameter MoE, 384 experts top-8, dense first layer
[arXiv:2501.kimi2 (paper-table)]. GQA per assignment (kv=8)."""
from repro.models.config import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, rope_theta=5e4,
    block_pattern=(ATTN,), first_layer_dense=True,
    n_experts=384, top_k=8, moe_d_ff=2048, moe_every=1,
    moe_dispatch_groups=64,   # grouped dispatch (§Perf: -40% collective, -35% memory)
    activation="swiglu", norm="rmsnorm",
    source="arXiv:2501.kimi2",
)
