"""Architecture config registry: ``get_config(arch_id)`` / ``--arch <id>``.

Perf experiments can override any ModelConfig field without code edits via
``REPRO_CFG_OVERRIDES='{"moe_dispatch_groups": 64, "remat_policy": "dots"}'``
(applied to every config this process loads — used by the §Perf hillclimb).
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os

from repro.models.config import ModelConfig

from .shapes import INPUT_SHAPES, InputShape, config_for_shape  # noqa: F401

_ARCH_MODULES = {
    "qwen2-1.5b": "qwen2_1_5b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "xlstm-125m": "xlstm_125m",
    "musicgen-large": "musicgen_large",
    "yi-9b": "yi_9b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    cfg = mod.CONFIG
    overrides = os.environ.get("REPRO_CFG_OVERRIDES")
    if overrides:
        cfg = dataclasses.replace(cfg, **json.loads(overrides))
    return cfg


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
