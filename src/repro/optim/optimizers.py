"""Optimizers. AdaGrad is the paper's choice (§3, [Duchi et al. 2011]).

Plain functional API (no optax in this container):
``opt.init(params) -> state``, ``opt.update(grads, state, params, lr)``.
States are pytrees mirroring the params, so they shard with the params
under whatever sharding rule the launcher picks (DP replicates them,
FSDP/ZeRO-1 shards them).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable  # (grads, state, params, lr) -> (new_params, new_state)


def adagrad(eps: float = 1e-8) -> Optimizer:
    """AdaGrad: G += g²; p -= lr·g/(√G+eps)."""

    def init(params):
        return {"accum": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, lr):
        accum = jax.tree.map(
            lambda a, g: a + jnp.square(g.astype(jnp.float32)),
            state["accum"], grads)
        new_params = jax.tree.map(
            lambda p, g, a: (p.astype(jnp.float32)
                             - lr * g.astype(jnp.float32)
                             / (jnp.sqrt(a) + eps)).astype(p.dtype),
            params, grads, accum)
        return new_params, {"accum": accum}

    return Optimizer("adagrad", init, update)


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params, lr):
        if momentum == 0.0:
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, state
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mu"], grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu}

    return Optimizer("sgd", init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        mh = 1.0 - b1 ** t.astype(jnp.float32)
        vh = 1.0 - b2 ** t.astype(jnp.float32)
        new_params = jax.tree.map(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               - lr * (m_ / mh) / (jnp.sqrt(v_ / vh) + eps)
                               ).astype(p.dtype),
            params, m, v)
        return new_params, {"m": m, "v": v, "t": t}

    return Optimizer("adam", init, update)
