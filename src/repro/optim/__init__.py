from .optimizers import Optimizer, adagrad, adam, sgd
from .schedule import parallel_lr_schedule, constant_lr

__all__ = ["Optimizer", "adagrad", "adam", "sgd",
           "parallel_lr_schedule", "constant_lr"]
