"""Learning-rate schedules, including the paper's parallel scaling rule (§3):

  effective lr = base_lr · k   (k = #workers) for the first ``reset_epochs``
  epochs, then reset to base_lr.  Base lr 0.001 in the paper.
"""
from __future__ import annotations


def constant_lr(lr: float):
    return lambda epoch: lr


def parallel_lr_schedule(base_lr: float = 1e-3, n_workers: int = 1,
                         reset_epochs: int = 10):
    """Paper §3: lr = base·k for the first 10 epochs, then base."""

    def schedule(epoch: int) -> float:
        return base_lr * n_workers if epoch < reset_epochs else base_lr

    return schedule
