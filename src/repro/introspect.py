"""Tiny signature-introspection helpers shared across layers."""
from __future__ import annotations

import inspect
from typing import Callable

__all__ = ["accepts_kwarg"]


def accepts_kwarg(fn: Callable, name: str, *, explicit: bool = False) -> bool:
    """True when ``fn(...)`` can be called with keyword argument ``name``.

    ``explicit=False`` counts a ``**kwargs`` catch-all as acceptance (the
    right question for "is it safe to forward this kwarg").
    ``explicit=True`` requires a named parameter — use it when accepting
    the kwarg signals a *semantic contract* (e.g. the engine's epoch-pure
    pipeline protocol), which a permissive catch-all must not opt into
    silently.  Returns False for non-introspectable callables.
    """
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False
    p = params.get(name)
    if p is not None and p.kind in (inspect.Parameter.POSITIONAL_OR_KEYWORD,
                                    inspect.Parameter.KEYWORD_ONLY):
        return True
    if explicit:
        return False
    return any(q.kind is inspect.Parameter.VAR_KEYWORD
               for q in params.values())
