"""Model configuration covering all assigned architecture families.

One ``ModelConfig`` describes any of: dense GQA transformer, MoE, SSM
(xLSTM), hybrid (Mamba+attention+MoE), VLM (interleaved cross-attention) and
audio decoder.  Layer heterogeneity is expressed with a repeating
``block_pattern`` (a "super-block"): the full stack is
``block_pattern × n_superblocks`` which lets the forward pass ``lax.scan``
over super-blocks (small HLO even for 100-layer models).
"""
from __future__ import annotations

import dataclasses

# Layer kinds usable inside a block pattern.
ATTN = "attn"            # causal self-attention + FFN
ATTN_SWA = "attn_swa"    # sliding-window self-attention + FFN
XATTN = "xattn"          # cross-attention (to modality embeddings) + FFN
MAMBA = "mamba"          # Mamba SSM mixer + FFN
SLSTM = "slstm"          # xLSTM sLSTM block (post-up-projection)
MLSTM = "mlstm"          # xLSTM mLSTM block (pre-up-projection)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    head_dim: int | None = None      # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: int | None = None        # window for ATTN_SWA layers
    # --- layer pattern ---
    block_pattern: tuple[str, ...] = (ATTN,)  # repeated n_layers/len times
    first_layer_dense: bool = False  # MoE archs with a dense first layer (kimi)
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int | None = None      # expert hidden dim (default d_ff)
    moe_every: int = 1               # MoE FFN on layers where idx % moe_every == 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch_groups: int = 0     # 0 = global dispatch; G = grouped (GShard)
    # --- SSM / Mamba ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    # --- norms / activations ---
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    activation: str = "swiglu"       # swiglu | gelu | relu
    tie_embeddings: bool = False
    # --- modality frontend stub (vlm / audio) ---
    modality_tokens: int = 0         # #frontend embeddings per example
    modality_dim: int = 0            # frontend embedding dim (projected to d_model)
    # --- misc ---
    remat_policy: str = "full"       # full | dots | none (superblock scan)
    dtype: str = "bfloat16"
    max_seq_len: int = 1 << 20
    source: str = ""                 # citation

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}")
        return self.n_layers // len(self.block_pattern)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> list[str]:
        return list(self.block_pattern) * self.n_superblocks

    def moe_layer(self, idx_in_block: int) -> bool:
        """Whether the FFN at pattern position ``idx_in_block`` is MoE."""
        return self.is_moe and (idx_in_block % self.moe_every == 0)

    def param_count(self) -> int:
        """Analytic parameter count (used for 6·N·D roofline terms)."""
        d, hd = self.d_model, self.hd
        total = self.vocab_size * d                    # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d               # lm_head
        total += d                                     # final norm
        if self.modality_tokens:
            total += self.modality_dim * d             # frontend projector
        for li, kind in enumerate(self.layer_kinds()):
            if kind in (ATTN, ATTN_SWA, XATTN):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o + d                # + norm
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
                if kind == XATTN:
                    total += d                         # extra norm + gate
                total += d + self._ffn_params(li)      # ffn norm + ffn
            elif kind == MAMBA:
                di = self.mamba_expand * d
                dtr = max(d // 16, 1)
                total += d * 2 * di                    # in_proj (x, z)
                total += di * self.mamba_d_conv        # depthwise conv
                total += di * (dtr + 2 * self.mamba_d_state)  # x -> dt,B,C
                total += dtr * di + di                 # dt_proj
                total += di * self.mamba_d_state + di  # A_log, D
                total += di * d + d                    # out_proj + norm
                total += d + self._ffn_params(li)
            elif kind in (SLSTM, MLSTM):
                # xLSTM blocks: 4 gates worth of projections + up/down proj.
                if kind == SLSTM:
                    total += 4 * (d * d + self.n_heads * self.hd_x * self.hd_x) + d
                    pf = 4 * d // 3
                    total += d * 2 * pf + pf * d       # GeGLU up/down (4/3 factor)
                else:
                    di = 2 * d
                    total += d * 2 * di                # up proj (x, z)
                    total += 3 * di * di // self.n_heads  # q,k,v per-head (approx)
                    total += 2 * di                    # i,f gate projections (approx)
                    total += di * d                    # down proj
                total += d                             # norm
            else:
                raise ValueError(kind)
        return int(total)

    @property
    def hd_x(self) -> int:
        return self.d_model // self.n_heads

    def _ffn_params(self, layer_idx: int) -> int:
        d = self.d_model
        if self.d_ff == 0:
            return 0
        pattern_pos = layer_idx % max(len(self.block_pattern), 1)
        if self.is_moe and self.moe_layer(pattern_pos):
            eff = self.moe_d_ff or self.d_ff
            mats = 3 if self.activation == "swiglu" else 2
            return self.n_experts * mats * d * eff + d * self.n_experts  # + router
        mats = 3 if self.activation == "swiglu" else 2
        return mats * d * self.d_ff

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k experts instead of all)."""
        if not self.is_moe:
            return self.param_count()
        total = self.param_count()
        eff = self.moe_d_ff or self.d_ff
        mats = 3 if self.activation == "swiglu" else 2
        per_expert = mats * self.d_model * eff
        n_moe_layers = sum(
            1 for li, k in enumerate(self.layer_kinds())
            if k in (ATTN, ATTN_SWA, XATTN, MAMBA)
            and self.moe_layer(li % len(self.block_pattern))
            and not (self.first_layer_dense and li == 0)
        )
        total -= n_moe_layers * per_expert * (self.n_experts - self.top_k)
        return int(total)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 super-block-lengths of layers, tiny dims."""
        pat_len = len(self.block_pattern)
        small = dict(
            name=self.name + "-smoke",
            n_layers=2 * pat_len if pat_len > 1 else 2,
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=0 if self.d_ff == 0 else min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=None if self.moe_d_ff is None else min(self.moe_d_ff, 128),
            sliding_window=None if self.sliding_window is None else 64,
            modality_tokens=min(self.modality_tokens, 16),
            modality_dim=min(self.modality_dim, 64) if self.modality_dim else 0,
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
