"""Composable decoder stack covering all assigned architecture families.

The stack is organized as ``n_superblocks`` repetitions of
``cfg.block_pattern`` (e.g. Jamba's ``(mamba×3, attn, mamba×4)`` with MoE on
alternate positions).  Parameters of all super-blocks are *stacked* on a
leading axis and the forward pass is a single ``lax.scan`` over super-blocks
— the lowered HLO contains one super-block body regardless of depth (100-layer
models compile in seconds) and maps directly onto pipeline-friendly sharding.

Three entry points:
  * ``forward``      — full-sequence training/prefill; returns per-token
                       logits + sequence-pooled logits (the SSL head).
  * ``init_cache``   — per-layer decode state (full KV / ring KV / SSM / xLSTM).
  * ``decode_step``  — one-token autoregressive step through all layers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .config import ATTN, ATTN_SWA, MAMBA, MLSTM, SLSTM, XATTN, ModelConfig
from .layers import attention as attn_lib
from .layers import mamba as mamba_lib
from .layers import moe as moe_lib
from .layers import xlstm as xlstm_lib
from .layers.attention import KVCache
from .layers.common import apply_norm, embed, init_embedding, init_norm, variance_scaling
from .layers.mamba import MambaState
from .layers.mlp import apply_mlp, init_mlp
from .layers.xlstm import MLSTMState, SLSTMState

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ===================================================================== init
def _init_layer(key, cfg: ModelConfig, kind: str, pattern_pos: int,
                *, force_dense_ffn: bool = False) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm1": init_norm(cfg.d_model, cfg.norm)}
    if kind in (ATTN, ATTN_SWA):
        p["attn"] = attn_lib.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
            qkv_bias=cfg.qkv_bias, dtype=dt)
    elif kind == XATTN:
        p["attn"] = attn_lib.init_cross_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype=dt)
    elif kind == MAMBA:
        p["mamba"] = mamba_lib.init_mamba(
            ks[0], cfg.d_model, expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state, d_conv=cfg.mamba_d_conv, dtype=dt)
    elif kind == SLSTM:
        p["block"] = xlstm_lib.init_slstm(ks[0], cfg.d_model, cfg.n_heads, dt)
        return p
    elif kind == MLSTM:
        p["block"] = xlstm_lib.init_mlstm(ks[0], cfg.d_model, cfg.n_heads, dt)
        return p
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["norm2"] = init_norm(cfg.d_model, cfg.norm)
        if cfg.moe_layer(pattern_pos) and not force_dense_ffn:
            p["moe"] = moe_lib.init_moe(
                ks[1], cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts,
                cfg.activation, dt)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation, dt)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_superblocks + 4)
    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = variance_scaling(
            keys[1], (cfg.d_model, cfg.vocab_size), cfg.d_model, dt)
    if cfg.modality_dim:
        params["modality_proj"] = variance_scaling(
            keys[2], (cfg.modality_dim, cfg.d_model), cfg.modality_dim, dt)

    def init_superblock(k):
        kk = jax.random.split(k, len(cfg.block_pattern))
        return [
            _init_layer(kk[i], cfg, kind, i)
            for i, kind in enumerate(cfg.block_pattern)
        ]

    n_scan = cfg.n_superblocks - (1 if cfg.first_layer_dense else 0)
    if cfg.first_layer_dense:
        params["first_block"] = [
            _init_layer(keys[3], cfg, cfg.block_pattern[0], 0,
                        force_dense_ffn=True)
        ]
    sb = [init_superblock(keys[4 + i]) for i in range(n_scan)]
    params["superblocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *sb)
    return params


def abstract_params(cfg: ModelConfig, *, param_dtype: str | None = None) -> dict:
    """ShapeDtypeStruct param tree (no allocation) — used by the dry-run."""
    dt = param_dtype or cfg.dtype

    def go():
        return init_params(cfg, jax.random.PRNGKey(0))

    shapes = jax.eval_shape(go)
    if param_dtype is None:
        return shapes
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(dt)), shapes)


# =================================================================== forward
def _apply_mixer(p, cfg: ModelConfig, kind: str, x: Array, positions: Array,
                 mem: Array | None) -> Array:
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == ATTN:
        return attn_lib.attention_block(p["attn"], h, positions,
                                        theta=cfg.rope_theta)
    if kind == ATTN_SWA:
        return attn_lib.attention_block(p["attn"], h, positions,
                                        theta=cfg.rope_theta,
                                        window=cfg.sliding_window)
    if kind == XATTN:
        mk, mv = attn_lib.cross_kv(p["attn"], mem)
        return attn_lib.cross_attention_block(p["attn"], h, mk, mv)
    if kind == MAMBA:
        return mamba_lib.mamba_forward(p["mamba"], h)
    if kind == SLSTM:
        return xlstm_lib.slstm_forward(p["block"], h)
    if kind == MLSTM:
        return xlstm_lib.mlstm_forward(p["block"], h)
    raise ValueError(kind)


def _apply_ffn(p, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """Post-mixer FFN (dense or MoE). Returns (out, moe_aux)."""
    if "norm2" not in p:
        return jnp.zeros_like(x), jnp.float32(0)
    h = apply_norm(p["norm2"], x, cfg.norm)
    if "moe" in p:
        y, aux = moe_lib.apply_moe(p["moe"], h, top_k=cfg.top_k,
                                   capacity_factor=cfg.capacity_factor,
                                   activation=cfg.activation,
                                   dispatch_groups=cfg.moe_dispatch_groups)
        return y, aux
    return apply_mlp(p["mlp"], h, cfg.activation), jnp.float32(0)


def _superblock_fwd(block_params: list, cfg: ModelConfig, x: Array,
                    positions: Array, mem: Array | None) -> tuple[Array, Array]:
    aux = jnp.float32(0)
    for i, kind in enumerate(cfg.block_pattern[: len(block_params)]):
        p = block_params[i]
        x = x + _apply_mixer(p, cfg, kind, x, positions, mem)
        y, a = _apply_ffn(p, cfg, x)
        x = x + y
        aux = aux + a
    return x, aux


def _constrain(x, sharding):
    if sharding is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def output_head(params: dict, cfg: ModelConfig) -> Array:
    """(d_model, vocab) output projection (tied or separate)."""
    return (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])


def forward(params: dict, cfg: ModelConfig, tokens: Array, *,
            modality_embeds: Array | None = None,
            positions: Array | None = None,
            remat: bool = True,
            act_sharding=None,
            with_logits: bool = True) -> dict:
    """Full-sequence forward.

    Returns {'logits': (B,T,V), 'pooled_logits': (B,V), 'moe_aux': scalar}.
    ``pooled_logits`` is the SSL head: the model's output distribution for
    the mean-pooled sequence representation (paper's p_θ(x_i) analogue).
    """
    B, T = tokens.shape
    x = _constrain(embed(params["embed"], tokens), act_sharding)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    mem = None
    if modality_embeds is not None:
        mem = jnp.einsum("bmd,de->bme", modality_embeds,
                         params["modality_proj"]).astype(x.dtype)

    aux_total = jnp.float32(0)
    if cfg.first_layer_dense:
        x, aux0 = _superblock_fwd(params["first_block"], cfg, x, positions, mem)
        aux_total = aux_total + aux0

    def body(carry, sb_params):
        x, aux = carry
        x = _constrain(x, act_sharding)      # keep batch on the data axes
        x, a = _superblock_fwd(sb_params, cfg, x, positions, mem)
        return (_constrain(x, act_sharding), aux + a), None

    if not remat or cfg.remat_policy == "none":
        body_fn = body
    elif cfg.remat_policy == "dots":
        # Save matmul outputs, recompute elementwise — trades HBM for the
        # 2× forward recompute of full remat (§Perf iteration).
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    else:
        body_fn = jax.checkpoint(body)
    (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total),
                                     params["superblocks"])
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = output_head(params, cfg)
    logits = jnp.einsum("btd,dv->btv", x, head) if with_logits else None
    pooled = jnp.mean(x, axis=1)
    pooled_logits = jnp.einsum("bd,dv->bv", pooled, head)
    return {"logits": logits, "hidden": x, "pooled_logits": pooled_logits,
            "moe_aux": aux_total}


# =================================================================== prefill
def _apply_mixer_with_state(p, cfg: ModelConfig, kind: str, x: Array,
                            positions: Array, mem: Array | None):
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind == ATTN:
        return attn_lib.attention_block(p["attn"], h, positions,
                                        theta=cfg.rope_theta, return_kv=True)
    if kind == ATTN_SWA:
        return attn_lib.attention_block(p["attn"], h, positions,
                                        theta=cfg.rope_theta,
                                        window=cfg.sliding_window,
                                        return_kv=True)
    if kind == XATTN:
        mk, mv = attn_lib.cross_kv(p["attn"], mem)
        y = attn_lib.cross_attention_block(p["attn"], h, mk, mv)
        B, M = mk.shape[0], mk.shape[1]
        cache = KVCache(k=mk, v=mv,
                        positions=jnp.broadcast_to(jnp.arange(M, dtype=jnp.int32)[None], (B, M)),
                        valid=jnp.ones((B, M), bool))
        return y, cache
    if kind == MAMBA:
        return mamba_lib.mamba_forward(p["mamba"], h, return_state=True)
    if kind == SLSTM:
        return xlstm_lib.slstm_forward(p["block"], h, return_state=True)
    if kind == MLSTM:
        return xlstm_lib.mlstm_forward(p["block"], h, return_state=True)
    raise ValueError(kind)


def _pad_kv_cache(c: KVCache, cache_len: int) -> KVCache:
    T = c.k.shape[1]
    if T >= cache_len:
        return c
    pad = cache_len - T
    return KVCache(
        k=jnp.pad(c.k, ((0, 0), (0, pad), (0, 0), (0, 0))),
        v=jnp.pad(c.v, ((0, 0), (0, pad), (0, 0), (0, 0))),
        positions=jnp.pad(c.positions, ((0, 0), (0, pad))),
        valid=jnp.pad(c.valid, ((0, 0), (0, pad))),
    )


def _superblock_prefill(block_params: list, cfg: ModelConfig, x: Array,
                        positions: Array, mem: Array | None,
                        cache_len: int | None):
    caches = []
    for i, kind in enumerate(cfg.block_pattern[: len(block_params)]):
        p = block_params[i]
        y, c = _apply_mixer_with_state(p, cfg, kind, x, positions, mem)
        if kind == ATTN and cache_len is not None:
            c = _pad_kv_cache(c, cache_len)
        x = x + y
        f, _ = _apply_ffn(p, cfg, x)
        x = x + f
        caches.append(c)
    return x, caches


def prefill(params: dict, cfg: ModelConfig, tokens: Array, *,
            modality_embeds: Array | None = None,
            cache_len: int | None = None,
            act_sharding=None) -> tuple[dict, Any]:
    """Prefill pass: full-sequence forward that also fills the decode cache.

    Returns ({'logits': (B,T,V)}, cache) where cache matches ``init_cache``'s
    structure (slot layout identical to incremental ``decode_step`` updates).
    """
    B, T = tokens.shape
    x = _constrain(embed(params["embed"], tokens), act_sharding)
    positions = jnp.broadcast_to(jnp.arange(T)[None, :], (B, T))
    mem = None
    if modality_embeds is not None:
        mem = jnp.einsum("bmd,de->bme", modality_embeds,
                         params["modality_proj"]).astype(x.dtype)
    cache: dict[str, Any] = {}
    if cfg.first_layer_dense:
        x, first_caches = _superblock_prefill(params["first_block"], cfg, x,
                                              positions, mem, cache_len)
        cache["first"] = first_caches

    def body(x, sb_params):
        x = _constrain(x, act_sharding)
        x, caches = _superblock_prefill(sb_params, cfg, x, positions, mem,
                                        cache_len)
        return _constrain(x, act_sharding), caches

    x, layer_caches = jax.lax.scan(body, x, params["superblocks"])
    cache["layers"] = layer_caches
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head)
    return {"logits": logits}, cache


# ==================================================================== decode
def _layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int, dt):
    if kind == ATTN:
        return KVCache.init(batch, cache_len, cfg.n_kv_heads, cfg.hd, dt)
    if kind == ATTN_SWA:
        w = min(cfg.sliding_window or cache_len, cache_len)
        return KVCache.init(batch, w, cfg.n_kv_heads, cfg.hd, dt)
    if kind == XATTN:
        # Cross KV is static per request; stored at its modality length.
        m = max(cfg.modality_tokens, 1)
        return KVCache.init(batch, m, cfg.n_kv_heads, cfg.hd, dt)
    if kind == MAMBA:
        di = cfg.mamba_expand * cfg.d_model
        return MambaState.init(batch, di, cfg.mamba_d_state,
                               cfg.mamba_d_conv, dt)
    if kind == SLSTM:
        return SLSTMState.init(batch, cfg.n_heads, cfg.d_model // cfg.n_heads)
    if kind == MLSTM:
        di = 2 * cfg.d_model
        return MLSTMState.init(batch, cfg.n_heads, di // cfg.n_heads, di)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int):
    """Decode cache pytree: per pattern position, stacked over super-blocks."""
    dt = _dtype(cfg)
    n_scan = cfg.n_superblocks - (1 if cfg.first_layer_dense else 0)

    def stacked(kind):
        one = _layer_cache(cfg, kind, batch, cache_len, dt)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_scan,) + a.shape), one)

    cache = {"layers": [stacked(k) for k in cfg.block_pattern]}
    if cfg.first_layer_dense:
        cache["first"] = [
            _layer_cache(cfg, cfg.block_pattern[0], batch, cache_len, dt)
        ]
    return cache


def _decode_layer(p, cfg: ModelConfig, kind: str, x: Array, pos: Array,
                  cache):
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in (ATTN, ATTN_SWA):
        w = cfg.sliding_window if kind == ATTN_SWA else None
        y, cache = attn_lib.attention_decode(p["attn"], h, pos, cache,
                                             theta=cfg.rope_theta, window=w)
    elif kind == XATTN:
        y = attn_lib.decode_attention(
            jnp.einsum("btd,dhk->bthk", h, p["attn"]["wq"]),
            cache.k, cache.v, cache.positions,
            cache.valid, jnp.full((x.shape[0],), jnp.iinfo(jnp.int32).max - 1,
                                  jnp.int32),
            window=None)
        y = (jnp.tanh(p["attn"]["gate"])
             * attn_lib.out_proj(p["attn"], y).astype(jnp.float32)
             ).astype(x.dtype)
    elif kind == MAMBA:
        y, cache = mamba_lib.mamba_decode(p["mamba"], h, cache)
    elif kind == SLSTM:
        y, cache = xlstm_lib.slstm_decode(p["block"], h, cache)
        return x + y, cache
    elif kind == MLSTM:
        y, cache = xlstm_lib.mlstm_decode(p["block"], h, cache)
        return x + y, cache
    else:
        raise ValueError(kind)
    x = x + y
    f, _ = _apply_ffn(p, cfg, x)
    return x + f, cache


def decode_step(params: dict, cfg: ModelConfig, cache, tokens: Array,
                pos: Array, *, act_sharding=None) -> tuple[Array, Any]:
    """One autoregressive step. tokens: (B, 1); pos: (B,). Returns (logits, cache)."""
    x = _constrain(embed(params["embed"], tokens), act_sharding)
    cache = dict(cache)
    if cfg.first_layer_dense:
        x, first_cache = _decode_layer(
            params["first_block"][0], cfg, cfg.block_pattern[0], x, pos,
            cache["first"][0])
        cache["first"] = [first_cache]

    def body(x, scanned):
        sb_params, layer_caches = scanned
        x = _constrain(x, act_sharding)
        new_caches = []
        for i, kind in enumerate(cfg.block_pattern):
            x, c = _decode_layer(sb_params[i], cfg, kind, x, pos,
                                 layer_caches[i])
            new_caches.append(c)
        return _constrain(x, act_sharding), new_caches

    x, new_layer_caches = jax.lax.scan(
        body, x, (params["superblocks"], cache["layers"]))
    cache["layers"] = new_layer_caches
    x = apply_norm(params["final_norm"], x, cfg.norm)
    head = (params["embed"]["table"].T if cfg.tie_embeddings
            else params["lm_head"])
    logits = jnp.einsum("btd,dv->btv", x, head)
    return logits, cache
