"""Mixture-of-Experts FFN with capacity-based token dispatch (GShard/Switch).

Dropless-ish routing: top-k softmax router, per-expert capacity
``C = ceil(tokens · k / E · capacity_factor)``; tokens are placed into
per-expert slots via an exclusive cumsum of the assignment one-hot (unique
slot per assignment, overflow dropped — the standard capacity discipline).
Expert FFNs run as one batched einsum over stacked expert weights, which
shards cleanly over the mesh 'model' axis (expert parallelism).

Includes the Switch-style load-balance auxiliary loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import variance_scaling

Array = jax.Array


def init_moe(key, d_model: int, d_ff: int, n_experts: int, activation: str,
             dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "router": variance_scaling(ks[0], (d_model, n_experts), d_model,
                                   jnp.float32),
        "wu": variance_scaling(ks[1], (n_experts, d_model, d_ff), d_model, dtype),
        "wd": variance_scaling(ks[2], (n_experts, d_ff, d_model), d_ff, dtype),
    }
    if activation == "swiglu":
        p["wg"] = variance_scaling(ks[3], (n_experts, d_model, d_ff), d_model,
                                   dtype)
    return p


def _expert_ffn(p, h: Array, activation: str) -> Array:
    """h: (E, C, d) -> (E, C, d), batched over the (sharded) expert dim."""
    if activation == "swiglu":
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wg"]))
        u = jnp.einsum("ecd,edf->ecf", h, p["wu"])
        return jnp.einsum("ecf,efd->ecd", g * u, p["wd"])
    act = jax.nn.gelu if activation == "gelu" else jax.nn.relu
    u = act(jnp.einsum("ecd,edf->ecf", h, p["wu"]))
    return jnp.einsum("ecf,efd->ecd", u, p["wd"])


def _dispatch_one_group(p, xf: Array, top_w: Array, top_e: Array,
                        cap: int, activation: str) -> Array:
    """Capacity dispatch + expert FFN for one token group.

    xf: (N, d); top_w/top_e: (N, k).  Position-in-expert via exclusive
    cumsum of the assignment one-hot — local to the group, so a sharded
    group axis never induces cross-shard scans.
    """
    N, d = xf.shape
    top_k = top_e.shape[1]
    E = p["router"].shape[1]
    e_flat = top_e.reshape(N * top_k)                      # (A,)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)        # (A, E)
    pos = jnp.cumsum(oh, axis=0) - oh                      # exclusive count
    pos_in_e = jnp.sum(pos * oh, axis=1)                   # (A,)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_flat * cap + pos_in_e, E * cap)  # OOB => dropped

    # a-th assignment belongs to token a//k — a static broadcast, NOT a
    # gather (a dynamic gather of the d-sharded token array lowers to a
    # 15 GB all-reduce per layer at kimi scale; §Perf kimi iter 3).
    xin = jnp.broadcast_to(xf[:, None, :], (N, top_k, d)).reshape(
        N * top_k, d)                                      # (A, d)
    buf = jnp.zeros((E * cap, d), xf.dtype).at[slot].set(xin, mode="drop")

    out_buf = _expert_ffn(p, buf.reshape(E, cap, d), activation)
    out_flat = out_buf.reshape(E * cap, d)
    ya = jnp.take(out_flat, slot, axis=0, mode="fill", fill_value=0)  # (A, d)
    ya = ya * (top_w.reshape(N * top_k, 1) * keep[:, None]).astype(ya.dtype)
    return jnp.sum(ya.reshape(N, top_k, d), axis=1)


def apply_moe(p, x: Array, *, top_k: int, capacity_factor: float,
              activation: str, dispatch_groups: int = 0) -> tuple[Array, Array]:
    """x: (B, T, d). Returns (y, aux_load_balance_loss).

    ``dispatch_groups=0`` — one global dispatch: the position-in-expert
    cumsum runs over ALL tokens, which under data sharding lowers to a
    cross-shard scan (collective-permute chain).  The baseline.

    ``dispatch_groups=G`` — GShard-style grouped dispatch: tokens reshape to
    (G, N/G) with per-group capacity; the cumsum is group-local, so with G a
    multiple of the data-axis size the dispatch needs NO cross-shard
    collective — only the expert all-to-all remains (§Perf iteration 1).
    """
    B, T, d = x.shape
    E = p["router"].shape[1]
    xf = x.reshape(B * T, d)
    N = B * T

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)            # (N, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # Switch load-balance loss: E * sum_e (dispatch fraction * mean prob).
    assign_onehot = jax.nn.one_hot(top_e, E, dtype=jnp.float32)  # (N, k, E)
    frac = jnp.mean(jnp.sum(assign_onehot, axis=1), axis=0) / top_k
    mean_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_prob)

    G = dispatch_groups if dispatch_groups and N % dispatch_groups == 0 else 1
    n_g = N // G
    cap = int(max(1, round(n_g * top_k / E * capacity_factor)))
    if G == 1:
        y = _dispatch_one_group(p, xf, top_w, top_e, cap, activation)
    else:
        y = jax.vmap(
            lambda xg, wg, eg: _dispatch_one_group(p, xg, wg, eg, cap,
                                                   activation))(
            xf.reshape(G, n_g, d), top_w.reshape(G, n_g, top_k),
            top_e.reshape(G, n_g, top_k))
    return y.reshape(B, T, d), aux
