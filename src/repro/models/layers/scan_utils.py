"""Memory-bounded sequential scan: lax.scan with chunked rematerialization.

Backward through a plain ``lax.scan`` stores the carry at every step — for
recurrent mixers with large states (mLSTM's (B,H,hd,hd) matrix memory,
Mamba's (B,d_inner,d_state)) that is O(T·state) and dwarfs everything else.
``chunked_scan`` nests two scans: the outer one checkpoints chunk boundaries
only, the inner chunk is recomputed during backward — O(T/C·state) residuals
at the cost of one extra forward over each chunk (the standard recurrent
remat trade, and the TPU-native analogue of Mamba's fused-SRAM scan).
"""
from __future__ import annotations

import jax


def chunked_scan(step, init, xs, *, chunk: int = 128, unroll: int = 1):
    """Equivalent to ``jax.lax.scan(step, init, xs)`` with chunked remat.

    ``xs`` leaves must share leading dim T; T is padded up to a multiple of
    ``chunk`` (padded steps run but their ys are dropped and the carry from
    the last real step is returned... padding is applied at the END and the
    final carry is taken at step T, so padded steps never affect results —
    we guard by masking: simpler, we require the caller's step to be safe on
    zero inputs; all our mixers are, but to be exact we slice the carry at
    the boundary).
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    c = min(chunk, T)
    if T % c != 0:
        # Fall back to plain scan for ragged tails (rare: T < chunk or odd T).
        return jax.lax.scan(step, init, xs, unroll=unroll)
    nc = T // c

    def inner(carry, xc):
        return jax.lax.scan(step, carry, xc, unroll=unroll)

    xs_chunked = jax.tree.map(
        lambda a: a.reshape((nc, c) + a.shape[1:]), xs)
    carry, ys = jax.lax.scan(jax.checkpoint(inner), init, xs_chunked)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys
