"""GQA attention: chunked (flash-style) training/prefill path + cached decode.

The training/prefill path never materializes the full (Tq, Tk) score matrix:
it tiles queries and scans KV blocks with an online-softmax accumulator —
this is what makes ``prefill_32k`` lowerable without a quadratic temp, and it
supports causal + sliding-window masking (the sub-quadratic variant used for
``long_500k`` on attention architectures).

Decode attends one query against a KV cache: a full cache for ATTN layers, a
ring buffer of ``window`` entries for ATTN_SWA layers (bounded memory at 500k
contexts), or precomputed cross-attention KV for XATTN layers.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .common import apply_rope, variance_scaling

Array = jax.Array
NEG_INF = -1e30


# ------------------------------------------------------------------ params
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, hd: int,
                   *, qkv_bias: bool, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": variance_scaling(ks[0], (d_model, n_heads, hd), d_model, dtype),
        "wk": variance_scaling(ks[1], (d_model, n_kv_heads, hd), d_model, dtype),
        "wv": variance_scaling(ks[2], (d_model, n_kv_heads, hd), d_model, dtype),
        "wo": variance_scaling(ks[3], (n_heads, hd, d_model), n_heads * hd, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, hd), dtype)
        p["bk"] = jnp.zeros((n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((n_kv_heads, hd), dtype)
    return p


def qkv_proj(p, x: Array):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def out_proj(p, o: Array) -> Array:
    return jnp.einsum("bthk,hkd->btd", o, p["wo"])


# ------------------------------------------------- chunked flash attention
def _mask_tile(q_pos, kv_pos, kv_valid, *, causal: bool, window: int | None):
    """(Tq_blk, Tk_blk) boolean mask for one tile from absolute positions."""
    m = kv_valid[None, :]
    diff = q_pos[:, None] - kv_pos[None, :]
    if causal:
        m = m & (diff >= 0)
    if window is not None:
        m = m & (diff < window)
    return m


def _flash_tile_shapes(q, k, q_block, kv_block):
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    qb, kb = min(q_block, Tq), min(kv_block, Tk)
    return B, Tq, H, hd, Tk, KV, H // KV, qb, kb, (-Tq) % qb, (-Tk) % kb


def _tri_tile_list(nq, nk, qb, kb, Tq, Tk, *, causal, window,
                   sequential) -> list[tuple[int, int]]:
    """Static (q_block, kv_block) tile list, row-major.

    With ``sequential`` positions (q = arange(Tq)+Tk−Tq, kv = arange(Tk)),
    fully-masked tiles are skipped: future tiles under causal masking and
    out-of-window tiles under sliding-window — this HALVES causal-attention
    FLOPs (triangular tiling) and makes SWA prefill O(T·w) (§Perf qwen2
    iteration 2).  Without it the full grid is emitted (identical math —
    masks still applied per tile)."""
    off = Tk - Tq  # absolute position of q row 0
    tiles = []
    for i in range(nq):
        q_lo, q_hi = off + i * qb, off + (i + 1) * qb - 1
        for j in range(nk):
            k_lo, k_hi = j * kb, (j + 1) * kb - 1
            if sequential:
                if causal and k_lo > q_hi:
                    continue                       # entirely in the future
                if window is not None and k_hi < q_lo - window + 1:
                    continue                       # entirely out of window
            tiles.append((i, j))
    return tiles


def _flash_fwd_tiles(q, k, v, q_positions, kv_positions, kv_valid,
                     causal, window, q_block, kv_block, sequential=False):
    """Tiled online-softmax forward. Returns (out (B,Tq,H,hd), lse (B,Tq,H))."""
    B, Tq, H, hd, Tk, KV, G, qb, kb, pq, pk = _flash_tile_shapes(
        q, k, q_block, kv_block)
    scale = hd ** -0.5
    q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    qp = jnp.pad(q_positions, (0, pq))
    k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kp = jnp.pad(kv_positions, (0, pk))
    kval = jnp.pad(kv_valid, (0, pk))
    nq, nk = (Tq + pq) // qb, (Tk + pk) // kb

    qt = (q.reshape(B, nq, qb, KV, G, hd) * scale).swapaxes(0, 1)
    qpt = qp.reshape(nq, qb)
    kt = k.reshape(B, nk, kb, KV, hd).swapaxes(0, 1)
    vt = v.reshape(B, nk, kb, KV, hd).swapaxes(0, 1)
    kpt = kp.reshape(nk, kb)
    kvt = kval.reshape(nk, kb)

    tiles = _tri_tile_list(nq, nk, qb, kb, Tq + pq, Tk + pk, causal=causal,
                           window=window, sequential=sequential)
    ti = jnp.asarray([t[0] for t in tiles], jnp.int32)
    tj = jnp.asarray([t[1] for t in tiles], jnp.int32)
    first = jnp.asarray(
        [a == 0 or tiles[a - 1][0] != tiles[a][0] for a in range(len(tiles))])
    last = jnp.asarray(
        [a == len(tiles) - 1 or tiles[a + 1][0] != tiles[a][0]
         for a in range(len(tiles))])

    def step(carry, inp):
        acc, m, lsum, out_buf, lse_buf = carry
        i, j, is_first, is_last = inp
        qi = jax.lax.dynamic_index_in_dim(qt, i, 0, keepdims=False)
        qposi = jax.lax.dynamic_index_in_dim(qpt, i, 0, keepdims=False)
        ki = jax.lax.dynamic_index_in_dim(kt, j, 0, keepdims=False)
        vi = jax.lax.dynamic_index_in_dim(vt, j, 0, keepdims=False)
        kposi = jax.lax.dynamic_index_in_dim(kpt, j, 0, keepdims=False)
        kvali = jax.lax.dynamic_index_in_dim(kvt, j, 0, keepdims=False)
        # Reset the online-softmax state at the start of each q row.
        acc = jnp.where(is_first, 0.0, acc)
        m = jnp.where(is_first, NEG_INF, m)
        lsum = jnp.where(is_first, 0.0, lsum)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, ki,
                       preferred_element_type=jnp.float32)
        mask = _mask_tile(qposi, kposi, kvali, causal=causal, window=window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        lsum = lsum * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", p, vi.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        # Emit the finished row.
        out_row = acc / jnp.maximum(lsum, 1e-30)[..., None]
        lse_row = jnp.where(lsum > 0,
                            m_new + jnp.log(jnp.maximum(lsum, 1e-30)),
                            0.0)
        out_buf = jnp.where(
            is_last,
            jax.lax.dynamic_update_index_in_dim(
                out_buf, out_row[None].astype(out_buf.dtype), i, 0),
            out_buf)
        lse_buf = jnp.where(
            is_last,
            jax.lax.dynamic_update_index_in_dim(lse_buf, lse_row[None], i, 0),
            lse_buf)
        return (acc, m_new, lsum, out_buf, lse_buf), None

    acc0 = jnp.zeros((B, qb, KV, G, hd), jnp.float32)
    m0 = jnp.full((B, qb, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, qb, KV, G), jnp.float32)
    out0 = jnp.zeros((nq, B, qb, KV, G, hd), v.dtype)
    lse0 = jnp.zeros((nq, B, qb, KV, G), jnp.float32)
    (_, _, _, outs, lses), _ = jax.lax.scan(
        step, (acc0, m0, l0, out0, lse0), (ti, tj, first, last))
    out = outs.swapaxes(0, 1).reshape(B, Tq + pq, H, hd)[:, :Tq]
    lse = lses.swapaxes(0, 1).reshape(B, Tq + pq, H)[:, :Tq]
    return out, lse


def _flash_bwd_tiles(res, do, causal, window, q_block, kv_block,
                     sequential=False):
    """Flash backward: recompute p tiles from (q,k,lse); O(T) residual memory.

    Flat scan over the same (triangular) tile list as the forward,
    accumulating dq / dk / dv buffers with dynamic-index updates."""
    q, k, v, q_positions, kv_positions, kv_valid, out, lse = res
    B, Tq, H, hd, Tk, KV, G, qb, kb, pq, pk = _flash_tile_shapes(
        q, k, q_block, kv_block)
    scale = hd ** -0.5
    qpad = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    do_p = jnp.pad(do, ((0, 0), (0, pq), (0, 0), (0, 0)))
    out_p = jnp.pad(out, ((0, 0), (0, pq), (0, 0), (0, 0)))
    lse_p = jnp.pad(lse, ((0, 0), (0, pq), (0, 0)))
    qp = jnp.pad(q_positions, (0, pq))
    kpad = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vpad = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    kp = jnp.pad(kv_positions, (0, pk))
    kval = jnp.pad(kv_valid, (0, pk))
    nq, nk = (Tq + pq) // qb, (Tk + pk) // kb

    qt = qpad.reshape(B, nq, qb, KV, G, hd).swapaxes(0, 1)
    dot_ = do_p.reshape(B, nq, qb, KV, G, hd).swapaxes(0, 1)
    outt = out_p.reshape(B, nq, qb, KV, G, hd).swapaxes(0, 1)
    lset = lse_p.reshape(B, nq, qb, KV, G).swapaxes(0, 1)
    qpt = qp.reshape(nq, qb)
    kt = kpad.reshape(B, nk, kb, KV, hd).swapaxes(0, 1)
    vt = vpad.reshape(B, nk, kb, KV, hd).swapaxes(0, 1)
    kpt = kp.reshape(nk, kb)
    kvt = kval.reshape(nk, kb)

    tiles = _tri_tile_list(nq, nk, qb, kb, Tq + pq, Tk + pk, causal=causal,
                           window=window, sequential=sequential)
    ti = jnp.asarray([t[0] for t in tiles], jnp.int32)
    tj = jnp.asarray([t[1] for t in tiles], jnp.int32)

    def step(carry, inp):
        dq_buf, dk_buf, dv_buf = carry
        i, j = inp
        idx = partial(jax.lax.dynamic_index_in_dim, keepdims=False)
        qi, doi, oi, lsei, qposi = (idx(qt, i, 0), idx(dot_, i, 0),
                                    idx(outt, i, 0), idx(lset, i, 0),
                                    idx(qpt, i, 0))
        ki, vi, kposi, kvali = (idx(kt, j, 0), idx(vt, j, 0), idx(kpt, j, 0),
                                idx(kvt, j, 0))
        Di = jnp.sum(doi.astype(jnp.float32) * oi.astype(jnp.float32), -1)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qi, ki,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_tile(qposi, kposi, kvali, causal=causal, window=window)
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lsei[..., None])                   # (B,qb,KV,G,s)
        dv_t = jnp.einsum("bqkgs,bqkgd->bskd", p, doi.astype(jnp.float32))
        dp = jnp.einsum("bqkgd,bskd->bqkgs", doi.astype(jnp.float32),
                        vi.astype(jnp.float32))
        ds = p * (dp - Di[..., None])
        dq_t = scale * jnp.einsum("bqkgs,bskd->bqkgd", ds,
                                  ki.astype(jnp.float32))
        dk_t = scale * jnp.einsum("bqkgs,bqkgd->bskd", ds,
                                  qi.astype(jnp.float32))
        upd = jax.lax.dynamic_update_index_in_dim
        dq_buf = upd(dq_buf, idx(dq_buf, i, 0) + dq_t, i, 0)
        dk_buf = upd(dk_buf, idx(dk_buf, j, 0) + dk_t, j, 0)
        dv_buf = upd(dv_buf, idx(dv_buf, j, 0) + dv_t, j, 0)
        return (dq_buf, dk_buf, dv_buf), None

    dq0 = jnp.zeros((nq, B, qb, KV, G, hd), jnp.float32)
    dk0 = jnp.zeros((nk, B, kb, KV, hd), jnp.float32)
    dv0 = jnp.zeros_like(dk0)
    (dqs, dk, dv), _ = jax.lax.scan(step, (dq0, dk0, dv0), (ti, tj))
    dq = dqs.swapaxes(0, 1).reshape(B, Tq + pq, H, hd)[:, :Tq]
    dk = dk.swapaxes(0, 1).reshape(B, Tk + pk, KV, hd)[:, :Tk]
    dv = dv.swapaxes(0, 1).reshape(B, Tk + pk, KV, hd)[:, :Tk]
    z = lambda a: jnp.zeros(a.shape, jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            z(q_positions), z(kv_positions), z(kv_valid))


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _flash_attention(q, k, v, q_positions, kv_positions, kv_valid,
                     causal, window, q_block, kv_block, sequential):
    out, _ = _flash_fwd_tiles(q, k, v, q_positions, kv_positions, kv_valid,
                              causal, window, q_block, kv_block, sequential)
    return out


def _flash_vjp_fwd(q, k, v, q_positions, kv_positions, kv_valid,
                   causal, window, q_block, kv_block, sequential):
    out, lse = _flash_fwd_tiles(q, k, v, q_positions, kv_positions, kv_valid,
                                causal, window, q_block, kv_block, sequential)
    return out, (q, k, v, q_positions, kv_positions, kv_valid, out, lse)


def _flash_vjp_bwd(causal, window, q_block, kv_block, sequential, res, do):
    return _flash_bwd_tiles(res, do, causal, window, q_block, kv_block,
                            sequential)


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(
    q: Array, k: Array, v: Array,
    q_positions: Array, kv_positions: Array, kv_valid: Array,
    *, causal: bool, window: int | None,
    q_block: int = 512, kv_block: int = 1024,
    sequential_positions: bool = False,
) -> Array:
    """Flash attention (online softmax over KV tiles, recomputing backward).

    q: (B, Tq, H, hd); k, v: (B, Tk, KV, hd); positions are (T,) absolute.
    H must be a multiple of KV (GQA).  Returns (B, Tq, H, hd).  Residual
    memory is O(T·H·hd) (out + lse), not O(T²): the backward pass recomputes
    probability tiles — the flash-attention trade that makes prefill_32k and
    train_4k fit.

    ``sequential_positions=True`` (callers with arange positions) enables
    static triangular/window tile skipping — half the FLOPs for causal,
    O(T·w) for sliding-window prefill.
    """
    return _flash_attention(q, k, v, q_positions, kv_positions, kv_valid,
                            causal, window, q_block, kv_block,
                            sequential_positions)


def reference_attention(q, k, v, q_positions, kv_positions, kv_valid,
                        *, causal, window):
    """O(T²)-memory oracle used by tests to validate the flash path."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k,
                   preferred_element_type=jnp.float32)
    mask = _mask_tile(q_positions, kv_positions, kv_valid,
                      causal=causal, window=window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[None, :, None, None, None], p, 0.0)
    o = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Tq, H, hd)


# ------------------------------------------------------------------ decode
def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     kv_positions: Array, kv_valid: Array,
                     q_position: Array, *, window: int | None) -> Array:
    """Single-step attention. q: (B, 1, H, hd); caches: (B, S, KV, hd).

    ``kv_positions``/``kv_valid`` are (B, S) — ring buffers pass their
    absolute slot positions so windowing works after wrap-around.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd) * (hd ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32)
    mask = kv_valid & (kv_positions <= q_position[:, None])
    if window is not None:
        mask = mask & (q_position[:, None] - kv_positions < window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd)


# ------------------------------------------------------------------ caches
@dataclasses.dataclass
class KVCache:
    """Full or ring-buffer KV cache (ring when ``window`` is set)."""
    k: Array            # (B, S, KV, hd)
    v: Array
    positions: Array    # (B, S) absolute position stored in each slot
    valid: Array        # (B, S) bool

    @staticmethod
    def init(batch: int, size: int, n_kv: int, hd: int, dtype) -> "KVCache":
        return KVCache(
            k=jnp.zeros((batch, size, n_kv, hd), dtype),
            v=jnp.zeros((batch, size, n_kv, hd), dtype),
            positions=jnp.zeros((batch, size), jnp.int32),
            valid=jnp.zeros((batch, size), bool),
        )

    def update(self, k_new: Array, v_new: Array, pos: Array) -> "KVCache":
        """Insert one token (k_new: (B, 1, KV, hd)) at slot pos % S."""
        S = self.k.shape[1]
        slot = (pos % S).astype(jnp.int32)                      # (B,)
        b = jnp.arange(self.k.shape[0])
        return KVCache(
            k=self.k.at[b, slot].set(k_new[:, 0]),
            v=self.v.at[b, slot].set(v_new[:, 0]),
            positions=self.positions.at[b, slot].set(pos.astype(jnp.int32)),
            valid=self.valid.at[b, slot].set(True),
        )


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "positions", "valid"], meta_fields=[])


def attention_block(p, x: Array, positions: Array, *, theta: float,
                    causal: bool = True, window: int | None = None,
                    return_kv: bool = False):
    """Full-sequence self-attention (train / prefill).

    ``return_kv=True`` also returns a KVCache seeded with this sequence —
    full length for ATTN, ring-compacted to ``window`` slots for ATTN_SWA
    (slot of position p is p % window, matching ``KVCache.update``).
    """
    B, T = x.shape[:2]
    q, k, v = qkv_proj(p, x)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    pos1d = positions[0]
    valid = jnp.ones_like(pos1d, bool)
    o = chunked_attention(q, k, v, pos1d, pos1d, valid,
                          causal=causal, window=window,
                          sequential_positions=True)
    out = out_proj(p, o)
    if not return_kv:
        return out
    posB = jnp.broadcast_to(pos1d[None, :], (B, T)).astype(jnp.int32)
    if window is None:
        cache = KVCache(k=k, v=v, positions=posB,
                        valid=jnp.ones((B, T), bool))
    elif T <= window:
        # Ring cache must be exactly `window` slots; slot p%window == p here.
        pad = window - T
        cache = KVCache(
            k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            positions=jnp.pad(posB, ((0, 0), (0, pad))),
            valid=jnp.pad(jnp.ones((B, T), bool), ((0, 0), (0, pad))),
        )
    else:
        # Keep the last `window` tokens, placed at slot (position % window):
        # slot s holds source index T - window + (s - T) % window.
        W = window
        s = jnp.arange(W)
        src = T - W + (s - T) % W
        cache = KVCache(k=k[:, src], v=v[:, src], positions=posB[:, src],
                        valid=jnp.ones((B, W), bool))
    return out, cache


def attention_decode(p, x: Array, pos: Array, cache: KVCache, *, theta: float,
                     window: int | None = None) -> tuple[Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: (B,) current absolute position."""
    q, k, v = qkv_proj(p, x)
    q = apply_rope(q, pos[:, None], theta)
    k = apply_rope(k, pos[:, None], theta)
    cache = cache.update(k, v, pos)
    o = decode_attention(q, cache.k, cache.v, cache.positions, cache.valid,
                         pos, window=window)
    return out_proj(p, o), cache


# ------------------------------------------------------------ cross-attn
def init_cross_attention(key, d_model, n_heads, n_kv_heads, hd, *, dtype):
    p = init_attention(key, d_model, n_heads, n_kv_heads, hd,
                       qkv_bias=False, dtype=dtype)
    p["gate"] = jnp.zeros((), jnp.float32)   # tanh-gated residual (Flamingo-style)
    return p


def cross_attention_block(p, x: Array, mem_k: Array, mem_v: Array) -> Array:
    """Cross-attention to precomputed memory KV (B, M, KV, hd)."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    M = mem_k.shape[1]
    pos = jnp.arange(M)
    o = chunked_attention(q, mem_k, mem_v,
                          jnp.zeros((x.shape[1],), jnp.int32), pos,
                          jnp.ones((M,), bool), causal=False, window=None)
    return (jnp.tanh(p["gate"]) * out_proj(p, o).astype(jnp.float32)
            ).astype(x.dtype)


def cross_kv(p, mem: Array):
    """Project modality memory once: (B, M, d) -> KV tensors."""
    k = jnp.einsum("bmd,dhk->bmhk", mem, p["wk"])
    v = jnp.einsum("bmd,dhk->bmhk", mem, p["wv"])
    return k, v
