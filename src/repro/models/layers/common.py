"""Shared layer primitives: norms, embeddings, RoPE, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def variance_scaling(key, shape, fan_in, dtype=jnp.float32, scale=1.0):
    std = (scale / max(fan_in, 1)) ** 0.5
    return jax.random.normal(key, shape, dtype) * std


# ---------------------------------------------------------------- norms
def init_norm(d: int, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(p, x: Array, kind: str = "rmsnorm", eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        nrm = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        return (nrm * p["scale"]).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    nrm = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (nrm * p["scale"] + p["bias"]).astype(x.dtype)


def group_norm_heads(x: Array, eps: float = 1e-6) -> Array:
    """Per-head group norm (xLSTM block output norm). x: (..., H, hd)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------- embed
def init_embedding(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p, tokens: Array) -> Array:
    return jnp.take(p["table"], tokens, axis=0)


# ---------------------------------------------------------------- RoPE
def rope_freqs(hd: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (B, T, H, hd); positions: (B, T) int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation_fn(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu,
            "swish": jax.nn.silu}[name]
