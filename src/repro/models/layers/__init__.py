from . import attention, common, mamba, mlp, moe, xlstm  # noqa: F401
