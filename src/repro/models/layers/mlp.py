"""Dense feed-forward blocks: SwiGLU (llama-family) and GeLU/ReLU MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import activation_fn, variance_scaling

Array = jax.Array


def init_mlp(key, d_model: int, d_ff: int, activation: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if activation == "swiglu":
        return {
            "wg": variance_scaling(ks[0], (d_model, d_ff), d_model, dtype),
            "wu": variance_scaling(ks[1], (d_model, d_ff), d_model, dtype),
            "wd": variance_scaling(ks[2], (d_ff, d_model), d_ff, dtype),
        }
    return {
        "wu": variance_scaling(ks[0], (d_model, d_ff), d_model, dtype),
        "wd": variance_scaling(ks[1], (d_ff, d_model), d_ff, dtype),
    }


def apply_mlp(p, x: Array, activation: str) -> Array:
    if activation == "swiglu":
        g = jax.nn.silu(jnp.einsum("btd,df->btf", x, p["wg"]))
        u = jnp.einsum("btd,df->btf", x, p["wu"])
        return jnp.einsum("btf,fd->btd", g * u, p["wd"])
    act = activation_fn(activation)
    h = act(jnp.einsum("btd,df->btf", x, p["wu"]))
    return jnp.einsum("btf,fd->btd", h, p["wd"])
