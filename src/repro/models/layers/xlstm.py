"""xLSTM blocks [arXiv:2405.04517]: sLSTM (post-up-proj) and mLSTM (pre-up-proj).

Both use exponential gating with the max-stabilizer state ``m``; sLSTM has a
scalar memory with per-head recurrent gate projections, mLSTM has a matrix
memory ``C ∈ R^{hd×hd}`` updated as a gated outer-product (linear-attention
form) — which is what gives the architecture O(1) decode state and makes
``long_500k`` native (no KV cache).

Forward passes scan over time with small carries; decode is one step of the
same recurrence.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .common import group_norm_heads, variance_scaling
from .scan_utils import chunked_scan

Array = jax.Array


# ================================================================= sLSTM
def init_slstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    hd = d_model // n_heads
    ks = jax.random.split(key, 11)
    p = {}
    for gi, g in enumerate(("z", "i", "f", "o")):
        p[f"w{g}"] = variance_scaling(ks[2 * gi], (d_model, n_heads, hd),
                                      d_model, dtype)
        p[f"r{g}"] = variance_scaling(ks[2 * gi + 1], (n_heads, hd, hd), hd,
                                      dtype)
        p[f"b{g}"] = jnp.zeros((n_heads, hd), dtype)
    # Forget-gate bias init positive (retain memory early in training).
    p["bf"] = p["bf"] + 1.0
    # GeGLU FFN with the paper's 4/3 projection factor.
    pf = (4 * d_model) // 3
    p["up_g"] = variance_scaling(ks[8], (d_model, pf), d_model, dtype)
    p["up_u"] = variance_scaling(ks[9], (d_model, pf), d_model, dtype)
    p["down"] = variance_scaling(ks[10], (pf, d_model), pf, dtype)
    return p


@dataclasses.dataclass
class SLSTMState:
    h: Array  # (B, H, hd)
    c: Array
    n: Array
    m: Array

    @staticmethod
    def init(batch, n_heads, hd, dtype=jnp.float32):
        z = jnp.zeros((batch, n_heads, hd), jnp.float32)
        return SLSTMState(h=z, c=z, n=z, m=z)


jax.tree_util.register_dataclass(
    SLSTMState, data_fields=["h", "c", "n", "m"], meta_fields=[])


def _slstm_step(p, st: SLSTMState, x_t: Array) -> tuple[SLSTMState, Array]:
    """x_t: (B, d_model) -> new state, h output (B, H, hd)."""
    def gate(g):
        return (jnp.einsum("bd,dhk->bhk", x_t, p[f"w{g}"])
                + jnp.einsum("bhk,hkj->bhj", st.h.astype(x_t.dtype), p[f"r{g}"])
                + p[f"b{g}"]).astype(jnp.float32)
    z = jnp.tanh(gate("z"))
    o = jax.nn.sigmoid(gate("o"))
    it, ft = gate("i"), gate("f")
    m_new = jnp.maximum(ft + st.m, it)
    i = jnp.exp(it - m_new)
    f = jnp.exp(ft + st.m - m_new)
    c = f * st.c + i * z
    n = f * st.n + i
    h = o * c / jnp.maximum(n, 1e-6)
    return SLSTMState(h=h, c=c, n=n, m=m_new), h


def slstm_forward(p, x: Array, *, return_state: bool = False):
    """x: (B, T, d) -> (B, T, d) (mixer output incl. GeGLU FFN)."""
    B, T, d = x.shape
    H, hd = p["wz"].shape[1], p["wz"].shape[2]
    st0 = SLSTMState.init(B, H, hd)
    def step(st, x_t):
        st, h = _slstm_step(p, st, x_t)
        return st, h
    st_last, hs = chunked_scan(step, st0, x.swapaxes(0, 1))
    h = group_norm_heads(hs.swapaxes(0, 1)).reshape(B, T, d).astype(x.dtype)
    g = jax.nn.gelu(jnp.einsum("btd,df->btf", h, p["up_g"]))
    u = jnp.einsum("btd,df->btf", h, p["up_u"])
    out = jnp.einsum("btf,fd->btd", g * u, p["down"])
    return (out, st_last) if return_state else out


def slstm_decode(p, x: Array, st: SLSTMState) -> tuple[Array, SLSTMState]:
    B = x.shape[0]
    st, h = _slstm_step(p, st, x[:, 0])
    h = group_norm_heads(h[:, None]).reshape(B, 1, -1).astype(x.dtype)
    g = jax.nn.gelu(jnp.einsum("btd,df->btf", h, p["up_g"]))
    u = jnp.einsum("btd,df->btf", h, p["up_u"])
    return jnp.einsum("btf,fd->btd", g * u, p["down"]), st


# ================================================================= mLSTM
def init_mlstm(key, d_model: int, n_heads: int, dtype=jnp.float32):
    di = 2 * d_model
    hd = di // n_heads
    ks = jax.random.split(key, 8)
    return {
        "up": variance_scaling(ks[0], (d_model, 2 * di), d_model, dtype),
        "conv_w": variance_scaling(ks[1], (4, di), 4, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": variance_scaling(ks[2], (di, n_heads, hd), di, dtype),
        "wk": variance_scaling(ks[3], (di, n_heads, hd), di, dtype),
        "wv": variance_scaling(ks[4], (di, n_heads, hd), di, dtype),
        "wi": variance_scaling(ks[5], (di, n_heads), di, jnp.float32),
        "bi": jnp.zeros((n_heads,), jnp.float32),
        "wf": variance_scaling(ks[6], (di, n_heads), di, jnp.float32),
        "bf": jnp.full((n_heads,), 3.0, jnp.float32),
        "down": variance_scaling(ks[7], (di, d_model), di, dtype),
    }


@dataclasses.dataclass
class MLSTMState:
    conv: Array  # (B, 3, di)
    C: Array     # (B, H, hd, hd)
    n: Array     # (B, H, hd)
    m: Array     # (B, H)

    @staticmethod
    def init(batch, n_heads, hd, di, dtype=jnp.float32):
        return MLSTMState(
            conv=jnp.zeros((batch, 3, di), dtype),
            C=jnp.zeros((batch, n_heads, hd, hd), jnp.float32),
            n=jnp.zeros((batch, n_heads, hd), jnp.float32),
            m=jnp.zeros((batch, n_heads), jnp.float32),
        )


jax.tree_util.register_dataclass(
    MLSTMState, data_fields=["conv", "C", "n", "m"], meta_fields=[])


def _mlstm_qkvif(p, xc: Array, xu: Array):
    """xc: post-conv (..., di); xu: pre-conv (..., di)."""
    hd = p["wq"].shape[2]
    q = jnp.einsum("...i,ihk->...hk", xc, p["wq"])
    k = jnp.einsum("...i,ihk->...hk", xc, p["wk"]) / (hd ** 0.5)
    v = jnp.einsum("...i,ihk->...hk", xu, p["wv"])
    it = jnp.einsum("...i,ih->...h", xu.astype(jnp.float32), p["wi"]) + p["bi"]
    ft = jnp.einsum("...i,ih->...h", xu.astype(jnp.float32), p["wf"]) + p["bf"]
    return q, k, v, it, ft


def _mlstm_step(p, st: MLSTMState, q, k, v, it, ft):
    """Single recurrence step; q/k/v: (B, H, hd); it/ft: (B, H)."""
    m_new = jnp.maximum(ft + st.m, it)
    i = jnp.exp(it - m_new)[..., None]                    # (B, H, 1)
    f = jnp.exp(ft + st.m - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C = f[..., None] * st.C + i[..., None] * vf[..., None] * kf[..., None, :]
    n = f * st.n + i * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = num / den[..., None]
    return MLSTMState(conv=st.conv, C=C, n=n, m=m_new), h


def mlstm_forward(p, x: Array, *, return_state: bool = False):
    """x: (B, T, d) -> (B, T, d)."""
    B, T, d = x.shape
    H, hd = p["wq"].shape[1], p["wq"].shape[2]
    di = 2 * d
    uz = jnp.einsum("btd,de->bte", x, p["up"])
    xu, z = jnp.split(uz, 2, axis=-1)                     # (B, T, di)
    xpad = jnp.pad(xu, ((0, 0), (3, 0), (0, 0)))
    windows = jnp.stack([xpad[:, i : i + T] for i in range(4)], axis=0)
    xc = jax.nn.silu(jnp.einsum("kbti,ki->bti", windows, p["conv_w"])
                     + p["conv_b"])
    q, k, v, it, ft = _mlstm_qkvif(p, xc, xu)

    def step(st, inp):
        st, h = _mlstm_step(p, st, *inp)
        return st, h

    st0 = MLSTMState.init(B, H, hd, di)
    st_last, hs = chunked_scan(
        step, st0,
        (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
         it.swapaxes(0, 1), ft.swapaxes(0, 1)))
    h = group_norm_heads(hs.swapaxes(0, 1)).reshape(B, T, di).astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", h, p["down"])
    if not return_state:
        return out
    tail = xu[:, -3:, :] if T >= 3 else jnp.pad(xu, ((0, 0), (3 - T, 0), (0, 0)))
    return out, MLSTMState(conv=tail, C=st_last.C, n=st_last.n, m=st_last.m)


def mlstm_decode(p, x: Array, st: MLSTMState) -> tuple[Array, MLSTMState]:
    B, _, d = x.shape
    di = 2 * d
    uz = jnp.einsum("btd,de->bte", x, p["up"])
    xu, z = jnp.split(uz[:, 0], 2, axis=-1)               # (B, di)
    conv_in = jnp.concatenate([st.conv, xu[:, None]], axis=1)
    xc = jax.nn.silu(jnp.einsum("bki,ki->bi", conv_in, p["conv_w"])
                     + p["conv_b"])
    q, k, v, it, ft = _mlstm_qkvif(p, xc, xu)
    st2, h = _mlstm_step(p, st, q, k, v, it, ft)
    h = group_norm_heads(h[:, None]).reshape(B, 1, di).astype(x.dtype)
    h = h * jax.nn.silu(z)[:, None]
    out = jnp.einsum("bti,id->btd", h, p["down"])
    return out, MLSTMState(conv=conv_in[:, 1:], C=st2.C, n=st2.n, m=st2.m)
